//! Two-player zero-sum matrix game solved with distributed Q-GenX under
//! the *random player updating* oracle (paper Appendix J.2 — a structural
//! source of relative noise), with simplex projection.
//!
//! Demonstrates: compact-domain VIs, exploitability as the gap metric, and
//! the relative-noise fast-rate behaviour on a game.
//!
//! ```bash
//! cargo run --release --example matrix_game
//! ```

use qgenx::coordinator::Compressor;
use qgenx::config::QuantConfig;
use qgenx::oracle::{MatrixGame, Operator, Oracle, RandomPlayerOracle};
use qgenx::util::{axpy, mean_into, Rng};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 32; // actions per player
    let k = 4; // workers
    let t_max = 4000;
    let mut rng = Rng::seed_from(2024);
    let game = Arc::new(MatrixGame::random(2 * n, &mut rng)?);
    let d = game.dim();

    // K workers, each with a private random-player oracle + compressor.
    let root = Rng::seed_from(7);
    let mut oracles: Vec<RandomPlayerOracle> = (0..k)
        .map(|w| RandomPlayerOracle::new(game.clone(), 2, root.fork(w as u64)).unwrap())
        .collect();
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&QuantConfig::default(), root.fork(100 + w as u64)))
        .collect::<qgenx::Result<_>>()?;

    // Projected extra-gradient with decaying step (projection keeps us on
    // the simplex product, so we drive the EG update manually here).
    let mut z = game.uniform_start();
    let mut z_avg = vec![0.0f64; d];
    let gamma0 = 1.0;
    let mut decoded = vec![vec![0.0f32; d]; k];
    let mut mean = vec![0.0f32; d];
    let mut total_bits = 0u64;

    println!("matrix game: {n}x{n}, K={k} workers, random-player oracle, UQ4+QAda");
    println!("  iter   exploitability (avg iterate)");
    for t in 1..=t_max {
        let gamma = (gamma0 / (1.0 + t as f64 / 50.0).sqrt()) as f32;

        // leg 1
        exchange(&game, &mut oracles, &mut comps, &z, &mut decoded, &mut total_bits)?;
        let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut mean);
        let mut z_half = z.clone();
        axpy(-gamma, &mean, &mut z_half);
        game.project(&mut z_half);

        // leg 2
        exchange(&game, &mut oracles, &mut comps, &z_half, &mut decoded, &mut total_bits)?;
        let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut mean);
        axpy(-gamma, &mean, &mut z);
        game.project(&mut z);

        for i in 0..d {
            z_avg[i] += z_half[i] as f64;
        }
        if t % 500 == 0 {
            let avg: Vec<f32> = z_avg.iter().map(|&v| (v / t as f64) as f32).collect();
            let mut proj = avg.clone();
            game.project(&mut proj);
            println!("  {t:>5}   {:>10.5}", game.exploitability(&proj));
        }
    }
    let avg: Vec<f32> = z_avg.iter().map(|&v| (v / t_max as f64) as f32).collect();
    let mut proj = avg;
    game.project(&mut proj);
    let expl = game.exploitability(&proj);
    println!("final exploitability: {expl:.5}  (uniform start: {:.5})",
        game.exploitability(&game.uniform_start()));
    println!("total wire bits: {total_bits} ({:.2} bits/coordinate/round)",
        total_bits as f64 / (2.0 * t_max as f64 * k as f64 * d as f64));
    assert!(expl < game.exploitability(&game.uniform_start()));
    Ok(())
}

fn exchange(
    _game: &Arc<MatrixGame>,
    oracles: &mut [RandomPlayerOracle],
    comps: &mut [Compressor],
    at: &[f32],
    decoded: &mut [Vec<f32>],
    total_bits: &mut u64,
) -> qgenx::Result<()> {
    let d = at.len();
    let mut g = vec![0.0f32; d];
    for w in 0..oracles.len() {
        oracles[w].sample(at, &mut g);
        let (bytes, bits) = comps[w].compress(&g)?;
        *total_bits += bits;
        comps[w].decompress(&bytes, &mut decoded[w])?;
    }
    Ok(())
}
