//! The distributed coordinator — Algorithm 1 of the paper.
//!
//! Two execution modes share all of the math:
//!
//! * [`inline`] — single-threaded simulation of the `K` processors.
//!   Deterministic, allocation-light, used by the rate/figure benches where
//!   thousands of runs are swept.
//! * [`threaded`] — `K` real worker threads exchanging *actual encoded
//!   bytes* through the [`crate::net::AllGather`] transport, each holding a
//!   replicated [`crate::algo::QGenX`] state (data-parallel replication:
//!   identical decoded vectors ⇒ identical replicas). This is the system
//!   the examples and the E2E drivers run on.
//!
//! Per-iteration protocol (both modes), following Algorithm 1:
//!
//! 1. if `t ∈ U` (level-update schedule): workers exchange sufficient
//!    statistics (stat wire-format v2 for single-codec pipelines, the
//!    per-layer v3 for layer-wise pipelines — byte layouts in
//!    `docs/WIRE.md`; counted as traffic), pool them in rank order, and
//!    each deterministically re-optimizes levels, rebuilds Huffman
//!    codecs, and — layer-wise with a bit budget — re-runs the Theorem-1
//!    allocator (identical inputs ⇒ identical tables and allocations).
//!    The payload is non-empty whenever *anything* adapts — QAda level
//!    placement, the Huffman probability model, or the budget allocator —
//!    matching what `update_levels` consumes
//!    ([`crate::config::QuantConfig::adapts`] is the single source of
//!    truth).
//! 2. variant-dependent base exchange (`V̂_{k,t}`): DE quantizes + exchanges
//!    fresh oracle queries at `X_t`; DA/OptDA send nothing.
//! 3. extrapolate to `X_{t+1/2}`.
//! 4. quantize + exchange `V̂_{k,t+1/2}`; everyone updates the replica.
//!
//! ## Runner families
//!
//! The config selects one of three scenario families, in both execution
//! modes:
//!
//! * **exact** — the protocol above over an exact topology: per-step dual
//!   exchange, all replicas bit-identical at every step (the seed
//!   behavior, `local.steps = 1`, non-gossip `[topo]`).
//! * **gossip** — same per-step protocol, but dual vectors average over
//!   closed graph neighborhoods only; replicas drift (`consensus_dist`).
//! * **local** (`local.steps = H ≥ 2`) — `H` private extra-gradient
//!   iterations per replica between communication rounds, then one
//!   quantized **model-delta** exchange and a resync by averaging
//!   (`inline::run_local` / the threaded local loop). Communication drops
//!   from one-to-two dual rounds per iteration to one delta round per `H`
//!   iterations; the `sync_drift` / `sync_bits` series and the `syncs` /
//!   `bits_per_sync` / `mean_sync_drift` scalars account for it. `H = 1`
//!   deliberately runs the exact (or gossip) family — with communication
//!   every iteration the per-step dual exchange *is* the algorithm, so the
//!   seed trajectory is reproduced bit-for-bit.
//!
//! ## Topology selection
//!
//! Both modes route the *data-plane* exchanges (steps 2 and 4) through the
//! [`crate::topo::Collective`] built from the `[topo]` config table:
//!
//! * `full-mesh` (default) — the paper's flat allgather; byte- and
//!   cost-identical to the pre-topology coordinator.
//! * `star` / `ring` / `hierarchical` — **exact**: they deliver the same
//!   rank-order mean via in-network aggregation, so trajectories are
//!   bit-identical to full mesh while modeled time/traffic follow the
//!   per-topology α-β formulas in [`crate::topo::cost`].
//! * `gossip` — **inexact**: each worker averages over its closed graph
//!   neighborhood, replicas genuinely diverge (tracked as the
//!   `consensus_dist` series/scalar via
//!   [`crate::metrics::consensus_distance`]), and the threaded runner skips
//!   the replica-equality assertion.
//!
//! The *control plane* (step 1's stat pooling) is always global and
//! accounted as a full-mesh round, even under gossip: the decode side of
//! the wire format requires bit-identical levels + Huffman tables (and,
//! layer-wise, bit allocations) on every worker, and the stat payloads are
//! small and infrequent. Gossip decentralizes the data plane only.
//!
//! ## Compression pipeline selection
//!
//! Orthogonal to the runner family and topology, `[quant.layers]` selects
//! the per-worker [`pipeline::Compressor`] shape: FP32, the single-codec
//! seed pipeline, or layer-wise heterogeneous quantization (Q-GenX-LW —
//! per-layer levels/codec/statistics with optional Theorem-1 bit-budget
//! allocation; `docs/CONFIG.md` documents the table, `docs/WIRE.md` the
//! formats). Every runner records the per-layer `layer_bits/<name>` /
//! `layer_variance/<name>` series and scalars when the layer-wise pipeline
//! is active. A single-layer map reproduces the un-layered runs
//! bit-for-bit in all three families (regression-tested).
//!
//! Timing: compute (oracle + encode + decode) is *measured*; network time
//! is *modeled* (α-β on the exact encoded byte counts) — see DESIGN.md §5.4.

pub mod inline;
pub mod pipeline;
pub mod schedule;
pub mod threaded;

pub use inline::{run_experiment, run_qsgda_baseline};
pub use pipeline::Compressor;
pub use schedule::UpdateSchedule;
pub use threaded::run_threaded;
