//! Local-steps execution: `H ≥ 1` extra-gradient iterations on a private
//! oracle between communication rounds (the "local updates" axis of
//! communication reduction — Beznosikov et al.'s three pillars, Zhang et
//! al.'s local GDA — composed with the paper's `CODE ∘ Q` compression).
//!
//! [`LocalQGenX`] wraps one replica's [`QGenX`] state (with `K = 1`: the
//! replica only ever averages its own oracle) plus the synchronization
//! bookkeeping:
//!
//! * [`LocalQGenX::local_round`] — one full extra-gradient iteration
//!   (base query if the variant needs one, extrapolate, half-step sample,
//!   update) against the worker's private oracle. No communication.
//! * [`LocalQGenX::delta`] — the model delta `X_t − X_sync` accumulated
//!   since the last synchronization; this (not per-step duals) is what the
//!   replicas quantize and exchange, so the wire cost is one vector per
//!   worker per sync instead of one or two per iteration.
//! * [`LocalQGenX::resync`] — move the replica to
//!   `X_sync + mean(decoded deltas)` via [`QGenX::shift_world`] and open
//!   the next local segment from there.
//!
//! Invariances worth knowing:
//!
//! * `resync` does not touch the dual accumulator, the adaptive step-size
//!   or the ergodic history — each replica keeps *its own* optimizer state
//!   across syncs (the standard local-update design; resetting state every
//!   sync destroys the adaptive γ_t schedule).
//! * The per-replica ergodic average is translated by the consensus
//!   correction `mean_delta − delta_r`, and those corrections sum to zero
//!   across replicas — so the *mean* ergodic average the coordinator
//!   evaluates is unaffected by the resync bookkeeping.
//! * With exact (all-delivering) sync topologies every replica decodes the
//!   same payload set, so all replicas compute the **same consensus point**
//!   ([`LocalQGenX::sync_base`]) bit-for-bit after every sync. The
//!   replica's own iterate is moved onto it by an origin shift whose f32
//!   arithmetic can land one rounding ulp away (and differently per
//!   replica, since each adds a different internal offset) — so
//!   coordinators that assert replica agreement compare sync bases, not
//!   raw iterates. Drift *within* a local segment is tracked by the
//!   coordinator's `sync_drift` series.
//!
//! `H = 1` is deliberately *not* expressed through this wrapper: with one
//! local step between syncs the algorithm communicates every iteration
//! anyway, and the seed's per-step dual exchange (Algorithm 1) is both
//! cheaper in state and the trajectory the paper's theorems describe — the
//! coordinator dispatches `local.steps = 1` to the exact runner, which
//! reproduces the seed bit-for-bit.

use super::method::{method_state, MethodState};
use super::qgenx::QGenX;
use crate::config::{AlgoConfig, Variant};
use crate::error::Result;
use crate::oracle::Oracle;

/// One worker's replica in local-steps mode: a `K = 1` method state
/// (any [`MethodState`] — QGenX historically, hence the name) plus the
/// last synchronization point.
#[derive(Clone)]
pub struct LocalQGenX {
    state: Box<dyn MethodState>,
    /// Which qgenx-family variant backs `state` (meaningful only for the
    /// qgenx method; retained for the legacy accessor).
    variant: Variant,
    /// World-coordinate iterate at the last sync (`X_sync`); deltas are
    /// measured against this and resync rebases it.
    sync_base: Vec<f32>,
    /// Local iterations since the last sync (diagnostic).
    steps_since_sync: usize,
}

impl LocalQGenX {
    pub fn new(variant: Variant, x0: &[f32], gamma0: f64, adaptive: bool) -> Self {
        LocalQGenX {
            state: Box::new(QGenX::new(variant, x0, 1, gamma0, adaptive)),
            variant,
            sync_base: x0.to_vec(),
            steps_since_sync: 0,
        }
    }

    /// Build a replica for whatever `[algo]` selects — the method-cadence
    /// seam applied to the local-steps family. For the default method this
    /// is identical to [`Self::new`] with the configured variant.
    pub fn from_algo(algo: &AlgoConfig, x0: &[f32]) -> Self {
        LocalQGenX {
            state: method_state(algo, x0, 1),
            variant: algo.variant,
            sync_base: x0.to_vec(),
            steps_since_sync: 0,
        }
    }

    /// One extra-gradient iteration against the private oracle. `g_buf` is
    /// caller-provided scratch of length `d` (avoids per-step allocation in
    /// the inner loop — the only allocations left are the `Vec<Vec<f32>>`
    /// views `QGenX` takes).
    pub fn local_round(&mut self, oracle: &mut dyn Oracle, g_buf: &mut [f32]) -> Result<()> {
        let base: Vec<Vec<f32>> = match self.state.base_query() {
            Some(xq) => {
                oracle.sample(&xq, g_buf);
                vec![g_buf.to_vec()]
            }
            None => Vec::new(),
        };
        let x_half = self.state.extrapolate(&base)?;
        oracle.sample(&x_half, g_buf);
        self.state.update(&[g_buf.to_vec()])?;
        self.steps_since_sync += 1;
        Ok(())
    }

    /// Model delta accumulated since the last sync: `X_t − X_sync`.
    pub fn delta(&self) -> Vec<f32> {
        let x = self.state.x_world();
        x.iter().zip(self.sync_base.iter()).map(|(a, b)| a - b).collect()
    }

    /// Re-synchronize: move to `X_sync + mean_delta` (the average of the
    /// decoded deltas, computed by the coordinator) and start the next
    /// local segment there.
    pub fn resync(&mut self, mean_delta: &[f32]) -> Result<()> {
        let target: Vec<f32> =
            self.sync_base.iter().zip(mean_delta.iter()).map(|(b, d)| b + d).collect();
        self.state.shift_world(&target)?;
        self.sync_base = target;
        self.steps_since_sync = 0;
        Ok(())
    }

    /// Current iterate in world coordinates.
    pub fn x_world(&self) -> Vec<f32> {
        self.state.x_world()
    }

    /// The consensus point established by the last [`Self::resync`] (the
    /// starting point of the current local segment). Computed from the
    /// decoded deltas by identical arithmetic on every replica, so under
    /// exact sync topologies it is bit-identical across replicas — the
    /// quantity replica-agreement invariants must compare (the raw
    /// [`Self::x_world`] can sit one origin-shift rounding ulp off it).
    pub fn sync_base(&self) -> &[f32] {
        &self.sync_base
    }

    /// Per-replica ergodic average (see module docs: the *mean* over
    /// replicas is invariant under resync bookkeeping).
    pub fn ergodic_average(&self) -> Vec<f32> {
        self.state.ergodic_average()
    }

    pub fn gamma(&self) -> f64 {
        self.state.gamma()
    }

    pub fn steps_since_sync(&self) -> usize {
        self.steps_since_sync
    }

    /// The qgenx-family variant backing this replica. Meaningful only
    /// when the method is `qgenx` (the default); other methods carry the
    /// config's (unused) variant along.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Cumulative oracle calls made by this replica (cadence-dependent:
    /// one per local round for single-call methods, two for EG-shaped).
    pub fn oracle_calls(&self) -> u64 {
        self.state.oracle_calls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{ExactOracle, MonotoneQuadratic, Operator};
    use crate::util::{dist_sq, Rng};
    use std::sync::Arc;

    fn problem(d: usize) -> Arc<MonotoneQuadratic> {
        let mut rng = Rng::seed_from(42);
        Arc::new(MonotoneQuadratic::random(d, 0.3, 1.0, &mut rng).unwrap())
    }

    #[test]
    fn delta_tracks_movement_and_resync_rebases() {
        let d = 8;
        let op = problem(d);
        let mut oracle = ExactOracle::new(op.clone());
        let mut rep = LocalQGenX::new(Variant::DualExtrapolation, &vec![0.5f32; d], 0.3, true);
        assert_eq!(rep.delta(), vec![0.0f32; d]);
        let mut g = vec![0.0f32; d];
        for _ in 0..4 {
            rep.local_round(&mut oracle, &mut g).unwrap();
        }
        assert_eq!(rep.steps_since_sync(), 4);
        let delta = rep.delta();
        assert!(delta.iter().any(|&x| x != 0.0), "iterate must have moved");
        // Resync exactly onto own delta = stay put, but segment restarts.
        rep.resync(&delta).unwrap();
        assert_eq!(rep.steps_since_sync(), 0);
        // The origin shift is f32 arithmetic: the iterate lands on the new
        // sync base up to a rounding ulp, not exactly.
        assert!(rep.delta().iter().all(|&x| x.abs() < 1e-5));
        // Resync onto a different consensus point moves the iterate there.
        let before = rep.x_world();
        let shift = vec![0.25f32; d];
        rep.resync(&shift).unwrap();
        let after = rep.x_world();
        for i in 0..d {
            assert!((after[i] - (before[i] + 0.25)).abs() < 1e-6);
        }
    }

    #[test]
    fn two_replicas_converge_under_averaging() {
        // K = 2 replicas with private exact oracles, H = 5 local steps,
        // plain (unquantized) delta averaging: the consensus trajectory
        // should approach the solution.
        let d = 12;
        let op = problem(d);
        let xs = op.solution().unwrap();
        let x0 = vec![0.0f32; d];
        let mut reps: Vec<LocalQGenX> = (0..2)
            .map(|_| LocalQGenX::new(Variant::DualExtrapolation, &x0, 0.25, true))
            .collect();
        let mut oracles: Vec<ExactOracle> =
            (0..2).map(|_| ExactOracle::new(op.clone())).collect();
        let mut g = vec![0.0f32; d];
        let d0 = dist_sq(&x0, &xs);
        for _ in 0..400 {
            for _ in 0..5 {
                for (rep, or) in reps.iter_mut().zip(oracles.iter_mut()) {
                    rep.local_round(or, &mut g).unwrap();
                }
            }
            let deltas: Vec<Vec<f32>> = reps.iter().map(|r| r.delta()).collect();
            let mean: Vec<f32> = (0..d)
                .map(|i| deltas.iter().map(|dl| dl[i]).sum::<f32>() / 2.0)
                .collect();
            for rep in reps.iter_mut() {
                rep.resync(&mean).unwrap();
            }
            // exact decode on both sides -> replicas are identical post-sync
            assert_eq!(reps[0].x_world(), reps[1].x_world());
        }
        let mut mean_avg = vec![0.0f32; d];
        for rep in &reps {
            for (m, &x) in mean_avg.iter_mut().zip(rep.ergodic_average().iter()) {
                *m += x / 2.0;
            }
        }
        let ratio = dist_sq(&mean_avg, &xs) / d0.max(1e-12);
        assert!(ratio < 0.05, "local-steps consensus ratio {ratio}");
    }

    #[test]
    fn all_methods_drive_local_rounds() {
        // The cadence seam in the local family: PEG does one oracle call
        // per local round, EG-AA two, and both sync/resync like QGenX.
        use crate::config::Method;
        let d = 6;
        let op = problem(d);
        for (method, calls_per_round) in [(Method::Peg, 3u64), (Method::EgAa, 6)] {
            let algo = AlgoConfig { method, gamma0: 0.3, ..AlgoConfig::default() };
            let mut oracle = ExactOracle::new(op.clone());
            let mut rep = LocalQGenX::from_algo(&algo, &vec![0.0f32; d]);
            let mut g = vec![0.0f32; d];
            for _ in 0..3 {
                rep.local_round(&mut oracle, &mut g).unwrap();
            }
            assert_eq!(rep.oracle_calls(), calls_per_round, "{method:?}");
            assert!(rep.x_world().iter().all(|x| x.is_finite()));
            let delta = rep.delta();
            rep.resync(&delta).unwrap();
            assert_eq!(rep.steps_since_sync(), 0);
        }
    }

    #[test]
    fn all_variants_drive_local_rounds() {
        let d = 6;
        let op = problem(d);
        for v in
            [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging]
        {
            let mut oracle = ExactOracle::new(op.clone());
            let mut rep = LocalQGenX::new(v, &vec![0.0f32; d], 0.5, true);
            let mut g = vec![0.0f32; d];
            for _ in 0..3 {
                rep.local_round(&mut oracle, &mut g).unwrap();
            }
            assert!(rep.x_world().iter().all(|x| x.is_finite()));
            let delta = rep.delta();
            rep.resync(&delta).unwrap();
        }
    }
}
