//! First-order VI algorithms.
//!
//! * [`qgenx`] — the paper's contribution: the Q-GenX template
//!
//!   ```text
//!   X_{t+1/2} = X_t − (γ_t/K) Σ_k V̂_{k,t}
//!   Y_{t+1}   = Y_t − (1/K)  Σ_k V̂_{k,t+1/2}
//!   X_{t+1}   = γ_{t+1} Y_{t+1}
//!   ```
//!
//!   with the adaptive step-size of Theorems 3/4 and the three unified
//!   variants (Examples 3.1–3.3) selected by
//!   [`crate::config::Variant`]: dual averaging (`V̂_t ≡ 0`), dual
//!   extrapolation (fresh query at `X_t`), optimistic dual averaging
//!   (reuse of the previous half-step query).
//! * [`stepsize`] — the adaptive rule
//!   `γ_t = K (1 + Σ_{i<t} Σ_k ‖V̂_{k,i} − V̂_{k,i+1/2}‖²)^{−1/2}` (shared
//!   by all variants; never needs σ, c, or β).
//! * [`local`] — local-steps replica wrapper ([`LocalQGenX`]): `H`
//!   private extra-gradient iterations between communication rounds, with
//!   quantized model-delta synchronization (the third communication-
//!   reduction axis next to compression and topology).
//! * [`baselines`] — full-precision extra-gradient (Korpelevich), SGDA,
//!   and QSGDA (Beznosikov et al. 2022) for the Figure-4 comparison.
//! * [`method`] — the method-cadence seam: every first-class algorithm is
//!   a [`MethodState`] phase machine owning its per-iteration oracle-call
//!   and exchange cadence; the coordinator policies execute the plan it
//!   exposes and never assume the two-call Q-GenX shape.
//! * [`past`] — past extra-gradient / optimistic gradient
//!   ([`PastExtraGradient`], `[algo] method = "peg"`): ONE oracle call and
//!   ONE quantized exchange per iteration by reusing the previous
//!   half-step dual (the `prev_half` idiom generalized from OptDA).
//! * [`anderson`] — safeguarded EG-AA(1) ([`AndersonEg`],
//!   `[algo] method = "eg-aa"`): extra-gradient cadence plus a depth-1
//!   Anderson candidate behind a residual-decrease guard that can never
//!   add a wire round.

pub mod anderson;
pub mod baselines;
pub mod local;
pub mod method;
pub mod past;
pub mod qgenx;
pub mod stepsize;

pub use anderson::AndersonEg;
pub use baselines::{ExtraGradient, Sgda};
pub use local::LocalQGenX;
pub use method::{method_state, MethodState};
pub use past::PastExtraGradient;
pub use qgenx::{QGenX, QGenXPhase};
pub use stepsize::AdaptiveStepSize;
