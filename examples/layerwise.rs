//! Walkthrough: layer-wise heterogeneous quantization (Q-GenX-LW).
//!
//! Deep-learning dual vectors concatenate per-layer gradients whose norm
//! profiles differ by orders of magnitude. With `[quant.layers]` each
//! layer gets its own level sequence, codec and sufficient statistics; the
//! v3 stat exchange pools them per layer across workers, and an optional
//! global bit budget (`budget = B`) lets `quant::alloc` re-split
//! bits/coordinate by the Theorem-1 variance objective — wide-and-cold
//! layers surrender bits to narrow-and-hot ones.
//!
//! ```bash
//! cargo run --release --example layerwise
//! # or, from the CLI (the count form auto-splits at any problem.dim;
//! # explicit `--layers name:end,…` bounds must fit the configured dim):
//! qgenx run --layers 3 --iters 600
//! ```

use qgenx::config::ExperimentConfig;
use qgenx::coordinator::run_experiment;

fn base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "layerwise".into();
    // LM-shaped synthetic oracle: 60% cold "embed", 30% "body", 10% hot
    // "head", under relative noise so the heterogeneity persists.
    cfg.problem.kind = "lm-proxy".into();
    cfg.problem.dim = 640;
    cfg.problem.noise = "relative".into();
    cfg.problem.rel_c = 0.5;
    cfg.workers = 4;
    cfg.iters = 600;
    cfg.eval_every = 150;
    cfg.quant.mode = qgenx::config::QuantMode::parse("uq4").unwrap();
    cfg.quant.scheme = qgenx::config::LevelScheme::Uniform;
    cfg.quant.codec = qgenx::coding::SymbolCodec::Fixed;
    cfg.quant.bucket_size = 64;
    cfg
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("Q-GenX-LW on the lm-proxy oracle (d = 640, K = 4, 4-bit budget).\n");

    // 1) The regression contract: a one-layer map is the seed pipeline,
    //    bit for bit.
    let baseline = run_experiment(&base())?;
    let mut one = base();
    one.quant.layers.names = vec!["all".into()];
    let one_rec = run_experiment(&one)?;
    assert_eq!(
        baseline.get("gap").unwrap().ys(),
        one_rec.get("gap").unwrap().ys(),
        "single-layer map must reproduce the seed trajectory bit-for-bit"
    );
    assert_eq!(baseline.scalar("total_bits"), one_rec.scalar("total_bits"));
    println!("single-layer map == seed pipeline: identical trajectory and wire bits ✓\n");

    // 2) Layer-wise with a 4-bit/coordinate budget, layers aligned with
    //    the oracle's blocks.
    let mut lw = base();
    lw.quant.layers.names = vec!["embed".into(), "body".into(), "head".into()];
    lw.quant.layers.bounds = vec![384, 576];
    lw.quant.layers.budget = 4.0;
    let lw_rec = run_experiment(&lw)?;

    println!(
        "{:<12} {:>10} {:>12} {:>10}",
        "scheme", "final gap", "wire MiB", "eps_q"
    );
    for (label, rec) in [("uniform", &baseline), ("layer-wise", &lw_rec)] {
        println!(
            "{label:<12} {:>10.5} {:>12.3} {:>10.3}",
            rec.get("gap").unwrap().last().unwrap(),
            rec.scalar("total_bits").unwrap() / 8.0 / 1048576.0,
            rec.scalar("epsilon_q").unwrap(),
        );
    }
    println!();
    assert_eq!(lw_rec.scalar("layers"), Some(3.0));
    println!("{:<8} {:>8} {:>12} {:>14}", "layer", "levels", "wire MiB", "eps_q(layer)");
    for name in ["embed", "body", "head"] {
        println!(
            "{name:<8} {:>8.0} {:>12.3} {:>14.3}",
            lw_rec.scalar(&format!("layer_levels/{name}")).unwrap(),
            lw_rec.scalar(&format!("layer_bits/{name}")).unwrap() / 8.0 / 1048576.0,
            lw_rec.scalar(&format!("layer_variance/{name}")).unwrap(),
        );
    }

    // The budget is a hard cap on mean symbol bits, so the layer-wise run
    // cannot meaningfully out-spend uniform UQ4 (small slack: per-layer
    // frames + sign-bit differences).
    let bits_u = baseline.scalar("total_bits").unwrap();
    let bits_l = lw_rec.scalar("total_bits").unwrap();
    assert!(
        bits_l <= bits_u * 1.15,
        "budgeted layer-wise must stay near the uniform wire cost: {bits_l} vs {bits_u}"
    );

    println!(
        "\nReading the table:\n\
         * the allocator (re-run at every level update from the pooled v3\n\
           per-layer statistics) strips the cold embed block down to few\n\
           levels and spends the freed bits on the hot head block;\n\
         * mean symbol bits stay within the 4-bit budget, so the wire cost\n\
           matches uniform UQ4 while the blended ε_Q drops — variance where\n\
           the mass is, bits where they matter;\n\
         * all of it composes with the topo collectives and local steps:\n\
           try `[topo] kind = \"ring\"` or `[local] steps = 4` on top.\n\
         \n\
         Config-file form:  [quant.layers]  names = [\"embed\",\"body\",\"head\"]\n\
                            bounds = [384, 576]   budget = 4.0\n\
         plus optional per-layer overrides in [quant.layers.<name>] tables;\n\
         see docs/CONFIG.md. `cargo bench --bench layerwise_tradeoff` runs\n\
         the matched-gap accounting on the LM and GAN proxy oracles."
    );
    Ok(())
}
