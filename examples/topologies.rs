//! Walkthrough: the same Q-GenX experiment over every exchange topology.
//!
//! Algorithm 1 assumes a flat all-to-all broadcast; the `topo` subsystem
//! generalizes the exchange to star (sharded parameter server), ring,
//! two-level hierarchical, and random-regular gossip graphs — all moving
//! the *real* encoded wire bytes through the threaded coordinator's
//! transport. Exact topologies (everything but gossip) reproduce the
//! full-mesh trajectory bit-for-bit and differ only in modeled cost;
//! gossip trades exactness for locality, which the consensus-distance
//! metric quantifies.
//!
//! ```bash
//! cargo run --release --example topologies
//! ```

use qgenx::benchkit::example_iters;
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::run_threaded;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "topologies".into();
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 64;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.5;
    cfg.workers = 8;
    cfg.iters = example_iters(400);
    cfg.eval_every = (cfg.iters / 4).max(1);

    println!(
        "Q-GenX, quadratic VI d={} K={} workers, uq4 adaptive quantization.",
        cfg.problem.dim, cfg.workers
    );
    println!("Same experiment, five exchange topologies (threaded coordinator):\n");

    println!(
        "{:<14} {:>10} {:>12} {:>14} {:>12} {:>12}",
        "topology", "final gap", "wire MiB", "sim net ms", "max link KiB", "consensus"
    );
    let mut mesh_final: Option<Vec<Vec<f32>>> = None;
    for kind in ["full-mesh", "star", "ring", "hierarchical", "gossip"] {
        cfg.topo.kind = kind.into();
        let run = run_threaded(&cfg)?;
        let rec = &run.recorder;
        let gap = rec.get("gap").and_then(|s| s.last()).unwrap_or(f64::NAN);
        let mib = rec.scalar("total_bits").unwrap_or(0.0) / 8.0 / 1048576.0;
        // pure modeled α-β network time (compute time excluded)
        let net_ms = rec.scalar("sim_net_time").unwrap_or(0.0) * 1e3;
        let link_kib = rec.scalar("max_link_bytes").unwrap_or(0.0) / 1024.0;
        let consensus = rec
            .scalar("consensus_dist")
            .map(|c| format!("{c:.5}"))
            .unwrap_or_else(|| "exact".into());
        println!(
            "{kind:<14} {gap:>10.5} {mib:>12.2} {net_ms:>14.3} {link_kib:>12.1} {consensus:>12}"
        );

        match kind {
            "full-mesh" => mesh_final = Some(run.replicas.clone()),
            "star" | "ring" | "hierarchical" => {
                // Exactness: aggregation preserves the rank-order mean, so
                // the replicas are bit-identical to the mesh run's.
                assert_eq!(
                    Some(&run.replicas),
                    mesh_final.as_ref(),
                    "{kind} diverged from the full-mesh trajectory"
                );
            }
            _ => {}
        }
    }

    println!(
        "\nReading the table:\n\
         * star/ring/hierarchical reproduce the mesh gap exactly (asserted) while\n\
           moving fewer bytes — in-network aggregation sends O(b) per NIC, the mesh O(K·b);\n\
         * the hottest single link shifts with the graph (leader links under\n\
           hierarchical, uniform chunks under ring);\n\
         * gossip averages over graph neighborhoods only: cheapest rounds, but the\n\
           replicas drift apart — `consensus` is the RMS deviation across workers\n\
           (metrics::consensus_distance), the quantity decentralized-VI analyses bound.\n\
         \n\
         Try `[topo]` in a config file (kind/groups/degree/seed) or\n\
         `qgenx run --topo ring` to sweep these from the CLI."
    );
    Ok(())
}
