//! First-order VI algorithms.
//!
//! * [`qgenx`] — the paper's contribution: the Q-GenX template
//!
//!   ```text
//!   X_{t+1/2} = X_t − (γ_t/K) Σ_k V̂_{k,t}
//!   Y_{t+1}   = Y_t − (1/K)  Σ_k V̂_{k,t+1/2}
//!   X_{t+1}   = γ_{t+1} Y_{t+1}
//!   ```
//!
//!   with the adaptive step-size of Theorems 3/4 and the three unified
//!   variants (Examples 3.1–3.3) selected by
//!   [`crate::config::Variant`]: dual averaging (`V̂_t ≡ 0`), dual
//!   extrapolation (fresh query at `X_t`), optimistic dual averaging
//!   (reuse of the previous half-step query).
//! * [`stepsize`] — the adaptive rule
//!   `γ_t = K (1 + Σ_{i<t} Σ_k ‖V̂_{k,i} − V̂_{k,i+1/2}‖²)^{−1/2}` (shared
//!   by all variants; never needs σ, c, or β).
//! * [`local`] — local-steps replica wrapper ([`LocalQGenX`]): `H`
//!   private extra-gradient iterations between communication rounds, with
//!   quantized model-delta synchronization (the third communication-
//!   reduction axis next to compression and topology).
//! * [`baselines`] — full-precision extra-gradient (Korpelevich), SGDA,
//!   and QSGDA (Beznosikov et al. 2022) for the Figure-4 comparison.

pub mod baselines;
pub mod local;
pub mod qgenx;
pub mod stepsize;

pub use baselines::{ExtraGradient, Sgda};
pub use local::LocalQGenX;
pub use qgenx::{QGenX, QGenXPhase};
pub use stepsize::AdaptiveStepSize;
