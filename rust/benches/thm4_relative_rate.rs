//! E6 — Theorem 4: under *relative* noise on a co-coercive operator,
//! Q-GenX with the same adaptive step-size reaches the fast `O(1/(KT))`
//! rate — and the step-size γ_t stays bounded away from zero (the noise
//! vanishes near the solution, so the accumulated differences converge).
//!
//! Contrast bench: the identical algorithm under absolute noise decays
//! γ_t ∝ 1/√t — the interpolation claim ("without prior knowledge of the
//! noise profile").

use qgenx::benchkit::{loglog_slope, scaled, Table};
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::run_experiment;

fn cfg_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = "cocoercive".into();
    cfg.problem.dim = 32;
    cfg.problem.noise = "relative".into();
    cfg.problem.rel_c = 1.0;
    cfg.algo.gamma0 = 0.3;
    cfg.quant.update_every = 200;
    cfg
}

fn mean_dist(cfg: &ExperimentConfig, seeds: u64) -> f64 {
    let mut acc = 0.0;
    for s in 0..seeds {
        let mut c = cfg.clone();
        c.seed = 2000 + s;
        acc += run_experiment(&c).unwrap().get("dist").unwrap().last().unwrap();
    }
    acc / seeds as f64
}

fn main() {
    println!("== E6 / Theorem 4: fast O(1/T) under relative noise (co-coercive) ==\n");
    let seeds = scaled(4, 2) as u64;

    let ts = if qgenx::benchkit::fast_mode() {
        vec![250usize, 1000]
    } else {
        vec![250usize, 500, 1000, 2000, 4000]
    };
    let mut table = Table::new(&["T", "mean dist (relative noise)", "mean dist (absolute noise)"]);
    let (mut xs, mut y_rel, mut y_abs) = (Vec::new(), Vec::new(), Vec::new());
    for &t in &ts {
        let mut rel = cfg_base();
        rel.iters = t;
        rel.eval_every = t;
        rel.workers = 2;
        let d_rel = mean_dist(&rel, seeds);
        let mut abs = rel.clone();
        abs.problem.noise = "absolute".into();
        abs.problem.sigma = 1.0;
        let d_abs = mean_dist(&abs, seeds);
        table.row(&[t.to_string(), format!("{d_rel:.6}"), format!("{d_abs:.6}")]);
        xs.push(t as f64);
        y_rel.push(d_rel);
        y_abs.push(d_abs);
    }
    table.print();
    let s_rel = loglog_slope(&xs, &y_rel);
    let s_abs = loglog_slope(&xs, &y_abs);
    println!("\nlog-log slopes: relative {s_rel:.3} vs absolute {s_abs:.3}");
    println!("Theorem 4 predicts the relative-noise slope is steeper (≈ -1 vs ≈ -0.5).");
    assert!(s_rel < s_abs - 0.1, "relative-noise rate should beat absolute-noise rate");

    // gamma behaviour: bounded under relative noise, decaying under absolute.
    println!("\n-- adaptive step-size interpolation --");
    let mut cfg = cfg_base();
    cfg.iters = scaled(3000, 500);
    cfg.eval_every = cfg.iters / 10;
    cfg.workers = 2;
    cfg.seed = 5;
    let rec_rel = run_experiment(&cfg).unwrap();
    let mut cfg_a = cfg.clone();
    cfg_a.problem.noise = "absolute".into();
    cfg_a.problem.sigma = 1.0;
    let rec_abs = run_experiment(&cfg_a).unwrap();
    let g_rel = rec_rel.get("gamma").unwrap();
    let g_abs = rec_abs.get("gamma").unwrap();
    let rel_ratio = g_rel.points.first().unwrap().1 / g_rel.last().unwrap();
    let abs_ratio = g_abs.points.first().unwrap().1 / g_abs.last().unwrap();
    println!("gamma(first)/gamma(last): relative {rel_ratio:.2} vs absolute {abs_ratio:.2}");
    assert!(
        abs_ratio > rel_ratio * 1.5,
        "absolute-noise gamma should decay much more ({abs_ratio} vs {rel_ratio})"
    );

    // K-scaling under relative noise
    println!("\n-- K-scaling at fixed T (relative noise) --");
    let mut ktab = Table::new(&["K", "mean dist", "vs K=1"]);
    let mut base = 0.0;
    for &k in &[1usize, 2, 4, 8] {
        let mut c = cfg_base();
        c.iters = scaled(1000, 250);
        c.eval_every = c.iters;
        c.workers = k;
        let d = mean_dist(&c, seeds);
        if k == 1 {
            base = d;
        }
        ktab.row(&[k.to_string(), format!("{d:.6}"), format!("{:.2}x", base / d)]);
    }
    ktab.print();

    qgenx::benchkit::write_csv(
        "results/thm4_rate.csv",
        &["T", "dist_rel", "dist_abs"],
        &xs.iter()
            .enumerate()
            .map(|(i, x)| vec![x.to_string(), y_rel[i].to_string(), y_abs[i].to_string()])
            .collect::<Vec<_>>(),
    )
    .unwrap();
    println!("\ncsv -> results/thm4_rate.csv");
}
