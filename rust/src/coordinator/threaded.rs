//! Threaded coordinator: `K` real worker threads, replicated Q-GenX state,
//! actual encoded bytes through the [`AllGather`] transport, delivered over
//! the configured [`Topology`] by a [`Collective`].
//!
//! Replication invariant (exact topologies — mesh/star/ring/hierarchical):
//! every worker decodes the *same* payload set in the same rank order, runs
//! the same deterministic state update, and pools the same sufficient
//! statistics at level-update steps — so all replicas of `QGenX`, `Levels`
//! and the Huffman tables stay bit-identical without a parameter server.
//! The invariant is asserted at the end of every run by comparing replica
//! iterates across workers.
//!
//! Gossip topologies are *inexact by design*: each worker averages dual
//! vectors over its closed graph neighborhood only, replicas drift, and the
//! run records [`crate::metrics::consensus_distance`] instead of asserting
//! replica equality (series via an out-of-band diagnostic exchange at eval
//! steps — not billed to traffic — plus a final scalar). Codec/level state
//! stays global (see `coordinator::mod` docs), so every worker can still
//! decode every neighbor.
//!
//! Local-steps mode (`local.steps ≥ 2`) swaps the per-iteration protocol
//! for the local worker loop (`worker_local_loop`): `H` private
//! extra-gradient iterations, then
//! one quantized model-delta exchange and a resync by averaging. Under
//! exact topologies replicas drift *within* a segment but re-agree on a
//! bit-identical consensus point at every sync; the end-of-run invariant
//! compares those sync bases (the raw iterate can sit an origin-shift
//! rounding ulp off the consensus point — see `algo::local`). Under gossip
//! the delta averaging is neighborhood-local and replicas drift
//! persistently.
//!
//! Fault behavior: each worker holds a transport
//! [`crate::net::PoisonGuard`]; if one
//! worker panics mid-round its peers' `exchange` calls error out instead of
//! deadlocking, and `run_threaded` surfaces the failure.

use super::pipeline::Compressor;
use super::schedule::UpdateSchedule;
use crate::algo::{LocalQGenX, QGenX};
use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::metrics::{consensus_distance, Recorder, SyncAccounting};
use crate::net::{AllGather, NetModel, TrafficStats};
use crate::oracle::{build_operator, build_oracle, GapEvaluator};
use crate::topo::{build_collective, Collective, LinkTraffic, Topology};
use crate::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Outcome of one threaded run: rank-0 recorder plus the final iterate of
/// every replica (for the replication invariant check and tests).
pub struct ThreadedRun {
    pub recorder: Recorder,
    pub replicas: Vec<Vec<f32>>,
}

/// Run Algorithm 1 on `K` OS threads over the configured topology.
/// Functionally equivalent to [`super::inline::run_experiment`] modulo RNG
/// stream interleaving.
pub fn run_threaded(cfg: &ExperimentConfig) -> Result<ThreadedRun> {
    cfg.validate()?;
    let topo = Topology::from_config(&cfg.topo, cfg.workers)?;
    let collective = build_collective(topo, cfg.workers)?;
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let transport = AllGather::new(k);
    let net = NetModel::from_config(&cfg.net);
    let schedule = if cfg.quant.adapts() {
        UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
    } else {
        UpdateSchedule::never()
    };

    let handles: Vec<std::thread::JoinHandle<Result<(Recorder, Vec<f32>)>>> = (0..k)
        .map(|rank| {
            let op = op.clone();
            let cfg = cfg.clone();
            let transport = transport.clone();
            let collective = collective.clone();
            std::thread::Builder::new()
                .name(format!("qgenx-worker-{rank}"))
                .spawn(move || {
                    let out = if cfg.local.steps > 1 {
                        worker_local_loop(rank, &cfg, op, transport.clone(), collective, net, d)
                    } else {
                        worker_loop(
                            rank,
                            &cfg,
                            op,
                            transport.clone(),
                            collective,
                            net,
                            schedule,
                            d,
                        )
                    };
                    // An Err return (codec/oracle failure) must release the
                    // peers just like a panic does — otherwise they block at
                    // the barrier forever waiting for this worker's deposit.
                    if out.is_err() {
                        transport.poison();
                    }
                    out
                })
                .expect("spawn worker")
        })
        .collect();

    let mut recorders = Vec::with_capacity(k);
    let mut replicas = Vec::with_capacity(k);
    for h in handles {
        let (rec, x) = h
            .join()
            .map_err(|_| Error::Coordinator("worker thread panicked".into()))??;
        recorders.push(rec);
        replicas.push(x);
    }
    let mut recorder = recorders.swap_remove(0);
    if topo.is_exact() {
        // Replication invariant: all replicas ended at the same iterate.
        for r in 1..k {
            if replicas[r] != replicas[0] {
                return Err(Error::Coordinator(format!(
                    "replica divergence: worker {r} differs from worker 0"
                )));
            }
        }
    } else {
        recorder.set_scalar("consensus_dist", consensus_distance(&replicas));
    }
    Ok(ThreadedRun { recorder, replicas })
}

/// Out-of-band diagnostic allgather at eval steps: every rank contributes
/// `[X_t ‖ X̄]` as raw f32 (deliberately NOT billed to traffic — it exists
/// so rank 0 can evaluate cross-replica metrics, not as protocol traffic);
/// every rank must call it at the same step so the barrier matches.
/// Returns `Some((per-rank iterates, mean ergodic average))` on rank 0,
/// `None` elsewhere.
fn diag_exchange(
    rank: usize,
    k: usize,
    d: usize,
    transport: &AllGather,
    x_world: &[f32],
    ergodic: &[f32],
) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
    let mut diag = Vec::with_capacity(8 * d);
    for &x in x_world.iter().chain(ergodic.iter()) {
        diag.extend_from_slice(&x.to_le_bytes());
    }
    let got = transport.exchange(rank, diag)?;
    if rank != 0 {
        return Ok(None);
    }
    let mut iterates = Vec::with_capacity(k);
    let mut mean_avg = vec![0.0f32; d];
    for p in &got {
        let f: Vec<f32> = p
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        if f.len() != 2 * d {
            return Err(Error::Coordinator("bad diagnostic payload".into()));
        }
        iterates.push(f[..d].to_vec());
        for (m, &x) in mean_avg.iter_mut().zip(f[d..].iter()) {
            *m += x / k as f32;
        }
    }
    Ok(Some((iterates, mean_avg)))
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rank: usize,
    cfg: &ExperimentConfig,
    op: Arc<dyn crate::oracle::Operator>,
    transport: Arc<AllGather>,
    collective: Arc<dyn Collective>,
    net: NetModel,
    schedule: UpdateSchedule,
    d: usize,
) -> Result<(Recorder, Vec<f32>)> {
    // A panic anywhere below must not strand peers at the barrier.
    let _poison = transport.guard();
    let k = cfg.workers;
    let exact = collective.topology().is_exact();
    // Ranks whose payloads this worker consumes (all K for exact
    // topologies; the closed neighborhood under gossip).
    let recv_ranks = collective.recipients(rank);
    let k_local = recv_ranks.len();
    let root = Rng::seed_from(cfg.seed);
    let mut oracle = build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (rank as u64 + 1) * 0x9e37)?;
    let mut comp = Compressor::from_config(&cfg.quant, root.fork(rank as u64 + 101))?;
    let mut state = QGenX::new(
        cfg.algo.variant,
        &vec![0.0f32; d],
        k_local,
        cfg.algo.gamma0,
        cfg.algo.adaptive_step,
    );
    let gap_eval = if rank == 0 { GapEvaluator::around_solution(op.as_ref(), 2.0) } else { None };
    let mut traffic = TrafficStats::default();
    let mut links = LinkTraffic::new();
    let mut rec = Recorder::new();
    let mut g_buf = vec![0.0f32; d];
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];

    // One exchange round: contribute my wire bytes through the collective
    // and decode the payloads it logically delivers into `decoded`
    // (sender-indexed). Callers read `decoded` directly when exact —
    // zero-copy, as the seed did — and take the `recv_ranks` view under
    // gossip.
    let exchange = |payload: Vec<u8>,
                    comp: &Compressor,
                    decoded: &mut Vec<Vec<f32>>,
                    traffic: &mut TrafficStats,
                    links: &mut LinkTraffic|
     -> Result<()> {
        let (recv, bits) = collective.exchange(&transport, rank, payload)?;
        collective.record_round(&bits, &net, traffic);
        if rank == 0 {
            links.record(collective.as_ref(), &bits);
        }
        for (sender, bytes) in &recv {
            comp.decompress(bytes, &mut decoded[*sender])?;
        }
        Ok(())
    };
    let neighborhood_view = |decoded: &[Vec<f32>]| -> Vec<Vec<f32>> {
        recv_ranks.iter().map(|&r| decoded[r].clone()).collect()
    };

    for t in 1..=cfg.iters {
        // (1) stat exchange + synchronized level update — always global
        //     (full-mesh), so codecs stay identical on every worker.
        if schedule.is_update(t) && comp.is_quantized() {
            let payload = comp.stats_payload();
            let got = transport.exchange(rank, payload)?;
            let bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
            traffic.record_allgather(&bits, &net);
            let rank_order: Vec<&[u8]> = got.iter().map(|p| p.as_slice()).collect();
            comp.update_levels(&rank_order)?;
        }

        // (2) base exchange
        let base_vecs: Vec<Vec<f32>> = if let Some(xq) = state.base_query() {
            let t0 = Instant::now();
            oracle.sample(&xq, &mut g_buf);
            let (bytes, _) = comp.compress(&g_buf)?;
            traffic.add_compute(t0.elapsed().as_secs_f64());
            exchange(bytes, &comp, &mut decoded, &mut traffic, &mut links)?;
            if exact { decoded.clone() } else { neighborhood_view(&decoded) }
        } else {
            Vec::new()
        };

        // (3) extrapolate (identical on every replica when exact; the
        //     replica's own neighborhood mean under gossip)
        let x_half = state.extrapolate(&base_vecs)?;

        // (4) half-step exchange
        let t0 = Instant::now();
        oracle.sample(&x_half, &mut g_buf);
        let (bytes, _) = comp.compress(&g_buf)?;
        traffic.add_compute(t0.elapsed().as_secs_f64());
        exchange(bytes, &comp, &mut decoded, &mut traffic, &mut links)?;
        if exact {
            state.update(&decoded)?;
        } else {
            state.update(&neighborhood_view(&decoded))?;
        }

        // (5) evaluation
        let eval_now = t % cfg.eval_every.max(1) == 0 || t == cfg.iters;
        if eval_now && !exact {
            if let Some((iterates, mean_avg)) = diag_exchange(
                rank,
                k,
                d,
                &transport,
                &state.x_world(),
                &state.ergodic_average(),
            )? {
                rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
                }
            }
        } else if eval_now && rank == 0 {
            let avg = state.ergodic_average();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                rec.push("dist", t as f64, ev.dist_to_center(&avg));
            }
        }
        if eval_now && rank == 0 {
            rec.push("gamma", t as f64, state.gamma());
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            rec.push("sim_time_cum", t as f64, traffic.total_time());
            comp.record_layer_series(&mut rec, t as f64);
        }
    }
    if rank == 0 {
        rec.set_scalar("total_bits", traffic.bits_sent as f64);
        rec.set_scalar("rounds", traffic.rounds as f64);
        rec.set_scalar("level_updates", comp.updates() as f64);
        rec.set_scalar("sim_net_time", traffic.sim_net_time);
        rec.set_scalar("compute_time", traffic.compute_time);
        rec.set_scalar("wire_links", links.links() as f64);
        rec.set_scalar("max_link_bytes", links.max_link_bytes());
        comp.emit_layer_scalars(&mut rec);
    }
    Ok((rec, state.x_world()))
}

/// Local-steps worker loop (`local.steps = H ≥ 2`): `H` private
/// extra-gradient iterations per communication round, then a quantized
/// **model-delta** exchange over the collective and a resync onto the
/// (neighborhood-)averaged delta. The threaded twin of
/// [`super::inline::run_experiment`]'s local runner; see that runner's
/// docs for the algorithm and the `coordinator::mod` docs for the
/// exact / gossip / local runner split.
///
/// Diagnostics: the `sync_drift` series is computed on rank 0 from the
/// *decoded* deltas it already holds (no extra barrier) — under exact
/// topologies that is the global pre-averaging drift up to quantization
/// noise; under gossip it is rank 0's neighborhood view.
#[allow(clippy::too_many_arguments)]
fn worker_local_loop(
    rank: usize,
    cfg: &ExperimentConfig,
    op: Arc<dyn crate::oracle::Operator>,
    transport: Arc<AllGather>,
    collective: Arc<dyn Collective>,
    net: NetModel,
    d: usize,
) -> Result<(Recorder, Vec<f32>)> {
    // A panic anywhere below must not strand peers at the barrier.
    let _poison = transport.guard();
    let k = cfg.workers;
    let h = cfg.local.steps;
    let recv_ranks = collective.recipients(rank);
    let root = Rng::seed_from(cfg.seed);
    let mut oracle = build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (rank as u64 + 1) * 0x9e37)?;
    let mut comp = Compressor::from_config(&cfg.quant, root.fork(rank as u64 + 101))?;
    let mut rep = LocalQGenX::new(
        cfg.algo.variant,
        &vec![0.0f32; d],
        cfg.algo.gamma0,
        cfg.algo.adaptive_step,
    );
    let gap_eval = if rank == 0 { GapEvaluator::around_solution(op.as_ref(), 2.0) } else { None };
    let adaptive = cfg.quant.adapts() && comp.is_quantized();
    let update_every = cfg.quant.update_every;
    // Same early-warmup due point as the inline local runner (and, in
    // spirit, the per-step runners' UpdateSchedule) — deterministic in t,
    // so every rank fires the stat barrier at the same syncs.
    let mut next_stat_due = update_every.min(10);
    let mut traffic = TrafficStats::default();
    let mut links = LinkTraffic::new();
    let mut rec = Recorder::new();
    let mut sync_acc = SyncAccounting::new();
    let mut g_buf = vec![0.0f32; d];
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];

    for t in 1..=cfg.iters {
        // (1) One private extra-gradient iteration — no wire.
        let t0 = Instant::now();
        rep.local_round(oracle.as_mut(), &mut g_buf)?;
        traffic.add_compute(t0.elapsed().as_secs_f64());

        // (2) Delta synchronization every H iterations (plus final).
        if t % h == 0 || t == cfg.iters {
            let t0 = Instant::now();
            let delta = rep.delta();
            let (bytes, _) = comp.compress(&delta)?;
            traffic.add_compute(t0.elapsed().as_secs_f64());
            let (recv, bits) = collective.exchange(&transport, rank, bytes)?;
            let bits_before = traffic.bits_sent;
            collective.record_round(&bits, &net, &mut traffic);
            for (sender, payload) in &recv {
                comp.decompress(payload, &mut decoded[*sender])?;
            }
            if rank == 0 {
                links.record(collective.as_ref(), &bits);
                // Drift of the decoded deltas == drift of the pre-averaging
                // iterates (the common sync base cancels in the deviations).
                let view: Vec<Vec<f32>> =
                    recv_ranks.iter().map(|&r| decoded[r].clone()).collect();
                sync_acc.record(
                    &mut rec,
                    t,
                    consensus_distance(&view),
                    traffic.bits_sent - bits_before,
                );
            }
            let mut mean = vec![0.0f32; d];
            for &w in &recv_ranks {
                for (m, &x) in mean.iter_mut().zip(decoded[w].iter()) {
                    *m += x / recv_ranks.len() as f32;
                }
            }
            rep.resync(&mean)?;

            // Control plane: global stat pooling at the first sync on or
            // after each due point (identical schedule on all ranks).
            if adaptive && update_every != 0 && t >= next_stat_due {
                let payload = comp.stats_payload();
                let got = transport.exchange(rank, payload)?;
                let stat_bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
                traffic.record_allgather(&stat_bits, &net);
                let rank_order: Vec<&[u8]> = got.iter().map(|p| p.as_slice()).collect();
                comp.update_levels(&rank_order)?;
                next_stat_due = t + update_every;
            }
        }

        // (3) Evaluation via the shared out-of-band diagnostic exchange
        //     (every rank calls it so the barrier matches; local mode
        //     evaluates at the mean ergodic average across replicas, like
        //     the inline local runner).
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            if let Some((iterates, mean_avg)) = diag_exchange(
                rank,
                k,
                d,
                &transport,
                &rep.x_world(),
                &rep.ergodic_average(),
            )? {
                rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
                }
                rec.push("gamma", t as f64, rep.gamma());
                rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
                rec.push("sim_time_cum", t as f64, traffic.total_time());
                comp.record_layer_series(&mut rec, t as f64);
            }
        }
    }
    if rank == 0 {
        rec.set_scalar("total_bits", traffic.bits_sent as f64);
        rec.set_scalar("rounds", traffic.rounds as f64);
        rec.set_scalar("level_updates", comp.updates() as f64);
        rec.set_scalar("sim_net_time", traffic.sim_net_time);
        rec.set_scalar("compute_time", traffic.compute_time);
        rec.set_scalar("wire_links", links.links() as f64);
        rec.set_scalar("max_link_bytes", links.max_link_bytes());
        rec.set_scalar("local_steps", h as f64);
        sync_acc.emit_scalars(&mut rec);
        comp.emit_layer_scalars(&mut rec);
    }
    // Report the final *sync base* as this replica's end state: the run
    // ends on a sync, the consensus point is computed by identical
    // arithmetic on every rank (bit-identical under exact topologies — the
    // replication invariant `run_threaded` asserts), whereas the raw
    // iterate can sit an origin-shift rounding ulp off it.
    Ok((rec, rep.sync_base().to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::inline::run_experiment;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 150;
        cfg.eval_every = 50;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 12;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 60;
        cfg
    }

    #[test]
    fn threaded_run_completes_and_replicas_agree() {
        let run = run_threaded(&cfg()).unwrap();
        assert_eq!(run.replicas.len(), 3);
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0]);
        }
        let gap = run.recorder.get("gap").unwrap().last().unwrap();
        assert!(gap.is_finite());
    }

    #[test]
    fn threaded_matches_inline_bit_counts() {
        // Same config: identical wire-format sizes per round in expectation;
        // totals agree because both run the same number of rounds with the
        // same quantization parameters (RNG streams differ so exact bits
        // differ slightly under Huffman/Elias; compare within 5%).
        let c = cfg();
        let inline_rec = run_experiment(&c).unwrap();
        let threaded = run_threaded(&c).unwrap();
        let bi = inline_rec.scalar("total_bits").unwrap();
        let bt = threaded.recorder.scalar("total_bits").unwrap();
        assert!(
            (bi - bt).abs() / bi < 0.05,
            "inline {bi} vs threaded {bt}"
        );
        assert_eq!(
            inline_rec.scalar("rounds").unwrap(),
            threaded.recorder.scalar("rounds").unwrap()
        );
    }

    #[test]
    fn threaded_converges() {
        let mut c = cfg();
        c.iters = 400;
        let run = run_threaded(&c).unwrap();
        let gaps = run.recorder.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn threaded_fp32_mode() {
        let mut c = cfg();
        c.quant.mode = crate::config::QuantMode::Fp32;
        c.iters = 60;
        let run = run_threaded(&c).unwrap();
        // fp32: bits = 32 * d * senders * rounds exactly — deterministic.
        let bits = run.recorder.scalar("total_bits").unwrap();
        let rounds = run.recorder.scalar("rounds").unwrap();
        let expect = rounds * 3.0 * 2.0 * 32.0 * 12.0;
        assert!((bits - expect).abs() < 1e-6, "bits {bits} expect {expect}");
    }

    #[test]
    fn all_topologies_run_threaded_end_to_end() {
        // Acceptance: all five topologies through coordinator::threaded on a
        // small problem; exact ones agree with the full-mesh replicas
        // bit-for-bit, gossip records consensus instead.
        let mut c = cfg();
        c.workers = 5;
        c.iters = 80;
        c.eval_every = 40;
        let mesh = run_threaded(&c).unwrap();
        for kind in ["star", "ring", "hierarchical"] {
            c.topo.kind = kind.into();
            let run = run_threaded(&c).unwrap();
            assert_eq!(
                run.replicas, mesh.replicas,
                "{kind} must reproduce the mesh trajectory bit-for-bit"
            );
            assert!(
                run.recorder.scalar("total_bits").unwrap()
                    < mesh.recorder.scalar("total_bits").unwrap(),
                "{kind} must put fewer bits on the wire than mesh"
            );
        }
        c.topo.kind = "gossip".into();
        c.topo.degree = 2;
        let run = run_threaded(&c).unwrap();
        let cons = run.recorder.scalar("consensus_dist").unwrap();
        assert!(cons.is_finite() && cons > 0.0, "gossip replicas must drift: {cons}");
        assert!(run.recorder.get("consensus_dist").unwrap().len() >= 2);
        assert!(run.recorder.get("gap").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn threaded_local_steps_sync_exactly_and_cut_bits() {
        let mut c = cfg();
        c.iters = 200;
        c.eval_every = 50;
        let exact = run_threaded(&c).unwrap();
        c.local.steps = 4;
        let local = run_threaded(&c).unwrap();
        // Exact topology: the final sync leaves every replica bit-identical
        // (run_threaded would have errored otherwise; assert explicitly).
        for r in &local.replicas[1..] {
            assert_eq!(r, &local.replicas[0]);
        }
        let bl = local.recorder.scalar("total_bits").unwrap();
        let be = exact.recorder.scalar("total_bits").unwrap();
        assert!(bl < be, "H = 4 must cut wire bits: {bl} vs {be}");
        assert_eq!(local.recorder.scalar("syncs"), Some(50.0));
        assert_eq!(local.recorder.scalar("local_steps"), Some(4.0));
        assert!(local.recorder.get("gap").unwrap().last().unwrap().is_finite());
        assert!(local.recorder.get("sync_drift").unwrap().len() >= 2);

        // Same seeds, same per-worker streams: threaded and inline local
        // runners agree on the wire budget.
        let inline_rec = run_experiment(&c).unwrap();
        let bi = inline_rec.scalar("total_bits").unwrap();
        assert!((bi - bl).abs() / bi < 0.05, "inline {bi} vs threaded {bl}");
    }

    #[test]
    fn threaded_local_steps_compose_with_gossip() {
        let mut c = cfg();
        c.workers = 5;
        c.iters = 120;
        c.eval_every = 40;
        c.local.steps = 3;
        c.topo.kind = "gossip".into();
        c.topo.degree = 2;
        let run = run_threaded(&c).unwrap();
        let cons = run.recorder.scalar("consensus_dist").unwrap();
        assert!(cons.is_finite() && cons > 0.0, "gossip replicas must drift: {cons}");
        assert_eq!(run.recorder.scalar("syncs"), Some(40.0));
    }

    #[test]
    fn threaded_layerwise_keeps_replicas_identical() {
        // Layer-wise levels/codecs/allocations update in lockstep from the
        // pooled v3 payloads, so the exact-topology replication invariant
        // must hold exactly as it does for the single-codec pipeline.
        let mut c = cfg();
        c.iters = 200;
        c.quant.bucket_size = 4;
        c.quant.layers.names = vec!["lo".into(), "hi".into()];
        c.quant.layers.bounds = vec![4];
        c.quant.layers.budget = 4.0;
        let run = run_threaded(&c).unwrap();
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0], "layer-wise replicas must stay bit-identical");
        }
        assert_eq!(run.recorder.scalar("layers"), Some(2.0));
        assert!(run.recorder.scalar("level_updates").unwrap() >= 1.0);
        assert!(run.recorder.scalar("layer_bits/lo").unwrap() > 0.0);
        assert!(run.recorder.get("layer_bits/hi").unwrap().len() >= 2);
        assert!(run.recorder.get("gap").unwrap().last().unwrap().is_finite());

        // And the threaded local-steps loop composes with layers too.
        c.local.steps = 4;
        let run = run_threaded(&c).unwrap();
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0]);
        }
        assert_eq!(run.recorder.scalar("syncs"), Some(50.0));
        assert_eq!(run.recorder.scalar("layers"), Some(2.0));
    }

    #[test]
    fn threaded_worker_panic_surfaces_as_error() {
        // A mid-run worker panic must produce Err, not a hang: drive the
        // transport directly the way worker_loop does.
        use std::sync::Arc;
        let transport = AllGather::new(2);
        let t1 = {
            let tr = Arc::clone(&transport);
            std::thread::spawn(move || {
                let _g = tr.guard();
                tr.exchange(1, vec![1]).unwrap();
                panic!("worker 1 dies");
            })
        };
        let t0 = {
            let tr = Arc::clone(&transport);
            std::thread::spawn(move || -> Result<()> {
                let _g = tr.guard();
                tr.exchange(0, vec![0])?;
                tr.exchange(0, vec![0])?; // peer is dead: must error
                Ok(())
            })
        };
        assert!(t1.join().is_err());
        assert!(t0.join().unwrap().is_err());
    }
}
