//! The [`Collective`] trait: one exchange round of real encoded wire bytes
//! over a [`Topology`], generalizing the seed's flat `AllGather`.
//!
//! Physical vs logical: every worker's payload lands in a full
//! [`crate::net::Transport`] exchange — the in-process barrier or the
//! multi-process socket mesh, interchangeably (that is our wire). The
//! collective decides (a) which payloads each rank *logically* receives —
//! [`Collective::recipients`] — (b) what the round costs under the α-β
//! model — [`Collective::round_cost`] — and (c) how the round's bytes land
//! on individual directed links — [`Collective::link_loads`], accumulated
//! by [`LinkTraffic`]. Exact topologies deliver every rank the full `K`
//! payload set (the simulation's stand-in for in-network aggregation of
//! the rank-order mean — see the module doc of [`crate::topo`]); gossip
//! delivers closed neighborhoods only. Note that both real fabrics move
//! every payload over a physical full mesh (the logical pattern filters
//! afterwards), while the modeled star/ring loads assume in-network
//! aggregation and gossip bills neighborhood links only — so *measured*
//! link bytes equal the *modeled* ones exactly on full mesh, and are a
//! diagnostic (not an identity) elsewhere.

use super::cost::{self, RoundCost, AGG_PIGGYBACK_BYTES};
use super::{circulant_neighbors, gossip_neighbors, Topology};
use crate::error::Result;
use crate::net::{bits_to_bytes, NetModel, Plane, TrafficStats, Transport};
use crate::util::rng::splitmix64;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// A directed link `(sender, receiver)`.
pub type Link = (usize, usize);

/// One synchronous exchange round of encoded wire bytes over a topology.
pub trait Collective: Send + Sync {
    /// Participants.
    fn k(&self) -> usize;

    /// The graph this collective runs on.
    fn topology(&self) -> Topology;

    /// Ranks whose payloads `rank` logically receives this round
    /// (sorted, always includes `rank` itself).
    fn recipients(&self, rank: usize) -> Vec<usize>;

    /// α-β cost of one round given everyone's exact payload bits.
    fn round_cost(&self, model: &NetModel, bits_each: &[u64]) -> RoundCost;

    /// Modeled payload bytes per directed link for one round, written into
    /// `out` (cleared first). The buffer-reuse form is what
    /// [`LinkTraffic::record`] calls on every data round, so implementations
    /// keep the hot topologies (mesh, star, ring, gossip) allocation-free.
    fn link_loads_into(&self, bits_each: &[u64], out: &mut Vec<(Link, f64)>);

    /// Allocating convenience wrapper around [`Self::link_loads_into`].
    fn link_loads(&self, bits_each: &[u64]) -> Vec<(Link, f64)> {
        let mut out = Vec::new();
        self.link_loads_into(bits_each, &mut out);
        out
    }

    /// Execute one data round through any [`Transport`] fabric: deposit
    /// `payload`, block for the group, and return the payloads this rank
    /// logically receives as `(sender, bytes)` plus everyone's exact
    /// payload bit counts (every rank sees the same `bits` vector, so
    /// accounting stays replica-identical across fabrics).
    fn exchange(
        &self,
        transport: &dyn Transport,
        rank: usize,
        payload: Vec<u8>,
    ) -> Result<(Vec<(usize, Arc<Vec<u8>>)>, Vec<u64>)> {
        let got = transport.exchange(rank, payload, Plane::Data)?;
        let bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
        let recv =
            self.recipients(rank).into_iter().map(|r| (r, got[r].clone())).collect();
        Ok((recv, bits))
    }

    /// Record one round into `traffic` (wire bits, messages, modeled time).
    fn record_round(&self, bits_each: &[u64], model: &NetModel, traffic: &mut TrafficStats) {
        let c = self.round_cost(model, bits_each);
        traffic.record_modeled(c.wire_bits, c.messages, c.secs);
    }

    /// Advance a time-varying schedule to step `t` (1-based). Returns
    /// `true` when the edge set changed — callers must then refresh any
    /// cached [`Self::recipients`] sets. Static collectives never change;
    /// [`RewiringGossip`] re-draws its graph every `rewire_every` steps.
    /// Deterministic in `t`, so every rank of a group converges on the
    /// same graph without communicating.
    fn advance_round(&self, t: u64) -> bool {
        let _ = t;
        false
    }
}

/// Build the collective for a topology over `k` ranks.
pub fn build_collective(topo: Topology, k: usize) -> Result<Arc<dyn Collective>> {
    match topo {
        Topology::Gossip { degree, seed } => {
            Ok(Arc::new(GossipCollective::new(k, degree, seed)))
        }
        _ => Ok(Arc::new(ExactCollective { topo, k })),
    }
}

/// Like [`build_collective`], with an optional time-varying schedule:
/// `rewire_every > 0` over a gossip topology yields a [`RewiringGossip`]
/// whose edge set is re-drawn every `rewire_every` steps (driven by
/// [`Collective::advance_round`]). Exact topologies and `rewire_every = 0`
/// fall through to the static builder unchanged — the default config is
/// bit-identical to the pre-schedule behavior.
pub fn build_collective_dynamic(
    topo: Topology,
    k: usize,
    rewire_every: u64,
) -> Result<Arc<dyn Collective>> {
    match topo {
        Topology::Gossip { degree, seed } if rewire_every > 0 => {
            Ok(Arc::new(RewiringGossip::new(k, degree, seed, rewire_every)))
        }
        _ => build_collective(topo, k),
    }
}

/// Mesh / star / ring / hierarchical: every rank logically receives all `K`
/// payloads; topologies differ only in cost and link pattern.
pub struct ExactCollective {
    topo: Topology,
    k: usize,
}

impl Collective for ExactCollective {
    fn k(&self) -> usize {
        self.k
    }

    fn topology(&self) -> Topology {
        self.topo
    }

    fn recipients(&self, _rank: usize) -> Vec<usize> {
        (0..self.k).collect()
    }

    fn round_cost(&self, model: &NetModel, bits_each: &[u64]) -> RoundCost {
        match self.topo {
            Topology::FullMesh => cost::full_mesh(model, bits_each),
            Topology::Star => cost::star(model, bits_each),
            Topology::Ring => cost::ring(model, bits_each),
            Topology::Hierarchical { groups } => {
                cost::hierarchical(model, bits_each, groups)
            }
            Topology::Gossip { .. } => unreachable!("gossip uses GossipCollective"),
        }
    }

    fn link_loads_into(&self, bits_each: &[u64], out: &mut Vec<(Link, f64)>) {
        out.clear();
        let k = self.k;
        if k <= 1 {
            return;
        }
        // §Perf: per-sender bytes are recomputed at each use instead of
        // collected into a Vec — mesh/star/ring stay allocation-free.
        let byte = |i: usize| bits_to_bytes(bits_each[i]) as f64;
        let agg = (bits_each.iter().map(|&b| bits_to_bytes(b)).max().unwrap_or(0)
            + AGG_PIGGYBACK_BYTES) as f64;
        match self.topo {
            Topology::FullMesh => {
                for i in 0..k {
                    for j in 0..k {
                        if i != j {
                            out.push(((i, j), byte(i)));
                        }
                    }
                }
            }
            Topology::Star => {
                // push: i's foreign shard slice to j; pull: j's aggregated
                // shard back to i.
                for i in 0..k {
                    for j in 0..k {
                        if i != j {
                            out.push(((i, j), byte(i) / k as f64 + agg / k as f64));
                        }
                    }
                }
            }
            Topology::Ring => {
                let per_link = 2.0 * (k - 1) as f64 * agg / k as f64;
                for i in 0..k {
                    out.push(((i, (i + 1) % k), per_link));
                }
            }
            Topology::Hierarchical { groups } => {
                let ranges = super::group_ranges(k, groups);
                for range in &ranges {
                    let leader = range.start;
                    for r in range.start + 1..range.end {
                        out.push(((r, leader), byte(r))); // up, exact leaf
                        out.push(((leader, r), agg)); // down, aggregate
                    }
                }
                for ra in &ranges {
                    for rb in &ranges {
                        if ra.start != rb.start {
                            out.push(((ra.start, rb.start), agg));
                        }
                    }
                }
            }
            Topology::Gossip { .. } => unreachable!("gossip uses GossipCollective"),
        }
    }
}

/// Gossip: fixed undirected graph; each rank receives only its closed
/// neighborhood. Replicas become *neighborhood averages* — inexact by
/// design; consensus is tracked by [`crate::metrics::consensus_distance`].
pub struct GossipCollective {
    k: usize,
    topo: Topology,
    /// Closed neighborhoods (sorted, self included).
    closed: Vec<Vec<usize>>,
    /// Open degree per rank.
    degrees: Vec<usize>,
}

impl GossipCollective {
    pub fn new(k: usize, degree: usize, seed: u64) -> Self {
        let open = gossip_neighbors(k, degree, seed);
        let degrees: Vec<usize> = open.iter().map(|n| n.len()).collect();
        let closed = open
            .into_iter()
            .enumerate()
            .map(|(i, mut n)| {
                n.push(i);
                n.sort_unstable();
                n
            })
            .collect();
        GossipCollective { k, topo: Topology::Gossip { degree, seed }, closed, degrees }
    }

    /// Closed neighborhood sizes (the per-worker `K_r` the gossip replicas
    /// average over).
    pub fn neighborhood_sizes(&self) -> Vec<usize> {
        self.closed.iter().map(|n| n.len()).collect()
    }
}

impl Collective for GossipCollective {
    fn k(&self) -> usize {
        self.k
    }

    fn topology(&self) -> Topology {
        self.topo
    }

    fn recipients(&self, rank: usize) -> Vec<usize> {
        self.closed[rank].clone()
    }

    fn round_cost(&self, model: &NetModel, bits_each: &[u64]) -> RoundCost {
        cost::gossip(model, bits_each, &self.degrees)
    }

    fn link_loads_into(&self, bits_each: &[u64], out: &mut Vec<(Link, f64)>) {
        out.clear();
        for (i, neigh) in self.closed.iter().enumerate() {
            for &j in neigh {
                if j != i {
                    out.push(((i, j), bits_to_bytes(bits_each[i]) as f64));
                }
            }
        }
    }
}

/// Time-varying gossip: the graph is re-drawn every `rewire_every` steps
/// from a per-epoch seed (à la decentralized SEG on time-varying networks,
/// Beznosikov et al. 2021). Epoch graphs are *degree-regular* circulants
/// ([`circulant_neighbors`]) so neighborhood membership churns while every
/// node's neighborhood size stays fixed — per-replica algorithm states
/// (sized once at build) remain valid across rewires. The schedule is a
/// pure function of `(seed, epoch)`: every rank derives the same epoch
/// graph from its own clock, no coordination round needed, and the same
/// seed reproduces the same churn bit-for-bit.
pub struct RewiringGossip {
    k: usize,
    degree: usize,
    seed: u64,
    rewire_every: u64,
    state: Mutex<RewireState>,
}

struct RewireState {
    epoch: u64,
    /// Closed neighborhoods of the current epoch (sorted, self included).
    closed: Vec<Vec<usize>>,
    /// Open degree per rank (uniform by construction).
    degrees: Vec<usize>,
}

impl RewiringGossip {
    pub fn new(k: usize, degree: usize, seed: u64, rewire_every: u64) -> Self {
        assert!(rewire_every > 0, "rewire_every = 0 means a static graph");
        let (closed, degrees) = Self::epoch_graph(k, degree, seed, 0);
        RewiringGossip {
            k,
            degree,
            seed,
            rewire_every,
            state: Mutex::new(RewireState { epoch: 0, closed, degrees }),
        }
    }

    /// The epoch active at 1-based step `t`: steps `1..=rewire_every` run
    /// epoch 0, the next `rewire_every` steps epoch 1, and so on.
    pub fn epoch_at(&self, t: u64) -> u64 {
        t.saturating_sub(1) / self.rewire_every
    }

    fn epoch_graph(
        k: usize,
        degree: usize,
        seed: u64,
        epoch: u64,
    ) -> (Vec<Vec<usize>>, Vec<usize>) {
        let mut s = seed ^ epoch.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let open = circulant_neighbors(k, degree, splitmix64(&mut s));
        let degrees: Vec<usize> = open.iter().map(|n| n.len()).collect();
        let closed = open
            .into_iter()
            .enumerate()
            .map(|(i, mut n)| {
                n.push(i);
                n.sort_unstable();
                n
            })
            .collect();
        (closed, degrees)
    }

    fn lock(&self) -> MutexGuard<'_, RewireState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Collective for RewiringGossip {
    fn k(&self) -> usize {
        self.k
    }

    fn topology(&self) -> Topology {
        Topology::Gossip { degree: self.degree, seed: self.seed }
    }

    fn recipients(&self, rank: usize) -> Vec<usize> {
        self.lock().closed[rank].clone()
    }

    fn round_cost(&self, model: &NetModel, bits_each: &[u64]) -> RoundCost {
        cost::gossip(model, bits_each, &self.lock().degrees)
    }

    fn link_loads_into(&self, bits_each: &[u64], out: &mut Vec<(Link, f64)>) {
        out.clear();
        for (i, neigh) in self.lock().closed.iter().enumerate() {
            for &j in neigh {
                if j != i {
                    out.push(((i, j), bits_to_bytes(bits_each[i]) as f64));
                }
            }
        }
    }

    fn advance_round(&self, t: u64) -> bool {
        let epoch = self.epoch_at(t);
        let mut st = self.lock();
        if epoch == st.epoch {
            return false;
        }
        let (closed, degrees) = Self::epoch_graph(self.k, self.degree, self.seed, epoch);
        st.closed = closed;
        st.degrees = degrees;
        st.epoch = epoch;
        true
    }
}

/// Per-directed-link payload bytes — both the cumulative totals across a
/// run and the per-round delta stream (the most recent round's loads,
/// kept in a reusable scratch buffer so steady-state recording does not
/// allocate). Totals answer "which wire is hot under this topology?";
/// [`Self::last_round`] feeds the telemetry per-link time series.
#[derive(Clone, Debug, Default)]
pub struct LinkTraffic {
    loads: BTreeMap<Link, f64>,
    /// Most recent round's `(link, bytes)` deltas; reused across rounds.
    last: Vec<(Link, f64)>,
    rounds: u64,
}

impl LinkTraffic {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate one round's link loads and expose them as the current
    /// per-round delta ([`Self::last_round`]).
    pub fn record(&mut self, coll: &dyn Collective, bits_each: &[u64]) {
        coll.link_loads_into(bits_each, &mut self.last);
        for &(link, bytes) in &self.last {
            *self.loads.entry(link).or_insert(0.0) += bytes;
        }
        self.rounds += 1;
    }

    /// The most recent round's `(link, bytes)` deltas, in the
    /// collective's deterministic link order. Empty before any round.
    pub fn last_round(&self) -> &[(Link, f64)] {
        &self.last
    }

    /// Number of rounds recorded.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Number of distinct directed links that carried traffic.
    pub fn links(&self) -> usize {
        self.loads.len()
    }

    /// Total bytes across all links.
    pub fn total_bytes(&self) -> f64 {
        self.loads.values().sum()
    }

    /// Cumulative `(link, bytes)` totals in deterministic link order.
    pub fn totals(&self) -> Vec<(Link, f64)> {
        self.loads.iter().map(|(&l, &b)| (l, b)).collect()
    }

    /// Hottest link and its bytes.
    pub fn hottest(&self) -> Option<(Link, f64)> {
        self.loads
            .iter()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(&l, &b)| (l, b))
    }

    /// Max single-link bytes (0 if no traffic).
    pub fn max_link_bytes(&self) -> f64 {
        self.hottest().map(|(_, b)| b).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopoConfig;
    use crate::net::AllGather;

    fn mk(kind: &str, k: usize) -> Arc<dyn Collective> {
        let mut cfg = TopoConfig::default();
        cfg.kind = kind.into();
        let topo = Topology::from_config(&cfg, k).unwrap();
        build_collective(topo, k).unwrap()
    }

    #[test]
    fn mesh_collective_matches_seed_traffic_accounting() {
        // The full-mesh collective must reproduce record_allgather exactly —
        // the bit-for-bit compatibility contract with the seed.
        let model = NetModel::new(1e6, 0.0);
        let coll = mk("full-mesh", 3);
        let bits = [800u64, 800, 800];
        let mut a = TrafficStats::default();
        let mut b = TrafficStats::default();
        a.record_allgather(&bits, &model);
        coll.record_round(&bits, &model, &mut b);
        assert_eq!(a.bits_sent, b.bits_sent);
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.rounds, b.rounds);
        assert!((a.sim_net_time - b.sim_net_time).abs() < 1e-15);
    }

    #[test]
    fn exact_collectives_deliver_everyone() {
        for kind in ["full-mesh", "star", "ring", "hierarchical"] {
            let coll = mk(kind, 5);
            for r in 0..5 {
                assert_eq!(coll.recipients(r), vec![0, 1, 2, 3, 4], "{kind} rank {r}");
            }
            assert!(coll.topology().is_exact());
        }
    }

    #[test]
    fn gossip_delivers_closed_neighborhoods_only() {
        let coll = mk("gossip", 8);
        for r in 0..8 {
            let recv = coll.recipients(r);
            assert!(recv.contains(&r), "self always included");
            assert!(recv.len() < 8, "gossip must not be full mesh at k=8");
            assert!(recv.windows(2).all(|w| w[0] < w[1]), "sorted");
        }
        assert!(!coll.topology().is_exact());
    }

    #[test]
    fn exchange_filters_by_recipients() {
        let k = 4;
        let coll = mk("gossip", k);
        let transport = AllGather::new(k);
        let mut handles = Vec::new();
        for rank in 0..k {
            let coll = {
                // rebuild an identical collective per thread (deterministic graph)
                mk("gossip", k)
            };
            let transport = transport.clone();
            handles.push(std::thread::spawn(move || {
                let (recv, bits) =
                    coll.exchange(transport.as_ref(), rank, vec![rank as u8; rank + 1]).unwrap();
                assert_eq!(bits.len(), k);
                for (w, &b) in bits.iter().enumerate() {
                    assert_eq!(b, 8 * (w as u64 + 1), "exact sizes visible to all");
                }
                for (sender, payload) in &recv {
                    assert_eq!(payload.len(), sender + 1);
                    assert!(payload.iter().all(|&x| x == *sender as u8));
                }
                recv.iter().map(|(s, _)| *s).collect::<Vec<_>>()
            }));
        }
        let views: Vec<Vec<usize>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (r, v) in views.iter().enumerate() {
            assert_eq!(*v, coll.recipients(r));
        }
    }

    #[test]
    fn link_loads_are_consistent_with_round_totals() {
        // For topologies whose wire bits are purely byte-modeled, the sum of
        // link loads ≈ total wire bytes.
        let model = NetModel::gbe();
        let bits = vec![8 * 1000u64; 6];
        for kind in ["full-mesh", "star", "ring", "hierarchical", "gossip"] {
            let coll = mk(kind, 6);
            let total: f64 = coll.link_loads(&bits).iter().map(|(_, b)| b).sum();
            let cost = coll.round_cost(&model, &bits);
            let wire_bytes = cost.wire_bits as f64 / 8.0;
            assert!(
                (total - wire_bytes).abs() / wire_bytes < 0.05,
                "{kind}: links {total} vs cost {wire_bytes}"
            );
        }
    }

    #[test]
    fn link_traffic_identifies_hot_links() {
        let bits = vec![8 * 1000u64; 6];
        // hierarchical: leader links are hotter than member links
        let coll = mk("hierarchical", 6);
        let mut lt = LinkTraffic::new();
        lt.record(coll.as_ref(), &bits);
        lt.record(coll.as_ref(), &bits);
        assert!(lt.links() > 0);
        let ((a, b), hot) = lt.hottest().unwrap();
        assert!(hot >= lt.total_bytes() / lt.links() as f64, "hottest >= mean");
        assert_ne!(a, b);
        // ring: all k links equal
        let ring = mk("ring", 6);
        let mut lr = LinkTraffic::new();
        lr.record(ring.as_ref(), &bits);
        assert_eq!(lr.links(), 6);
        assert!((lr.max_link_bytes() - lr.total_bytes() / 6.0).abs() < 1e-9);
    }

    #[test]
    fn static_collectives_never_advance() {
        for kind in ["full-mesh", "star", "ring", "hierarchical", "gossip"] {
            let coll = mk(kind, 6);
            for t in 1..=50 {
                assert!(!coll.advance_round(t), "{kind} rewired at t={t}");
            }
        }
        // build_collective_dynamic with rewire_every = 0 is the static path
        let topo = Topology::Gossip { degree: 3, seed: 9 };
        let coll = build_collective_dynamic(topo, 8, 0).unwrap();
        assert!(!coll.advance_round(100));
        assert_eq!(coll.recipients(0), build_collective(topo, 8).unwrap().recipients(0));
    }

    #[test]
    fn rewiring_gossip_advances_exactly_at_epoch_boundaries() {
        let topo = Topology::Gossip { degree: 4, seed: 11 };
        let coll = build_collective_dynamic(topo, 12, 5).unwrap();
        assert!(!coll.topology().is_exact());
        for t in 1..=5 {
            assert!(!coll.advance_round(t), "epoch 0 covers steps 1..=5, t={t}");
        }
        assert!(coll.advance_round(6), "step 6 opens epoch 1");
        for t in 7..=10 {
            assert!(!coll.advance_round(t), "epoch 1 covers steps 6..=10, t={t}");
        }
        assert!(coll.advance_round(11), "step 11 opens epoch 2");
    }

    #[test]
    fn rewiring_gossip_is_deterministic_and_size_preserving() {
        let k = 12;
        let mk_dyn = || {
            build_collective_dynamic(Topology::Gossip { degree: 4, seed: 11 }, k, 5).unwrap()
        };
        let (a, b) = (mk_dyn(), mk_dyn());
        let size0 = a.recipients(0).len();
        let mut membership = Vec::new();
        for t in 1..=100u64 {
            a.advance_round(t);
            b.advance_round(t);
            for r in 0..k {
                let (ra, rb) = (a.recipients(r), b.recipients(r));
                assert_eq!(ra, rb, "two instances diverged at t={t} rank {r}");
                assert!(ra.contains(&r), "self always included");
                assert!(ra.windows(2).all(|w| w[0] < w[1]), "sorted");
                assert_eq!(ra.len(), size0, "neighborhood size drifted at t={t}");
            }
            membership.push(a.recipients(0));
        }
        assert!(
            membership.iter().any(|m| m != &membership[0]),
            "20 epochs never changed rank 0's neighborhood"
        );
        // cost model and link loads follow the current epoch's degrees
        let model = NetModel::gbe();
        let bits = vec![8 * 100u64; k];
        let cost = a.round_cost(&model, &bits);
        let total: f64 = a.link_loads(&bits).iter().map(|(_, b)| b).sum();
        assert!((total - cost.wire_bits as f64 / 8.0).abs() < 1e-6);
    }

    #[test]
    fn link_traffic_exposes_per_round_deltas() {
        let coll = mk("ring", 4);
        let mut lt = LinkTraffic::new();
        assert!(lt.last_round().is_empty());
        assert_eq!(lt.rounds(), 0);

        lt.record(coll.as_ref(), &[8 * 100u64; 4]);
        let first: Vec<(Link, f64)> = lt.last_round().to_vec();
        assert_eq!(first.len(), 4);
        assert!(first.iter().all(|&(_, b)| (b - 100.0).abs() < 1e-9));

        // A second, larger round replaces the delta but accumulates totals.
        lt.record(coll.as_ref(), &[8 * 300u64; 4]);
        assert_eq!(lt.rounds(), 2);
        assert!(lt.last_round().iter().all(|&(_, b)| (b - 300.0).abs() < 1e-9));
        assert!((lt.total_bytes() - 4.0 * 400.0).abs() < 1e-9);
        assert!((lt.max_link_bytes() - 400.0).abs() < 1e-9);

        // Delta stream order matches the collective's deterministic order.
        let again = coll.link_loads(&[8 * 300u64; 4]);
        assert_eq!(lt.last_round(), &again[..]);
    }
}
