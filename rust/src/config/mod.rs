//! Typed experiment configuration on top of the [`toml`] subset parser.
//!
//! One `ExperimentConfig` drives the whole launcher: which VI problem /
//! model, how many workers `K`, the quantization mode, the codec, the
//! network model and the algorithm variant. Every field has a default so a
//! config file only states what it changes; `ExperimentConfig::default()`
//! is itself a valid smoke experiment.

pub mod toml;

use crate::coding::SymbolCodec;
use crate::error::{Error, Result};
use toml::Doc;

/// Compression mode — FP32 (no compression) or quantized with `s` levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// Full precision: 32 bits/coordinate on the wire, no quantization.
    Fp32,
    /// Unbiased stochastic quantization with `s` interior levels
    /// (UQ4 ≡ s = 14 → 4 bits/symbol fixed-width; UQ8 ≡ s = 254).
    Quantized { levels: usize },
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fp32" | "full" => Ok(QuantMode::Fp32),
            "uq4" => Ok(QuantMode::Quantized { levels: 14 }),
            "uq8" => Ok(QuantMode::Quantized { levels: 254 }),
            other => {
                if let Some(n) = other.strip_prefix("s") {
                    if let Ok(levels) = n.parse::<usize>() {
                        return Ok(QuantMode::Quantized { levels });
                    }
                }
                Err(Error::Config(format!("unknown quant mode `{other}` (fp32|uq4|uq8|s<N>)")))
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            QuantMode::Fp32 => "fp32".into(),
            QuantMode::Quantized { levels: 14 } => "uq4".into(),
            QuantMode::Quantized { levels: 254 } => "uq8".into(),
            QuantMode::Quantized { levels } => format!("s{levels}"),
        }
    }
}

/// How the interior levels are placed / maintained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LevelScheme {
    /// Equally spaced (QSGD-style).
    Uniform,
    /// Exponentially spaced toward 0 (NUQSGD-style).
    Exponential,
    /// QAda: optimized to minimize quantization variance, updated on the
    /// schedule `U` (paper §3.3).
    Adaptive,
}

impl LevelScheme {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uniform" => Ok(LevelScheme::Uniform),
            "exponential" | "exp" => Ok(LevelScheme::Exponential),
            "adaptive" | "qada" => Ok(LevelScheme::Adaptive),
            other => Err(Error::Config(format!("unknown level scheme `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LevelScheme::Uniform => "uniform",
            LevelScheme::Exponential => "exponential",
            LevelScheme::Adaptive => "adaptive",
        }
    }
}

/// Per-layer overrides from a `[quant.layers.<name>]` table; `None` fields
/// inherit the base `[quant]` value. Only the quantizer knobs the wire
/// format depends on per layer are overridable (bits via `mode`, level
/// `scheme`, `codec`, `bucket_size`); the statistic shape (`hist_bins`,
/// `norm`) and the schedule (`update_every`, `stat_samples`) stay global so
/// the v3 stat payload is rectangular and all layers update in lockstep.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerOverride {
    pub mode: Option<QuantMode>,
    pub scheme: Option<LevelScheme>,
    pub codec: Option<SymbolCodec>,
    pub bucket_size: Option<usize>,
}

impl LayerOverride {
    pub fn is_empty(&self) -> bool {
        *self == LayerOverride::default()
    }

    /// Base `[quant]` config with this layer's overrides applied. The
    /// returned config is *flat* — its own `layers` table is cleared, since
    /// it describes one layer of an already-partitioned pipeline.
    pub fn apply(&self, base: &QuantConfig) -> QuantConfig {
        let mut cfg = base.clone();
        cfg.layers = LayersConfig::default();
        if let Some(m) = self.mode {
            cfg.mode = m;
        }
        if let Some(s) = self.scheme {
            cfg.scheme = s;
        }
        if let Some(c) = self.codec {
            cfg.codec = c;
        }
        if let Some(b) = self.bucket_size {
            cfg.bucket_size = b;
        }
        cfg
    }
}

/// Layer-wise quantization (`[quant.layers]` table / `--layers` CLI flag).
///
/// Empty `names` (the default) disables layer-wise handling entirely; one
/// name applies its override to the whole vector through the ordinary
/// single-codec pipeline (bit-identical machinery to no layer map at all);
/// two or more names engage the layer-wise compressor: per-layer
/// levels/codec/statistics, the v3 stat wire format, and — when `budget`
/// is set — the [`crate::quant::alloc`] bit-budget allocator re-run at
/// every level update. See `docs/CONFIG.md` for the full reference.
#[derive(Clone, Debug, Default)]
pub struct LayersConfig {
    /// Layer names, in coordinate order. Also the `[quant.layers.<name>]`
    /// override-table keys and the `layer_bits/<name>` metric suffixes.
    pub names: Vec<String>,
    /// Interior split points (`names.len() − 1` strictly increasing
    /// coordinate offsets). Empty → equal split aligned to the base bucket
    /// size, resolved once the vector dimension is known.
    pub bounds: Vec<usize>,
    /// Global symbol-bit budget per coordinate for the Theorem-1 allocator
    /// (`quant::alloc`); `0` (default) keeps each layer's configured bits.
    pub budget: f64,
    /// Per-layer overrides, parallel to `names` (missing entries = none).
    pub overrides: Vec<LayerOverride>,
}

impl LayersConfig {
    /// True when the layer-wise compressor (≥ 2 layers) is engaged.
    pub fn enabled(&self) -> bool {
        self.names.len() >= 2
    }

    /// Layer `i`'s override (default when none was configured).
    pub fn override_for(&self, i: usize) -> LayerOverride {
        self.overrides.get(i).cloned().unwrap_or_default()
    }

    /// Resolve the partition for dimension `d`; `align` is the boundary
    /// alignment for the automatic equal split (pass the base bucket size
    /// so buckets never straddle layers; ignored with explicit bounds).
    pub fn resolve_map(&self, d: usize, align: usize) -> Result<crate::quant::LayerMap> {
        if self.bounds.is_empty() {
            crate::quant::LayerMap::equal_split(self.names.clone(), d, align)
        } else {
            crate::quant::LayerMap::new(self.names.clone(), &self.bounds, d)
        }
    }

    /// Resolve one flat [`QuantConfig`] per layer from the base config.
    pub fn resolve_quant(&self, base: &QuantConfig) -> Vec<QuantConfig> {
        (0..self.names.len()).map(|i| self.override_for(i).apply(base)).collect()
    }

    /// Dimension-independent sanity checks (called from
    /// [`ExperimentConfig::validate`] and `Compressor::from_config`).
    pub fn validate(&self, base: &QuantConfig) -> Result<()> {
        if self.names.is_empty() {
            if self.budget != 0.0 || !self.bounds.is_empty() {
                return Err(Error::Config(
                    "quant.layers: bounds/budget set without layer names".into(),
                ));
            }
            return Ok(());
        }
        if !self.bounds.is_empty() && self.bounds.len() + 1 != self.names.len() {
            return Err(Error::Config(format!(
                "quant.layers: {} names need {} bounds (or none for an equal split), got {}",
                self.names.len(),
                self.names.len() - 1,
                self.bounds.len()
            )));
        }
        for w in self.bounds.windows(2) {
            if w[1] <= w[0] {
                return Err(Error::Config(format!(
                    "quant.layers.bounds must be strictly increasing, got {:?}",
                    self.bounds
                )));
            }
        }
        if let Some(&0) = self.bounds.first() {
            return Err(Error::Config("quant.layers.bounds must start above 0".into()));
        }
        if self.enabled() && base.mode == QuantMode::Fp32 {
            return Err(Error::Config(
                "quant.layers needs a quantized base mode (fp32 has no layer-wise path)".into(),
            ));
        }
        for (i, ov) in self.overrides.iter().enumerate() {
            if ov.mode == Some(QuantMode::Fp32) {
                return Err(Error::Config(format!(
                    "quant.layers.{}: per-layer mode must be quantized, not fp32",
                    self.names.get(i).map(String::as_str).unwrap_or("?")
                )));
            }
        }
        if !(self.budget == 0.0 || (2.0..=32.0).contains(&self.budget)) {
            return Err(Error::Config(format!(
                "quant.layers.budget = {} (0 = off, else 2..=32 bits/coordinate)",
                self.budget
            )));
        }
        if self.budget > 0.0 && !self.enabled() {
            return Err(Error::Config(
                "quant.layers.budget needs at least two layers to allocate across".into(),
            ));
        }
        Ok(())
    }

    /// Parse the `--layers` CLI spec: either a layer count (`--layers 4`,
    /// equal bucket-aligned split) or explicit named bounds
    /// (`--layers embed:4096,body:244736,head` — every layer but the last
    /// carries its end offset; the last ends at `d`).
    pub fn parse_cli(spec: &str) -> Result<LayersConfig> {
        if let Ok(n) = spec.parse::<usize>() {
            if n == 0 {
                return Err(Error::Config("--layers count must be >= 1".into()));
            }
            return Ok(LayersConfig {
                names: (0..n).map(|i| format!("l{i}")).collect(),
                ..Default::default()
            });
        }
        let parts: Vec<&str> = spec.split(',').collect();
        let mut names = Vec::with_capacity(parts.len());
        let mut bounds = Vec::with_capacity(parts.len().saturating_sub(1));
        for (i, part) in parts.iter().enumerate() {
            let part = part.trim();
            match part.split_once(':') {
                Some((name, end)) => {
                    if i + 1 == parts.len() {
                        return Err(Error::Config(
                            "--layers: the last layer ends at d; drop its `:end`".into(),
                        ));
                    }
                    names.push(name.trim().to_string());
                    bounds.push(end.trim().parse::<usize>().map_err(|_| {
                        Error::Config(format!("--layers: bad end offset in `{part}`"))
                    })?);
                }
                None => {
                    if i + 1 != parts.len() {
                        return Err(Error::Config(format!(
                            "--layers: layer `{part}` needs `name:end` (only the last \
                             layer's end is implicit)"
                        )));
                    }
                    names.push(part.to_string());
                }
            }
        }
        Ok(LayersConfig { names, bounds, ..Default::default() })
    }
}

/// Which contractive operator the error-feedback pipeline applies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EfScheme {
    /// Error feedback disabled — the unbiased `CODE∘Q` pipeline runs
    /// untouched (the default; bit-identical to configs predating
    /// `[quant.ef]`).
    #[default]
    Off,
    /// Deterministic top-k by magnitude (index-ascending tie-break).
    TopK,
    /// Seeded random-k; the support travels on the wire.
    RandK,
    /// Rank-r subspace-iteration projection of the matrix-shaped dual.
    RankR,
}

impl EfScheme {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" | "none" => Ok(EfScheme::Off),
            "topk" | "top-k" => Ok(EfScheme::TopK),
            "randk" | "rand-k" => Ok(EfScheme::RandK),
            "rankr" | "rank-r" => Ok(EfScheme::RankR),
            other => {
                Err(Error::Config(format!("unknown ef scheme `{other}` (off|topk|randk|rankr)")))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EfScheme::Off => "off",
            EfScheme::TopK => "topk",
            EfScheme::RandK => "randk",
            EfScheme::RankR => "rankr",
        }
    }
}

/// Per-layer overrides from a `[quant.ef.<name>]` table; `None` fields
/// inherit the base `[quant.ef]` value. The scheme itself stays global —
/// mixing sparsifiers and low-rank projections across layers of one dual
/// vector is not supported.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EfOverride {
    pub k: Option<usize>,
    pub rank: Option<usize>,
}

/// Contractive compression with error feedback (`[quant.ef]` table /
/// `--ef` CLI flag). When enabled, the biased-compressor pipeline
/// (`Compressor::Contractive`) replaces the unbiased `CODE∘Q` stack:
/// `quant.mode`/`scheme`/`codec` are bypassed, nothing adapts
/// ([`QuantConfig::adapts`] is false) and stat rounds stay at zero. The
/// per-worker error memory `e_{t+1} = e_t + g_t − C(e_t + g_t)` repairs
/// the compression bias over time; see `quant::contractive` for the
/// operator family and `docs/WIRE.md` §5 for the frames.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EfConfig {
    /// Operator family; `Off` (default) disables the subsystem entirely.
    pub scheme: EfScheme,
    /// Coordinates kept per (layer) vector for `topk`/`randk`; required
    /// (≥ 1) when one of those schemes is active.
    pub k: usize,
    /// Target rank for `rankr`; required (≥ 1) when active.
    pub rank: usize,
    /// Matrix rows for `rankr` on an unpartitioned dual (`0` = automatic
    /// near-square factorisation, [`crate::quant::auto_shape`]). Must
    /// divide the problem dimension. With `[quant.layers]` active every
    /// layer is auto-shaped and `rows` must stay 0.
    pub rows: usize,
    /// Per-layer `k`/`rank` overrides keyed by `[quant.layers]` names.
    pub overrides: Vec<(String, EfOverride)>,
}

impl EfConfig {
    /// True when the contractive pipeline replaces the unbiased one.
    pub fn enabled(&self) -> bool {
        self.scheme != EfScheme::Off
    }

    /// The override for layer `name`, if any.
    pub fn override_for(&self, name: &str) -> EfOverride {
        self.overrides
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, ov)| *ov)
            .unwrap_or_default()
    }

    /// Resolve the concrete operator for one (layer) vector of dimension
    /// `d`. `name = None` is the unpartitioned single-vector pipeline.
    pub fn resolve_op(&self, name: Option<&str>, d: usize) -> Result<crate::quant::ContractiveOp> {
        use crate::quant::ContractiveOp;
        let ov = name.map(|n| self.override_for(n)).unwrap_or_default();
        let where_ = |k: &str| match name {
            Some(n) => format!("quant.ef.{n}.{k}"),
            None => format!("quant.ef.{k}"),
        };
        match self.scheme {
            EfScheme::Off => Err(Error::Config("quant.ef: scheme is off".into())),
            EfScheme::TopK | EfScheme::RandK => {
                let k = ov.k.unwrap_or(self.k);
                if k == 0 {
                    return Err(Error::Config(format!(
                        "{}: k must be >= 1 for scheme `{}`",
                        where_("k"),
                        self.scheme.name()
                    )));
                }
                if self.scheme == EfScheme::TopK {
                    Ok(ContractiveOp::TopK { k })
                } else {
                    Ok(ContractiveOp::RandK { k })
                }
            }
            EfScheme::RankR => {
                let rank = ov.rank.unwrap_or(self.rank);
                if rank == 0 {
                    return Err(Error::Config(format!(
                        "{}: rank must be >= 1 for scheme `rankr`",
                        where_("rank")
                    )));
                }
                let (rows, cols) = if self.rows > 0 && name.is_none() {
                    if d % self.rows != 0 {
                        return Err(Error::Config(format!(
                            "quant.ef.rows = {} does not divide dimension {d}",
                            self.rows
                        )));
                    }
                    (self.rows, d / self.rows)
                } else {
                    crate::quant::auto_shape(d)
                };
                Ok(ContractiveOp::RankR { rank, rows, cols })
            }
        }
    }

    /// Validate against the base `[quant]` config and the problem
    /// dimension; every resolved operator must fit its (layer) vector.
    pub fn validate(&self, base: &QuantConfig, d: usize) -> Result<()> {
        if !self.enabled() {
            if self.k != 0 || self.rank != 0 || self.rows != 0 || !self.overrides.is_empty() {
                return Err(Error::Config(
                    "quant.ef: k/rank/rows/overrides set while scheme = \"off\"".into(),
                ));
            }
            return Ok(());
        }
        if base.layers.budget > 0.0 {
            return Err(Error::Config(
                "quant.ef is incompatible with quant.layers.budget (the bit-budget \
                 allocator is unbiased-pipeline machinery and nothing adapts under EF)"
                    .into(),
            ));
        }
        for (name, _) in &self.overrides {
            if !base.layers.names.iter().any(|n| n == name) {
                return Err(Error::Config(format!(
                    "quant.ef.{name}: no such layer in quant.layers.names"
                )));
            }
        }
        if !self.overrides.is_empty() && !base.layers.enabled() {
            return Err(Error::Config(
                "quant.ef: per-layer overrides need quant.layers with >= 2 names".into(),
            ));
        }
        if self.rows > 0 && self.scheme != EfScheme::RankR {
            return Err(Error::Config("quant.ef.rows only applies to scheme = \"rankr\"".into()));
        }
        if self.rows > 0 && base.layers.enabled() {
            return Err(Error::Config(
                "quant.ef.rows is for the unpartitioned dual; layered rankr auto-shapes \
                 each layer"
                    .into(),
            ));
        }
        if base.layers.enabled() {
            let map = base.layers.resolve_map(d, base.bucket_size)?;
            for i in 0..map.len() {
                let op = self.resolve_op(Some(map.name(i)), map.dim(i))?;
                op.validate(map.dim(i)).map_err(|e| {
                    Error::Config(format!("quant.ef (layer `{}`): {e}", map.name(i)))
                })?;
            }
        } else {
            let op = self.resolve_op(None, d)?;
            op.validate(d).map_err(|e| Error::Config(format!("quant.ef: {e}")))?;
        }
        Ok(())
    }

    /// Parse the `--ef` CLI spec: `off`, `topk:<k>`, `randk:<k>`,
    /// `rankr:<rank>` or `rankr:<rank>:<rows>`.
    pub fn parse_cli(spec: &str) -> Result<EfConfig> {
        let mut parts = spec.split(':');
        let scheme = EfScheme::parse(parts.next().unwrap_or("").trim())?;
        let mut cfg = EfConfig { scheme, ..Default::default() };
        let arg = |p: Option<&str>, what: &str| -> Result<usize> {
            p.map(str::trim)
                .filter(|s| !s.is_empty())
                .ok_or_else(|| Error::Config(format!("--ef: `{spec}` is missing {what}")))?
                .parse::<usize>()
                .map_err(|_| Error::Config(format!("--ef: bad {what} in `{spec}`")))
        };
        match scheme {
            EfScheme::Off => {}
            EfScheme::TopK | EfScheme::RandK => {
                cfg.k = arg(parts.next(), "k (e.g. `topk:64`)")?;
            }
            EfScheme::RankR => {
                cfg.rank = arg(parts.next(), "rank (e.g. `rankr:4`)")?;
                if let Some(rows) = parts.next() {
                    cfg.rows = arg(Some(rows), "rows")?;
                }
            }
        }
        if parts.next().is_some() {
            return Err(Error::Config(format!("--ef: trailing fields in `{spec}`")));
        }
        Ok(cfg)
    }
}

/// Quantization + wire-format configuration.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub mode: QuantMode,
    pub scheme: LevelScheme,
    /// `q` of the `L^q` normalization; `u32::MAX` = L∞.
    pub norm_q: u32,
    /// Bucket size: vectors are quantized in independent buckets of this
    /// many coordinates (torch_cgx uses 1024). 0 = whole vector.
    pub bucket_size: usize,
    pub codec: SymbolCodec,
    /// Re-optimize adaptive levels every this many iterations (schedule U).
    pub update_every: usize,
    /// Histogram bins for the QAda sufficient statistic.
    pub hist_bins: usize,
    /// Per-segment cap on the vectors (buckets, under bucketing) fed to
    /// the QAda sufficient statistic in `Compressor::compress` — bounds
    /// stat upkeep at large `d`. 0 (the default) = unlimited, the
    /// historical behavior.
    pub stat_samples: usize,
    /// Layer-wise quantization (`[quant.layers]`): named partition of the
    /// dual vector with per-layer overrides and an optional bit budget.
    /// Default (no names) = the single-codec pipeline.
    pub layers: LayersConfig,
    /// Contractive compression with error feedback (`[quant.ef]`). When
    /// enabled it *replaces* the unbiased pipeline; default = off.
    pub ef: EfConfig,
}

impl QuantConfig {
    /// True when anything adapts on the update schedule `U` — QAda level
    /// placement (`scheme == Adaptive`), the Huffman probability model
    /// (`codec == Huffman`) on *any* layer, or the layer-wise bit-budget
    /// allocator (`layers.budget > 0`, which re-runs on pooled stats). The
    /// single source of truth for "does this pipeline exchange sufficient
    /// statistics": `stats_payload`, `update_levels` and every runner's
    /// stat-round schedule must agree on it (they once didn't, and
    /// Huffman-with-fixed-levels runs paid for stat rounds whose payloads
    /// were all empty).
    pub fn adapts(&self) -> bool {
        if self.ef.enabled() {
            // Contractive modes are non-adaptive by construction: no level
            // placement, no probability model, no stat payloads. Asserted
            // again in `Compressor::from_config` and pinned by tests.
            return false;
        }
        if self.layers.names.is_empty() {
            return self.scheme == LevelScheme::Adaptive || self.codec == SymbolCodec::Huffman;
        }
        if self.layers.enabled() && self.layers.budget > 0.0 {
            return true;
        }
        self.layers.resolve_quant(self).iter().any(|c| {
            c.scheme == LevelScheme::Adaptive || c.codec == SymbolCodec::Huffman
        })
    }
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            mode: QuantMode::Quantized { levels: 14 },
            scheme: LevelScheme::Adaptive,
            norm_q: 2,
            bucket_size: 1024,
            codec: SymbolCodec::Huffman,
            update_every: 100,
            hist_bins: 256,
            stat_samples: 0,
            layers: LayersConfig::default(),
            ef: EfConfig::default(),
        }
    }
}

/// Q-GenX variant: which oracle queries feed V̂_{k,t} and V̂_{k,t+1/2}
/// (paper Examples 3.1–3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Quantized dual averaging (V̂_t ≡ 0).
    DualAveraging,
    /// Quantized dual extrapolation (classic extra-gradient queries).
    DualExtrapolation,
    /// Quantized optimistic dual averaging (reuses the previous half-step
    /// query — one oracle call per iteration).
    OptimisticDualAveraging,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "da" | "dual-averaging" => Ok(Variant::DualAveraging),
            "de" | "dual-extrapolation" | "extragradient" | "eg" => Ok(Variant::DualExtrapolation),
            "optda" | "optimistic" => Ok(Variant::OptimisticDualAveraging),
            other => Err(Error::Config(format!("unknown variant `{other}` (da|de|optda)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::DualAveraging => "da",
            Variant::DualExtrapolation => "de",
            Variant::OptimisticDualAveraging => "optda",
        }
    }
}

/// Which algorithm family drives each iteration (`[algo] method`). The
/// method owns the per-iteration cadence — how many oracle calls and
/// quantized exchanges one step costs — while the policies only execute
/// the round-plan it exposes (see `algo::method::MethodState`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Method {
    /// The paper's Q-GenX template (Algorithm 1) in the configured
    /// `variant`. Two oracle calls and up to two exchanges per step
    /// (one each under DA/OptDA).
    #[default]
    QGenX,
    /// Past extra-gradient / optimistic gradient: reuses the previous
    /// half-step dual in the extrapolation, so ONE oracle call and ONE
    /// quantized exchange per iteration.
    Peg,
    /// Extra-gradient with safeguarded Anderson acceleration, EG-AA(1):
    /// same two-call/two-exchange cadence as extra-gradient, with a
    /// depth-1 Anderson candidate accepted only under a residual-decrease
    /// guard (the safeguard never adds wire rounds).
    EgAa,
}

impl Method {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "qgenx" => Ok(Method::QGenX),
            "peg" | "past" | "past-eg" | "optimistic-gradient" => Ok(Method::Peg),
            "eg-aa" | "egaa" | "anderson" => Ok(Method::EgAa),
            other => Err(Error::Config(format!("unknown method `{other}` (qgenx|peg|eg-aa)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::QGenX => "qgenx",
            Method::Peg => "peg",
            Method::EgAa => "eg-aa",
        }
    }
}

/// Algorithm configuration.
#[derive(Clone, Debug)]
pub struct AlgoConfig {
    /// Algorithm family (`qgenx` | `peg` | `eg-aa`). The default is the
    /// paper's template; anything else makes `variant` meaningless (and
    /// setting both is rejected at parse time).
    pub method: Method,
    pub variant: Variant,
    /// Base step scale multiplying the adaptive rule (γ0).
    pub gamma0: f64,
    /// Use the paper's adaptive step-size (false = fixed γ0/√T style).
    pub adaptive_step: bool,
}

impl Default for AlgoConfig {
    fn default() -> Self {
        AlgoConfig {
            method: Method::QGenX,
            variant: Variant::DualExtrapolation,
            gamma0: 1.0,
            adaptive_step: true,
        }
    }
}

/// Strict `[algo]` table parsing: unknown keys are hard errors (matching
/// the `[quant.ef]` strictness), and qgenx-family knobs cannot leak onto
/// the single-call methods.
fn parse_algo(doc: &toml::Doc, d: &AlgoConfig) -> Result<AlgoConfig> {
    const KNOWN: [&str; 4] = ["method", "variant", "gamma0", "adaptive_step"];
    for key in doc.keys_with_prefix("algo.") {
        let bare = &key["algo.".len()..];
        if !KNOWN.contains(&bare) {
            return Err(Error::Config(format!(
                "unknown key `{key}` in [algo] (known: method, variant, gamma0, adaptive_step)"
            )));
        }
    }
    let method = Method::parse(&doc.get_str("algo.method", d.method.name())?)?;
    if method != Method::QGenX && doc.contains("algo.variant") {
        return Err(Error::Config(format!(
            "algo.variant is a qgenx-family knob; method = \"{}\" does not take one \
             (drop the key or set method = \"qgenx\")",
            method.name()
        )));
    }
    Ok(AlgoConfig {
        method,
        variant: Variant::parse(&doc.get_str("algo.variant", d.variant.name())?)?,
        gamma0: doc.get_f64("algo.gamma0", d.gamma0)?,
        adaptive_step: doc.get_bool("algo.adaptive_step", d.adaptive_step)?,
    })
}

/// Communication topology selection (`[topo]` table) — which graph carries
/// each exchange round; see [`crate::topo::Topology`] for semantics.
#[derive(Clone, Debug)]
pub struct TopoConfig {
    /// `full-mesh` (default, the paper's Algorithm 1) | `star` | `ring` |
    /// `hierarchical` | `gossip` (plus aliases; see `Topology::from_config`).
    pub kind: String,
    /// Hierarchical: number of groups; 0 = auto (`⌈√K⌉`).
    pub groups: usize,
    /// Gossip: target neighbor count per node.
    pub degree: usize,
    /// Gossip: chord-placement seed; 0 = derived from `degree`.
    pub seed: u64,
    /// Gossip: re-draw the edge set every this many steps (time-varying
    /// schedule; see [`crate::topo::RewiringGossip`]). 0 = static graph
    /// (the default, bit-identical to the pre-schedule behavior). Only
    /// meaningful with `kind = "gossip"` — rejected elsewhere.
    pub rewire_every: usize,
}

impl Default for TopoConfig {
    fn default() -> Self {
        TopoConfig { kind: "full-mesh".into(), groups: 0, degree: 3, seed: 0, rewire_every: 0 }
    }
}

/// Local-steps execution (`[local]` table): each worker runs `steps`
/// extra-gradient iterations on its private oracle between communication
/// rounds, then the replicas exchange *quantized model deltas* over the
/// configured topology and re-synchronize by averaging.
///
/// `steps = 1` (the default) is the seed behavior — communication every
/// iteration via per-step dual exchange, bit-for-bit identical to the
/// runners predating this table. `steps ≥ 2` engages the delta-sync
/// runner (`coordinator::inline::run_local` / the threaded local loop).
#[derive(Clone, Debug)]
pub struct LocalConfig {
    /// Local extra-gradient iterations per communication round (H ≥ 1).
    pub steps: usize,
    /// Bounded-staleness cap for semi-async delta syncs: a worker that
    /// misses the (modeled) sync deadline may have its previous delta
    /// carried forward for at most this many consecutive syncs before the
    /// sync falls back to the blocking barrier for it. 0 disables the
    /// semi-async path entirely (the default; fully synchronous syncs,
    /// bit-identical to the pre-staleness behavior).
    pub staleness: usize,
    /// Probability in `[0, 1)` that a worker misses a sync deadline
    /// (deterministic per `(seed, step, worker)` — a modeled straggler,
    /// not wall-clock racing). 0.0 = nobody straggles. Requires
    /// `staleness >= 1` when positive.
    pub straggler_rate: f64,
}

impl Default for LocalConfig {
    fn default() -> Self {
        LocalConfig { steps: 1, staleness: 0, straggler_rate: 0.0 }
    }
}

/// Simulated network (α-β model) plus real-transport knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Link bandwidth in bytes/second (default 1 GbE ≈ 117 MiB/s usable).
    pub bandwidth_bps: f64,
    /// Per-message latency in seconds (default 50 µs).
    pub latency_s: f64,
    /// All-to-all (true, paper's broadcast model) vs star via leader.
    pub all_to_all: bool,
    /// Real-transport exchange timeout in milliseconds: how long one rank
    /// waits for its peers in a synchronous round before poisoning the
    /// group (`0` = wait forever on the in-process barrier; the socket
    /// fabric substitutes its own 30 s default).
    pub timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            bandwidth_bps: 117.0 * 1024.0 * 1024.0,
            latency_s: 50e-6,
            all_to_all: true,
            timeout_ms: 0,
        }
    }
}

impl NetConfig {
    /// The configured exchange timeout as a [`std::time::Duration`]
    /// (`None` when `timeout_ms = 0`, i.e. no cap configured).
    pub fn exchange_timeout(&self) -> Option<std::time::Duration> {
        (self.timeout_ms > 0).then(|| std::time::Duration::from_millis(self.timeout_ms))
    }
}

/// VI problem selection.
#[derive(Clone, Debug)]
pub struct ProblemConfig {
    /// bilinear | quadratic | rotation | cocoercive | game
    pub kind: String,
    pub dim: usize,
    /// Absolute-noise stddev σ (Assumption 2).
    pub sigma: f64,
    /// Relative-noise factor c (Assumption 3); used by relative oracles.
    pub rel_c: f64,
    /// absolute | relative | rcd | player
    pub noise: String,
}

impl Default for ProblemConfig {
    fn default() -> Self {
        ProblemConfig {
            kind: "bilinear".into(),
            dim: 64,
            sigma: 1.0,
            rel_c: 1.0,
            noise: "absolute".into(),
        }
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// Number of processors K.
    pub workers: usize,
    /// Iterations T.
    pub iters: usize,
    /// Evaluate the gap every this many iterations.
    pub eval_every: usize,
    pub quant: QuantConfig,
    pub algo: AlgoConfig,
    pub net: NetConfig,
    pub topo: TopoConfig,
    pub local: LocalConfig,
    pub problem: ProblemConfig,
    /// Where benches/drivers write CSV output.
    pub out_dir: String,
    /// Directory holding AOT HLO artifacts.
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 42,
            workers: 3,
            iters: 1000,
            eval_every: 50,
            quant: QuantConfig::default(),
            algo: AlgoConfig::default(),
            net: NetConfig::default(),
            topo: TopoConfig::default(),
            local: LocalConfig::default(),
            problem: ProblemConfig::default(),
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML text.
    pub fn from_toml(src: &str) -> Result<Self> {
        let doc = Doc::parse(src)?;
        Self::from_doc(&doc)
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Self> {
        let doc = Doc::load(path)?;
        let cfg = Self::from_doc(&doc)?;
        let unused = doc.unused_keys();
        if !unused.is_empty() {
            eprintln!("warning: config {path}: unused keys (typos?): {unused:?}");
        }
        Ok(cfg)
    }

    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let d = ExperimentConfig::default();
        let layers = parse_layers(doc)?;
        let ef = parse_ef(doc, &layers.names)?;
        let cfg = ExperimentConfig {
            name: doc.get_str("name", &d.name)?,
            seed: doc.get_i64("seed", d.seed as i64)? as u64,
            workers: doc.get_usize("workers", d.workers)?,
            iters: doc.get_usize("iters", d.iters)?,
            eval_every: doc.get_usize("eval_every", d.eval_every)?,
            quant: QuantConfig {
                mode: QuantMode::parse(&doc.get_str("quant.mode", &d.quant.mode.name())?)?,
                scheme: LevelScheme::parse(&doc.get_str("quant.scheme", d.quant.scheme.name())?)?,
                norm_q: {
                    let q = doc.get_str("quant.norm", "l2")?;
                    parse_norm(&q)?
                },
                bucket_size: doc.get_usize("quant.bucket_size", d.quant.bucket_size)?,
                codec: SymbolCodec::parse(&doc.get_str("quant.codec", d.quant.codec.name())?)
                    .ok_or_else(|| Error::Config("bad quant.codec".into()))?,
                update_every: doc.get_usize("quant.update_every", d.quant.update_every)?,
                hist_bins: doc.get_usize("quant.hist_bins", d.quant.hist_bins)?,
                stat_samples: doc.get_usize("quant.stat_samples", d.quant.stat_samples)?,
                layers,
                ef,
            },
            algo: parse_algo(doc, &d.algo)?,
            net: NetConfig {
                bandwidth_bps: doc.get_f64("net.bandwidth_mbps", d.net.bandwidth_bps / 1e6)?
                    * 1e6,
                latency_s: doc.get_f64("net.latency_us", d.net.latency_s * 1e6)? * 1e-6,
                all_to_all: doc.get_bool("net.all_to_all", d.net.all_to_all)?,
                timeout_ms: doc.get_usize("net.timeout_ms", d.net.timeout_ms as usize)? as u64,
            },
            topo: {
                // Back-compat: `net.all_to_all = false` predates the [topo]
                // table and means "route through a leader"; an explicit
                // `topo.kind` wins. Note the topo-era star is the *sharded*
                // parameter server (cheaper than mesh at scale), not the
                // seed's centralized-leader cost model — warn so the
                // semantic shift is never silent.
                let legacy_star = !doc.get_bool("net.all_to_all", true)?
                    && !doc.contains("topo.kind");
                if legacy_star {
                    eprintln!(
                        "warning: net.all_to_all = false is deprecated; mapping to \
                         topo.kind = \"star\" (sharded parameter server — costs differ \
                         from the old leader-star model). Set [topo] kind explicitly."
                    );
                }
                TopoConfig {
                    kind: if legacy_star {
                        "star".into()
                    } else {
                        doc.get_str("topo.kind", &d.topo.kind)?
                    },
                    groups: doc.get_usize("topo.groups", d.topo.groups)?,
                    degree: doc.get_usize("topo.degree", d.topo.degree)?,
                    seed: doc.get_i64("topo.seed", d.topo.seed as i64)? as u64,
                    rewire_every: doc.get_usize("topo.rewire_every", d.topo.rewire_every)?,
                }
            },
            local: LocalConfig {
                steps: doc.get_usize("local.steps", d.local.steps)?,
                staleness: doc.get_usize("local.staleness", d.local.staleness)?,
                straggler_rate: doc.get_f64("local.straggler_rate", d.local.straggler_rate)?,
            },
            problem: ProblemConfig {
                kind: doc.get_str("problem.kind", &d.problem.kind)?,
                dim: doc.get_usize("problem.dim", d.problem.dim)?,
                sigma: doc.get_f64("problem.sigma", d.problem.sigma)?,
                rel_c: doc.get_f64("problem.rel_c", d.problem.rel_c)?,
                noise: doc.get_str("problem.noise", &d.problem.noise)?,
            },
            out_dir: doc.get_str("out_dir", &d.out_dir)?,
            artifacts_dir: doc.get_str("artifacts_dir", &d.artifacts_dir)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks that catch misconfiguration early.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("workers must be >= 1".into()));
        }
        if self.iters == 0 {
            return Err(Error::Config("iters must be >= 1".into()));
        }
        if let QuantMode::Quantized { levels } = self.quant.mode {
            if levels == 0 {
                return Err(Error::Config("quant levels must be >= 1".into()));
            }
            if levels > 65_534 {
                return Err(Error::Config("quant levels too large (> 65534)".into()));
            }
        }
        if self.quant.hist_bins < 2 {
            return Err(Error::Config("quant.hist_bins must be >= 2".into()));
        }
        self.quant.layers.validate(&self.quant)?;
        if !self.quant.layers.names.is_empty() {
            // The VI runners' dual vector has dimension problem.dim, so the
            // partition can be resolved (and rejected) at config time.
            self.quant
                .layers
                .resolve_map(self.problem.dim, self.quant.bucket_size)
                .map_err(|e| Error::Config(format!("quant.layers: {e}")))?;
        }
        self.quant.ef.validate(&self.quant, self.problem.dim)?;
        if !(self.net.bandwidth_bps > 0.0) {
            return Err(Error::Config("net.bandwidth must be positive".into()));
        }
        if self.problem.dim == 0 {
            return Err(Error::Config("problem.dim must be >= 1".into()));
        }
        if self.algo.gamma0 <= 0.0 {
            return Err(Error::Config("algo.gamma0 must be positive".into()));
        }
        if self.local.steps == 0 {
            return Err(Error::Config("local.steps must be >= 1".into()));
        }
        if !(0.0..1.0).contains(&self.local.straggler_rate) {
            return Err(Error::Config(format!(
                "local.straggler_rate = {} must be in [0, 1)",
                self.local.straggler_rate
            )));
        }
        if self.local.straggler_rate > 0.0 && self.local.staleness == 0 {
            return Err(Error::Config(
                "local.straggler_rate > 0 needs local.staleness >= 1 \
                 (a staleness cap of 0 means fully synchronous syncs)"
                    .into(),
            ));
        }
        // A timeout below the floor cannot cover even a local round trip —
        // it would poison healthy groups. 0 stays valid: it means
        // "uncapped" (the socket fabric substitutes its own 30 s default).
        if self.net.timeout_ms != 0 && self.net.timeout_ms < 10 {
            return Err(Error::Config(format!(
                "net.timeout_ms = {} is absurdly small (minimum 10 ms; \
                 0 = no cap)",
                self.net.timeout_ms
            )));
        }
        // Topology must resolve for this worker count (kind known, groups /
        // degree in range); surfaced at config time, not mid-run.
        let topo = crate::topo::Topology::from_config(&self.topo, self.workers)?;
        if self.topo.rewire_every > 0
            && !matches!(topo, crate::topo::Topology::Gossip { .. })
        {
            return Err(Error::Config(format!(
                "topo.rewire_every = {} needs topo.kind = \"gossip\" \
                 (exact topologies have no edge schedule to rewire); got `{}`",
                self.topo.rewire_every,
                topo.name()
            )));
        }
        Ok(())
    }
}

/// Parse the `[quant.layers]` table (+ per-layer `[quant.layers.<name>]`
/// override tables) into a [`LayersConfig`]. Reserved keys inside
/// `[quant.layers]`: `names`, `bounds`, `budget`, `count` — a layer may not
/// use one of these as its name.
fn parse_layers(doc: &Doc) -> Result<LayersConfig> {
    let mut names = doc.get_str_array("quant.layers.names")?.unwrap_or_default();
    let count = doc.get_usize("quant.layers.count", 0)?;
    if names.is_empty() && count > 0 {
        names = (0..count).map(|i| format!("l{i}")).collect();
    } else if !names.is_empty() && count > 0 && count != names.len() {
        return Err(Error::Config(format!(
            "quant.layers: count = {count} contradicts {} names",
            names.len()
        )));
    }
    const RESERVED: [&str; 4] = ["names", "bounds", "budget", "count"];
    let mut overrides = Vec::with_capacity(names.len());
    for name in &names {
        if RESERVED.contains(&name.as_str()) {
            return Err(Error::Config(format!("quant.layers: `{name}` is a reserved key")));
        }
        let key = |k: &str| format!("quant.layers.{name}.{k}");
        let mode = match doc.get_str(&key("mode"), "")?.as_str() {
            "" => None,
            m => Some(QuantMode::parse(m)?),
        };
        let scheme = match doc.get_str(&key("scheme"), "")?.as_str() {
            "" => None,
            s => Some(LevelScheme::parse(s)?),
        };
        let codec = match doc.get_str(&key("codec"), "")?.as_str() {
            "" => None,
            c => Some(
                SymbolCodec::parse(c)
                    .ok_or_else(|| Error::Config(format!("bad {}", key("codec"))))?,
            ),
        };
        let bucket_size = if doc.contains(&key("bucket_size")) {
            Some(doc.get_usize(&key("bucket_size"), 0)?)
        } else {
            None
        };
        overrides.push(LayerOverride { mode, scheme, codec, bucket_size });
    }
    Ok(LayersConfig {
        names,
        bounds: doc.get_usize_array("quant.layers.bounds")?.unwrap_or_default(),
        budget: doc.get_f64("quant.layers.budget", 0.0)?,
        overrides,
    })
}

/// Parse the `[quant.ef]` table (+ per-layer `[quant.ef.<name>]` override
/// tables keyed by the `[quant.layers]` names) into an [`EfConfig`].
/// Reserved keys inside `[quant.ef]`: `scheme`, `k`, `rank`, `rows`.
fn parse_ef(doc: &Doc, layer_names: &[String]) -> Result<EfConfig> {
    let scheme = EfScheme::parse(&doc.get_str("quant.ef.scheme", "off")?)?;
    const RESERVED: [&str; 4] = ["scheme", "k", "rank", "rows"];
    let mut overrides = Vec::new();
    if scheme != EfScheme::Off {
        for name in layer_names {
            if RESERVED.contains(&name.as_str()) {
                return Err(Error::Config(format!("quant.ef: `{name}` is a reserved key")));
            }
            let key = |k: &str| format!("quant.ef.{name}.{k}");
            let k = doc.contains(&key("k")).then(|| doc.get_usize(&key("k"), 0)).transpose()?;
            let rank =
                doc.contains(&key("rank")).then(|| doc.get_usize(&key("rank"), 0)).transpose()?;
            if k.is_some() || rank.is_some() {
                overrides.push((name.clone(), EfOverride { k, rank }));
            }
        }
    }
    Ok(EfConfig {
        scheme,
        k: doc.get_usize("quant.ef.k", 0)?,
        rank: doc.get_usize("quant.ef.rank", 0)?,
        rows: doc.get_usize("quant.ef.rows", 0)?,
        overrides,
    })
}

/// Parse "l1" | "l2" | "linf" | "l<q>" into the norm exponent.
pub fn parse_norm(s: &str) -> Result<u32> {
    match s {
        "l1" => Ok(1),
        "l2" => Ok(2),
        "linf" | "inf" => Ok(u32::MAX),
        other => other
            .strip_prefix('l')
            .and_then(|n| n.parse::<u32>().ok())
            .filter(|&q| q >= 1)
            .ok_or_else(|| Error::Config(format!("bad norm `{other}` (l1|l2|linf|l<q>)"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let src = r#"
name = "fig4"
seed = 7
workers = 8
iters = 5000
eval_every = 100

[quant]
mode = "uq8"
scheme = "adaptive"
norm = "linf"
bucket_size = 512
codec = "huffman"
update_every = 250

[algo]
variant = "optda"
gamma0 = 0.5
adaptive_step = true

[net]
bandwidth_mbps = 125.0
latency_us = 20.0
timeout_ms = 1500

[problem]
kind = "quadratic"
dim = 1024
sigma = 0.1
noise = "relative"
"#;
        let cfg = ExperimentConfig::from_toml(src).unwrap();
        assert_eq!(cfg.name, "fig4");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.quant.mode, QuantMode::Quantized { levels: 254 });
        assert_eq!(cfg.quant.norm_q, u32::MAX);
        assert_eq!(cfg.algo.variant, Variant::OptimisticDualAveraging);
        assert!((cfg.net.bandwidth_bps - 125e6).abs() < 1.0);
        assert!((cfg.net.latency_s - 20e-6).abs() < 1e-12);
        assert_eq!(cfg.net.timeout_ms, 1500);
        assert_eq!(cfg.net.exchange_timeout(), Some(std::time::Duration::from_millis(1500)));
        assert_eq!(cfg.problem.kind, "quadratic");
    }

    #[test]
    fn exchange_timeout_zero_means_uncapped() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.net.timeout_ms, 0);
        assert_eq!(cfg.net.exchange_timeout(), None);
        cfg.validate().unwrap();
    }

    #[test]
    fn absurdly_small_timeouts_are_rejected_at_load() {
        // The satellite bugfix: 1–9 ms cannot cover even a local round
        // trip and would poison healthy groups; reject at config load.
        for ms in [1u64, 5, 9] {
            let mut cfg = ExperimentConfig::default();
            cfg.net.timeout_ms = ms;
            let err = cfg.validate().expect_err("sub-10ms timeout");
            assert!(err.to_string().contains("timeout_ms"), "got: {err}");
            assert!(err.to_string().contains("absurdly small"), "got: {err}");
            let err = ExperimentConfig::from_toml(&format!("[net]\ntimeout_ms = {ms}\n"))
                .expect_err("rejected at parse too");
            assert!(err.to_string().contains("timeout_ms"), "got: {err}");
        }
        // The floor itself and 0 (= uncapped) stay valid.
        for ms in [0u64, 10, 1500] {
            let mut cfg = ExperimentConfig::default();
            cfg.net.timeout_ms = ms;
            cfg.validate().unwrap_or_else(|e| panic!("timeout_ms = {ms} valid: {e}"));
        }
    }

    #[test]
    fn parses_rewire_schedule_and_requires_gossip() {
        assert_eq!(ExperimentConfig::default().topo.rewire_every, 0);
        let cfg = ExperimentConfig::from_toml(
            "workers = 8\n[topo]\nkind = \"gossip\"\ndegree = 4\nrewire_every = 25\n",
        )
        .unwrap();
        assert_eq!(cfg.topo.rewire_every, 25);
        // rewiring an exact topology is a config error, not a silent no-op
        let err = ExperimentConfig::from_toml("[topo]\nkind = \"ring\"\nrewire_every = 25\n")
            .expect_err("exact topologies have no schedule");
        assert!(err.to_string().contains("rewire_every"), "got: {err}");
        assert!(err.to_string().contains("gossip"), "got: {err}");
    }

    #[test]
    fn parses_staleness_knobs_and_validates_bounds() {
        let d = ExperimentConfig::default();
        assert_eq!(d.local.staleness, 0);
        assert_eq!(d.local.straggler_rate, 0.0);
        let cfg = ExperimentConfig::from_toml(
            "workers = 4\n[local]\nsteps = 4\nstaleness = 2\nstraggler_rate = 0.3\n",
        )
        .unwrap();
        assert_eq!(cfg.local.staleness, 2);
        assert!((cfg.local.straggler_rate - 0.3).abs() < 1e-12);
        // rate outside [0, 1) rejected
        for rate in ["1.0", "1.5", "-0.1"] {
            let err = ExperimentConfig::from_toml(&format!(
                "[local]\nsteps = 4\nstaleness = 2\nstraggler_rate = {rate}\n"
            ))
            .expect_err(rate);
            assert!(err.to_string().contains("straggler_rate"), "{rate}: {err}");
        }
        // a positive rate without a staleness cap cannot work
        let err = ExperimentConfig::from_toml("[local]\nsteps = 4\nstraggler_rate = 0.3\n")
            .expect_err("rate without staleness");
        assert!(err.to_string().contains("staleness"), "got: {err}");
    }

    #[test]
    fn quant_mode_parsing() {
        assert_eq!(QuantMode::parse("fp32").unwrap(), QuantMode::Fp32);
        assert_eq!(QuantMode::parse("uq4").unwrap(), QuantMode::Quantized { levels: 14 });
        assert_eq!(QuantMode::parse("s31").unwrap(), QuantMode::Quantized { levels: 31 });
        assert!(QuantMode::parse("zzz").is_err());
        // name() round-trips
        for m in ["fp32", "uq4", "uq8", "s31"] {
            assert_eq!(QuantMode::parse(m).unwrap().name(), m);
        }
    }

    #[test]
    fn norm_parsing() {
        assert_eq!(parse_norm("l1").unwrap(), 1);
        assert_eq!(parse_norm("l2").unwrap(), 2);
        assert_eq!(parse_norm("linf").unwrap(), u32::MAX);
        assert_eq!(parse_norm("l4").unwrap(), 4);
        assert!(parse_norm("x").is_err());
        assert!(parse_norm("l0").is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.quant.mode = QuantMode::Quantized { levels: 0 };
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.algo.gamma0 = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn parses_topo_table_and_validates() {
        let cfg = ExperimentConfig::from_toml(
            "workers = 9\n[topo]\nkind = \"hierarchical\"\ngroups = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.topo.kind, "hierarchical");
        assert_eq!(cfg.topo.groups, 3);
        let cfg =
            ExperimentConfig::from_toml("workers = 8\n[topo]\nkind = \"gossip\"\ndegree = 4\n")
                .unwrap();
        assert_eq!(cfg.topo.degree, 4);
        // default is the paper's full mesh
        assert_eq!(ExperimentConfig::default().topo.kind, "full-mesh");
        // bad kind / zero degree rejected at parse time; over-degree clamps
        assert!(ExperimentConfig::from_toml("[topo]\nkind = \"moebius\"\n").is_err());
        assert!(ExperimentConfig::from_toml(
            "workers = 4\n[topo]\nkind = \"gossip\"\ndegree = 0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "workers = 4\n[topo]\nkind = \"gossip\"\ndegree = 9\n"
        )
        .is_ok());
    }

    #[test]
    fn legacy_all_to_all_false_maps_to_star() {
        let cfg = ExperimentConfig::from_toml("[net]\nall_to_all = false\n").unwrap();
        assert_eq!(cfg.topo.kind, "star");
        // explicit topo.kind wins over the legacy flag
        let cfg = ExperimentConfig::from_toml(
            "[net]\nall_to_all = false\n[topo]\nkind = \"ring\"\n",
        )
        .unwrap();
        assert_eq!(cfg.topo.kind, "ring");
    }

    #[test]
    fn adapts_predicate_covers_levels_and_codec() {
        let mut q = QuantConfig::default();
        // default: adaptive levels + huffman
        assert!(q.adapts());
        q.scheme = LevelScheme::Uniform;
        assert!(q.adapts(), "fixed levels + Huffman still refresh the codec");
        q.codec = SymbolCodec::Fixed;
        assert!(!q.adapts(), "fully static pipeline");
        q.scheme = LevelScheme::Adaptive;
        assert!(q.adapts());
        // default cap is unlimited (historical behavior)
        assert_eq!(QuantConfig::default().stat_samples, 0);
    }

    #[test]
    fn parses_local_table_and_validates() {
        // default: one local step = seed per-step dual exchange
        assert_eq!(ExperimentConfig::default().local.steps, 1);
        let cfg = ExperimentConfig::from_toml("workers = 4\n[local]\nsteps = 8\n").unwrap();
        assert_eq!(cfg.local.steps, 8);
        // steps = 0 rejected at validation time
        assert!(ExperimentConfig::from_toml("[local]\nsteps = 0\n").is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.local.steps = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn parses_quant_layers_table_with_overrides() {
        let src = r#"
workers = 4
[problem]
dim = 512

[quant]
mode = "uq4"
bucket_size = 128

[quant.layers]
names = ["embed", "body", "head"]
bounds = [128, 384]
budget = 4.0

[quant.layers.embed]
mode = "s6"
codec = "fixed"

[quant.layers.head]
mode = "uq8"
scheme = "uniform"
bucket_size = 64
"#;
        let cfg = ExperimentConfig::from_toml(src).unwrap();
        let l = &cfg.quant.layers;
        assert!(l.enabled());
        assert_eq!(l.names, vec!["embed", "body", "head"]);
        assert_eq!(l.bounds, vec![128, 384]);
        assert_eq!(l.budget, 4.0);
        assert_eq!(l.override_for(0).mode, Some(QuantMode::Quantized { levels: 6 }));
        assert_eq!(l.override_for(0).codec, Some(crate::coding::SymbolCodec::Fixed));
        assert!(l.override_for(1).is_empty());
        assert_eq!(l.override_for(2).mode, Some(QuantMode::Quantized { levels: 254 }));
        assert_eq!(l.override_for(2).scheme, Some(LevelScheme::Uniform));
        assert_eq!(l.override_for(2).bucket_size, Some(64));
        // Resolution applies overrides on top of the base [quant].
        let subs = l.resolve_quant(&cfg.quant);
        assert_eq!(subs[0].mode, QuantMode::Quantized { levels: 6 });
        assert_eq!(subs[1].mode, QuantMode::Quantized { levels: 14 });
        assert_eq!(subs[1].bucket_size, 128);
        assert_eq!(subs[2].bucket_size, 64);
        assert!(subs.iter().all(|s| s.layers.names.is_empty()), "sub-configs are flat");
        // Map resolution at the problem dimension.
        let map = l.resolve_map(512, cfg.quant.bucket_size).unwrap();
        assert_eq!(map.dims(), vec![128, 256, 128]);
        // `count` shorthand.
        let cfg =
            ExperimentConfig::from_toml("[quant]\nbucket_size = 16\n[quant.layers]\ncount = 3\n")
                .unwrap();
        assert_eq!(cfg.quant.layers.names, vec!["l0", "l1", "l2"]);
    }

    #[test]
    fn layers_validation_rejects_bad_tables() {
        // wrong bounds count
        assert!(ExperimentConfig::from_toml(
            "[quant.layers]\nnames = [\"a\", \"b\"]\nbounds = [5, 9]\n"
        )
        .is_err());
        // fp32 base with layers
        assert!(ExperimentConfig::from_toml(
            "[quant]\nmode = \"fp32\"\n[quant.layers]\nnames = [\"a\", \"b\"]\n"
        )
        .is_err());
        // per-layer fp32
        assert!(ExperimentConfig::from_toml(
            "[quant.layers]\nnames = [\"a\", \"b\"]\n[quant.layers.a]\nmode = \"fp32\"\n"
        )
        .is_err());
        // budget outside 2..=32 (and budget without enough layers)
        assert!(ExperimentConfig::from_toml(
            "[quant.layers]\nnames = [\"a\", \"b\"]\nbudget = 1.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[quant.layers]\nnames = [\"a\"]\nbudget = 4.0\n"
        )
        .is_err());
        // budget/bounds without names
        assert!(ExperimentConfig::from_toml("[quant.layers]\nbudget = 4.0\n").is_err());
        // bound at/above the problem dimension is caught at config time
        assert!(ExperimentConfig::from_toml(
            "[problem]\ndim = 64\n[quant.layers]\nnames = [\"a\", \"b\"]\nbounds = [64]\n"
        )
        .is_err());
        // reserved layer name
        assert!(ExperimentConfig::from_toml(
            "[quant.layers]\nnames = [\"bounds\", \"b\"]\nbounds = [8]\n"
        )
        .is_err());
        // contradictory count
        assert!(ExperimentConfig::from_toml(
            "[quant.layers]\nnames = [\"a\", \"b\"]\ncount = 3\n"
        )
        .is_err());
        // a valid two-layer split of the default dim (64) still parses
        let cfg = ExperimentConfig::from_toml(
            "[quant]\nbucket_size = 16\n[quant.layers]\nnames = [\"a\", \"b\"]\nbounds = [32]\n",
        )
        .unwrap();
        assert!(cfg.quant.layers.enabled());
    }

    #[test]
    fn layers_cli_spec_parses() {
        let l = LayersConfig::parse_cli("4").unwrap();
        assert_eq!(l.names, vec!["l0", "l1", "l2", "l3"]);
        assert!(l.bounds.is_empty());
        let l = LayersConfig::parse_cli("embed:4096, body:244736, head").unwrap();
        assert_eq!(l.names, vec!["embed", "body", "head"]);
        assert_eq!(l.bounds, vec![4096, 244736]);
        assert!(LayersConfig::parse_cli("0").is_err());
        assert!(LayersConfig::parse_cli("a:10,b:20").is_err(), "last end must be implicit");
        assert!(LayersConfig::parse_cli("a,b:20,c").is_err(), "interior layers need ends");
        assert!(LayersConfig::parse_cli("a:x,b").is_err());
    }

    #[test]
    fn adapts_accounts_for_layer_overrides_and_budget() {
        // Fully static base…
        let mut q = QuantConfig {
            scheme: LevelScheme::Uniform,
            codec: SymbolCodec::Fixed,
            ..Default::default()
        };
        assert!(!q.adapts());
        // …stays static under a static layer map…
        q.layers.names = vec!["a".into(), "b".into()];
        assert!(!q.adapts());
        // …adapts when any layer override adapts…
        q.layers.overrides =
            vec![LayerOverride::default(), LayerOverride {
                codec: Some(SymbolCodec::Huffman),
                ..Default::default()
            }];
        assert!(q.adapts());
        // …and the bit-budget allocator forces stat exchange on its own.
        q.layers.overrides.clear();
        assert!(!q.adapts());
        q.layers.budget = 4.0;
        assert!(q.adapts());
        // An adapting base stays adapting under layers with no overrides.
        let mut q = QuantConfig::default();
        q.layers.names = vec!["a".into(), "b".into()];
        assert!(q.adapts());
    }

    #[test]
    fn parses_quant_ef_table_with_overrides() {
        let src = r#"
workers = 4
[problem]
dim = 512

[quant]
mode = "uq4"
bucket_size = 128

[quant.layers]
names = ["embed", "body", "head"]
bounds = [128, 384]

[quant.ef]
scheme = "topk"
k = 32

[quant.ef.embed]
k = 8
"#;
        let cfg = ExperimentConfig::from_toml(src).unwrap();
        let ef = &cfg.quant.ef;
        assert!(ef.enabled());
        assert_eq!(ef.scheme, EfScheme::TopK);
        assert_eq!(ef.k, 32);
        assert_eq!(ef.override_for("embed").k, Some(8));
        assert_eq!(ef.override_for("body"), EfOverride::default());
        assert_eq!(
            ef.resolve_op(Some("embed"), 128).unwrap(),
            crate::quant::ContractiveOp::TopK { k: 8 }
        );
        assert_eq!(
            ef.resolve_op(Some("body"), 256).unwrap(),
            crate::quant::ContractiveOp::TopK { k: 32 }
        );
        // Nothing adapts under EF, whatever the base scheme/codec say.
        assert!(!cfg.quant.adapts());
        // Flat rankr with an explicit shape.
        let cfg = ExperimentConfig::from_toml(
            "[problem]\ndim = 64\n[quant.ef]\nscheme = \"rankr\"\nrank = 2\nrows = 4\n",
        )
        .unwrap();
        assert_eq!(
            cfg.quant.ef.resolve_op(None, 64).unwrap(),
            crate::quant::ContractiveOp::RankR { rank: 2, rows: 4, cols: 16 }
        );
        // rows = 0 auto-shapes near-square.
        let ef = EfConfig { scheme: EfScheme::RankR, rank: 2, ..Default::default() };
        assert_eq!(
            ef.resolve_op(None, 64).unwrap(),
            crate::quant::ContractiveOp::RankR { rank: 2, rows: 8, cols: 8 }
        );
    }

    #[test]
    fn ef_validation_rejects_bad_tables() {
        // k missing for topk
        assert!(ExperimentConfig::from_toml("[quant.ef]\nscheme = \"topk\"\n").is_err());
        // k beyond the dimension
        assert!(ExperimentConfig::from_toml(
            "[problem]\ndim = 16\n[quant.ef]\nscheme = \"topk\"\nk = 17\n"
        )
        .is_err());
        // rank missing / rows not dividing d / rows without rankr
        assert!(ExperimentConfig::from_toml("[quant.ef]\nscheme = \"rankr\"\n").is_err());
        assert!(ExperimentConfig::from_toml(
            "[problem]\ndim = 64\n[quant.ef]\nscheme = \"rankr\"\nrank = 2\nrows = 7\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[quant.ef]\nscheme = \"topk\"\nk = 8\nrows = 8\n"
        )
        .is_err());
        // knobs without a scheme (typo safety)
        assert!(ExperimentConfig::from_toml("[quant.ef]\nk = 8\n").is_err());
        // unknown scheme
        assert!(ExperimentConfig::from_toml("[quant.ef]\nscheme = \"svd\"\n").is_err());
        // override for a layer that does not exist
        assert!(ExperimentConfig::from_toml(
            "[quant.ef]\nscheme = \"topk\"\nk = 8\n[quant.ef.embed]\nk = 4\n"
        )
        .is_err());
        // incompatible with the bit-budget allocator
        assert!(ExperimentConfig::from_toml(
            "[problem]\ndim = 512\n[quant]\nbucket_size = 128\n\
             [quant.layers]\nnames = [\"a\", \"b\"]\nbudget = 4.0\n\
             [quant.ef]\nscheme = \"topk\"\nk = 8\n"
        )
        .is_err());
        // per-layer k larger than that layer's dimension
        assert!(ExperimentConfig::from_toml(
            "[problem]\ndim = 512\n[quant]\nbucket_size = 128\n\
             [quant.layers]\nnames = [\"a\", \"b\"]\nbounds = [128]\n\
             [quant.ef]\nscheme = \"topk\"\nk = 8\n[quant.ef.a]\nk = 129\n"
        )
        .is_err());
        // a valid layered EF config still parses
        assert!(ExperimentConfig::from_toml(
            "[problem]\ndim = 512\n[quant]\nbucket_size = 128\n\
             [quant.layers]\nnames = [\"a\", \"b\"]\nbounds = [128]\n\
             [quant.ef]\nscheme = \"topk\"\nk = 8\n"
        )
        .is_ok());
    }

    #[test]
    fn ef_cli_spec_parses() {
        assert_eq!(EfConfig::parse_cli("off").unwrap(), EfConfig::default());
        let ef = EfConfig::parse_cli("topk:64").unwrap();
        assert_eq!((ef.scheme, ef.k), (EfScheme::TopK, 64));
        let ef = EfConfig::parse_cli("randk:128").unwrap();
        assert_eq!((ef.scheme, ef.k), (EfScheme::RandK, 128));
        let ef = EfConfig::parse_cli("rankr:4").unwrap();
        assert_eq!((ef.scheme, ef.rank, ef.rows), (EfScheme::RankR, 4, 0));
        let ef = EfConfig::parse_cli("rankr:4:32").unwrap();
        assert_eq!((ef.scheme, ef.rank, ef.rows), (EfScheme::RankR, 4, 32));
        assert!(EfConfig::parse_cli("topk").is_err(), "missing k");
        assert!(EfConfig::parse_cli("topk:x").is_err());
        assert!(EfConfig::parse_cli("topk:8:9").is_err(), "trailing fields");
        assert!(EfConfig::parse_cli("svd:3").is_err());
    }

    #[test]
    fn variant_parsing_aliases() {
        assert_eq!(Variant::parse("eg").unwrap(), Variant::DualExtrapolation);
        assert_eq!(Variant::parse("da").unwrap(), Variant::DualAveraging);
        assert_eq!(Variant::parse("optimistic").unwrap(), Variant::OptimisticDualAveraging);
    }

    #[test]
    fn method_parsing_aliases_and_default() {
        assert_eq!(Method::parse("qgenx").unwrap(), Method::QGenX);
        assert_eq!(Method::parse("peg").unwrap(), Method::Peg);
        assert_eq!(Method::parse("past").unwrap(), Method::Peg);
        assert_eq!(Method::parse("past-eg").unwrap(), Method::Peg);
        assert_eq!(Method::parse("eg-aa").unwrap(), Method::EgAa);
        assert_eq!(Method::parse("anderson").unwrap(), Method::EgAa);
        assert!(Method::parse("momentum").is_err());
        // absent [algo] method key stays on the paper template
        assert_eq!(ExperimentConfig::default().algo.method, Method::QGenX);
        assert_eq!(ExperimentConfig::from_toml("workers = 4\n").unwrap().algo.method, Method::QGenX);
    }

    #[test]
    fn algo_table_parses_new_methods() {
        let cfg = ExperimentConfig::from_toml("[algo]\nmethod = \"peg\"\ngamma0 = 0.5\n").unwrap();
        assert_eq!(cfg.algo.method, Method::Peg);
        assert!((cfg.algo.gamma0 - 0.5).abs() < 1e-12);
        let cfg = ExperimentConfig::from_toml("[algo]\nmethod = \"eg-aa\"\n").unwrap();
        assert_eq!(cfg.algo.method, Method::EgAa);
        // explicit qgenx keeps the variant knob working
        let cfg =
            ExperimentConfig::from_toml("[algo]\nmethod = \"qgenx\"\nvariant = \"optda\"\n")
                .unwrap();
        assert_eq!(cfg.algo.method, Method::QGenX);
        assert_eq!(cfg.algo.variant, Variant::OptimisticDualAveraging);
    }

    #[test]
    fn algo_table_rejects_junk_keys() {
        // The satellite bugfix: [algo] used to silently ignore unknown
        // keys (warn-only), unlike the strict [quant.ef] table. A typo'd
        // knob must be a hard error, with and without `method`.
        let err = ExperimentConfig::from_toml("[algo]\ngama0 = 0.5\n").unwrap_err();
        assert!(err.to_string().contains("gama0"), "{err}");
        assert!(ExperimentConfig::from_toml("[algo]\nmethod = \"peg\"\nmomentum = 0.9\n").is_err());
        assert!(ExperimentConfig::from_toml("[algo]\nvariant = \"de\"\nrho = 0.5\n").is_err());
    }

    #[test]
    fn algo_table_rejects_variant_on_single_call_methods() {
        // `variant` selects inside the qgenx family; combining it with a
        // non-qgenx method is a contradiction, not a preference.
        for method in ["peg", "eg-aa"] {
            let src = format!("[algo]\nmethod = \"{method}\"\nvariant = \"optda\"\n");
            let err = ExperimentConfig::from_toml(&src).unwrap_err();
            assert!(err.to_string().contains("qgenx-family"), "{err}");
        }
        // gamma0/adaptive_step are shared (the adaptive rule is the seam's
        // common stepsize) and stay legal on every method.
        let cfg = ExperimentConfig::from_toml(
            "[algo]\nmethod = \"peg\"\ngamma0 = 0.25\nadaptive_step = false\n",
        )
        .unwrap();
        assert!(!cfg.algo.adaptive_step);
    }
}
