//! The wire format: `CODE ∘ Q` (§3.2, Appendix K) and its inverse
//! `DEQ ∘ CODE`.
//!
//! Per bucket:  `[‖v‖_q : f32 (C_b = 32)]` then, for each coordinate, the
//! level-index symbol under Ψ followed by one sign bit *iff* the symbol is
//! nonzero (a zero reconstructs to 0 and needs no sign — Lemma 3's
//! `(1 − p_0) d` sign-bit count).
//!
//! Ψ options ([`WireCodec`]): fixed-width (torch_cgx UQ4/UQ8), Elias γ/δ on
//! `symbol + 1` (universal; QSGD-style), or canonical Huffman built from
//! the Proposition 2 probabilities (minimum expected length; the code
//! lengths travel with the level update on schedule `U`, not per message).
//!
//! The decoder needs `(d, bucket_size, levels, codec)` as side information
//! — all of which the coordinator distributes at setup / level updates, so
//! the steady-state wire carries only what Theorem 2 counts.

use super::levels::Levels;
use super::quantizer::QuantizedVector;
use crate::coding::{
    elias, BitReader, BitWriter, HuffmanCode, SymbolCodec,
};
use crate::error::{Error, Result};

/// A symbol codec bound to its side information (the Huffman table when Ψ
/// is Huffman). Construct once per level-update, reuse per message.
#[derive(Clone, Debug)]
pub struct WireCodec {
    pub kind: SymbolCodec,
    /// Fixed width in bits for `SymbolCodec::Fixed`.
    fixed_width: u32,
    /// Huffman table for `SymbolCodec::Huffman`.
    huffman: Option<HuffmanCode>,
}

impl WireCodec {
    /// Build a codec for an alphabet of `s + 2` symbols.
    pub fn new(kind: SymbolCodec, levels: &Levels, probs: Option<&[f64]>) -> Result<Self> {
        let n = levels.alphabet_size();
        let fixed_width = (usize::BITS - (n - 1).leading_zeros()).max(1);
        let huffman = match kind {
            SymbolCodec::Huffman => {
                let probs = probs.ok_or_else(|| {
                    Error::Codec("huffman codec needs symbol probabilities".into())
                })?;
                if probs.len() != n {
                    return Err(Error::Codec(format!(
                        "probs length {} != alphabet {n}",
                        probs.len()
                    )));
                }
                // Floor probabilities so every symbol stays encodable even if
                // the estimate assigned it zero mass.
                let floored: Vec<f64> = probs.iter().map(|&p| p.max(1e-9)).collect();
                Some(HuffmanCode::from_weights(&floored)?)
            }
            _ => None,
        };
        Ok(WireCodec { kind, fixed_width, huffman })
    }

    /// Expected bits for one symbol stream under `probs` (diagnostics).
    pub fn expected_symbol_bits(&self, probs: &[f64]) -> f64 {
        match self.kind {
            SymbolCodec::Fixed => self.fixed_width as f64,
            SymbolCodec::EliasGamma => probs
                .iter()
                .enumerate()
                .map(|(j, p)| p * elias::gamma_len(j as u64 + 1) as f64)
                .sum(),
            SymbolCodec::EliasDelta => probs
                .iter()
                .enumerate()
                .map(|(j, p)| p * elias::delta_len(j as u64 + 1) as f64)
                .sum(),
            SymbolCodec::Huffman => self.huffman.as_ref().unwrap().expected_len(probs),
        }
    }

    #[inline]
    fn encode_symbol(&self, w: &mut BitWriter, sym: u16) -> Result<()> {
        match self.kind {
            SymbolCodec::Fixed => {
                w.write_bits(sym as u64, self.fixed_width);
                Ok(())
            }
            SymbolCodec::EliasGamma => {
                elias::gamma_encode(w, sym as u64 + 1);
                Ok(())
            }
            SymbolCodec::EliasDelta => {
                elias::delta_encode(w, sym as u64 + 1);
                Ok(())
            }
            SymbolCodec::Huffman => self.huffman.as_ref().unwrap().encode(w, sym as usize),
        }
    }

    /// Emit one symbol and, when it is nonzero, its sign bit — fused into a
    /// single `write_bits` call for Fixed and Huffman (the sign bit follows
    /// the codeword on the wire, which under the LSB-first writer is the
    /// next-higher bit of the same emission). Bit-identical to
    /// `encode_symbol` + `write_bit` (pinned by `tests/encode_parity.rs`).
    #[inline]
    fn encode_symbol_and_sign(&self, w: &mut BitWriter, sym: u16, neg: bool) -> Result<()> {
        match self.kind {
            SymbolCodec::Fixed => {
                let width = self.fixed_width;
                if sym == 0 {
                    w.write_bits(0, width);
                } else {
                    w.write_bits(sym as u64 | (neg as u64) << width, width + 1);
                }
                Ok(())
            }
            SymbolCodec::Huffman => {
                let (rev, l) = self.huffman.as_ref().unwrap().emission_of(sym as usize)?;
                if sym == 0 {
                    w.write_bits(rev, l);
                } else {
                    w.write_bits(rev | (neg as u64) << l, l + 1);
                }
                Ok(())
            }
            SymbolCodec::EliasGamma | SymbolCodec::EliasDelta => {
                self.encode_symbol(w, sym)?;
                if sym != 0 {
                    w.write_bit(neg);
                }
                Ok(())
            }
        }
    }

    #[inline]
    fn decode_symbol(&self, r: &mut BitReader) -> Result<u16> {
        match self.kind {
            SymbolCodec::Fixed => Ok(r.read_bits(self.fixed_width)? as u16),
            SymbolCodec::EliasGamma => Ok((elias::gamma_decode(r)? - 1) as u16),
            SymbolCodec::EliasDelta => Ok((elias::delta_decode(r)? - 1) as u16),
            SymbolCodec::Huffman => Ok(self.huffman.as_ref().unwrap().decode(r)? as u16),
        }
    }
}

/// `CODE ∘ Q`: serialize a quantized vector. Returns the wire bytes; the
/// exact bit count (pre-padding) is `bytes.1`.
pub fn encode_vector(qv: &QuantizedVector, codec: &WireCodec) -> Result<(Vec<u8>, u64)> {
    // Capacity guess: norms + ~6 bits/coordinate.
    let mut buf = Vec::with_capacity(4 * qv.norms.len() + qv.d);
    let bits = encode_vector_into(qv, codec, &mut buf)?;
    Ok((buf, bits))
}

/// [`encode_vector`] *appending* to a caller-owned buffer: identical wire
/// bytes, zero allocations once the buffer has grown to the steady-state
/// message size. Existing content is kept (the layer-wise pipeline writes
/// its length frame first); callers encoding a whole message clear first.
/// Returns this vector's exact bit count (pre-padding). On error the
/// buffer's contents are unspecified but its allocation is retained.
pub fn encode_vector_into(
    qv: &QuantizedVector,
    codec: &WireCodec,
    buf: &mut Vec<u8>,
) -> Result<u64> {
    buf.reserve(4 * qv.norms.len() + qv.d / 2);
    let mut w = BitWriter::over(std::mem::take(buf));
    // The buffer must be handed back to the caller even when a symbol
    // fails to encode — otherwise an error would silently replace the
    // caller's steady-state allocation with a fresh empty Vec.
    let result = encode_body(qv, codec, &mut w);
    let bits = w.bit_len();
    *buf = w.finish();
    result.map(|()| bits)
}

fn encode_body(qv: &QuantizedVector, codec: &WireCodec, w: &mut BitWriter) -> Result<()> {
    let b = qv.bucket_size;
    for (bi, &norm) in qv.norms.iter().enumerate() {
        w.write_f32(norm);
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(qv.d);
        if norm == 0.0 {
            continue; // empty bucket: decoder reconstructs zeros, no symbols
        }
        for i in lo..hi {
            codec.encode_symbol_and_sign(w, qv.symbols[i], qv.sign_is_neg(i))?;
        }
    }
    Ok(())
}

/// `DEQ ∘ CODE`: parse wire bytes back into a [`QuantizedVector`].
pub fn decode_vector(
    bytes: &[u8],
    d: usize,
    bucket_size: usize,
    codec: &WireCodec,
) -> Result<QuantizedVector> {
    let mut out = QuantizedVector::default();
    decode_vector_into(bytes, d, bucket_size, codec, &mut out)?;
    Ok(out)
}

/// [`decode_vector`] into a reusable arena (zero allocations in steady
/// state), with a strict tail check: after the last symbol, only byte
/// padding may remain — at most 7 bits, all zero. The check is what lets
/// the layer-wise frame reader detect a frame-length/`d` mismatch instead
/// of "successfully" decoding a wrong vector from a misaligned stream.
pub fn decode_vector_into(
    bytes: &[u8],
    d: usize,
    bucket_size: usize,
    codec: &WireCodec,
    out: &mut QuantizedVector,
) -> Result<()> {
    let b = if bucket_size == 0 { d } else { bucket_size };
    let nb = d.div_ceil(b);
    let mut r = BitReader::new(bytes);
    out.reset(d, b);
    for bi in 0..nb {
        let norm = r.read_f32()?;
        if !norm.is_finite() || norm < 0.0 {
            return Err(Error::Codec(format!("bad bucket norm {norm}")));
        }
        out.norms.push(norm);
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(d);
        if norm == 0.0 {
            continue;
        }
        for i in lo..hi {
            let sym = codec.decode_symbol(&mut r)?;
            out.symbols[i] = sym;
            if sym != 0 && r.read_bit()? {
                out.sign_words[i / 64] |= 1u64 << (i % 64);
            }
        }
    }
    // Strict tail: anything beyond zero byte-padding means the caller's
    // side information (d, bucket size, frame length) disagrees with the
    // stream — reject rather than return a silently wrong vector.
    let consumed = r.bits_read();
    let total = bytes.len() as u64 * 8;
    if total - consumed >= 8 {
        return Err(Error::Codec(format!(
            "wire has {} trailing bytes after the last symbol",
            (total - consumed) / 8
        )));
    }
    let pad = (total - consumed) as u32;
    if pad > 0 && r.read_bits(pad)? != 0 {
        return Err(Error::Codec("nonzero padding bits after the last symbol".into()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::adaptive::{symbol_probs, SufficientStats};
    use crate::quant::quantizer::{dequantize, quantize};
    use crate::testkit::forall;
    use crate::util::Rng;

    fn all_codecs(levels: &Levels, probs: &[f64]) -> Vec<WireCodec> {
        vec![
            WireCodec::new(SymbolCodec::Fixed, levels, None).unwrap(),
            WireCodec::new(SymbolCodec::EliasGamma, levels, None).unwrap(),
            WireCodec::new(SymbolCodec::EliasDelta, levels, None).unwrap(),
            WireCodec::new(SymbolCodec::Huffman, levels, Some(probs)).unwrap(),
        ]
    }

    fn gaussian_probs(levels: &Levels, d: usize) -> Vec<f64> {
        let mut stats = SufficientStats::new(256, 2);
        let mut rng = Rng::seed_from(31);
        for _ in 0..8 {
            let g = rng.gaussian_vec(d, 1.0);
            stats.observe(&g);
        }
        symbol_probs(&stats, levels)
    }

    #[test]
    fn roundtrip_exact_all_codecs() {
        let levels = Levels::uniform(14);
        let probs = gaussian_probs(&levels, 512);
        let mut rng = Rng::seed_from(1);
        let v = rng.gaussian_vec(512, 1.0);
        let qv = quantize(&v, &levels, 2, 128, &mut rng).unwrap();
        for codec in all_codecs(&levels, &probs) {
            let (bytes, bits) = encode_vector(&qv, &codec).unwrap();
            assert!(bits as usize <= bytes.len() * 8);
            let back = decode_vector(&bytes, 512, 128, &codec).unwrap();
            assert_eq!(qv, back, "codec {:?}", codec.kind);
            // Dequantized values identical too.
            assert_eq!(dequantize(&qv, &levels), dequantize(&back, &levels));
        }
    }

    #[test]
    fn huffman_beats_fixed_on_skewed_gradients() {
        // Gaussian coordinates at large d are overwhelmingly near zero ->
        // low symbols dominate -> Huffman/Elias crush fixed-width.
        let levels = Levels::uniform(14);
        let d = 4096;
        let probs = gaussian_probs(&levels, d);
        let mut rng = Rng::seed_from(2);
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
        let fixed = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let huff = WireCodec::new(SymbolCodec::Huffman, &levels, Some(&probs)).unwrap();
        let (_, bits_fixed) = encode_vector(&qv, &fixed).unwrap();
        let (_, bits_huff) = encode_vector(&qv, &huff).unwrap();
        assert!(
            (bits_huff as f64) < 0.75 * bits_fixed as f64,
            "huffman {bits_huff} vs fixed {bits_fixed}"
        );
    }

    #[test]
    fn wire_is_far_smaller_than_fp32() {
        let levels = Levels::uniform(14); // UQ4
        let d = 1 << 14;
        let mut rng = Rng::seed_from(3);
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, &levels, 2, 1024, &mut rng).unwrap();
        let fixed = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let (bytes, _) = encode_vector(&qv, &fixed).unwrap();
        let fp32_bytes = 4 * d;
        assert!(
            bytes.len() * 2 < fp32_bytes,
            "wire {} should be well under fp32 {}",
            bytes.len(),
            fp32_bytes
        );
    }

    #[test]
    fn empty_bucket_encodes_compactly() {
        let levels = Levels::uniform(3);
        let v = vec![0.0f32; 256];
        let mut rng = Rng::seed_from(4);
        let qv = quantize(&v, &levels, 2, 64, &mut rng).unwrap();
        let codec = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let (bytes, bits) = encode_vector(&qv, &codec).unwrap();
        // 4 buckets * 32-bit norms only.
        assert_eq!(bits, 4 * 32);
        let back = decode_vector(&bytes, 256, 64, &codec).unwrap();
        assert!(dequantize(&back, &levels).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn truncated_wire_is_error() {
        let levels = Levels::uniform(7);
        let mut rng = Rng::seed_from(5);
        let v = rng.gaussian_vec(64, 1.0);
        let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
        let codec = WireCodec::new(SymbolCodec::EliasGamma, &levels, None).unwrap();
        let (bytes, _) = encode_vector(&qv, &codec).unwrap();
        let cut = &bytes[..bytes.len() / 2];
        assert!(decode_vector(cut, 64, 0, &codec).is_err());
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        // Regression: decode_vector used to accept any bytes after the last
        // symbol, so a layer-wise frame-length/`d` mismatch "successfully"
        // decoded to a wrong vector. Strict tail: ≤ 7 padding bits, all 0.
        let levels = Levels::uniform(14);
        let mut rng = Rng::seed_from(8);
        let v = rng.gaussian_vec(128, 1.0);
        let qv = quantize(&v, &levels, 2, 32, &mut rng).unwrap();
        let probs = gaussian_probs(&levels, 128);
        for codec in all_codecs(&levels, &probs) {
            let (bytes, bits) = encode_vector(&qv, &codec).unwrap();
            // The honest wire still decodes.
            assert_eq!(decode_vector(&bytes, 128, 32, &codec).unwrap(), qv);
            // One appended garbage byte must be rejected...
            let mut padded = bytes.clone();
            padded.push(0xFF);
            assert!(
                decode_vector(&padded, 128, 32, &codec).is_err(),
                "trailing byte accepted ({:?})",
                codec.kind
            );
            // ...as must an appended zero byte (frame-length mismatch)...
            let mut zero_padded = bytes.clone();
            zero_padded.push(0x00);
            assert!(
                decode_vector(&zero_padded, 128, 32, &codec).is_err(),
                "trailing zero byte accepted ({:?})",
                codec.kind
            );
            // ...and nonzero bits inside the final padding.
            let pad = (8 - (bits % 8) as u32) % 8;
            if pad > 0 {
                let mut corrupt = bytes.clone();
                let last = corrupt.len() - 1;
                corrupt[last] |= 0x80; // flip the top padding bit
                assert!(
                    decode_vector(&corrupt, 128, 32, &codec).is_err(),
                    "nonzero padding accepted ({:?})",
                    codec.kind
                );
            }
        }
    }

    #[test]
    fn decoding_with_wrong_dim_errors_instead_of_misreading() {
        // A d mismatch (the frame-reader scenario) leaves the stream
        // misaligned: either a decode error or the strict tail check fires.
        // All-(-1) under L∞ quantizes every coordinate to the top symbol
        // (1111₂ + sign under UQ4/fixed) deterministically: 1312 wire bits,
        // zero padding — both mismatch directions are guaranteed to trip.
        let levels = Levels::uniform(14);
        let v = vec![-1.0f32; 256];
        let mut rng = Rng::seed_from(9);
        let qv = quantize(&v, &levels, u32::MAX, 0, &mut rng).unwrap();
        assert!(qv.symbols.iter().all(|&s| s == 15), "setup: saturated symbols");
        let codec = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let (bytes, bits) = encode_vector(&qv, &codec).unwrap();
        assert_eq!(bits, 32 + 256 * 5);
        assert!(decode_vector(&bytes, 255, 0, &codec).is_err(), "short d must not pass");
        assert!(decode_vector(&bytes, 257, 0, &codec).is_err(), "long d must not pass");
    }

    #[test]
    fn encode_into_appends_and_reuses_without_reallocating() {
        let levels = Levels::uniform(14);
        let probs = gaussian_probs(&levels, 512);
        let mut rng = Rng::seed_from(10);
        let v = rng.gaussian_vec(512, 1.0);
        let qv = quantize(&v, &levels, 2, 128, &mut rng).unwrap();
        for codec in all_codecs(&levels, &probs) {
            let (fresh, bits) = encode_vector(&qv, &codec).unwrap();
            // Append semantics: pre-existing prefix is preserved verbatim.
            let mut buf = vec![0xAB, 0xCD];
            let bits2 = encode_vector_into(&qv, &codec, &mut buf).unwrap();
            assert_eq!(bits, bits2);
            assert_eq!(&buf[..2], &[0xAB, 0xCD]);
            assert_eq!(&buf[2..], &fresh[..], "codec {:?}", codec.kind);
            // Steady state: clearing and re-encoding reuses the allocation.
            let cap = buf.capacity();
            let ptr = buf.as_ptr();
            buf.clear();
            let bits3 = encode_vector_into(&qv, &codec, &mut buf).unwrap();
            assert_eq!(bits3, bits);
            assert_eq!(buf, fresh);
            assert_eq!(buf.capacity(), cap);
            assert_eq!(buf.as_ptr(), ptr);
            // Arena decode matches the allocating decode.
            let mut arena = QuantizedVector::default();
            decode_vector_into(&buf, 512, 128, &codec, &mut arena).unwrap();
            assert_eq!(arena, qv);
        }
    }

    #[test]
    fn huffman_requires_probs() {
        let levels = Levels::uniform(3);
        assert!(WireCodec::new(SymbolCodec::Huffman, &levels, None).is_err());
        assert!(WireCodec::new(SymbolCodec::Huffman, &levels, Some(&[0.5, 0.5])).is_err());
    }

    #[test]
    fn expected_symbol_bits_tracks_measured() {
        let levels = Levels::uniform(14);
        let d = 8192;
        let probs = gaussian_probs(&levels, d);
        let mut rng = Rng::seed_from(6);
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
        for codec in all_codecs(&levels, &probs) {
            let (_, bits) = encode_vector(&qv, &codec).unwrap();
            let nonzeros = d - qv.num_zeros();
            let predicted = 32.0 + codec.expected_symbol_bits(&probs) * d as f64 + nonzeros as f64;
            let measured = bits as f64;
            assert!(
                (measured - predicted).abs() / predicted < 0.15,
                "codec {:?}: measured {measured} predicted {predicted}",
                codec.kind
            );
        }
    }

    #[test]
    fn prop_roundtrip_random_everything() {
        forall("wire roundtrip", 60, |g| {
            let s = g.usize_in(1, 40);
            let levels = Levels::new(g.levels(s)).unwrap();
            let d = g.usize_in(1, 400);
            let bucket = *g.choose(&[0usize, 3, 50, 333]);
            let v = g.f32_vec(d, -3.0, 3.0);
            let uniforms: Vec<f32> = (0..d).map(|_| g.f32_in(0.0, 1.0)).collect();
            let qv = crate::quant::quantize_with_uniforms(&v, &levels, 2, bucket, &uniforms)
                .unwrap();
            let kinds = [SymbolCodec::Fixed, SymbolCodec::EliasGamma, SymbolCodec::EliasDelta];
            let kind = *g.choose(&kinds);
            let codec = WireCodec::new(kind, &levels, None).unwrap();
            let (bytes, _) = encode_vector(&qv, &codec).unwrap();
            let back = decode_vector(&bytes, d, bucket, &codec).unwrap();
            assert_eq!(qv, back);
        });
    }
}
