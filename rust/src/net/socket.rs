//! Multi-process socket transport: real length-framed wire messages
//! between worker processes over TCP or Unix-domain sockets.
//!
//! Roles and handshake (see docs/WIRE.md §"Transport framing"):
//!
//! 1. Rank 0 binds the rendezvous address ([`SocketHub::bind`]) and waits
//!    for `K-1` workers ([`SocketHub::accept`]).
//! 2. Each rank `i ≥ 1` dials the rendezvous, binds its own peer listener,
//!    and sends `HELLO {k, listener-addr}` ([`SocketTransport::connect`]).
//! 3. Once everyone has arrived, rank 0 broadcasts `WELCOME` with the full
//!    peer directory; the rendezvous connection itself becomes the
//!    `(0, i)` mesh link.
//! 4. Rank `i` then dials every lower rank `1 ≤ j < i` (sending a `PEER`
//!    frame to identify itself) and accepts one connection from every
//!    higher rank — a full mesh of `K·(K-1)/2` duplex connections.
//!
//! Exchanges are synchronous all-to-all rounds like the in-process
//! [`crate::net::AllGather`]: every endpoint writes its payload to all
//! peers (on a scoped writer thread, so no write-write deadlock) and
//! reads one frame from each peer in rank order, validating
//! kind/rank/round lockstep. A dead peer (EOF, `GOODBYE`/`ABORT`
//! mid-round, read timeout) poisons the group: the local endpoint
//! broadcasts `ABORT` with the reason and every subsequent exchange fails
//! fast with [`Error::Net`] — the same semantics the threaded fabric gets
//! from `PoisonGuard`, mapped onto real connections.
//!
//! The transport also *measures* what it moves: per-link data-plane
//! payload bytes, aggregate control/out-of-band bytes, and frame-header
//! overhead ([`Transport::measured`]) — the physical side of the ledger
//! that tests and telemetry reconcile against the modeled
//! [`crate::topo::LinkTraffic`].

use crate::error::{Error, Result};
use crate::net::frame::{read_frame, write_frame, FrameKind, FRAME_HEADER_LEN};
use crate::net::transport::{MeasuredWire, Plane, Transport};
use std::fmt;
use std::io::{Read, Write};
use std::net::{IpAddr, Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

/// Socket transport tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct SocketOpts {
    /// Per-read/write socket timeout and handshake budget. A peer that
    /// stays silent longer than this poisons the group instead of hanging
    /// it. `None` disables socket timeouts (reads block forever — only
    /// sensible in tests that control both ends).
    pub timeout: Option<Duration>,
}

impl Default for SocketOpts {
    fn default() -> Self {
        SocketOpts { timeout: Some(Duration::from_secs(30)) }
    }
}

impl SocketOpts {
    /// Derive options from `[net]` config: `timeout_ms > 0` is used
    /// verbatim; the block-forever default (`0`) falls back to this
    /// type's 30 s default — a socket fabric should never hang on a dead
    /// peer unless explicitly asked to.
    pub fn from_config(net: &crate::config::NetConfig) -> SocketOpts {
        SocketOpts { timeout: net.exchange_timeout().or(SocketOpts::default().timeout) }
    }

    fn handshake_deadline(&self) -> Instant {
        Instant::now() + self.timeout.unwrap_or(Duration::from_secs(30))
    }
}

/// A parsed transport address: `HOST:PORT` (TCP) or `unix:PATH`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Addr {
    Tcp(String),
    #[cfg(unix)]
    Unix(PathBuf),
}

impl Addr {
    pub fn parse(s: &str) -> Result<Addr> {
        if let Some(path) = s.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                if path.is_empty() {
                    return Err(Error::Net("empty unix socket path".into()));
                }
                return Ok(Addr::Unix(PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(Error::Net(
                    "unix-domain sockets are not available on this platform".into(),
                ));
            }
        }
        let tcp_like =
            s.rsplit_once(':').map(|(host, port)| !host.is_empty() && port.parse::<u16>().is_ok());
        if tcp_like == Some(true) {
            Ok(Addr::Tcp(s.to_string()))
        } else {
            Err(Error::Net(format!(
                "bad transport address {s:?}: expected HOST:PORT or unix:PATH"
            )))
        }
    }
}

impl fmt::Display for Addr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Addr::Tcp(a) => write!(f, "{a}"),
            #[cfg(unix)]
            Addr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// One duplex connection, TCP or Unix-domain.
enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> Result<Stream> {
        let cloned = match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        };
        cloned.map_err(|e| Error::Net(format!("splitting connection into read/write halves: {e}")))
    }

    fn set_timeouts(&self, t: Option<Duration>) -> Result<()> {
        let r = match self {
            Stream::Tcp(s) => s.set_read_timeout(t).and_then(|_| s.set_write_timeout(t)),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(t).and_then(|_| s.set_write_timeout(t)),
        };
        r.map_err(|e| Error::Net(format!("setting socket timeouts: {e}")))
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    /// The local IP this connection uses (TCP only) — the address peer
    /// listeners should bind so other ranks can reach them the same way.
    fn local_ip(&self) -> Option<IpAddr> {
        match self {
            Stream::Tcp(s) => s.local_addr().ok().map(|a| a.ip()),
            #[cfg(unix)]
            Stream::Unix(_) => None,
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A listener on either family; Unix listeners unlink their path on drop.
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    fn bind(addr: &Addr) -> Result<Listener> {
        match addr {
            Addr::Tcp(a) => TcpListener::bind(a)
                .map(Listener::Tcp)
                .map_err(|e| Error::Net(format!("binding tcp listener on {a}: {e}"))),
            #[cfg(unix)]
            Addr::Unix(p) => {
                // A stale socket file from a crashed previous run would
                // make bind fail; it is dead by construction, remove it.
                let _ = std::fs::remove_file(p);
                UnixListener::bind(p).map(|l| Listener::Unix(l, p.clone())).map_err(|e| {
                    Error::Net(format!("binding unix listener at {}: {e}", p.display()))
                })
            }
        }
    }

    /// Bind the peer listener rank `rank` advertises in its HELLO: an
    /// ephemeral TCP port on the same interface the rendezvous dial used,
    /// or `<rendezvous-path>.r<rank>` for Unix sockets.
    fn bind_peer(rendezvous: &Addr, conn: &Stream, rank: usize) -> Result<Listener> {
        match rendezvous {
            Addr::Tcp(_) => {
                let ip = conn.local_ip().unwrap_or(IpAddr::from([127, 0, 0, 1]));
                TcpListener::bind((ip, 0))
                    .map(Listener::Tcp)
                    .map_err(|e| Error::Net(format!("binding peer listener on {ip}: {e}")))
            }
            #[cfg(unix)]
            Addr::Unix(p) => {
                let mut path = p.as_os_str().to_os_string();
                path.push(format!(".r{rank}"));
                Listener::bind(&Addr::Unix(PathBuf::from(path)))
            }
        }
    }

    /// The address peers should dial, in [`Addr::parse`] syntax.
    fn advertised(&self) -> Result<String> {
        match self {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .map_err(|e| Error::Net(format!("reading bound tcp address: {e}"))),
            #[cfg(unix)]
            Listener::Unix(_, p) => Ok(format!("unix:{}", p.display())),
        }
    }

    fn accept_deadline(&self, deadline: Instant, what: &str) -> Result<Stream> {
        let set_nb = |nb: bool| match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(nb),
        };
        set_nb(true).map_err(|e| Error::Net(format!("listener nonblocking mode: {e}")))?;
        loop {
            let attempt = match self {
                Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
                #[cfg(unix)]
                Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
            };
            match attempt {
                Ok(s) => {
                    let _ = set_nb(false);
                    s.set_nonblocking(false)
                        .map_err(|e| Error::Net(format!("accepted stream blocking mode: {e}")))?;
                    return Ok(s);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(Error::Net(format!("timed out {what}")));
                    }
                    thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(Error::Net(format!("accepting {what}: {e}"))),
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Dial `addr`, retrying until `deadline` — the target process may not
/// have bound its listener yet (process startup is racy by nature).
/// Each TCP attempt is individually bounded by the time left: a
/// blackholed address (SYN drop, no RST) must not pin one attempt on
/// the OS default connect timeout long past our deadline. Unix sockets
/// connect locally and need no per-attempt bound.
fn dial(addr: &Addr, deadline: Instant) -> Result<Stream> {
    loop {
        let attempt = match addr {
            Addr::Tcp(a) => dial_tcp(a, deadline).map(Stream::Tcp),
            #[cfg(unix)]
            Addr::Unix(p) => UnixStream::connect(p).map(Stream::Unix),
        };
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(Error::Net(format!(
                        "dialing {addr}: {e} (gave up at the handshake deadline)"
                    )));
                }
                thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// One deadline-bounded TCP connect attempt: resolve, then
/// `connect_timeout` each candidate address with the time remaining.
fn dial_tcp(addr: &str, deadline: Instant) -> std::io::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let mut last = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, remaining(deadline)) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "address resolved to nothing")
    }))
}

/// Time left until `deadline`, clamped to ≥ 1 ms (a zero socket timeout
/// means "no timeout" to the OS, the opposite of what we want).
fn remaining(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(1))
}

// ---------------------------------------------------------------------------
// Handshake payloads (HELLO / WELCOME / PEER bodies)
// ---------------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    let b = s.as_bytes();
    if b.len() > u16::MAX as usize {
        return Err(Error::Net(format!("address too long for the wire: {s:?}")));
    }
    out.extend_from_slice(&(b.len() as u16).to_le_bytes());
    out.extend_from_slice(b);
    Ok(())
}

/// Bounds-checked little-endian reader for handshake payloads.
struct HsReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> HsReader<'a> {
    fn new(b: &'a [u8]) -> Self {
        HsReader { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.pos < n {
            return Err(Error::Net("truncated handshake payload".into()));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::Net("non-UTF-8 address in handshake".into()))
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(Error::Net("trailing bytes in handshake payload".into()));
        }
        Ok(())
    }
}

/// HELLO body: `[k u32][addr_len u16][addr]` (sender rank is in the header).
fn hello_payload(k: usize, addr: &str) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(6 + addr.len());
    out.extend_from_slice(&(k as u32).to_le_bytes());
    put_str(&mut out, addr)?;
    Ok(out)
}

fn parse_hello(b: &[u8]) -> Result<(usize, String)> {
    let mut r = HsReader::new(b);
    let k = r.u32()? as usize;
    let addr = r.string()?;
    r.finish()?;
    Ok((k, addr))
}

/// WELCOME body: `[k u32][n u32]` then `n × ([rank u32][addr_len u16][addr])`.
fn welcome_payload(k: usize, peers: &[(usize, String)]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&(k as u32).to_le_bytes());
    out.extend_from_slice(&(peers.len() as u32).to_le_bytes());
    for (rank, addr) in peers {
        out.extend_from_slice(&(*rank as u32).to_le_bytes());
        put_str(&mut out, addr)?;
    }
    Ok(out)
}

fn parse_welcome(b: &[u8]) -> Result<(usize, Vec<(usize, String)>)> {
    let mut r = HsReader::new(b);
    let k = r.u32()? as usize;
    let n = r.u32()? as usize;
    if n > k {
        return Err(Error::Net(format!("WELCOME directory of {n} entries for a group of {k}")));
    }
    let mut peers = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = r.u32()? as usize;
        let addr = r.string()?;
        peers.push((rank, addr));
    }
    r.finish()?;
    Ok((k, peers))
}

// ---------------------------------------------------------------------------
// Measured-byte bookkeeping
// ---------------------------------------------------------------------------

/// Raw byte/frame counters, updated under the connection lock. Link
/// vectors are indexed by peer rank.
struct Tally {
    data_rounds: u64,
    frames_sent: u64,
    frames_recv: u64,
    header_bytes: u64,
    data_sent: Vec<u64>,
    data_recv: Vec<u64>,
    control_sent: u64,
    control_recv: u64,
    oob_sent: u64,
    oob_recv: u64,
}

impl Tally {
    fn new(k: usize) -> Tally {
        Tally {
            data_rounds: 0,
            frames_sent: 0,
            frames_recv: 0,
            header_bytes: 0,
            data_sent: vec![0; k],
            data_recv: vec![0; k],
            control_sent: 0,
            control_recv: 0,
            oob_sent: 0,
            oob_recv: 0,
        }
    }

    /// Handshake frames bill as out-of-band traffic.
    fn on_send_handshake(&mut self, payload: usize) {
        self.frames_sent += 1;
        self.header_bytes += FRAME_HEADER_LEN as u64;
        self.oob_sent += payload as u64;
    }

    fn on_recv_handshake(&mut self, payload: usize) {
        self.frames_recv += 1;
        self.header_bytes += FRAME_HEADER_LEN as u64;
        self.oob_recv += payload as u64;
    }

    fn to_measured(&self, rank: usize) -> MeasuredWire {
        let links = |v: &[u64], incoming: bool| {
            v.iter()
                .enumerate()
                .filter(|&(p, &b)| p != rank && b > 0)
                .map(|(p, &b)| (if incoming { (p, rank) } else { (rank, p) }, b))
                .collect()
        };
        MeasuredWire {
            rank,
            data_rounds: self.data_rounds,
            frames_sent: self.frames_sent,
            frames_recv: self.frames_recv,
            header_bytes: self.header_bytes,
            data_sent: links(&self.data_sent, false),
            data_recv: links(&self.data_recv, true),
            control_sent: self.control_sent,
            control_recv: self.control_recv,
            oob_sent: self.oob_sent,
            oob_recv: self.oob_recv,
        }
    }
}

// ---------------------------------------------------------------------------
// Rendezvous hub (rank 0)
// ---------------------------------------------------------------------------

/// Rank 0's side of the rendezvous: bind the group address, then
/// [`SocketHub::accept`] blocks until all `K-1` workers have said HELLO
/// and returns rank 0's assembled [`SocketTransport`].
pub struct SocketHub {
    listener: Listener,
    k: usize,
    opts: SocketOpts,
}

impl SocketHub {
    pub fn bind(addr: &str, k: usize, opts: SocketOpts) -> Result<SocketHub> {
        if k < 1 {
            return Err(Error::Net("group size must be at least 1".into()));
        }
        let addr = Addr::parse(addr)?;
        Ok(SocketHub { listener: Listener::bind(&addr)?, k, opts })
    }

    /// The actual bound address (ephemeral TCP ports resolved) — pass this
    /// to the workers' `--connect`.
    pub fn addr(&self) -> Result<String> {
        self.listener.advertised()
    }

    /// Run the rendezvous to completion: collect HELLOs from ranks
    /// `1..k`, broadcast the WELCOME directory, and become rank 0's
    /// transport endpoint.
    pub fn accept(self) -> Result<Arc<SocketTransport>> {
        let k = self.k;
        let deadline = self.opts.handshake_deadline();
        let mut conns: Vec<Option<Stream>> = (0..k).map(|_| None).collect();
        let mut dir: Vec<Option<String>> = vec![None; k];
        let mut tally = Tally::new(k);
        for _ in 1..k {
            let mut s =
                self.listener.accept_deadline(deadline, "waiting for workers at the rendezvous")?;
            s.set_timeouts(Some(remaining(deadline)))?;
            let (hdr, body) = read_frame(&mut s)?;
            if hdr.kind != FrameKind::Hello {
                return Err(Error::Net(format!(
                    "expected HELLO at the rendezvous, got {:?}",
                    hdr.kind
                )));
            }
            let r = hdr.rank as usize;
            if r == 0 || r >= k {
                return Err(Error::Net(format!(
                    "HELLO from out-of-range rank {r} (group of {k})"
                )));
            }
            if conns[r].is_some() {
                return Err(Error::Net(format!("two workers claimed rank {r}")));
            }
            let (their_k, peer_addr) = parse_hello(&body)?;
            if their_k != k {
                return Err(Error::Net(format!(
                    "rank {r} thinks the group has {their_k} workers, the rendezvous expects {k}"
                )));
            }
            tally.on_recv_handshake(body.len());
            dir[r] = Some(peer_addr);
            conns[r] = Some(s);
        }
        let peers: Vec<(usize, String)> =
            (1..k).map(|r| (r, dir[r].clone().expect("rendezvous filled every slot"))).collect();
        let welcome = welcome_payload(k, &peers)?;
        for r in 1..k {
            let s = conns[r].as_mut().expect("rendezvous filled every slot");
            write_frame(s, FrameKind::Welcome, 0, 0, &welcome)?;
            tally.on_send_handshake(welcome.len());
        }
        SocketTransport::assemble(0, k, conns, self.opts, tally)
    }
}

// ---------------------------------------------------------------------------
// The transport endpoint
// ---------------------------------------------------------------------------

/// Per-connection state: read/write halves per peer rank (own slot is
/// `None`), the lockstep round counter, and the measured-byte tally.
struct Io {
    readers: Vec<Option<Stream>>,
    writers: Vec<Option<Stream>>,
    round: u64,
    tally: Tally,
}

fn lock_io(m: &Mutex<Io>) -> MutexGuard<'_, Io> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One rank's endpoint of a multi-process socket group. Implements
/// [`Transport`]; see the module docs for handshake and failure semantics.
pub struct SocketTransport {
    rank: usize,
    k: usize,
    io: Mutex<Io>,
    poisoned: Mutex<Option<String>>,
}

impl SocketTransport {
    /// Join the group as rank `rank ≥ 1`: dial the rank-0 rendezvous at
    /// `addr`, handshake, and wire up the peer mesh. Blocks until the
    /// whole group is connected or the handshake deadline passes.
    pub fn connect(
        addr: &str,
        rank: usize,
        k: usize,
        opts: SocketOpts,
    ) -> Result<Arc<SocketTransport>> {
        if rank == 0 {
            return Err(Error::Net(
                "rank 0 hosts the rendezvous: use SocketHub::bind + accept".into(),
            ));
        }
        if rank >= k {
            return Err(Error::Net(format!("rank {rank} out of range for a group of {k}")));
        }
        let addr = Addr::parse(addr)?;
        let deadline = opts.handshake_deadline();
        let mut tally = Tally::new(k);
        let mut rendezvous = dial(&addr, deadline)?;
        rendezvous.set_timeouts(Some(remaining(deadline)))?;
        let listener = Listener::bind_peer(&addr, &rendezvous, rank)?;
        let my_addr = listener.advertised()?;
        let hello = hello_payload(k, &my_addr)?;
        write_frame(&mut rendezvous, FrameKind::Hello, rank as u32, 0, &hello)?;
        tally.on_send_handshake(hello.len());
        let (hdr, body) = read_frame(&mut rendezvous)?;
        if hdr.kind != FrameKind::Welcome {
            return Err(Error::Net(format!(
                "expected WELCOME from the rendezvous, got {:?}",
                hdr.kind
            )));
        }
        if hdr.rank != 0 {
            return Err(Error::Net(format!("WELCOME must come from rank 0, not {}", hdr.rank)));
        }
        tally.on_recv_handshake(body.len());
        let (their_k, peer_dir) = parse_welcome(&body)?;
        if their_k != k {
            return Err(Error::Net(format!(
                "rendezvous runs a group of {their_k}, this worker expected {k}"
            )));
        }
        let mut conns: Vec<Option<Stream>> = (0..k).map(|_| None).collect();
        conns[0] = Some(rendezvous);
        // Mesh rule: rank i dials every lower rank 1 ≤ j < i; the PEER
        // frame tells the listener who arrived.
        for (peer, peer_addr) in &peer_dir {
            let peer = *peer;
            if peer == 0 || peer >= k {
                return Err(Error::Net(format!(
                    "WELCOME directory names out-of-range rank {peer}"
                )));
            }
            if peer >= rank {
                continue;
            }
            let mut s = dial(&Addr::parse(peer_addr)?, deadline)?;
            s.set_timeouts(Some(remaining(deadline)))?;
            write_frame(&mut s, FrameKind::Peer, rank as u32, 0, &(k as u32).to_le_bytes())?;
            tally.on_send_handshake(4);
            if conns[peer].is_some() {
                return Err(Error::Net(format!("duplicate directory entry for rank {peer}")));
            }
            conns[peer] = Some(s);
        }
        // ... and accepts one connection from every higher rank.
        for _ in rank + 1..k {
            let mut s = listener.accept_deadline(deadline, "waiting for higher-rank peers")?;
            s.set_timeouts(Some(remaining(deadline)))?;
            let (hdr, body) = read_frame(&mut s)?;
            if hdr.kind != FrameKind::Peer {
                return Err(Error::Net(format!(
                    "expected PEER on the mesh listener, got {:?}",
                    hdr.kind
                )));
            }
            let peer = hdr.rank as usize;
            if peer <= rank || peer >= k {
                return Err(Error::Net(format!(
                    "PEER from unexpected rank {peer} (I am rank {rank} of {k})"
                )));
            }
            if body.len() != 4
                || u32::from_le_bytes(body[..4].try_into().expect("4 bytes")) != k as u32
            {
                return Err(Error::Net(format!("PEER from rank {peer} disagrees on group size")));
            }
            if conns[peer].is_some() {
                return Err(Error::Net(format!("rank {peer} connected twice")));
            }
            tally.on_recv_handshake(body.len());
            conns[peer] = Some(s);
        }
        // The listener drops here, unlinking its unix path if any.
        Self::assemble(rank, k, conns, opts, tally)
    }

    /// Split every connection into read/write halves and box up the
    /// endpoint. `conns[rank]` must be `None` (no connection to self).
    fn assemble(
        rank: usize,
        k: usize,
        conns: Vec<Option<Stream>>,
        opts: SocketOpts,
        tally: Tally,
    ) -> Result<Arc<SocketTransport>> {
        let mut readers = Vec::with_capacity(k);
        let mut writers = Vec::with_capacity(k);
        for (p, conn) in conns.into_iter().enumerate() {
            match conn {
                None => {
                    debug_assert_eq!(p, rank, "only the own-rank slot may be empty");
                    readers.push(None);
                    writers.push(None);
                }
                Some(s) => {
                    s.set_timeouts(opts.timeout)?;
                    readers.push(Some(s.try_clone()?));
                    writers.push(Some(s));
                }
            }
        }
        Ok(Arc::new(SocketTransport {
            rank,
            k,
            io: Mutex::new(Io { readers, writers, round: 0, tally }),
            poisoned: Mutex::new(None),
        }))
    }

    /// The rank this endpoint was wired up as.
    pub fn rank(&self) -> usize {
        self.rank
    }

    fn poison_reason(&self) -> Option<String> {
        self.poisoned.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Record the first poison reason (later ones lose).
    fn set_poisoned(&self, reason: &str) {
        let mut p = self.poisoned.lock().unwrap_or_else(|e| e.into_inner());
        if p.is_none() {
            *p = Some(reason.to_string());
        }
    }
}

impl Transport for SocketTransport {
    fn peers(&self) -> usize {
        self.k
    }

    fn exchange(&self, rank: usize, payload: Vec<u8>, plane: Plane) -> Result<Vec<Arc<Vec<u8>>>> {
        if rank != self.rank {
            return Err(Error::Net(format!(
                "this endpoint is rank {}, cannot exchange as rank {rank}",
                self.rank
            )));
        }
        if let Some(why) = self.poison_reason() {
            return Err(Error::Net(format!("transport poisoned: {why}")));
        }
        let k = self.k;
        let mut io = lock_io(&self.io);
        let Io { readers, writers, round, tally } = &mut *io;
        let this_round = *round;
        let kind = FrameKind::for_plane(plane);
        let payload = Arc::new(payload);

        // Writer runs on a scoped thread while this thread reads: with
        // everyone writing to everyone, a sequential write-then-read would
        // deadlock once payloads outgrow the OS socket buffers.
        let outcome: std::result::Result<Vec<Arc<Vec<u8>>>, String> = thread::scope(|s| {
            let to_send = payload.clone();
            // Move a reborrow into the closure, not `writers` itself: the
            // reborrow expires when the scope ends, leaving the original
            // binding usable for the ABORT broadcast in the Err arm below.
            let writer = s.spawn({
                let writers = &mut *writers;
                move || -> std::result::Result<(), String> {
                    for p in 0..k {
                        if p == rank {
                            continue;
                        }
                        let w = writers[p].as_mut().expect("mesh has a conn per peer");
                        write_frame(w, kind, rank as u32, this_round, &to_send)
                            .map_err(|e| format!("round {this_round}: sending to peer {p}: {e}"))?;
                    }
                    Ok(())
                }
            });
            let mut slots: Vec<Option<Arc<Vec<u8>>>> = vec![None; k];
            slots[rank] = Some(payload.clone());
            let mut read_err: Option<String> = None;
            for p in 0..k {
                if p == rank {
                    continue;
                }
                let r = readers[p].as_mut().expect("mesh has a conn per peer");
                match read_frame(r) {
                    Err(e) => {
                        read_err =
                            Some(format!("round {this_round}: receiving from peer {p}: {e}"));
                        break;
                    }
                    Ok((hdr, body)) => {
                        if hdr.kind == FrameKind::Abort {
                            read_err = Some(format!(
                                "peer {p} aborted: {}",
                                String::from_utf8_lossy(&body)
                            ));
                            break;
                        }
                        if hdr.kind == FrameKind::Goodbye {
                            read_err = Some(format!(
                                "peer {p} closed the connection during round {this_round}"
                            ));
                            break;
                        }
                        if hdr.kind != kind || hdr.rank as usize != p || hdr.round != this_round {
                            read_err = Some(format!(
                                "lockstep violation: expected {kind:?} rank {p} round \
                                 {this_round}, got {:?} rank {} round {}",
                                hdr.kind, hdr.rank, hdr.round
                            ));
                            break;
                        }
                        slots[p] = Some(Arc::new(body));
                    }
                }
            }
            let wrote = writer.join().unwrap_or_else(|_| Err("writer thread panicked".into()));
            if let Some(e) = read_err {
                return Err(e);
            }
            wrote?;
            Ok(slots.into_iter().map(|s| s.expect("all slots filled")).collect())
        });

        match outcome {
            Ok(out) => {
                let n = (k - 1) as u64;
                tally.frames_sent += n;
                tally.frames_recv += n;
                tally.header_bytes += (FRAME_HEADER_LEN as u64) * 2 * n;
                let sent = payload.len() as u64;
                let recv: u64 = out
                    .iter()
                    .enumerate()
                    .filter(|&(p, _)| p != rank)
                    .map(|(_, b)| b.len() as u64)
                    .sum();
                match plane {
                    Plane::Data => {
                        tally.data_rounds += 1;
                        for p in 0..k {
                            if p != rank {
                                tally.data_sent[p] += sent;
                                tally.data_recv[p] += out[p].len() as u64;
                            }
                        }
                    }
                    Plane::Control => {
                        tally.control_sent += sent * n;
                        tally.control_recv += recv;
                    }
                    Plane::Oob => {
                        tally.oob_sent += sent * n;
                        tally.oob_recv += recv;
                    }
                }
                *round += 1;
                Ok(out)
            }
            Err(reason) => {
                // Tell everyone why before surfacing the error; peers
                // blocked mid-read get the ABORT instead of a timeout.
                self.set_poisoned(&reason);
                for p in 0..k {
                    if p == rank {
                        continue;
                    }
                    if let Some(w) = writers[p].as_mut() {
                        let _ = write_frame(
                            w,
                            FrameKind::Abort,
                            rank as u32,
                            this_round,
                            reason.as_bytes(),
                        );
                    }
                }
                Err(Error::Net(format!("transport poisoned: {reason}")))
            }
        }
    }

    fn poison(&self, reason: &str) {
        self.set_poisoned(reason);
        // Notifying peers is best-effort: an in-flight exchange holds the
        // io lock and will broadcast its own ABORT on the way out (it sees
        // the poison flag), so we must not block here. But a *transient*
        // holder (e.g. `measured()` snapshotting the tally) releases the
        // lock quickly — retry briefly rather than silently skipping the
        // broadcast and leaving peers to discover the poison only via
        // their read timeout.
        for _ in 0..50 {
            if let Ok(mut io) = self.io.try_lock() {
                let Io { writers, round, .. } = &mut *io;
                for w in writers.iter_mut().flatten() {
                    let _ = write_frame(
                        w,
                        FrameKind::Abort,
                        self.rank as u32,
                        *round,
                        reason.as_bytes(),
                    );
                }
                return;
            }
            thread::sleep(Duration::from_millis(2));
        }
    }

    fn is_poisoned(&self) -> bool {
        self.poison_reason().is_some()
    }

    fn kind(&self) -> &'static str {
        "socket"
    }

    fn measured(&self) -> Option<MeasuredWire> {
        Some(lock_io(&self.io).tally.to_measured(self.rank))
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        // &mut self: no other thread can hold the locks.
        let reason = match self.poisoned.get_mut() {
            Ok(g) => g.clone(),
            Err(e) => e.into_inner().clone(),
        };
        let io = match self.io.get_mut() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        };
        let round = io.round;
        for w in io.writers.iter_mut().flatten() {
            let _ = match &reason {
                None => write_frame(w, FrameKind::Goodbye, self.rank as u32, round, &[]),
                Some(r) => write_frame(w, FrameKind::Abort, self.rank as u32, round, r.as_bytes()),
            };
        }
        for s in io.writers.iter().flatten().chain(io.readers.iter().flatten()) {
            s.shutdown();
        }
    }
}

/// Spin up a whole socket group inside one process (rank 0's hub plus
/// `k-1` connecting threads) — the building block for tests and the
/// in-process side of parity checks. Returned endpoints are ordered by
/// rank.
pub fn connect_group(addr: &str, k: usize, opts: SocketOpts) -> Result<Vec<Arc<SocketTransport>>> {
    let hub = SocketHub::bind(addr, k, opts)?;
    let actual = hub.addr()?;
    thread::scope(|s| -> Result<Vec<Arc<SocketTransport>>> {
        let joiners: Vec<_> = (1..k)
            .map(|r| {
                let a = actual.clone();
                s.spawn(move || SocketTransport::connect(&a, r, k, opts))
            })
            .collect();
        let mut group = vec![hub.accept()?];
        for j in joiners {
            group.push(j.join().map_err(|_| Error::Net("connector thread panicked".into()))??);
        }
        Ok(group)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique per-test unix socket address (no global clock/randomness:
    /// pid + a process-local counter is collision-free enough).
    #[cfg(unix)]
    fn uds_addr() -> String {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        format!(
            "unix:{}/qgenx-sock-test-{}-{n}.sock",
            std::env::temp_dir().display(),
            std::process::id()
        )
    }

    #[test]
    fn addr_parse_accepts_tcp_and_unix_rejects_garbage() {
        assert_eq!(Addr::parse("127.0.0.1:4000").unwrap(), Addr::Tcp("127.0.0.1:4000".into()));
        assert_eq!(Addr::parse("node7:9").unwrap(), Addr::Tcp("node7:9".into()));
        #[cfg(unix)]
        assert_eq!(Addr::parse("unix:/tmp/x.sock").unwrap(), Addr::Unix("/tmp/x.sock".into()));
        for bad in ["", "no-port", ":4000", "host:notaport", "unix:"] {
            assert!(Addr::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn handshake_payloads_roundtrip() {
        let h = hello_payload(4, "10.0.0.7:5000").unwrap();
        assert_eq!(parse_hello(&h).unwrap(), (4, "10.0.0.7:5000".to_string()));
        let w = welcome_payload(3, &[(1, "a:1".into()), (2, "b:2".into())]).unwrap();
        let (k, dir) = parse_welcome(&w).unwrap();
        assert_eq!(k, 3);
        assert_eq!(dir, vec![(1, "a:1".to_string()), (2, "b:2".to_string())]);
        // Truncations error instead of panicking.
        assert!(parse_hello(&h[..3]).is_err());
        assert!(parse_welcome(&w[..w.len() - 1]).is_err());
    }

    #[test]
    fn tcp_group_exchanges_all_planes_and_measures() {
        let k = 3;
        let group = connect_group("127.0.0.1:0", k, SocketOpts::default()).unwrap();
        thread::scope(|s| {
            for (rank, t) in group.iter().enumerate() {
                let t = t.clone();
                s.spawn(move || {
                    for _round in 0..2 {
                        // Rank r contributes (r+1)*3 bytes of its own label.
                        let payload = vec![rank as u8; (rank + 1) * 3];
                        let got = t.exchange(rank, payload, Plane::Data).unwrap();
                        assert_eq!(got.len(), k);
                        for (p, b) in got.iter().enumerate() {
                            assert_eq!(b.as_slice(), &vec![p as u8; (p + 1) * 3][..]);
                        }
                    }
                    let got = t.exchange(rank, vec![0xC0, rank as u8], Plane::Control).unwrap();
                    assert_eq!(got[1].as_slice(), &[0xC0, 1]);
                });
            }
        });
        let views: Vec<_> = group.iter().map(|t| t.measured().unwrap()).collect();
        for (rank, v) in views.iter().enumerate() {
            assert_eq!(v.rank, rank);
            assert_eq!(v.data_rounds, 2);
            assert_eq!(v.data_bytes_sent(), (2 * (rank + 1) * 3 * (k - 1)) as u64);
            assert_eq!(v.control_sent, (2 * (k - 1)) as u64);
            assert!(v.header_bytes > 0, "handshake + rounds have framed overhead");
        }
        // Directed-link totals: every (src, dst) carries src's two payloads,
        // and receivers saw exactly what senders measured.
        let links = MeasuredWire::merge_links(&views);
        assert_eq!(links.len(), k * (k - 1));
        assert_eq!(links[&(2, 0)], 18);
        for v in &views {
            for &((src, dst), b) in &v.data_recv {
                assert_eq!(links[&(src, dst)], b, "recv view of ({src},{dst}) matches send view");
            }
        }
    }

    #[cfg(unix)]
    #[test]
    fn unix_domain_group_smoke() {
        let addr = uds_addr();
        let group = connect_group(&addr, 2, SocketOpts::default()).unwrap();
        assert_eq!(group[0].kind(), "socket");
        assert_eq!(group[1].rank(), 1);
        thread::scope(|s| {
            let a = group[0].clone();
            let b = group[1].clone();
            s.spawn(move || {
                let got = a.exchange(0, vec![10], Plane::Data).unwrap();
                assert_eq!(got[1].as_slice(), &[11]);
            });
            s.spawn(move || {
                let got = b.exchange(1, vec![11], Plane::Data).unwrap();
                assert_eq!(got[0].as_slice(), &[10]);
            });
        });
    }

    #[test]
    fn departed_peer_poisons_the_round() {
        let k = 3;
        let mut group = connect_group("127.0.0.1:0", k, SocketOpts::default()).unwrap();
        // Rank 2 leaves cleanly (GOODBYE) before the round starts.
        drop(group.remove(2));
        thread::scope(|s| {
            for (rank, t) in group.iter().enumerate() {
                let t = t.clone();
                s.spawn(move || {
                    let err = t
                        .exchange(rank, vec![rank as u8], Plane::Data)
                        .expect_err("round with a departed peer must fail");
                    let msg = err.to_string();
                    assert!(msg.contains("poisoned"), "got: {msg}");
                    assert!(
                        msg.contains("closed the connection") || msg.contains("aborted"),
                        "got: {msg}"
                    );
                });
            }
        });
        assert!(group[0].is_poisoned());
        // Fails fast forever after.
        let err = group[0].exchange(0, vec![0], Plane::Data).expect_err("dead group");
        assert!(err.to_string().contains("poisoned"));
    }

    #[test]
    fn poison_reason_reaches_blocked_peers() {
        let group = connect_group("127.0.0.1:0", 2, SocketOpts::default()).unwrap();
        let t1 = group[1].clone();
        let blocked = thread::spawn(move || t1.exchange(1, vec![1], Plane::Data));
        group[0].poison("operator kill");
        let err = blocked.join().unwrap().expect_err("poison interrupts the round");
        let msg = err.to_string();
        assert!(msg.contains("operator kill"), "reason travels on the ABORT frame: {msg}");
        assert!(group[0].exchange(0, vec![0], Plane::Data).is_err(), "poisoner is dead too");
    }

    #[test]
    fn poison_broadcast_survives_concurrent_measured_snapshots() {
        // Regression: `poison()` takes the io lock with a bounded
        // try_lock retry loop so a *transient* holder — `measured()`
        // snapshotting the byte tally — cannot make it silently skip the
        // peer ABORT broadcast. Hammer `measured()` on the poisoner while
        // a peer is blocked mid-round: the reason must still travel on the
        // ABORT frame instead of the peer timing out.
        use std::sync::atomic::{AtomicBool, Ordering};
        let group = connect_group("127.0.0.1:0", 2, SocketOpts::default()).unwrap();
        let t1 = group[1].clone();
        let blocked = thread::spawn(move || t1.exchange(1, vec![1], Plane::Data));
        let stop = Arc::new(AtomicBool::new(false));
        let hammer = {
            let t0 = group[0].clone();
            let stop = stop.clone();
            thread::spawn(move || {
                let mut snaps = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let m = t0.measured().expect("socket fabric always measures");
                    assert_eq!(m.rank, 0);
                    snaps += 1;
                }
                snaps
            })
        };
        group[0].poison("chaos kill");
        let err = blocked.join().unwrap().expect_err("poison interrupts the round");
        let msg = err.to_string();
        assert!(msg.contains("chaos kill"), "ABORT must not be skipped under contention: {msg}");
        stop.store(true, Ordering::Relaxed);
        assert!(hammer.join().unwrap() > 0, "snapshots actually contended the io lock");
    }

    #[test]
    fn connect_gives_up_at_the_deadline() {
        let opts = SocketOpts { timeout: Some(Duration::from_millis(200)) };
        let begun = Instant::now();
        // Port 1 (tcpmux) is never bound in the test environment.
        let err =
            SocketTransport::connect("127.0.0.1:1", 1, 2, opts).expect_err("nobody listening");
        assert!(begun.elapsed() < Duration::from_secs(20), "deadline must bound the retry loop");
        assert!(err.to_string().contains("dialing"), "got: {err}");
    }

    #[test]
    fn single_rank_group_is_trivial() {
        // k = 1 wires no connections; exchange returns the own payload.
        // (Useful for misuse tests higher up the stack.)
        let group = connect_group("127.0.0.1:0", 1, SocketOpts::default()).unwrap();
        let got = group[0].exchange(0, vec![5, 5], Plane::Data).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].as_slice(), &[5, 5]);
        let err = group[0].exchange(1, vec![0], Plane::Data).expect_err("wrong rank");
        assert!(err.to_string().contains("rank"), "got: {err}");
    }
}
