//! The paper's adaptive step-size (Theorems 3 and 4):
//!
//! `γ_t = γ₀ · K · (1 + Σ_{i=1}^{t−1} Σ_{k=1}^K ‖V̂_{k,i} − V̂_{k,i+1/2}‖²)^{−1/2}`
//!
//! The same rule achieves `O(1/√(TK))` under absolute noise and `O(1/(KT))`
//! under relative noise *without knowing which regime it is in* — the
//! accumulated half-step differences shrink automatically when the noise is
//! relative (the oracle quiets down near the solution), keeping `γ_t`
//! bounded away from zero; under absolute noise they grow linearly and
//! `γ_t ∝ 1/√t` emerges.

/// Adaptive step-size accumulator.
#[derive(Clone, Debug)]
pub struct AdaptiveStepSize {
    /// Base scale γ₀ (multiplies the whole rule; 1.0 in the paper).
    gamma0: f64,
    /// Number of workers K.
    k: usize,
    /// Accumulated Σ_i Σ_k ‖V̂_{k,i} − V̂_{k,i+1/2}‖².
    sum_sq: f64,
    /// If false, behave as a fixed step γ₀ (ablation).
    adaptive: bool,
}

impl AdaptiveStepSize {
    pub fn new(gamma0: f64, k: usize, adaptive: bool) -> Self {
        assert!(gamma0 > 0.0 && k > 0);
        AdaptiveStepSize { gamma0, k, sum_sq: 0.0, adaptive }
    }

    /// Current γ_t (before observing iteration t's vectors).
    #[inline]
    pub fn gamma(&self) -> f64 {
        if self.adaptive {
            self.gamma0 * self.k as f64 / (1.0 + self.sum_sq).sqrt()
        } else {
            self.gamma0
        }
    }

    /// Record one iteration's per-worker differences
    /// `Σ_k ‖V̂_{k,t} − V̂_{k,t+1/2}‖²`.
    pub fn observe(&mut self, sum_worker_diff_sq: f64) {
        debug_assert!(sum_worker_diff_sq >= 0.0);
        self.sum_sq += sum_worker_diff_sq;
    }

    /// Convenience: accumulate from per-worker vector pairs.
    pub fn observe_pairs(&mut self, base: &[Vec<f32>], half: &[Vec<f32>]) {
        assert_eq!(base.len(), half.len());
        let mut acc = 0.0;
        for (b, h) in base.iter().zip(half.iter()) {
            acc += crate::util::dist_sq(b, h);
        }
        self.observe(acc);
    }

    pub fn accumulated(&self) -> f64 {
        self.sum_sq
    }

    pub fn workers(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_gamma_is_k_gamma0() {
        let s = AdaptiveStepSize::new(0.5, 4, true);
        assert!((s.gamma() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_decays_like_inverse_sqrt_under_constant_noise() {
        // Constant per-iteration difference c -> gamma_t ~ K/sqrt(ct).
        let mut s = AdaptiveStepSize::new(1.0, 2, true);
        let c = 4.0;
        for _ in 0..10_000 {
            s.observe(c);
        }
        let expect = 2.0 / (1.0 + c * 10_000.0).sqrt();
        assert!((s.gamma() - expect).abs() < 1e-12);
        // ratio test for the 1/sqrt(t) law
        let g1 = s.gamma();
        for _ in 0..30_000 {
            s.observe(c);
        }
        let g2 = s.gamma();
        assert!((g1 / g2 - 2.0).abs() < 0.01, "{}", g1 / g2);
    }

    #[test]
    fn gamma_stays_bounded_when_noise_vanishes() {
        // Geometric decay of differences (relative-noise regime): the sum
        // converges, so gamma_t stays bounded below.
        let mut s = AdaptiveStepSize::new(1.0, 1, true);
        let mut diff = 1.0;
        for _ in 0..1000 {
            s.observe(diff);
            diff *= 0.9;
        }
        assert!(s.gamma() > 0.25, "gamma collapsed: {}", s.gamma());
    }

    #[test]
    fn non_adaptive_is_constant() {
        let mut s = AdaptiveStepSize::new(0.3, 8, false);
        s.observe(1e9);
        assert_eq!(s.gamma(), 0.3);
    }

    #[test]
    fn observe_pairs_accumulates_distances() {
        let mut s = AdaptiveStepSize::new(1.0, 2, true);
        let base = vec![vec![0.0f32, 0.0], vec![1.0, 1.0]];
        let half = vec![vec![3.0f32, 4.0], vec![1.0, 1.0]];
        s.observe_pairs(&base, &half);
        assert!((s.accumulated() - 25.0).abs() < 1e-9);
    }
}
