//! The distributed coordinator — Algorithm 1 of the paper, behind one
//! steppable run API.
//!
//! ## Architecture: Session → ExchangePolicy → RoundEngine
//!
//! * [`Session`] ([`session`]) — the public run API: a builder
//!   (`Session::builder(cfg).oracle(..).collective(..).observer(..)`)
//!   that validates once and yields a steppable state machine —
//!   `step() -> StepReport`, `run_to(t)`, `checkpoint()`/`resume()`, and
//!   the [`Observer`] trait for streaming metrics and early-stop
//!   predicates. Full surface: `docs/API.md`.
//! * `ExchangePolicy` ([`policy`]) — one implementation per runner
//!   family: **exact** (per-step dual exchange, replicas bit-identical),
//!   **gossip** (neighborhood-averaged duals, replicas drift,
//!   `consensus_dist`), **local** (`local.steps = H ≥ 2`: private
//!   extra-gradient segments + quantized model-delta syncs), plus the
//!   QSGDA baseline as an algorithm policy. The seed implemented these as
//!   six hand-copied loops; each is now written once.
//! * `RoundEngine` ([`engine`]) — the shared round primitives every
//!   policy drives: stat-exchange step (pooled sufficient statistics,
//!   lockstep level/codec refresh), base / extrapolated dual exchange,
//!   delta exchange, traffic + per-link accounting, and the *single*
//!   stat-schedule predicate both execution modes share.
//!
//! Two execution modes are two engine *fabrics*, not two implementations:
//!
//! * **loopback** — all `K` endpoints in one thread (the inline
//!   simulation; deterministic, allocation-light, used by the
//!   rate/figure benches where thousands of runs are swept).
//! * **transport** — one rank per endpoint over a [`crate::net::Transport`],
//!   real encoded bytes on the wire ([`SessionBuilder::transport`]):
//!   threads sharing the in-process [`crate::net::AllGather`] barrier, or
//!   separate OS processes over [`crate::net::SocketTransport`]
//!   (`qgenx worker` / `qgenx launch`; framing in `docs/WIRE.md` §4).
//!
//! Both fabrics support `checkpoint()`/`resume()`; a transport rank's
//! checkpoint is barrier-coordinated across the group, and
//! [`Session::resume_with_transport`] restarts a rank onto a fresh
//! fabric (`docs/API.md`).
//!
//! The one-shot wrappers — [`run_experiment`], [`run_threaded`],
//! [`run_qsgda_baseline`] — survive as thin `Session` consumers with
//! trajectories and wire accounting bit-identical to the pre-Session
//! runners (`tests/session_parity.rs` pins this against a frozen copy of
//! the seed loops).
//!
//! ## Per-iteration protocol (all families, both fabrics)
//!
//! 1. if `t ∈ U` (level-update schedule; for the local family, first sync
//!    on/after each due point): workers exchange sufficient statistics
//!    (stat wire-format v2, or v3 for layer-wise pipelines — byte layouts
//!    in `docs/WIRE.md`; counted as traffic), pool them in rank order,
//!    and each deterministically re-optimizes levels, rebuilds Huffman
//!    codecs, and — layer-wise with a bit budget — re-runs the Theorem-1
//!    allocator. [`crate::config::QuantConfig::adapts`] (× "is the
//!    pipeline quantized") is the single gating predicate, evaluated in
//!    one place — the engine.
//! 2. variant-dependent base exchange (`V̂_{k,t}`): DE quantizes +
//!    exchanges fresh oracle queries at `X_t`; DA/OptDA send nothing.
//! 3. extrapolate to `X_{t+1/2}`.
//! 4. quantize + exchange `V̂_{k,t+1/2}`; update the replica(s). (The
//!    local family replaces 2–4 with `H` private iterations + one delta
//!    sync; the SGDA policy with a single exchange at `X_t`.)
//!
//! ## Topology selection
//!
//! The data-plane exchanges route through the [`crate::topo::Collective`]
//! built from the `[topo]` table: `full-mesh` (the paper's flat
//! allgather), `star`/`ring`/`hierarchical` (exact in-network
//! aggregation — bit-identical trajectories at lower modeled cost), or
//! `gossip` (inexact neighborhood averaging). The *control plane* (stat
//! pooling) is always global and accounted full-mesh: the decode side of
//! the wire format requires bit-identical codecs on every worker.
//!
//! ## Compression pipeline selection
//!
//! Orthogonal to family and topology, `[quant.layers]` selects the
//! per-worker [`pipeline::Compressor`] shape: FP32, the single-codec seed
//! pipeline, or layer-wise heterogeneous quantization (Q-GenX-LW). Every
//! family records the per-layer series/scalars when layer-wise is active.
//!
//! Timing: compute (oracle + encode + decode) is *measured*; network time
//! is *modeled* (α-β on the exact encoded byte counts). Measured times
//! are exempt from the bit-for-bit reproducibility contract.
//!
//! ## Observability
//!
//! The engine owns a [`crate::telemetry::Telemetry`] recorder (off by
//! default): stage spans, bit/round counters, and per-link traffic
//! streams, emitted identically by every family and both fabrics. Enable
//! it with [`SessionBuilder::telemetry`] or the `QGENX_TELEMETRY`
//! environment knob; each [`StepReport`] then carries the step's closed
//! [`crate::telemetry::StepRecord`]. Telemetry is *neutral*: trajectories
//! and wire bytes are bit-identical with it on or off
//! (`tests/telemetry.rs`). Full schema: `docs/OBSERVABILITY.md`.

pub mod engine;
pub mod inline;
pub mod pipeline;
pub mod policy;
pub mod schedule;
pub mod session;
pub mod threaded;

pub use engine::{pool_local_stats, OracleFactory};
pub use inline::{run_experiment, run_qsgda_baseline};
pub use pipeline::Compressor;
pub use schedule::UpdateSchedule;
pub use session::{
    Algorithm, Checkpoint, Control, Observer, Session, SessionBuilder, StepReport, StopAtGap,
};
pub use threaded::{run_threaded, ThreadedRun};
