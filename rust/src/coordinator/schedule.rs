//! The level-update schedule `U` (paper §3.1: "Let U denote the set of
//! update steps").
//!
//! Quantization levels `ℓ_j` are re-optimized at iterations `t ∈ U`; the
//! run is thereby partitioned into `J` segments of lengths `T_j`
//! (`Σ T_j = T`), which is exactly how Theorems 3/4 account for the
//! per-segment variance bounds `ε_{Q,j}` and code lengths `N_{Q,j}`.

/// Deterministic update schedule: warmup at `t = warmup`, then every
/// `every` iterations.
#[derive(Clone, Copy, Debug)]
pub struct UpdateSchedule {
    /// First update after this many iterations (lets stats accumulate).
    pub warmup: usize,
    /// Period between updates; 0 disables updates entirely.
    pub every: usize,
}

impl UpdateSchedule {
    pub fn new(warmup: usize, every: usize) -> Self {
        UpdateSchedule { warmup, every }
    }

    /// Never update (fixed-level schemes).
    pub fn never() -> Self {
        UpdateSchedule { warmup: 0, every: 0 }
    }

    /// Is iteration `t` (1-based) an update step?
    pub fn is_update(&self, t: usize) -> bool {
        if self.every == 0 {
            return false;
        }
        t >= self.warmup && (t - self.warmup) % self.every == 0
    }

    /// Segment index `j` (0-based) that iteration `t` falls into.
    pub fn segment_of(&self, t: usize) -> usize {
        if self.every == 0 || t < self.warmup {
            0
        } else {
            (t - self.warmup) / self.every + 1
        }
    }

    /// Number of updates in a `T`-iteration run.
    pub fn updates_in(&self, t_total: usize) -> usize {
        (1..=t_total).filter(|&t| self.is_update(t)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_schedule_never_updates() {
        let s = UpdateSchedule::never();
        assert!((1..1000).all(|t| !s.is_update(t)));
        assert_eq!(s.segment_of(500), 0);
    }

    #[test]
    fn periodic_updates_with_warmup() {
        let s = UpdateSchedule::new(10, 100);
        assert!(!s.is_update(1));
        assert!(s.is_update(10));
        assert!(!s.is_update(11));
        assert!(s.is_update(110));
        assert!(s.is_update(210));
        assert_eq!(s.updates_in(500), 5); // t=10,110,210,310,410
    }

    #[test]
    fn segments_partition_the_run() {
        let s = UpdateSchedule::new(0, 50);
        assert_eq!(s.segment_of(0), 1);
        assert_eq!(s.segment_of(49), 1);
        assert_eq!(s.segment_of(50), 2);
        assert_eq!(s.segment_of(99), 2);
    }

    #[test]
    fn zero_warmup_updates_from_the_first_matching_step() {
        // warmup = 0: every multiple of `every` (including t = 0 if ever
        // queried) is an update step; the 1-based loop first hits t = every.
        let s = UpdateSchedule::new(0, 25);
        assert!(s.is_update(0));
        assert!(!s.is_update(1));
        assert!(s.is_update(25));
        assert!(s.is_update(50));
        assert_eq!(s.updates_in(100), 4); // 25, 50, 75, 100
        // every = 1 degenerates to "update at every iteration"
        let s1 = UpdateSchedule::new(0, 1);
        assert!((1..=10).all(|t| s1.is_update(t)));
        assert_eq!(s1.updates_in(10), 10);
    }

    #[test]
    fn zero_every_disables_even_with_warmup_set() {
        let s = UpdateSchedule::new(10, 0);
        assert!((0..1000).all(|t| !s.is_update(t)));
        assert_eq!(s.updates_in(1000), 0);
        // segment mapping collapses to a single segment
        assert!((0..1000).all(|t| s.segment_of(t) == 0));
    }

    #[test]
    fn pre_warmup_steps_map_to_segment_zero() {
        // t < warmup: never an update, always segment 0; the first update
        // (t = warmup) opens segment 1.
        let s = UpdateSchedule::new(100, 50);
        for t in 0..100 {
            assert!(!s.is_update(t), "t={t}");
            assert_eq!(s.segment_of(t), 0, "t={t}");
        }
        assert!(s.is_update(100));
        assert_eq!(s.segment_of(100), 1);
        assert_eq!(s.segment_of(149), 1);
        assert_eq!(s.segment_of(150), 2);
        // warmup beyond the horizon: a run can finish with zero updates
        let far = UpdateSchedule::new(10_000, 50);
        assert_eq!(far.updates_in(500), 0);
        assert_eq!(far.segment_of(500), 0);
    }
}
