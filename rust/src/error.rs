//! Crate-wide error type.
//!
//! One `thiserror` enum covering every layer so that `qgenx::Result<T>` can
//! flow from the config parser through the coordinator to the PJRT runtime
//! without per-module error plumbing.

use thiserror::Error;

/// Unified error type for the qgenx crate.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration file could not be parsed or failed validation.
    #[error("config error: {0}")]
    Config(String),

    /// Wire-format / entropy-coding error (truncated stream, bad symbol...).
    #[error("codec error: {0}")]
    Codec(String),

    /// Quantizer misuse (unsorted levels, empty vector, bad `q`...).
    #[error("quantization error: {0}")]
    Quant(String),

    /// Problem / oracle construction error (dimension mismatch etc.).
    #[error("oracle error: {0}")]
    Oracle(String),

    /// Coordinator / transport failure (worker panic, channel closed...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// PJRT runtime failure (missing artifact, compile/execute error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact manifest missing or malformed.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// Generic IO error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Error bubbled up from the `xla` crate.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
