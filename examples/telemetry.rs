//! Observability walkthrough: the run-telemetry subsystem end to end.
//!
//! Every session family emits the same structured telemetry from the one
//! `RoundEngine` seam: per-stage spans (sample / quantize / encode /
//! exchange / decode / apply / stat), run counters (wire bits per plane,
//! stat rounds, level updates, codec refreshes), and per-link traffic.
//! Two sinks are demonstrated here:
//!
//! 1. the **in-memory ring** (`TelemetryConfig::memory()`) — zero
//!    steady-state allocations, inspected after the run through
//!    `Session::telemetry()`, plus the `TelemetryObserver` bridge that
//!    streams compact lines while the run progresses;
//! 2. the **JSONL event stream** (`TelemetryConfig::jsonl(path)`) — one
//!    deterministic JSON object per line (`manifest`, then `step`*, then
//!    `summary`), parsed back below with the same in-tree JSON.
//!
//! Telemetry is *neutral*: trajectories and wire bytes are bit-identical
//! with it on or off (`rust/tests/telemetry.rs` pins this). Schema and
//! overhead contract: `docs/OBSERVABILITY.md`. The same machinery is one
//! flag away on the CLI (`qgenx run --telemetry mem|path.jsonl`) or one
//! env var away anywhere (`QGENX_TELEMETRY`).
//!
//! ```bash
//! cargo run --release --example telemetry
//! ```

use qgenx::benchkit::{example_iters, fmt_secs};
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::Session;
use qgenx::runtime::json::Json;
use qgenx::telemetry::{TelemetryConfig, TelemetryObserver, TELEMETRY_SCHEMA};
use std::collections::BTreeMap;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "telemetry".into();
    cfg.problem.kind = "bilinear".into();
    cfg.problem.dim = 96;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.5;
    cfg.workers = 4;
    cfg.topo.kind = "ring".into();
    cfg.iters = example_iters(600);
    cfg.eval_every = (cfg.iters / 4).max(1);

    // ---- 1. In-memory ring + streaming observer --------------------------
    println!("== in-memory telemetry: ring + TelemetryObserver ==");
    let mut session = Session::builder(cfg.clone())
        .telemetry(TelemetryConfig::memory())
        .observer(Box::new(TelemetryObserver::every((cfg.iters / 6).max(1))))
        .build()?;
    session.run_to(cfg.iters)?;

    let tele = session.telemetry();
    let c = tele.counters();
    println!("\nrun counters:");
    println!(
        "  steps={}  data rounds={}  stat rounds={}",
        c.steps, c.data_rounds, c.stat_rounds
    );
    println!(
        "  data bits={}  stat bits={}  level updates={}  codec refreshes={}",
        c.data_bits, c.stat_bits, c.level_updates, c.codec_refreshes
    );
    println!("stage spans (run totals; `exchange` is modeled α-β time):");
    for (stage, secs) in tele.totals().iter() {
        if secs > 0.0 {
            println!("  {:<9} {}", stage.name(), fmt_secs(secs));
        }
    }
    if let Some(last) = tele.ring().latest() {
        println!(
            "last step t={}: {} data bits over {} links; hottest link ({},{}) carried {:.0} B",
            last.t, last.data_bits, last.links, last.hot_link.0, last.hot_link.1, last.hot_link_bytes
        );
    }
    let gap = session.recorder().get("gap").and_then(|s| s.last()).unwrap_or(f64::NAN);
    println!("final gap {gap:.5} — identical with telemetry off (neutrality contract)");

    // ---- 2. JSONL event stream ------------------------------------------
    let path = "results/telemetry_example.jsonl";
    println!("\n== JSONL telemetry sink -> {path} ==");
    Session::builder(cfg.clone()).telemetry(TelemetryConfig::jsonl(path)).build()?.run()?;

    // The stream is one JSON object per line, serialized deterministically
    // (sorted keys) by the in-tree JSON — so it parses back with the same.
    let text = std::fs::read_to_string(path)?;
    let first = Json::parse(text.lines().next().ok_or("empty telemetry stream")?)?;
    assert_eq!(first.get("event").and_then(|e| e.as_str()), Some("manifest"));
    assert_eq!(first.get("schema").and_then(|s| s.as_usize()), Some(TELEMETRY_SCHEMA as usize));
    let mut kinds: BTreeMap<String, usize> = BTreeMap::new();
    let mut last_kind = String::new();
    for line in text.lines() {
        let kind = Json::parse(line)?
            .get("event")
            .and_then(|e| e.as_str())
            .unwrap_or("?")
            .to_string();
        *kinds.entry(kind.clone()).or_insert(0) += 1;
        last_kind = kind;
    }
    assert_eq!(last_kind, "summary", "stream must close with the summary event");
    print!("events:");
    for (kind, n) in &kinds {
        print!("  {kind} x{n}");
    }
    println!("  (schema v{TELEMETRY_SCHEMA}, docs/OBSERVABILITY.md)");
    Ok(())
}
