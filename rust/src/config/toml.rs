//! Minimal TOML-subset parser (no `serde`/`toml` in the offline image).
//!
//! Supports the subset the launcher needs:
//! * `[section]` and `[section.subsection]` headers
//! * `key = value` with string (`"..."`), integer, float, boolean values
//! * homogeneous inline arrays `[1, 2, 3]` / `["a", "b"]`
//! * `#` comments, blank lines
//!
//! Everything is stored in a flat `section.key -> Value` map; typed access
//! with defaulting lives in [`Doc`]'s getters. Unknown keys are kept so the
//! launcher can warn about typos (`Doc::unused_keys`).

use crate::error::{Error, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};

/// Parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Array(_) => "array",
        }
    }
}

/// A parsed document: flat map of `section.key` (or bare `key`) to values.
#[derive(Debug, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
    /// keys read at least once (for typo warnings)
    used: RefCell<BTreeSet<String>>,
}

impl Doc {
    /// Parse a TOML-subset string.
    pub fn parse(src: &str) -> Result<Doc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| err(lineno, &format!("bad value: {e}")))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if map.insert(full.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key `{full}`")));
            }
        }
        Ok(Doc { map, used: RefCell::new(BTreeSet::new()) })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Doc> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
        Doc::parse(&src)
    }

    fn mark(&self, key: &str) {
        self.used.borrow_mut().insert(key.to_string());
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    /// All keys under a flattened-section prefix (e.g. `"algo."`), in
    /// document order. Does not mark the keys as used — callers that
    /// enumerate a table for validation still read accepted keys through
    /// the typed getters.
    pub fn keys_with_prefix(&self, prefix: &str) -> Vec<String> {
        self.map.keys().filter(|k| k.starts_with(prefix)).cloned().collect()
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.mark(key);
        self.map.get(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(v) => Err(type_err(key, "string", v)),
        }
    }

    pub fn get_i64(&self, key: &str, default: i64) -> Result<i64> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Int(i)) => Ok(*i),
            Some(v) => Err(type_err(key, "integer", v)),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.get_i64(key, default as i64)?;
        if v < 0 {
            return Err(Error::Config(format!("{key}: must be non-negative, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(v) => Err(type_err(key, "float", v)),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Bool(b)) => Ok(*b),
            Some(v) => Err(type_err(key, "boolean", v)),
        }
    }

    pub fn get_f64_array(&self, key: &str) -> Result<Option<Vec<f64>>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::Array(xs)) => {
                let mut out = Vec::with_capacity(xs.len());
                for x in xs {
                    match x {
                        Value::Float(f) => out.push(*f),
                        Value::Int(i) => out.push(*i as f64),
                        v => return Err(type_err(key, "float array", v)),
                    }
                }
                Ok(Some(out))
            }
            Some(v) => Err(type_err(key, "array", v)),
        }
    }

    pub fn get_str_array(&self, key: &str) -> Result<Option<Vec<String>>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::Array(xs)) => {
                let mut out = Vec::with_capacity(xs.len());
                for x in xs {
                    match x {
                        Value::Str(s) => out.push(s.clone()),
                        v => return Err(type_err(key, "string array", v)),
                    }
                }
                Ok(Some(out))
            }
            Some(v) => Err(type_err(key, "array", v)),
        }
    }

    pub fn get_usize_array(&self, key: &str) -> Result<Option<Vec<usize>>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::Array(xs)) => {
                let mut out = Vec::with_capacity(xs.len());
                for x in xs {
                    match x {
                        Value::Int(i) if *i >= 0 => out.push(*i as usize),
                        v => return Err(type_err(key, "non-negative int array", v)),
                    }
                }
                Ok(Some(out))
            }
            Some(v) => Err(type_err(key, "array", v)),
        }
    }

    /// Keys present in the file but never read — likely typos.
    pub fn unused_keys(&self) -> Vec<String> {
        let used = self.used.borrow();
        self.map.keys().filter(|k| !used.contains(*k)).cloned().collect()
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

fn type_err(key: &str, want: &str, got: &Value) -> Error {
    Error::Config(format!("{key}: expected {want}, got {}", got.type_name()))
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote not supported".into());
        }
        return Ok(Value::Str(unescape(inner)));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .unwrap()
            .strip_suffix(']')
            .ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            items.push(parse_value(p)?);
        }
        return Ok(Value::Array(items));
    }
    // numbers (support underscores and exponents)
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse `{s}`"))
}

fn unescape(s: &str) -> String {
    s.replace("\\n", "\n").replace("\\t", "\t").replace("\\\\", "\\")
}

/// Split on commas that are not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "bilinear"           # inline comment
seed = 42

[quant]
bits = 4
levels = [0.1, 0.5, 0.9]
adaptive = true
norm_q = 2

[net]
bandwidth_gbps = 1.0
latency_us = 50.0
peers = [1, 2, 3]
label = "1GbE"
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("name", "").unwrap(), "bilinear");
        assert_eq!(doc.get_i64("seed", 0).unwrap(), 42);
        assert_eq!(doc.get_i64("quant.bits", 0).unwrap(), 4);
        assert!(doc.get_bool("quant.adaptive", false).unwrap());
        assert_eq!(doc.get_f64("net.bandwidth_gbps", 0.0).unwrap(), 1.0);
        assert_eq!(
            doc.get_f64_array("quant.levels").unwrap().unwrap(),
            vec![0.1, 0.5, 0.9]
        );
        assert_eq!(doc.get_usize_array("net.peers").unwrap().unwrap(), vec![1, 2, 3]);
        assert_eq!(doc.get_str("net.label", "").unwrap(), "1GbE");
        let named = Doc::parse("names = [\"a\", \"b\"]").unwrap();
        assert_eq!(
            named.get_str_array("names").unwrap().unwrap(),
            vec!["a".to_string(), "b".to_string()]
        );
        assert!(Doc::parse("names = [1, 2]").unwrap().get_str_array("names").is_err());
    }

    #[test]
    fn defaults_for_missing_keys() {
        let doc = Doc::parse("a = 1").unwrap();
        assert_eq!(doc.get_i64("missing", 7).unwrap(), 7);
        assert_eq!(doc.get_str("nope", "d").unwrap(), "d");
        assert!(doc.get_f64_array("arr").unwrap().is_none());
    }

    #[test]
    fn type_errors_are_reported() {
        let doc = Doc::parse("a = \"x\"").unwrap();
        assert!(doc.get_i64("a", 0).is_err());
        let doc2 = Doc::parse("b = 3").unwrap();
        assert!(doc2.get_bool("b", false).is_err());
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x", 0.0).unwrap(), 3.0);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = ").is_err());
        assert!(Doc::parse("k = \"open").is_err());
        assert!(Doc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn numbers_with_underscores_and_exponents() {
        let doc = Doc::parse("big = 1_000_000\nsmall = 1e-3\nneg = -42").unwrap();
        assert_eq!(doc.get_i64("big", 0).unwrap(), 1_000_000);
        assert!((doc.get_f64("small", 0.0).unwrap() - 1e-3).abs() < 1e-12);
        assert_eq!(doc.get_i64("neg", 0).unwrap(), -42);
    }

    #[test]
    fn unused_keys_tracked() {
        let doc = Doc::parse("a = 1\nb = 2").unwrap();
        let _ = doc.get_i64("a", 0).unwrap();
        assert_eq!(doc.unused_keys(), vec!["b".to_string()]);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = Doc::parse("s = \"a # b\"").unwrap();
        assert_eq!(doc.get_str("s", "").unwrap(), "a # b");
    }

    #[test]
    fn nested_section_names() {
        let doc = Doc::parse("[a.b]\nc = 1").unwrap();
        assert_eq!(doc.get_i64("a.b.c", 0).unwrap(), 1);
    }
}
