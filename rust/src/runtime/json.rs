//! Minimal JSON parser (no `serde_json` in the offline image).
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py`.
//! Supports the full JSON value grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP; surrogate pairs are rejected — the manifest is
//! ASCII anyway).

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from `(key, value)` pairs — the constructor the
    /// telemetry sink and the benches use instead of spelling
    /// `Json::Obj(BTreeMap::from([...]))` with per-key `.to_string()`
    /// noise at every call site. Later duplicates win (BTreeMap insert).
    pub fn obj<K: Into<String>>(pairs: impl IntoIterator<Item = (K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["entries", "lm_step", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to JSON text. Object keys come out in `BTreeMap`
    /// order (sorted — deterministic output for artifact diffing). Numbers
    /// print shortest-roundtrip via Rust's f64 `Display`; non-finite
    /// numbers (not representable in JSON) serialize as `null`. This is
    /// what the bench harness uses to emit `BENCH_*.json` trajectories
    /// (see `docs/PERF.md`) with the same module that can re-parse them.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.dump_into(&mut out);
        out
    }

    fn dump_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 9e15 {
                        out.push_str(&(*n as i64).to_string());
                    } else {
                        out.push_str(&n.to_string());
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => dump_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.dump_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    dump_str(k, out);
                    out.push(':');
                    v.dump_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape and quote one JSON string (shared by the `Str` arm and object
/// keys — no throwaway allocation per key).
fn dump_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Manifest(format!("json error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("surrogate escapes unsupported"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // collect UTF-8 continuation bytes verbatim
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = (start + width).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number `{s}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
            "lm": {"preset": "small", "params": 436736, "gp": 1.0},
            "entries": {"lm_step": {"file": "lm_step.hlo.txt",
                "inputs": [{"shape": [436736], "dtype": "float32"}]}},
            "flags": [true, false, null],
            "neg": -3.5e-2
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.at(&["lm", "preset"]).unwrap().as_str(), Some("small"));
        assert_eq!(j.at(&["lm", "params"]).unwrap().as_usize(), Some(436736));
        assert_eq!(
            j.at(&["entries", "lm_step", "file"]).unwrap().as_str(),
            Some("lm_step.hlo.txt")
        );
        let shape = j
            .at(&["entries", "lm_step", "inputs"])
            .unwrap()
            .as_array()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(436736));
        assert_eq!(j.get("neg").unwrap().as_f64(), Some(-3.5e-2));
        assert_eq!(j.get("flags").unwrap().as_array().unwrap().len(), 3);
    }

    #[test]
    fn parses_the_real_manifest_if_present() {
        if let Ok(src) = std::fs::read_to_string("artifacts/manifest.json") {
            let j = Json::parse(&src).unwrap();
            assert!(j.get("entries").is_some());
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn obj_constructor_builds_sorted_objects() {
        let j = Json::obj([("b", Json::Num(2.0)), ("a", Json::Str("x".into()))]);
        assert_eq!(j.get("a").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.dump(), r#"{"a":"x","b":2}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let src = r#"{
            "bench": "perf_hotpath",
            "cases": [{"stage": "decode", "ns_per_coord": 1.25, "allocs": 0}],
            "d": 4000000, "ok": true, "note": "a\n\"b\"", "none": null,
            "neg": -0.5
        }"#;
        let j = Json::parse(src).unwrap();
        let dumped = j.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), j, "dump must re-parse to itself");
        // Integers stay integral; keys come out sorted (BTreeMap order).
        assert!(dumped.contains("\"d\":4000000"));
        assert!(dumped.contains("\"allocs\":0"));
        let bench_pos = dumped.find("\"bench\"").unwrap();
        let ok_pos = dumped.find("\"ok\"").unwrap();
        assert!(bench_pos < ok_pos);
        // Non-finite numbers degrade to null instead of invalid JSON.
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }
}
