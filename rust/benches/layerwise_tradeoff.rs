//! E13 — layer-wise vs. uniform bit allocation at matched total bits.
//!
//! PR 1 varied *where* bytes flow (topologies), PR 2 *how often* (local
//! steps); this bench varies *how the bits are split across the vector*.
//! Deep-learning dual vectors concatenate per-layer gradients whose norms
//! differ by orders of magnitude; Q-GenX-LW gives each layer its own level
//! sequence and lets `quant::alloc` redistribute a global bits/coordinate
//! budget by the Theorem-1 variance objective. Method:
//!
//! 1. Two runs per oracle at the *same* mean symbol-bit budget
//!    (4 bits/coordinate, the UQ4 operating point, uniform levels + fixed
//!    codec so allocation is the only moving part):
//!    * **uniform** — single-codec UQ4 over the whole vector;
//!    * **layer-wise** — `[quant.layers]` aligned with the oracle's blocks
//!      plus `budget = 4.0`, so the allocator re-splits bits from the
//!      pooled per-layer norm mass on the update schedule.
//! 2. Oracles are the LM/GAN-shaped [`BlockScaledQuadratic`] proxies
//!    (`lm-proxy`: 60% cold embed / 30% body / 10% hot head; `gan-proxy`:
//!    cold generator half, hot critic half) under *relative* noise, so the
//!    per-block heterogeneity persists along the whole trajectory.
//! 3. Matched-gap accounting as in `benches/local_steps.rs`: the target
//!    gap is 1.05 × the worst final gap in the pair; a run's cost is
//!    `bits_cum` at its first eval point at or below the target.
//!
//! Acceptance (full-scale mode): on at least one of the two oracles,
//! layer-wise allocation reaches the matched gap with strictly fewer total
//! wire bits than uniform allocation.
//!
//! [`BlockScaledQuadratic`]: qgenx::oracle::BlockScaledQuadratic

use qgenx::benchkit::{fast_mode, scaled, write_csv, Table};
use qgenx::coding::SymbolCodec;
use qgenx::config::{ExperimentConfig, LevelScheme, QuantMode};
use qgenx::coordinator::run_experiment;
use qgenx::metrics::Recorder;
use qgenx::oracle::BlockScaledQuadratic;

struct OracleCase {
    kind: &'static str,
    dim: usize,
    names: Vec<&'static str>,
    bounds: Vec<usize>,
}

fn cases() -> Vec<OracleCase> {
    vec![
        OracleCase {
            kind: "lm-proxy",
            dim: 1280,
            names: vec!["embed", "body", "head"],
            bounds: BlockScaledQuadratic::lm_proxy_bounds(1280),
        },
        OracleCase {
            kind: "gan-proxy",
            dim: 1024,
            names: vec!["gen", "disc"],
            bounds: BlockScaledQuadratic::gan_proxy_bounds(1024),
        },
    ]
}

fn base_cfg(case: &OracleCase, iters: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = case.kind.into();
    cfg.problem.dim = case.dim;
    // Relative (multiplicative) noise keeps the per-block norm profile
    // heterogeneous down to the solution — the regime layer-wise targets.
    cfg.problem.noise = "relative".into();
    cfg.problem.rel_c = 0.5;
    cfg.workers = 4;
    cfg.iters = iters;
    cfg.eval_every = (iters / 50).max(1);
    cfg.seed = 17;
    cfg.quant.mode = QuantMode::parse("uq4").unwrap();
    cfg.quant.scheme = LevelScheme::Uniform;
    cfg.quant.codec = SymbolCodec::Fixed;
    cfg.quant.bucket_size = 128;
    cfg.quant.hist_bins = 128;
    cfg.quant.update_every = 100;
    cfg
}

fn run_pair(case: &OracleCase, iters: usize) -> (Recorder, Recorder) {
    let mut uni = base_cfg(case, iters);
    uni.name = format!("layerwise_{}_uniform", case.kind);
    let uniform = run_experiment(&uni).expect("uniform run");

    let mut lw = base_cfg(case, iters);
    lw.name = format!("layerwise_{}_lw", case.kind);
    lw.quant.layers.names = case.names.iter().map(|s| s.to_string()).collect();
    lw.quant.layers.bounds = case.bounds.clone();
    lw.quant.layers.budget = 4.0;
    let layered = run_experiment(&lw).expect("layer-wise run");
    (uniform, layered)
}

/// `bits_cum` at the first eval point whose gap is at or below `target`
/// (identical eval grids across the pair make this a fair match).
fn bits_to_gap(rec: &Recorder, target: f64) -> Option<f64> {
    let gaps = rec.get("gap").unwrap();
    let bits = rec.get("bits_cum").unwrap();
    gaps.points
        .iter()
        .zip(bits.points.iter())
        .find(|((_, g), _)| *g <= target)
        .map(|(_, (_, b))| *b)
}

fn main() {
    println!("== E13: layer-wise vs uniform allocation — bits at matched gap ==\n");
    let iters = scaled(1500, 250);
    let mut csv = Vec::new();
    let mut wins = Vec::new();

    for case in cases() {
        let (uniform, layered) = run_pair(&case, iters);
        let gap_u = uniform.get("gap").unwrap().last().unwrap();
        let gap_l = layered.get("gap").unwrap().last().unwrap();
        let target = 1.05 * gap_u.max(gap_l);
        let bits_u = bits_to_gap(&uniform, target).expect("uniform reaches the matched gap");
        let bits_l = bits_to_gap(&layered, target).expect("layer-wise reaches the matched gap");
        wins.push((case.kind, bits_l < bits_u));

        let mut table =
            Table::new(&["scheme", "final gap", "bits@gap", "x vs uniform", "total bits", "eps_q"]);
        for (label, rec, bits) in
            [("uniform", &uniform, bits_u), ("layer-wise", &layered, bits_l)]
        {
            let row = vec![
                label.to_string(),
                format!("{:.4}", rec.get("gap").unwrap().last().unwrap()),
                format!("{:.3e}", bits),
                format!("{:.2}", bits_u / bits),
                format!("{:.3e}", rec.scalar("total_bits").unwrap()),
                format!("{:.3}", rec.scalar("epsilon_q").unwrap()),
            ];
            table.row(&row);
            let mut crow = vec![case.kind.to_string()];
            crow.extend(row);
            csv.push(crow);
        }
        println!(
            "-- oracle = {} (d = {}, matched gap {target:.4}, T = {iters}) --",
            case.kind, case.dim
        );
        table.print();
        print!("   allocation:");
        for name in &case.names {
            let s = layered.scalar(&format!("layer_levels/{name}")).unwrap_or(f64::NAN);
            let mib = layered.scalar(&format!("layer_bits/{name}")).unwrap_or(0.0) / 8.0
                / 1048576.0;
            print!("  {name}: s = {s:.0} ({mib:.2} MiB)");
        }
        println!("\n");
    }

    write_csv(
        "results/layerwise_tradeoff.csv",
        &["oracle", "scheme", "final_gap", "bits_at_gap", "speedup_vs_uniform", "total_bits", "eps_q"],
        &csv,
    )
    .unwrap();

    if fast_mode() {
        println!("acceptance check skipped in QGENX_BENCH_FAST mode (budget too small)");
    } else {
        let any = wins.iter().any(|&(_, w)| w);
        println!(
            "acceptance: layer-wise reaches the matched gap with strictly fewer total\n\
             bits than uniform on at least one of the LM/GAN oracles: {}  ({})",
            if any { "YES" } else { "NO" },
            wins.iter()
                .map(|(k, w)| format!("{k}: {}", if *w { "win" } else { "loss" }))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    println!(
        "\npaper shape: one level sequence forces every layer to the same\n\
         bits/coordinate even though the Theorem-1 cost of a layer scales with\n\
         its norm mass w_l = Σ‖g_l‖². Allocating by the variance objective\n\
         (Nguyen et al. 2025's layer-wise observation, instantiated on Q-GenX)\n\
         moves bits from wide-and-cold layers to narrow-and-hot ones at the\n\
         same wire budget, cutting ε_Q and therefore the bits needed to reach\n\
         a fixed gap."
    );
}
