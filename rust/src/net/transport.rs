//! In-process synchronous allgather for the threaded coordinator.
//!
//! `K` worker threads each deposit one payload per round and receive
//! everyone's payloads — the exact communication pattern of Algorithm 1
//! ("each processor receives stochastic dual vectors from all other
//! processors"). Implementation: a shared slot array + two-phase barrier
//! (deposit → read). Payloads are `Vec<u8>` — real encoded wire bytes, so
//! the transport also measures exact per-round sizes.
//!
//! The generation counter catches protocol misuse (a worker calling twice
//! in one round) in debug builds, and `poisoned` propagates a worker panic
//! to its peers instead of deadlocking.

use std::sync::{Arc, Barrier, Mutex};

/// One synchronous allgather group of `k` participants.
pub struct AllGather {
    k: usize,
    slots: Mutex<Slots>,
    enter: Barrier,
    exit: Barrier,
}

struct Slots {
    payloads: Vec<Option<Arc<Vec<u8>>>>,
    generation: u64,
}

impl AllGather {
    pub fn new(k: usize) -> Arc<Self> {
        assert!(k >= 1);
        Arc::new(AllGather {
            k,
            slots: Mutex::new(Slots { payloads: vec![None; k], generation: 0 }),
            enter: Barrier::new(k),
            exit: Barrier::new(k),
        })
    }

    pub fn peers(&self) -> usize {
        self.k
    }

    /// Exchange: worker `rank` contributes `payload`, gets back all `k`
    /// payloads (rank-indexed, including its own). Blocks until everyone
    /// arrives. Panics on double-deposit within a round.
    pub fn exchange(&self, rank: usize, payload: Vec<u8>) -> Vec<Arc<Vec<u8>>> {
        assert!(rank < self.k);
        {
            let mut s = self.slots.lock().unwrap();
            assert!(
                s.payloads[rank].is_none(),
                "worker {rank} deposited twice in one round"
            );
            s.payloads[rank] = Some(Arc::new(payload));
        }
        // Wait until all deposits are in.
        self.enter.wait();
        let out: Vec<Arc<Vec<u8>>> = {
            let s = self.slots.lock().unwrap();
            s.payloads.iter().map(|p| p.clone().expect("slot must be filled")).collect()
        };
        // Second barrier: nobody proceeds until everyone has read. After it,
        // each worker clears only its OWN slot — a leader-side wipe would
        // race with a fast worker's next-round deposit.
        let leader = self.exit.wait();
        {
            let mut s = self.slots.lock().unwrap();
            s.payloads[rank] = None;
            if leader.is_leader() {
                s.generation += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn allgather_delivers_everyones_payload() {
        let k = 4;
        let ag = AllGather::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ag = ag.clone();
                thread::spawn(move || {
                    for round in 0..10u8 {
                        let payload = vec![rank as u8, round];
                        let got = ag.exchange(rank, payload);
                        assert_eq!(got.len(), k);
                        for (r, p) in got.iter().enumerate() {
                            assert_eq!(p.as_slice(), &[r as u8, round]);
                        }
                    }
                    rank
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn single_participant_trivially_exchanges() {
        let ag = AllGather::new(1);
        let got = ag.exchange(0, vec![7, 7]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].as_slice(), &[7, 7]);
    }

    #[test]
    fn payload_sizes_vary_per_round() {
        let k = 2;
        let ag = AllGather::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ag = ag.clone();
                thread::spawn(move || {
                    for round in 1..6usize {
                        let payload = vec![rank as u8; round * (rank + 1)];
                        let got = ag.exchange(rank, payload);
                        assert_eq!(got[0].len(), round);
                        assert_eq!(got[1].len(), round * 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
