//! Dense vector kernels used on the coordinator hot path.
//!
//! Everything operates on `&[f32]` — the universal representation of a
//! stochastic dual vector in this crate (see DESIGN.md §5.2). The functions
//! are deliberately simple and branch-free so that LLVM autovectorizes
//! them; `perf_hotpath` benches confirm they are memory-bound.

/// `y += alpha * x` (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x` (overwrite-scale).
#[inline]
pub fn scale_into(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = alpha * xi;
    }
}

/// In-place `x *= alpha`.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Dot product in f64 accumulation (stable for large d).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        acc += (*a as f64) * (*b as f64);
    }
    acc
}

/// Squared Euclidean norm (f64 accumulation).
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for a in x {
        acc += (*a as f64) * (*a as f64);
    }
    acc
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// L1 norm.
#[inline]
pub fn norm1(x: &[f32]) -> f64 {
    x.iter().map(|a| a.abs() as f64).sum()
}

/// L∞ norm.
#[inline]
pub fn norm_inf(x: &[f32]) -> f64 {
    x.iter().fold(0.0f64, |m, a| m.max(a.abs() as f64))
}

/// General `L^q` norm for integer `q >= 1`; `q == u32::MAX` denotes L∞.
/// These are the normalizations Definition 1 of the paper supports.
pub fn norm_q(x: &[f32], q: u32) -> f64 {
    match q {
        1 => norm1(x),
        2 => norm2(x),
        u32::MAX => norm_inf(x),
        q => {
            let p = q as f64;
            let mut acc = 0.0f64;
            // Scale by max for overflow safety at large q.
            let m = norm_inf(x);
            if m == 0.0 {
                return 0.0;
            }
            for a in x {
                acc += ((a.abs() as f64) / m).powf(p);
            }
            m * acc.powf(1.0 / p)
        }
    }
}

/// Squared distance ||x - y||_2^2.
#[inline]
pub fn dist_sq(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        let d = (*a as f64) - (*b as f64);
        acc += d * d;
    }
    acc
}

/// Elementwise sum of `K` vectors scaled by `1/K` — the aggregation step of
/// Algorithm 1 (`(1/K) Σ_k V̂_k`). Writes into `out`.
pub fn mean_into(vs: &[&[f32]], out: &mut [f32]) {
    assert!(!vs.is_empty());
    let k = vs.len() as f32;
    out.fill(0.0);
    for v in vs {
        debug_assert_eq!(v.len(), out.len());
        for (o, x) in out.iter_mut().zip(v.iter()) {
            *o += x;
        }
    }
    for o in out.iter_mut() {
        *o /= k;
    }
}

/// out = x - y.
#[inline]
pub fn sub_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..out.len() {
        out[i] = x[i] - y[i];
    }
}

/// Dense matrix-vector product `out = M x` with `M` row-major `(rows, cols)`.
pub fn matvec(m: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        out[r] = dot(row, x) as f32;
    }
}

/// Transposed matrix-vector product `out = M^T x`.
pub fn matvec_t(m: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(x.len(), rows);
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    for r in 0..rows {
        let row = &m[r * cols..(r + 1) * cols];
        axpy(x[r], row, out);
    }
}

/// Project `x` onto the probability simplex (Duchi et al. 2008 algorithm).
/// Used by the matrix-game example / oracle.
pub fn project_simplex(x: &mut [f32]) {
    let n = x.len();
    let mut u: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0f64;
    let mut rho = 0usize;
    let mut theta = 0.0f64;
    for (i, &ui) in u.iter().enumerate() {
        css += ui;
        let t = (css - 1.0) / (i as f64 + 1.0);
        if ui - t > 0.0 {
            rho = i;
            theta = t;
        }
    }
    let _ = rho;
    for v in x.iter_mut() {
        *v = ((*v as f64) - theta).max(0.0) as f32;
    }
    // Renormalize tiny drift.
    let s: f64 = x.iter().map(|&v| v as f64).sum();
    if s > 0.0 {
        for v in x.iter_mut() {
            *v = ((*v as f64) / s) as f32;
        }
    } else {
        let uniform = 1.0 / n as f32;
        x.fill(uniform);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_scale() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [1.5, 2.5, 3.5]);
    }

    #[test]
    fn norms_match_known_values() {
        let v = [3.0f32, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-9);
        assert!((norm1(&v) - 7.0).abs() < 1e-9);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-9);
        assert!((norm_q(&v, 2) - 5.0).abs() < 1e-9);
        assert!((norm_q(&v, 1) - 7.0).abs() < 1e-9);
        assert!((norm_q(&v, u32::MAX) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn norm_q_interpolates() {
        // L1 >= Lq >= Linf for q in between.
        let v = [1.0f32, 2.0, -3.0, 0.5];
        let l1 = norm_q(&v, 1);
        let l3 = norm_q(&v, 3);
        let l8 = norm_q(&v, 8);
        let li = norm_q(&v, u32::MAX);
        assert!(l1 >= l3 && l3 >= l8 && l8 >= li);
    }

    #[test]
    fn mean_into_averages() {
        let a = [2.0f32, 4.0];
        let b = [4.0f32, 8.0];
        let mut out = [0.0f32; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [3.0, 6.0]);
    }

    #[test]
    fn matvec_known() {
        // M = [[1,2],[3,4]], x = [1,1] -> [3,7]; M^T [1,1] -> [4,6]
        let m = [1.0f32, 2.0, 3.0, 4.0];
        let x = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        matvec(&m, 2, 2, &x, &mut out);
        assert_eq!(out, [3.0, 7.0]);
        matvec_t(&m, 2, 2, &x, &mut out);
        assert_eq!(out, [4.0, 6.0]);
    }

    #[test]
    fn simplex_projection_properties() {
        let mut x = [0.4f32, 0.3, -1.0, 2.0];
        project_simplex(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|&v| v >= 0.0));
        // Already-a-distribution is (nearly) fixed.
        let mut y = [0.25f32; 4];
        project_simplex(&mut y);
        for v in y {
            assert!((v - 0.25).abs() < 1e-5);
        }
    }

    #[test]
    fn dist_and_dot() {
        let a = [1.0f32, 2.0];
        let b = [4.0f32, 6.0];
        assert!((dist_sq(&a, &b) - 25.0).abs() < 1e-9);
        assert!((dot(&a, &b) - 16.0).abs() < 1e-9);
    }
}
