//! Hostile-network scenario suite: deterministic fault injection at the
//! [`qgenx::net::Transport`] seam, time-varying gossip schedules, and the
//! bounded-staleness semi-async local family (docs/SCENARIOS.md).
//!
//! Every scenario must terminate with either a structured error or a
//! converged run — never a deadlock or a panic — and the same seed must
//! reproduce the same outcome bit-for-bit:
//!
//! * slow link (seeded straggler delays): trajectory-neutral — delays cost
//!   wall-clock only, the bits and the gap series are untouched;
//! * dropped / truncated payload: every rank of the group decodes the
//!   identical mangled bytes in the identical round and fails in lockstep
//!   with a structured codec error;
//! * kill-at-round-k: the group poisons instead of hanging, on both the
//!   in-process barrier and the framed socket fabric;
//! * restart-from-shards: a coordinated checkpoint taken before an
//!   injected kill resumes on a fresh fabric and matches the fault-free
//!   run bit-for-bit;
//! * time-varying gossip: a rewiring edge schedule stays reproducible and
//!   converges, and the static default emits no rewire accounting at all;
//! * bounded staleness: modeled deadline misses substitute carried deltas
//!   deterministically; rate 0 is bit-identical to the synchronous family.

use qgenx::config::ExperimentConfig;
use qgenx::coordinator::{run_experiment, Checkpoint, Session};
use qgenx::metrics::Recorder;
use qgenx::net::{connect_group, AllGather, FaultPlan, FaultyTransport, SocketOpts, Transport};
use std::sync::Arc;
use std::thread;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 3;
    cfg.iters = 60;
    cfg.eval_every = 20;
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 12;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.3;
    cfg.quant.update_every = 30;
    cfg
}

/// Step until the session errors; returns (iteration, error message).
/// A session that finishes cleanly returns its "already completed" error,
/// which no fault assertion matches — so a fault that fails to fire shows
/// up as a loud assertion failure, not a false pass.
fn step_until_err(sess: &mut Session) -> (usize, String) {
    loop {
        if let Err(e) = sess.step() {
            return (sess.iteration(), e.to_string());
        }
    }
}

/// Drive one full K-thread run over the given shared transport; returns
/// every rank's recorder.
fn run_group(cfg: &ExperimentConfig, tr: &Arc<dyn Transport>) -> Vec<Recorder> {
    thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.workers)
            .map(|rank| {
                let cfg = cfg.clone();
                let tr = tr.clone();
                s.spawn(move || {
                    let mut sess = Session::builder(cfg.clone()).transport(tr, rank).build().unwrap();
                    sess.run_to(cfg.iters).unwrap();
                    sess.into_recorder()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn seeded_straggler_delays_are_trajectory_neutral() {
    let cfg = base_cfg();
    let reference = run_experiment(&cfg).unwrap();

    // ~20% of the first 40 (rank, round) cells stall for 2 ms each.
    let plan = FaultPlan::seeded_delays(0xC1A05, cfg.workers, 40, 0.2, 2);
    assert!(!plan.is_empty(), "the schedule must actually inject delays");
    let slow: Arc<dyn Transport> = FaultyTransport::wrap(AllGather::new(cfg.workers), plan);
    let recs = run_group(&cfg, &slow);

    // Delays cost wall-clock only: the gap trajectory and the exact wire
    // accounting match the fault-free loopback run bit-for-bit.
    assert_eq!(
        reference.get("gap").unwrap().ys(),
        recs[0].get("gap").unwrap().ys(),
        "stragglers must not change the trajectory"
    );
    assert_eq!(reference.scalar("rounds"), recs[0].scalar("rounds"));
}

#[test]
fn dropped_payload_fails_every_rank_in_lockstep_with_a_codec_error() {
    // fp32 mode: a dropped (zero-byte) payload is a structured length
    // mismatch on decode — the same error, at the same step, on every
    // rank, because the fault mangles the payload *before* the deposit.
    let mut cfg = base_cfg();
    cfg.quant.mode = qgenx::config::QuantMode::Fp32;
    for spec in ["drop@1:5", "trunc@1:5:3"] {
        let plan = FaultPlan::parse(spec).unwrap();
        let tr: Arc<dyn Transport> = FaultyTransport::wrap(AllGather::new(cfg.workers), plan);
        let outcomes: Vec<(usize, String)> = thread::scope(|s| {
            let handles: Vec<_> = (0..cfg.workers)
                .map(|rank| {
                    let cfg = cfg.clone();
                    let tr = tr.clone();
                    s.spawn(move || {
                        let mut sess =
                            Session::builder(cfg).transport(tr, rank).build().unwrap();
                        step_until_err(&mut sess)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (t, msg) in &outcomes {
            assert_eq!((*t, msg), (outcomes[0].0, &outcomes[0].1), "{spec}: lockstep failure");
            assert!(msg.contains("fp32 payload"), "{spec}: structured codec error, got: {msg}");
        }
        assert!(outcomes[0].0 < cfg.iters, "{spec}: the fault fired mid-run");
    }
}

#[test]
fn kill_at_round_k_poisons_the_group_on_the_inprocess_fabric() {
    let cfg = base_cfg();
    let plan = FaultPlan::parse("kill@2:7").unwrap();
    let tr: Arc<dyn Transport> = FaultyTransport::wrap(AllGather::new(cfg.workers), plan);
    let outcomes: Vec<(usize, String)> = thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.workers)
            .map(|rank| {
                let cfg = cfg.clone();
                let tr = tr.clone();
                s.spawn(move || {
                    let mut sess = Session::builder(cfg).transport(tr, rank).build().unwrap();
                    step_until_err(&mut sess)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, (_, msg)) in outcomes.iter().enumerate() {
        assert!(msg.contains("poisoned"), "rank {rank}: {msg}");
        assert!(msg.contains("killed at data round 7"), "rank {rank}: {msg}");
    }
    assert!(tr.is_poisoned());
}

#[test]
fn kill_at_round_k_poisons_the_group_on_the_socket_fabric() {
    // Same scenario over real framed sockets: each endpoint wears its own
    // decorator with the same plan (the multi-process shape `qgenx worker
    // --fault` uses). The killed rank poisons its endpoint, the ABORT
    // frame carries the reason to every blocked peer — nobody hangs.
    let cfg = base_cfg();
    let plan = FaultPlan::parse("kill@1:2").unwrap();
    let group = connect_group("127.0.0.1:0", cfg.workers, SocketOpts::default()).unwrap();
    let outcomes: Vec<(usize, String)> = thread::scope(|s| {
        let handles: Vec<_> = group
            .iter()
            .cloned()
            .enumerate()
            .map(|(rank, sock)| {
                let cfg = cfg.clone();
                let plan = plan.clone();
                s.spawn(move || {
                    let tr: Arc<dyn Transport> = FaultyTransport::wrap(sock, plan);
                    let mut sess = Session::builder(cfg).transport(tr, rank).build().unwrap();
                    step_until_err(&mut sess)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, (_, msg)) in outcomes.iter().enumerate() {
        assert!(msg.contains("poisoned"), "rank {rank}: {msg}");
        assert!(msg.contains("killed at data round 2"), "rank {rank}: {msg}");
    }
}

#[test]
fn restart_from_shards_after_an_injected_kill_matches_the_fault_free_run() {
    let cfg = base_cfg();
    let k = cfg.workers;
    let half = cfg.iters / 2;
    let reference = run_experiment(&cfg).unwrap();

    // Phase 1: a clean group runs to the halfway point and takes TWO
    // coordinated checkpoints at the same iteration (both barriers agree):
    // one shard set to feed the killed continuation, one to restart from.
    let clean = AllGather::new(k);
    let cps: Vec<(Checkpoint, Checkpoint)> = thread::scope(|s| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let cfg = cfg.clone();
                let tr = clean.clone();
                s.spawn(move || {
                    let mut sess = Session::builder(cfg).transport(tr, rank).build().unwrap();
                    sess.run_to(half).unwrap();
                    (sess.checkpoint().unwrap(), sess.checkpoint().unwrap())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(clean);
    let (cps_doomed, cps_fresh): (Vec<Checkpoint>, Vec<Checkpoint>) = cps.into_iter().unzip();

    // Phase 2: resume on a faulty fabric whose plan kills rank 1 three
    // data rounds in — every rank errors with the poison reason.
    let plan = FaultPlan::parse("kill@1:3").unwrap();
    let doomed: Arc<dyn Transport> = FaultyTransport::wrap(AllGather::new(k), plan);
    let msgs: Vec<String> = thread::scope(|s| {
        let handles: Vec<_> = cps_doomed
            .into_iter()
            .enumerate()
            .map(|(rank, cp)| {
                let tr = doomed.clone();
                s.spawn(move || {
                    let mut sess = Session::resume_with_transport(cp, tr, rank).unwrap();
                    step_until_err(&mut sess).1
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, msg) in msgs.iter().enumerate() {
        assert!(msg.contains("poisoned"), "rank {rank}: {msg}");
        assert!(msg.contains("killed at data round 3"), "rank {rank}: {msg}");
    }

    // Phase 3: the surviving shard set restarts on a fresh clean fabric
    // and finishes the run — bit-for-bit the fault-free trajectory.
    let fresh = AllGather::new(k);
    let recs: Vec<Recorder> = thread::scope(|s| {
        let handles: Vec<_> = cps_fresh
            .into_iter()
            .enumerate()
            .map(|(rank, cp)| {
                let tr = fresh.clone();
                let iters = cfg.iters;
                s.spawn(move || {
                    let mut sess = Session::resume_with_transport(cp, tr, rank).unwrap();
                    sess.run_to(iters).unwrap();
                    sess.into_recorder()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(
        reference.get("gap").unwrap().ys(),
        recs[0].get("gap").unwrap().ys(),
        "restart-from-shards must continue the fault-free trajectory bit-for-bit"
    );
    assert_eq!(reference.scalar("rounds"), recs[0].scalar("rounds"));
    assert_eq!(reference.scalar("level_updates"), recs[0].scalar("level_updates"));
}

#[test]
fn time_varying_gossip_is_reproducible_and_converges() {
    let mut cfg = base_cfg();
    cfg.workers = 12;
    cfg.iters = 150;
    cfg.eval_every = 50;
    cfg.topo.kind = "gossip".into();
    cfg.topo.degree = 4;
    cfg.topo.rewire_every = 5;

    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(
        a.get("gap").unwrap().ys(),
        b.get("gap").unwrap().ys(),
        "same seed, same rewire schedule, same trajectory"
    );
    assert_eq!(a.get("consensus_dist").unwrap().ys(), b.get("consensus_dist").unwrap().ys());
    assert_eq!(a.scalar("total_bits"), b.scalar("total_bits"));

    // 150 steps / 5-step epochs = 30 epochs → 29 edge-set advances, all
    // surfaced in the run summary.
    assert_eq!(a.scalar("rewires"), Some(29.0));

    // The run stays a run: finite gap that does not blow up under churn.
    let gaps = a.get("gap").unwrap().ys();
    assert!(gaps.iter().all(|g| g.is_finite()), "gap must stay finite under churn: {gaps:?}");
    let cons = a.get("consensus_dist").unwrap().ys();
    assert!(cons.iter().all(|c| c.is_finite()));

    // The static default emits no rewire accounting at all — fault-free
    // runs keep their scalar set (and frozen parity baselines) unchanged.
    cfg.topo.rewire_every = 0;
    let static_run = run_experiment(&cfg).unwrap();
    assert_eq!(static_run.scalar("rewires"), None);
    assert!(static_run.get("gap").unwrap().last().unwrap().is_finite());
}

#[test]
fn bounded_staleness_is_reproducible_and_counts_substitutions() {
    let mut cfg = base_cfg();
    cfg.iters = 120;
    cfg.eval_every = 40;
    cfg.local.steps = 4;
    cfg.local.staleness = 2;
    cfg.local.straggler_rate = 0.3;

    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(
        a.get("gap").unwrap().ys(),
        b.get("gap").unwrap().ys(),
        "modeled deadlines are seeded: same run, same substitutions, same trajectory"
    );
    assert_eq!(a.scalar("stale_syncs"), b.scalar("stale_syncs"));
    let stale = a.scalar("stale_syncs").expect("rate 0.3 over 30 syncs must substitute");
    assert!(stale > 0.0);
    // Substitutions change the resync means, so the semi-async trajectory
    // genuinely differs from the synchronous one — but the deadline is
    // modeled, not physical: every payload still moves exactly once, so
    // the round/sync structure is rate-invariant (encoded bit counts may
    // drift with the trajectory under the adaptive codec).
    let mut sync_cfg = cfg.clone();
    sync_cfg.local.straggler_rate = 0.0;
    let sync = run_experiment(&sync_cfg).unwrap();
    assert_ne!(a.get("gap").unwrap().ys(), sync.get("gap").unwrap().ys());
    assert_eq!(a.scalar("rounds"), sync.scalar("rounds"));
    assert_eq!(a.scalar("syncs"), sync.scalar("syncs"));

    // Rate 0 with a staleness cap configured is bit-identical to the plain
    // synchronous local family — the semi-async path is fully dormant.
    let mut plain = base_cfg();
    plain.iters = 120;
    plain.eval_every = 40;
    plain.local.steps = 4;
    let reference = run_experiment(&plain).unwrap();
    assert_eq!(reference.get("gap").unwrap().ys(), sync.get("gap").unwrap().ys());
    assert_eq!(reference.get("sync_drift").unwrap().ys(), sync.get("sync_drift").unwrap().ys());
    assert_eq!(reference.scalar("total_bits"), sync.scalar("total_bits"));
    assert_eq!(sync.scalar("stale_syncs"), None, "no substitutions, no scalar");
}
