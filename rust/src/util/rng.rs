//! Pseudo-random number generation.
//!
//! xoshiro256++ (Blackman & Vigna, 2019): 256-bit state, period 2^256−1,
//! passes BigCrush, and is trivially splittable into independent worker
//! streams via `jump()` or by re-seeding with SplitMix64 — which is what the
//! coordinator does to give each of the `K` workers a private stream, as the
//! paper's system model requires ("independent and private stochastic dual
//! vectors").

/// SplitMix64 step; used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
///
/// ```
/// use qgenx::util::Rng;
/// let mut r = Rng::seed_from(42);
/// let u = r.uniform();
/// assert!((0.0..1.0).contains(&u));
/// ```
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64 via SplitMix64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for worker `k` (distinct SplitMix64
    /// domain separation; streams for different `k` never collide in
    /// practice for the run lengths used here).
    pub fn fork(&self, k: u64) -> Self {
        // Mix the current state with the stream index through SplitMix64.
        let mut sm = self.s[0] ^ self.s[3].rotate_left(17) ^ (k.wrapping_mul(0xA24B_AED4_963E_E407));
        Rng::seed_from(splitmix64(&mut sm))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)` (used for quantization randomness; matches
    /// the precision of the uniforms fed to the Pallas kernel).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method with
    /// a single multiply; bias negligible for n << 2^64 but we still use the
    /// standard rejection loop for exactness).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (caches the spare variate).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let mut u = self.uniform();
        while u <= f64::EPSILON {
            u = self.uniform();
        }
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill a slice with i.i.d. N(0, sigma^2) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f64) {
        for x in out.iter_mut() {
            *x = (self.gaussian() * sigma) as f32;
        }
    }

    /// Fill a slice with i.i.d. U[0,1) f32 samples.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.uniform_f32();
        }
    }

    /// A fresh vector of i.i.d. N(0, sigma^2) samples.
    pub fn gaussian_vec(&mut self, d: usize, sigma: f64) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        self.fill_gaussian(&mut v, sigma);
        v
    }

    /// A fresh vector of i.i.d. U[0,1) samples.
    pub fn uniform_vec(&mut self, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        self.fill_uniform(&mut v);
        v
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Random index sampled from an unnormalized weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::seed_from(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::seed_from(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seed_from(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
