//! Quickstart: solve a stochastic bilinear saddle-point problem with
//! Q-GenX on 4 simulated workers with adaptive 4-bit quantization, and
//! compare the wire traffic against full precision.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use qgenx::config::{ExperimentConfig, QuantMode};
use qgenx::coordinator::run_experiment;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Configure straight from code; `ExperimentConfig::load("cfg.toml")`
    // does the same from a file.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.problem.kind = "bilinear".into();
    cfg.problem.dim = 128;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.5;
    cfg.workers = 4;
    cfg.iters = 2000;
    cfg.eval_every = 200;

    println!("Q-GenX on a {}-dim bilinear saddle, K = {} workers", cfg.problem.dim, cfg.workers);
    println!("== adaptive 4-bit quantization (UQ4 + QAda + Huffman) ==");
    let rec_q = run_experiment(&cfg)?;
    print_trajectory(&rec_q);

    println!("== full precision (FP32) ==");
    cfg.quant.mode = QuantMode::Fp32;
    let rec_f = run_experiment(&cfg)?;
    print_trajectory(&rec_f);

    let bits_q = rec_q.scalar("total_bits").unwrap();
    let bits_f = rec_f.scalar("total_bits").unwrap();
    let gap_q = rec_q.get("gap").unwrap().last().unwrap();
    let gap_f = rec_f.get("gap").unwrap().last().unwrap();
    println!("summary:");
    println!("  final gap     quantized {gap_q:.4}  vs fp32 {gap_f:.4}");
    println!(
        "  wire traffic  quantized {:.1} MiB vs fp32 {:.1} MiB  ({:.1}x saving)",
        bits_q / 8.0 / 1048576.0,
        bits_f / 8.0 / 1048576.0,
        bits_f / bits_q
    );
    Ok(())
}

fn print_trajectory(rec: &qgenx::metrics::Recorder) {
    let gaps = rec.get("gap").expect("gap series");
    println!("  iter        gap        gamma");
    let gammas = rec.get("gamma").unwrap();
    for ((x, g), (_, gm)) in gaps.points.iter().zip(gammas.points.iter()) {
        println!("  {x:>6.0}  {g:>10.5}  {gm:>10.5}");
    }
}
