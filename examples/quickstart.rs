//! Quickstart: solve a stochastic bilinear saddle-point problem with
//! Q-GenX on 4 simulated workers with adaptive 4-bit quantization, and
//! compare the wire traffic against full precision — through the
//! steppable [`Session`] API (`docs/API.md`).
//!
//! The quantized run streams its trajectory live through an [`Observer`];
//! the FP32 comparison run shows the one-shot `run()` form the benches
//! use. `Session::step()`/`run_to()`/`checkpoint()` give finer control —
//! see `examples/local_steps.rs` and the API docs.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Both runs honour the `QGENX_TELEMETRY` knob (read in
//! `SessionBuilder::build`): set it to `mem` for the in-memory ring or to
//! a path for a JSONL event stream — no code change needed. CI does
//! exactly that to validate the emitted schema. For the explicit
//! `TelemetryConfig`/`TelemetryObserver` API, see `examples/telemetry.rs`
//! and `docs/OBSERVABILITY.md`.

use qgenx::benchkit::example_iters;
use qgenx::config::{ExperimentConfig, QuantMode};
use qgenx::coordinator::{Control, Observer, Session, StepReport};

/// Streams each eval step as it happens (the post-hoc table this example
/// used to print, turned into a live feed).
struct Progress;

impl Observer for Progress {
    fn on_step(&mut self, r: &StepReport) -> Control {
        if r.evaluated {
            println!(
                "  {:>6}  {:>10.5}  {:>10.5}",
                r.t,
                r.gap.unwrap_or(f64::NAN),
                r.gamma
            );
        }
        Control::Continue
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Configure straight from code; `ExperimentConfig::load("cfg.toml")`
    // does the same from a file.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.problem.kind = "bilinear".into();
    cfg.problem.dim = 128;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.5;
    cfg.workers = 4;
    cfg.iters = example_iters(2000);
    cfg.eval_every = (cfg.iters / 10).max(1);

    println!("Q-GenX on a {}-dim bilinear saddle, K = {} workers", cfg.problem.dim, cfg.workers);
    println!("== adaptive 4-bit quantization (UQ4 + QAda + Huffman) ==");
    println!("  iter        gap        gamma");
    let rec_q = Session::builder(cfg.clone())
        .observer(Box::new(Progress))
        .build()?
        .run()?;

    println!("== full precision (FP32) ==");
    println!("  iter        gap        gamma");
    cfg.quant.mode = QuantMode::Fp32;
    let rec_f = Session::builder(cfg).observer(Box::new(Progress)).build()?.run()?;

    let bits_q = rec_q.scalar("total_bits").unwrap();
    let bits_f = rec_f.scalar("total_bits").unwrap();
    let gap_q = rec_q.get("gap").unwrap().last().unwrap();
    let gap_f = rec_f.get("gap").unwrap().last().unwrap();
    println!("summary:");
    println!("  final gap     quantized {gap_q:.4}  vs fp32 {gap_f:.4}");
    println!(
        "  wire traffic  quantized {:.1} MiB vs fp32 {:.1} MiB  ({:.1}x saving)",
        bits_q / 8.0 / 1048576.0,
        bits_f / 8.0 / 1048576.0,
        bits_f / bits_q
    );
    Ok(())
}
