//! The distributed coordinator — Algorithm 1 of the paper.
//!
//! Two execution modes share all of the math:
//!
//! * [`inline`] — single-threaded simulation of the `K` processors.
//!   Deterministic, allocation-light, used by the rate/figure benches where
//!   thousands of runs are swept.
//! * [`threaded`] — `K` real worker threads exchanging *actual encoded
//!   bytes* through the [`crate::net::AllGather`] transport, each holding a
//!   replicated [`crate::algo::QGenX`] state (data-parallel replication:
//!   identical decoded vectors ⇒ identical replicas). This is the system
//!   the examples and the E2E drivers run on.
//!
//! Per-iteration protocol (both modes), following Algorithm 1:
//!
//! 1. if `t ∈ U` (level-update schedule): workers exchange sufficient
//!    statistics (histograms, `4·bins` bytes — counted as traffic),
//!    pool them, and each deterministically re-optimizes the levels and
//!    rebuilds the Huffman codec (identical inputs ⇒ identical tables).
//! 2. variant-dependent base exchange (`V̂_{k,t}`): DE quantizes + exchanges
//!    fresh oracle queries at `X_t`; DA/OptDA send nothing.
//! 3. extrapolate to `X_{t+1/2}`.
//! 4. quantize + exchange `V̂_{k,t+1/2}`; everyone updates the replica.
//!
//! ## Topology selection
//!
//! Both modes route the *data-plane* exchanges (steps 2 and 4) through the
//! [`crate::topo::Collective`] built from the `[topo]` config table:
//!
//! * `full-mesh` (default) — the paper's flat allgather; byte- and
//!   cost-identical to the pre-topology coordinator.
//! * `star` / `ring` / `hierarchical` — **exact**: they deliver the same
//!   rank-order mean via in-network aggregation, so trajectories are
//!   bit-identical to full mesh while modeled time/traffic follow the
//!   per-topology α-β formulas in [`crate::topo::cost`].
//! * `gossip` — **inexact**: each worker averages over its closed graph
//!   neighborhood, replicas genuinely diverge (tracked as the
//!   `consensus_dist` series/scalar via
//!   [`crate::metrics::consensus_distance`]), and the threaded runner skips
//!   the replica-equality assertion.
//!
//! The *control plane* (step 1's stat pooling) is always global and
//! accounted as a full-mesh round, even under gossip: the decode side of
//! the wire format requires bit-identical levels + Huffman tables on every
//! worker, and the stat payloads are small and infrequent. Gossip
//! decentralizes the data plane only.
//!
//! Timing: compute (oracle + encode + decode) is *measured*; network time
//! is *modeled* (α-β on the exact encoded byte counts) — see DESIGN.md §5.4.

pub mod inline;
pub mod pipeline;
pub mod schedule;
pub mod threaded;

pub use inline::{run_experiment, run_qsgda_baseline};
pub use pipeline::Compressor;
pub use schedule::UpdateSchedule;
pub use threaded::run_threaded;
