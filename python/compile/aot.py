"""AOT lowering: JAX (L2, calling the L1 Pallas kernels) -> HLO text +
manifest.json, consumed by the Rust runtime (`rust/src/runtime/`).

HLO *text* is the interchange format, NOT `lowered.compiler_ir().serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Environment: QGENX_LM_PRESET=small|medium|large (default small).

`make artifacts` drives this and is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels.fused_extragrad import fused_extragrad
from .kernels.quantize import quantize

# Fixed shapes for the standalone kernel entries.
QUANT_D = 4096
QUANT_LEVELS = 16  # s = 14 interior levels (UQ4 alphabet)
FUSED_D = 4096


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _describe(specs) -> list:
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]


def build_entries(lm_cfg: model.LMConfig, gan_cfg: model.GanConfig):
    """Return {name: (fn, input_specs, output_specs)} for every artifact."""
    p_lm = model.lm_param_count(lm_cfg)
    pg, pd = model.gan_param_counts(gan_cfg)
    f32, i32 = jnp.float32, jnp.int32

    entries = {}

    # ---- LM ----
    lm_step = functools.partial(model.lm_step, cfg=lm_cfg)
    entries["lm_step"] = (
        lambda params, tokens: lm_step(params, tokens),
        [_spec((p_lm,), f32), _spec((lm_cfg.batch, lm_cfg.seq), i32)],
    )
    lm_loss = functools.partial(model.lm_loss, cfg=lm_cfg)
    entries["lm_loss"] = (
        lambda params, tokens: (lm_loss(params, tokens),),
        [_spec((p_lm,), f32), _spec((lm_cfg.batch, lm_cfg.seq), i32)],
    )

    # ---- GAN ----
    b, nz, dd = gan_cfg.batch, gan_cfg.nz, gan_cfg.data_dim
    entries["gan_disc_step"] = (
        lambda td, tg, real, z, eps: model.gan_disc_step(td, tg, real, z, eps, gan_cfg),
        [
            _spec((pd,), f32),
            _spec((pg,), f32),
            _spec((b, dd), f32),
            _spec((b, nz), f32),
            _spec((b, 1), f32),
        ],
    )
    entries["gan_gen_step"] = (
        lambda td, tg, z: model.gan_gen_step(td, tg, z, gan_cfg),
        [_spec((pd,), f32), _spec((pg,), f32), _spec((b, nz), f32)],
    )
    entries["gan_disc_w_step"] = (
        lambda td, tg, real, z: model.gan_disc_w_step(td, tg, real, z, gan_cfg),
        [_spec((pd,), f32), _spec((pg,), f32), _spec((b, dd), f32), _spec((b, nz), f32)],
    )
    entries["gan_pen_step"] = (
        lambda td, tg, real, z, eps: model.gan_pen_step(td, tg, real, z, eps, gan_cfg),
        [
            _spec((pd,), f32),
            _spec((pg,), f32),
            _spec((b, dd), f32),
            _spec((b, nz), f32),
            _spec((b, 1), f32),
        ],
    )
    entries["gan_sample"] = (
        lambda tg, z: (model.generator(tg, z, gan_cfg),),
        [_spec((pg,), f32), _spec((b, nz), f32)],
    )

    # ---- L1 kernels as standalone executables ----
    entries["quantize"] = (
        lambda v, levels, uniforms, norm: (quantize(v, levels, uniforms, norm),),
        [
            _spec((QUANT_D,), f32),
            _spec((QUANT_LEVELS,), f32),
            _spec((QUANT_D,), f32),
            _spec((1,), f32),
        ],
    )
    entries["fused_extragrad"] = (
        lambda x, y, vb, vh, gammas: fused_extragrad(x, y, vb, vh, gammas),
        [
            _spec((FUSED_D,), f32),
            _spec((FUSED_D,), f32),
            _spec((FUSED_D,), f32),
            _spec((FUSED_D,), f32),
            _spec((2,), f32),
        ],
    )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored; use --out-dir")
    ap.add_argument("--preset", default=os.environ.get("QGENX_LM_PRESET", "small"))
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    lm_cfg = model.LM_PRESETS[args.preset]
    gan_cfg = model.GanConfig()
    entries = build_entries(lm_cfg, gan_cfg)
    only = set(args.only.split(",")) if args.only else None

    manifest = {
        "lm": {
            "preset": args.preset,
            "params": model.lm_param_count(lm_cfg),
            **dataclasses.asdict(lm_cfg),
        },
        "gan": {
            "params_g": model.gan_param_counts(gan_cfg)[0],
            "params_d": model.gan_param_counts(gan_cfg)[1],
            **dataclasses.asdict(gan_cfg),
        },
        "quantize": {"d": QUANT_D, "levels": QUANT_LEVELS},
        "fused_extragrad": {"d": FUSED_D},
        "entries": {},
    }

    for name, (fn, in_specs) in entries.items():
        if only is not None and name not in only:
            continue
        print(f"lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.tree_util.tree_leaves(jax.eval_shape(fn, *in_specs))
        ]
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _describe(in_specs),
            "outputs": out_shapes,
        }
        print(f"  -> {path} ({len(text)} chars)")

    # Initial parameters as raw little-endian f32 blobs, so Rust needs no
    # numpy: params are just byte files.
    lm_params = model.lm_init(lm_cfg, seed=0)
    lm_params.tofile(os.path.join(out_dir, "lm_params_init.f32"))
    tg, td = model.gan_init(gan_cfg, seed=0)
    tg.tofile(os.path.join(out_dir, "gan_params_g_init.f32"))
    td.tofile(os.path.join(out_dir, "gan_params_d_init.f32"))
    manifest["inits"] = {
        "lm": "lm_params_init.f32",
        "gan_g": "gan_params_g_init.f32",
        "gan_d": "gan_params_d_init.f32",
    }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest -> {os.path.join(out_dir, 'manifest.json')}")

    # np import is used by model via lm_init; silence linters:
    _ = np


if __name__ == "__main__":
    main()
