//! Minimal in-house property-testing harness.
//!
//! The offline build image has no `proptest`, so this module provides the
//! subset we need: seeded value generators, a `forall` runner that executes
//! a property over many random cases, and on failure reports the seed and a
//! greedily-shrunk counterexample (for vector inputs, shrinking halves the
//! length and zeroes entries).
//!
//! ```
//! use qgenx::testkit::{forall, Gen};
//! forall("abs is non-negative", 100, |g| {
//!     let x = g.f32_in(-10.0, 10.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use crate::util::Rng;

/// Random value generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Case index (useful to make sizes grow over cases like proptest does).
    pub case: usize,
}

impl Gen {
    pub fn new(seed: u64, case: usize) -> Self {
        Gen { rng: Rng::seed_from(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)), case }
    }

    /// Direct access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    /// A vector of f32 drawn uniformly from [lo, hi], with occasional
    /// adversarial entries (exact zeros, +/- extremes) mixed in — the edge
    /// cases that matter for quantization (zero coordinates hit the `p_0`
    /// symbol; extremes hit the top level).
    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| {
                let r = self.rng.uniform();
                if r < 0.05 {
                    0.0
                } else if r < 0.08 {
                    hi
                } else if r < 0.11 {
                    lo
                } else {
                    self.f32_in(lo, hi)
                }
            })
            .collect()
    }

    /// Gaussian vector (the realistic distribution of gradient coordinates).
    pub fn gaussian_vec(&mut self, len: usize, sigma: f64) -> Vec<f32> {
        self.rng.gaussian_vec(len, sigma)
    }

    /// A sorted, strictly increasing level sequence in (0, 1) of length `s`,
    /// i.e. the interior levels of Definition 1.
    pub fn levels(&mut self, s: usize) -> Vec<f64> {
        let mut raw: Vec<f64> = (0..s).map(|_| self.rng.uniform() * 0.98 + 0.01).collect();
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Enforce strict monotonicity with a minimum gap.
        for i in 1..raw.len() {
            if raw[i] <= raw[i - 1] {
                raw[i] = (raw[i - 1] + 1e-4).min(0.999);
            }
        }
        raw
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Environment knob: `QGENX_PROPTEST_CASES` scales case counts (CI vs local).
fn case_multiplier() -> f64 {
    std::env::var("QGENX_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0)
}

/// Run `prop` over `cases` random cases. Panics (re-raising the property's
/// panic) with the failing seed/case so the failure is reproducible:
/// re-run with `QGENX_PROPTEST_SEED=<seed>` to replay a single case.
pub fn forall<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let seed = std::env::var("QGENX_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FF_EE00_D15E_A5E5);
    let cases = ((cases as f64) * case_multiplier()).ceil() as usize;
    for case in 0..cases.max(1) {
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, case);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}): {msg}\n\
                 replay: QGENX_PROPTEST_SEED={seed} and filter to case {case}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Assert a scalar is close.
#[track_caller]
pub fn assert_close(x: f64, y: f64, tol: f64) {
    assert!((x - y).abs() <= tol, "assert_close failed: {x} vs {y} (tol {tol})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("square non-negative", 50, |g| {
            let x = g.f64_in(-5.0, 5.0);
            assert!(x * x >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", 5, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn levels_are_strictly_increasing_in_unit_interval() {
        forall("levels sorted", 100, |g| {
            let s = g.usize_in(1, 32);
            let ls = g.levels(s);
            assert_eq!(ls.len(), s);
            for w in ls.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(ls[0] > 0.0 && *ls.last().unwrap() < 1.0);
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut a = Gen::new(1, 3);
        let mut b = Gen::new(1, 3);
        assert_eq!(a.f64_in(0.0, 1.0), b.f64_in(0.0, 1.0));
        assert_eq!(a.usize_in(0, 100), b.usize_in(0, 100));
    }

    #[test]
    fn f32_vec_hits_edge_cases_eventually() {
        let mut g = Gen::new(2, 0);
        let v = g.f32_vec(2000, -1.0, 1.0);
        assert!(v.iter().any(|&x| x == 0.0));
        assert!(v.iter().any(|&x| x == 1.0));
        assert!(v.iter().any(|&x| x == -1.0));
    }
}
