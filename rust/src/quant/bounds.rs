//! Theoretical bound calculators: Theorem 1 (variance), Theorem 2 (code
//! length), plus the QSGD / NUQSGD comparison bounds quoted in §4.
//!
//! These functions back two things: (i) the `thm1_variance_bound` and
//! `thm2_code_length` benches that regenerate the paper's comparisons, and
//! (ii) runtime assertions in the coordinator (`ε_Q` feeds the trade-off
//! analysis of Appendix I).

use super::levels::Levels;
use crate::coding::huffman::entropy_bits;

/// Theorem 1: variance factor `ε_Q` such that
/// `E‖Q_ℓ(v) − v‖² ≤ ε_Q ‖v‖²` under `L^q` normalization in dimension `d`:
///
/// ```text
/// ε_Q = (ℓ̄ + ℓ̄⁻¹)/4 − 1/2
///     + ¼ ℓ₁² d^{2/min(q,2)} · 1{d ≤ d_th}
///     + (ℓ₁ d^{1/min(q,2)} − 1) · 1{d ≥ d_th}
/// ```
///
/// with `ℓ̄ = max_j ℓ_{j+1}/ℓ_j` and `d_th = (2/ℓ₁)^{min(q,2)}`.
pub fn epsilon_q(levels: &Levels, d: usize, q: u32) -> f64 {
    let lbar = levels.max_ratio();
    let l1 = levels.l1();
    let qm = q.min(2) as f64;
    let d_f = d as f64;
    let d_th = levels.d_threshold(q);
    let mut eps = (lbar + 1.0 / lbar) / 4.0 - 0.5;
    if d_f <= d_th {
        eps += 0.25 * l1 * l1 * d_f.powf(2.0 / qm);
    }
    if d_f >= d_th {
        eps += l1 * d_f.powf(1.0 / qm) - 1.0;
    }
    // ε_Q is a variance factor; numerically guard against the small-d
    // regime where the closed form can dip below zero.
    eps.max(0.0)
}

/// QSGD (Alistarh et al. 2017, Thm 3.2) variance bound for `L²`
/// normalization with `s` uniform levels:
/// `ε = min(d/s², √d/s)`.
pub fn qsgd_variance_bound(d: usize, s: usize) -> f64 {
    let d = d as f64;
    let s = s as f64;
    (d / (s * s)).min(d.sqrt() / s)
}

/// NUQSGD (Ramezani-Kebrya et al. 2021, Thm 4) variance bound for `L²`
/// normalization with `s` exponential levels (large-d regime):
/// `ε = O(2^{-s} √d)`. We use the explicit dominant form
/// `2^{-s}√d + 2^{-2s}·d^{?}` truncated to its leading term plus the
/// constant level-ratio term (ℓ̄ = 2 ⇒ (2 + 1/2)/4 − 1/2 = 1/8).
pub fn nuqsgd_variance_bound(d: usize, s: usize) -> f64 {
    let d = d as f64;
    0.125 + 2f64.powi(-(s as i32)) * d.sqrt()
}

/// Theorem 2: bound on the expected number of bits to transmit
/// `CODE ∘ Q(Q_ℓ(g))` given symbol probabilities `probs = [p_0, …, p_{s+1}]`
/// (Proposition 2) in dimension `d`:
///
/// `E[bits] ≤ C_b + (1 − p_0) d + (H(L) + 1) d`
///
/// where `H(L) = −Σ_{j≥1} p_j log₂ p_j` is the entropy of the nonzero
/// symbols and `C_b` the float width for the norm (32 here). The `(1−p_0)d`
/// term is the expected count of sign bits (Lemma 3: only nonzeros carry a
/// sign).
pub fn code_length_bound(probs: &[f64], d: usize, norm_bits: u32, num_buckets: usize) -> f64 {
    assert!(!probs.is_empty());
    let p0 = probs[0];
    // Entropy over the *nonzero* symbols as in Appendix E (H(L) there is
    // computed on p_1..p_{s+1}; the zero symbol's own code contributes to
    // the symbol stream too, so we include the full-alphabet entropy as the
    // symbol cost and the (1 - p0) sign-bit cost separately).
    let h_all = entropy_bits(probs);
    (norm_bits as f64) * num_buckets as f64 + (1.0 - p0) * d as f64 + (h_all + 1.0) * d as f64
}

/// Expected bits/coordinate under fixed-width coding of the `s+2`-symbol
/// alphabet (the no-entropy-coding torch_cgx wire): `ceil(log2(s+2)) + 1`
/// sign bit for nonzeros.
pub fn fixed_width_bits(levels: &Levels, p0: f64) -> f64 {
    let w = (levels.alphabet_size() as f64).log2().ceil();
    w + (1.0 - p0)
}

/// Total expected bits for an `ε`-gap run (paper: `O(K d / ε)` matching the
/// Tsitsiklis–Luo lower bound): convenience for the Appendix I trade-off.
pub fn total_bits_to_eps(k: usize, d: usize, eps: f64) -> f64 {
    (k * d) as f64 / eps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn epsilon_q_decreases_with_more_levels() {
        // More uniform levels -> smaller l1, smaller dominant term.
        let d = 1 << 16;
        let e3 = epsilon_q(&Levels::uniform(3), d, 2);
        let e15 = epsilon_q(&Levels::uniform(15), d, 2);
        let e255 = epsilon_q(&Levels::uniform(255), d, 2);
        assert!(e3 > e15 && e15 > e255, "{e3} {e15} {e255}");
    }

    #[test]
    fn epsilon_q_large_d_is_order_l1_sqrt_d() {
        // L2, large d >> d_th: eps ~ l1 sqrt(d).
        let levels = Levels::uniform(15);
        let d = 1 << 20;
        let eps = epsilon_q(&levels, d, 2);
        let dominant = levels.l1() * (d as f64).sqrt();
        assert!(eps > 0.5 * dominant && eps < 2.0 * dominant, "eps={eps} dom={dominant}");
    }

    #[test]
    fn paper_claim_adaptive_beats_qsgd_bound_large_d() {
        // §4: for L2 large d, eps_Q = O(l1 sqrt(d)) is arbitrarily smaller
        // than O(sqrt(d)/s) when l1 << 1/s. Emulate adaptive levels with a
        // small l1.
        let d = 1 << 18;
        let s = 15usize;
        // Geometric levels from l1 = 1e-4 up to 1: moderate ratio lbar =
        // (1/l1)^{1/s} ~ 1.85, tiny l1 -> eps ~ lbar-term + l1*sqrt(d).
        let l1 = 1e-4f64;
        let ratio = (1.0 / l1).powf(1.0 / s as f64);
        let interior: Vec<f64> = (0..s).map(|j| l1 * ratio.powi(j as i32)).collect();
        let adaptive = Levels::new(interior).unwrap();
        let e_ada = epsilon_q(&adaptive, d, 2);
        let e_qsgd = qsgd_variance_bound(d, s);
        // eps_ada ~ 0.15 vs QSGD's sqrt(d)/s ~ 34.
        assert!(e_ada < 0.1 * e_qsgd, "e_ada={e_ada} e_qsgd={e_qsgd}");
    }

    #[test]
    fn qsgd_bound_matches_known_values() {
        // s = sqrt(d) -> bound = 1 (the QSGD sweet spot).
        let d = 1 << 16;
        let s = 1 << 8;
        assert!((qsgd_variance_bound(d, s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nuqsgd_bound_decays_exponentially() {
        let d = 1 << 16;
        let b4 = nuqsgd_variance_bound(d, 4);
        let b8 = nuqsgd_variance_bound(d, 8);
        assert!(b8 < b4);
        assert!(nuqsgd_variance_bound(d, 30) < 0.2);
    }

    #[test]
    fn code_length_bound_behaviour() {
        // Dense far-from-zero symbols: high entropy -> more bits.
        let spread = [0.05, 0.2, 0.25, 0.25, 0.25];
        let peaked = [0.9, 0.05, 0.03, 0.01, 0.01];
        let d = 1000;
        let b_spread = code_length_bound(&spread, d, 32, 1);
        let b_peaked = code_length_bound(&peaked, d, 32, 1);
        assert!(b_peaked < b_spread);
        // Upper bound is at most full fp32 for reasonable alphabets.
        assert!(b_peaked < 32.0 * d as f64);
    }

    #[test]
    fn fixed_width_bits_uq4() {
        // UQ4: s = 14 -> alphabet 16 -> 4 bits + sign for nonzeros.
        let levels = Levels::uniform(14);
        let bits = fixed_width_bits(&levels, 0.0);
        assert!((bits - 5.0).abs() < 1e-12);
    }

    #[test]
    fn prop_epsilon_nonnegative_and_monotone_in_lbar() {
        forall("eps_q sane", 100, |g| {
            let s = g.usize_in(1, 64);
            let levels = Levels::new(g.levels(s)).unwrap();
            let d = 1usize << g.usize_in(2, 22);
            let q = *g.choose(&[1u32, 2, 3, u32::MAX]);
            let e = epsilon_q(&levels, d, q);
            assert!(e.is_finite() && e >= 0.0, "eps={e}");
        });
    }

    #[test]
    fn total_bits_matches_lower_bound_shape() {
        // Halving eps doubles the bit budget; doubling K doubles it.
        let b = total_bits_to_eps(4, 1000, 0.1);
        assert!((total_bits_to_eps(4, 1000, 0.05) / b - 2.0).abs() < 1e-9);
        assert!((total_bits_to_eps(8, 1000, 0.1) / b - 2.0).abs() < 1e-9);
    }
}
