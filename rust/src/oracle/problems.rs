//! Concrete monotone operators.
//!
//! All problems are constructed *around a known solution* `x*` so that the
//! benches can report exact distances and gaps. Every operator here is
//! affine, `A(x) = M (x − x*)`, with the structure of `M` determining the
//! problem class:
//!
//! | operator | `M` | class |
//! |----------|-----|-------|
//! | [`BilinearSaddle`] | `[[0, B], [−Bᵀ, 0]]` | monotone, *not* co-coercive (skew) |
//! | [`MonotoneQuadratic`] | `SᵀS + μI` (sym. PSD) | strongly monotone, co-coercive |
//! | [`CocoerciveQuadratic`] | sym. PSD with known spectrum | co-coercive with known β = 1/λ_max |
//! | [`RotationOperator`] | block-diag `[[μ, λ],[−λ, μ]]` | monotone; the classic GDA-divergence example |
//! | [`MatrixGame`] | saddle of `min_x max_y xᵀCy` on simplices | monotone VI on a compact set |

use crate::error::{Error, Result};
use crate::util::{matvec, matvec_t, norm2, sub_into, Rng};

/// A (possibly set-valued-free) monotone operator `A : ℝ^d → ℝ^d`.
pub trait Operator: Send + Sync {
    fn dim(&self) -> usize;

    /// `out = A(x)`.
    fn apply(&self, x: &[f32], out: &mut [f32]);

    /// The known solution `x*` when available (all synthetic problems).
    fn solution(&self) -> Option<Vec<f32>> {
        None
    }

    /// Co-coercivity constant β (Assumption 4) when the operator has one.
    fn cocoercivity(&self) -> Option<f64> {
        None
    }

    /// Lipschitz constant of `A` when known (for fixed-step baselines).
    fn lipschitz(&self) -> Option<f64> {
        None
    }

    /// Operator residual `‖A(x)‖₂` — a cheap convergence surrogate.
    fn residual(&self, x: &[f32]) -> f64 {
        let mut out = vec![0.0f32; self.dim()];
        self.apply(x, &mut out);
        norm2(&out)
    }

    /// Project `x` onto the feasible set (identity for unconstrained).
    fn project(&self, _x: &mut [f32]) {}
}

/// `min_x max_y  (x−x*)ᵀ B (y−y*)` — the canonical convex-concave saddle;
/// `A(z) = (B(y−y*), −Bᵀ(x−x*))` is monotone (skew) but **not** co-coercive.
/// This is the structural surrogate for GAN training.
pub struct BilinearSaddle {
    /// B is (n, n) row-major; z = (x, y) each of dim n.
    b: Vec<f32>,
    n: usize,
    z_star: Vec<f32>,
    op_norm: f64,
}

impl BilinearSaddle {
    /// Random `B` with entries `N(0, scale²/n)` and random `z*`.
    pub fn random(dim: usize, scale: f64, rng: &mut Rng) -> Result<Self> {
        if dim < 2 || dim % 2 != 0 {
            return Err(Error::Oracle("bilinear needs even dim >= 2".into()));
        }
        let n = dim / 2;
        let b = rng.gaussian_vec(n * n, scale / (n as f64).sqrt());
        let z_star = rng.gaussian_vec(2 * n, 1.0);
        let op_norm = estimate_spectral_norm(&b, n, n, rng);
        Ok(BilinearSaddle { b, n, z_star, op_norm })
    }

    pub fn half_dim(&self) -> usize {
        self.n
    }
}

impl Operator for BilinearSaddle {
    fn dim(&self) -> usize {
        2 * self.n
    }

    fn apply(&self, z: &[f32], out: &mut [f32]) {
        let n = self.n;
        // shifted coordinates
        let dx: Vec<f32> = (0..n).map(|i| z[i] - self.z_star[i]).collect();
        let dy: Vec<f32> = (0..n).map(|i| z[n + i] - self.z_star[n + i]).collect();
        // A = (B dy, -B^T dx)
        matvec(&self.b, n, n, &dy, &mut out[..n]);
        let mut tmp = vec![0.0f32; n];
        matvec_t(&self.b, n, n, &dx, &mut tmp);
        for i in 0..n {
            out[n + i] = -tmp[i];
        }
    }

    fn solution(&self) -> Option<Vec<f32>> {
        Some(self.z_star.clone())
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.op_norm)
    }
}

/// `A(x) = M (x − x*)` with `M = SᵀS/d + μ I` — gradient of a strongly
/// convex quadratic: strongly monotone and co-coercive (β = 1/λ_max).
pub struct MonotoneQuadratic {
    m: Vec<f32>,
    d: usize,
    x_star: Vec<f32>,
    lambda_max: f64,
    mu: f64,
}

impl MonotoneQuadratic {
    pub fn random(d: usize, mu: f64, scale: f64, rng: &mut Rng) -> Result<Self> {
        if d == 0 {
            return Err(Error::Oracle("dim must be >= 1".into()));
        }
        // M = (1/d) S^T S * scale + mu I, S (d, d) gaussian.
        let s = rng.gaussian_vec(d * d, 1.0);
        let mut m = vec![0.0f32; d * d];
        for i in 0..d {
            for j in 0..=i {
                let mut acc = 0.0f64;
                for k in 0..d {
                    acc += (s[k * d + i] as f64) * (s[k * d + j] as f64);
                }
                let v = (acc * scale / d as f64) as f32;
                m[i * d + j] = v;
                m[j * d + i] = v;
            }
        }
        for i in 0..d {
            m[i * d + i] += mu as f32;
        }
        let x_star = rng.gaussian_vec(d, 1.0);
        let lambda_max = estimate_spectral_norm(&m, d, d, rng);
        Ok(MonotoneQuadratic { m, d, x_star, lambda_max, mu })
    }

    pub fn strong_monotonicity(&self) -> f64 {
        self.mu
    }
}

impl Operator for MonotoneQuadratic {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[f32], out: &mut [f32]) {
        let mut dx = vec![0.0f32; self.d];
        sub_into(x, &self.x_star, &mut dx);
        matvec(&self.m, self.d, self.d, &dx, out);
    }

    fn solution(&self) -> Option<Vec<f32>> {
        Some(self.x_star.clone())
    }

    fn cocoercivity(&self) -> Option<f64> {
        Some(1.0 / self.lambda_max)
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.lambda_max)
    }
}

/// Symmetric PSD operator with a *known* spectrum, diagonal in a random
/// orthogonal-ish basis. Used by the Theorem-4 bench where the co-coercivity
/// constant must be exact, not estimated.
pub struct CocoerciveQuadratic {
    /// eigenvalues λ_i ∈ [μ, L]
    eigs: Vec<f32>,
    /// Householder vector defining the basis Q = I − 2 w wᵀ.
    w: Vec<f32>,
    x_star: Vec<f32>,
    d: usize,
    l_max: f64,
}

impl CocoerciveQuadratic {
    pub fn random(d: usize, mu: f64, l_max: f64, rng: &mut Rng) -> Result<Self> {
        if d == 0 {
            return Err(Error::Oracle("dim must be >= 1".into()));
        }
        let eigs: Vec<f32> = (0..d)
            .map(|i| (mu + (l_max - mu) * (i as f64 / (d.max(2) - 1).max(1) as f64)) as f32)
            .collect();
        let mut w = rng.gaussian_vec(d, 1.0);
        let n = norm2(&w);
        for v in w.iter_mut() {
            *v = (*v as f64 / n) as f32;
        }
        let x_star = rng.gaussian_vec(d, 1.0);
        Ok(CocoerciveQuadratic { eigs, w, x_star, d, l_max })
    }

    /// `out = Q x` with `Q = I − 2wwᵀ` (orthogonal, symmetric).
    fn householder(&self, x: &[f32], out: &mut [f32]) {
        let dotp: f64 = x.iter().zip(self.w.iter()).map(|(a, b)| *a as f64 * *b as f64).sum();
        for i in 0..self.d {
            out[i] = x[i] - (2.0 * dotp * self.w[i] as f64) as f32;
        }
    }
}

impl Operator for CocoerciveQuadratic {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[f32], out: &mut [f32]) {
        // A = Q diag(eigs) Q (x - x*)
        let mut dx = vec![0.0f32; self.d];
        sub_into(x, &self.x_star, &mut dx);
        let mut t = vec![0.0f32; self.d];
        self.householder(&dx, &mut t);
        for i in 0..self.d {
            t[i] *= self.eigs[i];
        }
        self.householder(&t, out);
    }

    fn solution(&self) -> Option<Vec<f32>> {
        Some(self.x_star.clone())
    }

    fn cocoercivity(&self) -> Option<f64> {
        Some(1.0 / self.l_max)
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.l_max)
    }
}

/// Block-diagonal rotation-plus-shrink: each 2×2 block is
/// `[[μ, λ], [−λ, μ]]`. For `μ → 0` plain GDA diverges while EG converges —
/// the standard separator that motivates extra-gradient.
pub struct RotationOperator {
    mu: f32,
    lambda: f32,
    d: usize,
    x_star: Vec<f32>,
}

impl RotationOperator {
    pub fn new(d: usize, mu: f64, lambda: f64) -> Result<Self> {
        if d % 2 != 0 || d == 0 {
            return Err(Error::Oracle("rotation needs even dim".into()));
        }
        let mut rng = Rng::seed_from(0x0707);
        let x_star = rng.gaussian_vec(d, 1.0);
        Ok(RotationOperator { mu: mu as f32, lambda: lambda as f32, d, x_star })
    }
}

impl Operator for RotationOperator {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[f32], out: &mut [f32]) {
        for b in 0..self.d / 2 {
            let i = 2 * b;
            let dx = x[i] - self.x_star[i];
            let dy = x[i + 1] - self.x_star[i + 1];
            out[i] = self.mu * dx + self.lambda * dy;
            out[i + 1] = -self.lambda * dx + self.mu * dy;
        }
    }

    fn solution(&self) -> Option<Vec<f32>> {
        Some(self.x_star.clone())
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(((self.mu * self.mu + self.lambda * self.lambda) as f64).sqrt())
    }
}

/// Two-player zero-sum matrix game `min_{x∈Δ} max_{y∈Δ} xᵀ C y` as a VI on
/// the product of simplices: `A(x, y) = (C y, −Cᵀ x)` with simplex
/// projection. Compact domain; the gap has the exploitability closed form
/// `max_j (Cᵀx)_j − min_i (C y)_i`.
pub struct MatrixGame {
    c: Vec<f32>,
    n: usize,
}

impl MatrixGame {
    pub fn random(dim: usize, rng: &mut Rng) -> Result<Self> {
        if dim < 2 || dim % 2 != 0 {
            return Err(Error::Oracle("game needs even dim".into()));
        }
        let n = dim / 2;
        let c = rng.gaussian_vec(n * n, 1.0);
        Ok(MatrixGame { c, n })
    }

    /// Exploitability of a strategy profile (equals `Gap_Δ²` for games).
    pub fn exploitability(&self, z: &[f32]) -> f64 {
        let n = self.n;
        let (x, y) = z.split_at(n);
        let mut cy = vec![0.0f32; n];
        matvec(&self.c, n, n, y, &mut cy);
        let mut ctx = vec![0.0f32; n];
        matvec_t(&self.c, n, n, x, &mut ctx);
        let best_y = ctx.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let best_x = cy.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        // x^T C y sandwiched: exploitability = max_y' x^T C y' − min_x' x'^T C y
        best_y - best_x
    }

    /// Uniform strategies starting point.
    pub fn uniform_start(&self) -> Vec<f32> {
        vec![1.0 / self.n as f32; 2 * self.n]
    }
}

impl Operator for MatrixGame {
    fn dim(&self) -> usize {
        2 * self.n
    }

    fn apply(&self, z: &[f32], out: &mut [f32]) {
        let n = self.n;
        let (x, y) = z.split_at(n);
        matvec(&self.c, n, n, y, &mut out[..n]);
        let mut t = vec![0.0f32; n];
        matvec_t(&self.c, n, n, x, &mut t);
        for i in 0..n {
            out[n + i] = -t[i];
        }
    }

    fn project(&self, z: &mut [f32]) {
        let n = self.n;
        crate::util::project_simplex(&mut z[..n]);
        crate::util::project_simplex(&mut z[n..]);
    }

    fn lipschitz(&self) -> Option<f64> {
        // crude bound: max |C_ij| * n
        let m = self.c.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        Some((m as f64) * self.n as f64)
    }
}

/// Block-scaled diagonal quadratic: `A(x)_i = c_{B(i)} · λ_i · (x_i − x*_i)`
/// where coordinate `i` belongs to block `B(i)` with scale `c_b` and `λ_i`
/// sweeps `[0.5, 1.5]` deterministically within each block. Strongly
/// monotone and co-coercive, with *independent* blocks — so the per-block
/// dual-norm profile stays heterogeneous along the whole trajectory (under
/// relative noise it never washes out), exactly the structure layer-wise
/// quantization exploits.
///
/// The [`Self::lm_proxy`] and [`Self::gan_proxy`] constructors mimic the
/// layer-norm shape of the `train/` drivers' real workloads (a wide
/// low-norm embedding block vs. a narrow high-norm head; a cooler
/// generator vs. a hotter critic) so `benches/layerwise_tradeoff.rs` can
/// exercise the bit-budget allocator without AOT artifacts.
pub struct BlockScaledQuadratic {
    /// Per-coordinate coefficient `c_{B(i)} λ_i`.
    coeff: Vec<f32>,
    x_star: Vec<f32>,
    /// Interior block boundaries (fence posts without 0 and d) — mirror
    /// these into a `[quant.layers] bounds` to align layers with blocks.
    bounds: Vec<usize>,
    mu: f64,
    l_max: f64,
}

impl BlockScaledQuadratic {
    /// Build from `(width, scale)` blocks covering `d` coordinates.
    pub fn new(blocks: &[(usize, f64)], rng: &mut Rng) -> Result<Self> {
        if blocks.is_empty() || blocks.iter().any(|&(w, c)| w == 0 || !(c > 0.0)) {
            return Err(Error::Oracle("blocks need positive widths and scales".into()));
        }
        let d: usize = blocks.iter().map(|b| b.0).sum();
        let mut coeff = Vec::with_capacity(d);
        let mut bounds = Vec::with_capacity(blocks.len() - 1);
        for &(w, c) in blocks {
            for i in 0..w {
                // λ sweeps [0.5, 1.5] across the block.
                let lambda = 0.5 + i as f64 / (w.max(2) - 1).max(1) as f64;
                coeff.push((c * lambda) as f32);
            }
            bounds.push(coeff.len());
        }
        bounds.pop(); // last fence post is d itself
        let mu = blocks.iter().map(|b| b.1).fold(f64::INFINITY, f64::min) * 0.5;
        let l_max = blocks.iter().map(|b| b.1).fold(0.0f64, f64::max) * 1.5;
        let x_star = rng.gaussian_vec(d, 1.0);
        Ok(BlockScaledQuadratic { coeff, x_star, bounds, mu, l_max })
    }

    /// LM-shaped: 60% "embed" at scale 0.05, 30% "body" at 1.0, the rest
    /// "head" at 4.0 (wide-and-cold vs. narrow-and-hot).
    pub fn lm_proxy(d: usize, rng: &mut Rng) -> Result<Self> {
        if d < 16 {
            return Err(Error::Oracle("lm-proxy needs dim >= 16".into()));
        }
        let (w0, w1) = (d * 6 / 10, d * 3 / 10);
        Self::new(&[(w0, 0.05), (w1, 1.0), (d - w0 - w1, 4.0)], rng)
    }

    /// Interior block bounds of [`Self::lm_proxy`] for dimension `d`.
    pub fn lm_proxy_bounds(d: usize) -> Vec<usize> {
        vec![d * 6 / 10, d * 6 / 10 + d * 3 / 10]
    }

    /// GAN-shaped: a cooler generator half (0.25) and a hotter critic half
    /// (2.5) — the persistent player asymmetry of WGAN-GP duals.
    pub fn gan_proxy(d: usize, rng: &mut Rng) -> Result<Self> {
        if d < 4 || d % 2 != 0 {
            return Err(Error::Oracle("gan-proxy needs even dim >= 4".into()));
        }
        Self::new(&[(d / 2, 0.25), (d / 2, 2.5)], rng)
    }

    /// Interior block bounds of [`Self::gan_proxy`] for dimension `d`.
    pub fn gan_proxy_bounds(d: usize) -> Vec<usize> {
        vec![d / 2]
    }

    /// Interior block boundaries (for aligning a `LayerMap` with blocks).
    pub fn block_bounds(&self) -> &[usize] {
        &self.bounds
    }
}

impl Operator for BlockScaledQuadratic {
    fn dim(&self) -> usize {
        self.coeff.len()
    }

    fn apply(&self, x: &[f32], out: &mut [f32]) {
        for i in 0..self.coeff.len() {
            out[i] = self.coeff[i] * (x[i] - self.x_star[i]);
        }
    }

    fn solution(&self) -> Option<Vec<f32>> {
        Some(self.x_star.clone())
    }

    fn cocoercivity(&self) -> Option<f64> {
        Some(1.0 / self.l_max)
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.l_max)
    }
}

impl BlockScaledQuadratic {
    /// Strong-monotonicity constant (min coefficient).
    pub fn strong_monotonicity(&self) -> f64 {
        self.mu
    }
}

/// Power iteration estimate of `‖M‖₂` for an (r, c) row-major matrix
/// (applies `MᵀM`).
fn estimate_spectral_norm(m: &[f32], rows: usize, cols: usize, rng: &mut Rng) -> f64 {
    let mut v = rng.gaussian_vec(cols, 1.0);
    let mut mv = vec![0.0f32; rows];
    let mut mtmv = vec![0.0f32; cols];
    let mut sigma2 = 0.0f64;
    for _ in 0..50 {
        let n = norm2(&v);
        if n == 0.0 {
            return 0.0;
        }
        for x in v.iter_mut() {
            *x = (*x as f64 / n) as f32;
        }
        matvec(m, rows, cols, &v, &mut mv);
        matvec_t(m, rows, cols, &mv, &mut mtmv);
        sigma2 = norm2(&mtmv);
        v.copy_from_slice(&mtmv);
    }
    sigma2.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::util::{dist_sq, dot};

    fn check_monotone(op: &dyn Operator, rng: &mut Rng, trials: usize) {
        let d = op.dim();
        for _ in 0..trials {
            let x = rng.gaussian_vec(d, 2.0);
            let y = rng.gaussian_vec(d, 2.0);
            let mut ax = vec![0.0f32; d];
            let mut ay = vec![0.0f32; d];
            op.apply(&x, &mut ax);
            op.apply(&y, &mut ay);
            let diff_a: Vec<f32> = ax.iter().zip(ay.iter()).map(|(a, b)| a - b).collect();
            let diff_x: Vec<f32> = x.iter().zip(y.iter()).map(|(a, b)| a - b).collect();
            let inner = dot(&diff_a, &diff_x);
            assert!(inner >= -1e-3 * dist_sq(&x, &y).max(1.0), "monotonicity violated: {inner}");
        }
    }

    #[test]
    fn all_operators_are_monotone() {
        let mut rng = Rng::seed_from(1);
        let ops: Vec<Box<dyn Operator>> = vec![
            Box::new(BilinearSaddle::random(16, 1.0, &mut rng).unwrap()),
            Box::new(MonotoneQuadratic::random(12, 0.1, 1.0, &mut rng).unwrap()),
            Box::new(CocoerciveQuadratic::random(12, 0.1, 1.0, &mut rng).unwrap()),
            Box::new(RotationOperator::new(8, 0.05, 1.0).unwrap()),
            Box::new(MatrixGame::random(10, &mut rng).unwrap()),
            Box::new(BlockScaledQuadratic::lm_proxy(20, &mut rng).unwrap()),
            Box::new(BlockScaledQuadratic::gan_proxy(12, &mut rng).unwrap()),
        ];
        for op in &ops {
            check_monotone(op.as_ref(), &mut rng, 30);
        }
    }

    #[test]
    fn solutions_are_zeros_of_operator() {
        let mut rng = Rng::seed_from(2);
        let ops: Vec<Box<dyn Operator>> = vec![
            Box::new(BilinearSaddle::random(16, 1.0, &mut rng).unwrap()),
            Box::new(MonotoneQuadratic::random(12, 0.1, 1.0, &mut rng).unwrap()),
            Box::new(CocoerciveQuadratic::random(12, 0.1, 1.0, &mut rng).unwrap()),
            Box::new(RotationOperator::new(8, 0.05, 1.0).unwrap()),
            Box::new(BlockScaledQuadratic::lm_proxy(20, &mut rng).unwrap()),
        ];
        for op in &ops {
            let xs = op.solution().unwrap();
            assert!(op.residual(&xs) < 1e-4, "residual {}", op.residual(&xs));
        }
    }

    #[test]
    fn block_scaled_quadratic_is_genuinely_heterogeneous() {
        let mut rng = Rng::seed_from(8);
        let d = 1280;
        let op = BlockScaledQuadratic::lm_proxy(d, &mut rng).unwrap();
        assert_eq!(op.dim(), d);
        assert_eq!(op.block_bounds(), &BlockScaledQuadratic::lm_proxy_bounds(d)[..]);
        assert_eq!(op.block_bounds(), &[768, 1152]);
        // Per-block dual-norm profile at a generic point: head ≫ body ≫
        // embed per coordinate — the shape the allocator feeds on.
        let x = vec![0.0f32; d];
        let mut a = vec![0.0f32; d];
        op.apply(&x, &mut a);
        let rms = |s: &[f32]| {
            (s.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / s.len() as f64).sqrt()
        };
        let (e, rest) = a.split_at(768);
        let (b, h) = rest.split_at(384);
        assert!(rms(h) > 2.0 * rms(b), "head {} vs body {}", rms(h), rms(b));
        assert!(rms(b) > 4.0 * rms(e), "body {} vs embed {}", rms(b), rms(e));
        // Bounds and invariants.
        assert!((op.cocoercivity().unwrap() - 1.0 / 6.0).abs() < 1e-12);
        assert!(op.strong_monotonicity() > 0.0);
        let gp = BlockScaledQuadratic::gan_proxy(64, &mut rng).unwrap();
        assert_eq!(gp.block_bounds(), &[32]);
        assert!(BlockScaledQuadratic::gan_proxy(7, &mut rng).is_err());
        assert!(BlockScaledQuadratic::lm_proxy(8, &mut rng).is_err());
        assert!(BlockScaledQuadratic::new(&[(0, 1.0)], &mut rng).is_err());
        assert!(BlockScaledQuadratic::new(&[(4, 0.0)], &mut rng).is_err());
    }

    #[test]
    fn bilinear_is_skew_around_solution() {
        // <A(z), z - z*> = 0 for skew operators.
        let mut rng = Rng::seed_from(3);
        let op = BilinearSaddle::random(16, 1.0, &mut rng).unwrap();
        let zs = op.solution().unwrap();
        for _ in 0..20 {
            let z = rng.gaussian_vec(16, 1.0);
            let mut az = vec![0.0f32; 16];
            op.apply(&z, &mut az);
            let dz: Vec<f32> = z.iter().zip(zs.iter()).map(|(a, b)| a - b).collect();
            assert!(dot(&az, &dz).abs() < 1e-3);
        }
    }

    #[test]
    fn cocoercive_satisfies_assumption4() {
        // <A(x)-A(y), x-y> >= beta ||A(x)-A(y)||^2
        let mut rng = Rng::seed_from(4);
        let op = CocoerciveQuadratic::random(16, 0.2, 2.0, &mut rng).unwrap();
        let beta = op.cocoercivity().unwrap();
        forall("cocoercivity", 50, |g| {
            let x = g.gaussian_vec(16, 2.0);
            let y = g.gaussian_vec(16, 2.0);
            let mut ax = vec![0.0f32; 16];
            let mut ay = vec![0.0f32; 16];
            op.apply(&x, &mut ax);
            op.apply(&y, &mut ay);
            let da: Vec<f32> = ax.iter().zip(ay.iter()).map(|(a, b)| a - b).collect();
            let dx: Vec<f32> = x.iter().zip(y.iter()).map(|(a, b)| a - b).collect();
            let lhs = dot(&da, &dx);
            let rhs = beta * crate::util::norm2_sq(&da);
            assert!(lhs >= rhs - 1e-3, "lhs={lhs} rhs={rhs}");
        });
    }

    #[test]
    fn quadratic_lipschitz_estimate_is_upper_bound() {
        let mut rng = Rng::seed_from(5);
        let op = MonotoneQuadratic::random(16, 0.1, 1.0, &mut rng).unwrap();
        let l = op.lipschitz().unwrap();
        for _ in 0..30 {
            let x = rng.gaussian_vec(16, 1.0);
            let y = rng.gaussian_vec(16, 1.0);
            let mut ax = vec![0.0f32; 16];
            let mut ay = vec![0.0f32; 16];
            op.apply(&x, &mut ax);
            op.apply(&y, &mut ay);
            let num = dist_sq(&ax, &ay).sqrt();
            let den = dist_sq(&x, &y).sqrt();
            if den > 1e-9 {
                assert!(num / den <= l * 1.05, "ratio {} > L {}", num / den, l);
            }
        }
    }

    #[test]
    fn rotation_blocks_rotate() {
        let op = RotationOperator::new(4, 0.0, 1.0).unwrap();
        let xs = op.solution().unwrap();
        // A at x* + e1 should be (0*1, -1*1) pattern per block: (mu*dx+l*dy, -l*dx+mu*dy)
        let mut x = xs.clone();
        x[0] += 1.0;
        let mut a = vec![0.0f32; 4];
        op.apply(&x, &mut a);
        assert!((a[0] - 0.0).abs() < 1e-6);
        assert!((a[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn game_projection_and_exploitability() {
        let mut rng = Rng::seed_from(6);
        let game = MatrixGame::random(8, &mut rng).unwrap();
        let mut z = game.uniform_start();
        game.project(&mut z);
        let e0 = game.exploitability(&z);
        assert!(e0 >= -1e-6);
        // Exploitability decreases after a few projected EG steps.
        let d = game.dim();
        let gamma = 0.1f32 / game.lipschitz().unwrap() as f32;
        for _ in 0..200 {
            let mut a = vec![0.0f32; d];
            game.apply(&z, &mut a);
            let mut zh = z.clone();
            for i in 0..d {
                zh[i] -= gamma * a[i];
            }
            game.project(&mut zh);
            let mut ah = vec![0.0f32; d];
            game.apply(&zh, &mut ah);
            for i in 0..d {
                z[i] -= gamma * ah[i];
            }
            game.project(&mut z);
        }
        let e1 = game.exploitability(&z);
        assert!(e1 < e0 * 0.5, "exploitability did not drop: {e0} -> {e1}");
    }

    #[test]
    fn invalid_dims_rejected() {
        let mut rng = Rng::seed_from(7);
        assert!(BilinearSaddle::random(7, 1.0, &mut rng).is_err());
        assert!(RotationOperator::new(5, 0.1, 1.0).is_err());
        assert!(MatrixGame::random(3, &mut rng).is_err());
    }
}
