//! Per-worker compression pipeline: `Q_ℓ` → `CODE` on send,
//! `DEQ ∘ CODE` on receive, plus the QAda state machine (sufficient
//! statistics, level re-optimization, codec rebuild).
//!
//! One [`Compressor`] instance lives on each worker. Level updates must be
//! driven identically on every worker (the coordinator exchanges pooled
//! statistics first) so that all replicas hold the same levels/codec — the
//! decode side of the wire format depends on them.
//!
//! Four pipeline shapes, selected by the config:
//!
//! * **FP32** — raw little-endian f32 payloads, no state.
//! * **Single-codec** (the seed pipeline) — one level sequence + codec for
//!   the whole vector; v2 stat payloads. A one-layer `[quant.layers]` map
//!   resolves to this same machinery (bit-identical by construction).
//! * **Layer-wise** (Q-GenX-LW, `[quant.layers]` with ≥ 2 names) — the
//!   vector is partitioned by a [`crate::quant::LayerMap`]; each layer
//!   carries its own levels/codec/statistics and its wire payload is the
//!   per-layer `CODE ∘ Q` stream behind a `u32` length frame. Stat rounds
//!   move the v3 per-layer payload ([`crate::quant::LayerStats`], see
//!   `docs/WIRE.md`), and — when `[quant.layers] budget` is set — every
//!   level update re-runs the Theorem-1 bit-budget allocator
//!   ([`crate::quant::alloc`]) on the pooled per-layer weights before
//!   re-optimizing levels, so bits follow the norm profile as it drifts.
//! * **Contractive** (`[quant.ef]`) — the biased δ-contractive family
//!   ([`crate::quant::contractive`]: top-k / rand-k / rank-r) with the
//!   per-worker error-feedback memory `e_{t+1} = e_t + g_t − C(e_t + g_t)`.
//!   Entirely static: nothing adapts, stat rounds stay at zero, and the
//!   wire carries sparse/low-rank frames (`docs/WIRE.md` §5) instead of
//!   `CODE ∘ Q` streams.

use crate::coding::SymbolCodec;
use crate::config::{EfConfig, LayersConfig, LevelScheme, QuantConfig, QuantMode};
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::quant::{
    alloc, contractive, decode_vector, decode_vector_into, dequantize_into, encode_vector_into,
    optimize_levels, quantize_into, symbol_probs, ContractiveOp, LayerMap, LayerProfile,
    LayerStats, Levels, QuantizedVector, SufficientStats, WireCodec,
};
use crate::telemetry::{Stage, StageSpans};
use crate::util::Rng;
use std::time::Instant;

/// A worker's (de)compression endpoint.
#[derive(Clone)]
pub enum Compressor {
    /// Full precision: raw little-endian f32 payloads (32 bits/coordinate).
    Fp32,
    /// Quantize + entropy-code per the paper.
    Quant(Box<QuantCompressor>),
    /// Layer-wise heterogeneous quantization (Q-GenX-LW).
    LayerWise(Box<LayerWiseCompressor>),
    /// Biased δ-contractive compression with error feedback (`[quant.ef]`).
    Contractive(Box<ContractiveCompressor>),
}

#[derive(Clone)]
pub struct QuantCompressor {
    cfg: QuantConfig,
    levels: Levels,
    codec: WireCodec,
    rng: Rng,
    /// Local sufficient statistics for the *next* level update.
    stats: SufficientStats,
    /// Number of level updates performed (J counter).
    updates: usize,
    /// §Perf scratch arenas, reused across messages. Not semantic state:
    /// contents are overwritten per message and never consulted across
    /// calls (a cloned compressor drags them along harmlessly).
    scratch: Scratch,
}

/// Reusable per-endpoint buffers for the zero-allocation hot path: one
/// [`QuantizedVector`] arena each for the encode and decode directions
/// (decode has its own so a compress between two decompresses cannot
/// clobber state mid-use).
#[derive(Clone, Default)]
struct Scratch {
    enc: QuantizedVector,
    dec: QuantizedVector,
}

impl QuantCompressor {
    /// Feed the sufficient statistic (the caller gates on "does this
    /// pipeline adapt"). `stat_samples` caps how many vectors (buckets,
    /// under bucketing) feed the statistic per schedule segment, so stat
    /// upkeep stays O(cap) as `d` and the segment length grow; 0 =
    /// unlimited.
    fn observe_for_stats(&mut self, v: &[f32]) {
        let cap = self.cfg.stat_samples;
        if cap == 0 {
            self.stats.observe_bucketed(v, self.cfg.bucket_size);
        } else if self.stats.vectors_seen() < cap {
            let b = if self.cfg.bucket_size == 0 { v.len() } else { self.cfg.bucket_size };
            let room = cap - self.stats.vectors_seen();
            let take = room.saturating_mul(b).min(v.len());
            self.stats.observe_bucketed(&v[..take], self.cfg.bucket_size);
        }
    }

    /// `CODE ∘ Q` one vector (or one layer slice) with this state,
    /// *appending* the wire bytes to `out`. Quantizes into the encode
    /// arena and emits word-at-a-time — zero allocations in steady state.
    ///
    /// `spans` is the telemetry quantize/encode span split: identical wire
    /// bytes and RNG stream either way; the `Instant` reads only happen
    /// when a span accumulator is handed in.
    fn compress_vec_timed(
        &mut self,
        v: &[f32],
        out: &mut Vec<u8>,
        spans: Option<&mut StageSpans>,
    ) -> Result<u64> {
        let spans = match spans {
            Some(s) => s,
            None => {
                quantize_into(
                    v,
                    &self.levels,
                    self.cfg.norm_q,
                    self.cfg.bucket_size,
                    &mut self.rng,
                    &mut self.scratch.enc,
                )?;
                return encode_vector_into(&self.scratch.enc, &self.codec, out);
            }
        };
        let t0 = Instant::now();
        quantize_into(
            v,
            &self.levels,
            self.cfg.norm_q,
            self.cfg.bucket_size,
            &mut self.rng,
            &mut self.scratch.enc,
        )?;
        let t1 = Instant::now();
        spans.add(Stage::Quantize, (t1 - t0).as_secs_f64());
        let bits = encode_vector_into(&self.scratch.enc, &self.codec, out)?;
        spans.add(Stage::Encode, t1.elapsed().as_secs_f64());
        Ok(bits)
    }

    /// `DEQ ∘ CODE` one payload through the decode arena into `out`.
    fn decompress_into(&mut self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        decode_vector_into(
            bytes,
            out.len(),
            self.cfg.bucket_size,
            &self.codec,
            &mut self.scratch.dec,
        )?;
        dequantize_into(&self.scratch.dec, &self.levels, out);
        Ok(())
    }
}

impl Compressor {
    /// Build from config; `rng` seeds the quantization randomness (private
    /// per worker). A `[quant.layers]` table with ≥ 2 names selects the
    /// layer-wise pipeline; one name merges its override and runs the
    /// ordinary single-codec pipeline — bit-identical to no layer map.
    pub fn from_config(cfg: &QuantConfig, rng: Rng) -> Result<Self> {
        cfg.layers.validate(cfg)?;
        if cfg.ef.enabled() {
            // Contractive modes replace the unbiased stack wholesale and
            // must never engage the stat machinery ([`QuantConfig::adapts`]
            // is the single source of truth; re-asserted here).
            debug_assert!(!cfg.adapts(), "contractive pipelines are non-adaptive");
            return Ok(Compressor::Contractive(Box::new(ContractiveCompressor::from_config(
                cfg, rng,
            ))));
        }
        if cfg.layers.enabled() && cfg.mode != QuantMode::Fp32 {
            return LayerWiseCompressor::from_config(cfg, rng)
                .map(|lw| Compressor::LayerWise(Box::new(lw)));
        }
        // ≤ 1 layer: flatten the (possible) single override and run the
        // seed pipeline with the caller's rng untouched — the passthrough
        // that makes a one-layer map reproduce trajectories bit-for-bit.
        let flat = if cfg.layers.names.len() == 1 {
            cfg.layers.override_for(0).apply(cfg)
        } else {
            cfg.clone()
        };
        match flat.mode {
            QuantMode::Fp32 => Ok(Compressor::Fp32),
            QuantMode::Quantized { levels: s } => {
                let levels = initial_levels(flat.scheme, s);
                let codec = build_codec(&levels, flat.codec, None)?;
                Ok(Compressor::Quant(Box::new(QuantCompressor {
                    stats: SufficientStats::new(flat.hist_bins, flat.norm_q),
                    cfg: flat,
                    levels,
                    codec,
                    rng,
                    updates: 0,
                    scratch: Scratch::default(),
                })))
            }
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Compressor::Quant(_) | Compressor::LayerWise(_))
    }

    pub fn is_layerwise(&self) -> bool {
        matches!(self, Compressor::LayerWise(_))
    }

    /// True when the biased error-feedback pipeline is engaged.
    pub fn is_contractive(&self) -> bool {
        matches!(self, Compressor::Contractive(_))
    }

    /// Current levels (None for FP32 and for the layer-wise pipeline,
    /// which has one sequence *per layer* — see [`Self::layer_levels`]).
    pub fn levels(&self) -> Option<&Levels> {
        match self {
            Compressor::Fp32 | Compressor::LayerWise(_) | Compressor::Contractive(_) => None,
            Compressor::Quant(q) => Some(&q.levels),
        }
    }

    /// Layer `i`'s current level sequence (layer-wise pipelines only).
    pub fn layer_levels(&self, i: usize) -> Option<&Levels> {
        match self {
            Compressor::LayerWise(lw) => lw.subs.get(i).map(|s| &s.levels),
            _ => None,
        }
    }

    /// Theorem-1 variance factor of the current configuration. For the
    /// layer-wise pipeline this is the dimension-weighted mean of the
    /// per-layer factors (each at its own bucket size and level count).
    pub fn epsilon_q(&self, d: usize) -> f64 {
        match self {
            Compressor::Fp32 => 0.0,
            // Biased compression has no Theorem-1 unbiased variance factor;
            // its contraction is surfaced via [`Self::ef_scalars`] instead.
            Compressor::Contractive(_) => 0.0,
            Compressor::Quant(q) => {
                let per_bucket = if q.cfg.bucket_size == 0 { d } else { q.cfg.bucket_size.min(d) };
                crate::quant::epsilon_q(&q.levels, per_bucket, q.cfg.norm_q)
            }
            Compressor::LayerWise(lw) => lw
                .with_map(d, |map| {
                    Ok((0..map.len())
                        .map(|i| map.dim(i) as f64 / d as f64 * lw.layer_epsilon(i, map.dim(i)))
                        .sum())
                })
                .unwrap_or(f64::NAN),
        }
    }

    /// Compress a dual vector; returns (wire bytes, exact payload bits).
    /// Also feeds the local sufficient statistics (QAda observes the *raw*
    /// vector, pre-quantization). Allocating convenience wrapper around
    /// [`Self::compress_into`] — hot paths hand in a reusable buffer.
    pub fn compress(&mut self, v: &[f32]) -> Result<(Vec<u8>, u64)> {
        let mut bytes = Vec::new();
        let bits = self.compress_into(v, &mut bytes)?;
        Ok((bytes, bits))
    }

    /// [`Self::compress`] into a caller-owned buffer (cleared first):
    /// identical wire bytes and RNG stream, zero allocations per message
    /// once the scratch arenas and `out` reach steady-state size.
    pub fn compress_into(&mut self, v: &[f32], out: &mut Vec<u8>) -> Result<u64> {
        self.compress_timed(v, out, None)
    }

    /// [`Self::compress_into`] with the telemetry quantize/encode span
    /// split (see [`crate::telemetry::Stage`]). `spans: None` is the exact
    /// untimed hot path — no `Instant` reads at all; `Some` accumulates
    /// `quantize` and `encode` seconds (FP32 serialization counts as
    /// `encode`). Wire bytes and RNG stream are identical either way —
    /// the telemetry neutrality contract.
    pub fn compress_timed(
        &mut self,
        v: &[f32],
        out: &mut Vec<u8>,
        mut spans: Option<&mut StageSpans>,
    ) -> Result<u64> {
        out.clear();
        match self {
            Compressor::Fp32 => {
                let t0 = spans.is_some().then(Instant::now);
                crate::net::put_f32s(out, v);
                if let (Some(s), Some(t0)) = (spans.as_deref_mut(), t0) {
                    s.add(Stage::Encode, t0.elapsed().as_secs_f64());
                }
                Ok(32 * v.len() as u64)
            }
            Compressor::Quant(q) => {
                // Sufficient statistics feed (a) QAda level optimization and
                // (b) Huffman probability refreshes — needed even when the
                // level placement itself is fixed.
                if q.cfg.adapts() {
                    q.observe_for_stats(v);
                }
                q.compress_vec_timed(v, out, spans)
            }
            Compressor::LayerWise(lw) => lw.compress_timed(v, out, spans),
            Compressor::Contractive(ct) => ct.compress_timed(v, out, spans),
        }
    }

    /// Decompress a peer's wire bytes into `out` (length = d). Allocating
    /// (`&self`) convenience path — the engine uses
    /// [`Self::decompress_into`], which reuses the decode arena.
    pub fn decompress(&self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        match self {
            Compressor::Fp32 => Self::decompress_fp32(bytes, out),
            Compressor::Quant(q) => {
                let qv = decode_vector(bytes, out.len(), q.cfg.bucket_size, &q.codec)?;
                dequantize_into(&qv, &q.levels, out);
                Ok(())
            }
            Compressor::LayerWise(lw) => lw.decompress(bytes, out),
            Compressor::Contractive(ct) => ct.decompress(bytes, out),
        }
    }

    /// [`Self::decompress`] through the reusable decode arena: identical
    /// results, zero allocations per message in steady state.
    pub fn decompress_into(&mut self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        match self {
            Compressor::Fp32 => Self::decompress_fp32(bytes, out),
            Compressor::Quant(q) => q.decompress_into(bytes, out),
            Compressor::LayerWise(lw) => lw.decompress_into(bytes, out),
            Compressor::Contractive(ct) => ct.decompress_into(bytes, out),
        }
    }

    fn decompress_fp32(bytes: &[u8], out: &mut [f32]) -> Result<()> {
        crate::net::get_f32s_into(bytes, out)
    }

    /// Serialize local sufficient statistics for the stat exchange.
    ///
    /// Non-empty whenever *anything* adapts on the update schedule: QAda
    /// level placement (`scheme == Adaptive`) **or** the Huffman
    /// probability model (`codec == Huffman`, any level scheme) — the same
    /// condition under which [`Self::update_levels`] consumes the pooled
    /// payloads (both sides share [`QuantConfig::adapts`]). Gating on the
    /// scheme alone made Huffman-with-fixed-levels runs pay for stat
    /// rounds whose payloads were all empty, so the advertised probability
    /// refresh silently never happened.
    /// Empty for FP32 and for fully static pipelines. Single-codec
    /// pipelines ship the v2 payload; layer-wise pipelines ship the
    /// per-layer v3 payload (`docs/WIRE.md`).
    pub fn stats_payload(&self) -> Vec<u8> {
        match self {
            Compressor::Quant(q) if q.cfg.adapts() => q.stats.to_bytes(),
            Compressor::LayerWise(lw) if lw.adapts => {
                LayerStats::payload_from(&lw.subs.iter().map(|s| &s.stats).collect::<Vec<_>>())
            }
            _ => Vec::new(),
        }
    }

    /// Perform the level update from the *rank-ordered list of all workers'
    /// serialized statistics* (including this worker's own payload).
    ///
    /// Pooling exclusively from the serialized (f32-rounded) payloads in a
    /// fixed order — never from the in-memory f64 accumulator — guarantees
    /// every replica optimizes from bit-identical inputs and therefore
    /// lands on bit-identical levels and Huffman tables. Returns true if
    /// levels actually changed.
    pub fn update_levels(&mut self, all_stats_rank_order: &[&[u8]]) -> Result<bool> {
        let q = match self {
            Compressor::Fp32 | Compressor::Contractive(_) => return Ok(false),
            Compressor::LayerWise(lw) => return lw.update_levels(all_stats_rank_order),
            Compressor::Quant(q) => q,
        };
        if !q.cfg.adapts() {
            return Ok(false);
        }
        let adapt_levels = q.cfg.scheme == LevelScheme::Adaptive;
        let mut pooled = SufficientStats::new(q.cfg.hist_bins, q.cfg.norm_q);
        for p in all_stats_rank_order {
            if !p.is_empty() {
                pooled.absorb_bytes(p)?;
            }
        }
        if pooled.is_empty() {
            return Ok(false);
        }
        let new_levels = if adapt_levels {
            optimize_levels(&pooled, q.levels.s(), Some(&q.levels), 8)?
        } else {
            q.levels.clone()
        };
        let probs = symbol_probs(&pooled, &new_levels);
        q.codec = build_codec(&new_levels, q.cfg.codec, Some(&probs))?;
        let changed = new_levels != q.levels;
        q.levels = new_levels;
        q.stats.reset();
        q.updates += 1;
        Ok(changed)
    }

    /// Number of level updates performed so far (the `J` of Theorems 3/4).
    pub fn updates(&self) -> usize {
        match self {
            Compressor::Fp32 | Compressor::Contractive(_) => 0,
            Compressor::Quant(q) => q.updates,
            Compressor::LayerWise(lw) => lw.updates,
        }
    }

    /// Layer names, in coordinate order (layer-wise pipelines only).
    pub fn layer_names(&self) -> Option<&[String]> {
        match self {
            Compressor::LayerWise(lw) => Some(&lw.layers_cfg.names),
            _ => None,
        }
    }

    /// Cumulative encoded payload bits per layer (framing excluded) —
    /// the `layer_bits` metric source.
    pub fn layer_wire_bits(&self) -> Option<&[u64]> {
        match self {
            Compressor::LayerWise(lw) => Some(&lw.layer_bits),
            _ => None,
        }
    }

    /// Push the per-layer metric series (`layer_bits/<name>` cumulative
    /// payload bits, `layer_variance/<name>` current Theorem-1 factor) at
    /// eval step `t`. No-op for non-layer-wise pipelines, so every runner
    /// can call it unconditionally.
    pub fn record_layer_series(&self, rec: &mut Recorder, t: f64) {
        let Compressor::LayerWise(lw) = self else { return };
        for (i, name) in lw.layers_cfg.names.iter().enumerate() {
            rec.push(&format!("layer_bits/{name}"), t, lw.layer_bits[i] as f64);
            rec.push(&format!("layer_variance/{name}"), t, lw.layer_epsilon_auto(i));
        }
    }

    /// Emit the per-layer summary scalars (`layer_bits/<name>`,
    /// `layer_variance/<name>`, `layer_levels/<name>`, plus the `layers`
    /// count). No-op for non-layer-wise pipelines.
    pub fn emit_layer_scalars(&self, rec: &mut Recorder) {
        let Compressor::LayerWise(lw) = self else { return };
        rec.set_scalar("layers", lw.subs.len() as f64);
        for (i, name) in lw.layers_cfg.names.iter().enumerate() {
            rec.set_scalar(&format!("layer_bits/{name}"), lw.layer_bits[i] as f64);
            rec.set_scalar(&format!("layer_variance/{name}"), lw.layer_epsilon_auto(i));
            rec.set_scalar(&format!("layer_levels/{name}"), lw.subs[i].levels.s() as f64);
        }
    }

    /// Error-feedback diagnostics of the last compressed vector:
    /// `(‖e_{t+1}‖₂, effective δ)` where the effective contraction is
    /// `1 − ‖e_{t+1}‖² / ‖e_t + g_t‖²` (1.0 on an all-zero input). `None`
    /// for non-contractive pipelines and before the first compress, so
    /// callers can emit conditionally and EF-off telemetry stays
    /// byte-identical.
    pub fn ef_scalars(&self) -> Option<(f64, f64)> {
        match self {
            Compressor::Contractive(ct) if ct.steps > 0 => Some((ct.last_err_norm, ct.last_delta)),
            _ => None,
        }
    }

    /// The per-worker error memory `e_t` (tests/diagnostics). `None` for
    /// non-contractive pipelines or before the partition is resolved.
    pub fn ef_error_memory(&self) -> Option<&[f32]> {
        match self {
            Compressor::Contractive(ct) if !ct.err.is_empty() => Some(&ct.err),
            _ => None,
        }
    }

    /// Worst-case contraction factor δ of the configured operator(s) —
    /// the dimension-weighted mean across layers. `None` for
    /// non-contractive pipelines or before the partition is resolved.
    pub fn ef_delta_bound(&self) -> Option<f64> {
        let Compressor::Contractive(ct) = self else { return None };
        let map = ct.map.as_ref()?;
        let d = map.d().max(1);
        Some(
            (0..map.len())
                .map(|i| map.dim(i) as f64 / d as f64 * ct.ops[i].delta(map.dim(i)))
                .sum(),
        )
    }

    /// Emit the EF summary scalars (`ef_err_norm`, `ef_delta`,
    /// `ef_delta_bound`). No-op for non-contractive pipelines, so every
    /// runner calls it unconditionally — the neutrality contract that
    /// keeps EF-off summaries byte-identical.
    pub fn emit_ef_scalars(&self, rec: &mut Recorder) {
        let Some((err_norm, delta)) = self.ef_scalars() else { return };
        rec.set_scalar("ef_err_norm", err_norm);
        rec.set_scalar("ef_delta", delta);
        if let Some(bound) = self.ef_delta_bound() {
            rec.set_scalar("ef_delta_bound", bound);
        }
    }

    /// Push the EF metric series (`ef_err_norm`, `ef_delta`) at eval step
    /// `t`. No-op for non-contractive pipelines.
    pub fn record_ef_series(&self, rec: &mut Recorder, t: f64) {
        let Some((err_norm, delta)) = self.ef_scalars() else { return };
        rec.push("ef_err_norm", t, err_norm);
        rec.push("ef_delta", t, delta);
    }
}

/// Layer-wise compression state: one `(levels, codec, stats, rng)` per
/// layer of the [`LayerMap`], plus the shared update/allocation machinery.
///
/// Wire format of one compressed vector (see `docs/WIRE.md`): per layer,
/// in map order, `[u32 LE payload byte length][the layer's CODE ∘ Q
/// payload]`. The frame is needed because each layer's stream is
/// independently byte-padded; its 32 bits/layer are charged to the
/// reported bit count. The layer map itself is side information (derived
/// from the shared config once `d` is known), like `d` and the bucket size
/// in the single-codec pipeline.
#[derive(Clone)]
pub struct LayerWiseCompressor {
    layers_cfg: LayersConfig,
    /// Base bucket size — the alignment hint for auto-split maps.
    base_bucket: usize,
    norm_q: u32,
    hist_bins: usize,
    /// Cached `QuantConfig::adapts()` of the full pipeline: per-layer
    /// schemes/codecs *and* the bit-budget allocator can demand stat
    /// exchange.
    adapts: bool,
    /// Bits/coordinate for `quant::alloc`; 0 = keep configured widths.
    budget: f64,
    subs: Vec<QuantCompressor>,
    /// Partition, resolved from the first vector's dimension.
    map: Option<LayerMap>,
    /// Cumulative encoded payload bits per layer (framing excluded).
    layer_bits: Vec<u64>,
    updates: usize,
}

impl LayerWiseCompressor {
    fn from_config(cfg: &QuantConfig, rng: Rng) -> Result<Self> {
        let flat = cfg.layers.resolve_quant(cfg);
        let mut subs = Vec::with_capacity(flat.len());
        for (i, c) in flat.into_iter().enumerate() {
            let QuantMode::Quantized { levels: s } = c.mode else {
                return Err(Error::Quant(format!(
                    "layer `{}` resolved to fp32 — layer-wise pipelines are quantized",
                    cfg.layers.names[i]
                )));
            };
            let levels = initial_levels(c.scheme, s);
            let codec = build_codec(&levels, c.codec, None)?;
            subs.push(QuantCompressor {
                stats: SufficientStats::new(c.hist_bins, c.norm_q),
                levels,
                codec,
                // Deterministic per-layer stream off the worker's rng.
                rng: rng.fork(i as u64 + 1),
                cfg: c,
                updates: 0,
                scratch: Scratch::default(),
            });
        }
        Ok(LayerWiseCompressor {
            layers_cfg: cfg.layers.clone(),
            base_bucket: cfg.bucket_size,
            norm_q: cfg.norm_q,
            hist_bins: cfg.hist_bins,
            adapts: cfg.adapts(),
            budget: cfg.layers.budget,
            layer_bits: vec![0; cfg.layers.names.len()],
            subs,
            map: None,
            updates: 0,
        })
    }

    /// Run `f` against the partition for dimension `d` — the cached map
    /// when it matches (the steady state: no clone, no allocation), a
    /// freshly resolved one before the first compress (e.g. a
    /// receive-only endpoint). A changed `d` mid-run is a caller bug.
    fn with_map<R>(&self, d: usize, f: impl FnOnce(&LayerMap) -> Result<R>) -> Result<R> {
        match &self.map {
            Some(m) if m.d() == d => f(m),
            Some(m) => Err(Error::Quant(format!(
                "layer map resolved for d = {}, got a vector of d = {d}",
                m.d()
            ))),
            None => f(&self.layers_cfg.resolve_map(d, self.base_bucket)?),
        }
    }

    /// Compress one vector, *appending* per-layer `[u32 frame][payload]`
    /// pairs to `out` (the caller clears; wire bytes identical to the
    /// historical allocating path). Each layer's stream is encoded straight
    /// into `out` — the frame length is patched in afterwards — so steady
    /// state allocates nothing. `spans` threads the telemetry
    /// quantize/encode split into every layer's sub-pipeline.
    fn compress_timed(
        &mut self,
        v: &[f32],
        out: &mut Vec<u8>,
        mut spans: Option<&mut StageSpans>,
    ) -> Result<u64> {
        if let Some(m) = &self.map {
            if m.d() != v.len() {
                return Err(Error::Quant(format!(
                    "layer map resolved for d = {}, got a vector of d = {}",
                    m.d(),
                    v.len()
                )));
            }
        } else {
            self.map = Some(self.layers_cfg.resolve_map(v.len(), self.base_bucket)?);
        }
        let adapts = self.adapts;
        let n = self.subs.len();
        // Capacity guess: ~6 bits/coordinate plus frames.
        out.reserve(v.len() + 4 * n);
        let mut total_bits = 0u64;
        for i in 0..n {
            // Copy the range out so the map borrow does not overlap the
            // &mut borrow of the sub-state (§Perf: no per-call map clone).
            let r = self.map.as_ref().unwrap().range(i);
            let slice = &v[r];
            let sub = &mut self.subs[i];
            if adapts {
                sub.observe_for_stats(slice);
            }
            let frame_at = out.len();
            out.extend_from_slice(&[0u8; 4]);
            let body_at = out.len();
            let bits = sub.compress_vec_timed(slice, out, spans.as_deref_mut())?;
            let frame = ((out.len() - body_at) as u32).to_le_bytes();
            out[frame_at..frame_at + 4].copy_from_slice(&frame);
            total_bits += 32 + bits;
            self.layer_bits[i] += bits;
        }
        Ok(total_bits)
    }

    fn decompress(&self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        self.with_map(out.len(), |map| Self::decompress_with(&self.subs, map, bytes, out))
    }

    /// [`Self::decompress`] through the per-layer decode arenas. Resolves
    /// and caches the map on a receive-only endpoint's first payload.
    fn decompress_into(&mut self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        match &self.map {
            Some(m) if m.d() == out.len() => {}
            Some(m) => {
                return Err(Error::Quant(format!(
                    "layer map resolved for d = {}, got a vector of d = {}",
                    m.d(),
                    out.len()
                )))
            }
            None => self.map = Some(self.layers_cfg.resolve_map(out.len(), self.base_bucket)?),
        }
        let map = self.map.as_ref().unwrap();
        let subs = &mut self.subs;
        for_each_frame(map.len(), bytes, |i, body| {
            let sub = &mut subs[i];
            decode_vector_into(
                body,
                map.dim(i),
                sub.cfg.bucket_size,
                &sub.codec,
                &mut sub.scratch.dec,
            )?;
            dequantize_into(&sub.scratch.dec, &sub.levels, map.slice_mut(i, out));
            Ok(())
        })
    }

    fn decompress_with(
        subs: &[QuantCompressor],
        map: &LayerMap,
        bytes: &[u8],
        out: &mut [f32],
    ) -> Result<()> {
        for_each_frame(map.len(), bytes, |i, body| {
            let sub = &subs[i];
            let qv = decode_vector(body, map.dim(i), sub.cfg.bucket_size, &sub.codec)?;
            dequantize_into(&qv, &sub.levels, map.slice_mut(i, out));
            Ok(())
        })
    }

    /// Pool the rank-ordered v3 payloads and update every layer in
    /// lockstep: (a) if a bit budget is configured, re-run the Theorem-1
    /// allocator on the pooled per-layer weights and resize any layer whose
    /// alphabet moved; (b) re-optimize adaptive level placements and
    /// rebuild codecs from the pooled per-layer statistics. Identical
    /// rank-ordered inputs ⇒ identical allocations, levels and tables on
    /// every worker — the same replication contract as the single-codec
    /// pipeline, extended to the allocator.
    fn update_levels(&mut self, all_stats_rank_order: &[&[u8]]) -> Result<bool> {
        if !self.adapts {
            return Ok(false);
        }
        let n = self.subs.len();
        let mut pooled = LayerStats::new(n, self.hist_bins, self.norm_q);
        for p in all_stats_rank_order {
            if !p.is_empty() {
                pooled.absorb_bytes(p)?;
            }
        }
        if pooled.is_empty() {
            return Ok(false);
        }
        let mut changed = false;
        let mut resized = vec![false; n];
        if self.budget > 0.0 {
            let map = self.map.as_ref().ok_or_else(|| {
                Error::Quant("bit-budget allocation before any compressed vector".into())
            })?;
            let profiles: Vec<LayerProfile> = (0..n)
                .map(|i| {
                    let dim = map.dim(i);
                    let b = self.subs[i].cfg.bucket_size;
                    LayerProfile {
                        weight: pooled.layer(i).total_weight(),
                        dim,
                        eff_dim: if b == 0 { dim } else { b.min(dim) },
                    }
                })
                .collect();
            let allocation = alloc::allocate(&profiles, self.budget, self.norm_q)?;
            for (i, &s_new) in allocation.levels.iter().enumerate() {
                let sub = &mut self.subs[i];
                if let QuantMode::Quantized { levels } = &mut sub.cfg.mode {
                    if *levels != s_new {
                        *levels = s_new;
                        sub.levels = initial_levels(sub.cfg.scheme, s_new);
                        resized[i] = true;
                        changed = true;
                    }
                }
            }
        }
        for (i, sub) in self.subs.iter_mut().enumerate() {
            let stats_i = pooled.layer(i);
            if stats_i.is_empty() {
                // A layer no worker observed this segment (e.g. an all-zero
                // slice): keep its fitted state — unless the allocator just
                // resized it, in which case the codec must be rebuilt for
                // the new alphabet (a stale width would corrupt the wire).
                if resized[i] {
                    sub.codec = build_codec(&sub.levels, sub.cfg.codec, None)?;
                }
                sub.stats.reset();
                continue;
            }
            let new_levels = if sub.cfg.scheme == LevelScheme::Adaptive {
                optimize_levels(stats_i, sub.levels.s(), Some(&sub.levels), 8)?
            } else {
                sub.levels.clone()
            };
            let probs = symbol_probs(stats_i, &new_levels);
            sub.codec = build_codec(&new_levels, sub.cfg.codec, Some(&probs))?;
            if new_levels != sub.levels {
                changed = true;
            }
            sub.levels = new_levels;
            sub.stats.reset();
        }
        self.updates += 1;
        Ok(changed)
    }

    /// Theorem-1 factor of layer `i` at width `dim` (its own bucket size
    /// and level sequence).
    fn layer_epsilon(&self, i: usize, dim: usize) -> f64 {
        let sub = &self.subs[i];
        let b = sub.cfg.bucket_size;
        let eff = if b == 0 { dim } else { b.min(dim) };
        crate::quant::epsilon_q(&sub.levels, eff.max(1), sub.cfg.norm_q)
    }

    /// [`Self::layer_epsilon`] with the width taken from the resolved map
    /// (bucket-size fallback before the first compress — metrics only).
    fn layer_epsilon_auto(&self, i: usize) -> f64 {
        let dim = match &self.map {
            Some(m) => m.dim(i),
            None => self.subs[i].cfg.bucket_size.max(1),
        };
        self.layer_epsilon(i, dim)
    }
}

/// Contractive compression with per-worker error feedback (`[quant.ef]`).
///
/// Per step: `a_t = e_t + g_t` is compressed with the configured
/// δ-contractive operator ([`crate::quant::contractive`]); the wire
/// carries `C(a_t)` and the memory keeps `e_{t+1} = a_t − Ĉ(a_t)`. The
/// sender computes the residual from the *decoder's* reconstruction
/// (shared kernels), so what every receiver adds to its iterate is
/// exactly what the memory no longer carries.
///
/// Wire format (`docs/WIRE.md` §5): an unpartitioned dual ships one bare
/// sparse/low-rank frame; with `[quant.layers]` each layer's frame rides
/// behind the same `[u32 length]` framing as the layer-wise pipeline
/// (parsed by the shared [`for_each_frame`]). Decoding is stateless — the
/// support (sparse) or factors (low-rank) travel on the wire — so any
/// replica decodes any sender's payload identically.
///
/// The error memory is *semantic* state: `Clone` (the checkpoint path)
/// must and does carry it, so resumed runs continue bit-for-bit. The
/// remaining buffers are §Perf scratch arenas — contents overwritten per
/// message, zero allocations in steady state.
#[derive(Clone)]
pub struct ContractiveCompressor {
    ef: EfConfig,
    layers_cfg: LayersConfig,
    /// Alignment hint for auto-split layer maps (the base bucket size).
    base_bucket: usize,
    /// Seeded support draws for rand-k; only the sender's stream is ever
    /// consumed (the support travels on the wire).
    rng: Rng,
    /// Error memory `e_t` (length d once resolved). Semantic state.
    err: Vec<f32>,
    /// Resolved per-layer operators, parallel to the map (a single entry
    /// for the unpartitioned pipeline).
    ops: Vec<ContractiveOp>,
    /// Partition, resolved from the first vector's dimension
    /// ([`LayerMap::single`] when `[quant.layers]` is off).
    map: Option<LayerMap>,
    // §Perf scratch arenas (encode and decode directions kept separate so
    // a compress between two decompresses cannot clobber state mid-use).
    acc: Vec<f32>,
    recon: Vec<f32>,
    idx: Vec<u32>,
    perm: Vec<u32>,
    fac_u: Vec<f32>,
    fac_v: Vec<f32>,
    dec_idx: Vec<u32>,
    dec_u: Vec<f32>,
    dec_v: Vec<f32>,
    frame: Vec<u8>,
    /// Number of vectors compressed (gates the diagnostics).
    steps: u64,
    /// ‖e_{t+1}‖₂ after the last compress.
    last_err_norm: f64,
    /// Effective contraction `1 − ‖e_{t+1}‖²/‖a_t‖²` of the last compress.
    last_delta: f64,
}

impl ContractiveCompressor {
    fn from_config(cfg: &QuantConfig, rng: Rng) -> Self {
        ContractiveCompressor {
            ef: cfg.ef.clone(),
            layers_cfg: cfg.layers.clone(),
            base_bucket: cfg.bucket_size,
            rng,
            err: Vec::new(),
            ops: Vec::new(),
            map: None,
            acc: Vec::new(),
            recon: Vec::new(),
            idx: Vec::new(),
            perm: Vec::new(),
            fac_u: Vec::new(),
            fac_v: Vec::new(),
            dec_idx: Vec::new(),
            dec_u: Vec::new(),
            dec_v: Vec::new(),
            frame: Vec::new(),
            steps: 0,
            last_err_norm: 0.0,
            last_delta: 0.0,
        }
    }

    /// Resolve the partition and per-layer operators for dimension `d`
    /// without touching cached state (the `&self` decompress path calls
    /// this directly).
    fn resolve(&self, d: usize) -> Result<(LayerMap, Vec<ContractiveOp>)> {
        let layered = self.layers_cfg.enabled();
        let map = if layered {
            self.layers_cfg.resolve_map(d, self.base_bucket)?
        } else {
            LayerMap::single(d)?
        };
        let mut ops = Vec::with_capacity(map.len());
        for i in 0..map.len() {
            let name = if layered { Some(map.name(i)) } else { None };
            let op = self.ef.resolve_op(name, map.dim(i))?;
            op.validate(map.dim(i))?;
            ops.push(op);
        }
        Ok((map, ops))
    }

    /// Resolve and cache the partition/operators for dimension `d`; sizes
    /// the error memory on first contact (all-zero start). A changed `d`
    /// mid-run is a caller bug, as in the other pipelines.
    fn ensure(&mut self, d: usize) -> Result<()> {
        match &self.map {
            Some(m) if m.d() == d => return Ok(()),
            Some(m) => {
                return Err(Error::Quant(format!(
                    "ef map resolved for d = {}, got a vector of d = {d}",
                    m.d()
                )))
            }
            None => {}
        }
        let (map, ops) = self.resolve(d)?;
        self.map = Some(map);
        self.ops = ops;
        self.err = vec![0.0; d];
        self.acc = vec![0.0; d];
        Ok(())
    }

    /// Compress one vector: accumulate the error memory into `a_t`,
    /// apply the operator per layer, append the §5 frame(s) to `out`
    /// (the caller clears) and keep the dropped residual. Zero
    /// allocations in steady state. `spans` charges the whole step to
    /// `encode` — there is no quantize stage; wire bytes and RNG stream
    /// are identical either way (the telemetry neutrality contract).
    fn compress_timed(
        &mut self,
        v: &[f32],
        out: &mut Vec<u8>,
        spans: Option<&mut StageSpans>,
    ) -> Result<u64> {
        let t0 = spans.is_some().then(Instant::now);
        self.ensure(v.len())?;
        for (a, (&e, &g)) in self.acc.iter_mut().zip(self.err.iter().zip(v.iter())) {
            *a = e + g;
        }
        let acc_sq = crate::util::norm2_sq(&self.acc);
        // e_{t+1} starts as a_t; each layer then removes what it shipped.
        self.err.copy_from_slice(&self.acc);
        let layered = self.layers_cfg.enabled();
        let n = self.ops.len();
        let mut total_bits = 0u64;
        for i in 0..n {
            // Copy the range out so the map borrow does not overlap the
            // scratch borrows (same idiom as the layer-wise pipeline).
            let r = self.map.as_ref().unwrap().range(i);
            let bits = match self.ops[i] {
                ContractiveOp::TopK { k } => {
                    contractive::select_top_k(&self.acc[r.clone()], k, &mut self.idx);
                    let b = contractive::encode_sparse_into(
                        &self.acc[r.clone()],
                        &self.idx,
                        &mut self.frame,
                    );
                    for &ix in &self.idx {
                        self.err[r.start + ix as usize] = 0.0;
                    }
                    b
                }
                ContractiveOp::RandK { k } => {
                    contractive::select_rand_k(
                        r.len(),
                        k,
                        &mut self.rng,
                        &mut self.perm,
                        &mut self.idx,
                    );
                    let b = contractive::encode_sparse_into(
                        &self.acc[r.clone()],
                        &self.idx,
                        &mut self.frame,
                    );
                    for &ix in &self.idx {
                        self.err[r.start + ix as usize] = 0.0;
                    }
                    b
                }
                ContractiveOp::RankR { rank, rows, cols } => {
                    contractive::low_rank_project(
                        &self.acc[r.clone()],
                        rows,
                        cols,
                        rank,
                        &mut self.fac_u,
                        &mut self.fac_v,
                    );
                    let b = contractive::encode_low_rank_into(
                        &self.fac_u,
                        &self.fac_v,
                        rank,
                        &mut self.frame,
                    );
                    // Ĉ(a) is defined by the decoder: reuse its kernel so
                    // the kept residual is exact.
                    self.recon.resize(r.len(), 0.0);
                    contractive::reconstruct_low_rank(
                        &self.fac_u,
                        &self.fac_v,
                        rows,
                        cols,
                        rank,
                        &mut self.recon,
                    );
                    for (j, g) in r.clone().enumerate() {
                        self.err[g] = self.acc[g] - self.recon[j];
                    }
                    b
                }
            };
            if layered {
                out.extend_from_slice(&(self.frame.len() as u32).to_le_bytes());
                out.extend_from_slice(&self.frame);
                total_bits += 32 + bits;
            } else {
                out.extend_from_slice(&self.frame);
                total_bits += bits;
            }
        }
        let err_sq = crate::util::norm2_sq(&self.err);
        self.last_err_norm = err_sq.sqrt();
        self.last_delta = if acc_sq > 0.0 { (1.0 - err_sq / acc_sq).clamp(0.0, 1.0) } else { 1.0 };
        self.steps += 1;
        if let (Some(s), Some(t0)) = (spans, t0) {
            s.add(Stage::Encode, t0.elapsed().as_secs_f64());
        }
        Ok(total_bits)
    }

    /// Decode one payload through the reusable decode scratch into `out`.
    /// Resolves and caches the map on a receive-only endpoint's first
    /// payload; never touches the error memory or the rand-k stream.
    fn decompress_into(&mut self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        self.ensure(out.len())?;
        let map = self.map.as_ref().unwrap();
        let ops = &self.ops;
        let idx = &mut self.dec_idx;
        let fu = &mut self.dec_u;
        let fv = &mut self.dec_v;
        if self.layers_cfg.enabled() {
            for_each_frame(map.len(), bytes, |i, body| {
                decode_contractive_frame(ops[i], body, idx, fu, fv, map.slice_mut(i, out))
            })
        } else {
            decode_contractive_frame(ops[0], bytes, idx, fu, fv, out)
        }
    }

    /// Allocating (`&self`) decode path — resolves a fresh map/operator
    /// set when none is cached yet and uses local scratch.
    fn decompress(&self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        let resolved;
        let (map, ops): (&LayerMap, &[ContractiveOp]) = match &self.map {
            Some(m) if m.d() == out.len() => (m, &self.ops),
            Some(m) => {
                return Err(Error::Quant(format!(
                    "ef map resolved for d = {}, got a vector of d = {}",
                    m.d(),
                    out.len()
                )))
            }
            None => {
                resolved = self.resolve(out.len())?;
                (&resolved.0, &resolved.1)
            }
        };
        let mut idx = Vec::new();
        let (mut fu, mut fv) = (Vec::new(), Vec::new());
        if self.layers_cfg.enabled() {
            for_each_frame(map.len(), bytes, |i, body| {
                decode_contractive_frame(
                    ops[i],
                    body,
                    &mut idx,
                    &mut fu,
                    &mut fv,
                    map.slice_mut(i, out),
                )
            })
        } else {
            decode_contractive_frame(ops[0], bytes, &mut idx, &mut fu, &mut fv, out)
        }
    }
}

/// Decode one contractive frame body (sparse or low-rank, by operator)
/// into `out` — THE one decode shared by the arena and allocating paths
/// and by the flat and layered framings, so format handling cannot
/// diverge between them.
fn decode_contractive_frame(
    op: ContractiveOp,
    body: &[u8],
    idx: &mut Vec<u32>,
    fu: &mut Vec<f32>,
    fv: &mut Vec<f32>,
    out: &mut [f32],
) -> Result<()> {
    match op {
        ContractiveOp::TopK { .. } | ContractiveOp::RandK { .. } => {
            contractive::decode_sparse_into(body, idx, out).map(|_| ())
        }
        ContractiveOp::RankR { rank: _, rows, cols } => {
            contractive::decode_low_rank_into(body, rows, cols, fu, fv, out).map(|_| ())
        }
    }
}

/// Walk the layer-wise `[u32 frame][payload]` wire (see `docs/WIRE.md`),
/// calling `f(layer index, frame body)` in map order. THE one copy of the
/// frame parser — both the allocating and arena decompress paths go
/// through it, so frame-format or error-handling changes cannot diverge
/// between them (the duplication class that hid PR 2's Huffman no-op).
fn for_each_frame(
    n_layers: usize,
    bytes: &[u8],
    mut f: impl FnMut(usize, &[u8]) -> Result<()>,
) -> Result<()> {
    let mut cursor = 0usize;
    for i in 0..n_layers {
        if bytes.len() < cursor + 4 {
            return Err(Error::Codec(format!("layer-wise payload truncated at layer {i} frame")));
        }
        let len = u32::from_le_bytes([
            bytes[cursor],
            bytes[cursor + 1],
            bytes[cursor + 2],
            bytes[cursor + 3],
        ]) as usize;
        cursor += 4;
        if bytes.len() < cursor + len {
            return Err(Error::Codec(format!(
                "layer-wise payload truncated in layer {i} body ({len} framed bytes)"
            )));
        }
        f(i, &bytes[cursor..cursor + len])?;
        cursor += len;
    }
    if cursor != bytes.len() {
        return Err(Error::Codec(format!(
            "layer-wise payload has {} trailing bytes",
            bytes.len() - cursor
        )));
    }
    Ok(())
}

fn initial_levels(scheme: LevelScheme, s: usize) -> Levels {
    match scheme {
        LevelScheme::Uniform => Levels::uniform(s),
        LevelScheme::Exponential => Levels::exponential(s),
        // Adaptive starts from exponential (a decent prior for gradient
        // coordinates) and re-optimizes on schedule. For large alphabets
        // exponential spacing underflows f32 near zero (2^-s), so fall back
        // to uniform there.
        LevelScheme::Adaptive => {
            if s <= 32 {
                Levels::exponential(s)
            } else {
                Levels::uniform(s)
            }
        }
    }
}

fn build_codec(levels: &Levels, kind: SymbolCodec, probs: Option<&[f64]>) -> Result<WireCodec> {
    match kind {
        SymbolCodec::Huffman => match probs {
            Some(p) => WireCodec::new(kind, levels, Some(p)),
            // Before the first stat exchange there is no probability
            // estimate; bootstrap with a geometric prior over symbols
            // (favors small levels like gradients do).
            None => {
                let n = levels.alphabet_size();
                let prior: Vec<f64> = (0..n).map(|j| 0.5f64.powi(j.min(60) as i32)).collect();
                WireCodec::new(kind, levels, Some(&prior))
            }
        },
        _ => WireCodec::new(kind, levels, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_allclose;
    use crate::util::Rng;

    fn quant_cfg(scheme: LevelScheme, codec: SymbolCodec) -> QuantConfig {
        QuantConfig {
            mode: QuantMode::Quantized { levels: 14 },
            scheme,
            norm_q: 2,
            bucket_size: 256,
            codec,
            update_every: 50,
            hist_bins: 128,
            stat_samples: 8,
            layers: Default::default(),
            ef: Default::default(),
        }
    }

    fn ef_cfg(ef: crate::config::EfConfig) -> QuantConfig {
        QuantConfig { ef, ..Default::default() }
    }

    fn topk_ef(k: usize) -> crate::config::EfConfig {
        crate::config::EfConfig {
            scheme: crate::config::EfScheme::TopK,
            k,
            ..Default::default()
        }
    }

    fn layered_cfg(scheme: LevelScheme, codec: SymbolCodec) -> QuantConfig {
        let mut cfg = quant_cfg(scheme, codec);
        cfg.stat_samples = 0;
        cfg.bucket_size = 64;
        cfg.layers.names = vec!["embed".into(), "body".into(), "head".into()];
        cfg.layers.bounds = vec![128, 448];
        cfg
    }

    #[test]
    fn fp32_roundtrip_is_exact() {
        let mut c = Compressor::from_config(
            &QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            Rng::seed_from(1),
        )
        .unwrap();
        let v = Rng::seed_from(2).gaussian_vec(100, 1.0);
        let (bytes, bits) = c.compress(&v).unwrap();
        assert_eq!(bits, 3200);
        let mut out = vec![0.0f32; 100];
        c.decompress(&bytes, &mut out).unwrap();
        assert_eq!(v, out);
        assert_eq!(c.epsilon_q(100), 0.0);
    }

    #[test]
    fn quantized_roundtrip_approximates() {
        for codec in [SymbolCodec::Fixed, SymbolCodec::EliasGamma, SymbolCodec::Huffman] {
            let mut c = Compressor::from_config(
                &quant_cfg(LevelScheme::Uniform, codec),
                Rng::seed_from(3),
            )
            .unwrap();
            let v = Rng::seed_from(4).gaussian_vec(512, 1.0);
            let (bytes, bits) = c.compress(&v).unwrap();
            assert!(bits < 32 * 512, "must beat fp32: {bits}");
            let mut out = vec![0.0f32; 512];
            c.decompress(&bytes, &mut out).unwrap();
            // Unbiased noisy reconstruction: close in norm, not exact.
            let err = crate::util::dist_sq(&v, &out).sqrt();
            let nv = crate::util::norm2(&v);
            assert!(err < nv, "err {err} vs ‖v‖ {nv} ({codec:?})");
        }
    }

    #[test]
    fn sender_receiver_pairs_interoperate() {
        // Worker A compresses; worker B (separate instance, same config)
        // decompresses — the distributed wire contract.
        let cfg = quant_cfg(LevelScheme::Exponential, SymbolCodec::EliasGamma);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(5)).unwrap();
        let b = Compressor::from_config(&cfg, Rng::seed_from(6)).unwrap();
        let v = Rng::seed_from(7).gaussian_vec(300, 2.0);
        let (bytes, _) = a.compress(&v).unwrap();
        let mut out = vec![0.0f32; 300];
        b.decompress(&bytes, &mut out).unwrap();
        // B's decode must equal A's own decode exactly.
        let mut out_a = vec![0.0f32; 300];
        a.decompress(&bytes, &mut out_a).unwrap();
        assert_allclose(&out, &out_a, 0.0, 0.0);
    }

    #[test]
    fn adaptive_update_changes_levels_and_stays_in_sync() {
        let cfg = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(8)).unwrap();
        let mut b = Compressor::from_config(&cfg, Rng::seed_from(9)).unwrap();
        let mut rng = Rng::seed_from(10);
        for _ in 0..20 {
            let v = rng.gaussian_vec(1024, 1.0);
            let _ = a.compress(&v).unwrap();
            let v2 = rng.gaussian_vec(1024, 1.0);
            let _ = b.compress(&v2).unwrap();
        }
        // Exchange stats; both update with the same pooled payloads.
        let sa = a.stats_payload();
        let sb = b.stats_payload();
        assert!(!sa.is_empty());
        let changed_a = a.update_levels(&[&sa, &sb]).unwrap();
        let changed_b = b.update_levels(&[&sa, &sb]).unwrap();
        assert!(changed_a && changed_b);
        assert_eq!(a.levels().unwrap(), b.levels().unwrap());
        assert_eq!(a.updates(), 1);
        // Cross-decode still works after the update.
        let v = rng.gaussian_vec(1024, 1.0);
        let (bytes, _) = a.compress(&v).unwrap();
        let mut out = vec![0.0f32; 1024];
        b.decompress(&bytes, &mut out).unwrap();
    }

    #[test]
    fn adaptive_levels_reduce_wire_size_via_huffman() {
        let cfg = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(11)).unwrap();
        let mut rng = Rng::seed_from(12);
        let mut before_bits = 0u64;
        for _ in 0..10 {
            let v = rng.gaussian_vec(4096, 1.0);
            let (_, bits) = c.compress(&v).unwrap();
            before_bits = bits;
        }
        let own = c.stats_payload();
        c.update_levels(&[&own]).unwrap();
        let v = rng.gaussian_vec(4096, 1.0);
        let (_, after_bits) = c.compress(&v).unwrap();
        // With a proper probability model the Huffman stream shrinks
        // relative to the bootstrap prior (or at worst stays similar).
        assert!(
            (after_bits as f64) < before_bits as f64 * 1.1,
            "after {after_bits} vs before {before_bits}"
        );
    }

    #[test]
    fn huffman_fixed_levels_refresh_is_not_a_noop() {
        // Regression: Huffman with *fixed* (uniform) levels used to return
        // an empty stats payload, so the scheduled "codec refresh" pooled
        // nothing and silently kept the bootstrap prior forever.
        let cfg = quant_cfg(LevelScheme::Uniform, SymbolCodec::Huffman);
        let mut refreshed = Compressor::from_config(&cfg, Rng::seed_from(21)).unwrap();
        let mut bootstrap = Compressor::from_config(&cfg, Rng::seed_from(21)).unwrap();
        let mut rng = Rng::seed_from(22);
        for _ in 0..12 {
            let v = rng.gaussian_vec(2048, 1.0);
            let _ = refreshed.compress(&v).unwrap();
            let _ = bootstrap.compress(&v).unwrap();
        }
        let payload = refreshed.stats_payload();
        assert!(!payload.is_empty(), "fixed-levels Huffman must ship stats");
        let changed = refreshed.update_levels(&[&payload]).unwrap();
        assert!(!changed, "uniform level placement must not move");
        assert_eq!(refreshed.updates(), 1, "the refresh must count as an update");
        assert_eq!(refreshed.levels().unwrap(), bootstrap.levels().unwrap());
        // Identical seeds + identical levels => both compressors consumed
        // the same uniforms and emit the same symbols for the same input;
        // any wire-size difference below is purely the rebuilt Huffman
        // table. With a fitted probability model it must beat the
        // bootstrap geometric prior on in-distribution data.
        let v = rng.gaussian_vec(2048, 1.0);
        let (_, bits_refreshed) = refreshed.compress(&v).unwrap();
        let (_, bits_bootstrap) = bootstrap.compress(&v).unwrap();
        assert!(
            bits_refreshed < bits_bootstrap,
            "refreshed table must shrink the stream: {bits_refreshed} vs {bits_bootstrap}"
        );
    }

    #[test]
    fn stat_samples_caps_observed_vectors_per_segment() {
        // The `quant.stat_samples` knob is the per-segment cap on vectors
        // (buckets) absorbed into the sufficient statistic.
        let mut cfg = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        cfg.stat_samples = 3;
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(30)).unwrap();
        let mut rng = Rng::seed_from(31);
        for _ in 0..5 {
            // 512 coords / 256 bucket = 2 buckets per compress
            let v = rng.gaussian_vec(512, 1.0);
            let _ = c.compress(&v).unwrap();
        }
        // Payload header (wire format v2) carries the pooled vector count.
        let payload = c.stats_payload();
        let seen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        assert_eq!(seen, 3, "cap must stop stat intake exactly at stat_samples");
        // After an update the segment (and the counter) restarts.
        c.update_levels(&[&payload]).unwrap();
        let v = rng.gaussian_vec(512, 1.0);
        let _ = c.compress(&v).unwrap();
        let payload = c.stats_payload();
        let seen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        assert_eq!(seen, 2, "new segment observes again up to the cap");
        // cap = 0 means unlimited
        let mut cfg0 = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        cfg0.stat_samples = 0;
        let mut c0 = Compressor::from_config(&cfg0, Rng::seed_from(32)).unwrap();
        for _ in 0..5 {
            let v = rng.gaussian_vec(512, 1.0);
            let _ = c0.compress(&v).unwrap();
        }
        let payload = c0.stats_payload();
        let seen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        assert_eq!(seen, 10);
    }

    #[test]
    fn single_layer_map_is_bitwise_passthrough() {
        // The regression contract: a one-layer [quant.layers] map runs the
        // seed single-codec machinery with the same rng — identical wire
        // bytes, not merely identical distributions.
        let base = quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        let mut layered = base.clone();
        layered.layers.names = vec!["all".into()];
        let mut a = Compressor::from_config(&base, Rng::seed_from(70)).unwrap();
        let mut b = Compressor::from_config(&layered, Rng::seed_from(70)).unwrap();
        assert!(!b.is_layerwise(), "one layer must not engage the layer-wise path");
        let mut rng = Rng::seed_from(71);
        for _ in 0..6 {
            let v = rng.gaussian_vec(700, 1.0);
            let (wa, bits_a) = a.compress(&v).unwrap();
            let (wb, bits_b) = b.compress(&v).unwrap();
            assert_eq!(wa, wb, "wire bytes must match bit-for-bit");
            assert_eq!(bits_a, bits_b);
        }
        // …including through a level update driven by identical payloads.
        let (pa, pb) = (a.stats_payload(), b.stats_payload());
        assert_eq!(pa, pb, "one-layer pipelines speak stat wire v2");
        a.update_levels(&[&pa]).unwrap();
        b.update_levels(&[&pb]).unwrap();
        let v = rng.gaussian_vec(700, 1.0);
        assert_eq!(a.compress(&v).unwrap(), b.compress(&v).unwrap());
        // A single-layer override still applies (different mode ⇒ it
        // genuinely reconfigures the flat pipeline).
        let mut overridden = layered.clone();
        overridden.layers.overrides =
            vec![crate::config::LayerOverride {
                mode: Some(QuantMode::Quantized { levels: 254 }),
                ..Default::default()
            }];
        let c = Compressor::from_config(&overridden, Rng::seed_from(70)).unwrap();
        assert_eq!(c.levels().unwrap().s(), 254);
    }

    #[test]
    fn layerwise_roundtrip_and_cross_worker_decode() {
        for (scheme, codec) in [
            (LevelScheme::Uniform, SymbolCodec::Fixed),
            (LevelScheme::Adaptive, SymbolCodec::Huffman),
            (LevelScheme::Exponential, SymbolCodec::EliasGamma),
        ] {
            let cfg = layered_cfg(scheme, codec);
            let mut a = Compressor::from_config(&cfg, Rng::seed_from(80)).unwrap();
            let b = Compressor::from_config(&cfg, Rng::seed_from(81)).unwrap();
            assert!(a.is_layerwise() && a.is_quantized());
            let v = Rng::seed_from(82).gaussian_vec(512, 1.5);
            let (wire, bits) = a.compress(&v).unwrap();
            // 3 frames of 32 bits are charged on top of the payloads.
            assert!(bits >= 96 && (bits as usize) < 32 * 512, "bits {bits}");
            // The receiver (fresh instance, same config, different rng)
            // decodes to exactly what the sender decodes.
            let mut out_b = vec![0.0f32; 512];
            b.decompress(&wire, &mut out_b).unwrap();
            let mut out_a = vec![0.0f32; 512];
            a.decompress(&wire, &mut out_a).unwrap();
            assert_eq!(out_a, out_b, "{scheme:?}/{codec:?}");
            // Unbiased reconstruction stays within norm.
            let err = crate::util::dist_sq(&v, &out_a).sqrt();
            assert!(err < crate::util::norm2(&v), "{scheme:?}/{codec:?} err {err}");
            // Truncation and trailing garbage are rejected.
            assert!(b.decompress(&wire[..wire.len() - 1], &mut out_b).is_err());
            let mut padded = wire.clone();
            padded.push(0);
            assert!(b.decompress(&padded, &mut out_b).is_err());
            // Dimension mismatch against the resolved map errors out.
            let mut short = vec![0.0f32; 100];
            assert!(a.decompress(&wire, &mut short).is_err());
        }
    }

    #[test]
    fn layerwise_overrides_give_layers_their_own_wire() {
        // head at uq8/fixed, embed at s2/fixed: the per-coordinate wire
        // cost must differ across layers roughly like the symbol widths.
        let mut cfg = layered_cfg(LevelScheme::Uniform, SymbolCodec::Fixed);
        cfg.layers.overrides = vec![
            crate::config::LayerOverride {
                mode: Some(QuantMode::Quantized { levels: 2 }),
                ..Default::default()
            },
            Default::default(),
            crate::config::LayerOverride {
                mode: Some(QuantMode::Quantized { levels: 254 }),
                ..Default::default()
            },
        ];
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(90)).unwrap();
        let v = Rng::seed_from(91).gaussian_vec(512, 1.0);
        let _ = c.compress(&v).unwrap();
        let bits = c.layer_wire_bits().unwrap();
        // dims 128 / 320 / 64 at 2 / 4 / 8 symbol bits (+ signs + norms).
        let per_coord: Vec<f64> =
            bits.iter().zip([128.0, 320.0, 64.0]).map(|(&b, d)| b as f64 / d).collect();
        assert!(per_coord[0] < per_coord[1] && per_coord[1] < per_coord[2], "{per_coord:?}");
        assert_eq!(c.layer_names().unwrap(), &["embed", "body", "head"]);
        assert_eq!(c.layer_levels(0).unwrap().s(), 2);
        assert_eq!(c.layer_levels(2).unwrap().s(), 254);
        // Mixed static pipeline: no stats, no updates.
        assert!(c.stats_payload().is_empty());
        assert!(!c.update_levels(&[]).unwrap());
    }

    #[test]
    fn layerwise_update_keeps_workers_in_lockstep() {
        let cfg = layered_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(100)).unwrap();
        let mut b = Compressor::from_config(&cfg, Rng::seed_from(101)).unwrap();
        let mut rng = Rng::seed_from(102);
        for _ in 0..10 {
            let _ = a.compress(&rng.gaussian_vec(512, 1.0)).unwrap();
            let _ = b.compress(&rng.gaussian_vec(512, 1.0)).unwrap();
        }
        let (pa, pb) = (a.stats_payload(), b.stats_payload());
        assert!(!pa.is_empty(), "adaptive layer-wise pipelines ship v3 stats");
        // v3 header: layer count.
        assert_eq!(u32::from_le_bytes([pa[0], pa[1], pa[2], pa[3]]), 3);
        let changed_a = a.update_levels(&[&pa, &pb]).unwrap();
        let changed_b = b.update_levels(&[&pa, &pb]).unwrap();
        assert!(changed_a && changed_b);
        assert_eq!(a.updates(), 1);
        for i in 0..3 {
            assert_eq!(
                a.layer_levels(i).unwrap(),
                b.layer_levels(i).unwrap(),
                "layer {i} levels must stay replicated"
            );
        }
        // Cross-decode still exact after the lockstep update.
        let v = rng.gaussian_vec(512, 1.0);
        let (wire, _) = a.compress(&v).unwrap();
        let mut out_a = vec![0.0f32; 512];
        let mut out_b = vec![0.0f32; 512];
        a.decompress(&wire, &mut out_a).unwrap();
        b.decompress(&wire, &mut out_b).unwrap();
        assert_eq!(out_a, out_b);
        // A v2-sized (un-layered) payload is rejected, not misread.
        let v2_payload = vec![0u8; 4 + 4 * 128];
        assert!(a.update_levels(&[&v2_payload]).is_err());
    }

    #[test]
    fn budget_allocator_moves_bits_toward_heavy_layers() {
        // Layers with wildly different norm mass; uniform scheme + fixed
        // codec so the only moving part is the allocator.
        let mut cfg = layered_cfg(LevelScheme::Uniform, SymbolCodec::Fixed);
        cfg.layers.budget = 4.0;
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(110)).unwrap();
        assert!(c.is_layerwise());
        let mut rng = Rng::seed_from(111);
        let mut wire_before = 0usize;
        for _ in 0..8 {
            // embed (128 coords) tiny, body (320) unit, head (64) huge.
            let mut v = rng.gaussian_vec(128, 0.01);
            v.extend(rng.gaussian_vec(320, 1.0));
            v.extend(rng.gaussian_vec(64, 8.0));
            let (w, _) = c.compress(&v).unwrap();
            wire_before = w.len();
        }
        let p = c.stats_payload();
        assert!(!p.is_empty(), "budget > 0 must force stat exchange");
        let changed = c.update_levels(&[&p]).unwrap();
        assert!(changed, "allocation away from uniform 4-bit must change levels");
        let s_embed = c.layer_levels(0).unwrap().s();
        let s_body = c.layer_levels(1).unwrap().s();
        let s_head = c.layer_levels(2).unwrap().s();
        assert!(
            s_head > s_body && s_body >= s_embed,
            "allocator must follow the mass: embed {s_embed} body {s_body} head {s_head}"
        );
        // The budget is respected on the wire: mean symbol bits/coordinate
        // ≤ 4 → the post-allocation payload is no larger than ~uniform 4-bit
        // (signs/norms are common to both).
        let mut v = rng.gaussian_vec(128, 0.01);
        v.extend(rng.gaussian_vec(320, 1.0));
        v.extend(rng.gaussian_vec(64, 8.0));
        let (w, _) = c.compress(&v).unwrap();
        assert!(
            w.len() <= wire_before + 8,
            "post-allocation wire {} vs uniform {}",
            w.len(),
            wire_before
        );
        // Identical payloads on a second worker reproduce the allocation.
        let mut c2 = Compressor::from_config(&cfg, Rng::seed_from(112)).unwrap();
        let mut v2 = rng.gaussian_vec(128, 0.01);
        v2.extend(rng.gaussian_vec(320, 1.0));
        v2.extend(rng.gaussian_vec(64, 8.0));
        let _ = c2.compress(&v2).unwrap();
        c2.update_levels(&[&p]).unwrap();
        for i in 0..3 {
            assert_eq!(c2.layer_levels(i).unwrap(), c.layer_levels(i).unwrap());
        }
    }

    #[test]
    fn layer_metrics_surface_series_and_scalars() {
        let cfg = layered_cfg(LevelScheme::Uniform, SymbolCodec::Fixed);
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(120)).unwrap();
        let v = Rng::seed_from(121).gaussian_vec(512, 1.0);
        let _ = c.compress(&v).unwrap();
        let mut rec = Recorder::new();
        c.record_layer_series(&mut rec, 1.0);
        c.emit_layer_scalars(&mut rec);
        assert_eq!(rec.scalar("layers"), Some(3.0));
        for name in ["embed", "body", "head"] {
            assert!(rec.get(&format!("layer_bits/{name}")).unwrap().last().unwrap() > 0.0);
            assert!(rec.scalar(&format!("layer_variance/{name}")).unwrap() > 0.0);
            assert_eq!(rec.scalar(&format!("layer_levels/{name}")), Some(14.0));
        }
        // Non-layer-wise pipelines: both calls are silent no-ops.
        let flat = Compressor::from_config(
            &quant_cfg(LevelScheme::Uniform, SymbolCodec::Fixed),
            Rng::seed_from(122),
        )
        .unwrap();
        let mut rec2 = Recorder::new();
        flat.record_layer_series(&mut rec2, 1.0);
        flat.emit_layer_scalars(&mut rec2);
        assert!(rec2.series.is_empty() && rec2.scalars.is_empty());
    }

    #[test]
    fn into_variants_match_allocating_paths_for_every_pipeline() {
        // compress_into/decompress_into are the hot path; compress/
        // decompress are the compat wrappers. Same config + same seed ⇒
        // identical RNG stream ⇒ identical wire bytes, for all three
        // pipeline shapes.
        let cfgs = [
            QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            quant_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman),
            quant_cfg(LevelScheme::Uniform, SymbolCodec::EliasGamma),
            layered_cfg(LevelScheme::Adaptive, SymbolCodec::Huffman),
        ];
        for cfg in cfgs {
            let is_fp32 = matches!(cfg.mode, QuantMode::Fp32);
            let mut a = Compressor::from_config(&cfg, Rng::seed_from(200)).unwrap();
            let mut b = Compressor::from_config(&cfg, Rng::seed_from(200)).unwrap();
            let mut rng = Rng::seed_from(201);
            let mut buf = Vec::new();
            let mut out_a = vec![0.0f32; 512];
            let mut out_b = vec![0.0f32; 512];
            for _ in 0..5 {
                let v = rng.gaussian_vec(512, 1.0);
                let (wire_a, bits_a) = a.compress(&v).unwrap();
                let bits_b = b.compress_into(&v, &mut buf).unwrap();
                assert_eq!(wire_a, buf, "wire bytes must match bit-for-bit");
                assert_eq!(bits_a, bits_b);
                a.decompress(&wire_a, &mut out_a).unwrap();
                b.decompress_into(&buf, &mut out_b).unwrap();
                assert_eq!(out_a, out_b);
            }
            // Steady state: the wire buffer is reused, not reallocated.
            // Asserted on the fixed-size fp32 wire only — entropy-coded
            // messages legitimately drift a few bytes with content, so
            // their allocation behavior is pinned by the deterministic
            // same-input tests in `quant::encode` and by the bench's
            // zero-alloc assertion instead.
            if is_fp32 {
                let ptr = buf.as_ptr();
                let v = rng.gaussian_vec(512, 1.0);
                let _ = a.compress(&v).unwrap();
                let _ = b.compress_into(&v, &mut buf).unwrap();
                assert_eq!(buf.as_ptr(), ptr, "steady-state compress must reuse the buffer");
            }
        }
    }

    #[test]
    fn layerwise_decompress_into_rejects_corrupted_frames() {
        let cfg = layered_cfg(LevelScheme::Uniform, SymbolCodec::Fixed);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(210)).unwrap();
        let v = Rng::seed_from(211).gaussian_vec(512, 1.0);
        let (wire, _) = a.compress(&v).unwrap();
        let mut out = vec![0.0f32; 512];
        a.decompress_into(&wire, &mut out).unwrap();
        // Shrink the first frame by one byte: the strict tail check inside
        // the frame (or the shifted later frames) must reject the payload
        // instead of decoding a wrong vector.
        let mut bad = wire.clone();
        let len = u32::from_le_bytes([bad[0], bad[1], bad[2], bad[3]]);
        bad[0..4].copy_from_slice(&(len - 1).to_le_bytes());
        assert!(a.decompress_into(&bad, &mut out).is_err());
        // Grow it by one: the extra byte lands in this frame as a trailing
        // byte — also rejected.
        let mut bad2 = wire.clone();
        bad2[0..4].copy_from_slice(&(len + 1).to_le_bytes());
        bad2.insert(4 + len as usize, 0);
        assert!(a.decompress_into(&bad2, &mut out).is_err());
    }

    #[test]
    fn fp32_stat_payload_is_empty_and_update_is_noop() {
        let mut c = Compressor::from_config(
            &QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            Rng::seed_from(13),
        )
        .unwrap();
        assert!(c.stats_payload().is_empty());
        assert!(!c.update_levels(&[]).unwrap());
    }

    #[test]
    fn decompress_validates_length() {
        let c = Compressor::from_config(
            &QuantConfig { mode: QuantMode::Fp32, ..Default::default() },
            Rng::seed_from(14),
        )
        .unwrap();
        let mut out = vec![0.0f32; 4];
        assert!(c.decompress(&[0u8; 7], &mut out).is_err());
    }

    #[test]
    fn contractive_topk_feeds_back_the_dropped_error() {
        let cfg = ef_cfg(topk_ef(4));
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(300)).unwrap();
        assert!(c.is_contractive() && !c.is_quantized());
        let g1 = Rng::seed_from(301).gaussian_vec(32, 1.0);
        let (wire, bits) = c.compress(&g1).unwrap();
        assert!(bits < 32 * 32, "4 of 32 coordinates must beat fp32: {bits}");
        let mut out = vec![0.0f32; 32];
        c.decompress(&wire, &mut out).unwrap();
        // First step: e_0 = 0, so the wire carries top-4 of g1 exactly.
        let mut idx = Vec::new();
        contractive::select_top_k(&g1, 4, &mut idx);
        for i in 0..32 {
            if idx.contains(&(i as u32)) {
                assert_eq!(out[i], g1[i]);
            } else {
                assert_eq!(out[i], 0.0);
            }
        }
        // The memory holds exactly what was dropped…
        let err: Vec<f32> = c.ef_error_memory().unwrap().to_vec();
        for i in 0..32 {
            assert_eq!(err[i], g1[i] - out[i]);
        }
        // …and the next step compresses e_1 + g_2, not g_2 alone.
        let g2 = Rng::seed_from(302).gaussian_vec(32, 1.0);
        let (wire2, _) = c.compress(&g2).unwrap();
        let mut out2 = vec![0.0f32; 32];
        c.decompress(&wire2, &mut out2).unwrap();
        let acc: Vec<f32> = (0..32).map(|i| err[i] + g2[i]).collect();
        contractive::select_top_k(&acc, 4, &mut idx);
        for &ix in &idx {
            assert_eq!(out2[ix as usize], acc[ix as usize]);
        }
        let (err_norm, delta) = c.ef_scalars().unwrap();
        assert!(err_norm > 0.0 && delta > 0.0 && delta <= 1.0);
        assert_eq!(c.ef_delta_bound(), Some(4.0 / 32.0));
    }

    #[test]
    fn contractive_full_k_is_exact_with_empty_memory() {
        let d = 24;
        let cfg = ef_cfg(topk_ef(d));
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(310)).unwrap();
        let mut rng = Rng::seed_from(311);
        let mut out = vec![0.0f32; d];
        for _ in 0..5 {
            let v = rng.gaussian_vec(d, 1.5);
            let (wire, _) = c.compress(&v).unwrap();
            c.decompress(&wire, &mut out).unwrap();
            assert_eq!(out, v, "k = d decodes the raw vector exactly");
            let (err_norm, delta) = c.ef_scalars().unwrap();
            assert_eq!(err_norm, 0.0, "full feedback never accumulates error");
            assert_eq!(delta, 1.0);
        }
    }

    #[test]
    fn contractive_randk_decode_is_stateless_across_ranks() {
        let ef = crate::config::EfConfig {
            scheme: crate::config::EfScheme::RandK,
            k: 6,
            ..Default::default()
        };
        let cfg = ef_cfg(ef);
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(320)).unwrap();
        let mut b = Compressor::from_config(&cfg, Rng::seed_from(321)).unwrap();
        let v = Rng::seed_from(322).gaussian_vec(40, 1.0);
        let (wire, _) = a.compress(&v).unwrap();
        // The support travels on the wire: ranks with *different* rng
        // streams decode identically, via both decode paths.
        let mut out_a = vec![0.0f32; 40];
        a.decompress(&wire, &mut out_a).unwrap();
        let mut out_b = vec![0.0f32; 40];
        b.decompress_into(&wire, &mut out_b).unwrap();
        assert_eq!(out_a, out_b);
        let mut out_c = vec![0.0f32; 40];
        Compressor::from_config(&cfg, Rng::seed_from(323))
            .unwrap()
            .decompress(&wire, &mut out_c)
            .unwrap();
        assert_eq!(out_a, out_c);
        assert_eq!(out_a.iter().filter(|x| **x != 0.0).count(), 6);
    }

    #[test]
    fn contractive_pipelines_never_adapt() {
        // The default config adapts (QAda + Huffman); [quant.ef] must
        // force the fully static path regardless.
        let cfg = ef_cfg(topk_ef(3));
        assert!(!cfg.adapts(), "[quant.ef] must disable adaptation");
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(330)).unwrap();
        let v = Rng::seed_from(331).gaussian_vec(16, 1.0);
        let _ = c.compress(&v).unwrap();
        assert!(c.stats_payload().is_empty(), "no stat payloads, ever");
        assert!(!c.update_levels(&[]).unwrap());
        assert_eq!(c.updates(), 0);
        assert!(c.levels().is_none() && c.layer_levels(0).is_none());
        assert_eq!(c.epsilon_q(16), 0.0);
        assert!(c.layer_names().is_none() && c.layer_wire_bits().is_none());
    }

    #[test]
    fn contractive_rankr_matches_sender_reconstruction() {
        let ef = crate::config::EfConfig {
            scheme: crate::config::EfScheme::RankR,
            rank: 2,
            rows: 6,
            ..Default::default()
        };
        let cfg = ef_cfg(ef);
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(340)).unwrap();
        let v = Rng::seed_from(341).gaussian_vec(48, 1.0);
        let (wire, bits) = c.compress(&v).unwrap();
        // [u32 r] + 32 · (rows + cols) · r = 32 + 32 · 14 · 2.
        assert_eq!(bits, 928);
        let mut out = vec![0.0f32; 48];
        c.decompress(&wire, &mut out).unwrap();
        // e_1 = a_1 − Ĉ(a_1) with the decoder's own reconstruction: the
        // memory plus the decode reassembles g_1 (up to f32 rounding of
        // the subtraction itself).
        let err = c.ef_error_memory().unwrap();
        for i in 0..48 {
            assert!((err[i] + out[i] - v[i]).abs() < 1e-5, "coordinate {i}");
        }
        let (_, delta) = c.ef_scalars().unwrap();
        assert!(delta > 0.0 && delta <= 1.0);
    }

    #[test]
    fn contractive_layered_frames_ride_the_shared_framing() {
        let mut cfg = layered_cfg(LevelScheme::Uniform, SymbolCodec::Fixed);
        cfg.ef = topk_ef(16);
        let ov = crate::config::EfOverride { k: Some(4), ..Default::default() };
        cfg.ef.overrides = vec![("embed".into(), ov)];
        let mut a = Compressor::from_config(&cfg, Rng::seed_from(350)).unwrap();
        let mut b = Compressor::from_config(&cfg, Rng::seed_from(351)).unwrap();
        assert!(a.is_contractive() && !a.is_layerwise());
        let v = Rng::seed_from(352).gaussian_vec(512, 1.0);
        let (wire, bits) = a.compress(&v).unwrap();
        // 3 frames of 32 bits ride on top of the sparse payloads.
        assert!(bits >= 96);
        let mut out_a = vec![0.0f32; 512];
        a.decompress(&wire, &mut out_a).unwrap();
        let mut out_b = vec![0.0f32; 512];
        b.decompress_into(&wire, &mut out_b).unwrap();
        assert_eq!(out_a, out_b);
        // embed (128 coords) keeps its override k = 4; body/head keep 16.
        let nz = |r: std::ops::Range<usize>| out_a[r].iter().filter(|x| **x != 0.0).count();
        assert_eq!(nz(0..128), 4);
        assert_eq!(nz(128..448), 16);
        assert_eq!(nz(448..512), 16);
        // Truncation and trailing garbage are rejected.
        assert!(b.decompress_into(&wire[..wire.len() - 1], &mut out_b).is_err());
        let mut padded = wire.clone();
        padded.push(0);
        assert!(b.decompress_into(&padded, &mut out_b).is_err());
    }

    #[test]
    fn contractive_clone_carries_the_error_memory() {
        // The checkpoint path is a deep clone: a compressor cloned mid-run
        // must continue bit-for-bit (nonzero memory and rand-k stream).
        for scheme in [crate::config::EfScheme::TopK, crate::config::EfScheme::RandK] {
            let ef = crate::config::EfConfig { scheme, k: 5, ..Default::default() };
            let cfg = ef_cfg(ef);
            let mut c = Compressor::from_config(&cfg, Rng::seed_from(360)).unwrap();
            let mut rng = Rng::seed_from(361);
            for _ in 0..3 {
                let _ = c.compress(&rng.gaussian_vec(33, 1.0)).unwrap();
            }
            assert!(c.ef_scalars().unwrap().0 > 0.0, "memory must be nonzero");
            let mut resumed = c.clone();
            for _ in 0..4 {
                let v = rng.gaussian_vec(33, 1.0);
                assert_eq!(c.compress(&v).unwrap(), resumed.compress(&v).unwrap());
            }
        }
    }

    #[test]
    fn ef_metrics_emit_only_for_contractive_pipelines() {
        let cfg = ef_cfg(topk_ef(2));
        let mut c = Compressor::from_config(&cfg, Rng::seed_from(370)).unwrap();
        // Before any compress: nothing to report (receive-only endpoints
        // stay silent in summaries).
        let mut rec = Recorder::new();
        c.emit_ef_scalars(&mut rec);
        assert!(rec.scalars.is_empty());
        let _ = c.compress(&Rng::seed_from(371).gaussian_vec(16, 1.0)).unwrap();
        c.emit_ef_scalars(&mut rec);
        c.record_ef_series(&mut rec, 1.0);
        assert!(rec.scalar("ef_err_norm").unwrap() > 0.0);
        let delta = rec.scalar("ef_delta").unwrap();
        assert!(delta > 0.0 && delta <= 1.0);
        assert_eq!(rec.scalar("ef_delta_bound"), Some(2.0 / 16.0));
        assert_eq!(rec.get("ef_err_norm").unwrap().len(), 1);
        // Non-contractive pipelines: silent no-ops, keeping EF-off
        // telemetry byte-identical.
        let flat = Compressor::from_config(
            &quant_cfg(LevelScheme::Uniform, SymbolCodec::Fixed),
            Rng::seed_from(372),
        )
        .unwrap();
        let mut rec2 = Recorder::new();
        flat.emit_ef_scalars(&mut rec2);
        flat.record_ef_series(&mut rec2, 1.0);
        assert!(rec2.series.is_empty() && rec2.scalars.is_empty());
        assert!(flat.ef_scalars().is_none() && flat.ef_error_memory().is_none());
        assert!(flat.ef_delta_bound().is_none());
    }
}
