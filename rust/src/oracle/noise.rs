//! Stochastic first-order oracles `g(x; ω) = A(x) + U(x; ω)`.
//!
//! Two noise regimes from the paper:
//!
//! * **Absolute** (Assumption 2): `E‖U‖² ≤ σ²` independent of `x` — the
//!   standard SGD-style oracle. [`AbsoluteNoiseOracle`] adds truncated
//!   Gaussian noise (truncation keeps the a.s.-boundedness part of the
//!   assumption honest).
//! * **Relative** (Assumption 3): `E‖U‖² ≤ c‖A(x)‖²` — the noise *vanishes
//!   at the solution*, which is what unlocks the fast `O(1/T)` rate of
//!   Theorem 4. [`RelativeNoiseOracle`] uses Rademacher-modulated
//!   multiplicative noise; [`RcdOracle`] and [`RandomPlayerOracle`] are the
//!   paper's Appendix-J examples where relative noise arises structurally.

use super::problems::Operator;
use crate::util::Rng;
use std::sync::Arc;

/// A stochastic dual-vector oracle bound to one worker (owns its RNG — the
/// paper's "independent and private stochastic dual vectors").
pub trait Oracle: Send {
    fn dim(&self) -> usize;

    /// Draw `g(x; ω)` into `out`.
    fn sample(&mut self, x: &[f32], out: &mut [f32]);

    /// The underlying deterministic operator.
    fn operator(&self) -> &dyn Operator;

    /// Deep copy including the private RNG state, so a cloned oracle
    /// continues the *same* noise stream — the primitive behind
    /// [`crate::coordinator::Session::checkpoint`]'s bit-for-bit resume.
    fn clone_box(&self) -> Box<dyn Oracle>;
}

/// Noise-free oracle: `g = A(x)` (the deterministic baseline).
#[derive(Clone)]
pub struct ExactOracle {
    op: Arc<dyn Operator>,
}

impl ExactOracle {
    pub fn new(op: Arc<dyn Operator>) -> Self {
        ExactOracle { op }
    }
}

impl Oracle for ExactOracle {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn sample(&mut self, x: &[f32], out: &mut [f32]) {
        self.op.apply(x, out);
    }

    fn operator(&self) -> &dyn Operator {
        self.op.as_ref()
    }

    fn clone_box(&self) -> Box<dyn Oracle> {
        Box::new(self.clone())
    }
}

/// Absolute noise: `g = A(x) + σ ζ`, ζ i.i.d. truncated standard normal
/// (|ζ_i| ≤ 5 — so ‖U‖ is a.s. bounded as Assumption 2 requires, while the
/// first two moments match N(0,1) to < 1e−5).
#[derive(Clone)]
pub struct AbsoluteNoiseOracle {
    op: Arc<dyn Operator>,
    sigma: f64,
    rng: Rng,
}

impl AbsoluteNoiseOracle {
    pub fn new(op: Arc<dyn Operator>, sigma: f64, rng: Rng) -> Self {
        AbsoluteNoiseOracle { op, sigma, rng }
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }
}

impl Oracle for AbsoluteNoiseOracle {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn sample(&mut self, x: &[f32], out: &mut [f32]) {
        self.op.apply(x, out);
        // Per-coordinate sigma scaled so that E||U||^2 = sigma^2 regardless
        // of dimension (the assumption bounds the *vector* variance).
        let per_coord = self.sigma / (self.op.dim() as f64).sqrt();
        for o in out.iter_mut() {
            let mut z = self.rng.gaussian();
            while z.abs() > 5.0 {
                z = self.rng.gaussian();
            }
            *o += (z * per_coord) as f32;
        }
    }

    fn operator(&self) -> &dyn Operator {
        self.op.as_ref()
    }

    fn clone_box(&self) -> Box<dyn Oracle> {
        Box::new(self.clone())
    }
}

/// Relative noise: `g_i = A_i(x) (1 + √c ε_i)` with ε_i Rademacher.
/// Unbiased, and `E‖U‖² = c ‖A(x)‖²` exactly — Assumption 3 with equality.
#[derive(Clone)]
pub struct RelativeNoiseOracle {
    op: Arc<dyn Operator>,
    c: f64,
    rng: Rng,
}

impl RelativeNoiseOracle {
    pub fn new(op: Arc<dyn Operator>, c: f64, rng: Rng) -> Self {
        RelativeNoiseOracle { op, c, rng }
    }

    pub fn c(&self) -> f64 {
        self.c
    }
}

impl Oracle for RelativeNoiseOracle {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn sample(&mut self, x: &[f32], out: &mut [f32]) {
        self.op.apply(x, out);
        let amp = self.c.sqrt();
        for o in out.iter_mut() {
            let eps: f64 = if self.rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            *o = (*o as f64 * (1.0 + amp * eps)) as f32;
        }
    }

    fn operator(&self) -> &dyn Operator {
        self.op.as_ref()
    }

    fn clone_box(&self) -> Box<dyn Oracle> {
        Box::new(self.clone())
    }
}

/// Random coordinate descent oracle (paper Example J.1):
/// `g = d · A_{i}(x) e_i` for a uniformly random coordinate `i`.
/// Unbiased with `E‖g − A‖² = (d − 1)‖A(x)‖²` — relative noise with
/// `c = d − 1`.
#[derive(Clone)]
pub struct RcdOracle {
    op: Arc<dyn Operator>,
    rng: Rng,
    scratch: Vec<f32>,
}

impl RcdOracle {
    pub fn new(op: Arc<dyn Operator>, rng: Rng) -> Self {
        let d = op.dim();
        RcdOracle { op, rng, scratch: vec![0.0; d] }
    }

    /// The relative-noise constant this oracle realizes.
    pub fn rel_c(&self) -> f64 {
        (self.op.dim() - 1) as f64
    }
}

impl Oracle for RcdOracle {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn sample(&mut self, x: &[f32], out: &mut [f32]) {
        let d = self.op.dim();
        self.op.apply(x, &mut self.scratch);
        out.fill(0.0);
        let i = self.rng.below(d as u64) as usize;
        out[i] = self.scratch[i] * d as f32;
    }

    fn operator(&self) -> &dyn Operator {
        self.op.as_ref()
    }

    fn clone_box(&self) -> Box<dyn Oracle> {
        Box::new(self.clone())
    }
}

/// Random player updating (paper Example J.2): the coordinate space is
/// split into `players` contiguous blocks; one block is sampled per query
/// (probability ∝ block size) and its component of `A` returned scaled by
/// `1/p_i`. Unbiased; variance vanishes at equilibria (Assumption 3).
#[derive(Clone)]
pub struct RandomPlayerOracle {
    op: Arc<dyn Operator>,
    rng: Rng,
    /// block boundaries, len = players + 1
    bounds: Vec<usize>,
    scratch: Vec<f32>,
}

impl RandomPlayerOracle {
    pub fn new(op: Arc<dyn Operator>, players: usize, rng: Rng) -> crate::Result<Self> {
        let d = op.dim();
        if players == 0 || players > d {
            return Err(crate::Error::Oracle(format!(
                "players {players} must be in 1..={d}"
            )));
        }
        let mut bounds = Vec::with_capacity(players + 1);
        for p in 0..=players {
            bounds.push(p * d / players);
        }
        Ok(RandomPlayerOracle { op, rng, bounds, scratch: vec![0.0; d] })
    }

    pub fn players(&self) -> usize {
        self.bounds.len() - 1
    }
}

impl Oracle for RandomPlayerOracle {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn sample(&mut self, x: &[f32], out: &mut [f32]) {
        self.op.apply(x, &mut self.scratch);
        out.fill(0.0);
        let players = self.players();
        let p = self.rng.below(players as u64) as usize;
        let (lo, hi) = (self.bounds[p], self.bounds[p + 1]);
        let inv_prob = players as f32; // uniform player selection
        for i in lo..hi {
            out[i] = self.scratch[i] * inv_prob;
        }
    }

    fn operator(&self) -> &dyn Operator {
        self.op.as_ref()
    }

    fn clone_box(&self) -> Box<dyn Oracle> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::problems::MonotoneQuadratic;
    use crate::util::{dist_sq, norm2_sq, Rng};

    fn quad(d: usize, seed: u64) -> Arc<dyn Operator> {
        let mut rng = Rng::seed_from(seed);
        Arc::new(MonotoneQuadratic::random(d, 0.3, 1.0, &mut rng).unwrap())
    }

    /// Estimate E[g], E||U||^2 at a point.
    fn moments(oracle: &mut dyn Oracle, x: &[f32], trials: usize) -> (Vec<f64>, f64) {
        let d = oracle.dim();
        let mut mean = vec![0.0f64; d];
        let mut var = 0.0f64;
        let mut a = vec![0.0f32; d];
        oracle.operator().apply(x, &mut a);
        let mut g = vec![0.0f32; d];
        for _ in 0..trials {
            oracle.sample(x, &mut g);
            for i in 0..d {
                mean[i] += g[i] as f64;
            }
            var += dist_sq(&g, &a);
        }
        for m in mean.iter_mut() {
            *m /= trials as f64;
        }
        (mean, var / trials as f64)
    }

    fn assert_unbiased(oracle: &mut dyn Oracle, x: &[f32], trials: usize, tol: f64) {
        let d = oracle.dim();
        let mut a = vec![0.0f32; d];
        oracle.operator().apply(x, &mut a);
        let (mean, _) = moments(oracle, x, trials);
        for i in 0..d {
            assert!(
                (mean[i] - a[i] as f64).abs() < tol,
                "coordinate {i}: mean {} vs A {}",
                mean[i],
                a[i]
            );
        }
    }

    #[test]
    fn absolute_oracle_unbiased_with_bounded_variance() {
        let op = quad(8, 1);
        let mut oracle = AbsoluteNoiseOracle::new(op, 0.7, Rng::seed_from(2));
        let x = vec![1.0f32; 8];
        assert_unbiased(&mut oracle, &x, 40_000, 0.03);
        let (_, var) = moments(&mut oracle, &x, 40_000);
        let sigma2 = 0.49;
        assert!((var - sigma2).abs() < 0.05 * sigma2 + 0.01, "var={var} sigma2={sigma2}");
    }

    #[test]
    fn relative_oracle_variance_scales_with_operator() {
        let op = quad(8, 3);
        let xs = op.solution().unwrap();
        let mut oracle = RelativeNoiseOracle::new(op.clone(), 0.5, Rng::seed_from(4));
        // Far from solution: variance = c ||A||^2.
        let far = vec![3.0f32; 8];
        let mut a = vec![0.0f32; 8];
        op.apply(&far, &mut a);
        let (_, var) = moments(&mut oracle, &far, 20_000);
        let expect = 0.5 * norm2_sq(&a);
        assert!((var - expect).abs() < 0.05 * expect, "var={var} expect={expect}");
        // At the solution: exactly zero noise.
        let (_, var0) = moments(&mut oracle, &xs, 100);
        assert!(var0 < 1e-10, "var at solution {var0}");
        assert_unbiased(&mut oracle, &far, 40_000, 0.1);
    }

    #[test]
    fn rcd_oracle_is_unbiased_relative_noise() {
        let d = 8;
        let op = quad(d, 5);
        let mut oracle = RcdOracle::new(op.clone(), Rng::seed_from(6));
        let x = vec![2.0f32; d];
        assert_unbiased(&mut oracle, &x, 60_000, 0.15);
        // E||g - A||^2 = (d-1)||A||^2
        let mut a = vec![0.0f32; d];
        op.apply(&x, &mut a);
        let (_, var) = moments(&mut oracle, &x, 60_000);
        let expect = (d - 1) as f64 * norm2_sq(&a);
        assert!((var - expect).abs() < 0.1 * expect, "var={var} expect={expect}");
        assert!((oracle.rel_c() - (d - 1) as f64).abs() < 1e-12);
    }

    #[test]
    fn player_oracle_unbiased_and_vanishes_at_solution() {
        let d = 8;
        let op = quad(d, 7);
        let xs = op.solution().unwrap();
        let mut oracle = RandomPlayerOracle::new(op.clone(), 4, Rng::seed_from(8)).unwrap();
        assert_eq!(oracle.players(), 4);
        let x = vec![1.5f32; d];
        assert_unbiased(&mut oracle, &x, 60_000, 0.12);
        let mut g = vec![0.0f32; d];
        oracle.sample(&xs, &mut g);
        assert!(norm2_sq(&g) < 1e-8);
    }

    #[test]
    fn player_oracle_rejects_bad_player_count() {
        let op = quad(4, 9);
        assert!(RandomPlayerOracle::new(op.clone(), 0, Rng::seed_from(1)).is_err());
        assert!(RandomPlayerOracle::new(op, 9, Rng::seed_from(1)).is_err());
    }

    #[test]
    fn exact_oracle_is_noise_free() {
        let op = quad(6, 10);
        let mut oracle = ExactOracle::new(op.clone());
        let x = vec![0.3f32; 6];
        let mut g1 = vec![0.0f32; 6];
        let mut g2 = vec![0.0f32; 6];
        oracle.sample(&x, &mut g1);
        oracle.sample(&x, &mut g2);
        assert_eq!(g1, g2);
        let mut a = vec![0.0f32; 6];
        op.apply(&x, &mut a);
        assert_eq!(g1, a);
    }

    #[test]
    fn absolute_noise_is_as_bounded() {
        // truncation at 5 sigma/sqrt(d) per coordinate
        let op = quad(4, 11);
        let mut oracle = AbsoluteNoiseOracle::new(op.clone(), 1.0, Rng::seed_from(12));
        let x = vec![0.0f32; 4];
        let mut a = vec![0.0f32; 4];
        op.apply(&x, &mut a);
        let mut g = vec![0.0f32; 4];
        let bound = 5.0 / 2.0; // 5 / sqrt(4)
        for _ in 0..10_000 {
            oracle.sample(&x, &mut g);
            for i in 0..4 {
                assert!((g[i] - a[i]).abs() as f64 <= bound + 1e-6);
            }
        }
    }
}
