//! §Perf — L3 hot-path microbenchmarks: quantize, entropy-encode, decode,
//! dequantize, and the whole compressor round-trip, across the
//! UQ4/UQ8 × Ψ-codec × bucket-size matrix.
//!
//! Besides the printed table this emits `results/BENCH_hotpath.json`
//! (schema in `docs/PERF.md`) — ns/coordinate and allocations/message per
//! stage — seeding the repo's perf trajectory so future PRs can show
//! "measurably faster" against a baseline instead of an anecdote.
//!
//! Knobs: `QGENX_BENCH_FAST=1` shrinks the workload for smoke runs (the
//! CI `perf-smoke` job), `QGENX_BENCH_DIM` pins `d` explicitly, and
//! `QGENX_BENCH_OUT` moves the JSON artifact.
//!
//! Targets (DESIGN.md §Perf): single-thread quantize+encode ≥ 400 MB/s so
//! the wire path is never the bottleneck against a 1 GbE (≈ 117 MiB/s)
//! link; steady-state compress/decompress must not allocate; the LUT
//! Huffman decoder must beat the per-bit reference ≥ 2×.

use qgenx::benchkit::{
    allocs_per_call, bench, env_usize, fmt_secs, fmt_throughput, scaled, write_json,
    CountingAlloc, Table,
};
use qgenx::coding::{BitReader, HuffmanCode, SymbolCodec};
use qgenx::config::{LevelScheme, QuantConfig, QuantMode};
use qgenx::coordinator::Compressor;
use qgenx::net::NetModel;
use qgenx::quant::{
    decode_vector_into, dequantize_into, encode_vector_into, quantize_into, symbol_probs,
    Levels, QuantizedVector, SufficientStats,
};
use qgenx::runtime::json::Json;
use qgenx::util::Rng;
use std::collections::BTreeMap;

// The shared counting wrapper over the system allocator (benchkit):
// `allocs_per_call` deltas give the allocations-per-message numbers in
// the JSON. Installing it here makes this binary's counts real; the same
// counter feeds telemetry's `allocs` field.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn case(
    stage: &str,
    quant: &str,
    codec: Option<&str>,
    bucket: usize,
    d: usize,
    secs: f64,
    allocs_msg: f64,
    extra: &[(&str, Json)],
) -> Json {
    let mut m = BTreeMap::from([
        ("stage".to_string(), Json::Str(stage.into())),
        ("quant".to_string(), Json::Str(quant.into())),
        (
            "codec".to_string(),
            codec.map(|c| Json::Str(c.into())).unwrap_or(Json::Null),
        ),
        ("bucket".to_string(), Json::Num(bucket as f64)),
        ("ns_per_coord".to_string(), Json::Num(secs * 1e9 / d as f64)),
        ("mb_per_s".to_string(), Json::Num(4.0 * d as f64 / secs / 1e6)),
        ("allocs_per_message".to_string(), Json::Num(allocs_msg)),
    ]);
    for (k, v) in extra {
        m.insert((*k).to_string(), v.clone());
    }
    Json::Obj(m)
}

/// The frozen per-bit reference decode walk (what `decode_vector` did
/// before the LUT): canonical first-code Huffman symbol by symbol, one
/// sign bit per nonzero. Fills a caller-owned arena so the comparison
/// against the LUT path is allocation-for-allocation fair.
fn ref_decode_huffman(
    bytes: &[u8],
    d: usize,
    bucket: usize,
    huff: &HuffmanCode,
    out: &mut QuantizedVector,
) {
    let b = if bucket == 0 { d } else { bucket };
    out.d = d;
    out.bucket_size = b;
    out.norms.clear();
    out.symbols.clear();
    out.symbols.resize(d, 0);
    out.sign_words.clear();
    out.sign_words.resize(d.div_ceil(64), 0);
    let mut r = BitReader::new(bytes);
    for bi in 0..d.div_ceil(b) {
        let norm = r.read_f32().unwrap();
        out.norms.push(norm);
        if norm == 0.0 {
            continue;
        }
        for i in bi * b..((bi + 1) * b).min(d) {
            let sym = huff.decode_linear(&mut r).unwrap() as u16;
            out.symbols[i] = sym;
            if sym != 0 && r.read_bit().unwrap() {
                out.sign_words[i / 64] |= 1u64 << (i % 64);
            }
        }
    }
}

fn main() {
    println!("== §Perf: wire-path microbenchmarks ==\n");
    let fast = qgenx::benchkit::fast_mode();
    let d = env_usize("QGENX_BENCH_DIM", scaled(1_000_000, 20_000));
    let bytes = 4 * d;
    let reps = scaled(7, 2);
    let alloc_calls = 3u64;
    let mut rng = Rng::seed_from(0x9e7f);
    let v = rng.gaussian_vec(d, 1.0);

    let mut table =
        Table::new(&["stage", "quant", "codec", "bucket", "median", "ns/coord", "allocs/msg"]);
    let mut cases: Vec<Json> = Vec::new();
    let mut huffman_speedups: Vec<f64> = Vec::new();

    for (quant, s) in [("uq4", 14usize), ("uq8", 254usize)] {
        let levels = Levels::uniform(s);
        let mut stats = SufficientStats::new(256, 2);
        stats.observe_bucketed(&v, 1024);
        let probs = symbol_probs(&stats, &levels);
        for bucket in [256usize, 1024] {
            // -- quantize (codec-independent) --------------------------
            let mut q_rng = Rng::seed_from(1);
            let mut arena = QuantizedVector::default();
            quantize_into(&v, &levels, 2, bucket, &mut q_rng, &mut arena).unwrap();
            let t = bench("quantize", 1, reps, || {
                quantize_into(&v, &levels, 2, bucket, &mut q_rng, &mut arena).unwrap();
                std::hint::black_box(arena.symbols.len());
            });
            let a = allocs_per_call(alloc_calls, || {
                quantize_into(&v, &levels, 2, bucket, &mut q_rng, &mut arena).unwrap();
            });
            push_row(&mut table, "quantize", quant, "-", bucket, &t, d, a);
            cases.push(case("quantize", quant, None, bucket, d, t.median(), a, &[]));

            // -- dequantize (codec-independent) ------------------------
            let mut out = vec![0.0f32; d];
            let t = bench("dequantize", 1, reps, || {
                dequantize_into(&arena, &levels, &mut out);
                std::hint::black_box(out[0]);
            });
            let a = allocs_per_call(alloc_calls, || {
                dequantize_into(&arena, &levels, &mut out);
            });
            push_row(&mut table, "dequantize", quant, "-", bucket, &t, d, a);
            cases.push(case("dequantize", quant, None, bucket, d, t.median(), a, &[]));

            for kind in [
                SymbolCodec::Fixed,
                SymbolCodec::EliasGamma,
                SymbolCodec::EliasDelta,
                SymbolCodec::Huffman,
            ] {
                let codec = match kind {
                    SymbolCodec::Huffman => {
                        qgenx::quant::WireCodec::new(kind, &levels, Some(&probs)).unwrap()
                    }
                    _ => qgenx::quant::WireCodec::new(kind, &levels, None).unwrap(),
                };
                // -- encode -------------------------------------------
                let mut wire = Vec::new();
                encode_vector_into(&arena, &codec, &mut wire).unwrap();
                let wire_bytes = wire.len();
                let t = bench("encode", 1, reps, || {
                    wire.clear();
                    encode_vector_into(&arena, &codec, &mut wire).unwrap();
                    std::hint::black_box(wire.len());
                });
                let a = allocs_per_call(alloc_calls, || {
                    wire.clear();
                    encode_vector_into(&arena, &codec, &mut wire).unwrap();
                });
                push_row(&mut table, "encode", quant, kind.name(), bucket, &t, d, a);
                cases.push(case(
                    "encode",
                    quant,
                    Some(kind.name()),
                    bucket,
                    d,
                    t.median(),
                    a,
                    &[("wire_bytes", Json::Num(wire_bytes as f64))],
                ));

                // -- decode -------------------------------------------
                let mut dec = QuantizedVector::default();
                decode_vector_into(&wire, d, bucket, &codec, &mut dec).unwrap();
                assert_eq!(dec, arena, "decode must invert encode");
                let t = bench("decode", 1, reps, || {
                    decode_vector_into(&wire, d, bucket, &codec, &mut dec).unwrap();
                    std::hint::black_box(dec.symbols.len());
                });
                let a = allocs_per_call(alloc_calls, || {
                    decode_vector_into(&wire, d, bucket, &codec, &mut dec).unwrap();
                });
                let mut extra = vec![("wire_bytes", Json::Num(wire_bytes as f64))];
                if kind == SymbolCodec::Huffman {
                    // Per-bit reference decoder: the ≥ 2× claim's baseline.
                    let huff = HuffmanCode::from_weights(
                        &probs.iter().map(|p| p.max(1e-9)).collect::<Vec<_>>(),
                    )
                    .unwrap();
                    let mut ref_dec = QuantizedVector::default();
                    ref_decode_huffman(&wire, d, bucket, &huff, &mut ref_dec);
                    assert_eq!(ref_dec, arena, "reference decode must agree");
                    let t_ref = bench("decode-ref", 1, reps, || {
                        ref_decode_huffman(&wire, d, bucket, &huff, &mut ref_dec);
                        std::hint::black_box(ref_dec.symbols.len());
                    });
                    let speedup = t_ref.median() / t.median();
                    huffman_speedups.push(speedup);
                    extra.push((
                        "ref_ns_per_coord",
                        Json::Num(t_ref.median() * 1e9 / d as f64),
                    ));
                    extra.push(("speedup_vs_ref", Json::Num(speedup)));
                    push_row(
                        &mut table,
                        "decode-ref",
                        quant,
                        "huffman/bit",
                        bucket,
                        &t_ref,
                        d,
                        0.0,
                    );
                }
                push_row(&mut table, "decode", quant, kind.name(), bucket, &t, d, a);
                cases.push(case(
                    "decode",
                    quant,
                    Some(kind.name()),
                    bucket,
                    d,
                    t.median(),
                    a,
                    &extra,
                ));
            }
        }
    }
    table.print();

    // -- full compressor round trip (what the coordinator actually runs) --
    let mut comp = Compressor::from_config(
        &QuantConfig {
            mode: QuantMode::Quantized { levels: 14 },
            scheme: LevelScheme::Uniform,
            codec: SymbolCodec::Huffman,
            bucket_size: 1024,
            ..Default::default()
        },
        Rng::seed_from(2),
    )
    .unwrap();
    let mut wire = Vec::new();
    let mut out = vec![0.0f32; d];
    comp.compress_into(&v, &mut wire).unwrap();
    comp.decompress_into(&wire, &mut out).unwrap();
    let t_rt = bench("roundtrip", 1, reps, || {
        comp.compress_into(&v, &mut wire).unwrap();
        comp.decompress_into(&wire, &mut out).unwrap();
        std::hint::black_box(out[0]);
    });
    let rt_allocs = allocs_per_call(alloc_calls, || {
        comp.compress_into(&v, &mut wire).unwrap();
        comp.decompress_into(&wire, &mut out).unwrap();
    });
    println!(
        "\ncompressor round-trip: {} ({}), {} allocs/message",
        fmt_secs(t_rt.median()),
        fmt_throughput(bytes, t_rt.median()),
        rt_allocs,
    );
    assert_eq!(
        rt_allocs, 0.0,
        "steady-state compress/decompress must not allocate"
    );

    // Economics: is the codec cheaper than the network saving it buys?
    let net = NetModel::gbe();
    let t_fp32 = net.allgather_time(&[bytes; 3]);
    let t_q = net.allgather_time(&[wire.len(); 3]);
    let saving = t_fp32 - t_q;
    let cost = t_rt.median();
    println!(
        "economics at d={d}, K=3, 1GbE: network saving {}/round vs codec cost {}/vector — {}",
        fmt_secs(saving),
        fmt_secs(cost),
        if cost < saving { "PROFITABLE" } else { "NOT profitable at this scale" },
    );

    let speedup_min =
        huffman_speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "huffman LUT decode speedup vs per-bit reference: min {:.2}x across {} configs",
        speedup_min,
        huffman_speedups.len()
    );

    let doc = Json::Obj(BTreeMap::from([
        ("bench".to_string(), Json::Str("perf_hotpath".into())),
        ("schema".to_string(), Json::Num(1.0)),
        ("mode".to_string(), Json::Str(if fast { "fast".into() } else { "full".into() })),
        ("d".to_string(), Json::Num(d as f64)),
        ("reps".to_string(), Json::Num(reps as f64)),
        ("cases".to_string(), Json::Arr(cases)),
        ("huffman_decode_speedup_min".to_string(), Json::Num(speedup_min)),
        (
            "roundtrip".to_string(),
            Json::Obj(BTreeMap::from([
                ("ns_per_coord".to_string(), Json::Num(t_rt.median() * 1e9 / d as f64)),
                ("allocs_per_message".to_string(), Json::Num(rt_allocs)),
                ("wire_bytes".to_string(), Json::Num(wire.len() as f64)),
            ])),
        ),
    ]));
    let out_path = std::env::var("QGENX_BENCH_OUT")
        .unwrap_or_else(|_| "results/BENCH_hotpath.json".to_string());
    write_json(&out_path, &doc).unwrap();
    println!("json -> {out_path}");
}

#[allow(clippy::too_many_arguments)]
fn push_row(
    table: &mut Table,
    stage: &str,
    quant: &str,
    codec: &str,
    bucket: usize,
    t: &qgenx::benchkit::Timing,
    d: usize,
    allocs_msg: f64,
) {
    table.row(&[
        stage.to_string(),
        quant.to_string(),
        codec.to_string(),
        bucket.to_string(),
        fmt_secs(t.median()),
        format!("{:.2}", t.median() * 1e9 / d as f64),
        format!("{allocs_msg:.1}"),
    ]);
}
