//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build image has no network and no XLA shared library, so this crate
//! provides the exact API surface `qgenx::runtime` consumes — enough to
//! type-check and to run every code path that does not need a real device.
//! `PjRtClient::cpu()` fails with a descriptive error, which `runtime`
//! surfaces as "built against the xla stub"; the artifact-driven tests and
//! examples already skip themselves when no runtime can be opened.
//!
//! Shape bookkeeping (`Literal::vec1` / `reshape`) is implemented for real
//! because argument validation is exercised by unit tests without a device.
//!
//! To build with the real bindings, point the `xla` path dependency in
//! `rust/Cargo.toml` at an xla-rs checkout; no qgenx source changes needed.

use std::fmt;

/// Error type mirroring `xla::Error`'s public face (Display + std::error).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: built against the offline xla stub (vendor/xla-stub); \
         link the real xla-rs bindings to execute artifacts"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor: the stub tracks element count and dims only.
#[derive(Clone, Debug)]
pub struct Literal {
    len: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { len: data.len(), dims: vec![data.len() as i64] }
    }

    /// Reshape; validates that the element count is preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.len {
            return Err(Error(format!(
                "reshape: literal has {} elements, dims {dims:?} want {want}",
                self.len
            )));
        }
        Ok(Literal { len: self.len, dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.len
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Decompose a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub: never constructible without a device stack).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_validation() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3]).is_err());
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
    }
}
