//! The [`Transport`] seam and its in-process implementation.
//!
//! `K` worker endpoints each deposit one payload per round and receive
//! everyone's payloads — the exact communication pattern of Algorithm 1
//! ("each processor receives stochastic dual vectors from all other
//! processors"). Payloads are `Vec<u8>` — real encoded wire bytes, so the
//! transport also measures exact per-round sizes. Topology-restricted
//! delivery (ring/star/tree/gossip) is layered on top by
//! [`crate::topo::Collective`], which uses this full exchange as the
//! physical substrate and applies the logical delivery pattern.
//!
//! Two implementations share the trait:
//!
//! * [`AllGather`] — the in-process (loopback-of-threads) barrier below:
//!   a two-phase (deposit → read) sense-reversing barrier on one mutex +
//!   condvar. The historical threaded fabric; zero wire overhead.
//! * [`crate::net::SocketTransport`] — real length-framed messages over
//!   TCP or Unix-domain sockets between separate OS processes.
//!
//! Failure semantics are shared: a worker that panics mid-round would
//! leave its peers blocked forever with a plain `std::sync::Barrier`;
//! instead every worker holds a [`PoisonGuard`] whose `Drop` during a
//! panic marks the group poisoned and wakes/aborts all waiters, which then
//! return [`Error::Net`] — the failure propagates instead of deadlocking.
//! (Clean `Err` returns don't unwind, so coordinators additionally call
//! [`Transport::poison`] when a worker exits with an error.) A peer that
//! simply never arrives is covered by the configurable exchange timeout
//! ([`AllGather::with_timeout`], socket read timeouts), which feeds the
//! same poison path.

use crate::error::{Error, Result};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Which plane a round belongs to. The socket transport stamps it into the
/// frame header (a cheap lockstep check: every rank must be exchanging the
/// same kind of round) and splits its measured byte tallies by it, so the
/// *measured* data-plane bytes reconcile against the *modeled*
/// [`crate::topo::LinkTraffic`] without control/diagnostic contamination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// Data-plane payloads (encoded dual vectors / model deltas) — the
    /// traffic the paper's theorems bound; billed per link.
    Data,
    /// Control-plane pooled sufficient statistics — billed full-mesh in
    /// aggregate ([`crate::net::TrafficStats`]).
    Control,
    /// Out-of-band rounds (eval diagnostics, checkpoint barriers) —
    /// deliberately never billed to traffic.
    Oob,
}

/// Byte counts actually observed on a physical wire by one endpoint,
/// split by [`Plane`]. `None` for in-process transports (nothing crosses a
/// wire); the socket transport reports framed reality here, reconciled in
/// tests and telemetry against the modeled `LinkTraffic` accounting.
///
/// Links are directed `(sender, receiver)` pairs, matching
/// [`crate::topo::Link`]. Each endpoint sees only its incident links;
/// [`MeasuredWire::merge_links`] unions a whole group's views.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MeasuredWire {
    /// The reporting endpoint's rank.
    pub rank: usize,
    /// Data-plane rounds completed.
    pub data_rounds: u64,
    /// Frames written / read by this endpoint (all planes).
    pub frames_sent: u64,
    pub frames_recv: u64,
    /// Frame-header overhead bytes, both directions.
    pub header_bytes: u64,
    /// Data-plane payload bytes per outgoing link `(rank, peer)`.
    pub data_sent: Vec<((usize, usize), u64)>,
    /// Data-plane payload bytes per incoming link `(peer, rank)`.
    pub data_recv: Vec<((usize, usize), u64)>,
    /// Control-plane payload bytes, both directions (aggregate).
    pub control_sent: u64,
    pub control_recv: u64,
    /// Out-of-band payload bytes, both directions (aggregate).
    pub oob_sent: u64,
    pub oob_recv: u64,
}

impl MeasuredWire {
    /// Total data-plane payload bytes this endpoint put on the wire.
    pub fn data_bytes_sent(&self) -> u64 {
        self.data_sent.iter().map(|&(_, b)| b).sum()
    }

    /// Total data-plane payload bytes this endpoint received.
    pub fn data_bytes_recv(&self) -> u64 {
        self.data_recv.iter().map(|&(_, b)| b).sum()
    }

    /// Union the *sent* link tallies of every endpoint of a group into
    /// global directed-link totals — the measured counterpart of
    /// [`crate::topo::LinkTraffic::totals`] on a full-mesh physical fabric.
    pub fn merge_links(
        views: &[MeasuredWire],
    ) -> std::collections::BTreeMap<(usize, usize), u64> {
        let mut out = std::collections::BTreeMap::new();
        for v in views {
            for &(link, bytes) in &v.data_sent {
                *out.entry(link).or_insert(0) += bytes;
            }
        }
        out
    }
}

/// How one round of encoded payloads moves between `K` ranks: the seam the
/// [`crate::coordinator::RoundEngine`]'s `Fabric::Transport` arm and every
/// [`crate::topo::Collective`] run over, with two implementations — the
/// in-process [`AllGather`] barrier and the multi-process
/// [`crate::net::SocketTransport`]. See the module docs for the shared
/// poison/lifecycle semantics.
pub trait Transport: Send + Sync {
    /// Group size `K`.
    fn peers(&self) -> usize;

    /// Exchange: endpoint `rank` contributes `payload`, gets back all `K`
    /// payloads (rank-indexed, including its own). Blocks until everyone
    /// arrives, the configured timeout elapses, or the group is poisoned —
    /// the latter two surface as [`Error::Net`].
    fn exchange(&self, rank: usize, payload: Vec<u8>, plane: Plane) -> Result<Vec<Arc<Vec<u8>>>>;

    /// Mark the group poisoned (sticky, first reason wins) and release
    /// every blocked or future exchange with an error.
    fn poison(&self, reason: &str);

    fn is_poisoned(&self) -> bool;

    /// Implementation name for diagnostics/telemetry (`"inproc"`, `"socket"`).
    fn kind(&self) -> &'static str;

    /// Physical wire bytes observed by this endpoint; `None` when nothing
    /// actually crosses a wire (in-process transports).
    fn measured(&self) -> Option<MeasuredWire> {
        None
    }
}

/// One in-process synchronous allgather group of `k` participants — the
/// [`Transport`] implementation behind the threaded coordinator.
pub struct AllGather {
    k: usize,
    /// Max wait for peers inside one exchange; `None` blocks forever.
    timeout: Option<Duration>,
    state: Mutex<State>,
    cv: Condvar,
}

struct State {
    payloads: Vec<Option<Arc<Vec<u8>>>>,
    /// Deposits received this round.
    deposited: usize,
    /// Participants that finished reading this round.
    read: usize,
    /// Round counter; readers wait on it to flip before re-entering.
    generation: u64,
    /// First poison reason; sticky.
    poisoned: Option<String>,
}

impl AllGather {
    pub fn new(k: usize) -> Arc<Self> {
        Self::with_timeout(k, None)
    }

    /// Like [`Self::new`], with a cap on how long one [`Self::exchange`]
    /// waits for its peers. A peer that never arrives (wedged oracle, dead
    /// thread that neither panicked nor errored) then poisons the group
    /// with a timeout [`Error::Net`] instead of blocking forever.
    /// `None` preserves the historical block-forever behavior.
    pub fn with_timeout(k: usize, timeout: Option<Duration>) -> Arc<Self> {
        assert!(k >= 1);
        Arc::new(AllGather {
            k,
            timeout,
            state: Mutex::new(State {
                payloads: vec![None; k],
                deposited: 0,
                read: 0,
                generation: 0,
                poisoned: None,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn peers(&self) -> usize {
        self.k
    }

    /// RAII handle that poisons the group if dropped during a panic.
    /// Every worker thread should hold one for the duration of its run.
    pub fn guard(self: &Arc<Self>) -> PoisonGuard {
        PoisonGuard::new(self.clone())
    }

    /// Mark the group poisoned (first reason sticks) and wake all waiters.
    pub fn poison(&self, reason: &str) {
        let mut s = self.lock();
        if s.poisoned.is_none() {
            s.poisoned = Some(reason.to_string());
        }
        self.cv.notify_all();
    }

    pub fn is_poisoned(&self) -> bool {
        self.lock().poisoned.is_some()
    }

    /// Deposits outstanding in the current round (diagnostics/tests).
    pub fn pending_deposits(&self) -> usize {
        self.lock().deposited
    }

    /// Lock the state, surviving mutex poisoning (a panicking peer may have
    /// held the lock; our own `poisoned` flag is the source of truth).
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn poison_err(s: &State) -> Error {
        let why = s.poisoned.as_deref().unwrap_or("a peer worker panicked mid-round");
        Error::Net(format!("transport poisoned: {why}"))
    }

    /// One condvar wait bounded by `deadline`. On expiry the group is
    /// poisoned in place (peers must not keep waiting for us either) and
    /// the timeout surfaces as [`Error::Net`].
    fn wait_deadline<'a>(
        &self,
        g: MutexGuard<'a, State>,
        deadline: Option<Instant>,
        phase: &str,
    ) -> Result<MutexGuard<'a, State>> {
        match deadline {
            None => Ok(self.cv.wait(g).unwrap_or_else(|e| e.into_inner())),
            Some(dl) => {
                let left = dl.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    let mut g = g;
                    let reason = format!(
                        "exchange timed out after {:?} {phase} ({}/{} deposits in)",
                        self.timeout.unwrap_or_default(),
                        g.deposited,
                        self.k
                    );
                    if g.poisoned.is_none() {
                        g.poisoned = Some(reason.clone());
                    }
                    self.cv.notify_all();
                    return Err(Error::Net(format!("transport poisoned: {reason}")));
                }
                let (g, _timed_out) =
                    self.cv.wait_timeout(g, left).unwrap_or_else(|e| e.into_inner());
                Ok(g)
            }
        }
    }

    /// Exchange: worker `rank` contributes `payload`, gets back all `k`
    /// payloads (rank-indexed, including its own). Blocks until everyone
    /// arrives or the configured timeout elapses. Errors on double-deposit
    /// within a round and when the group is poisoned (peer panic, peer
    /// error exit, or a timed-out peer).
    pub fn exchange(&self, rank: usize, payload: Vec<u8>) -> Result<Vec<Arc<Vec<u8>>>> {
        assert!(rank < self.k);
        let deadline = self.timeout.map(|d| Instant::now() + d);
        // Phase 1: deposit, then wait until all k deposits are in.
        let mut s = self.lock();
        if s.poisoned.is_some() {
            return Err(Self::poison_err(&s));
        }
        if s.payloads[rank].is_some() {
            return Err(Error::Coordinator(format!(
                "worker {rank} deposited twice in one round"
            )));
        }
        s.payloads[rank] = Some(Arc::new(payload));
        s.deposited += 1;
        if s.deposited == self.k {
            self.cv.notify_all();
        }
        while s.deposited < self.k && s.poisoned.is_none() {
            s = self.wait_deadline(s, deadline, "waiting for peer deposits")?;
        }
        if s.poisoned.is_some() {
            return Err(Self::poison_err(&s));
        }
        let out: Vec<Arc<Vec<u8>>> =
            s.payloads.iter().map(|p| p.clone().expect("slot must be filled")).collect();
        // Phase 2: the last reader resets the slots and flips the
        // generation; everyone else waits for the flip so a fast worker's
        // next-round deposit cannot race a slow worker's read.
        s.read += 1;
        if s.read == self.k {
            s.deposited = 0;
            s.read = 0;
            for p in s.payloads.iter_mut() {
                *p = None;
            }
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            let gen = s.generation;
            while s.generation == gen && s.poisoned.is_none() {
                s = self.wait_deadline(s, deadline, "waiting for peers to finish reading")?;
            }
            if s.poisoned.is_some() {
                return Err(Self::poison_err(&s));
            }
        }
        Ok(out)
    }
}

impl Transport for AllGather {
    fn peers(&self) -> usize {
        self.k
    }

    fn exchange(&self, rank: usize, payload: Vec<u8>, _plane: Plane) -> Result<Vec<Arc<Vec<u8>>>> {
        // In-process slots carry no frames; the plane only matters to
        // transports that bill a physical wire.
        AllGather::exchange(self, rank, payload)
    }

    fn poison(&self, reason: &str) {
        AllGather::poison(self, reason)
    }

    fn is_poisoned(&self) -> bool {
        AllGather::is_poisoned(self)
    }

    fn kind(&self) -> &'static str {
        "inproc"
    }
}

/// Dropping this during a panic poisons the [`Transport`] group so peers
/// blocked in an exchange error out instead of deadlocking.
pub struct PoisonGuard(Arc<dyn Transport>);

impl PoisonGuard {
    pub fn new(transport: Arc<dyn Transport>) -> Self {
        PoisonGuard(transport)
    }
}

impl Drop for PoisonGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison("a peer worker panicked mid-round");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn allgather_delivers_everyones_payload() {
        let k = 4;
        let ag = AllGather::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ag = ag.clone();
                thread::spawn(move || {
                    for round in 0..10u8 {
                        let payload = vec![rank as u8, round];
                        let got = ag.exchange(rank, payload).unwrap();
                        assert_eq!(got.len(), k);
                        for (r, p) in got.iter().enumerate() {
                            assert_eq!(p.as_slice(), &[r as u8, round]);
                        }
                    }
                    rank
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn single_participant_trivially_exchanges() {
        let ag = AllGather::new(1);
        let got = ag.exchange(0, vec![7, 7]).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].as_slice(), &[7, 7]);
        // and again — generations reset correctly for the next round
        let got = ag.exchange(0, vec![8]).unwrap();
        assert_eq!(got[0].as_slice(), &[8]);
    }

    #[test]
    fn payload_sizes_vary_per_round() {
        let k = 2;
        let ag = AllGather::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ag = ag.clone();
                thread::spawn(move || {
                    for round in 1..6usize {
                        let payload = vec![rank as u8; round * (rank + 1)];
                        let got = ag.exchange(rank, payload).unwrap();
                        assert_eq!(got[0].len(), round);
                        assert_eq!(got[1].len(), round * 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn peer_panic_poisons_instead_of_deadlocking() {
        let k = 3;
        let ag = AllGather::new(k);
        let mut handles = Vec::new();
        // Workers 0 and 2 run normally; worker 1 panics mid-round after a
        // successful first exchange.
        for rank in [0usize, 2] {
            let ag = ag.clone();
            handles.push(thread::spawn(move || -> Result<()> {
                let _guard = ag.guard();
                ag.exchange(rank, vec![rank as u8])?;
                // Round 2: worker 1 never deposits; this must error out, not hang.
                ag.exchange(rank, vec![rank as u8])?;
                Ok(())
            }));
        }
        let crasher = {
            let ag = ag.clone();
            thread::spawn(move || {
                let _guard = ag.guard();
                ag.exchange(1, vec![1]).unwrap();
                panic!("simulated oracle failure on worker 1");
            })
        };
        assert!(crasher.join().is_err(), "crasher must panic");
        for h in handles {
            let res = h.join().expect("survivors must not panic");
            let err = res.expect_err("survivors must observe poisoning");
            assert!(err.to_string().contains("poisoned"), "got: {err}");
        }
        assert!(ag.is_poisoned());
        // Any later round fails fast.
        assert!(ag.exchange(0, vec![0]).is_err());
    }

    #[test]
    fn double_deposit_is_an_error_not_a_panic() {
        let ag = AllGather::new(2);
        let ag2 = ag.clone();
        let t = thread::spawn(move || ag2.exchange(0, vec![0]));
        // Wait until the spawned thread's rank-0 deposit has actually
        // landed (a sleep would race on a loaded machine), then deposit on
        // the same rank — must error immediately.
        while ag.pending_deposits() == 0 {
            thread::yield_now();
        }
        let err = ag.exchange(0, vec![9]).expect_err("double deposit");
        assert!(err.to_string().contains("twice"), "got: {err}");
        // Unblock the waiter so the test ends cleanly.
        let got = ag.exchange(1, vec![1]).unwrap();
        assert_eq!(got.len(), 2);
        t.join().unwrap().unwrap();
    }

    #[test]
    fn exchange_timeout_poisons_instead_of_hanging() {
        // The satellite bug: a peer that never arrives (no panic, no Err)
        // used to block its peers forever. With a timeout the waiter
        // surfaces a NetError through the poison path instead.
        let ag = AllGather::with_timeout(2, Some(Duration::from_millis(50)));
        let t0 = Instant::now();
        let err = ag.exchange(0, vec![0]).expect_err("peer never arrives");
        assert!(t0.elapsed() < Duration::from_secs(10), "must not block forever");
        let msg = err.to_string();
        assert!(msg.contains("net error"), "timeout is a NetError: {msg}");
        assert!(msg.contains("timed out"), "got: {msg}");
        assert!(msg.contains("poisoned"), "propagates via poison: {msg}");
        assert!(ag.is_poisoned());
        // The late peer observes the poisoning, not a fresh round.
        let late = ag.exchange(1, vec![1]).expect_err("group is dead");
        assert!(late.to_string().contains("poisoned"), "got: {late}");
    }

    #[test]
    fn timeout_does_not_fire_when_peers_arrive() {
        let ag = AllGather::with_timeout(2, Some(Duration::from_secs(30)));
        let ag2 = ag.clone();
        let t = thread::spawn(move || ag2.exchange(1, vec![1]));
        let got = ag.exchange(0, vec![0]).unwrap();
        assert_eq!(got.len(), 2);
        t.join().unwrap().unwrap();
        assert!(!ag.is_poisoned());
    }

    #[test]
    fn allgather_is_a_transport_object() {
        // The trait-object surface the engine's Fabric uses.
        let t: Arc<dyn Transport> = AllGather::new(1);
        assert_eq!(t.peers(), 1);
        assert_eq!(t.kind(), "inproc");
        assert!(t.measured().is_none(), "nothing crosses a wire in-process");
        let got = t.exchange(0, vec![3, 1], Plane::Data).unwrap();
        assert_eq!(got[0].as_slice(), &[3, 1]);
        let _guard = PoisonGuard::new(t.clone());
        t.poison("test reason");
        let err = t.exchange(0, vec![0], Plane::Control).expect_err("poisoned");
        assert!(err.to_string().contains("test reason"), "reason carried: {err}");
    }

    #[test]
    fn merge_links_unions_endpoint_views() {
        let a = MeasuredWire {
            rank: 0,
            data_sent: vec![((0, 1), 10), ((0, 2), 10)],
            ..MeasuredWire::default()
        };
        let b = MeasuredWire {
            rank: 1,
            data_sent: vec![((1, 0), 7), ((1, 2), 7)],
            ..MeasuredWire::default()
        };
        let merged = MeasuredWire::merge_links(&[a, b]);
        assert_eq!(merged.len(), 4);
        assert_eq!(merged[&(0, 1)], 10);
        assert_eq!(merged[&(1, 2)], 7);
    }
}
