"""L2: the paper's compute graphs in JAX — a tiny-GPT causal LM and a
WGAN-GP-style 2D GAN — exposed as flat-parameter-vector functions so the
Rust coordinator can treat every model's dual vector uniformly as f32[P]
(DESIGN.md §5.2).

Everything here is build-time only: `aot.py` lowers these functions to HLO
text once; Rust loads and executes them via PJRT. LayerNorm (not BatchNorm)
throughout, matching the paper's experimental setup.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Flat parameter packing
# --------------------------------------------------------------------------


class Packer:
    """Maps a list of named shapes to slices of one flat f32 vector."""

    def __init__(self):
        self.shapes: List[Tuple[str, Tuple[int, ...]]] = []
        self.offsets: Dict[str, Tuple[int, int, Tuple[int, ...]]] = {}
        self.total = 0

    def add(self, name: str, shape: Tuple[int, ...]) -> None:
        size = int(np.prod(shape)) if shape else 1
        self.offsets[name] = (self.total, size, shape)
        self.shapes.append((name, shape))
        self.total += size

    def get(self, flat, name: str):
        off, size, shape = self.offsets[name]
        # Static slice: offsets are Python ints, so XLA sees a fixed layout.
        return flat[off : off + size].reshape(shape)

    def pack(self, arrays: Dict[str, np.ndarray]) -> np.ndarray:
        flat = np.zeros(self.total, dtype=np.float32)
        for name, (off, size, _shape) in self.offsets.items():
            a = np.asarray(arrays[name], dtype=np.float32).reshape(-1)
            assert a.size == size, f"{name}: {a.size} != {size}"
            flat[off : off + size] = a
        return flat


# --------------------------------------------------------------------------
# Tiny-GPT causal language model
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq: int = 64
    d_ff: int = 512
    batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


LM_PRESETS = {
    # ~0.8M params: CI / pytest scale.
    "small": LMConfig(vocab=256, d_model=128, n_layers=2, n_heads=4, seq=64, d_ff=512, batch=8),
    # ~3.4M params: quick E2E runs.
    "medium": LMConfig(vocab=256, d_model=256, n_layers=4, n_heads=8, seq=128, d_ff=1024, batch=8),
    # ~19M params: the recorded E2E experiment.
    "large": LMConfig(vocab=512, d_model=512, n_layers=6, n_heads=8, seq=128, d_ff=2048, batch=8),
}


def lm_packer(cfg: LMConfig) -> Packer:
    p = Packer()
    p.add("embed", (cfg.vocab, cfg.d_model))
    p.add("pos", (cfg.seq, cfg.d_model))
    for l in range(cfg.n_layers):
        p.add(f"l{l}.ln1.g", (cfg.d_model,))
        p.add(f"l{l}.ln1.b", (cfg.d_model,))
        p.add(f"l{l}.wq", (cfg.d_model, cfg.d_model))
        p.add(f"l{l}.wk", (cfg.d_model, cfg.d_model))
        p.add(f"l{l}.wv", (cfg.d_model, cfg.d_model))
        p.add(f"l{l}.wo", (cfg.d_model, cfg.d_model))
        p.add(f"l{l}.ln2.g", (cfg.d_model,))
        p.add(f"l{l}.ln2.b", (cfg.d_model,))
        p.add(f"l{l}.w1", (cfg.d_model, cfg.d_ff))
        p.add(f"l{l}.b1", (cfg.d_ff,))
        p.add(f"l{l}.w2", (cfg.d_ff, cfg.d_model))
        p.add(f"l{l}.b2", (cfg.d_model,))
    p.add("lnf.g", (cfg.d_model,))
    p.add("lnf.b", (cfg.d_model,))
    return p


def lm_param_count(cfg: LMConfig) -> int:
    return lm_packer(cfg).total


def lm_init(cfg: LMConfig, seed: int = 0) -> np.ndarray:
    """GPT-2-style init into a flat vector."""
    rng = np.random.default_rng(seed)
    p = lm_packer(cfg)
    arrays = {}
    for name, (_, _size, shape) in p.offsets.items():
        if name.endswith(".b") or name.endswith(".b1") or name.endswith(".b2"):
            arrays[name] = np.zeros(shape, np.float32)
        elif name.endswith(".g"):
            arrays[name] = np.ones(shape, np.float32)
        elif name == "pos":
            arrays[name] = rng.normal(0, 0.01, shape).astype(np.float32)
        else:
            scale = 0.02
            if name.endswith("wo") or name.endswith("w2"):
                # residual-branch scaling
                scale = 0.02 / np.sqrt(2.0 * cfg.n_layers)
            arrays[name] = rng.normal(0, scale, shape).astype(np.float32)
    return p.pack(arrays)


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def lm_loss(params_flat, tokens, cfg: LMConfig):
    """Mean next-token cross-entropy. tokens: i32[batch, seq]."""
    p = lm_packer(cfg)
    x = p.get(params_flat, "embed")[tokens] + p.get(params_flat, "pos")[None, :, :]
    b, s, dm = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    causal = jnp.tril(jnp.ones((s, s), bool))

    for l in range(cfg.n_layers):
        h = _layernorm(x, p.get(params_flat, f"l{l}.ln1.g"), p.get(params_flat, f"l{l}.ln1.b"))
        q = (h @ p.get(params_flat, f"l{l}.wq")).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        k = (h @ p.get(params_flat, f"l{l}.wk")).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        v = (h @ p.get(params_flat, f"l{l}.wv")).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(causal[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, dm)
        x = x + o @ p.get(params_flat, f"l{l}.wo")

        h = _layernorm(x, p.get(params_flat, f"l{l}.ln2.g"), p.get(params_flat, f"l{l}.ln2.b"))
        h = jax.nn.gelu(h @ p.get(params_flat, f"l{l}.w1") + p.get(params_flat, f"l{l}.b1"))
        x = x + h @ p.get(params_flat, f"l{l}.w2") + p.get(params_flat, f"l{l}.b2")

    x = _layernorm(x, p.get(params_flat, "lnf.g"), p.get(params_flat, "lnf.b"))
    logits = x @ p.get(params_flat, "embed").T  # tied embedding

    # next-token prediction: predict tokens[:, 1:] from positions [:-1]
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    targets = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def lm_step(params_flat, tokens, cfg: LMConfig):
    """AOT entry: (loss, grads_flat)."""
    loss, grads = jax.value_and_grad(lm_loss)(params_flat, tokens, cfg)
    return loss, grads


# --------------------------------------------------------------------------
# WGAN-GP-style 2D GAN (the paper's experiment, CPU-scale substitute)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GanConfig:
    nz: int = 4  # latent dim
    hidden: int = 256
    data_dim: int = 2
    batch: int = 256
    gp_lambda: float = 1.0


def _mlp_packer(prefix: str, sizes: List[int], p: Packer) -> None:
    for i in range(len(sizes) - 1):
        p.add(f"{prefix}.w{i}", (sizes[i], sizes[i + 1]))
        p.add(f"{prefix}.b{i}", (sizes[i + 1],))


def gan_packers(cfg: GanConfig) -> Tuple[Packer, Packer]:
    pg = Packer()
    _mlp_packer("g", [cfg.nz, cfg.hidden, cfg.hidden, cfg.data_dim], pg)
    pd = Packer()
    _mlp_packer("d", [cfg.data_dim, cfg.hidden, cfg.hidden, 1], pd)
    return pg, pd


def gan_param_counts(cfg: GanConfig) -> Tuple[int, int]:
    pg, pd = gan_packers(cfg)
    return pg.total, pd.total


def gan_init(cfg: GanConfig, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    pg, pd = gan_packers(cfg)

    def init_packer(p: Packer):
        arrays = {}
        for name, (_, _size, shape) in p.offsets.items():
            if ".b" in name:
                arrays[name] = np.zeros(shape, np.float32)
            else:
                fan_in = shape[0]
                arrays[name] = rng.normal(0, 1.0 / np.sqrt(fan_in), shape).astype(np.float32)
        return p.pack(arrays)

    return init_packer(pg), init_packer(pd)


def _mlp(flat, p: Packer, prefix: str, x, n_layers: int = 3):
    for i in range(n_layers):
        w = p.get(flat, f"{prefix}.w{i}")
        b = p.get(flat, f"{prefix}.b{i}")
        x = x @ w + b
        if i < n_layers - 1:
            x = jax.nn.leaky_relu(x, 0.2)
    return x


def generator(theta_g, z, cfg: GanConfig):
    pg, _ = gan_packers(cfg)
    return _mlp(theta_g, pg, "g", z)


def critic(theta_d, x, cfg: GanConfig):
    _, pd = gan_packers(cfg)
    return _mlp(theta_d, pd, "d", x)[..., 0]


def gan_disc_loss(theta_d, theta_g, real, z, eps, cfg: GanConfig):
    """WGAN-GP critic loss: E[D(fake)] − E[D(real)] + λ GP."""
    fake = generator(theta_g, z, cfg)
    loss_w = jnp.mean(critic(theta_d, fake, cfg)) - jnp.mean(critic(theta_d, real, cfg))
    # gradient penalty at interpolates
    x_hat = eps * real + (1.0 - eps) * fake

    def d_single(xi):
        return critic(theta_d, xi[None, :], cfg)[0]

    grads = jax.vmap(jax.grad(d_single))(x_hat)
    gp = jnp.mean((jnp.linalg.norm(grads, axis=-1) - 1.0) ** 2)
    return loss_w + cfg.gp_lambda * gp


def gan_gen_loss(theta_d, theta_g, z, cfg: GanConfig):
    fake = generator(theta_g, z, cfg)
    return -jnp.mean(critic(theta_d, fake, cfg))


def gan_disc_step(theta_d, theta_g, real, z, eps, cfg: GanConfig):
    """AOT entry: critic loss + grad wrt theta_d."""
    loss, grad = jax.value_and_grad(gan_disc_loss)(theta_d, theta_g, real, z, eps, cfg)
    return loss, grad


def gan_gen_step(theta_d, theta_g, z, cfg: GanConfig):
    """AOT entry: generator loss + grad wrt theta_g."""

    def loss_fn(tg):
        return gan_gen_loss(theta_d, tg, z, cfg)

    loss, grad = jax.value_and_grad(loss_fn)(theta_g)
    return loss, grad


def gan_disc_w_loss(theta_d, theta_g, real, z, cfg: GanConfig):
    """Wasserstein part of the critic loss only (no gradient penalty) —
    lowered separately so the Rust driver can reproduce the paper's
    GenBP / DiscBP / PenBP timing breakdown (Figure 3)."""
    fake = generator(theta_g, z, cfg)
    return jnp.mean(critic(theta_d, fake, cfg)) - jnp.mean(critic(theta_d, real, cfg))


def gan_pen_loss(theta_d, theta_g, real, z, eps, cfg: GanConfig):
    """Gradient-penalty term only (lambda * GP)."""
    fake = generator(theta_g, z, cfg)
    x_hat = eps * real + (1.0 - eps) * fake

    def d_single(xi):
        return critic(theta_d, xi[None, :], cfg)[0]

    grads = jax.vmap(jax.grad(d_single))(x_hat)
    gp = jnp.mean((jnp.linalg.norm(grads, axis=-1) - 1.0) ** 2)
    return cfg.gp_lambda * gp


def gan_disc_w_step(theta_d, theta_g, real, z, cfg: GanConfig):
    loss, grad = jax.value_and_grad(gan_disc_w_loss)(theta_d, theta_g, real, z, cfg)
    return loss, grad


def gan_pen_step(theta_d, theta_g, real, z, eps, cfg: GanConfig):
    loss, grad = jax.value_and_grad(gan_pen_loss)(theta_d, theta_g, real, z, eps, cfg)
    return loss, grad


def ring_of_gaussians(batch: int, seed: int, modes: int = 8, radius: float = 2.0,
                      sigma: float = 0.05) -> np.ndarray:
    """The classic 2D GAN benchmark dataset (build-time sampler; the Rust
    driver has its own identical implementation in train/data.rs)."""
    rng = np.random.default_rng(seed)
    which = rng.integers(0, modes, size=batch)
    angles = 2.0 * np.pi * which / modes
    centers = np.stack([radius * np.cos(angles), radius * np.sin(angles)], axis=1)
    return (centers + rng.normal(0, sigma, size=(batch, 2))).astype(np.float32)
