//! LSB-first bit writer/reader over byte buffers.
//!
//! The wire format packs sub-byte fields (sign bits, prefix codes); both
//! codecs and the quantizer wire format share these primitives. LSB-first
//! ordering keeps `write_bits`/`read_bits` branch-light: a 64-bit staging
//! register is flushed a byte at a time.

use crate::error::{Error, Result};

/// Reverse the low `n` bits of `v`. The LSB-first writer emits a value's
/// bit 0 first, so an MSB-first codeword (canonical Huffman, Elias
/// mantissas) goes on the wire as its bit-reversal — shared by both
/// codecs' word-at-a-time fast paths.
#[inline]
pub(crate) fn reverse_low_bits(v: u64, n: u32) -> u64 {
    debug_assert!(n >= 1 && n <= 64);
    v.reverse_bits() >> (64 - n)
}

/// Append-only bit sink backed by `Vec<u8>`.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// bytes already present when this writer took over (see [`Self::over`])
    base: usize,
    /// staging register, LSB-first
    acc: u64,
    /// number of valid bits in `acc`
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), base: 0, acc: 0, nbits: 0 }
    }

    /// Take over an existing buffer and *append* to it. Existing content is
    /// kept verbatim (it must be byte-aligned by construction — this writer
    /// starts at a byte boundary) and excluded from [`Self::bit_len`].
    /// The zero-allocation hot path hands its reusable payload buffer
    /// through here via `std::mem::take`, then reclaims it from
    /// [`Self::finish`].
    pub fn over(buf: Vec<u8>) -> Self {
        let base = buf.len();
        BitWriter { buf, base, acc: 0, nbits: 0 }
    }

    /// Bits written *by this writer* (content predating [`Self::over`] is
    /// not counted).
    #[inline]
    pub fn bit_len(&self) -> u64 {
        ((self.buf.len() - self.base) as u64) * 8 + self.nbits as u64
    }

    /// Write the low `n` bits of `value` (n <= 57 to keep the staging
    /// register from overflowing in one call).
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57, "write_bits supports at most 57 bits per call");
        debug_assert!(n == 64 || value < (1u64 << n), "value {value} does not fit in {n} bits");
        self.acc |= value << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write a full u32 (e.g. the f32 norm bits, C_b = 32).
    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.write_bits(v as u64 & 0xFFFF_FFFF, 32);
    }

    /// Write an f32 by bit pattern.
    #[inline]
    pub fn write_f32(&mut self, v: f32) {
        self.write_u32(v.to_bits());
    }

    /// Flush and return the byte buffer (final partial byte zero-padded).
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// Bit source over a byte slice (LSB-first, mirror of [`BitWriter`]).
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// next byte index
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nbits: 0 }
    }

    /// Bits consumed so far.
    pub fn bits_read(&self) -> u64 {
        (self.pos as u64) * 8 - self.nbits as u64
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.buf.len() {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits (n <= 57).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 57);
        if self.nbits < n {
            self.refill();
            if self.nbits < n {
                return Err(Error::Codec(format!(
                    "bitstream truncated: wanted {n} bits, {} available",
                    self.nbits
                )));
            }
        }
        let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        let v = self.acc & mask;
        self.acc >>= n;
        self.nbits -= n;
        Ok(v)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Result<bool> {
        Ok(self.read_bits(1)? == 1)
    }

    #[inline]
    pub fn read_u32(&mut self) -> Result<u32> {
        Ok(self.read_bits(32)? as u32)
    }

    #[inline]
    pub fn read_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.read_u32()?))
    }

    /// Peek up to `n` bits without consuming (fewer if the stream ends).
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> (u64, u32) {
        self.refill();
        let avail = self.nbits.min(n);
        let mask = if avail >= 64 { u64::MAX } else { (1u64 << avail) - 1 };
        (self.acc & mask, avail)
    }

    /// Consume `n` bits previously peeked, clamped to the bits actually
    /// buffered. A [`Self::peek_bits`] can return fewer bits than requested
    /// near the end of the stream; skipping more than that is a caller bug,
    /// but it must not corrupt the stream — the old `debug_assert!`-only
    /// guard let `self.nbits` wrap in release builds, silently turning the
    /// rest of the message into garbage. Clamping instead leaves the reader
    /// drained, so the next read reports truncation.
    #[inline]
    pub fn skip_bits(&mut self, n: u32) {
        let n = n.min(self.nbits);
        self.acc >>= n;
        self.nbits -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn roundtrip_fixed_patterns() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bit(true);
        w.write_u32(0xDEAD_BEEF);
        w.write_f32(3.5);
        w.write_bits(0x7F, 7);
        let bytes = w.finish();

        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert!(r.read_bit().unwrap());
        assert_eq!(r.read_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.read_f32().unwrap(), 3.5);
        assert_eq!(r.read_bits(7).unwrap(), 0x7F);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let _ = r.read_bits(2).unwrap();
        // Only padding left; reading 32 bits must fail.
        assert!(r.read_bits(32).is_err());
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.write_u32(7);
        assert_eq!(w.bit_len(), 33);
    }

    #[test]
    fn prop_roundtrip_random_fields() {
        forall("bitio roundtrip", 200, |g| {
            let n_fields = g.usize_in(1, 64);
            let fields: Vec<(u64, u32)> = (0..n_fields)
                .map(|_| {
                    let n = g.usize_in(1, 57) as u32;
                    let v = g.u64_below(1u64 << n.min(63));
                    (v & ((1u64 << n) - 1), n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.write_bits(v, n);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &fields {
                assert_eq!(r.read_bits(n).unwrap(), v);
            }
        });
    }

    #[test]
    fn skip_more_than_buffered_saturates_instead_of_wrapping() {
        // Regression: skip_bits(n) with n > buffered bits used to wrap
        // `nbits` (u32 underflow) in release builds and silently corrupt
        // every subsequent read. It must drain the reader instead, so the
        // next read reports truncation.
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let (_, avail) = r.peek_bits(8);
        assert_eq!(avail, 8); // one padded byte buffered
        r.skip_bits(13); // more than buffered: clamps to 8
        assert_eq!(r.bits_read(), 8);
        assert!(r.read_bits(1).is_err(), "drained reader must report truncation");
        // An entirely fresh reader skipping past the end behaves the same.
        let mut r2 = BitReader::new(&bytes);
        r2.skip_bits(64);
        assert_eq!(r2.bits_read(), 0, "nothing buffered yet: nothing skipped");
        assert_eq!(r2.read_bits(3).unwrap(), 0b101);
    }

    #[test]
    fn over_appends_and_counts_only_new_bits() {
        let mut w = BitWriter::new();
        w.write_u32(0xAABB_CCDD);
        let bytes = w.finish();
        let mut w2 = BitWriter::over(bytes);
        assert_eq!(w2.bit_len(), 0, "pre-existing bytes are not counted");
        w2.write_bits(0b11, 2);
        assert_eq!(w2.bit_len(), 2);
        let all = w2.finish();
        assert_eq!(all.len(), 5);
        let mut r = BitReader::new(&all);
        assert_eq!(r.read_u32().unwrap(), 0xAABB_CCDD);
        assert_eq!(r.read_bits(2).unwrap(), 0b11);
    }

    #[test]
    fn over_reuses_capacity_without_reallocating() {
        let mut buf = Vec::with_capacity(64);
        for round in 0..3u64 {
            buf.clear();
            let ptr = buf.as_ptr();
            let mut w = BitWriter::over(std::mem::take(&mut buf));
            w.write_bits(round, 7);
            w.write_u32(round as u32);
            buf = w.finish();
            assert_eq!(buf.as_ptr(), ptr, "steady state must reuse the buffer");
            assert_eq!(buf.capacity(), 64);
        }
    }

    #[test]
    fn peek_then_skip_matches_read() {
        let mut w = BitWriter::new();
        w.write_bits(0b1101_0110, 8);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let (p, avail) = r.peek_bits(5);
        assert_eq!(avail, 5);
        assert_eq!(p, 0b1_0110);
        r.skip_bits(5);
        assert_eq!(r.read_bits(3).unwrap(), 0b110);
    }
}
