//! E1 — Figure 1 (left) / Figure 2a: quality-metric evolution during GAN
//! training for FP32 vs UQ8 vs UQ4.
//!
//! Paper claim: "this speedup does not drastically change the performance"
//! — the three trajectories should overlap (same final quality band) while
//! the quantized modes put far fewer bits on the wire.
//!
//! Substitution (DESIGN.md): CIFAR-10 WGAN-GP + FID → ring-of-Gaussians
//! WGAN-GP + energy distance. Identical code path, CPU-feasible scale.

use qgenx::benchkit::{scaled, Table};
use qgenx::net::NetModel;
use qgenx::runtime::{default_artifacts_dir, Runtime};
use qgenx::train::{GanMode, GanTrainConfig, GanTrainer};

fn main() {
    println!("== E1 / Figure 1 (left): FID-analog evolution, FP32 vs UQ8 vs UQ4 ==\n");
    let Some(dir) = default_artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    let steps = scaled(150, 20);

    let mut curves = Vec::new();
    for mode in [GanMode::Fp32, GanMode::Uq8, GanMode::Uq4] {
        let cfg = GanTrainConfig {
            mode,
            steps,
            workers: 3,
            eval_every: (steps / 6).max(1),
            ..Default::default()
        };
        let mut tr = GanTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
        let rec = tr.train().unwrap();
        curves.push((mode, rec, tr.traffic.bits_sent));
    }

    let mut table = Table::new(&["step", "FP32 ED", "UQ8 ED", "UQ4 ED"]);
    let n = curves[0].1.get("metric").unwrap().points.len();
    let mut csv = Vec::new();
    for i in 0..n {
        let row = vec![
            format!("{:.0}", curves[0].1.get("metric").unwrap().points[i].0),
            format!("{:.4}", curves[0].1.get("metric").unwrap().points[i].1),
            format!("{:.4}", curves[1].1.get("metric").unwrap().points[i].1),
            format!("{:.4}", curves[2].1.get("metric").unwrap().points[i].1),
        ];
        table.row(&row);
        csv.push(row);
    }
    table.print();

    println!();
    for (mode, rec, bits) in &curves {
        let first = rec.get("metric").unwrap().points.first().unwrap().1;
        let last = rec.get("metric").unwrap().last().unwrap();
        println!(
            "{}: energy distance {first:.3} -> {last:.3}, wire {:.1} MB",
            mode.name(),
            *bits as f64 / 8e6
        );
        assert!(last < first, "{} did not improve the metric", mode.name());
    }
    // Quality overlap check: quantized finals within a band of FP32's.
    let f_fp32 = curves[0].1.get("metric").unwrap().last().unwrap();
    let f_uq4 = curves[2].1.get("metric").unwrap().last().unwrap();
    println!(
        "\nfinal-quality ratio UQ4/FP32 = {:.2} (paper: compression does not degrade quality)",
        f_uq4 / f_fp32
    );
    qgenx::benchkit::write_csv(
        "results/fig1_gan_quality.csv",
        &["step", "fp32", "uq8", "uq4"],
        &csv,
    )
    .unwrap();
    println!("csv -> results/fig1_gan_quality.csv");
}
