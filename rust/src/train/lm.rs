//! Distributed data-parallel tiny-GPT training with quantized gradient
//! exchange — the end-to-end validation driver (DESIGN.md E12): proves the
//! full stack composes (Pallas kernel → JAX grads → AOT HLO → PJRT →
//! quantize → entropy-code → allgather → optimizer) on a real workload.
//!
//! Two optimizers:
//! * [`LmOptimizer::QGenX`] — the paper's method (dual-extrapolation
//!   variant with the adaptive step-size) applied to `A = ∇L`, the
//!   gradient operator. Faithful but 2 oracle calls/step.
//! * [`LmOptimizer::Msgd`] — momentum SGD over quantized averaged grads
//!   (classic QSGD-style distributed training); 1 oracle call/step, the
//!   configuration used for the recorded loss-curve experiment.

use super::data::TokenStream;
use crate::algo::method_state;
use crate::config::{AlgoConfig, Method, QuantConfig};
use crate::coordinator::Compressor;
use crate::error::Result;
use crate::metrics::Recorder;
use crate::net::{NetModel, TrafficStats};
use crate::runtime::{Arg, Runtime};
use crate::util::{axpy, mean_into, Rng};
use std::time::Instant;

/// Optimizer selection for the LM driver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LmOptimizer {
    /// Q-GenX (dual extrapolation, adaptive step) — 2 exchanges/step.
    QGenX,
    /// Momentum SGD on quantized averaged gradients — 1 exchange/step.
    Msgd { momentum_pct: u8 },
}

/// LM training configuration.
#[derive(Clone, Debug)]
pub struct LmTrainConfig {
    pub optimizer: LmOptimizer,
    /// VI method driving the QGenX optimizer path (`--algo`); ignored by
    /// the MSGD baseline, which is its own update rule.
    pub method: Method,
    pub quant: QuantConfig,
    pub workers: usize,
    pub steps: usize,
    pub lr: f64,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for LmTrainConfig {
    fn default() -> Self {
        LmTrainConfig {
            optimizer: LmOptimizer::Msgd { momentum_pct: 90 },
            method: Method::QGenX,
            quant: QuantConfig::default(),
            workers: 3,
            steps: 200,
            lr: 0.05,
            eval_every: 10,
            seed: 3,
        }
    }
}

/// The distributed LM trainer.
pub struct LmTrainer<'rt> {
    rt: &'rt mut Runtime,
    cfg: LmTrainConfig,
    params: Vec<f32>,
    momentum: Vec<f32>,
    comps: Vec<Compressor>,
    streams: Vec<TokenStream>,
    net: NetModel,
    pub traffic: TrafficStats,
    /// measured seconds in HLO grad execution
    pub grad_time: f64,
    /// measured codec + modeled network seconds
    pub comm_time: f64,
}

impl<'rt> LmTrainer<'rt> {
    pub fn new(rt: &'rt mut Runtime, cfg: LmTrainConfig, net: NetModel) -> Result<Self> {
        let m = rt.manifest().clone();
        let params = rt.load_f32_blob(&m.lm_init_file)?;
        let root = Rng::seed_from(cfg.seed);
        let comps = (0..cfg.workers)
            .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 31)))
            .collect::<Result<Vec<_>>>()?;
        // Each worker owns a private data shard (different stream seed) —
        // the paper's "processors partition a large dataset among
        // themselves".
        let streams =
            (0..cfg.workers).map(|w| TokenStream::new(m.lm.vocab, cfg.seed ^ (w as u64 * 7919))).collect();
        let d = params.len();
        Ok(LmTrainer {
            rt,
            cfg,
            params,
            momentum: vec![0.0; d],
            comps,
            streams,
            net,
            traffic: TrafficStats::default(),
            grad_time: 0.0,
            comm_time: 0.0,
        })
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }


    /// QAda level-update step: exchange sufficient statistics (tiny —
    /// `4 + 4·hist_bins` bytes each under stat wire-format v2, counted as
    /// traffic) and re-optimize all workers' levels from the identical
    /// pooled payload list.
    fn maybe_update_levels(&mut self, t: usize) -> Result<()> {
        let every = self.cfg.quant.update_every;
        // Fire at an early warmup step (so short runs still adapt once),
        // then on the periodic schedule U.
        let fire = every != 0 && (t == every.min(10) || t % every == 0);
        if !fire {
            return Ok(());
        }
        // The pooled exchange is the coordinator engine's shared stat round
        // (one home for the gather-record-refresh body; a no-op for the
        // fixed-level modes whose payloads are all empty).
        crate::coordinator::pool_local_stats(&mut self.comps, &self.net, &mut self.traffic)
            .map(|_| ())
    }

    /// All K workers' local gradients at `params` (measured).
    fn local_grads(&mut self, params: &[f32]) -> Result<(f64, Vec<Vec<f32>>)> {
        let m = self.rt.manifest().clone();
        let mut tokens = Vec::new();
        let mut grads = Vec::with_capacity(self.cfg.workers);
        let mut loss_sum = 0.0f64;
        let t0 = Instant::now();
        for w in 0..self.cfg.workers {
            self.streams[w].next_batch(m.lm.batch, m.lm.seq, &mut tokens);
            let (loss, g) = self.rt.run_loss_grad(
                "lm_step",
                &[Arg::F32(params, &[m.lm.params]), Arg::I32(&tokens, &[m.lm.batch, m.lm.seq])],
            )?;
            loss_sum += loss as f64;
            grads.push(g);
        }
        // Parallel-cluster wall model: K workers' backward passes overlap.
        self.grad_time += t0.elapsed().as_secs_f64() / self.cfg.workers as f64;
        Ok((loss_sum / self.cfg.workers as f64, grads))
    }

    /// Quantize + allgather + decode + average.
    fn exchange_mean(&mut self, locals: &[Vec<f32>]) -> Result<Vec<f32>> {
        let d = self.params.len();
        let k = locals.len() as f64;
        let t0 = Instant::now();
        let mut bits = Vec::with_capacity(locals.len());
        let mut wires = Vec::with_capacity(locals.len());
        for (w, v) in locals.iter().enumerate() {
            let (bytes, b) = self.comps[w].compress(v)?;
            bits.push(b);
            wires.push(bytes);
        }
        let encode = t0.elapsed().as_secs_f64() / k; // workers encode in parallel
        let t1 = Instant::now();
        let mut decoded = vec![vec![0.0f32; d]; locals.len()];
        for (w, bytes) in wires.iter().enumerate() {
            self.comps[0].decompress(bytes, &mut decoded[w])?;
        }
        let codec = encode + t1.elapsed().as_secs_f64(); // each worker decodes all K
        self.traffic.add_compute(codec);
        self.traffic.record_allgather(&bits, &self.net);
        self.comm_time += codec
            + self
                .net
                .allgather_time(&bits.iter().map(|&b| crate::net::bits_to_bytes(b)).collect::<Vec<_>>());
        let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
        let mut mean = vec![0.0f32; d];
        mean_into(&refs, &mut mean);
        Ok(mean)
    }

    /// Train; recorder series: `loss`, `bits_cum`, `time_cum`.
    pub fn train(&mut self) -> Result<Recorder> {
        match self.cfg.optimizer {
            LmOptimizer::Msgd { momentum_pct } => self.train_msgd(momentum_pct as f32 / 100.0),
            LmOptimizer::QGenX => self.train_qgenx(),
        }
    }

    fn train_msgd(&mut self, beta: f32) -> Result<Recorder> {
        let mut rec = Recorder::new();
        let lr = self.cfg.lr as f32;
        for t in 1..=self.cfg.steps {
            self.maybe_update_levels(t)?;
            let p = self.params.clone();
            let (loss, locals) = self.local_grads(&p)?;
            let mean = self.exchange_mean(&locals)?;
            // momentum: m = beta m + g; params -= lr m
            for i in 0..self.momentum.len() {
                self.momentum[i] = beta * self.momentum[i] + mean[i];
            }
            let m = self.momentum.clone();
            axpy(-lr, &m, &mut self.params);
            if t % self.cfg.eval_every.max(1) == 0 || t == 1 || t == self.cfg.steps {
                rec.push("loss", t as f64, loss);
                rec.push("bits_cum", t as f64, self.traffic.bits_sent as f64);
                rec.push("time_cum", t as f64, self.grad_time + self.comm_time);
            }
        }
        self.finalize(&mut rec);
        Ok(rec)
    }

    /// Quantize + allgather + decode, keeping all K per-worker vectors
    /// (the method states need them, not the mean).
    fn exchange_decode(&mut self, locals: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let d = self.params.len();
        let t0 = Instant::now();
        let mut bits = Vec::with_capacity(locals.len());
        let mut decoded = vec![vec![0.0f32; d]; locals.len()];
        for (w, v) in locals.iter().enumerate() {
            let (bytes, b) = self.comps[w].compress(v)?;
            bits.push(b);
            self.comps[w].decompress(&bytes, &mut decoded[w])?;
        }
        self.comm_time += t0.elapsed().as_secs_f64();
        self.traffic.record_allgather(&bits, &self.net);
        Ok(decoded)
    }

    fn train_qgenx(&mut self) -> Result<Recorder> {
        let mut rec = Recorder::new();
        let k = self.cfg.workers;
        let algo = AlgoConfig {
            method: self.cfg.method,
            gamma0: self.cfg.lr,
            adaptive_step: true,
            ..AlgoConfig::default()
        };
        let x0 = self.params.clone();
        let mut state = method_state(&algo, &x0, k);
        for t in 1..=self.cfg.steps {
            self.maybe_update_levels(t)?;
            // Base leg — only methods whose cadence asks for it pay the
            // oracle pass and the exchange (PEG skips both).
            let mut base_loss = None;
            let decoded_base = match state.base_query() {
                Some(xq) => {
                    let (loss, locals) = self.local_grads(&xq)?;
                    base_loss = Some(loss);
                    Some(self.exchange_decode(&locals)?)
                }
                None => None,
            };
            let x_half = state.extrapolate(decoded_base.as_deref().unwrap_or(&[]))?;

            let (half_loss, locals_half) = self.local_grads(&x_half)?;
            let decoded_half = self.exchange_decode(&locals_half)?;
            state.update(&decoded_half)?;
            self.params = state.x_world();
            let loss = base_loss.unwrap_or(half_loss);

            if t % self.cfg.eval_every.max(1) == 0 || t == 1 || t == self.cfg.steps {
                rec.push("loss", t as f64, loss);
                rec.push("bits_cum", t as f64, self.traffic.bits_sent as f64);
                rec.push("time_cum", t as f64, self.grad_time + self.comm_time);
                rec.push("gamma", t as f64, state.gamma());
            }
        }
        if self.cfg.method != Method::QGenX {
            rec.set_scalar("oracle_calls", state.oracle_calls() as f64);
            rec.set_scalar("exchanges_per_step", state.exchanges_per_step());
            for (name, v) in state.method_scalars() {
                rec.set_scalar(name, v);
            }
        }
        self.finalize(&mut rec);
        Ok(rec)
    }

    fn finalize(&self, rec: &mut Recorder) {
        rec.set_scalar("total_bits", self.traffic.bits_sent as f64);
        rec.set_scalar("grad_time", self.grad_time);
        rec.set_scalar("comm_time", self.comm_time);
        rec.set_scalar("params", self.params.len() as f64);
        // Layer-wise runs (`quant.layers` / `--layers N`: the parameter
        // vector auto-splits into equal bucket-aligned ranges) report the
        // per-layer bit/variance scalars like the VI runners do.
        self.comps[0].emit_layer_scalars(rec);
    }

    /// Held-out loss on a fresh stream.
    pub fn eval_loss(&mut self) -> Result<f64> {
        let m = self.rt.manifest().clone();
        let mut stream = TokenStream::new(m.lm.vocab, self.cfg.seed ^ 0xeeee);
        let mut tokens = Vec::new();
        stream.next_batch(m.lm.batch, m.lm.seq, &mut tokens);
        let outs = self.rt.run(
            "lm_loss",
            &[
                Arg::F32(&self.params, &[m.lm.params]),
                Arg::I32(&tokens, &[m.lm.batch, m.lm.seq]),
            ],
        )?;
        Ok(outs[0][0] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    #[test]
    fn msgd_reduces_loss() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let cfg = LmTrainConfig { steps: 30, workers: 2, eval_every: 5, ..Default::default() };
        let mut tr = LmTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
        let rec = tr.train().unwrap();
        let losses = rec.get("loss").unwrap();
        let first = losses.points.first().unwrap().1;
        let last = losses.last().unwrap();
        assert!(last < first - 0.3, "loss should fall: {first} -> {last}");
        assert!(tr.traffic.bits_sent > 0);
    }

    #[test]
    fn qgenx_optimizer_runs() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let cfg = LmTrainConfig {
            optimizer: LmOptimizer::QGenX,
            steps: 10,
            workers: 2,
            eval_every: 2,
            lr: 0.5,
            ..Default::default()
        };
        let mut tr = LmTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
        let rec = tr.train().unwrap();
        assert!(rec.get("loss").unwrap().last().unwrap().is_finite());
        let eval = tr.eval_loss().unwrap();
        assert!(eval.is_finite());
    }
}
