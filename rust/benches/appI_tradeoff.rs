//! E9 — Appendix I: the trade-off between the number of iterations to
//! reach an ε gap and the time per iteration.
//!
//! Sweeping the bit budget `s`: more aggressive compression raises ε_Q
//! (more iterations, `T(ε, ε̄_Q) ∝ (ε̄_Q M² + σ²)²/ε²`) but shrinks Δ
//! (time/iteration at a given bandwidth). The total wall-clock `T·Δ` is
//! U-shaped; the optimum depends on the network — we report the sweep at
//! 1 GbE and 10 GbE to show the optimum moving toward less compression on
//! the faster network, exactly the Appendix-I discussion.

use qgenx::benchkit::{scaled, Table};
use qgenx::config::{ExperimentConfig, QuantMode};
use qgenx::coordinator::run_experiment;
use qgenx::net::NetModel;

/// Iterations until the ergodic dist falls below `target` (capped).
fn iters_to_target(cfg: &ExperimentConfig, target: f64) -> (usize, f64, f64) {
    let rec = run_experiment(cfg).unwrap();
    let dist = rec.get("dist").unwrap();
    let times = rec.get("sim_time_cum").unwrap();
    for (i, (x, y)) in dist.points.iter().enumerate() {
        if *y <= target {
            return (*x as usize, times.points[i].1, *y);
        }
    }
    (cfg.iters, times.points.last().unwrap().1, dist.last().unwrap())
}

fn main() {
    println!("== E9 / Appendix I: iterations vs time-per-iteration trade-off ==\n");
    let target = 0.35;
    let iters_cap = scaled(6000, 800);

    for (net_name, net) in [("1GbE", NetModel::gbe()), ("10GbE", NetModel::ten_gbe())] {
        println!("-- network: {net_name} --");
        let mut table = Table::new(&[
            "mode", "bits/coord", "T(eps)", "sim secs/iter", "total sim secs",
        ]);
        let mut csv = Vec::new();
        let mut best: Option<(String, f64)> = None;
        for mode in ["s1", "s3", "uq4", "uq8", "fp32"] {
            let mut cfg = ExperimentConfig::default();
            cfg.problem.kind = "quadratic".into();
            // Large-ish d so comm time actually matters.
            cfg.problem.dim = 512;
            cfg.problem.noise = "absolute".into();
            cfg.problem.sigma = 1.0;
            cfg.workers = 3;
            cfg.iters = iters_cap;
            cfg.eval_every = iters_cap / 40;
            cfg.algo.gamma0 = 0.3;
            cfg.seed = 9;
            cfg.quant.mode = QuantMode::parse(mode).unwrap();
            cfg.net.bandwidth_bps = net.bandwidth_bps;
            cfg.net.latency_s = net.latency_s;
            let (t_eps, total_time, reached) = iters_to_target(&cfg, target);
            let rec = run_experiment(&cfg).unwrap();
            let bits_per_coord = rec.scalar("bits_per_round_per_worker").unwrap()
                / cfg.problem.dim as f64;
            let per_iter = total_time / t_eps.max(1) as f64;
            let row = vec![
                mode.to_string(),
                format!("{bits_per_coord:.2}"),
                if reached <= target { t_eps.to_string() } else { format!(">{t_eps}") },
                format!("{:.2e}", per_iter),
                format!("{total_time:.4}"),
            ];
            table.row(&row);
            csv.push(row);
            if reached <= target {
                match &best {
                    Some((_, bt)) if *bt <= total_time => {}
                    _ => best = Some((mode.to_string(), total_time)),
                }
            }
        }
        table.print();
        if let Some((m, t)) = best {
            println!("fastest-to-eps on {net_name}: {m} ({t:.4} sim-s)\n");
        }
        qgenx::benchkit::write_csv(
            &format!("results/appI_tradeoff_{net_name}.csv"),
            &["mode", "bits_per_coord", "t_eps", "secs_per_iter", "total_secs"],
            &csv,
        )
        .unwrap();
    }
    println!("paper shape (App. I): compressing harder lowers Δ but raises T(ε); the");
    println!("best wall-clock sits at an intermediate bit budget that grows with bandwidth.");
}
