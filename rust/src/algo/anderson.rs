//! Extra-gradient with safeguarded Anderson acceleration — EG-AA(1).
//!
//! One iteration is a plain extra-gradient step viewed as a fixed-point
//! map, plus a depth-1 Anderson candidate that is accepted only under a
//! residual-decrease guard:
//!
//! ```text
//! Y_t   = Z_t − γ_t (1/K) Σ_k V̂_k(Z_t)          // extrapolation leg
//! R_t   = γ_t (1/K) Σ_k V̂_k(Y_t)                // the EG residual
//! Z_eg  = Z_t − R_t                              // plain EG step
//! α_t   = ⟨R_t, R_t − R_{t−1}⟩ / ‖R_t − R_{t−1}‖²
//! Z_aa  = Z_eg − α_t ((Z_t − Z_{t−1}) − (R_t − R_{t−1}))
//! Z_{t+1} = Z_aa   if ‖R_t‖ ≤ ρ‖R_{t−1}‖, the mixing is well-posed
//!                  (denominator not tiny, α clamped, candidate finite)
//! Z_{t+1} = Z_eg   otherwise                     // the safeguard
//! ```
//!
//! The guard decides from quantities the cadence already computed —
//! `R_t`, `R_{t−1}` and the iterates — so a rejected candidate costs
//! nothing: the per-iteration cadence stays exactly two oracle calls and
//! two quantized exchanges, identical to extra-gradient, and the
//! safeguard can never add a wire round. (Cf. Anderson acceleration for
//! fixed-point iterations, Walker & Ni 2011; safeguarding à la Zhang,
//! O'Donoghue & Boyd 2020.)
//!
//! Under heavy noise or coarse quantization the residuals rarely shrink
//! monotonically, the guard keeps rejecting, and the method degrades
//! gracefully to plain (quantized) extra-gradient; near the solution
//! under relative noise the guard opens and the AA(1) candidate does its
//! work.

use crate::algo::method::MethodState;
use crate::algo::stepsize::AdaptiveStepSize;
use crate::algo::qgenx::QGenXPhase;
use crate::error::{Error, Result};
use crate::util::{axpy, mean_into, norm2_sq};

/// Residual-decrease factor ρ: the Anderson candidate is only considered
/// while ‖R_t‖ ≤ ρ‖R_{t−1}‖.
const SAFEGUARD_RHO: f64 = 0.9;
/// Mixing weight clamp: |α_t| is capped to keep a near-degenerate
/// secant from catapulting the iterate.
const ALPHA_CAP: f64 = 5.0;
/// Denominator floor for the secant ‖R_t − R_{t−1}‖².
const DENOM_TINY: f64 = 1e-24;

/// Safeguarded EG-AA(1) state for `K` workers; implements
/// [`MethodState`]. Shifted coordinates around `x0`, like the other
/// methods.
#[derive(Clone, Debug)]
pub struct AndersonEg {
    d: usize,
    k: usize,
    x0: Vec<f32>,
    /// Z_t (shifted).
    z: Vec<f32>,
    /// Y_t (shifted) — the extrapolated point of the current iteration.
    y: Vec<f32>,
    /// Σ_t Y_t in f64 for the ergodic average.
    y_sum: Vec<f64>,
    /// The base duals of the current iteration (feeds the step-size pair).
    cur_base: Vec<Vec<f32>>,
    /// Z_{t−1} and R_{t−1} for the depth-1 secant.
    prev_z: Option<Vec<f32>>,
    prev_r: Option<Vec<f32>>,
    prev_r_norm_sq: f64,
    step: AdaptiveStepSize,
    /// γ_t captured at `extrapolate`, reused for the residual.
    gamma_t: f64,
    t: usize,
    /// Iterations where the Anderson candidate was accepted.
    aa_accepted: u64,
    phase: QGenXPhase,
    mean_buf: Vec<f32>,
}

impl AndersonEg {
    pub fn new(x0: &[f32], k: usize, gamma0: f64, adaptive: bool) -> Self {
        let d = x0.len();
        AndersonEg {
            d,
            k,
            x0: x0.to_vec(),
            z: vec![0.0; d],
            y: vec![0.0; d],
            y_sum: vec![0.0; d],
            cur_base: Vec::new(),
            prev_z: None,
            prev_r: None,
            prev_r_norm_sq: 0.0,
            step: AdaptiveStepSize::new(gamma0, k, adaptive),
            gamma_t: 0.0,
            t: 0,
            aa_accepted: 0,
            phase: QGenXPhase::AwaitBase,
            mean_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// How many completed iterations accepted the Anderson candidate
    /// (the rest fell back to the plain EG step).
    pub fn aa_accepted_steps(&self) -> u64 {
        self.aa_accepted
    }

    /// Y_t in world coordinates.
    pub fn y_world(&self) -> Vec<f32> {
        let mut out = self.x0.clone();
        axpy(1.0, &self.y, &mut out);
        out
    }
}

impl MethodState for AndersonEg {
    /// EG-AA queries a fresh base at Z_t, like extra-gradient.
    fn base_query(&self) -> Option<Vec<f32>> {
        let mut out = self.x0.clone();
        axpy(1.0, &self.z, &mut out);
        Some(out)
    }

    fn extrapolate(&mut self, base_vectors: &[Vec<f32>]) -> Result<Vec<f32>> {
        if self.phase != QGenXPhase::AwaitBase {
            return Err(Error::Coordinator("extrapolate called out of phase".into()));
        }
        if base_vectors.len() != self.k {
            return Err(Error::Coordinator(format!(
                "EG-AA needs {} base vectors, got {}",
                self.k,
                base_vectors.len()
            )));
        }
        for v in base_vectors {
            if v.len() != self.d {
                return Err(Error::Coordinator("base vector dim mismatch".into()));
            }
        }
        self.cur_base = base_vectors.to_vec();
        self.gamma_t = self.step.gamma();
        let refs: Vec<&[f32]> = self.cur_base.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        self.y.copy_from_slice(&self.z);
        axpy(-(self.gamma_t as f32), &self.mean_buf, &mut self.y);
        self.phase = QGenXPhase::AwaitHalf;
        Ok(self.y_world())
    }

    fn update(&mut self, half_vectors: &[Vec<f32>]) -> Result<()> {
        if self.phase != QGenXPhase::AwaitHalf {
            return Err(Error::Coordinator("update called out of phase".into()));
        }
        if half_vectors.len() != self.k {
            return Err(Error::Coordinator(format!(
                "need {} half vectors, got {}",
                self.k,
                half_vectors.len()
            )));
        }
        for v in half_vectors {
            if v.len() != self.d {
                return Err(Error::Coordinator("half vector dim mismatch".into()));
            }
        }
        // Ergodic average accumulates Y_t.
        for i in 0..self.d {
            self.y_sum[i] += self.y[i] as f64;
        }
        // R_t = γ_t mean(V̂(Y_t)); Z_eg = Z_t − R_t.
        let refs: Vec<&[f32]> = half_vectors.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        let g = self.gamma_t as f32;
        let r: Vec<f32> = self.mean_buf.iter().map(|v| g * v).collect();
        let r_norm_sq = norm2_sq(&r);

        let mut z_next: Vec<f32> = (0..self.d).map(|i| self.z[i] - r[i]).collect();
        let guard_open = self.prev_r.is_some()
            && r_norm_sq.sqrt() <= SAFEGUARD_RHO * self.prev_r_norm_sq.sqrt();
        if guard_open {
            let (zp, rp) = (self.prev_z.as_ref().unwrap(), self.prev_r.as_ref().unwrap());
            let mut denom = 0.0f64;
            let mut numer = 0.0f64;
            for i in 0..self.d {
                let dr = (r[i] - rp[i]) as f64;
                denom += dr * dr;
                numer += r[i] as f64 * dr;
            }
            if denom > DENOM_TINY {
                let alpha = (numer / denom).clamp(-ALPHA_CAP, ALPHA_CAP) as f32;
                let cand: Vec<f32> = (0..self.d)
                    .map(|i| {
                        self.z[i] - r[i] - alpha * ((self.z[i] - zp[i]) - (r[i] - rp[i]))
                    })
                    .collect();
                if cand.iter().all(|v| v.is_finite()) {
                    z_next = cand;
                    self.aa_accepted += 1;
                }
            }
        }

        // The shared adaptive rule learns ‖base − half‖² per worker.
        self.step.observe_pairs(&self.cur_base, half_vectors);
        self.prev_z = Some(std::mem::take(&mut self.z));
        self.prev_r = Some(r);
        self.prev_r_norm_sq = r_norm_sq;
        self.z = z_next;
        self.t += 1;
        self.phase = QGenXPhase::AwaitBase;
        Ok(())
    }

    fn gamma(&self) -> f64 {
        self.step.gamma()
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn x_world(&self) -> Vec<f32> {
        let mut out = self.x0.clone();
        axpy(1.0, &self.z, &mut out);
        out
    }

    fn ergodic_average(&self) -> Vec<f32> {
        let t = self.t.max(1) as f64;
        let mut out = self.x0.clone();
        for i in 0..self.d {
            out[i] += (self.y_sum[i] / t) as f32;
        }
        out
    }

    fn shift_world(&mut self, target: &[f32]) -> Result<()> {
        if self.phase != QGenXPhase::AwaitBase {
            return Err(Error::Coordinator("shift_world called mid-iteration".into()));
        }
        if target.len() != self.d {
            return Err(Error::Coordinator("shift_world target dim mismatch".into()));
        }
        // The secant history (prev_z, prev_r) lives in shifted coordinates
        // and is translation-invariant — only the origin moves.
        let cur = self.x_world();
        for i in 0..self.d {
            self.x0[i] += target[i] - cur[i];
        }
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        2 * self.t as u64
    }

    fn exchanges_per_step(&self) -> f64 {
        2.0
    }

    fn method_scalars(&self) -> Vec<(&'static str, f64)> {
        vec![("aa_accepted_steps", self.aa_accepted as f64)]
    }

    fn clone_box(&self) -> Box<dyn MethodState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{ExactOracle, MonotoneQuadratic, Operator, Oracle, RotationOperator};
    use crate::util::{dist_sq, Rng};
    use std::sync::Arc;

    /// Drive EG-AA with `k` exact oracles for `iters` iterations.
    fn run_exact(op: Arc<dyn Operator>, d: usize, k: usize, gamma0: f64, iters: usize) -> AndersonEg {
        let x0 = vec![0.0f32; d];
        let mut oracles: Vec<ExactOracle> = (0..k).map(|_| ExactOracle::new(op.clone())).collect();
        let mut state = AndersonEg::new(&x0, k, gamma0, true);
        for _ in 0..iters {
            let xq = MethodState::base_query(&state).unwrap();
            let base: Vec<Vec<f32>> = oracles
                .iter_mut()
                .map(|o| {
                    let mut g = vec![0.0f32; d];
                    o.sample(&xq, &mut g);
                    g
                })
                .collect();
            let xh = state.extrapolate(&base).unwrap();
            let half: Vec<Vec<f32>> = oracles
                .iter_mut()
                .map(|o| {
                    let mut g = vec![0.0f32; d];
                    o.sample(&xh, &mut g);
                    g
                })
                .collect();
            state.update(&half).unwrap();
        }
        state
    }

    #[test]
    fn converges_on_strongly_monotone_quadratic() {
        let d = 12;
        let mut rng = Rng::seed_from(42);
        let op = Arc::new(MonotoneQuadratic::random(d, 0.3, 1.0, &mut rng).unwrap());
        let xs = op.solution().unwrap();
        let state = run_exact(op, d, 2, 0.25, 3000);
        let d0 = dist_sq(&vec![0.0f32; d], &xs).max(1e-12);
        let avg_ratio = dist_sq(&state.ergodic_average(), &xs) / d0;
        let last_ratio = dist_sq(&MethodState::x_world(&state), &xs) / d0;
        assert!(avg_ratio < 1e-2, "ergodic ratio {avg_ratio}");
        assert!(last_ratio < 1.0, "last-iterate ratio {last_ratio}");
    }

    #[test]
    fn converges_on_pure_rotation() {
        let d = 8;
        let op = Arc::new(RotationOperator::new(d, 0.0, 1.0).unwrap());
        let xs = op.solution().unwrap();
        let state = run_exact(op, d, 1, 0.2, 4000);
        let ratio = dist_sq(&state.ergodic_average(), &xs) / dist_sq(&vec![0.0f32; d], &xs);
        assert!(ratio < 0.05, "rotation ergodic ratio {ratio}");
    }

    #[test]
    fn anderson_candidate_is_used_on_smooth_problems() {
        let d = 12;
        let mut rng = Rng::seed_from(11);
        let op = Arc::new(MonotoneQuadratic::random(d, 0.3, 1.0, &mut rng).unwrap());
        let state = run_exact(op, d, 2, 0.25, 500);
        assert!(
            state.aa_accepted_steps() > 0,
            "exact residuals shrink, so the guard must open at least once"
        );
        assert!(state.aa_accepted_steps() <= state.iteration() as u64);
    }

    #[test]
    fn degenerate_secant_falls_back_to_plain_eg() {
        // Feed the same dual every iteration: R_t = R_{t−1}, the secant
        // denominator is 0, and the safeguard must route every step to
        // plain EG (the residual-decrease guard also never opens).
        let mut state = AndersonEg::new(&[0.0f32; 3], 1, 0.5, false);
        let dual = vec![1.0f32, -1.0, 0.5];
        let mut manual_z = vec![0.0f32; 3];
        for _ in 0..4 {
            let gamma = MethodState::gamma(&state) as f32;
            state.extrapolate(&[dual.clone()]).unwrap();
            state.update(&[dual.clone()]).unwrap();
            for i in 0..3 {
                manual_z[i] -= gamma * dual[i];
            }
        }
        assert_eq!(state.aa_accepted_steps(), 0, "no mixing on a frozen residual");
        let z = MethodState::x_world(&state);
        for i in 0..3 {
            assert!((z[i] - manual_z[i]).abs() < 1e-6, "plain EG fallback trajectory");
        }
    }

    #[test]
    fn safeguard_never_changes_the_cadence() {
        // Whether the guard accepts or rejects, the cadence constants are
        // structural: 2 calls, 2 exchanges, always.
        let d = 6;
        let mut rng = Rng::seed_from(5);
        let op = Arc::new(MonotoneQuadratic::random(d, 0.3, 1.0, &mut rng).unwrap());
        let state = run_exact(op, d, 2, 0.25, 40);
        assert_eq!(MethodState::oracle_calls(&state), 80);
        assert_eq!(MethodState::exchanges_per_step(&state), 2.0);
        assert_eq!(
            state.method_scalars(),
            vec![("aa_accepted_steps", state.aa_accepted_steps() as f64)]
        );
    }

    #[test]
    fn phase_protocol_is_enforced() {
        let mut state = AndersonEg::new(&[0.0; 3], 2, 0.5, true);
        assert!(state.update(&[vec![0.0; 3]; 2]).is_err(), "update before extrapolate");
        assert!(state.extrapolate(&[vec![0.0; 3]]).is_err(), "wrong base count");
        state.extrapolate(&[vec![0.0; 3], vec![0.0; 3]]).unwrap();
        assert!(state.extrapolate(&[vec![0.0; 3]; 2]).is_err(), "double extrapolate");
        assert!(state.shift_world(&[0.0; 3]).is_err(), "shift mid-iteration");
        assert!(state.update(&[vec![0.0; 3]]).is_err(), "wrong half count");
        assert!(state.update(&[vec![0.0; 2]; 2]).is_err(), "wrong dim");
        state.update(&[vec![0.0; 3]; 2]).unwrap();
        assert_eq!(state.iteration(), 1);
    }
}
