//! Past extra-gradient (PEG) / optimistic gradient — the single-call,
//! single-exchange method behind the cadence seam.
//!
//! The recursion (Popov 1980; Hsieh et al. 2019; Gorbunov et al. 2022):
//!
//! ```text
//! X̃_t     = X_t − γ_t (1/K) Σ_k V̂_{k, t−1/2}     // reuse the PAST dual
//! X_{t+1} = X_t − γ_t (1/K) Σ_k V̂_{k, t+1/2}     // one fresh query, at X̃_t
//! ```
//!
//! Only the half-step dual `V̂_{t+1/2}` is ever evaluated or exchanged:
//! one oracle call and ONE quantized exchange per iteration — half the
//! gradient and wire cost of extra-gradient at the same `O(1/T)` /
//! `O(1/√T)` rates. This generalizes the `prev_half` idiom of the OptDA
//! variant from the dual-averaging template to the primal extra-gradient
//! update, so it composes with every topology, local steps, layer-wise
//! quantization and EF compression exactly like the other methods.
//!
//! The adaptive step-size is the shared rule: it learns
//! `Σ_k ‖V̂_{k,t−1/2} − V̂_{k,t+1/2}‖²` — for PEG the base slot of each
//! pair *is* the reused past dual.

use crate::algo::method::MethodState;
use crate::algo::stepsize::AdaptiveStepSize;
use crate::algo::qgenx::QGenXPhase;
use crate::error::{Error, Result};
use crate::util::{axpy, mean_into};

/// Past extra-gradient state for `K` workers; implements
/// [`MethodState`]. Lives in shifted coordinates around `x0` like
/// [`crate::algo::QGenX`] (world points are re-derived as `x0 + X` on
/// read; `shift_world` moves only the origin).
#[derive(Clone, Debug)]
pub struct PastExtraGradient {
    d: usize,
    k: usize,
    x0: Vec<f32>,
    /// X_t (shifted).
    x: Vec<f32>,
    /// X̃_t (shifted), the extrapolated point of the current iteration.
    x_half: Vec<f32>,
    /// Σ_t X̃_t in f64 for the ergodic average.
    x_half_sum: Vec<f64>,
    /// V̂_{k, t−1/2}: the previous half-step duals, reused as this step's
    /// base. `None` only before the first update (PEG-1/2 starts from a
    /// zero past dual, i.e. X̃_1 = X_1).
    prev_half: Option<Vec<Vec<f32>>>,
    /// The base actually used this iteration (feeds the step-size pair).
    cur_base: Vec<Vec<f32>>,
    step: AdaptiveStepSize,
    /// γ_t captured at `extrapolate` so both legs of iteration `t` use the
    /// same step-size (the classic PEG coupling).
    gamma_t: f64,
    t: usize,
    phase: QGenXPhase,
    mean_buf: Vec<f32>,
}

impl PastExtraGradient {
    pub fn new(x0: &[f32], k: usize, gamma0: f64, adaptive: bool) -> Self {
        let d = x0.len();
        PastExtraGradient {
            d,
            k,
            x0: x0.to_vec(),
            x: vec![0.0; d],
            x_half: vec![0.0; d],
            x_half_sum: vec![0.0; d],
            prev_half: None,
            cur_base: Vec::new(),
            step: AdaptiveStepSize::new(gamma0, k, adaptive),
            gamma_t: 0.0,
            t: 0,
            phase: QGenXPhase::AwaitBase,
            mean_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// X̃_t in world coordinates.
    pub fn x_half_world(&self) -> Vec<f32> {
        let mut out = self.x0.clone();
        axpy(1.0, &self.x_half, &mut out);
        out
    }
}

impl MethodState for PastExtraGradient {
    /// PEG never needs a fresh base query — that is the whole point.
    fn base_query(&self) -> Option<Vec<f32>> {
        None
    }

    fn extrapolate(&mut self, base_vectors: &[Vec<f32>]) -> Result<Vec<f32>> {
        if self.phase != QGenXPhase::AwaitBase {
            return Err(Error::Coordinator("extrapolate called out of phase".into()));
        }
        if !base_vectors.is_empty() {
            return Err(Error::Coordinator(
                "PEG takes no base vectors (base_query is None); pass &[]".into(),
            ));
        }
        self.cur_base = match self.prev_half.take() {
            Some(prev) => prev,
            None => vec![vec![0.0; self.d]; self.k], // V̂_{1/2} ≡ 0 at t = 1
        };
        self.gamma_t = self.step.gamma();
        let refs: Vec<&[f32]> = self.cur_base.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        self.x_half.copy_from_slice(&self.x);
        axpy(-(self.gamma_t as f32), &self.mean_buf, &mut self.x_half);
        self.phase = QGenXPhase::AwaitHalf;
        Ok(self.x_half_world())
    }

    fn update(&mut self, half_vectors: &[Vec<f32>]) -> Result<()> {
        if self.phase != QGenXPhase::AwaitHalf {
            return Err(Error::Coordinator("update called out of phase".into()));
        }
        if half_vectors.len() != self.k {
            return Err(Error::Coordinator(format!(
                "need {} half vectors, got {}",
                self.k,
                half_vectors.len()
            )));
        }
        for v in half_vectors {
            if v.len() != self.d {
                return Err(Error::Coordinator("half vector dim mismatch".into()));
            }
        }
        // Ergodic average accumulates X̃_t.
        for i in 0..self.d {
            self.x_half_sum[i] += self.x_half[i] as f64;
        }
        // X_{t+1} = X_t − γ_t mean(V̂_{t+1/2}) — the same γ_t as the
        // extrapolation leg.
        let refs: Vec<&[f32]> = half_vectors.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        axpy(-(self.gamma_t as f32), &self.mean_buf, &mut self.x);
        // The shared adaptive rule learns ‖past − fresh‖² per worker.
        self.step.observe_pairs(&self.cur_base, half_vectors);
        self.prev_half = Some(half_vectors.to_vec());
        self.t += 1;
        self.phase = QGenXPhase::AwaitBase;
        Ok(())
    }

    fn gamma(&self) -> f64 {
        self.step.gamma()
    }

    fn iteration(&self) -> usize {
        self.t
    }

    fn x_world(&self) -> Vec<f32> {
        let mut out = self.x0.clone();
        axpy(1.0, &self.x, &mut out);
        out
    }

    fn ergodic_average(&self) -> Vec<f32> {
        let t = self.t.max(1) as f64;
        let mut out = self.x0.clone();
        for i in 0..self.d {
            out[i] += (self.x_half_sum[i] / t) as f32;
        }
        out
    }

    fn shift_world(&mut self, target: &[f32]) -> Result<()> {
        if self.phase != QGenXPhase::AwaitBase {
            return Err(Error::Coordinator("shift_world called mid-iteration".into()));
        }
        if target.len() != self.d {
            return Err(Error::Coordinator("shift_world target dim mismatch".into()));
        }
        let cur = self.x_world();
        for i in 0..self.d {
            self.x0[i] += target[i] - cur[i];
        }
        Ok(())
    }

    fn oracle_calls(&self) -> u64 {
        self.t as u64
    }

    fn exchanges_per_step(&self) -> f64 {
        1.0
    }

    fn clone_box(&self) -> Box<dyn MethodState> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{ExactOracle, MonotoneQuadratic, Operator, Oracle, RotationOperator};
    use crate::util::{dist_sq, Rng};
    use std::sync::Arc;

    /// Drive PEG with `k` exact oracles for `iters` iterations.
    fn run_exact(
        op: Arc<dyn Operator>,
        d: usize,
        k: usize,
        gamma0: f64,
        iters: usize,
    ) -> PastExtraGradient {
        let x0 = vec![0.0f32; d];
        let mut oracles: Vec<ExactOracle> = (0..k).map(|_| ExactOracle::new(op.clone())).collect();
        let mut state = PastExtraGradient::new(&x0, k, gamma0, true);
        for _ in 0..iters {
            assert!(MethodState::base_query(&state).is_none());
            let xh = state.extrapolate(&[]).unwrap();
            let half: Vec<Vec<f32>> = oracles
                .iter_mut()
                .map(|o| {
                    let mut g = vec![0.0f32; d];
                    o.sample(&xh, &mut g);
                    g
                })
                .collect();
            state.update(&half).unwrap();
        }
        state
    }

    #[test]
    fn converges_on_strongly_monotone_quadratic() {
        let d = 12;
        let mut rng = Rng::seed_from(42);
        let op = Arc::new(MonotoneQuadratic::random(d, 0.3, 1.0, &mut rng).unwrap());
        let xs = op.solution().unwrap();
        let state = run_exact(op, d, 2, 0.25, 3000);
        let d0 = dist_sq(&vec![0.0f32; d], &xs).max(1e-12);
        let avg_ratio = dist_sq(&state.ergodic_average(), &xs) / d0;
        let last_ratio = dist_sq(&MethodState::x_world(&state), &xs) / d0;
        assert!(avg_ratio < 1e-2, "ergodic ratio {avg_ratio}");
        assert!(last_ratio < 1.0, "last-iterate ratio {last_ratio}");
    }

    #[test]
    fn converges_on_pure_rotation_where_gda_diverges() {
        // The bilinear/rotation stress test: the reused past dual keeps
        // the extra-gradient stability that plain descent lacks.
        let d = 8;
        let op = Arc::new(RotationOperator::new(d, 0.0, 1.0).unwrap());
        let xs = op.solution().unwrap();
        let state = run_exact(op, d, 1, 0.2, 4000);
        let ratio = dist_sq(&state.ergodic_average(), &xs) / dist_sq(&vec![0.0f32; d], &xs);
        assert!(ratio < 0.05, "rotation ergodic ratio {ratio}");
    }

    #[test]
    fn first_extrapolation_is_identity_then_reuses_past_dual() {
        // t = 1: no past dual yet, so X̃_1 = X_1. t = 2: the extrapolation
        // must move by exactly −γ_2 · mean(V̂_{1+1/2}).
        let mut state = PastExtraGradient::new(&[1.0, 1.0], 2, 0.5, false);
        let x_half = state.extrapolate(&[]).unwrap();
        assert_eq!(x_half, vec![1.0, 1.0], "zero past dual at t = 1");
        state.update(&[vec![1.0, 0.0], vec![0.0, 1.0]]).unwrap();
        let x1 = MethodState::x_world(&state);
        let gamma = MethodState::gamma(&state) as f32;
        let x_half2 = state.extrapolate(&[]).unwrap();
        // mean of stored halves is (0.5, 0.5)
        assert!((x_half2[0] - (x1[0] - gamma * 0.5)).abs() < 1e-6);
        assert!((x_half2[1] - (x1[1] - gamma * 0.5)).abs() < 1e-6);
    }

    #[test]
    fn phase_protocol_is_enforced() {
        let mut state = PastExtraGradient::new(&[0.0; 3], 1, 0.5, true);
        assert!(state.update(&[vec![0.0; 3]]).is_err(), "update before extrapolate");
        state.extrapolate(&[]).unwrap();
        assert!(state.extrapolate(&[]).is_err(), "double extrapolate");
        assert!(
            state.shift_world(&[0.0; 3]).is_err(),
            "shift mid-iteration"
        );
        // wrong worker count / dim at update
        assert!(state.update(&[vec![0.0; 3], vec![0.0; 3]]).is_err());
        assert!(state.update(&[vec![0.0; 2]]).is_err());
        state.update(&[vec![0.0; 3]]).unwrap();
        // base vectors are a protocol error for a single-call method
        assert!(state.extrapolate(&[vec![0.0; 3]]).is_err());
    }

    #[test]
    fn cadence_is_one_call_one_exchange() {
        let mut rng = Rng::seed_from(7);
        let op = Arc::new(MonotoneQuadratic::random(4, 0.3, 1.0, &mut rng).unwrap());
        let state = run_exact(op, 4, 3, 0.25, 50);
        assert_eq!(state.iteration(), 50);
        assert_eq!(MethodState::oracle_calls(&state), 50, "one call per iteration");
        assert_eq!(MethodState::exchanges_per_step(&state), 1.0);
    }

    #[test]
    fn shift_world_moves_origin_only() {
        let mut rng = Rng::seed_from(9);
        let op = Arc::new(MonotoneQuadratic::random(4, 0.3, 1.0, &mut rng).unwrap());
        let mut state = run_exact(op, 4, 1, 0.25, 10);
        let target = vec![0.25; 4];
        state.shift_world(&target).unwrap();
        let moved = MethodState::x_world(&state);
        for i in 0..4 {
            assert!((moved[i] - target[i]).abs() < 1e-5);
        }
        assert_eq!(state.iteration(), 10, "counter untouched");
    }
}
