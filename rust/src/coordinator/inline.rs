//! Single-threaded simulation of the K-processor system — Algorithm 1 with
//! every byte of the wire format exercised, but no thread machinery.
//! Deterministic given the config seed; the workhorse of the benches.

use super::pipeline::Compressor;
use super::schedule::UpdateSchedule;
use crate::algo::{QGenX, Sgda};
use crate::config::{ExperimentConfig, LevelScheme};
use crate::error::Result;
use crate::metrics::Recorder;
use crate::net::{NetModel, TrafficStats};
use crate::oracle::{build_operator, build_oracle, GapEvaluator, Oracle};
use crate::util::Rng;
use std::time::Instant;

/// Run one Q-GenX experiment per the config; returns the metric recorder
/// with series `gap`, `dist`, `residual`, `gamma`, `bits_cum`,
/// `sim_time_cum` and summary scalars.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Recorder> {
    cfg.validate()?;
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let root = Rng::seed_from(cfg.seed);

    // K private oracles + K compression endpoints.
    let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
        .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
        .collect::<Result<_>>()?;
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
        .collect::<Result<_>>()?;

    let adaptive = cfg.quant.scheme == LevelScheme::Adaptive
        || cfg.quant.codec == crate::coding::SymbolCodec::Huffman;
    let schedule = if adaptive && comps[0].is_quantized() {
        UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
    } else {
        UpdateSchedule::never()
    };

    let x0 = vec![0.0f32; d];
    let mut state = QGenX::new(cfg.algo.variant, &x0, k, cfg.algo.gamma0, cfg.algo.adaptive_step);

    let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
    let net = NetModel::from_config(&cfg.net);
    let mut traffic = TrafficStats::default();
    let mut rec = Recorder::new();

    // Scratch buffers reused across iterations.
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
    let mut g_buf = vec![0.0f32; d];

    for t in 1..=cfg.iters {
        // (1) Level-update step: exchange sufficient statistics, pool,
        //     re-optimize — identical on all workers.
        if schedule.is_update(t) {
            let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
            let bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
            traffic.record_allgather(&bits, &net);
            let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            for comp in comps.iter_mut() {
                comp.update_levels(&rank_order)?;
            }
        }

        // (2) Base exchange (variant-dependent).
        let base_vecs: Vec<Vec<f32>> = if let Some(xq) = state.base_query() {
            let t0 = Instant::now();
            let mut bits = Vec::with_capacity(k);
            let mut wires = Vec::with_capacity(k);
            for w in 0..k {
                oracles[w].sample(&xq, &mut g_buf);
                let (bytes, b) = comps[w].compress(&g_buf)?;
                bits.push(b);
                wires.push(bytes);
            }
            // Everyone decodes everyone (we decode once — identical everywhere).
            for w in 0..k {
                comps[w].decompress(&wires[w], &mut decoded[w])?;
            }
            traffic.add_compute(t0.elapsed().as_secs_f64());
            traffic.record_allgather(&bits, &net);
            decoded.clone()
        } else {
            Vec::new()
        };

        // (3) Extrapolate.
        let x_half = state.extrapolate(&base_vecs)?;

        // (4) Half-step exchange.
        let t0 = Instant::now();
        let mut bits = Vec::with_capacity(k);
        let mut wires = Vec::with_capacity(k);
        for w in 0..k {
            oracles[w].sample(&x_half, &mut g_buf);
            let (bytes, b) = comps[w].compress(&g_buf)?;
            bits.push(b);
            wires.push(bytes);
        }
        for w in 0..k {
            comps[w].decompress(&wires[w], &mut decoded[w])?;
        }
        traffic.add_compute(t0.elapsed().as_secs_f64());
        traffic.record_allgather(&bits, &net);
        state.update(&decoded)?;

        // (5) Evaluation.
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            let avg = state.ergodic_average();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                rec.push("dist", t as f64, ev.dist_to_center(&avg));
            }
            rec.push("residual", t as f64, op.residual(&avg));
            rec.push("gamma", t as f64, state.gamma());
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            rec.push("sim_time_cum", t as f64, traffic.total_time());
        }
    }

    rec.set_scalar("total_bits", traffic.bits_sent as f64);
    rec.set_scalar("bits_per_round_per_worker", traffic.bits_per_round_per_worker(k));
    rec.set_scalar("sim_net_time", traffic.sim_net_time);
    rec.set_scalar("compute_time", traffic.compute_time);
    rec.set_scalar("rounds", traffic.rounds as f64);
    rec.set_scalar("level_updates", comps[0].updates() as f64);
    rec.set_scalar("epsilon_q", comps[0].epsilon_q(d));
    Ok(rec)
}

/// QSGDA baseline (Beznosikov et al. 2022): quantized SGDA with γ_t = γ₀/√t,
/// same oracles/compressors/network — only the update rule differs
/// (no extrapolation, no adaptive step). The Figure-4 comparator.
pub fn run_qsgda_baseline(cfg: &ExperimentConfig) -> Result<Recorder> {
    cfg.validate()?;
    let op = build_operator(&cfg.problem, cfg.seed)?;
    let d = op.dim();
    let k = cfg.workers;
    let root = Rng::seed_from(cfg.seed);
    let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
        .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
        .collect::<Result<_>>()?;
    let mut comps: Vec<Compressor> = (0..k)
        .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
        .collect::<Result<_>>()?;
    let x0 = vec![0.0f32; d];
    let mut sgda = Sgda::new(&x0, cfg.algo.gamma0, true);
    let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
    let net = NetModel::from_config(&cfg.net);
    let mut traffic = TrafficStats::default();
    let mut rec = Recorder::new();
    let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
    let mut g_buf = vec![0.0f32; d];

    for t in 1..=cfg.iters {
        let xq = sgda.query();
        let mut bits = Vec::with_capacity(k);
        let mut wires = Vec::with_capacity(k);
        for w in 0..k {
            oracles[w].sample(&xq, &mut g_buf);
            let (bytes, b) = comps[w].compress(&g_buf)?;
            bits.push(b);
            wires.push(bytes);
        }
        for w in 0..k {
            comps[w].decompress(&wires[w], &mut decoded[w])?;
        }
        traffic.record_allgather(&bits, &net);
        sgda.update(&decoded);
        if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
            let avg = sgda.ergodic_average();
            if let Some(ev) = &gap_eval {
                rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                rec.push("dist", t as f64, ev.dist_to_center(&avg));
                rec.push("dist_last", t as f64, ev.dist_to_center(sgda.x()));
            }
            rec.push("residual", t as f64, op.residual(&avg));
            rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
        }
    }
    rec.set_scalar("total_bits", traffic.bits_sent as f64);
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{QuantMode, Variant};

    fn base_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 400;
        cfg.eval_every = 100;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 16;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 100;
        cfg
    }

    #[test]
    fn qgenx_converges_quantized_absolute_noise() {
        let cfg = base_cfg();
        let rec = run_experiment(&cfg).unwrap();
        let gaps = rec.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "gap should shrink: {first} -> {last}");
        assert!(rec.scalar("total_bits").unwrap() > 0.0);
        assert!(rec.scalar("level_updates").unwrap() >= 1.0);
    }

    #[test]
    fn fp32_and_quantized_converge_similarly_but_quantized_sends_fewer_bits() {
        let mut cfg = base_cfg();
        cfg.iters = 600;
        let rec_q = run_experiment(&cfg).unwrap();
        cfg.quant.mode = QuantMode::Fp32;
        let rec_f = run_experiment(&cfg).unwrap();
        let bits_q = rec_q.scalar("total_bits").unwrap();
        let bits_f = rec_f.scalar("total_bits").unwrap();
        assert!(bits_q < bits_f / 3.0, "quantized {bits_q} vs fp32 {bits_f}");
        // Both reach a small gap.
        let gq = rec_q.get("gap").unwrap().last().unwrap();
        let gf = rec_f.get("gap").unwrap().last().unwrap();
        assert!(gq < 1.0 && gf < 1.0, "gq={gq} gf={gf}");
    }

    #[test]
    fn all_variants_run_and_converge() {
        for v in [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging] {
            let mut cfg = base_cfg();
            cfg.algo.variant = v;
            cfg.iters = 500;
            let rec = run_experiment(&cfg).unwrap();
            let last = rec.get("gap").unwrap().last().unwrap();
            assert!(last.is_finite(), "variant {v:?} gap {last}");
        }
    }

    #[test]
    fn da_and_optda_send_half_the_rounds_of_de() {
        let mut cfg = base_cfg();
        cfg.quant.scheme = LevelScheme::Uniform; // no stat-exchange rounds
        cfg.algo.variant = Variant::DualExtrapolation;
        let rec_de = run_experiment(&cfg).unwrap();
        cfg.algo.variant = Variant::OptimisticDualAveraging;
        let rec_opt = run_experiment(&cfg).unwrap();
        let r_de = rec_de.scalar("rounds").unwrap();
        let r_opt = rec_opt.scalar("rounds").unwrap();
        assert!((r_de / r_opt - 2.0).abs() < 0.01, "de {r_de} opt {r_opt}");
    }

    #[test]
    fn more_workers_reduce_final_error_under_absolute_noise() {
        // Theorem 3's 1/sqrt(K): K=8 should beat K=1 on the same budget.
        // Average over seeds — a single run's final gap is itself noisy.
        let mut d1 = 0.0;
        let mut d8 = 0.0;
        for seed in 0..5u64 {
            let mut cfg = base_cfg();
            cfg.seed = 1000 + seed;
            cfg.iters = 1500;
            cfg.problem.sigma = 2.0;
            cfg.algo.gamma0 = 0.3;
            cfg.workers = 1;
            d1 += run_experiment(&cfg).unwrap().get("dist").unwrap().last().unwrap();
            cfg.workers = 8;
            d8 += run_experiment(&cfg).unwrap().get("dist").unwrap().last().unwrap();
        }
        assert!(d8 < d1 * 0.8, "K=8 dist {d8} should beat K=1 dist {d1}");
    }

    #[test]
    fn qsgda_baseline_runs() {
        let mut cfg = base_cfg();
        cfg.iters = 300;
        let rec = run_qsgda_baseline(&cfg).unwrap();
        assert!(rec.get("dist").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = base_cfg();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(
            a.get("gap").unwrap().ys(),
            b.get("gap").unwrap().ys(),
            "inline runner must be deterministic"
        );
    }
}
