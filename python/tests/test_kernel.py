"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: bit-identical
agreement with ref.py, plus statistical properties (unbiasedness, variance
bound) and a hypothesis sweep over shapes/levels/seeds.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quantize import quantize, quantize_bucketed
from compile.kernels.fused_extragrad import fused_extragrad
from compile.kernels.ref import (
    ref_fused_extragrad,
    ref_quantize,
    ref_quantize_symbols,
)


def make_levels(s: int) -> np.ndarray:
    """Uniform levels 0, 1/(s+1), ..., 1 (s interior)."""
    return np.linspace(0.0, 1.0, s + 2).astype(np.float32)


def rand_inputs(d: int, seed: int, scale: float = 1.0):
    rng = np.random.default_rng(seed)
    v = (rng.normal(size=d) * scale).astype(np.float32)
    u = rng.random(size=d).astype(np.float32)
    return v, u


class TestQuantizeKernel:
    def test_matches_ref_bitexact(self):
        d = 8192
        v, u = rand_inputs(d, 0)
        levels = make_levels(14)
        norm = np.array([np.linalg.norm(v)], np.float32)
        out = quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
        ref = ref_quantize(v, levels, u, norm[0])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_zero_vector(self):
        d = 4096
        levels = make_levels(3)
        v = np.zeros(d, np.float32)
        u = np.full(d, 0.5, np.float32)
        norm = np.array([0.0], np.float32)
        out = quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
        assert np.all(np.asarray(out) == 0.0)

    def test_values_on_levels_are_fixed_points(self):
        levels = make_levels(3)  # 0, .25, .5, .75, 1
        d = 4096
        v = np.zeros(d, np.float32)
        v[:5] = [1.0, -0.75, 0.5, 0.25, 0.0]
        u = np.random.default_rng(1).random(d).astype(np.float32)
        norm = np.array([1.0], np.float32)  # Linf norm
        out = np.asarray(
            quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
        )
        np.testing.assert_allclose(out[:5], v[:5], rtol=0, atol=1e-7)

    def test_unbiasedness_montecarlo(self):
        d = 4096
        levels = make_levels(4)
        rng = np.random.default_rng(2)
        v = rng.normal(size=d).astype(np.float32)
        norm = np.array([np.linalg.norm(v)], np.float32)
        acc = np.zeros(d, np.float64)
        trials = 200
        for t in range(trials):
            u = rng.random(size=d).astype(np.float32)
            out = quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
            acc += np.asarray(out, np.float64)
        mean = acc / trials
        # MC tolerance: bin width * norm / sqrt(trials) * 4
        tol = 4.0 * 0.2 * float(norm[0]) / np.sqrt(trials) + 1e-3
        assert np.max(np.abs(mean - v)) < tol

    def test_reconstruction_bounded_by_norm(self):
        d = 4096
        v, u = rand_inputs(d, 3, scale=5.0)
        levels = make_levels(7)
        norm = np.array([np.linalg.norm(v)], np.float32)
        out = np.asarray(
            quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
        )
        assert np.max(np.abs(out)) <= float(norm[0]) * (1 + 1e-6)

    def test_symbols_adjacent_to_magnitude(self):
        # Each coordinate rounds to one of its two bracketing levels.
        d = 4096
        v, u = rand_inputs(d, 4)
        levels = make_levels(6)
        norm = np.array([np.linalg.norm(v)], np.float32)
        syms = np.asarray(ref_quantize_symbols(v, levels, u, norm[0]))
        mag = np.minimum(np.abs(v) / norm[0], 1.0)
        lo = levels[np.maximum(syms - 1, 0)]
        hi = levels[np.minimum(syms + 1, len(levels) - 1)]
        assert np.all(mag >= lo - 1e-6)
        assert np.all(mag <= hi + 1e-6)

    def test_bucketed_matches_per_bucket_ref(self):
        d = 4096
        bucket = 1024
        v, u = rand_inputs(d, 5)
        levels = make_levels(14)
        out = np.asarray(
            quantize_bucketed(jnp.array(v), jnp.array(levels), jnp.array(u), bucket)
        )
        # Use the same f32 norm computation as the wrapper so the
        # comparison is bit-exact (np.linalg.norm accumulates in f64).
        norms = np.asarray(jnp.linalg.norm(jnp.array(v).reshape(-1, bucket), axis=1))
        for bi in range(d // bucket):
            sl = slice(bi * bucket, (bi + 1) * bucket)
            ref = np.asarray(ref_quantize(v[sl], levels, u[sl], norms[bi]))
            np.testing.assert_array_equal(out[sl], ref)

    def test_rejects_non_multiple_of_block(self):
        levels = make_levels(3)
        with pytest.raises(ValueError):
            quantize(
                jnp.zeros(100), jnp.array(levels), jnp.zeros(100), jnp.array([1.0])
            )

    @settings(max_examples=20, deadline=None)
    @given(
        s=st.integers(min_value=1, max_value=30),
        seed=st.integers(min_value=0, max_value=10_000),
        scale=st.floats(min_value=0.01, max_value=100.0),
        blocks=st.integers(min_value=1, max_value=3),
    )
    def test_hypothesis_matches_ref(self, s, seed, scale, blocks):
        d = 4096 * blocks
        v, u = rand_inputs(d, seed, scale)
        # occasionally zero out coordinates (p0 symbol path)
        v[:: max(1, seed % 17)] = 0.0
        levels = make_levels(s)
        norm = np.array([np.linalg.norm(v)], np.float32)
        out = quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
        ref = ref_quantize(v, levels, u, norm[0])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        s=st.integers(min_value=1, max_value=14),
    )
    def test_hypothesis_nonuniform_levels(self, seed, s):
        # exponential level placement, like NUQSGD
        interior = np.array([2.0 ** -(s - j) for j in range(s)], np.float32)
        levels = np.concatenate([[0.0], interior, [1.0]]).astype(np.float32)
        levels = np.unique(levels)  # dedupe if s small
        d = 4096
        v, u = rand_inputs(d, seed)
        norm = np.array([np.linalg.norm(v)], np.float32)
        out = quantize(jnp.array(v), jnp.array(levels), jnp.array(u), jnp.array(norm))
        ref = ref_quantize(v, levels, u, norm[0])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


class TestFusedExtragrad:
    def test_matches_ref(self):
        d = 8192
        rng = np.random.default_rng(7)
        x, y, vb, vh = (rng.normal(size=d).astype(np.float32) for _ in range(4))
        g = np.array([0.7, 0.35], np.float32)
        xh, yn, xn = fused_extragrad(
            jnp.array(x), jnp.array(y), jnp.array(vb), jnp.array(vh), jnp.array(g)
        )
        rxh, ryn, rxn = ref_fused_extragrad(x, y, vb, vh, g[0], g[1])
        # allclose, not equal: interpret-mode contraction (FMA) differs by
        # <= 1 ulp from the separate multiply-add in the jnp reference.
        for a, b in zip((xh, yn, xn), (rxh, ryn, rxn)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6)

    def test_zero_gamma_freezes_x_half(self):
        d = 4096
        x = np.ones(d, np.float32)
        y = np.zeros(d, np.float32)
        v = np.ones(d, np.float32)
        g = np.array([0.0, 1.0], np.float32)
        xh, yn, xn = fused_extragrad(
            jnp.array(x), jnp.array(y), jnp.array(v), jnp.array(v), jnp.array(g)
        )
        np.testing.assert_array_equal(np.asarray(xh), x)
        np.testing.assert_array_equal(np.asarray(yn), -v)
        np.testing.assert_array_equal(np.asarray(xn), -v)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=1000))
    def test_hypothesis_matches_ref(self, seed):
        d = 4096
        rng = np.random.default_rng(seed)
        x, y, vb, vh = (rng.normal(size=d).astype(np.float32) for _ in range(4))
        g = rng.random(2).astype(np.float32)
        outs = fused_extragrad(
            jnp.array(x), jnp.array(y), jnp.array(vb), jnp.array(vh), jnp.array(g)
        )
        refs = ref_fused_extragrad(x, y, vb, vh, g[0], g[1])
        for a, b in zip(outs, refs):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6)
