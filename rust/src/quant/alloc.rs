//! Bit-budget allocation across layers by the Theorem-1 variance objective.
//!
//! Given a [`crate::quant::layers::LayerMap`] partition with per-layer
//! Theorem-1 weights `w_ℓ = Σ_j ‖g_{j,ℓ}‖_q²` (pooled by the v3 stat
//! exchange), a global budget of `B` symbol bits per coordinate is
//! redistributed by minimizing the total quantization variance
//!
//! `min_{b_1..b_n} Σ_ℓ w_ℓ · ε_Q(uniform(2^{b_ℓ} − 2); d_ℓ^eff, q)`
//! `s.t. Σ_ℓ d_ℓ · b_ℓ ≤ B · d,  b_ℓ ∈ {2, …, 8}`
//!
//! where `ε_Q` is the Theorem-1 variance factor ([`crate::quant::bounds`])
//! of the densest uniform level sequence that a `b_ℓ`-bit fixed-width
//! symbol can index (`s = 2^b − 2` interior levels, alphabet `s + 2`), and
//! `d^eff` is the per-bucket dimension the layer actually quantizes at.
//! Low-mass layers (frozen embeddings, converged blocks) surrender bits to
//! high-mass layers (output heads) — the Layer-wise-QODA observation that
//! matching bits to the per-layer norm profile strictly improves the
//! variance–bits trade-off.
//!
//! The integer program is solved greedily: start every layer at the 2-bit
//! floor and repeatedly grant one more bit to the layer with the best
//! variance reduction *per wire bit* until the budget (or the 8-bit cap)
//! is reached. The per-layer gain `w_ℓ · Δε_Q` is decreasing in `b_ℓ`
//! (ε_Q is convex-ish in bits over this range), so the greedy solution
//! matches the LP-relaxation rounding for this separable objective. The
//! result is a pure function of the inputs — every worker that pools the
//! same v3 payloads computes the same allocation, which the wire format
//! requires (the decode side must know every layer's alphabet).

use super::bounds::epsilon_q;
use super::levels::Levels;
use crate::error::{Error, Result};

/// Fewest symbol bits a layer can hold: alphabet 4 = 2 interior levels
/// (Definition 1 needs `s ≥ 1`; `s = 2` keeps the alphabet a power of two).
pub const MIN_SYMBOL_BITS: u32 = 2;

/// Most symbol bits a layer can be granted: alphabet 256 = 254 interior
/// levels — the paper's UQ8 operating point.
pub const MAX_SYMBOL_BITS: u32 = 8;

/// Densest uniform level count a `bits`-wide fixed symbol can index:
/// `s = 2^bits − 2` interior levels (alphabet `s + 2 = 2^bits`).
pub fn levels_for_bits(bits: u32) -> usize {
    (1usize << bits) - 2
}

/// Fixed-width symbol bits needed for `s` interior levels:
/// `ceil(log2(s + 2))` — the inverse of [`levels_for_bits`] up to rounding.
pub fn bits_for_levels(s: usize) -> u32 {
    (usize::BITS - (s + 1).leading_zeros()).max(1)
}

/// One layer's allocator input.
#[derive(Clone, Copy, Debug)]
pub struct LayerProfile {
    /// Theorem-1 weight `Σ_j ‖g_{j,ℓ}‖_q²` (pooled norm² mass). All-zero
    /// weights fall back to `w_ℓ = d_ℓ` — the isotropic prior.
    pub weight: f64,
    /// Layer width (coordinates) — the wire cost of one extra bit.
    pub dim: usize,
    /// Effective per-bucket dimension the layer quantizes at
    /// (`min(bucket_size, dim)`; `dim` for whole-layer buckets) — the `d`
    /// that enters `ε_Q`.
    pub eff_dim: usize,
}

/// Allocator outcome: per-layer symbol widths and level counts, plus the
/// achieved objective value (for diagnostics / benches).
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// Fixed-width symbol bits per layer, each in
    /// `[MIN_SYMBOL_BITS, MAX_SYMBOL_BITS]`.
    pub bits: Vec<u32>,
    /// Interior level count per layer (`levels_for_bits(bits)`).
    pub levels: Vec<usize>,
    /// `Σ_ℓ w_ℓ ε_Q(ℓ)` at the returned allocation.
    pub objective: f64,
}

impl Allocation {
    /// Average symbol bits per coordinate actually used.
    pub fn mean_bits(&self, dims: &[usize]) -> f64 {
        let total: usize = dims.iter().sum();
        let used: usize =
            self.bits.iter().zip(dims.iter()).map(|(&b, &d)| b as usize * d).sum();
        used as f64 / total.max(1) as f64
    }
}

/// `Σ_ℓ w_ℓ · ε_Q(uniform(2^{b_ℓ} − 2); eff_dim_ℓ, q)` — the objective the
/// greedy loop descends. Public so benches can score a *uniform* allocation
/// with the same yardstick.
pub fn objective(profiles: &[LayerProfile], bits: &[u32], q: u32) -> f64 {
    assert_eq!(profiles.len(), bits.len());
    profiles
        .iter()
        .zip(bits.iter())
        .map(|(p, &b)| {
            p.weight * epsilon_q(&Levels::uniform(levels_for_bits(b)), p.eff_dim.max(1), q)
        })
        .sum()
}

/// Redistribute `budget_bits_per_coord` (averaged over all `d` coordinates)
/// across the layers. Deterministic in its inputs; ties break toward the
/// lower layer index.
pub fn allocate(
    profiles: &[LayerProfile],
    budget_bits_per_coord: f64,
    q: u32,
) -> Result<Allocation> {
    if profiles.is_empty() {
        return Err(Error::Quant("allocator needs at least one layer".into()));
    }
    if profiles.iter().any(|p| p.dim == 0) {
        return Err(Error::Quant("allocator: zero-width layer".into()));
    }
    if !(budget_bits_per_coord.is_finite() && budget_bits_per_coord > 0.0) {
        return Err(Error::Quant(format!(
            "allocator: bad bit budget {budget_bits_per_coord}"
        )));
    }
    let d_total: usize = profiles.iter().map(|p| p.dim).sum();
    let budget = budget_bits_per_coord * d_total as f64;
    let floor_cost = (MIN_SYMBOL_BITS as usize * d_total) as f64;
    if budget + 1e-9 < floor_cost {
        return Err(Error::Quant(format!(
            "bit budget {budget_bits_per_coord:.2}/coord below the \
             {MIN_SYMBOL_BITS}-bit floor"
        )));
    }
    // Isotropic fallback when no layer has observed mass yet (first
    // allocation can run before any stat round).
    let weights: Vec<f64> = if profiles.iter().all(|p| p.weight <= 0.0) {
        profiles.iter().map(|p| p.dim as f64).collect()
    } else {
        profiles.iter().map(|p| p.weight.max(0.0)).collect()
    };
    let eps = |i: usize, b: u32| -> f64 {
        epsilon_q(&Levels::uniform(levels_for_bits(b)), profiles[i].eff_dim.max(1), q)
    };

    let n = profiles.len();
    let mut bits = vec![MIN_SYMBOL_BITS; n];
    let mut used = floor_cost;
    loop {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..n {
            let b = bits[i];
            if b >= MAX_SYMBOL_BITS {
                continue;
            }
            let cost = profiles[i].dim as f64;
            if used + cost > budget + 1e-9 {
                continue;
            }
            // Variance reduction per wire bit for granting layer i one bit.
            let gain = weights[i] * (eps(i, b) - eps(i, b + 1)) / cost;
            match best {
                Some((_, g)) if g >= gain => {}
                _ => best = Some((i, gain)),
            }
        }
        let Some((i, gain)) = best else { break };
        if gain <= 0.0 {
            // No upgrade helps (all remaining candidates have zero weight
            // and the fallback was not triggered) — stop rather than burn
            // budget on noise.
            break;
        }
        used += profiles[i].dim as f64;
        bits[i] += 1;
    }

    let levels = bits.iter().map(|&b| levels_for_bits(b)).collect();
    let obj = objective(profiles, &bits, q);
    Ok(Allocation { bits, levels, objective: obj })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof(weight: f64, dim: usize, eff: usize) -> LayerProfile {
        LayerProfile { weight, dim, eff_dim: eff }
    }

    #[test]
    fn levels_bits_roundtrip() {
        assert_eq!(levels_for_bits(2), 2);
        assert_eq!(levels_for_bits(4), 14); // uq4
        assert_eq!(levels_for_bits(8), 254); // uq8
        for b in MIN_SYMBOL_BITS..=MAX_SYMBOL_BITS {
            assert_eq!(bits_for_levels(levels_for_bits(b)), b);
        }
        // the wire codec's fixed width for s levels matches bits_for_levels
        assert_eq!(bits_for_levels(14), 4);
        assert_eq!(bits_for_levels(254), 8);
        assert_eq!(bits_for_levels(1), 2);
    }

    #[test]
    fn respects_budget_and_floor() {
        let ps = [prof(1.0, 300, 128), prof(5.0, 100, 100), prof(0.2, 600, 128)];
        for budget in [2.0, 3.0, 4.0, 6.5, 8.0] {
            let a = allocate(&ps, budget, 2).unwrap();
            let d: usize = ps.iter().map(|p| p.dim).sum();
            let used: usize =
                a.bits.iter().zip(ps.iter()).map(|(&b, p)| b as usize * p.dim).sum();
            assert!(used as f64 <= budget * d as f64 + 1e-6, "budget {budget}: used {used}");
            assert!(a.bits.iter().all(|&b| (MIN_SYMBOL_BITS..=MAX_SYMBOL_BITS).contains(&b)));
            assert_eq!(a.levels, a.bits.iter().map(|&b| levels_for_bits(b)).collect::<Vec<_>>());
        }
        // budget below the floor is a config error
        assert!(allocate(&ps, 1.5, 2).is_err());
        assert!(allocate(&ps, 0.0, 2).is_err());
        assert!(allocate(&[], 4.0, 2).is_err());
    }

    #[test]
    fn heavy_layers_win_bits() {
        // LM-shaped: wide light "embed", medium "body", narrow heavy "head".
        let ps = [prof(2.0, 768, 128), prof(380.0, 384, 128), prof(2000.0, 128, 128)];
        let a = allocate(&ps, 4.0, 2).unwrap();
        assert!(a.bits[2] > a.bits[0], "head {:?} must out-bit embed", a.bits);
        assert!(a.bits[1] >= a.bits[0], "body must not trail embed: {:?}", a.bits);
        assert!(a.mean_bits(&[768, 384, 128]) <= 4.0 + 1e-9);
    }

    #[test]
    fn beats_uniform_allocation_on_heterogeneous_mass() {
        let ps = [prof(2.0, 768, 128), prof(380.0, 384, 128), prof(2000.0, 128, 128)];
        let a = allocate(&ps, 4.0, 2).unwrap();
        let uniform = objective(&ps, &[4, 4, 4], 2);
        assert!(
            a.objective < 0.8 * uniform,
            "layer-wise {:.3} must beat uniform {:.3}",
            a.objective,
            uniform
        );
        // On homogeneous mass the greedy solution IS (near-)uniform.
        let flat = [prof(1.0, 256, 128), prof(1.0, 256, 128)];
        let f = allocate(&flat, 4.0, 2).unwrap();
        assert_eq!(f.bits, vec![4, 4]);
    }

    #[test]
    fn more_budget_never_hurts() {
        let ps = [prof(1.0, 100, 100), prof(9.0, 100, 100)];
        let mut prev = f64::INFINITY;
        for budget in [2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0] {
            let a = allocate(&ps, budget, 2).unwrap();
            assert!(a.objective <= prev + 1e-12, "objective rose at budget {budget}");
            prev = a.objective;
        }
        // Saturates at the cap.
        let a = allocate(&ps, 100.0, 2).unwrap();
        assert_eq!(a.bits, vec![MAX_SYMBOL_BITS, MAX_SYMBOL_BITS]);
    }

    #[test]
    fn zero_weights_fall_back_to_isotropic() {
        let ps = [prof(0.0, 512, 128), prof(0.0, 128, 128)];
        let a = allocate(&ps, 4.0, 2).unwrap();
        // With w ∝ d the narrow layer still gets at least the floor and the
        // overall budget is spent (not stuck at the 2-bit floor).
        assert!(a.mean_bits(&[512, 128]) > 3.0, "fallback must spend budget: {:?}", a.bits);
    }

    #[test]
    fn deterministic() {
        let ps = [prof(3.0, 100, 64), prof(1.0, 300, 64), prof(7.0, 50, 50)];
        let a = allocate(&ps, 5.0, 2).unwrap();
        let b = allocate(&ps, 5.0, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn single_layer_gets_the_whole_budget() {
        let a = allocate(&[prof(1.0, 1000, 128)], 4.0, 2).unwrap();
        assert_eq!(a.bits, vec![4]);
        assert_eq!(a.levels, vec![14]); // uq4
    }
}
