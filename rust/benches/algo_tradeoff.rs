//! E15 — method cadence trade-off: single-call Past Extra-Gradient and
//! Anderson-accelerated EG against the Q-GenX dual-extrapolation baseline.
//!
//! Q-GenX (DE) pays two oracle calls and two quantized exchanges per
//! iteration. Past Extra-Gradient reuses the previous half-step dual as
//! the extrapolation direction (Popov 1980; Gidel et al. 2019 —
//! PAPERS.md), so each iteration costs ONE fresh oracle call and ONE
//! quantized exchange; EG-AA(1) keeps the two-call cadence but mixes in
//! a safeguarded depth-1 Anderson candidate to cut the iteration count
//! on smooth problems. Method:
//!
//! 1. Three runs per oracle, identical everything except `[algo] method`:
//!    `qgenx` (DE baseline), `peg`, `eg-aa`.
//! 2. Oracles are the LM/GAN-shaped [`BlockScaledQuadratic`] proxies
//!    under relative noise, exactly as `benches/ef_tradeoff.rs`.
//! 3. Matched-gap accounting: the target gap is 1.05 × the worst final
//!    gap across the triple; a run's wire cost is `bits_cum` at its
//!    first eval point at or below the target, and its oracle cost is
//!    that eval point's iteration × the method's calls-per-step.
//!
//! Acceptance (full-scale mode): on `lm-proxy`, PEG reaches the matched
//! gap with strictly fewer total wire bits AND strictly fewer oracle
//! calls than the Q-GenX baseline. Emits `results/BENCH_algo.json`.
//!
//! [`BlockScaledQuadratic`]: qgenx::oracle::BlockScaledQuadratic

use qgenx::benchkit::{fast_mode, scaled, write_json, Table};
use qgenx::config::{ExperimentConfig, Method};
use qgenx::coordinator::run_experiment;
use qgenx::metrics::Recorder;
use qgenx::runtime::json::Json;

struct OracleCase {
    kind: &'static str,
    dim: usize,
}

fn cases() -> Vec<OracleCase> {
    vec![
        OracleCase { kind: "lm-proxy", dim: 1280 },
        OracleCase { kind: "gan-proxy", dim: 1024 },
    ]
}

fn method_cfg(case: &OracleCase, iters: usize, method: Method) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("algo_{}_{}", case.kind, method.name());
    cfg.problem.kind = case.kind.into();
    cfg.problem.dim = case.dim;
    cfg.problem.noise = "relative".into();
    cfg.problem.rel_c = 0.5;
    cfg.workers = 4;
    cfg.iters = iters;
    cfg.eval_every = (iters / 50).max(1);
    cfg.seed = 17;
    cfg.algo.method = method;
    cfg
}

/// Fresh oracle calls per iteration for each method (the DE baseline and
/// EG-AA query base and half points; PEG only the half point).
fn calls_per_step(method: Method) -> f64 {
    match method {
        Method::QGenX => 2.0,
        Method::Peg => 1.0,
        Method::EgAa => 2.0,
    }
}

/// `(bits_cum, oracle_calls)` at the first eval point whose gap is at or
/// below `target` (identical eval grids across the triple make this a
/// fair match).
fn cost_to_gap(rec: &Recorder, target: f64, method: Method) -> Option<(f64, f64)> {
    let gaps = rec.get("gap").unwrap();
    let bits = rec.get("bits_cum").unwrap();
    gaps.points
        .iter()
        .zip(bits.points.iter())
        .find(|((_, g), _)| *g <= target)
        .map(|((t, _), (_, b))| (*b, t * calls_per_step(method)))
}

fn main() {
    println!("== E15: method cadence — bits AND oracle calls at matched gap ==\n");
    let iters = scaled(1500, 250);
    let methods = [Method::QGenX, Method::Peg, Method::EgAa];
    let mut curves = Vec::new();
    let mut lm_win = false;

    for case in cases() {
        let runs: Vec<(Method, Recorder)> = methods
            .iter()
            .map(|&m| (m, run_experiment(&method_cfg(&case, iters, m)).expect("bench run")))
            .collect();

        let target = 1.05
            * runs
                .iter()
                .map(|(_, r)| r.get("gap").unwrap().last().unwrap())
                .fold(f64::MIN, f64::max);

        let mut table = Table::new(&["method", "final gap", "bits@gap", "calls@gap", "x vs qgenx"]);
        let (bits_q, calls_q) =
            cost_to_gap(&runs[0].1, target, Method::QGenX).expect("baseline reaches the matched gap");
        let mut configs = Vec::new();
        for (method, rec) in &runs {
            let final_gap = rec.get("gap").unwrap().last().unwrap();
            let (bits, calls) =
                cost_to_gap(rec, target, *method).expect("every run reaches its own final gap");
            let total = rec.scalar("total_bits").unwrap();
            match method {
                // The default method stays scalar-for-scalar identical to
                // the pre-seam telemetry: no cadence scalars at all.
                Method::QGenX => {
                    assert!(rec.scalar("oracle_calls").is_none(), "qgenx run carries no cadence scalars");
                    assert!(rec.scalar("exchanges_per_step").is_none());
                }
                Method::Peg => {
                    assert_eq!(rec.scalar("exchanges_per_step"), Some(1.0), "PEG: one exchange/step");
                    assert_eq!(rec.scalar("oracle_calls"), Some(iters as f64), "PEG: one call/step");
                }
                Method::EgAa => {
                    assert_eq!(rec.scalar("exchanges_per_step"), Some(2.0), "EG-AA keeps the EG cadence");
                    assert!(rec.scalar("aa_accepted_steps").is_some(), "EG-AA reports its accept count");
                }
            }
            if *method == Method::Peg && case.kind == "lm-proxy" && bits < bits_q && calls < calls_q {
                lm_win = true;
            }
            table.row(&[
                method.name().to_string(),
                format!("{final_gap:.4}"),
                format!("{bits:.3e}"),
                format!("{calls:.0}"),
                format!("{:.2}", bits_q / bits),
            ]);
            let mut fields = vec![
                ("name", Json::Str(method.name().to_string())),
                ("final_gap", Json::Num(final_gap)),
                ("bits_at_gap", Json::Num(bits)),
                ("calls_at_gap", Json::Num(calls)),
                ("total_bits", Json::Num(total)),
            ];
            if let Some(n) = rec.scalar("aa_accepted_steps") {
                fields.push(("aa_accepted_steps", Json::Num(n)));
            }
            configs.push(Json::obj(fields));
        }
        println!(
            "-- oracle = {} (d = {}, matched gap {target:.4}, T = {iters}) --",
            case.kind, case.dim
        );
        table.print();
        println!();

        curves.push(Json::obj([
            ("oracle", Json::Str(case.kind.into())),
            ("dim", Json::Num(case.dim as f64)),
            ("target_gap", Json::Num(target)),
            ("configs", Json::Arr(configs)),
        ]));
    }

    let doc = Json::obj([
        ("bench", Json::Str("algo_tradeoff".into())),
        ("schema", Json::Num(1.0)),
        ("mode", Json::Str(if fast_mode() { "fast".into() } else { "full".into() })),
        ("curves", Json::Arr(curves)),
    ]);
    write_json("results/BENCH_algo.json", &doc).unwrap();
    println!("wrote results/BENCH_algo.json");

    if fast_mode() {
        println!("acceptance check skipped in QGENX_BENCH_FAST mode (budget too small)");
    } else {
        println!(
            "acceptance: PEG reaches the matched gap on lm-proxy with strictly\n\
             fewer wire bits AND strictly fewer oracle calls than Q-GenX (DE): {}",
            if lm_win { "YES" } else { "NO" }
        );
        assert!(lm_win, "PEG must beat the DE baseline on both axes on lm-proxy");
    }
    println!(
        "\npaper shape: dual extrapolation pays two stochastic-oracle rounds per\n\
         iteration to move through the extrapolated point. Popov's trick replays\n\
         the previous half-step dual as the extrapolation direction, halving both\n\
         the oracle and the wire budget per iteration at the cost of a slightly\n\
         smaller stable step-size — at a matched gap the single-call cadence wins\n\
         both axes. Anderson depth-1 mixing attacks the other axis: same cadence,\n\
         fewer iterations when the safeguard accepts the secant candidate."
    );
}
