//! Chaos degradation curves: how gracefully does Q-GenX degrade as the
//! network gets hostile? Two sweeps, both fully deterministic
//! (docs/SCENARIOS.md):
//!
//! 1. **Straggler sweep** (local-steps family): increase the modeled
//!    deadline-miss rate of the bounded-staleness semi-async sync and
//!    track final gap, cumulative sync drift, and how many resyncs
//!    substituted a carried stale delta. The deadline is *modeled* — no
//!    extra rounds or retransmissions anywhere in the sweep — so the
//!    curve isolates the pure optimization cost of staleness.
//! 2. **Rewire sweep** (gossip family): shrink the epoch length of a
//!    time-varying degree-regular gossip schedule and track final gap,
//!    consensus distance under churn, and observed edge-set changes.
//!
//! Acceptance: the rate-0 / static entries are bit-identical to the plain
//! synchronous / static runs (the chaos machinery is fully dormant when
//! off), and every sweep point converges to a finite gap. Emits
//! `results/BENCH_churn.json` with both curves.

use qgenx::benchkit::{fast_mode, scaled, write_json, Table};
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::run_experiment;
use qgenx::runtime::json::Json;

fn local_cfg(rate: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 64;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.4;
    cfg.workers = 8;
    cfg.iters = scaled(400, 120);
    cfg.eval_every = cfg.iters / 4;
    cfg.seed = 29;
    cfg.local.steps = 4;
    cfg.local.staleness = 2;
    cfg.local.straggler_rate = rate;
    cfg
}

fn gossip_cfg(rewire_every: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 64;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.4;
    cfg.workers = 12;
    cfg.iters = scaled(400, 120);
    cfg.eval_every = cfg.iters / 4;
    cfg.seed = 29;
    cfg.topo.kind = "gossip".into();
    cfg.topo.degree = 4;
    cfg.topo.rewire_every = rewire_every;
    cfg
}

fn main() {
    println!("== churn degradation: fault rate vs trajectory quality ==\n");

    // ---- sweep 1: bounded-staleness straggler rate (local family)
    println!("-- semi-async local steps (H=4, staleness cap 2, modeled deadline) --");
    let mut table = Table::new(&["straggler rate", "final gap", "sync drift", "stale syncs"]);
    let mut straggler_curve = Vec::new();
    let mut baseline: Option<(Vec<f64>, Option<f64>)> = None;
    for rate in [0.0, 0.1, 0.2, 0.4] {
        let rec = run_experiment(&local_cfg(rate)).unwrap();
        let gap = rec.get("gap").unwrap().last().unwrap();
        let drift = rec.get("sync_drift").unwrap().ys().iter().sum::<f64>();
        let stale = rec.scalar("stale_syncs").unwrap_or(0.0);
        assert!(gap.is_finite(), "rate {rate}: run must converge to a finite gap");
        if rate == 0.0 {
            // The dormant path must be bit-identical to a config that never
            // mentions staleness at all.
            let mut plain_cfg = local_cfg(0.0);
            plain_cfg.local.staleness = 0;
            let plain = run_experiment(&plain_cfg).unwrap();
            assert_eq!(rec.get("gap").unwrap().ys(), plain.get("gap").unwrap().ys());
            assert_eq!(stale, 0.0, "no substitutions at rate 0");
            baseline = Some((rec.get("gap").unwrap().ys(), rec.scalar("rounds")));
        } else {
            let (_, rounds) = baseline.as_ref().unwrap();
            assert_eq!(
                rec.scalar("rounds"),
                *rounds,
                "the deadline is modeled: no extra rounds or retransmissions"
            );
            assert!(stale > 0.0, "rate {rate} must actually substitute");
        }
        table.row(&[
            format!("{rate:.2}"),
            format!("{gap:.5}"),
            format!("{drift:.4}"),
            format!("{stale:.0}"),
        ]);
        straggler_curve.push(Json::obj([
            ("rate", Json::Num(rate)),
            ("gap", Json::Num(gap)),
            ("sync_drift", Json::Num(drift)),
            ("stale_syncs", Json::Num(stale)),
        ]));
    }
    table.print();

    // ---- sweep 2: gossip rewire cadence (time-varying topology)
    println!("\n-- time-varying gossip (K=12, degree 4, seeded circulant epochs) --");
    let mut table = Table::new(&["rewire every", "final gap", "consensus", "rewires"]);
    let mut rewire_curve = Vec::new();
    for rewire_every in [0usize, 20, 10, 5] {
        let rec = run_experiment(&gossip_cfg(rewire_every)).unwrap();
        let gap = rec.get("gap").unwrap().last().unwrap();
        let cons = rec.get("consensus_dist").unwrap().last().unwrap();
        let rewires = rec.scalar("rewires").unwrap_or(0.0);
        assert!(gap.is_finite() && cons.is_finite(), "rewire_every {rewire_every}: finite run");
        if rewire_every == 0 {
            assert_eq!(rec.scalar("rewires"), None, "static runs carry no rewire accounting");
        } else {
            assert!(rewires > 0.0, "rewire_every {rewire_every} must actually rewire");
        }
        table.row(&[
            if rewire_every == 0 { "static".into() } else { format!("{rewire_every}") },
            format!("{gap:.5}"),
            format!("{cons:.5}"),
            format!("{rewires:.0}"),
        ]);
        rewire_curve.push(Json::obj([
            ("rewire_every", Json::Num(rewire_every as f64)),
            ("gap", Json::Num(gap)),
            ("consensus_dist", Json::Num(cons)),
            ("rewires", Json::Num(rewires)),
        ]));
    }
    table.print();

    let doc = Json::obj([
        ("bench", Json::Str("churn_degradation".into())),
        ("schema", Json::Num(1.0)),
        ("mode", Json::Str(if fast_mode() { "fast".into() } else { "full".into() })),
        ("straggler_curve", Json::Arr(straggler_curve)),
        ("rewire_curve", Json::Arr(rewire_curve)),
    ]);
    write_json("results/BENCH_churn.json", &doc).unwrap();
    println!("\nwrote results/BENCH_churn.json");
    println!(
        "\npaper shape: both axes degrade smoothly — staleness costs extra drift but\n\
         no extra rounds (the deadline is modeled, not physical), and epoch\n\
         rewiring keeps consensus bounded because every epoch graph is degree-regular\n\
         with the same mixing weight. Fault-free entries are bit-identical to the\n\
         plain synchronous/static runs."
    );
}
