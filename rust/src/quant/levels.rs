//! Quantization level sequences (Definition 1).
//!
//! A level sequence is `ℓ = (ℓ_0, ℓ_1, …, ℓ_s, ℓ_{s+1})` with
//! `0 = ℓ_0 < ℓ_1 < … < ℓ_s < ℓ_{s+1} = 1`. We store only the `s` interior
//! levels; the endpoints are implicit. The alphabet the encoder sees has
//! `s + 2` symbols (indices `0..=s+1`).

use crate::error::{Error, Result};

/// A validated level sequence.
#[derive(Clone, Debug)]
pub struct Levels {
    /// Interior levels ℓ_1..ℓ_s, strictly increasing, in (0, 1).
    interior: Vec<f64>,
    /// Full sequence (0, ℓ_1..ℓ_s, 1) as f32 — the hot-path table.
    full_f32: Vec<f32>,
    /// `Some(s + 1)` when the levels are exactly uniform `j/(s+1)`: enables
    /// the O(1) bin computation on the hot path (§Perf).
    uniform_denom: Option<f32>,
}

impl PartialEq for Levels {
    fn eq(&self, other: &Self) -> bool {
        self.interior == other.interior
    }
}

impl Levels {
    fn build(interior: Vec<f64>) -> Self {
        let mut full_f32 = Vec::with_capacity(interior.len() + 2);
        full_f32.push(0.0);
        full_f32.extend(interior.iter().map(|&x| x as f32));
        full_f32.push(1.0);
        // Detect exact uniform spacing.
        let s = interior.len();
        let denom = (s + 1) as f64;
        let uniform = (0..s).all(|j| interior[j] == (j + 1) as f64 / denom);
        Levels { interior, full_f32, uniform_denom: uniform.then_some(denom as f32) }
    }

    /// Build from interior levels, validating Definition 1's ordering.
    pub fn new(interior: Vec<f64>) -> Result<Self> {
        if interior.is_empty() {
            return Err(Error::Quant("need at least one interior level".into()));
        }
        let mut prev = 0.0f64;
        for (i, &l) in interior.iter().enumerate() {
            if !(l.is_finite() && l > prev && l < 1.0) {
                return Err(Error::Quant(format!(
                    "level {i} = {l} violates 0 < ℓ_1 < … < ℓ_s < 1 (prev {prev})"
                )));
            }
            prev = l;
        }
        Ok(Levels::build(interior))
    }

    /// QSGD-style uniform levels: ℓ_j = j / (s + 1).
    pub fn uniform(s: usize) -> Self {
        assert!(s >= 1);
        let interior = (1..=s).map(|j| j as f64 / (s + 1) as f64).collect();
        Levels::build(interior)
    }

    /// NUQSGD-style exponential levels: ℓ_j = 2^{-(s + 1 - j)}
    /// (…, 1/8, 1/4, 1/2 for s = 3).
    pub fn exponential(s: usize) -> Self {
        assert!(s >= 1);
        let interior = (1..=s).map(|j| 2f64.powi(-((s + 1 - j) as i32))).collect();
        Levels::build(interior)
    }

    /// Number of interior levels `s`.
    pub fn s(&self) -> usize {
        self.interior.len()
    }

    /// Alphabet size `s + 2` (symbols 0..=s+1 including both endpoints).
    pub fn alphabet_size(&self) -> usize {
        self.interior.len() + 2
    }

    /// Interior levels ℓ_1..ℓ_s.
    pub fn interior(&self) -> &[f64] {
        &self.interior
    }

    /// ℓ_1, the smallest nonzero level (drives the Theorem 1 bound).
    pub fn l1(&self) -> f64 {
        self.interior[0]
    }

    /// Value of level `j` for `j ∈ 0..=s+1` (0 and 1 at the endpoints).
    #[inline]
    pub fn value(&self, j: usize) -> f64 {
        if j == 0 {
            0.0
        } else if j <= self.interior.len() {
            self.interior[j - 1]
        } else {
            1.0
        }
    }

    /// The full sequence including endpoints — what ships to the L1 kernel.
    pub fn full(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.interior.len() + 2);
        v.push(0.0);
        v.extend_from_slice(&self.interior);
        v.push(1.0);
        v
    }

    /// Full sequence as f32 (the dtype of the Pallas kernel operand and
    /// the Rust hot-path table).
    pub fn full_f32(&self) -> Vec<f32> {
        self.full_f32.clone()
    }

    /// Borrowed f32 table (hot path; index j in 0..=s+1).
    #[inline]
    pub fn table_f32(&self) -> &[f32] {
        &self.full_f32
    }

    /// `Some(s+1)` when levels are exactly uniform (O(1) bin math applies).
    #[inline]
    pub fn uniform_denom(&self) -> Option<f32> {
        self.uniform_denom
    }

    /// `τ(u)`: index of the level with `ℓ_τ <= u < ℓ_{τ+1}` for `u ∈ [0,1)`;
    /// `u == 1` maps to `s` (so that `τ+1 = s+1` is the top endpoint).
    /// Binary search over the interior levels: O(log s).
    #[inline]
    pub fn bin_of(&self, u: f64) -> usize {
        debug_assert!((0.0..=1.0).contains(&u), "u={u} out of [0,1]");
        if u >= 1.0 {
            return self.interior.len();
        }
        // partition_point = count of interior levels <= u.
        self.interior.partition_point(|&l| l <= u)
    }

    /// Variance of quantizing a single normalized coordinate `u`:
    /// `σ_Q²(u; ℓ) = (ℓ_{τ(u)+1} − u)(u − ℓ_{τ(u)})` (Eq. 3.1).
    #[inline]
    pub fn coord_variance(&self, u: f64) -> f64 {
        let t = self.bin_of(u);
        (self.value(t + 1) - u) * (u - self.value(t))
    }

    /// `ℓ̄ = max_{1<=j<=s} ℓ_{j+1}/ℓ_j` — the max level ratio of Theorem 1
    /// (includes the ratio to the top endpoint ℓ_{s+1} = 1).
    pub fn max_ratio(&self) -> f64 {
        let mut m: f64 = 1.0;
        for j in 0..self.interior.len() {
            let hi = if j + 1 < self.interior.len() { self.interior[j + 1] } else { 1.0 };
            m = m.max(hi / self.interior[j]);
        }
        m
    }

    /// Dimension threshold `d_th = (2/ℓ_1)^{min(q,2)}` of Theorem 1.
    pub fn d_threshold(&self, q: u32) -> f64 {
        let qm = q.min(2) as f64;
        (2.0 / self.l1()).powf(qm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn uniform_levels_are_evenly_spaced() {
        let l = Levels::uniform(3);
        assert_eq!(l.s(), 3);
        assert_eq!(l.alphabet_size(), 5);
        let full = l.full();
        assert_eq!(full, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn exponential_levels_double() {
        let l = Levels::exponential(3);
        assert_eq!(l.full(), vec![0.0, 0.125, 0.25, 0.5, 1.0]);
        assert!((l.max_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn new_validates_ordering() {
        assert!(Levels::new(vec![]).is_err());
        assert!(Levels::new(vec![0.0]).is_err());
        assert!(Levels::new(vec![0.5, 0.4]).is_err());
        assert!(Levels::new(vec![0.5, 0.5]).is_err());
        assert!(Levels::new(vec![0.5, 1.0]).is_err());
        assert!(Levels::new(vec![0.2, 0.7]).is_ok());
    }

    #[test]
    fn bin_of_brackets_u() {
        let l = Levels::new(vec![0.25, 0.5, 0.75]).unwrap();
        assert_eq!(l.bin_of(0.0), 0);
        assert_eq!(l.bin_of(0.1), 0);
        assert_eq!(l.bin_of(0.25), 1);
        assert_eq!(l.bin_of(0.3), 1);
        assert_eq!(l.bin_of(0.74), 2);
        assert_eq!(l.bin_of(0.75), 3);
        assert_eq!(l.bin_of(0.99), 3);
        assert_eq!(l.bin_of(1.0), 3);
    }

    #[test]
    fn prop_bin_brackets() {
        forall("bin_of brackets u", 200, |g| {
            let s = g.usize_in(1, 40);
            let l = Levels::new(g.levels(s)).unwrap();
            let u = g.f64_in(0.0, 1.0);
            let t = l.bin_of(u);
            assert!(l.value(t) <= u || u >= 1.0, "lower bracket");
            if u < 1.0 {
                assert!(u < l.value(t + 1), "upper bracket u={u} t={t}");
            }
        });
    }

    #[test]
    fn coord_variance_zero_at_levels() {
        let l = Levels::uniform(3);
        for u in [0.0, 0.25, 0.5, 0.75, 1.0] {
            assert!(l.coord_variance(u).abs() < 1e-15, "u={u}");
        }
        // Max at bin midpoints: (w/2)^2 with w = 0.25.
        let v = l.coord_variance(0.125);
        assert!((v - 0.015625).abs() < 1e-12);
    }

    #[test]
    fn max_ratio_uniform() {
        // For uniform s=3: ratios 2 (0.5/0.25), 1.5, 4/3 -> max 2.
        let l = Levels::uniform(3);
        assert!((l.max_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn d_threshold_formula() {
        let l = Levels::new(vec![0.5]).unwrap();
        assert!((l.d_threshold(2) - 16.0).abs() < 1e-9); // (2/0.5)^2
        assert!((l.d_threshold(1) - 4.0).abs() < 1e-9); // (2/0.5)^1
        assert!((l.d_threshold(u32::MAX) - 16.0).abs() < 1e-9); // min(q,2)=2
    }

    #[test]
    fn full_f32_roundtrip() {
        let l = Levels::uniform(7);
        let f = l.full_f32();
        assert_eq!(f.len(), 9);
        assert_eq!(f[0], 0.0);
        assert_eq!(f[8], 1.0);
    }
}
