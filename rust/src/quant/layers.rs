//! Layer-wise partition of the dual vector (Q-GenX-LW).
//!
//! Deep-learning dual vectors are concatenations of per-layer gradients
//! whose norm/variance profiles differ by orders of magnitude (embedding
//! tables vs. output heads), and layer-wise bit allocation strictly
//! improves the variance–bits trade-off over one global level sequence
//! (Nguyen et al. 2025, "Layer-wise Quantization for QODA"; Beznosikov et
//! al. 2023 frame compression heterogeneity as one of the pillars of
//! communication-efficient VIs). This module provides the two data types
//! the layer-wise pipeline is built on:
//!
//! * [`LayerMap`] — a validated partition of `0..d` into contiguous named
//!   layers. Explicit from `[quant.layers]` bounds, or auto-split into
//!   equal bucket-aligned ranges for the LM/GAN trainers.
//! * [`LayerStats`] — one [`SufficientStats`] per layer plus the **v3 stat
//!   wire format** that pools statistics *per layer* across workers
//!   (`[u32 n_layers][per layer: u32 count + hist_bins × f32 mass]`,
//!   little-endian). See `docs/WIRE.md` for the byte-layout diagrams and
//!   the v2→v3 evolution; v2 payloads (no layer header) remain the format
//!   of single-layer pipelines.
//!
//! The bit-budget allocator that redistributes a global bits/coordinate
//! budget over a [`LayerMap`] lives in [`crate::quant::alloc`]; the
//! per-layer compression state machine lives in
//! [`crate::coordinator::pipeline`].

use super::adaptive::SufficientStats;
use crate::error::{Error, Result};
use std::ops::Range;

/// A validated partition of the dual vector `0..d` into contiguous,
/// non-empty, named layers.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerMap {
    names: Vec<String>,
    /// Fence-post offsets: `bounds[0] = 0 < bounds[1] < … < bounds[n] = d`.
    bounds: Vec<usize>,
}

impl LayerMap {
    /// Build from layer names and *interior* split points (the end offset
    /// of every layer but the last; the last layer ends at `d`).
    pub fn new(names: Vec<String>, splits: &[usize], d: usize) -> Result<Self> {
        if names.is_empty() {
            return Err(Error::Quant("layer map needs at least one layer".into()));
        }
        if splits.len() + 1 != names.len() {
            return Err(Error::Quant(format!(
                "layer map: {} names need {} interior bounds, got {}",
                names.len(),
                names.len() - 1,
                splits.len()
            )));
        }
        if d == 0 {
            return Err(Error::Quant("layer map over an empty vector".into()));
        }
        let mut bounds = Vec::with_capacity(names.len() + 1);
        bounds.push(0);
        for (i, &b) in splits.iter().enumerate() {
            if b <= bounds[i] || b >= d {
                return Err(Error::Quant(format!(
                    "layer bound {b} (index {i}) violates 0 < b_1 < … < b_n-1 < d = {d}"
                )));
            }
            bounds.push(b);
        }
        bounds.push(d);
        for (i, name) in names.iter().enumerate() {
            if name.is_empty() {
                return Err(Error::Quant(format!("layer {i} has an empty name")));
            }
            if names[..i].contains(name) {
                return Err(Error::Quant(format!("duplicate layer name `{name}`")));
            }
        }
        Ok(LayerMap { names, bounds })
    }

    /// The trivial one-layer map covering the whole vector.
    pub fn single(d: usize) -> Result<Self> {
        LayerMap::new(vec!["all".into()], &[], d)
    }

    /// Auto-split `0..d` into `n` roughly equal layers, preferring
    /// boundaries on multiples of `align` (pass the quantizer bucket size
    /// so every bucket but each layer's last is full-width; buckets restart
    /// per layer, so alignment is an efficiency preference, not a
    /// correctness requirement). Falls back to the unaligned equal split
    /// when the grid is too coarse for `n` layers. This is the split the
    /// LM/GAN trainers and `--layers N` use when no explicit bounds are
    /// configured.
    pub fn equal_split(names: Vec<String>, d: usize, align: usize) -> Result<Self> {
        let n = names.len();
        if n == 0 {
            return Err(Error::Quant("layer map needs at least one layer".into()));
        }
        if n > d {
            return Err(Error::Quant(format!("cannot split d = {d} into {n} layers")));
        }
        let a = align.max(1);
        if a > 1 {
            if let Ok(m) = Self::equal_split_on_grid(names.clone(), d, a) {
                return Ok(m);
            }
        }
        Self::equal_split_on_grid(names, d, 1)
    }

    fn equal_split_on_grid(names: Vec<String>, d: usize, a: usize) -> Result<Self> {
        let n = names.len();
        let mut splits = Vec::with_capacity(n.saturating_sub(1));
        let mut prev = 0usize;
        for i in 1..n {
            // Ideal boundary, rounded down to the alignment grid, then
            // pushed forward if that collapsed the layer to zero width.
            let ideal = i * d / n;
            let mut b = (ideal / a) * a;
            if b <= prev {
                b = prev + a;
            }
            if b >= d {
                return Err(Error::Quant(format!(
                    "cannot split d = {d} into {n} layers aligned to {a}"
                )));
            }
            splits.push(b);
            prev = b;
        }
        LayerMap::new(names, &splits, d)
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Total dimension `d`.
    pub fn d(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Coordinate range of layer `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        self.bounds[i]..self.bounds[i + 1]
    }

    /// Width of layer `i`.
    pub fn dim(&self, i: usize) -> usize {
        self.bounds[i + 1] - self.bounds[i]
    }

    /// Per-layer widths.
    pub fn dims(&self) -> Vec<usize> {
        (0..self.len()).map(|i| self.dim(i)).collect()
    }

    /// Layer `i`'s slice of a full-dimension vector.
    pub fn slice<'a>(&self, i: usize, v: &'a [f32]) -> &'a [f32] {
        &v[self.range(i)]
    }

    /// Mutable variant of [`Self::slice`].
    pub fn slice_mut<'a>(&self, i: usize, v: &'a mut [f32]) -> &'a mut [f32] {
        &mut v[self.range(i)]
    }
}

/// Per-layer sufficient statistics plus the v3 stat wire format.
///
/// In-memory this is one [`SufficientStats`] per layer (all with the same
/// histogram bin count and norm exponent — per-layer overrides cover the
/// quantizer, not the statistic). On the wire it serializes as
///
/// ```text
/// [u32 n_layers | LE]
/// layer 0: [u32 vectors_seen][f32 norm² mass][hist_bins × f32 bin mass]   (all LE)
/// layer 1: …
/// ```
///
/// i.e. a layer-count header followed by one block per layer. The block is
/// the v2 payload plus one new `f32`: the layer's pooled norm² mass
/// `Σ_j λ_j = Σ_j ‖g_j‖_q²`, which the bit-budget allocator
/// ([`crate::quant::alloc`]) needs and which the v2 histogram (normalized
/// shape only) cannot recover. Pooling from payloads
/// ([`Self::absorb_bytes`]) agrees with in-memory pooling ([`Self::merge`])
/// layer by layer. Total size: `4 + n · (8 + 4 · hist_bins)` bytes — still
/// independent of `d`.
#[derive(Clone, Debug)]
pub struct LayerStats {
    per: Vec<SufficientStats>,
    bins: usize,
}

impl LayerStats {
    pub fn new(n_layers: usize, hist_bins: usize, q: u32) -> Self {
        LayerStats {
            per: (0..n_layers).map(|_| SufficientStats::new(hist_bins, q)).collect(),
            bins: hist_bins,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.per.len()
    }

    pub fn layer(&self, i: usize) -> &SufficientStats {
        &self.per[i]
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut SufficientStats {
        &mut self.per[i]
    }

    /// True when no layer has observed anything.
    pub fn is_empty(&self) -> bool {
        self.per.iter().all(|s| s.is_empty())
    }

    /// Per-layer norm² mass `Σ_j λ_j = Σ_j ‖g_j‖_q²` — the Theorem-1
    /// weights the bit-budget allocator consumes.
    pub fn weights(&self) -> Vec<f64> {
        self.per.iter().map(|s| s.total_weight()).collect()
    }

    /// In-memory pooling (layer-by-layer [`SufficientStats::merge`]).
    pub fn merge(&mut self, other: &LayerStats) {
        assert_eq!(self.per.len(), other.per.len(), "layer count mismatch in merge");
        for (a, b) in self.per.iter_mut().zip(other.per.iter()) {
            a.merge(b);
        }
    }

    /// Serialize to the v3 stat wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        Self::payload_from(&self.per.iter().collect::<Vec<_>>())
    }

    /// Assemble a v3 payload from borrowed per-layer statistics (the
    /// layer-wise compressor keeps its stats inside per-layer sub-states;
    /// this keeps the framing defined in exactly one place).
    pub fn payload_from(stats: &[&SufficientStats]) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + stats.len() * 8);
        out.extend_from_slice(&(stats.len() as u32).to_le_bytes());
        for s in stats {
            out.extend_from_slice(&s.to_block_v3());
        }
        out
    }

    /// Pool a peer's v3 payload into this one. Rejects layer-count or
    /// length mismatches — the compatibility rule runners rely on: every
    /// worker derives its layer map and histogram shape from the same
    /// config, so a mismatch is a deployment error, not data.
    pub fn absorb_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let block = 8 + 4 * self.bins;
        let want = 4 + self.per.len() * block;
        if bytes.len() != want {
            return Err(Error::Quant(format!(
                "v3 stat payload {} bytes, expected {want} ({} layers × {block} + 4)",
                bytes.len(),
                self.per.len()
            )));
        }
        let (head, body) = bytes.split_at(4);
        let n = u32::from_le_bytes([head[0], head[1], head[2], head[3]]) as usize;
        if n != self.per.len() {
            return Err(Error::Quant(format!(
                "v3 stat payload advertises {n} layers, this pipeline has {}",
                self.per.len()
            )));
        }
        for (i, s) in self.per.iter_mut().enumerate() {
            s.absorb_block_v3(&body[i * block..(i + 1) * block])?;
        }
        Ok(())
    }

    /// Reset every layer (start of a new schedule segment).
    pub fn reset(&mut self) {
        for s in self.per.iter_mut() {
            s.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::util::Rng;

    #[test]
    fn layer_map_basics() {
        let m = LayerMap::new(vec!["embed".into(), "body".into(), "head".into()], &[100, 400], 512)
            .unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.d(), 512);
        assert_eq!(m.range(0), 0..100);
        assert_eq!(m.range(1), 100..400);
        assert_eq!(m.range(2), 400..512);
        assert_eq!(m.dims(), vec![100, 300, 112]);
        assert_eq!(m.name(2), "head");
        let v: Vec<f32> = (0..512).map(|i| i as f32).collect();
        assert_eq!(m.slice(1, &v).len(), 300);
        assert_eq!(m.slice(1, &v)[0], 100.0);
    }

    #[test]
    fn layer_map_validation() {
        // wrong bound count
        assert!(LayerMap::new(vec!["a".into(), "b".into()], &[], 10).is_err());
        // non-increasing / out-of-range bounds
        assert!(LayerMap::new(vec!["a".into(), "b".into(), "c".into()], &[5, 5], 10).is_err());
        assert!(LayerMap::new(vec!["a".into(), "b".into()], &[10], 10).is_err());
        assert!(LayerMap::new(vec!["a".into(), "b".into()], &[0], 10).is_err());
        // duplicate / empty names
        assert!(LayerMap::new(vec!["a".into(), "a".into()], &[5], 10).is_err());
        assert!(LayerMap::new(vec!["".into()], &[], 10).is_err());
        // empty vector
        assert!(LayerMap::single(0).is_err());
        assert_eq!(LayerMap::single(7).unwrap().len(), 1);
    }

    #[test]
    fn equal_split_aligns_to_buckets() {
        let names: Vec<String> = (0..3).map(|i| format!("l{i}")).collect();
        let m = LayerMap::equal_split(names, 1000, 128).unwrap();
        // boundaries land on the bucket grid and partition 0..1000
        assert_eq!(m.len(), 3);
        for i in 0..2 {
            assert_eq!(m.range(i).end % 128, 0, "boundary {} not aligned", m.range(i).end);
        }
        assert_eq!(m.d(), 1000);
        // unaligned split is exact thirds
        let names: Vec<String> = (0..4).map(|i| format!("l{i}")).collect();
        let m = LayerMap::equal_split(names, 100, 0).unwrap();
        assert_eq!(m.dims(), vec![25, 25, 25, 25]);
        // grid too coarse → falls back to the unaligned equal split
        let names: Vec<String> = (0..5).map(|i| format!("l{i}")).collect();
        let m = LayerMap::equal_split(names, 256, 128).unwrap();
        assert_eq!(m.len(), 5);
        assert_eq!(m.d(), 256);
        assert!(m.dims().iter().all(|&w| w > 0));
        // more layers than coordinates is impossible
        let names: Vec<String> = (0..5).map(|i| format!("l{i}")).collect();
        assert!(LayerMap::equal_split(names, 3, 0).is_err());
    }

    fn observed(bins: usize, layers: &[usize], vecs: usize, seed: u64) -> LayerStats {
        let mut ls = LayerStats::new(layers.len(), bins, 2);
        let mut rng = Rng::seed_from(seed);
        for _ in 0..vecs {
            for (i, &d) in layers.iter().enumerate() {
                let g = rng.gaussian_vec(d, 1.0 + i as f64);
                ls.layer_mut(i).observe(&g);
            }
        }
        ls
    }

    #[test]
    fn v3_roundtrip_matches_merge_across_map_shapes() {
        // The satellite property: to_bytes/absorb_bytes parity with
        // in-memory merge across layer maps of 1, 3, and ragged sizes.
        for layers in [vec![64usize], vec![32, 32, 32], vec![1, 200, 7, 64]] {
            let a = observed(64, &layers, 3, 1000 + layers.len() as u64);
            let b = observed(64, &layers, 5, 2000 + layers.len() as u64);
            let mut merged = a.clone();
            merged.merge(&b);
            let mut absorbed = LayerStats::new(layers.len(), 64, 2);
            absorbed.absorb_bytes(&a.to_bytes()).unwrap();
            absorbed.absorb_bytes(&b.to_bytes()).unwrap();
            for i in 0..layers.len() {
                assert_eq!(
                    absorbed.layer(i).vectors_seen(),
                    merged.layer(i).vectors_seen(),
                    "layer {i} pooled count"
                );
                for u in [0.01, 0.1, 0.5, 0.9] {
                    assert!(
                        (absorbed.layer(i).cdf(u) - merged.layer(i).cdf(u)).abs() < 1e-6,
                        "layer {i} cdf({u})"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_v3_roundtrip_parity() {
        forall("v3 payload parity with merge", 40, |g| {
            let n = g.usize_in(1, 6);
            let bins = *g.choose(&[8usize, 32, 128]);
            let dims: Vec<usize> = (0..n).map(|_| g.usize_in(1, 100)).collect();
            let vecs_a = g.usize_in(0, 4);
            let vecs_b = g.usize_in(1, 4);
            let a = observed(bins, &dims, vecs_a, g.case as u64 + 31);
            let b = observed(bins, &dims, vecs_b, g.case as u64 + 77);
            let mut merged = a.clone();
            merged.merge(&b);
            let mut absorbed = LayerStats::new(n, bins, 2);
            absorbed.absorb_bytes(&a.to_bytes()).unwrap();
            absorbed.absorb_bytes(&b.to_bytes()).unwrap();
            let payload = a.to_bytes();
            assert_eq!(payload.len(), 4 + n * (8 + 4 * bins));
            for i in 0..n {
                assert_eq!(absorbed.layer(i).vectors_seen(), merged.layer(i).vectors_seen());
                for u in [0.05, 0.3, 0.8] {
                    assert!((absorbed.layer(i).cdf(u) - merged.layer(i).cdf(u)).abs() < 1e-6);
                }
                // The v3-only field (pooled norm² mass) survives the wire
                // up to f32 rounding of each summand.
                let wm = merged.layer(i).total_weight();
                let wa = absorbed.layer(i).total_weight();
                assert!((wa - wm).abs() <= 1e-5 * wm.max(1.0), "layer {i} weight {wa} vs {wm}");
            }
        });
    }

    #[test]
    fn v3_rejects_mismatched_payloads() {
        let a = observed(32, &[16, 16], 2, 9);
        let bytes = a.to_bytes();
        // truncated
        let mut sink = LayerStats::new(2, 32, 2);
        assert!(sink.absorb_bytes(&bytes[..bytes.len() - 1]).is_err());
        // layer-count mismatch (right length for 3 layers, wrong header)
        let mut sink3 = LayerStats::new(3, 32, 2);
        assert!(sink3.absorb_bytes(&bytes).is_err());
        // bin-count mismatch shows up as a length error
        let mut sink_bins = LayerStats::new(2, 64, 2);
        assert!(sink_bins.absorb_bytes(&bytes).is_err());
        // header forged to a different layer count but same length
        let mut forged = bytes.clone();
        forged[0] = 3;
        assert!(sink.absorb_bytes(&forged).is_err());
    }

    #[test]
    fn weights_track_layer_mass() {
        // Layer 1 observes vectors with ~3x the norm of layer 0 → its
        // λ-mass (norm²-weighted) must dominate.
        let mut ls = LayerStats::new(2, 64, 2);
        let mut rng = Rng::seed_from(5);
        for _ in 0..8 {
            let g0 = rng.gaussian_vec(64, 1.0);
            let g1 = rng.gaussian_vec(64, 3.0);
            ls.layer_mut(0).observe(&g0);
            ls.layer_mut(1).observe(&g1);
        }
        let w = ls.weights();
        assert!(w[1] > 4.0 * w[0], "weights {w:?} must reflect norm² mass");
        assert!(!ls.is_empty());
        ls.reset();
        assert!(ls.is_empty());
        assert_eq!(ls.weights(), vec![0.0, 0.0]);
    }
}
