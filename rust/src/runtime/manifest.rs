//! Typed view of `artifacts/manifest.json` (emitted by `python/compile/aot.py`).

use super::json::Json;
use crate::error::{Error, Result};
use std::path::Path;

/// Tensor metadata (shape + dtype string).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_array)
            .ok_or_else(|| Error::Manifest("tensor missing shape".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Manifest("bad dim".into())))
            .collect::<Result<_>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Manifest("tensor missing dtype".into()))?
            .to_string();
        Ok(TensorMeta { shape, dtype })
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// LM model metadata.
#[derive(Clone, Debug)]
pub struct LmMeta {
    pub preset: String,
    pub params: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq: usize,
    pub batch: usize,
}

/// GAN model metadata.
#[derive(Clone, Debug)]
pub struct GanMeta {
    pub params_g: usize,
    pub params_d: usize,
    pub nz: usize,
    pub batch: usize,
    pub data_dim: usize,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub lm: LmMeta,
    pub gan: GanMeta,
    pub quantize_d: usize,
    pub quantize_levels: usize,
    pub fused_d: usize,
    pub entries: std::collections::BTreeMap<String, EntryMeta>,
    pub lm_init_file: String,
    pub gan_g_init_file: String,
    pub gan_d_init_file: String,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let src = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.as_ref().display()
            ))
        })?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Self> {
        let j = Json::parse(src)?;
        let u = |path: &[&str]| -> Result<usize> {
            j.at(path)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Manifest(format!("missing {path:?}")))
        };
        let s = |path: &[&str]| -> Result<String> {
            j.at(path)
                .and_then(Json::as_str)
                .map(|x| x.to_string())
                .ok_or_else(|| Error::Manifest(format!("missing {path:?}")))
        };
        let lm = LmMeta {
            preset: s(&["lm", "preset"])?,
            params: u(&["lm", "params"])?,
            vocab: u(&["lm", "vocab"])?,
            d_model: u(&["lm", "d_model"])?,
            n_layers: u(&["lm", "n_layers"])?,
            seq: u(&["lm", "seq"])?,
            batch: u(&["lm", "batch"])?,
        };
        let gan = GanMeta {
            params_g: u(&["gan", "params_g"])?,
            params_d: u(&["gan", "params_d"])?,
            nz: u(&["gan", "nz"])?,
            batch: u(&["gan", "batch"])?,
            data_dim: u(&["gan", "data_dim"])?,
        };
        let mut entries = std::collections::BTreeMap::new();
        let entries_json = j
            .get("entries")
            .and_then(Json::as_object)
            .ok_or_else(|| Error::Manifest("missing entries".into()))?;
        for (name, e) in entries_json {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Manifest(format!("{name}: missing file")))?
                .to_string();
            let parse_tensors = |key: &str| -> Result<Vec<TensorMeta>> {
                e.get(key)
                    .and_then(Json::as_array)
                    .ok_or_else(|| Error::Manifest(format!("{name}: missing {key}")))?
                    .iter()
                    .map(TensorMeta::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntryMeta { file, inputs: parse_tensors("inputs")?, outputs: parse_tensors("outputs")? },
            );
        }
        Ok(Manifest {
            lm,
            gan,
            quantize_d: u(&["quantize", "d"])?,
            quantize_levels: u(&["quantize", "levels"])?,
            fused_d: u(&["fused_extragrad", "d"])?,
            entries,
            lm_init_file: s(&["inits", "lm"])?,
            gan_g_init_file: s(&["inits", "gan_g"])?,
            gan_d_init_file: s(&["inits", "gan_d"])?,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries.get(name).ok_or_else(|| {
            Error::Manifest(format!(
                "no entry `{name}` in manifest (have: {:?})",
                self.entries.keys().collect::<Vec<_>>()
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "lm": {"preset": "small", "params": 1000, "vocab": 256, "d_model": 128,
             "n_layers": 2, "n_heads": 4, "seq": 64, "d_ff": 512, "batch": 8},
      "gan": {"params_g": 100, "params_d": 90, "nz": 4, "hidden": 64,
              "data_dim": 2, "batch": 256, "gp_lambda": 1.0},
      "quantize": {"d": 4096, "levels": 16},
      "fused_extragrad": {"d": 4096},
      "entries": {
        "lm_step": {"file": "lm_step.hlo.txt",
          "inputs": [{"shape": [1000], "dtype": "float32"},
                     {"shape": [8, 64], "dtype": "int32"}],
          "outputs": [{"shape": [], "dtype": "float32"},
                      {"shape": [1000], "dtype": "float32"}]}
      },
      "inits": {"lm": "lm.f32", "gan_g": "g.f32", "gan_d": "d.f32"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.lm.preset, "small");
        assert_eq!(m.lm.params, 1000);
        assert_eq!(m.gan.params_d, 90);
        assert_eq!(m.quantize_d, 4096);
        let e = m.entry("lm_step").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[1].shape, vec![8, 64]);
        assert_eq!(e.inputs[1].dtype, "int32");
        assert_eq!(e.outputs[0].numel(), 1);
        assert!(m.entry("missing").is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        let no_entries = SAMPLE.replace("\"entries\"", "\"nentries\"");
        assert!(Manifest::parse(&no_entries).is_err());
    }
}
