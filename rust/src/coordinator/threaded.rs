//! Threaded coordinator: `K` real worker threads, replicated state,
//! actual encoded bytes through the in-process [`AllGather`]
//! [`crate::net::Transport`], delivered over the configured topology.
//!
//! [`run_threaded`] is a thin wrapper over [`crate::coordinator::Session`]:
//! it spawns one **transport-fabric session per rank** against a shared
//! [`AllGather`] group, steps each to completion, and checks the
//! replication invariant. Every rank runs the *same*
//! `ExchangePolicy`/`RoundEngine` code as the inline wrapper — the
//! execution mode is a fabric choice, not a second implementation. (The
//! same sessions run unchanged over [`crate::net::SocketTransport`] when
//! each rank is its own OS process — the `qgenx worker` CLI.)
//!
//! Replication invariant (exact topologies — mesh/star/ring/hierarchical):
//! every worker decodes the *same* payload set in the same rank order,
//! runs the same deterministic state update, and pools the same sufficient
//! statistics at level-update steps — so all replicas of `QGenX`, the
//! levels and the Huffman tables stay bit-identical without a parameter
//! server. Asserted at the end of every run by comparing
//! [`Session::replica`] across workers (the local family reports sync
//! bases — the raw iterate can sit an origin-shift rounding ulp off the
//! consensus point; see `algo::local`).
//!
//! Gossip topologies are *inexact by design*: replicas drift, and the run
//! records [`crate::metrics::consensus_distance`] instead of asserting
//! replica equality (series via the engine's out-of-band diagnostic
//! exchange at eval steps — not billed to traffic — plus a final scalar).
//!
//! Fault behavior: each rank session's engine holds a transport
//! [`crate::net::PoisonGuard`]; if one worker panics or errors mid-round
//! its peers' exchanges error out instead of deadlocking, and
//! `run_threaded` surfaces the failure.
//!
//! Direct `Session` use in threaded form (observers on chosen ranks,
//! partial stepping) is available through
//! [`crate::coordinator::SessionBuilder::transport`] — see `docs/API.md`
//! for the lockstep rules.

use super::session::Session;
use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::metrics::{consensus_distance, Recorder};
use crate::net::AllGather;
use crate::topo::Topology;

/// Outcome of one threaded run: rank-0 recorder plus the final replica
/// state of every worker (for the replication invariant check and tests).
pub struct ThreadedRun {
    pub recorder: Recorder,
    pub replicas: Vec<Vec<f32>>,
}

/// Run Algorithm 1 on `K` OS threads over the configured topology.
/// Functionally equivalent to [`super::inline::run_experiment`] modulo RNG
/// stream interleaving (the transport accounts whole wire bytes where the
/// inline encoder reports exact code bits — the seed's split, preserved).
pub fn run_threaded(cfg: &ExperimentConfig) -> Result<ThreadedRun> {
    cfg.validate()?;
    let topo = Topology::from_config(&cfg.topo, cfg.workers)?;
    let k = cfg.workers;
    let transport = AllGather::with_timeout(k, cfg.net.exchange_timeout());

    let handles: Vec<std::thread::JoinHandle<Result<(Recorder, Vec<f32>)>>> = (0..k)
        .map(|rank| {
            let cfg = cfg.clone();
            let transport = transport.clone();
            std::thread::Builder::new()
                .name(format!("qgenx-worker-{rank}"))
                .spawn(move || {
                    let out = (|| -> Result<(Recorder, Vec<f32>)> {
                        let mut session = Session::builder(cfg.clone())
                            .transport(transport.clone(), rank)
                            .build()?;
                        session.run_to(cfg.iters)?;
                        let replica = session.replica();
                        Ok((session.into_recorder(), replica))
                    })();
                    // An Err return (codec/oracle failure) must release the
                    // peers just like a panic does — otherwise they block at
                    // the barrier forever waiting for this worker's deposit.
                    if let Err(e) = &out {
                        transport.poison(&format!("worker {rank} failed: {e}"));
                    }
                    out
                })
                .expect("spawn worker")
        })
        .collect();

    let mut recorders = Vec::with_capacity(k);
    let mut replicas = Vec::with_capacity(k);
    for h in handles {
        let (rec, x) = h
            .join()
            .map_err(|_| Error::Coordinator("worker thread panicked".into()))??;
        recorders.push(rec);
        replicas.push(x);
    }
    let mut recorder = recorders.swap_remove(0);
    if topo.is_exact() {
        // Replication invariant: all replicas ended at the same state.
        for r in 1..k {
            if replicas[r] != replicas[0] {
                return Err(Error::Coordinator(format!(
                    "replica divergence: worker {r} differs from worker 0"
                )));
            }
        }
    } else {
        recorder.set_scalar("consensus_dist", consensus_distance(&replicas));
    }
    Ok(ThreadedRun { recorder, replicas })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::inline::run_experiment;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 3;
        cfg.iters = 150;
        cfg.eval_every = 50;
        cfg.problem.kind = "quadratic".into();
        cfg.problem.dim = 12;
        cfg.problem.noise = "absolute".into();
        cfg.problem.sigma = 0.3;
        cfg.quant.update_every = 60;
        cfg
    }

    #[test]
    fn threaded_run_completes_and_replicas_agree() {
        let run = run_threaded(&cfg()).unwrap();
        assert_eq!(run.replicas.len(), 3);
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0]);
        }
        let gap = run.recorder.get("gap").unwrap().last().unwrap();
        assert!(gap.is_finite());
    }

    #[test]
    fn threaded_matches_inline_bit_counts() {
        // Same config: identical wire-format sizes per round in expectation;
        // totals agree because both run the same number of rounds with the
        // same quantization parameters (RNG streams differ so exact bits
        // differ slightly under Huffman/Elias; compare within 5%).
        let c = cfg();
        let inline_rec = run_experiment(&c).unwrap();
        let threaded = run_threaded(&c).unwrap();
        let bi = inline_rec.scalar("total_bits").unwrap();
        let bt = threaded.recorder.scalar("total_bits").unwrap();
        assert!(
            (bi - bt).abs() / bi < 0.05,
            "inline {bi} vs threaded {bt}"
        );
        assert_eq!(
            inline_rec.scalar("rounds").unwrap(),
            threaded.recorder.scalar("rounds").unwrap()
        );
    }

    #[test]
    fn threaded_converges() {
        let mut c = cfg();
        c.iters = 400;
        let run = run_threaded(&c).unwrap();
        let gaps = run.recorder.get("gap").unwrap();
        let first = gaps.points.first().unwrap().1;
        let last = gaps.last().unwrap();
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn threaded_fp32_mode() {
        let mut c = cfg();
        c.quant.mode = crate::config::QuantMode::Fp32;
        c.iters = 60;
        let run = run_threaded(&c).unwrap();
        // fp32: bits = 32 * d * senders * rounds exactly — deterministic.
        let bits = run.recorder.scalar("total_bits").unwrap();
        let rounds = run.recorder.scalar("rounds").unwrap();
        let expect = rounds * 3.0 * 2.0 * 32.0 * 12.0;
        assert!((bits - expect).abs() < 1e-6, "bits {bits} expect {expect}");
    }

    #[test]
    fn all_topologies_run_threaded_end_to_end() {
        // Acceptance: all five topologies through coordinator::threaded on a
        // small problem; exact ones agree with the full-mesh replicas
        // bit-for-bit, gossip records consensus instead.
        let mut c = cfg();
        c.workers = 5;
        c.iters = 80;
        c.eval_every = 40;
        let mesh = run_threaded(&c).unwrap();
        for kind in ["star", "ring", "hierarchical"] {
            c.topo.kind = kind.into();
            let run = run_threaded(&c).unwrap();
            assert_eq!(
                run.replicas, mesh.replicas,
                "{kind} must reproduce the mesh trajectory bit-for-bit"
            );
            assert!(
                run.recorder.scalar("total_bits").unwrap()
                    < mesh.recorder.scalar("total_bits").unwrap(),
                "{kind} must put fewer bits on the wire than mesh"
            );
        }
        c.topo.kind = "gossip".into();
        c.topo.degree = 2;
        let run = run_threaded(&c).unwrap();
        let cons = run.recorder.scalar("consensus_dist").unwrap();
        assert!(cons.is_finite() && cons > 0.0, "gossip replicas must drift: {cons}");
        assert!(run.recorder.get("consensus_dist").unwrap().len() >= 2);
        assert!(run.recorder.get("gap").unwrap().last().unwrap().is_finite());
    }

    #[test]
    fn threaded_local_steps_sync_exactly_and_cut_bits() {
        let mut c = cfg();
        c.iters = 200;
        c.eval_every = 50;
        let exact = run_threaded(&c).unwrap();
        c.local.steps = 4;
        let local = run_threaded(&c).unwrap();
        // Exact topology: the final sync leaves every replica bit-identical
        // (run_threaded would have errored otherwise; assert explicitly).
        for r in &local.replicas[1..] {
            assert_eq!(r, &local.replicas[0]);
        }
        let bl = local.recorder.scalar("total_bits").unwrap();
        let be = exact.recorder.scalar("total_bits").unwrap();
        assert!(bl < be, "H = 4 must cut wire bits: {bl} vs {be}");
        assert_eq!(local.recorder.scalar("syncs"), Some(50.0));
        assert_eq!(local.recorder.scalar("local_steps"), Some(4.0));
        assert!(local.recorder.get("gap").unwrap().last().unwrap().is_finite());
        assert!(local.recorder.get("sync_drift").unwrap().len() >= 2);

        // Same seeds, same per-worker streams: threaded and inline local
        // runners agree on the wire budget.
        let inline_rec = run_experiment(&c).unwrap();
        let bi = inline_rec.scalar("total_bits").unwrap();
        assert!((bi - bl).abs() / bi < 0.05, "inline {bi} vs threaded {bl}");
    }

    #[test]
    fn threaded_local_steps_compose_with_gossip() {
        let mut c = cfg();
        c.workers = 5;
        c.iters = 120;
        c.eval_every = 40;
        c.local.steps = 3;
        c.topo.kind = "gossip".into();
        c.topo.degree = 2;
        let run = run_threaded(&c).unwrap();
        let cons = run.recorder.scalar("consensus_dist").unwrap();
        assert!(cons.is_finite() && cons > 0.0, "gossip replicas must drift: {cons}");
        assert_eq!(run.recorder.scalar("syncs"), Some(40.0));
    }

    #[test]
    fn threaded_layerwise_keeps_replicas_identical() {
        // Layer-wise levels/codecs/allocations update in lockstep from the
        // pooled v3 payloads, so the exact-topology replication invariant
        // must hold exactly as it does for the single-codec pipeline.
        let mut c = cfg();
        c.iters = 200;
        c.quant.bucket_size = 4;
        c.quant.layers.names = vec!["lo".into(), "hi".into()];
        c.quant.layers.bounds = vec![4];
        c.quant.layers.budget = 4.0;
        let run = run_threaded(&c).unwrap();
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0], "layer-wise replicas must stay bit-identical");
        }
        assert_eq!(run.recorder.scalar("layers"), Some(2.0));
        assert!(run.recorder.scalar("level_updates").unwrap() >= 1.0);
        assert!(run.recorder.scalar("layer_bits/lo").unwrap() > 0.0);
        assert!(run.recorder.get("layer_bits/hi").unwrap().len() >= 2);
        assert!(run.recorder.get("gap").unwrap().last().unwrap().is_finite());

        // And the threaded local-steps loop composes with layers too.
        c.local.steps = 4;
        let run = run_threaded(&c).unwrap();
        for r in &run.replicas[1..] {
            assert_eq!(r, &run.replicas[0]);
        }
        assert_eq!(run.recorder.scalar("syncs"), Some(50.0));
        assert_eq!(run.recorder.scalar("layers"), Some(2.0));
    }

    #[test]
    fn threaded_worker_panic_surfaces_as_error() {
        // A mid-run worker panic must produce Err, not a hang: drive the
        // transport directly the way worker_loop does.
        use std::sync::Arc;
        let transport = AllGather::new(2);
        let t1 = {
            let tr = Arc::clone(&transport);
            std::thread::spawn(move || {
                let _g = tr.guard();
                tr.exchange(1, vec![1]).unwrap();
                panic!("worker 1 dies");
            })
        };
        let t0 = {
            let tr = Arc::clone(&transport);
            std::thread::spawn(move || -> Result<()> {
                let _g = tr.guard();
                tr.exchange(0, vec![0])?;
                tr.exchange(0, vec![0])?; // peer is dead: must error
                Ok(())
            })
        };
        assert!(t1.join().is_err());
        assert!(t0.join().unwrap().is_err());
    }
}
