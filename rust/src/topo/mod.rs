//! Topology-aware collectives: which communication graph carries one
//! exchange round of Algorithm 1, at what α-β cost.
//!
//! The paper's Algorithm 1 states a flat synchronous all-to-all exchange of
//! encoded dual vectors — one topology, one cost curve. This subsystem
//! generalizes the exchange over five graphs so the repro can pose the
//! question the paper cannot: *how does `CODE ∘ Q` interact with the
//! communication graph?* (cf. Beznosikov et al. 2021/2023 on decentralized
//! extra-gradient and compression under restricted communication).
//!
//! * [`Topology`] — the graph family: full mesh, star (sharded parameter
//!   server), ring, two-level hierarchical tree, random-regular gossip.
//! * [`cost`] — per-topology α-β round timing and wire accounting,
//!   absorbing the seed's test-only `NetModel::star_round_time`
//!   ([`cost::centralized_star_time`]).
//! * [`collective`] — the [`Collective`] trait: executes one exchange round
//!   of *real encoded wire bytes* over the graph (the seed's `AllGather`
//!   becomes the full-mesh implementation), plus per-link traffic
//!   accounting ([`LinkTraffic`]).
//!
//! ## Exactness
//!
//! Mesh, star, ring and hierarchical are **exact**: every worker ends the
//! round knowing the rank-order mean of all `K` decoded dual vectors
//! (mesh by flat broadcast, the others by in-network aggregation — valid
//! because Algorithm 1 consumes only the mean; see `cost` for how the
//! per-worker step-size statistic survives aggregation). Exact topologies
//! therefore produce **bit-identical trajectories** and differ only in
//! modeled time / wire traffic. Gossip is **inexact**: each worker averages
//! over its closed graph neighborhood only, replicas genuinely diverge, and
//! [`crate::metrics::consensus_distance`] quantifies by how much.

pub mod collective;
pub mod cost;

pub use collective::{
    build_collective, build_collective_dynamic, Collective, Link, LinkTraffic, RewiringGossip,
};
pub use cost::{RoundCost, AGG_PIGGYBACK_BYTES};

use crate::config::TopoConfig;
use crate::error::{Error, Result};
use crate::util::Rng;

/// Communication graph for one exchange round among `K` workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Flat synchronous all-to-all (the paper's Algorithm 1; the seed's
    /// only mode). No aggregation: per-NIC traffic `O(K·b)`.
    FullMesh,
    /// Sharded parameter server: each worker serves `1/K` of the
    /// coordinates; push foreign shards, pull aggregated shards.
    Star,
    /// Ring allreduce: reduce-scatter + allgather of aggregate chunks.
    Ring,
    /// Two-level tree: `groups` contiguous groups, first rank of each
    /// group leads; reduce up, allgather across leaders, broadcast down.
    Hierarchical {
        /// Number of groups (resolved; never 0).
        groups: usize,
    },
    /// Fixed random-regular-ish gossip graph (ring base + seeded chords up
    /// to `degree`); workers average over closed neighborhoods only.
    Gossip {
        /// Target neighbor count per node (resolved to `[2, K−1]`).
        degree: usize,
        /// Seed for the chord placement (deterministic graph).
        seed: u64,
    },
}

impl Topology {
    /// Resolve a topology from the `[topo]` config table for `k` workers.
    /// Auto values are resolved here: `groups = 0` → `⌈√K⌉`, and explicit
    /// `groups` is normalized to the *realized* contiguous-partition count
    /// (e.g. K=5 with `groups = 4` partitions as {0,1},{2,3},{4} → 3);
    /// gossip `seed = 0` → derived from `degree` (stable across runs);
    /// gossip `degree` is clamped into `[2, K−1]` (to `K−1` when `K ≤ 3`).
    /// Out-of-range values are clamped, never errors — only `groups`
    /// exceeding `K` and `degree = 0` are rejected as likely typos.
    pub fn from_config(cfg: &TopoConfig, k: usize) -> Result<Topology> {
        if k == 0 {
            return Err(Error::Topology("topology needs at least 1 worker".into()));
        }
        match cfg.kind.as_str() {
            "full-mesh" | "mesh" | "all-to-all" | "full" => Ok(Topology::FullMesh),
            "star" | "ps" | "parameter-server" => Ok(Topology::Star),
            "ring" => Ok(Topology::Ring),
            "hierarchical" | "tree" | "two-level" => {
                let groups = if cfg.groups == 0 {
                    (k as f64).sqrt().ceil() as usize
                } else {
                    cfg.groups
                };
                if groups > k {
                    return Err(Error::Topology(format!(
                        "topo.groups = {groups} exceeds workers = {k}"
                    )));
                }
                // Normalize to the realized partition count so the field,
                // the cost model and the link pattern all agree.
                Ok(Topology::Hierarchical { groups: group_ranges(k, groups.max(1)).len() })
            }
            "gossip" | "random-regular" => {
                if cfg.degree == 0 {
                    return Err(Error::Topology("topo.degree must be >= 1".into()));
                }
                let degree = cfg.degree.max(2).min(k.saturating_sub(1).max(1));
                let seed =
                    if cfg.seed == 0 { 0xf0f0_u64 ^ (degree as u64) << 8 } else { cfg.seed };
                Ok(Topology::Gossip { degree, seed })
            }
            other => Err(Error::Topology(format!(
                "unknown topo.kind `{other}` \
                 (full-mesh|star|ring|hierarchical|gossip)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::FullMesh => "full-mesh",
            Topology::Star => "star",
            Topology::Ring => "ring",
            Topology::Hierarchical { .. } => "hierarchical",
            Topology::Gossip { .. } => "gossip",
        }
    }

    /// Exact topologies deliver the global rank-order mean to every worker
    /// (bit-identical trajectories across them); gossip does not.
    pub fn is_exact(&self) -> bool {
        !matches!(self, Topology::Gossip { .. })
    }
}

/// Contiguous group partition used by the hierarchical topology: `k` ranks
/// into groups of `⌈k/g⌉`, first rank of each range leads. The single
/// source of truth for grouping — both the cost model and the per-link
/// pattern derive from it, so they cannot desynchronize.
pub fn group_ranges(k: usize, groups: usize) -> Vec<std::ops::Range<usize>> {
    let g = groups.clamp(1, k.max(1));
    let m = k.div_ceil(g);
    let mut out = Vec::with_capacity(g);
    let mut gi = 0usize;
    while gi < k {
        let hi = (gi + m).min(k);
        out.push(gi..hi);
        gi = hi;
    }
    out
}

/// Build the gossip graph: ring base (connectivity) plus seeded chords
/// until nodes reach `degree` neighbors (or no legal chord remains).
/// Returns *open* neighborhoods, symmetric and sorted. Deterministic in
/// `(k, degree, seed)`.
pub fn gossip_neighbors(k: usize, degree: usize, seed: u64) -> Vec<Vec<usize>> {
    if k <= 1 {
        return vec![Vec::new(); k];
    }
    let degree = degree.max(1).min(k - 1);
    let mut adj = vec![std::collections::BTreeSet::new(); k];
    // ring base
    for i in 0..k {
        let j = (i + 1) % k;
        if i != j {
            adj[i].insert(j);
            adj[j].insert(i);
        }
    }
    let mut rng = Rng::seed_from(seed ^ (k as u64) << 32 ^ degree as u64);
    let mut attempts = 0usize;
    let budget = 64 * k * degree.max(1);
    while attempts < budget {
        attempts += 1;
        if adj.iter().all(|n| n.len() >= degree) {
            break;
        }
        let i = rng.below(k as u64) as usize;
        let j = rng.below(k as u64) as usize;
        if i == j || adj[i].contains(&j) || adj[i].len() >= degree || adj[j].len() >= degree {
            continue;
        }
        adj[i].insert(j);
        adj[j].insert(i);
    }
    adj.into_iter().map(|s| s.into_iter().collect()).collect()
}

/// Build one epoch of a time-varying gossip schedule: a *degree-regular*
/// circulant graph where `v`'s neighbors are `v ± o (mod k)` for a seeded
/// offset set. Unlike [`gossip_neighbors`], every node gets exactly the
/// same open degree — the invariant that lets per-replica algorithm states
/// survive rewiring (neighborhood *membership* churns between epochs,
/// neighborhood *size* never does). At least one offset is coprime with
/// `k`, so every epoch's graph is connected. The realized degree is the
/// request rounded down to what a circulant on `k` nodes can hit exactly
/// (`2·⌊degree/2⌋`, plus 1 via the diameter offset when `k` is even),
/// after clamping the request into `[2, k−1]`. Returns open neighborhoods,
/// symmetric and sorted; deterministic in `(k, degree, seed)`.
pub fn circulant_neighbors(k: usize, degree: usize, seed: u64) -> Vec<Vec<usize>> {
    if k <= 1 {
        return vec![Vec::new(); k];
    }
    let degree = degree.max(2).min(k - 1);
    // Offsets 1..=⌊(k−1)/2⌋ contribute two neighbors each; k/2 (k even)
    // contributes one. Shuffle the two-sided candidates, then make sure a
    // k-coprime offset is among the picks (connectivity).
    let mut cands: Vec<usize> = (1..=(k - 1) / 2).collect();
    let mut rng = Rng::seed_from(seed ^ (k as u64) << 32 ^ (degree as u64) << 1);
    rng.shuffle(&mut cands);
    let take = (degree / 2).min(cands.len());
    if take > 0 && !cands[..take].iter().any(|&o| gcd(o, k) == 1) {
        if let Some(pos) = cands.iter().position(|&o| gcd(o, k) == 1) {
            cands.swap(take - 1, pos);
        }
    }
    let mut offsets: Vec<usize> = cands.into_iter().take(take).collect();
    if degree % 2 == 1 && k % 2 == 0 {
        offsets.push(k / 2);
    }
    let mut adj = vec![std::collections::BTreeSet::new(); k];
    for (v, nv) in adj.iter_mut().enumerate() {
        for &o in &offsets {
            nv.insert((v + o) % k);
            nv.insert((v + k - o) % k);
        }
    }
    adj.into_iter().map(|s| s.into_iter().collect()).collect()
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopoConfig;

    fn cfg(kind: &str) -> TopoConfig {
        TopoConfig { kind: kind.into(), ..Default::default() }
    }

    #[test]
    fn parse_all_kinds_with_aliases() {
        assert_eq!(Topology::from_config(&cfg("mesh"), 4).unwrap(), Topology::FullMesh);
        assert_eq!(Topology::from_config(&cfg("all-to-all"), 4).unwrap(), Topology::FullMesh);
        assert_eq!(Topology::from_config(&cfg("ps"), 4).unwrap(), Topology::Star);
        assert_eq!(Topology::from_config(&cfg("ring"), 4).unwrap(), Topology::Ring);
        assert!(matches!(
            Topology::from_config(&cfg("tree"), 9).unwrap(),
            Topology::Hierarchical { groups: 3 }
        ));
        assert!(matches!(
            Topology::from_config(&cfg("gossip"), 8).unwrap(),
            Topology::Gossip { .. }
        ));
        assert!(Topology::from_config(&cfg("zzz"), 4).is_err());
    }

    #[test]
    fn hierarchical_auto_groups_is_ceil_sqrt_k() {
        for (k, want) in [(4, 2), (8, 3), (16, 4), (1, 1)] {
            let Topology::Hierarchical { groups } =
                Topology::from_config(&cfg("hierarchical"), k).unwrap()
            else {
                panic!()
            };
            assert_eq!(groups, want, "k={k}");
        }
        let mut c = cfg("hierarchical");
        c.groups = 9;
        assert!(Topology::from_config(&c, 4).is_err());
        // explicit groups normalize to the realized partition count:
        // k=5, groups=4 → {0,1},{2,3},{4} → 3 groups
        c.groups = 4;
        let Topology::Hierarchical { groups } = Topology::from_config(&c, 5).unwrap() else {
            panic!()
        };
        assert_eq!(groups, 3);
    }

    #[test]
    fn group_ranges_partition_exactly() {
        assert_eq!(group_ranges(5, 4), vec![0..2, 2..4, 4..5]);
        assert_eq!(group_ranges(8, 3), vec![0..3, 3..6, 6..8]);
        assert_eq!(group_ranges(4, 1), vec![0..4]);
        assert_eq!(group_ranges(3, 3), vec![0..1, 1..2, 2..3]);
        // ranges cover 0..k with no gaps or overlaps
        for (k, g) in [(7usize, 3usize), (9, 4), (16, 5), (1, 1)] {
            let rs = group_ranges(k, g);
            assert_eq!(rs.first().unwrap().start, 0);
            assert_eq!(rs.last().unwrap().end, k);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn gossip_degree_validation_and_clamping() {
        let mut c = cfg("gossip");
        c.degree = 0;
        assert!(Topology::from_config(&c, 8).is_err());
        // over-degree clamps to K−1 (never an error — matches the doc)
        c.degree = 8;
        let Topology::Gossip { degree, .. } = Topology::from_config(&c, 8).unwrap() else {
            panic!()
        };
        assert_eq!(degree, 7);
        c.degree = 4;
        let Topology::Gossip { degree, seed } = Topology::from_config(&c, 8).unwrap() else {
            panic!()
        };
        assert_eq!(degree, 4);
        assert_ne!(seed, 0);
        // tiny worker counts: default degree (3) must not be an error
        c.degree = 3;
        for k in [2usize, 3] {
            let Topology::Gossip { degree, .. } = Topology::from_config(&c, k).unwrap() else {
                panic!()
            };
            assert_eq!(degree, k - 1, "k={k}");
        }
    }

    #[test]
    fn gossip_graph_is_symmetric_connected_and_deterministic() {
        for (k, deg) in [(8usize, 3usize), (12, 4), (5, 2), (16, 5)] {
            let a = gossip_neighbors(k, deg, 7);
            let b = gossip_neighbors(k, deg, 7);
            assert_eq!(a, b, "deterministic for k={k}");
            // symmetry + no self loops + degree bounds
            for i in 0..k {
                assert!(!a[i].contains(&i));
                assert!(a[i].len() >= 2.min(k - 1), "node {i} under-connected");
                assert!(a[i].len() <= deg.max(2), "node {i} over degree: {:?}", a[i]);
                for &j in &a[i] {
                    assert!(a[j].contains(&i), "edge {i}-{j} not symmetric");
                }
            }
            // connectivity via BFS (ring base guarantees it)
            let mut seen = vec![false; k];
            let mut stack = vec![0usize];
            seen[0] = true;
            while let Some(i) = stack.pop() {
                for &j in &a[i] {
                    if !seen[j] {
                        seen[j] = true;
                        stack.push(j);
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "graph disconnected for k={k}");
        }
    }

    #[test]
    fn different_seeds_give_different_chords() {
        let a = gossip_neighbors(16, 5, 1);
        let b = gossip_neighbors(16, 5, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn circulant_is_degree_regular_symmetric_connected_deterministic() {
        for (k, deg) in [(2usize, 2usize), (3, 2), (5, 2), (6, 2), (8, 3), (12, 4), (16, 5)] {
            for seed in 0..8u64 {
                let a = circulant_neighbors(k, deg, seed);
                assert_eq!(a, circulant_neighbors(k, deg, seed), "deterministic k={k}");
                // degree-regular: every node has the same open degree
                let d0 = a[0].len();
                for (i, n) in a.iter().enumerate() {
                    assert_eq!(n.len(), d0, "irregular at node {i}, k={k} seed={seed}");
                    assert!(!n.contains(&i), "self loop at {i}");
                    assert!(n.windows(2).all(|w| w[0] < w[1]), "unsorted");
                    for &j in n {
                        assert!(a[j].contains(&i), "edge {i}-{j} not symmetric");
                    }
                }
                assert!(d0 >= 1 && d0 <= deg.max(2), "k={k} deg={deg} got {d0}");
                // connectivity via BFS (a coprime offset is always included)
                let mut seen = vec![false; k];
                let mut stack = vec![0usize];
                seen[0] = true;
                while let Some(i) = stack.pop() {
                    for &j in &a[i] {
                        if !seen[j] {
                            seen[j] = true;
                            stack.push(j);
                        }
                    }
                }
                assert!(seen.iter().all(|&s| s), "disconnected k={k} seed={seed}");
            }
        }
    }

    #[test]
    fn circulant_membership_varies_across_seeds_but_size_does_not() {
        // The rewiring invariant: across epochs (here: seeds) the edge set
        // churns while every node's neighborhood size stays fixed.
        let graphs: Vec<_> = (0..20u64).map(|s| circulant_neighbors(12, 4, s)).collect();
        let size = graphs[0][0].len();
        for g in &graphs {
            for n in g {
                assert_eq!(n.len(), size);
            }
        }
        assert!(
            graphs.iter().any(|g| g != &graphs[0]),
            "20 seeds never rewired the k=12 degree-4 circulant"
        );
    }
}
