//! The paper's experiment (§5): WGAN-GP training with quantized gradient
//! exchange — Q-GenX instantiated on a two-player game, with per-phase
//! backward timing (GenBP / DiscBP / PenBP) and exact wire accounting.
//!
//! Mapping from the paper's setup (DESIGN.md §Hardware-Adaptation):
//! CIFAR-10 → ring-of-Gaussians; FID → energy distance; 3×V100+Ethernet →
//! K worker shards with measured HLO-exec time + α-β-modeled comm; CUDA
//! torch_cgx buckets → `quant::` with bucket size 1024; ExtraAdam →
//! extra-gradient (the un-Adam'd core the paper's theory actually covers).
//!
//! The joint dual vector is `V = (∇_g L_g, ∇_d L_d) ∈ ℝ^{Pg+Pd}` — the
//! game operator whose zeros are the GAN's equilibria. Per Algorithm 1:
//! each worker computes its *local* V on its private data shard, quantizes,
//! allgathers; everyone averages and takes the extra-gradient step.

use super::data::{energy_distance_2d, ring_of_gaussians};
use crate::config::{QuantConfig, QuantMode};
use crate::coordinator::Compressor;
use crate::error::Result;
use crate::metrics::Recorder;
use crate::net::{NetModel, TrafficStats};
use crate::runtime::{Arg, Runtime};
use crate::util::{axpy, mean_into, Rng};
use std::time::Instant;

/// Compression mode for the Figure-1 comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GanMode {
    Fp32,
    Uq8,
    Uq4,
}

impl GanMode {
    pub fn name(&self) -> &'static str {
        match self {
            GanMode::Fp32 => "FP32",
            GanMode::Uq8 => "UQ8",
            GanMode::Uq4 => "UQ4",
        }
    }

    pub fn quant_config(&self) -> QuantConfig {
        // torch_cgx semantics: uniform levels, fixed-width symbols, bucket
        // size 1024 — "the simplest possible unbiased quantization" of §5.
        let mut q = QuantConfig::default();
        q.bucket_size = 1024;
        q.scheme = crate::config::LevelScheme::Uniform;
        q.codec = crate::coding::SymbolCodec::Fixed;
        match self {
            GanMode::Fp32 => q.mode = QuantMode::Fp32,
            GanMode::Uq8 => q.mode = QuantMode::Quantized { levels: 254 },
            GanMode::Uq4 => q.mode = QuantMode::Quantized { levels: 14 },
        }
        q
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(GanMode::Fp32),
            "uq8" => Some(GanMode::Uq8),
            "uq4" => Some(GanMode::Uq4),
            _ => None,
        }
    }

    /// [`Self::quant_config`] with the game's natural two-layer map
    /// installed: `gen` = `0..Pg`, `disc` = `Pg..Pg+Pd` (the joint dual
    /// vector concatenates the players' gradients, whose norm profiles
    /// differ persistently in WGAN-GP training). FP32 has no layer-wise
    /// path, so it stays flat.
    pub fn quant_config_layered(&self, params_g: usize) -> QuantConfig {
        let mut q = self.quant_config();
        if q.mode != QuantMode::Fp32 {
            q.layers.names = vec!["gen".into(), "disc".into()];
            q.layers.bounds = vec![params_g];
        }
        q
    }
}

/// GAN training configuration.
#[derive(Clone, Debug)]
pub struct GanTrainConfig {
    pub mode: GanMode,
    pub workers: usize,
    pub steps: usize,
    pub gamma: f64,
    pub eval_every: usize,
    pub seed: u64,
    /// Split the critic backward into W-part and GP-part (two artifact
    /// executions) to measure DiscBP and PenBP separately as in Figure 3.
    pub split_penalty: bool,
    /// Layer-wise quantization over the game's natural two-layer map:
    /// `gen` = `0..Pg`, `disc` = `Pg..Pg+Pd` (the joint dual vector is the
    /// concatenation of the two players' gradients, whose norm profiles
    /// differ persistently in WGAN-GP training).
    pub layerwise: bool,
}

impl Default for GanTrainConfig {
    fn default() -> Self {
        GanTrainConfig {
            mode: GanMode::Uq4,
            workers: 3,
            steps: 300,
            gamma: 0.01,
            eval_every: 25,
            seed: 7,
            split_penalty: true,
            layerwise: false,
        }
    }
}

/// Per-phase accumulated backward times (the Figure-1/3 table row).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    pub gen_bp: f64,
    pub disc_bp: f64,
    pub pen_bp: f64,
    /// encode + decode + exchange (modeled network + measured codec time)
    pub comm: f64,
    pub steps: usize,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.gen_bp + self.disc_bp + self.pen_bp + self.comm
    }

    /// Per-step averages in seconds: (gen, disc, pen, total).
    pub fn averages(&self) -> (f64, f64, f64, f64) {
        let n = self.steps.max(1) as f64;
        (self.gen_bp / n, self.disc_bp / n, self.pen_bp / n, self.total() / n)
    }
}

/// The WGAN-GP trainer over the AOT artifacts.
pub struct GanTrainer<'rt> {
    rt: &'rt mut Runtime,
    cfg: GanTrainConfig,
    theta_g: Vec<f32>,
    theta_d: Vec<f32>,
    comps: Vec<Compressor>,
    rngs: Vec<Rng>,
    net: NetModel,
    pub traffic: TrafficStats,
    pub phases: PhaseTimes,
    real_eval: Vec<f32>,
}

impl<'rt> GanTrainer<'rt> {
    pub fn new(rt: &'rt mut Runtime, cfg: GanTrainConfig, net: NetModel) -> Result<Self> {
        let m = rt.manifest().clone();
        let theta_g = rt.load_f32_blob(&m.gan_g_init_file)?;
        let theta_d = rt.load_f32_blob(&m.gan_d_init_file)?;
        let root = Rng::seed_from(cfg.seed);
        let qcfg = if cfg.layerwise {
            cfg.mode.quant_config_layered(theta_g.len())
        } else {
            cfg.mode.quant_config()
        };
        let comps = (0..cfg.workers)
            .map(|w| Compressor::from_config(&qcfg, root.fork(w as u64 + 11)))
            .collect::<Result<Vec<_>>>()?;
        let rngs: Vec<Rng> = (0..cfg.workers).map(|w| root.fork(w as u64 + 211)).collect();
        let mut eval_rng = Rng::seed_from(cfg.seed ^ 0xe5a1);
        let real_eval = ring_of_gaussians(256, 8, 2.0, 0.05, &mut eval_rng);
        Ok(GanTrainer { rt, cfg, theta_g, theta_d, comps, rngs, net, traffic: TrafficStats::default(), phases: PhaseTimes::default(), real_eval })
    }

    /// Dual-vector dimension Pg + Pd.
    fn joint_dim(&self) -> usize {
        self.theta_g.len() + self.theta_d.len()
    }

    /// One worker's joint dual vector at (θg, θd): runs the gen and critic
    /// backward passes through the runtime and times each phase.
    fn local_dual_vector(
        &mut self,
        worker: usize,
        theta_g: &[f32],
        theta_d: &[f32],
        time_phases: bool,
    ) -> Result<Vec<f32>> {
        let m = self.rt.manifest().clone();
        let b = m.gan.batch;
        let nz = m.gan.nz;
        let rng = &mut self.rngs[worker];
        let real = ring_of_gaussians(b, 8, 2.0, 0.05, rng);
        let z = rng.gaussian_vec(b * nz, 1.0);
        let eps = rng.uniform_vec(b);

        // GenBP
        let t0 = Instant::now();
        let (_lg, grad_g) = self.rt.run_loss_grad(
            "gan_gen_step",
            &[
                Arg::F32(theta_d, &[m.gan.params_d]),
                Arg::F32(theta_g, &[m.gan.params_g]),
                Arg::F32(&z, &[b, nz]),
            ],
        )?;
        let t_gen = t0.elapsed().as_secs_f64();

        // DiscBP (+ PenBP)
        let (grad_d, t_disc, t_pen) = if self.cfg.split_penalty {
            let t1 = Instant::now();
            let (_lw, mut gd) = self.rt.run_loss_grad(
                "gan_disc_w_step",
                &[
                    Arg::F32(theta_d, &[m.gan.params_d]),
                    Arg::F32(theta_g, &[m.gan.params_g]),
                    Arg::F32(&real, &[b, 2]),
                    Arg::F32(&z, &[b, nz]),
                ],
            )?;
            let t_disc = t1.elapsed().as_secs_f64();
            let t2 = Instant::now();
            let (_lp, gp) = self.rt.run_loss_grad(
                "gan_pen_step",
                &[
                    Arg::F32(theta_d, &[m.gan.params_d]),
                    Arg::F32(theta_g, &[m.gan.params_g]),
                    Arg::F32(&real, &[b, 2]),
                    Arg::F32(&z, &[b, nz]),
                    Arg::F32(&eps, &[b, 1]),
                ],
            )?;
            let t_pen = t2.elapsed().as_secs_f64();
            axpy(1.0, &gp, &mut gd); // grad(W + λGP) = grad W + grad λGP
            (gd, t_disc, t_pen)
        } else {
            let t1 = Instant::now();
            let (_ld, gd) = self.rt.run_loss_grad(
                "gan_disc_step",
                &[
                    Arg::F32(theta_d, &[m.gan.params_d]),
                    Arg::F32(theta_g, &[m.gan.params_g]),
                    Arg::F32(&real, &[b, 2]),
                    Arg::F32(&z, &[b, nz]),
                    Arg::F32(&eps, &[b, 1]),
                ],
            )?;
            (gd, t1.elapsed().as_secs_f64(), 0.0)
        };

        if time_phases {
            // Wall-clock model: the K workers of the simulated cluster run
            // their backward passes in parallel; we execute them serially
            // on one host, so each call charges 1/K of its measured time.
            let par = self.cfg.workers as f64;
            self.phases.gen_bp += t_gen / par;
            self.phases.disc_bp += t_disc / par;
            self.phases.pen_bp += t_pen / par;
        }

        // Joint dual vector: generator plays descent on L_g, critic descent
        // on L_d (L_d already has the signs of a min problem for D).
        let mut v = Vec::with_capacity(self.joint_dim());
        v.extend_from_slice(&grad_g);
        v.extend_from_slice(&grad_d);
        Ok(v)
    }

    /// Quantize + allgather + decode one round of per-worker vectors;
    /// returns the decoded mean and records comm time/bits.
    fn exchange_mean(&mut self, locals: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        let d = self.joint_dim();
        let k = self.cfg.workers as f64;
        // Encode: each worker encodes only its own vector -> parallel on
        // the cluster -> charge measured/K.
        let t0 = Instant::now();
        let mut bits = Vec::with_capacity(self.cfg.workers);
        let mut wires = Vec::with_capacity(self.cfg.workers);
        for (w, v) in locals.iter().enumerate() {
            let (bytes, b) = self.comps[w].compress(v)?;
            bits.push(b);
            wires.push(bytes);
        }
        let encode_time = t0.elapsed().as_secs_f64() / k;
        // Decode: every worker decodes all K payloads -> our K serial
        // decodes equal one worker's wall time -> charge in full.
        let t1 = Instant::now();
        let mut decoded = vec![vec![0.0f32; d]; self.cfg.workers];
        for (w, bytes) in wires.iter().enumerate() {
            self.comps[0].decompress(bytes, &mut decoded[w])?;
        }
        let decode_time = t1.elapsed().as_secs_f64();
        let codec_time = encode_time + decode_time;
        self.traffic.add_compute(codec_time);
        self.traffic.record_allgather(&bits, &self.net);
        self.phases.comm += codec_time + self.net.allgather_time(
            &bits.iter().map(|&b| crate::net::bits_to_bytes(b)).collect::<Vec<_>>(),
        );
        let refs: Vec<&[f32]> = decoded.iter().map(|v| v.as_slice()).collect();
        let mut mean = vec![0.0f32; d];
        mean_into(&refs, &mut mean);
        Ok(mean)
    }


    /// QAda level-update step (no-op for the fixed-level UQ modes; active
    /// when a caller installs an adaptive QuantConfig).
    fn maybe_update_levels(&mut self, t: usize) -> Result<()> {
        let every = self.cfg.mode.quant_config().update_every;
        if every == 0 || t % every != 0 {
            return Ok(());
        }
        // Shared with the coordinator engine and the LM trainer; a no-op
        // for the fixed-level UQ modes (all payloads empty).
        crate::coordinator::pool_local_stats(&mut self.comps, &self.net, &mut self.traffic)
            .map(|_| ())
    }

    /// One extra-gradient step (two oracle rounds, two exchanges).
    pub fn step(&mut self) -> Result<()> {
        let k = self.cfg.workers;
        let gamma = self.cfg.gamma as f32;
        let (pg, pd) = (self.theta_g.len(), self.theta_d.len());

        // Leg 1 at θ.
        let tg = self.theta_g.clone();
        let td = self.theta_d.clone();
        let locals: Vec<Vec<f32>> =
            (0..k).map(|w| self.local_dual_vector(w, &tg, &td, true)).collect::<Result<_>>()?;
        let mean = self.exchange_mean(locals)?;
        let mut tg_half = tg.clone();
        let mut td_half = td.clone();
        axpy(-gamma, &mean[..pg], &mut tg_half);
        axpy(-gamma, &mean[pg..pg + pd], &mut td_half);

        // Leg 2 at θ_{+1/2}.
        let locals_half: Vec<Vec<f32>> = (0..k)
            .map(|w| self.local_dual_vector(w, &tg_half, &td_half, true))
            .collect::<Result<_>>()?;
        let mean_half = self.exchange_mean(locals_half)?;
        axpy(-gamma, &mean_half[..pg], &mut self.theta_g);
        axpy(-gamma, &mean_half[pg..pg + pd], &mut self.theta_d);
        self.phases.steps += 1;
        Ok(())
    }

    /// Energy distance between generator samples and held-out real data —
    /// the FID analog.
    pub fn eval_metric(&mut self) -> Result<f64> {
        let m = self.rt.manifest().clone();
        let b = m.gan.batch;
        let mut rng = Rng::seed_from(self.cfg.seed ^ 0x5a5a);
        let z = rng.gaussian_vec(b * m.gan.nz, 1.0);
        let outs = self.rt.run(
            "gan_sample",
            &[Arg::F32(&self.theta_g, &[m.gan.params_g]), Arg::F32(&z, &[b, m.gan.nz])],
        )?;
        Ok(energy_distance_2d(&outs[0], &self.real_eval))
    }

    /// Full training run; recorder series: `metric` (energy distance),
    /// `bits_cum`, `time_cum` (backward+comm).
    pub fn train(&mut self) -> Result<Recorder> {
        let mut rec = Recorder::new();
        let m0 = self.eval_metric()?;
        rec.push("metric", 0.0, m0);
        for t in 1..=self.cfg.steps {
            self.maybe_update_levels(t)?;
            self.step()?;
            if t % self.cfg.eval_every.max(1) == 0 || t == self.cfg.steps {
                rec.push("metric", t as f64, self.eval_metric()?);
                rec.push("bits_cum", t as f64, self.traffic.bits_sent as f64);
                rec.push("time_cum", t as f64, self.phases.total());
            }
        }
        let (g, d, p, tot) = self.phases.averages();
        rec.set_scalar("avg_gen_bp", g);
        rec.set_scalar("avg_disc_bp", d);
        rec.set_scalar("avg_pen_bp", p);
        rec.set_scalar("avg_total", tot);
        rec.set_scalar("total_bits", self.traffic.bits_sent as f64);
        rec.set_scalar("comm_time", self.phases.comm);
        self.comps[0].emit_layer_scalars(&mut rec);
        Ok(rec)
    }

    /// Zero the timing/traffic counters (call after warmup steps so that
    /// one-time XLA compilation does not pollute the measured phases).
    pub fn reset_counters(&mut self) {
        self.phases = PhaseTimes::default();
        self.traffic = TrafficStats::default();
    }

    pub fn mode(&self) -> GanMode {
        self.cfg.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    fn trainer_cfg(mode: GanMode, steps: usize) -> GanTrainConfig {
        GanTrainConfig { mode, steps, workers: 2, eval_every: steps, ..Default::default() }
    }

    #[test]
    fn layered_quant_config_builds_a_layerwise_compressor() {
        // No artifacts needed: the gen/disc split must produce a working
        // layer-wise pipeline at the joint dual dimension.
        use crate::coordinator::Compressor;
        use crate::util::Rng;
        let (pg, pd) = (96usize, 64usize);
        for mode in [GanMode::Uq4, GanMode::Uq8] {
            let q = mode.quant_config_layered(pg);
            assert_eq!(q.layers.names, vec!["gen", "disc"]);
            assert_eq!(q.layers.bounds, vec![pg]);
            let mut c = Compressor::from_config(&q, Rng::seed_from(1)).unwrap();
            assert!(c.is_layerwise());
            let v = Rng::seed_from(2).gaussian_vec(pg + pd, 1.0);
            let (wire, _) = c.compress(&v).unwrap();
            let mut out = vec![0.0f32; pg + pd];
            c.decompress(&wire, &mut out).unwrap();
            let bits = c.layer_wire_bits().unwrap();
            assert!(bits[0] > 0 && bits[1] > 0);
        }
        // FP32 has no layer-wise path and must stay flat.
        let q = GanMode::Fp32.quant_config_layered(pg);
        assert!(q.layers.names.is_empty());
        let c = Compressor::from_config(&q, Rng::seed_from(3)).unwrap();
        assert!(!c.is_layerwise());
    }

    #[test]
    fn gan_trains_and_metric_improves() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let mut tr =
            GanTrainer::new(&mut rt, trainer_cfg(GanMode::Uq4, 60), NetModel::gbe()).unwrap();
        let rec = tr.train().unwrap();
        let series = rec.get("metric").unwrap();
        let first = series.points.first().unwrap().1;
        let last = series.last().unwrap();
        assert!(last < first, "energy distance should fall: {first} -> {last}");
        assert!(rec.scalar("avg_total").unwrap() > 0.0);
        assert!(tr.traffic.bits_sent > 0);
    }

    #[test]
    fn quantized_modes_send_fewer_bits_than_fp32() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let bits_of = |rt: &mut Runtime, mode| {
            let mut tr = GanTrainer::new(rt, trainer_cfg(mode, 3), NetModel::gbe()).unwrap();
            tr.train().unwrap();
            tr.traffic.bits_sent
        };
        let fp32 = bits_of(&mut rt, GanMode::Fp32);
        let uq8 = bits_of(&mut rt, GanMode::Uq8);
        let uq4 = bits_of(&mut rt, GanMode::Uq4);
        assert!(uq4 < uq8 && uq8 < fp32, "uq4 {uq4} uq8 {uq8} fp32 {fp32}");
        assert!(uq4 * 4 < fp32, "uq4 should be >4x smaller than fp32");
    }
}
