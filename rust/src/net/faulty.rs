//! Deterministic fault injection at the [`Transport`] seam.
//!
//! [`FaultyTransport`] decorates any transport — the in-process
//! [`crate::net::AllGather`] barrier (the "loopback wrapper" case: one
//! shared decorator serves every thread of a threaded group) or a
//! per-process [`crate::net::SocketTransport`] — and perturbs data-plane
//! exchanges according to a scripted [`FaultPlan`]: link delays
//! (stragglers), dropped or truncated payloads, and worker death at a
//! chosen round. Faults fire on the *sender* side, before the payload is
//! deposited, so every rank of the group observes the identical mangled
//! bytes in the identical round and fails (or recovers) in lockstep —
//! a corrupted round can never leave half the group waiting on a barrier
//! the other half already abandoned. Worker death goes through the
//! poison path exactly like a real crash, so peers surface
//! `transport poisoned` instead of hanging.
//!
//! Plans come from a compact scenario string (`kill@2:5,delay@0:3:40`) or
//! from a seeded per-rank schedule ([`FaultPlan::seeded_delays`]); both are
//! pure functions of their inputs, so the same scenario reproduces the
//! same failure bit-for-bit. Rounds are counted per exchanging rank on the
//! data plane only — control and out-of-band rounds pass through
//! untouched. See docs/SCENARIOS.md for the scenario format.

use crate::error::{Error, Result};
use crate::net::transport::{MeasuredWire, Plane, Transport};
use crate::util::rng::splitmix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One injected failure mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Stall the sender for `ms` milliseconds before the exchange — a slow
    /// link / straggler. Trajectory-neutral: the payload is untouched.
    Delay { ms: u64 },
    /// Replace the payload with zero bytes — a lost message whose frame
    /// still arrives (decoders must reject it, not panic).
    Drop,
    /// Keep only the first `keep` bytes of the payload — a torn write.
    Truncate { keep: usize },
    /// The worker dies mid-round: the group is poisoned and the exchange
    /// returns the poison error, exactly like a peer crash.
    Kill,
}

/// [`Fault`] scheduled at one `(rank, round)` cell of the exchange grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultRule {
    /// Rank whose exchange is perturbed.
    pub rank: usize,
    /// Zero-based data-plane round index at which the fault fires.
    pub round: u64,
    pub fault: Fault,
}

/// A deterministic schedule of [`FaultRule`]s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    pub fn new(rules: Vec<FaultRule>) -> Self {
        FaultPlan { rules }
    }

    /// Parse a comma-separated scenario string. Each entry is
    /// `kind@rank:round[:arg]` with kinds `delay` (arg = milliseconds,
    /// default 10), `drop`, `trunc` (arg = bytes kept, default 0) and
    /// `kill`: `"kill@2:5,delay@0:3:40,drop@1:2,trunc@1:4:7"`.
    pub fn parse(spec: &str) -> Result<Self> {
        let bad = |entry: &str, why: &str| {
            Error::Config(format!(
                "bad fault spec `{entry}`: {why} (expected kind@rank:round[:arg], \
                 kinds: delay/drop/trunc/kill)"
            ))
        };
        let mut rules = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, at) = entry
                .split_once('@')
                .ok_or_else(|| bad(entry, "missing `@`"))?;
            let mut parts = at.split(':');
            let rank: usize = parts
                .next()
                .and_then(|p| p.parse().ok())
                .ok_or_else(|| bad(entry, "rank is not a number"))?;
            let round: u64 = parts
                .next()
                .and_then(|p| p.parse().ok())
                .ok_or_else(|| bad(entry, "round is not a number"))?;
            let arg: Option<u64> = match parts.next() {
                None => None,
                Some(a) => Some(a.parse().map_err(|_| bad(entry, "arg is not a number"))?),
            };
            if parts.next().is_some() {
                return Err(bad(entry, "too many `:` fields"));
            }
            let fault = match kind {
                "delay" => Fault::Delay { ms: arg.unwrap_or(10) },
                "drop" => Fault::Drop,
                "trunc" => Fault::Truncate { keep: arg.unwrap_or(0) as usize },
                "kill" => Fault::Kill,
                other => return Err(bad(entry, &format!("unknown kind `{other}`"))),
            };
            rules.push(FaultRule { rank, round, fault });
        }
        Ok(FaultPlan { rules })
    }

    /// A seeded per-rank straggler schedule: each `(rank, round)` cell of a
    /// `k × rounds` grid independently delays with probability `rate`,
    /// drawn from `splitmix64(seed, rank, round)` — the same seed always
    /// yields the same schedule on every process of the group.
    pub fn seeded_delays(seed: u64, k: usize, rounds: u64, rate: f64, delay_ms: u64) -> Self {
        let mut rules = Vec::new();
        for rank in 0..k {
            for round in 0..rounds {
                let mut s = seed ^ ((rank as u64) << 40) ^ round;
                let draw = (splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64;
                if draw < rate {
                    rules.push(FaultRule { rank, round, fault: Fault::Delay { ms: delay_ms } });
                }
            }
        }
        FaultPlan { rules }
    }

    /// The fault scheduled for `(rank, round)`, if any (first match wins).
    pub fn fault_for(&self, rank: usize, round: u64) -> Option<Fault> {
        self.rules
            .iter()
            .find(|r| r.rank == rank && r.round == round)
            .map(|r| r.fault)
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }
}

/// A [`Transport`] decorator that executes a [`FaultPlan`]. Wraps either
/// fabric; see the module docs for the sender-side lockstep guarantee.
pub struct FaultyTransport {
    inner: Arc<dyn Transport>,
    plan: FaultPlan,
    /// Data-plane rounds completed, per exchanging rank. Indexed by the
    /// `rank` argument of [`Transport::exchange`], so one shared decorator
    /// over the in-process barrier counts each thread independently, and a
    /// per-process decorator over a socket endpoint counts its own rank.
    rounds: Vec<AtomicU64>,
}

impl FaultyTransport {
    pub fn wrap(inner: Arc<dyn Transport>, plan: FaultPlan) -> Arc<Self> {
        let k = inner.peers();
        Arc::new(FaultyTransport {
            inner,
            plan,
            rounds: (0..k).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    /// Data-plane rounds rank `rank` has entered so far.
    pub fn rounds_entered(&self, rank: usize) -> u64 {
        self.rounds[rank].load(Ordering::SeqCst)
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl Transport for FaultyTransport {
    fn peers(&self) -> usize {
        self.inner.peers()
    }

    fn exchange(&self, rank: usize, mut payload: Vec<u8>, plane: Plane) -> Result<Vec<Arc<Vec<u8>>>> {
        if plane == Plane::Data {
            let round = self.rounds[rank].fetch_add(1, Ordering::SeqCst);
            match self.plan.fault_for(rank, round) {
                None => {}
                Some(Fault::Delay { ms }) => {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                Some(Fault::Drop) => {
                    payload.clear();
                }
                Some(Fault::Truncate { keep }) => {
                    payload.truncate(keep);
                }
                Some(Fault::Kill) => {
                    let reason = format!(
                        "injected fault: worker {rank} killed at data round {round}"
                    );
                    self.inner.poison(&reason);
                    return Err(Error::Net(format!("transport poisoned: {reason}")));
                }
            }
        }
        self.inner.exchange(rank, payload, plane)
    }

    fn poison(&self, reason: &str) {
        self.inner.poison(reason)
    }

    fn is_poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }

    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn measured(&self) -> Option<MeasuredWire> {
        self.inner.measured()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::AllGather;
    use std::thread;

    #[test]
    fn parse_covers_every_kind_and_rejects_garbage() {
        let plan = FaultPlan::parse("kill@2:5, delay@0:3:40 ,drop@1:2,trunc@1:4:7").unwrap();
        assert_eq!(plan.rules().len(), 4);
        assert_eq!(plan.fault_for(2, 5), Some(Fault::Kill));
        assert_eq!(plan.fault_for(0, 3), Some(Fault::Delay { ms: 40 }));
        assert_eq!(plan.fault_for(1, 2), Some(Fault::Drop));
        assert_eq!(plan.fault_for(1, 4), Some(Fault::Truncate { keep: 7 }));
        assert_eq!(plan.fault_for(0, 0), None);
        // defaults
        let plan = FaultPlan::parse("delay@0:1,trunc@0:2").unwrap();
        assert_eq!(plan.fault_for(0, 1), Some(Fault::Delay { ms: 10 }));
        assert_eq!(plan.fault_for(0, 2), Some(Fault::Truncate { keep: 0 }));
        // empty spec is a valid no-op plan
        assert!(FaultPlan::parse("").unwrap().is_empty());
        for bad in ["kill", "kill@x:1", "kill@1:y", "warp@1:2", "delay@1:2:z", "kill@1:2:3:4"] {
            let err = FaultPlan::parse(bad).expect_err(bad);
            assert!(err.to_string().contains("bad fault spec"), "{bad}: {err}");
        }
    }

    #[test]
    fn seeded_schedule_is_deterministic_and_rate_scaled() {
        let a = FaultPlan::seeded_delays(42, 4, 100, 0.25, 5);
        let b = FaultPlan::seeded_delays(42, 4, 100, 0.25, 5);
        assert_eq!(a, b, "same seed, same schedule");
        let c = FaultPlan::seeded_delays(43, 4, 100, 0.25, 5);
        assert_ne!(a, c, "different seed, different schedule");
        // ~25% of 400 cells; loose bounds to stay robust to the generator.
        let n = a.rules().len();
        assert!((40..=180).contains(&n), "rate 0.25 over 400 cells gave {n}");
        assert!(FaultPlan::seeded_delays(7, 4, 100, 0.0, 5).is_empty());
    }

    #[test]
    fn mangled_payload_reaches_every_rank_in_lockstep() {
        let k = 3;
        let plan = FaultPlan::parse("drop@1:1,trunc@2:2:1").unwrap();
        let ft = FaultyTransport::wrap(AllGather::new(k), plan);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ft = ft.clone();
                thread::spawn(move || {
                    let mut seen = Vec::new();
                    for _round in 0..3 {
                        let got = ft.exchange(rank, vec![rank as u8; 4], Plane::Data).unwrap();
                        seen.push(got.iter().map(|p| p.len()).collect::<Vec<_>>());
                    }
                    seen
                })
            })
            .collect();
        let views: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for v in &views {
            assert_eq!(v, &views[0], "every rank sees identical bytes per round");
        }
        assert_eq!(views[0][0], vec![4, 4, 4], "round 0 untouched");
        assert_eq!(views[0][1], vec![4, 0, 4], "round 1: rank 1 dropped");
        assert_eq!(views[0][2], vec![4, 4, 1], "round 2: rank 2 truncated to 1");
    }

    #[test]
    fn kill_poisons_the_group_instead_of_hanging() {
        let k = 3;
        let ft = FaultyTransport::wrap(AllGather::new(k), FaultPlan::parse("kill@2:1").unwrap());
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ft = ft.clone();
                thread::spawn(move || {
                    let mut errs = Vec::new();
                    for _round in 0..2 {
                        if let Err(e) = ft.exchange(rank, vec![rank as u8], Plane::Data) {
                            errs.push(e.to_string());
                            break;
                        }
                    }
                    errs
                })
            })
            .collect();
        for h in handles {
            let errs = h.join().unwrap();
            assert_eq!(errs.len(), 1, "every rank errors in round 1");
            assert!(errs[0].contains("poisoned"), "got: {}", errs[0]);
            assert!(errs[0].contains("killed at data round 1"), "got: {}", errs[0]);
        }
        assert!(ft.is_poisoned());
    }

    #[test]
    fn control_and_oob_rounds_pass_through_unscathed() {
        // The plan targets data round 0; the same payload on the control
        // and OOB planes is untouched and does not advance the round count.
        let ft = FaultyTransport::wrap(AllGather::new(1), FaultPlan::parse("drop@0:0").unwrap());
        let got = ft.exchange(0, vec![9; 8], Plane::Control).unwrap();
        assert_eq!(got[0].len(), 8);
        let got = ft.exchange(0, vec![9; 8], Plane::Oob).unwrap();
        assert_eq!(got[0].len(), 8);
        assert_eq!(ft.rounds_entered(0), 0);
        let got = ft.exchange(0, vec![9; 8], Plane::Data).unwrap();
        assert_eq!(got[0].len(), 0, "data round 0 dropped");
        assert_eq!(ft.rounds_entered(0), 1);
    }

    #[test]
    fn delay_is_trajectory_neutral() {
        let ft = FaultyTransport::wrap(AllGather::new(1), FaultPlan::parse("delay@0:0:1").unwrap());
        let got = ft.exchange(0, vec![1, 2, 3], Plane::Data).unwrap();
        assert_eq!(got[0].as_slice(), &[1, 2, 3]);
    }
}
