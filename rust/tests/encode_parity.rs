//! Encode-parity pins: the word-at-a-time `CODE ∘ Q` encoder (multi-bit
//! Huffman/Elias emission, fused sign bits, buffer reuse) must produce
//! **byte-identical wire output and identical exact bit counts** to the
//! seed's per-bit encoder, across all four codecs × bucket sizes × ragged
//! dims × alphabet sizes.
//!
//! The reference below is a *frozen verbatim copy* of the pre-PR-5 encode
//! path (`encode_vector` + per-bit `HuffmanCode::encode` + per-bit Elias
//! emission + the canonical code derivation), deliberately independent of
//! the library's current internals: it rebuilds canonical codewords from
//! the shipped length vector itself. If the hot path ever drifts by one
//! bit, these tests name the codec and configuration that moved.

use qgenx::coding::{BitWriter, HuffmanCode, SymbolCodec};
use qgenx::quant::{
    decode_vector, encode_vector, encode_vector_into, quantize_with_uniforms, Levels,
    QuantizedVector, WireCodec,
};
use qgenx::util::Rng;

// ---------------------------------------------------------------------
// Frozen reference (pre-PR-5 bit emission) — do not "modernize".
// ---------------------------------------------------------------------

fn ref_ilog2(n: u64) -> u32 {
    63 - n.leading_zeros()
}

/// Frozen per-bit Elias γ emission (seed `elias::gamma_encode`).
fn ref_gamma_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    let nb = ref_ilog2(n);
    w.write_bits(0, nb.min(57));
    if nb > 57 {
        w.write_bits(0, nb - 57);
    }
    w.write_bit(true);
    for i in (0..nb).rev() {
        w.write_bit((n >> i) & 1 == 1);
    }
}

/// Frozen per-bit Elias δ emission (seed `elias::delta_encode`).
fn ref_delta_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    let nb = ref_ilog2(n);
    ref_gamma_encode(w, nb as u64 + 1);
    for i in (0..nb).rev() {
        w.write_bit((n >> i) & 1 == 1);
    }
}

/// Frozen canonical-code derivation from a length vector (seed
/// `HuffmanCode::from_lengths` code-assignment loop), kept independent of
/// the library so the parity holds even if the library's tables change.
struct RefHuffman {
    lengths: Vec<u32>,
    codes: Vec<u64>,
}

impl RefHuffman {
    fn from_lengths(lengths: &[u32]) -> Self {
        let max_len = lengths.iter().copied().max().unwrap() as usize;
        let mut count = vec![0u64; max_len + 1];
        for &l in lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut next = vec![0u64; max_len + 1];
        let mut c = 0u64;
        for l in 1..=max_len {
            c = (c + if l > 1 { count[l - 1] } else { 0 }) << 1;
            next[l] = c;
        }
        let mut symbols: Vec<u32> =
            (0..lengths.len() as u32).filter(|&i| lengths[i as usize] > 0).collect();
        symbols.sort_by_key(|&s| (lengths[s as usize], s));
        let mut codes = vec![0u64; lengths.len()];
        for &s in &symbols {
            let l = lengths[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }
        RefHuffman { lengths: lengths.to_vec(), codes }
    }

    /// Frozen per-bit MSB-first emission (seed `HuffmanCode::encode`).
    fn encode(&self, w: &mut BitWriter, symbol: usize) {
        let l = self.lengths[symbol];
        assert!(l > 0, "symbol {symbol} has no code");
        let code = self.codes[symbol];
        for i in (0..l).rev() {
            w.write_bit((code >> i) & 1 == 1);
        }
    }
}

enum RefCodec {
    Fixed(u32),
    Gamma,
    Delta,
    Huffman(RefHuffman),
}

/// Frozen copy of the seed `encode_vector` loop: per-bucket norm, per
/// coordinate the symbol then — separately — one sign bit iff nonzero.
fn ref_encode_vector(qv: &QuantizedVector, codec: &RefCodec) -> (Vec<u8>, u64) {
    let mut w = BitWriter::with_capacity(4 * qv.norms.len() + qv.d);
    let b = qv.bucket_size;
    for (bi, &norm) in qv.norms.iter().enumerate() {
        w.write_f32(norm);
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(qv.d);
        if norm == 0.0 {
            continue;
        }
        for i in lo..hi {
            let sym = qv.symbols[i];
            match codec {
                RefCodec::Fixed(width) => w.write_bits(sym as u64, *width),
                RefCodec::Gamma => ref_gamma_encode(&mut w, sym as u64 + 1),
                RefCodec::Delta => ref_delta_encode(&mut w, sym as u64 + 1),
                RefCodec::Huffman(h) => h.encode(&mut w, sym as usize),
            }
            if sym != 0 {
                w.write_bit(qv.sign_is_neg(i));
            }
        }
    }
    let bits = w.bit_len();
    (w.finish(), bits)
}

// ---------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------

/// Fixed width exactly as `WireCodec::new` derives it.
fn fixed_width(alphabet: usize) -> u32 {
    (usize::BITS - (alphabet - 1).leading_zeros()).max(1)
}

/// Geometric symbol probabilities (the Huffman bootstrap prior shape) —
/// skewed enough to give ragged code lengths.
fn geometric_probs(alphabet: usize) -> Vec<f64> {
    (0..alphabet).map(|j| 0.5f64.powi(j.min(60) as i32)).collect()
}

fn check_parity(qv: &QuantizedVector, kind: SymbolCodec, levels: &Levels, probs: Option<&[f64]>) {
    let codec = WireCodec::new(kind, levels, probs).unwrap();
    let reference = match kind {
        SymbolCodec::Fixed => RefCodec::Fixed(fixed_width(levels.alphabet_size())),
        SymbolCodec::EliasGamma => RefCodec::Gamma,
        SymbolCodec::EliasDelta => RefCodec::Delta,
        SymbolCodec::Huffman => {
            // Same floor + build as WireCodec::new, then take the *length
            // vector* (the side information peers receive) and derive the
            // canonical codewords with the frozen algorithm above.
            let floored: Vec<f64> = probs.unwrap().iter().map(|&p| p.max(1e-9)).collect();
            let code = HuffmanCode::from_weights(&floored).unwrap();
            RefCodec::Huffman(RefHuffman::from_lengths(code.lengths()))
        }
    };
    let (ref_bytes, ref_bits) = ref_encode_vector(qv, &reference);
    let (new_bytes, new_bits) = encode_vector(qv, &codec).unwrap();
    assert_eq!(
        ref_bytes, new_bytes,
        "wire bytes drifted: codec {kind:?}, d {}, bucket {}",
        qv.d, qv.bucket_size
    );
    assert_eq!(ref_bits, new_bits, "bit count drifted: codec {kind:?}");
    // The buffer-reuse entry point is the same encoder.
    let mut buf = Vec::new();
    let into_bits = encode_vector_into(qv, &codec, &mut buf).unwrap();
    assert_eq!(buf, new_bytes);
    assert_eq!(into_bits, new_bits);
    // And the (LUT) decoder inverts the reference bytes exactly.
    let back = decode_vector(&ref_bytes, qv.d, qv.bucket_size, &codec).unwrap();
    assert_eq!(&back, qv, "decode must invert the frozen wire: codec {kind:?}");
}

#[test]
fn parity_across_codecs_buckets_dims_alphabets() {
    let mut rng = Rng::seed_from(0xC0DE);
    for s in [2usize, 14, 254] {
        let levels = Levels::uniform(s);
        let probs = geometric_probs(levels.alphabet_size());
        for d in [1usize, 5, 63, 64, 65, 257, 1000] {
            for bucket in [0usize, 3, 64, 333] {
                let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32 * 1.5).collect();
                let uniforms: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
                let qv = quantize_with_uniforms(&v, &levels, 2, bucket, &uniforms).unwrap();
                for kind in [
                    SymbolCodec::Fixed,
                    SymbolCodec::EliasGamma,
                    SymbolCodec::EliasDelta,
                    SymbolCodec::Huffman,
                ] {
                    let p = (kind == SymbolCodec::Huffman).then_some(probs.as_slice());
                    check_parity(&qv, kind, &levels, p);
                }
            }
        }
    }
}

#[test]
fn parity_with_empty_and_mixed_buckets() {
    // Zero buckets emit only their norm; the parity must hold through the
    // skip logic too.
    let levels = Levels::uniform(14);
    let probs = geometric_probs(levels.alphabet_size());
    let mut v = vec![0.0f32; 64]; // first bucket all-zero
    let mut rng = Rng::seed_from(7);
    v.extend((0..130).map(|_| rng.gaussian() as f32));
    let uniforms: Vec<f32> = (0..v.len()).map(|_| rng.uniform_f32()).collect();
    let qv = quantize_with_uniforms(&v, &levels, 2, 64, &uniforms).unwrap();
    assert_eq!(qv.norms[0], 0.0, "setup: first bucket must be empty");
    for kind in [
        SymbolCodec::Fixed,
        SymbolCodec::EliasGamma,
        SymbolCodec::EliasDelta,
        SymbolCodec::Huffman,
    ] {
        let p = (kind == SymbolCodec::Huffman).then_some(probs.as_slice());
        check_parity(&qv, kind, &levels, p);
    }
}

#[test]
fn parity_under_adaptive_probability_models() {
    // Huffman tables from *estimated* (non-geometric) probabilities, the
    // steady-state shape after a stat exchange: still bit-identical.
    use qgenx::quant::{symbol_probs, SufficientStats};
    let levels = Levels::uniform(14);
    let mut stats = SufficientStats::new(128, 2);
    let mut rng = Rng::seed_from(0xADA);
    for _ in 0..6 {
        let g: Vec<f32> = (0..512).map(|_| rng.gaussian() as f32).collect();
        stats.observe(&g);
    }
    let probs = symbol_probs(&stats, &levels);
    let v: Vec<f32> = (0..777).map(|_| rng.gaussian() as f32).collect();
    let uniforms: Vec<f32> = (0..777).map(|_| rng.uniform_f32()).collect();
    for bucket in [0usize, 128] {
        let qv = quantize_with_uniforms(&v, &levels, 2, bucket, &uniforms).unwrap();
        check_parity(&qv, SymbolCodec::Huffman, &levels, Some(&probs));
    }
}
