//! Network substrate: the [`Transport`] seam, its two fabrics, framing,
//! and the α-β cost model.
//!
//! Wire *contents* are always exact — every message goes through the real
//! `CODE ∘ Q` encoder and the transport counts its exact length. Wire
//! *time* is modeled (the α-β `latency + bytes / bandwidth` model
//! calibrated to the paper's 1 GbE / 3-node setup), but wire *movement*
//! now has two real options:
//!
//! * [`transport`] — the [`Transport`] trait plus [`AllGather`], the
//!   in-process barrier fabric for the threaded coordinator.
//! * [`socket`] — [`SocketTransport`]: separate worker processes over
//!   TCP or Unix-domain sockets, rank-0 rendezvous, full-mesh framed
//!   connections, measured per-link bytes ([`MeasuredWire`]).
//! * [`frame`] — the versioned length-framed message envelope the socket
//!   fabric speaks (docs/WIRE.md).
//! * [`faulty`] — [`FaultyTransport`]: a deterministic fault-injection
//!   decorator over either fabric (delays, drops, truncation, worker
//!   death), scripted by a [`FaultPlan`] (docs/SCENARIOS.md).
//! * [`NetModel`] — α-β timing for point-to-point and all-to-all rounds.
//! * [`TrafficStats`] — exact bits/messages/simulated-seconds accounting.

pub mod faulty;
pub mod frame;
pub mod socket;
pub mod transport;

pub use faulty::{Fault, FaultPlan, FaultRule, FaultyTransport};
pub use socket::{connect_group, SocketHub, SocketOpts, SocketTransport};
pub use transport::{AllGather, MeasuredWire, Plane, PoisonGuard, Transport};

/// Serialize a slice of `f32` into little-endian wire bytes, appending to
/// `out`. The shared primitive behind the fp32 compressor payloads and the
/// out-of-band diagnostic exchange — one encoding, every fabric.
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Decode little-endian `f32` wire bytes into `out`, requiring an exact
/// length match (`bytes.len() == 4 * out.len()`).
pub fn get_f32s_into(bytes: &[u8], out: &mut [f32]) -> crate::error::Result<()> {
    if bytes.len() != 4 * out.len() {
        return Err(crate::error::Error::Codec(format!(
            "fp32 payload {} bytes for d = {}",
            bytes.len(),
            out.len()
        )));
    }
    for (chunk, slot) in bytes.chunks_exact(4).zip(out.iter_mut()) {
        *slot = f32::from_le_bytes(chunk.try_into().expect("4-byte chunk"));
    }
    Ok(())
}

/// Exact payload bits → wire bytes (the wire rounds every payload up to a
/// whole byte). The one place this conversion lives; callers throughout
/// `train`, `topo` and the coordinators use it instead of hand-rolling
/// `div_ceil(8)`.
#[inline]
pub const fn bits_to_bytes(bits: u64) -> usize {
    (bits as usize).div_ceil(8)
}

/// α-β network cost model.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Usable link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
}

impl NetModel {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0 && latency_s >= 0.0);
        NetModel { bandwidth_bps, latency_s }
    }

    /// 1 GbE with protocol overhead (~117 MiB/s usable), 50 µs latency —
    /// the paper's Ethernet cluster.
    pub fn gbe() -> Self {
        NetModel::new(117.0 * 1024.0 * 1024.0, 50e-6)
    }

    /// 10 GbE datacenter link.
    pub fn ten_gbe() -> Self {
        NetModel::new(1170.0 * 1024.0 * 1024.0, 20e-6)
    }

    /// From the launcher config.
    pub fn from_config(cfg: &crate::config::NetConfig) -> Self {
        NetModel::new(cfg.bandwidth_bps, cfg.latency_s)
    }

    /// Point-to-point transfer time for `bytes`.
    #[inline]
    pub fn p2p_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// One synchronous all-to-all broadcast round among `k` peers where
    /// peer `i` contributes `bytes[i]`: every node serializes its sends
    /// over its own NIC (K−1 copies) while receiving in parallel, so the
    /// round completes when the slowest sender finishes:
    /// `max_i (α + (k−1)·bytes[i]/β)`.
    pub fn allgather_time(&self, bytes: &[usize]) -> f64 {
        let k = bytes.len();
        if k <= 1 {
            return 0.0;
        }
        bytes
            .iter()
            .map(|&b| self.latency_s + ((k - 1) * b) as f64 / self.bandwidth_bps)
            .fold(0.0, f64::max)
    }

}

/// Exact traffic accounting for one run.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficStats {
    /// Total payload bits put on the wire (all senders).
    pub bits_sent: u64,
    /// Number of point-to-point messages.
    pub messages: u64,
    /// Accumulated simulated network time (seconds).
    pub sim_net_time: f64,
    /// Accumulated measured compute time (seconds) — encode/decode/oracle.
    pub compute_time: f64,
    /// Synchronous rounds completed.
    pub rounds: u64,
}

impl TrafficStats {
    /// Record one allgather round: each of the `k` peers broadcast its
    /// payload to `k − 1` others (full-mesh; topology-aware rounds go
    /// through [`crate::topo::Collective`], which calls
    /// [`Self::record_modeled`] with its own α-β cost).
    pub fn record_allgather(&mut self, bits_each: &[u64], model: &NetModel) {
        let k = bits_each.len();
        if k == 0 {
            return;
        }
        let bytes: Vec<usize> = bits_each.iter().map(|&b| bits_to_bytes(b)).collect();
        let wire_bits: u64 =
            bits_each.iter().map(|&b| b * (k.saturating_sub(1)) as u64).sum();
        self.record_modeled(
            wire_bits,
            (k * k.saturating_sub(1)) as u64,
            model.allgather_time(&bytes),
        );
    }

    /// Record one synchronous round whose wire bits / message count /
    /// simulated time were computed by an external cost model (the topology
    /// layer). Bumps `rounds` by one.
    pub fn record_modeled(&mut self, wire_bits: u64, messages: u64, secs: f64) {
        self.bits_sent += wire_bits;
        self.messages += messages;
        self.sim_net_time += secs;
        self.rounds += 1;
    }

    pub fn add_compute(&mut self, secs: f64) {
        self.compute_time += secs;
    }

    /// Total modeled wall-clock: compute + network.
    pub fn total_time(&self) -> f64 {
        self.sim_net_time + self.compute_time
    }

    /// Average bits per round per worker (the quantity Theorems 3/4 bound).
    pub fn bits_per_round_per_worker(&self, k: usize) -> f64 {
        if self.rounds == 0 || k == 0 {
            return 0.0;
        }
        self.bits_sent as f64 / self.rounds as f64 / k as f64 / (k.saturating_sub(1)).max(1) as f64
    }

    pub fn merge(&mut self, other: &TrafficStats) {
        self.bits_sent += other.bits_sent;
        self.messages += other.messages;
        self.sim_net_time += other.sim_net_time;
        self.compute_time += other.compute_time;
        self.rounds += other.rounds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_time_formula() {
        let m = NetModel::new(1e6, 1e-3);
        assert!((m.p2p_time(1000) - (1e-3 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn allgather_scales_with_k_and_max_payload() {
        let m = NetModel::new(1e6, 0.0);
        let t2 = m.allgather_time(&[1000, 1000]);
        let t4 = m.allgather_time(&[1000; 4]);
        assert!((t4 / t2 - 3.0).abs() < 1e-9, "t4/t2 = {}", t4 / t2);
        // dominated by slowest sender
        let t_uneven = m.allgather_time(&[10, 4000]);
        assert!((t_uneven - 4000.0 / 1e6).abs() < 1e-9);
        assert_eq!(m.allgather_time(&[1234]), 0.0);
    }

    #[test]
    fn fp32_vs_uq4_shows_comm_saving() {
        // d = 4M coords, K = 3, 1GbE: fp32 round vs ~4.5-bit round.
        let m = NetModel::gbe();
        let d = 4_000_000usize;
        let fp32 = m.allgather_time(&[4 * d; 3]);
        let uq4 = m.allgather_time(&[(45 * d) / 80; 3]); // ~4.5 bits/coord
        assert!(uq4 < fp32 / 5.0, "uq4 {uq4} vs fp32 {fp32}");
    }

    #[test]
    fn traffic_stats_accounting() {
        let m = NetModel::new(1e6, 0.0);
        let mut s = TrafficStats::default();
        s.record_allgather(&[800, 800, 800], &m); // 100 bytes each
        assert_eq!(s.rounds, 1);
        assert_eq!(s.messages, 6);
        assert_eq!(s.bits_sent, 800 * 2 * 3);
        assert!((s.sim_net_time - 2.0 * 100.0 / 1e6).abs() < 1e-12);
        assert!((s.bits_per_round_per_worker(3) - 800.0).abs() < 1e-9);
        s.add_compute(0.5);
        assert!((s.total_time() - (0.5 + s.sim_net_time)).abs() < 1e-12);
    }

    #[test]
    fn bits_to_bytes_rounds_up() {
        assert_eq!(bits_to_bytes(0), 0);
        assert_eq!(bits_to_bytes(1), 1);
        assert_eq!(bits_to_bytes(8), 1);
        assert_eq!(bits_to_bytes(9), 2);
        assert_eq!(bits_to_bytes(800), 100);
    }

    #[test]
    fn record_modeled_accumulates_raw_counts() {
        let mut s = TrafficStats::default();
        s.record_modeled(1000, 12, 0.25);
        s.record_modeled(500, 6, 0.25);
        assert_eq!(s.bits_sent, 1500);
        assert_eq!(s.messages, 18);
        assert_eq!(s.rounds, 2);
        assert!((s.sim_net_time - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f32_wire_helpers_roundtrip_and_validate() {
        let xs = [1.5f32, -0.25, f32::MIN_POSITIVE, 3.4e38];
        let mut wire = Vec::new();
        put_f32s(&mut wire, &xs);
        assert_eq!(wire.len(), 16);
        let mut back = [0f32; 4];
        get_f32s_into(&wire, &mut back).unwrap();
        assert_eq!(back, xs);
        // Length mismatches are codec errors, not panics.
        let mut short = [0f32; 3];
        let err = get_f32s_into(&wire, &mut short).expect_err("length mismatch");
        assert!(err.to_string().contains("fp32 payload"), "got: {err}");
        // Empty roundtrip.
        let mut empty = Vec::new();
        put_f32s(&mut empty, &[]);
        get_f32s_into(&empty, &mut []).unwrap();
    }

    #[test]
    fn merge_accumulates() {
        let mut a = TrafficStats::default();
        let mut b = TrafficStats::default();
        let m = NetModel::gbe();
        a.record_allgather(&[100, 100], &m);
        b.record_allgather(&[100, 100], &m);
        a.merge(&b);
        assert_eq!(a.rounds, 2);
    }
}
