//! Foundation utilities: PRNG, vector math, running statistics.
//!
//! The build image is offline and the `rand` crate is unavailable, so
//! [`rng`] implements xoshiro256++ (Blackman & Vigna) with SplitMix64
//! seeding and Box-Muller Gaussian sampling. [`linalg`] provides the small
//! set of dense vector kernels the coordinator hot path needs, and
//! [`stats`] the running/empirical statistics used by QAda and the bench
//! harness.

pub mod linalg;
pub mod rng;
pub mod stats;

pub use linalg::*;
pub use rng::Rng;
pub use stats::{ecdf::WeightedEcdf, Histogram, RunningStats};
