//! Run telemetry: stage spans, counters, and per-link traffic streams
//! over the one [`crate::coordinator`] round seam.
//!
//! The paper's claims are rates and budgets — O(1/T) vs O(1/√T), Theorem-2
//! code lengths, wall-clock speedup from distribution — so the question a
//! run has to answer is "where did the bits and the microseconds go, per
//! round, per link, per stage". This module is the substrate: a cheap,
//! always-compiled recorder owned by the `RoundEngine`, so every session
//! family (exact / gossip / local / sgda, inline and threaded) emits the
//! same structured events with zero hand-copied instrumentation.
//!
//! ## Taxonomy
//!
//! * **Stage spans** ([`Stage`], [`StageSpans`]) — per-step seconds in
//!   `sample` (oracle draws), `quantize` (Q_ℓ), `encode` (CODE),
//!   `exchange` (the *modeled* α-β round time — network time is simulated,
//!   see [`crate::net`]), `decode` (DEQ ∘ CODE), `apply` (iterate math in
//!   the policies), and `stat` (control-plane stat rounds, measured).
//!   All spans except `exchange` are wall-clock measurements and therefore
//!   — like `compute_time` — exempt from the bit-for-bit reproducibility
//!   contract. Everything else in this module is deterministic.
//! * **Counters** ([`Counters`]) — wire bits split data-plane vs
//!   control-plane, data/stat round counts, adaptive level updates, codec
//!   (Huffman) refreshes, and allocation events (the PR 5
//!   [`crate::benchkit::CountingAlloc`] counter; reads 0 unless the binary
//!   installed it).
//! * **Per-link streams** — [`crate::topo::LinkTraffic`] keeps per-round
//!   deltas next to its cumulative totals; the recorder snapshots the
//!   hottest link per step so hot-spotting is visible per topology.
//!
//! ## Sinks
//!
//! * The **ring recorder** (default): a fixed-capacity ring of `Copy`
//!   [`StepRecord`]s, preallocated at session build — recording a
//!   steady-state loopback round performs **zero heap allocations**
//!   (asserted by `tests/telemetry.rs` under the counting allocator).
//! * The **JSONL sink** ([`sink::JsonlSink`]): one event object per line
//!   (`manifest`, then `step`*, then `summary`), built on
//!   [`crate::runtime::json::Json`] so the output is deterministic,
//!   sorted-key, and re-parsable by the same crate. Schema:
//!   `docs/OBSERVABILITY.md`, version [`TELEMETRY_SCHEMA`].
//! * The [`TelemetryObserver`] bridge: streams per-step summaries through
//!   the existing [`crate::coordinator::Observer`] trait.
//!
//! ## Surface
//!
//! `Session::builder(..).telemetry(TelemetryConfig::jsonl(path))`, the
//! `qgenx run --telemetry <path>` flag, or the `QGENX_TELEMETRY`
//! environment variable (`1`/`mem` = ring only, anything else = JSONL
//! path). The env knob is read in `SessionBuilder::build`, which is why
//! every example and every session-driven bench picks it up for free.
//! Threaded runs attach the JSONL sink on rank 0 only (one file, one
//! writer); every rank still keeps its in-memory ring.
//!
//! Neutrality contract: telemetry on vs off changes **no** trajectory,
//! wire byte, or deterministic metric — it only reads what the engine
//! already computed (`tests/telemetry.rs` pins this for inline and
//! threaded coordinators).

pub mod sink;

use crate::error::Result;
use crate::runtime::json::Json;
use crate::topo::collective::Link;
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use sink::JsonlSink;

/// JSONL event-schema version (bump on breaking event/field changes; see
/// `docs/OBSERVABILITY.md`).
pub const TELEMETRY_SCHEMA: u32 = 1;

/// Pipeline stages a round spends time in (span taxonomy — module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Oracle draws (`V̂(X)` sampling).
    Sample,
    /// `Q_ℓ` — quantization into the symbol arena.
    Quantize,
    /// `CODE` — entropy-coding symbols onto the wire.
    Encode,
    /// The synchronous round itself — *modeled* α-β seconds, not measured.
    Exchange,
    /// `DEQ ∘ CODE` — decoding received payloads.
    Decode,
    /// Iterate math in the policy (extrapolate / update / local segments).
    Apply,
    /// Control-plane stat rounds (pool + re-optimize + codec rebuild).
    Stat,
}

/// Number of [`Stage`] variants (array-accumulator width).
pub const N_STAGES: usize = 7;

/// All stages, in canonical report order.
pub const STAGES: [Stage; N_STAGES] = [
    Stage::Sample,
    Stage::Quantize,
    Stage::Encode,
    Stage::Exchange,
    Stage::Decode,
    Stage::Apply,
    Stage::Stat,
];

impl Stage {
    /// Stable lowercase name (JSONL field key).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Sample => "sample",
            Stage::Quantize => "quantize",
            Stage::Encode => "encode",
            Stage::Exchange => "exchange",
            Stage::Decode => "decode",
            Stage::Apply => "apply",
            Stage::Stat => "stat",
        }
    }

    #[inline]
    fn idx(self) -> usize {
        match self {
            Stage::Sample => 0,
            Stage::Quantize => 1,
            Stage::Encode => 2,
            Stage::Exchange => 3,
            Stage::Decode => 4,
            Stage::Apply => 5,
            Stage::Stat => 6,
        }
    }
}

/// Fixed-width per-stage seconds accumulator (`Copy`, allocation-free).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageSpans {
    secs: [f64; N_STAGES],
}

impl StageSpans {
    #[inline]
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.idx()] += secs;
    }

    #[inline]
    pub fn get(&self, stage: Stage) -> f64 {
        self.secs[stage.idx()]
    }

    /// Sum over all stages.
    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn merge(&mut self, other: &StageSpans) {
        for i in 0..N_STAGES {
            self.secs[i] += other.secs[i];
        }
    }

    pub fn reset(&mut self) {
        self.secs = [0.0; N_STAGES];
    }

    /// `(stage, seconds)` in canonical order.
    pub fn iter(&self) -> impl Iterator<Item = (Stage, f64)> + '_ {
        STAGES.iter().map(move |&s| (s, self.secs[s.idx()]))
    }

    fn to_json(self) -> Json {
        Json::obj(self.iter().map(|(s, v)| (s.name(), Json::Num(v))))
    }
}

/// Run-total event counters (all deterministic except `allocs`, which is
/// measured — and exactly 0 when no counting allocator is installed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// Steps closed by [`Telemetry::end_step`].
    pub steps: u64,
    /// Data-plane exchange rounds.
    pub data_rounds: u64,
    /// Control-plane stat rounds that actually fired.
    pub stat_rounds: u64,
    /// Wire bits moved by data rounds.
    pub data_bits: u64,
    /// Wire bits moved by stat rounds.
    pub stat_bits: u64,
    /// Stat rounds after which some endpoint's level placement changed.
    pub level_updates: u64,
    /// Stat rounds that rebuilt codecs (Huffman probability refreshes —
    /// counts even when the level placement held still).
    pub codec_refreshes: u64,
    /// Allocation events while telemetry was active.
    pub allocs: u64,
    /// Fault events observed (injected faults, topology rewires, stale
    /// sync substitutions). Exactly 0 on a fault-free static run.
    pub faults: u64,
}

/// One closed step of telemetry (`Copy` — ring storage is allocation-free).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepRecord {
    /// Session step index (1-based, like `StepReport::t`).
    pub t: u64,
    /// Seconds per stage within this step.
    pub spans: StageSpans,
    /// Data-plane wire bits this step.
    pub data_bits: u64,
    /// Control-plane wire bits this step.
    pub stat_bits: u64,
    /// Data rounds this step (2 per step for the exact family, 1 for
    /// sgda, 1 per sync for local).
    pub rounds: u32,
    /// Stat rounds that fired this step.
    pub stat_rounds: u32,
    /// Did a stat round change some endpoint's levels this step?
    pub level_update: bool,
    /// Did a stat round rebuild codecs this step?
    pub codec_refresh: bool,
    /// Allocation events this step (0 without a counting allocator).
    pub allocs: u64,
    /// Hottest directed link of this step's rounds.
    pub hot_link: Link,
    /// Bytes that link carried in its hottest round this step.
    pub hot_link_bytes: f64,
    /// Distinct links touched by the last round of this step.
    pub links: u32,
    /// Did an error-feedback compressor report this step? (Gates the
    /// `ef_*` JSONL fields so EF-off streams stay byte-identical.)
    pub ef: bool,
    /// ‖e_{t+1}‖₂ of rank 0's error memory after this step's compress.
    pub ef_err_norm: f64,
    /// Effective contraction `1 − ‖e‖²/‖a‖²` observed this step.
    pub ef_delta: f64,
}

/// Fixed-capacity ring of [`StepRecord`]s — the default in-memory sink.
/// Preallocated at construction; pushing overwrites the oldest record, so
/// steady-state recording never allocates.
#[derive(Clone, Debug, Default)]
pub struct Ring {
    buf: Vec<StepRecord>,
    cap: usize,
    /// Index of the next write.
    head: usize,
}

impl Ring {
    fn with_capacity(cap: usize) -> Self {
        Ring { buf: Vec::with_capacity(cap), cap, head: 0 }
    }

    fn push(&mut self, r: StepRecord) {
        if self.cap == 0 {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(r);
        } else {
            self.buf[self.head] = r;
        }
        self.head = (self.head + 1) % self.cap;
    }

    /// Records currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Most recently pushed record.
    pub fn latest(&self) -> Option<&StepRecord> {
        if self.buf.is_empty() {
            return None;
        }
        // `head` is the next write slot; the previous slot (mod the filled
        // length) is the newest record, whether or not we have wrapped.
        let i = if self.head == 0 { self.buf.len() - 1 } else { self.head - 1 };
        Some(&self.buf[i])
    }

    /// Records oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &StepRecord> + '_ {
        let n = self.buf.len();
        let start = if n < self.cap { 0 } else { self.head };
        (0..n).map(move |i| &self.buf[(start + i) % n.max(1)])
    }
}

/// How a session's telemetry is configured.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Ring capacity (step records kept in memory). 0 keeps counters and
    /// spans only.
    pub ring: usize,
    /// JSONL event-stream path (None = in-memory only).
    pub jsonl: Option<String>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { ring: 1024, jsonl: None }
    }
}

impl TelemetryConfig {
    /// In-memory ring + counters only.
    pub fn memory() -> Self {
        TelemetryConfig::default()
    }

    /// Ring + JSONL event stream at `path`.
    pub fn jsonl(path: impl Into<String>) -> Self {
        TelemetryConfig { jsonl: Some(path.into()), ..TelemetryConfig::default() }
    }

    /// Parse a `QGENX_TELEMETRY` value: `0`/empty = disabled, `1`/`mem`/
    /// `memory` = in-memory, anything else = JSONL path.
    pub fn parse(value: &str) -> Option<Self> {
        match value.trim() {
            "" | "0" => None,
            "1" | "mem" | "memory" => Some(TelemetryConfig::memory()),
            path => Some(TelemetryConfig::jsonl(path)),
        }
    }

    /// The `QGENX_TELEMETRY` environment knob (module docs).
    pub fn from_env() -> Option<Self> {
        std::env::var("QGENX_TELEMETRY").ok().and_then(|v| TelemetryConfig::parse(&v))
    }
}

/// The per-engine telemetry recorder (see module docs). Disabled is the
/// default and costs one branch per hook; enabled it accumulates spans /
/// counters / ring records without allocating, and optionally streams
/// JSONL events.
///
/// Cloning (checkpoints, engine clones) deep-copies the in-memory state
/// and *shares* the JSONL sink handle — a resumed session appends to the
/// same stream rather than truncating it.
#[derive(Clone, Default)]
pub struct Telemetry {
    enabled: bool,
    /// Spans of the step currently being accumulated.
    spans: StageSpans,
    /// Run-total spans (merged at each `end_step`).
    totals: StageSpans,
    counters: Counters,
    ring: Ring,
    sink: Option<Arc<Mutex<JsonlSink>>>,
    // --- per-step marks, reset by `end_step` ---
    step_data_bits: u64,
    step_stat_bits: u64,
    step_rounds: u32,
    step_stat_rounds: u32,
    step_level_update: bool,
    step_codec_refresh: bool,
    step_hot_link: Link,
    step_hot_bytes: f64,
    step_links: u32,
    step_ef: bool,
    step_ef_err_norm: f64,
    step_ef_delta: f64,
    alloc_mark: u64,
}

impl Telemetry {
    /// The disabled recorder (every hook is a cheap no-op).
    pub fn off() -> Self {
        Telemetry::default()
    }

    /// An enabled recorder. `manifest` is written as the JSONL stream's
    /// first event when a path is configured.
    pub fn new(cfg: &TelemetryConfig, manifest: &Json) -> Result<Self> {
        let sink = match &cfg.jsonl {
            Some(path) => {
                Some(Arc::new(Mutex::new(JsonlSink::create(path, manifest)?)))
            }
            None => None,
        };
        Ok(Telemetry {
            enabled: true,
            ring: Ring::with_capacity(cfg.ring),
            sink,
            alloc_mark: crate::benchkit::allocs(),
            ..Telemetry::default()
        })
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Start a measured span: `Some(now)` when enabled, `None` (free)
    /// otherwise. Close it with [`Self::lap`].
    #[inline]
    pub fn clock(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span opened by [`Self::clock`].
    #[inline]
    pub fn lap(&mut self, t0: Option<Instant>, stage: Stage) {
        if let Some(t0) = t0 {
            self.spans.add(stage, t0.elapsed().as_secs_f64());
        }
    }

    /// Add already-known seconds to a stage (the modeled `exchange` span).
    #[inline]
    pub fn span_secs(&mut self, stage: Stage, secs: f64) {
        if self.enabled {
            self.spans.add(stage, secs);
        }
    }

    /// Current-step span accumulator for callees that time sub-stages
    /// themselves (the compressor's quantize/encode split). `None` when
    /// disabled so the hot path can skip its `Instant` reads entirely.
    #[inline]
    pub fn spans_mut(&mut self) -> Option<&mut StageSpans> {
        if self.enabled {
            Some(&mut self.spans)
        } else {
            None
        }
    }

    /// Record one data-plane round: its wire bits, its modeled α-β
    /// seconds (accumulated into the `exchange` span), and the per-link
    /// loads of the round (per-round deltas from
    /// [`crate::topo::LinkTraffic::last_round`]).
    pub fn on_data_round(&mut self, wire_bits: u64, modeled_secs: f64, links: &[(Link, f64)]) {
        if !self.enabled {
            return;
        }
        self.counters.data_rounds += 1;
        self.counters.data_bits += wire_bits;
        self.step_data_bits += wire_bits;
        self.step_rounds += 1;
        self.spans.add(Stage::Exchange, modeled_secs);
        self.step_links = links.len() as u32;
        for &(link, bytes) in links {
            if bytes > self.step_hot_bytes {
                self.step_hot_bytes = bytes;
                self.step_hot_link = link;
            }
        }
    }

    /// Record one control-plane stat round. `refreshed` = some endpoint
    /// rebuilt its codec (an update actually ran); `changed` = some
    /// endpoint's level placement moved.
    pub fn on_stat_round(&mut self, wire_bits: u64, refreshed: bool, changed: bool) {
        if !self.enabled {
            return;
        }
        self.counters.stat_rounds += 1;
        self.counters.stat_bits += wire_bits;
        self.step_stat_bits += wire_bits;
        self.step_stat_rounds += 1;
        if refreshed {
            self.counters.codec_refreshes += 1;
            self.step_codec_refresh = true;
        }
        if changed {
            self.counters.level_updates += 1;
            self.step_level_update = true;
        }
    }

    /// Record the error-feedback diagnostics of this step's compress
    /// (rank 0's endpoint): error-memory norm and effective contraction.
    /// Called only by engines whose pipeline actually runs error feedback,
    /// so EF-off runs never set the marks and their step events carry no
    /// `ef_*` fields (schema stays 1: the fields are additive and gated).
    pub fn on_ef(&mut self, err_norm: f64, delta: f64) {
        if !self.enabled {
            return;
        }
        self.step_ef = true;
        self.step_ef_err_norm = err_norm;
        self.step_ef_delta = delta;
    }

    /// Record one fault event — an injected network fault taking effect,
    /// a time-varying-topology rewire, or a stale-sync substitution.
    /// Streams an additive `{"event":"fault",...}` record to the JSONL
    /// sink (schema stays 1: fault events are a new event kind, existing
    /// kinds are unchanged) and bumps the `faults` run counter. Fault-free
    /// runs emit none, so event streams stay bit-identical without faults.
    pub fn on_fault(&mut self, kind: &str, rank: usize, t: u64) {
        if !self.enabled {
            return;
        }
        self.counters.faults += 1;
        if let Some(sink) = &self.sink {
            if let Ok(mut s) = sink.lock() {
                s.write(&Json::obj([
                    ("event", Json::Str("fault".into())),
                    ("kind", Json::Str(kind.into())),
                    ("rank", Json::Num(rank as f64)),
                    ("t", Json::Num(t as f64)),
                ]));
            }
        }
    }

    /// Close step `t`: fold the per-step marks into a [`StepRecord`],
    /// merge spans into the run totals, push the record into the ring,
    /// stream it to the JSONL sink if one is attached, and reset the
    /// per-step state. Returns the record (None when disabled).
    pub fn end_step(&mut self, t: u64) -> Option<StepRecord> {
        if !self.enabled {
            return None;
        }
        let allocs_now = crate::benchkit::allocs();
        let rec = StepRecord {
            t,
            spans: self.spans,
            data_bits: self.step_data_bits,
            stat_bits: self.step_stat_bits,
            rounds: self.step_rounds,
            stat_rounds: self.step_stat_rounds,
            level_update: self.step_level_update,
            codec_refresh: self.step_codec_refresh,
            allocs: allocs_now - self.alloc_mark,
            hot_link: self.step_hot_link,
            hot_link_bytes: self.step_hot_bytes,
            links: self.step_links,
            ef: self.step_ef,
            ef_err_norm: self.step_ef_err_norm,
            ef_delta: self.step_ef_delta,
        };
        self.counters.steps += 1;
        self.counters.allocs += rec.allocs;
        self.totals.merge(&self.spans);
        self.ring.push(rec);
        if let Some(sink) = &self.sink {
            if let Ok(mut s) = sink.lock() {
                s.write(&step_event(&rec));
            }
        }
        self.spans.reset();
        self.step_data_bits = 0;
        self.step_stat_bits = 0;
        self.step_rounds = 0;
        self.step_stat_rounds = 0;
        self.step_level_update = false;
        self.step_codec_refresh = false;
        self.step_hot_link = (0, 0);
        self.step_hot_bytes = 0.0;
        self.step_links = 0;
        self.step_ef = false;
        self.step_ef_err_norm = 0.0;
        self.step_ef_delta = 0.0;
        self.alloc_mark = allocs_now;
        Some(rec)
    }

    /// Emit the run `summary` event and flush the sink. `layers` carries
    /// the per-layer cumulative wire bits of a layer-wise pipeline;
    /// `link_totals` the run's cumulative *modeled* per-link bytes;
    /// `measured` this endpoint's physical framed-byte counters when the
    /// fabric actually moves bytes over a wire (socket transport) — the
    /// pair is what lets an observer reconcile measured against modeled
    /// traffic per link (`docs/OBSERVABILITY.md`).
    pub fn finish(
        &mut self,
        layers: Option<(&[String], &[u64])>,
        link_totals: &[(Link, f64)],
        measured: Option<&crate::net::MeasuredWire>,
    ) {
        if !self.enabled {
            return;
        }
        if let Some(sink) = &self.sink {
            if let Ok(mut s) = sink.lock() {
                s.write(&self.summary_event(layers, link_totals, measured));
                s.flush();
            }
        }
    }

    /// Run-total counters so far.
    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Run-total per-stage seconds so far.
    pub fn totals(&self) -> &StageSpans {
        &self.totals
    }

    /// The in-memory ring of recent step records.
    pub fn ring(&self) -> &Ring {
        &self.ring
    }

    fn summary_event(
        &self,
        layers: Option<(&[String], &[u64])>,
        link_totals: &[(Link, f64)],
        measured: Option<&crate::net::MeasuredWire>,
    ) -> Json {
        let c = &self.counters;
        let mut fields: Vec<(&str, Json)> = vec![
            ("event", Json::Str("summary".into())),
            ("steps", Json::Num(c.steps as f64)),
            ("data_rounds", Json::Num(c.data_rounds as f64)),
            ("stat_rounds", Json::Num(c.stat_rounds as f64)),
            ("data_bits", Json::Num(c.data_bits as f64)),
            ("stat_bits", Json::Num(c.stat_bits as f64)),
            ("level_updates", Json::Num(c.level_updates as f64)),
            ("codec_refreshes", Json::Num(c.codec_refreshes as f64)),
            ("allocs", Json::Num(c.allocs as f64)),
            ("faults", Json::Num(c.faults as f64)),
            ("spans", self.totals.to_json()),
            ("links", Json::Num(link_totals.len() as f64)),
        ];
        let hottest = link_totals
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .copied()
            .unwrap_or(((0, 0), 0.0));
        fields.push(("hot_link", link_json(hottest.0)));
        fields.push(("hot_link_bytes", Json::Num(hottest.1)));
        // Modeled per-link totals as `[src, dst, bytes]` triples, sorted by
        // endpoint pair so streams from different runs diff cleanly.
        let mut totals: Vec<(Link, f64)> = link_totals.to_vec();
        totals.sort_by_key(|(l, _)| *l);
        fields.push((
            "link_totals",
            Json::Arr(
                totals
                    .iter()
                    .map(|&((i, j), b)| {
                        Json::Arr(vec![
                            Json::Num(i as f64),
                            Json::Num(j as f64),
                            Json::Num(b),
                        ])
                    })
                    .collect(),
            ),
        ));
        if let Some(m) = measured {
            fields.push(("measured", measured_json(m)));
        }
        if let Some((names, bits)) = layers {
            fields.push((
                "layer_bits",
                Json::obj(
                    names
                        .iter()
                        .zip(bits.iter())
                        .map(|(n, &b)| (n.clone(), Json::Num(b as f64))),
                ),
            ));
        }
        Json::obj(fields)
    }
}

fn link_json(link: Link) -> Json {
    Json::Arr(vec![Json::Num(link.0 as f64), Json::Num(link.1 as f64)])
}

/// This endpoint's physical framed-byte counters (socket fabric), as the
/// summary's `measured` object: per-plane payload bytes, frame/header
/// overhead, and the endpoint's per-link data-plane view (`links_sent` /
/// `links_recv` as `[src, dst, bytes]` triples) for measured-vs-modeled
/// reconciliation.
fn measured_json(m: &crate::net::MeasuredWire) -> Json {
    let links = |v: &[(Link, u64)]| {
        let mut v = v.to_vec();
        v.sort_by_key(|(l, _)| *l);
        Json::Arr(
            v.iter()
                .map(|&((i, j), b)| {
                    Json::Arr(vec![Json::Num(i as f64), Json::Num(j as f64), Json::Num(b as f64)])
                })
                .collect(),
        )
    };
    Json::obj([
        ("rank", Json::Num(m.rank as f64)),
        ("data_rounds", Json::Num(m.data_rounds as f64)),
        ("frames_sent", Json::Num(m.frames_sent as f64)),
        ("frames_recv", Json::Num(m.frames_recv as f64)),
        ("header_bytes", Json::Num(m.header_bytes as f64)),
        ("data_bytes_sent", Json::Num(m.data_bytes_sent() as f64)),
        ("data_bytes_recv", Json::Num(m.data_bytes_recv() as f64)),
        ("control_bytes_sent", Json::Num(m.control_sent as f64)),
        ("control_bytes_recv", Json::Num(m.control_recv as f64)),
        ("oob_bytes_sent", Json::Num(m.oob_sent as f64)),
        ("oob_bytes_recv", Json::Num(m.oob_recv as f64)),
        ("links_sent", links(&m.data_sent)),
        ("links_recv", links(&m.data_recv)),
    ])
}

/// The JSONL `step` event for one record (schema: `docs/OBSERVABILITY.md`).
/// The `ef_*` fields appear only on steps where an error-feedback
/// compressor reported, so EF-off streams stay byte-identical.
fn step_event(r: &StepRecord) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("event", Json::Str("step".into())),
        ("t", Json::Num(r.t as f64)),
        ("spans", r.spans.to_json()),
        ("data_bits", Json::Num(r.data_bits as f64)),
        ("stat_bits", Json::Num(r.stat_bits as f64)),
        ("rounds", Json::Num(r.rounds as f64)),
        ("stat_rounds", Json::Num(r.stat_rounds as f64)),
        ("level_update", Json::Bool(r.level_update)),
        ("codec_refresh", Json::Bool(r.codec_refresh)),
        ("allocs", Json::Num(r.allocs as f64)),
        ("links", Json::Num(r.links as f64)),
        ("hot_link", link_json(r.hot_link)),
        ("hot_link_bytes", Json::Num(r.hot_link_bytes)),
    ];
    if r.ef {
        fields.push(("ef_err_norm", Json::Num(r.ef_err_norm)));
        fields.push(("ef_delta", Json::Num(r.ef_delta)));
    }
    Json::obj(fields)
}

/// Build the JSONL `manifest` event (the stream's first line).
pub fn manifest_event(cfg: &crate::config::ExperimentConfig) -> Json {
    Json::obj([
        ("event", Json::Str("manifest".into())),
        ("schema", Json::Num(TELEMETRY_SCHEMA as f64)),
        ("workers", Json::Num(cfg.workers as f64)),
        ("iters", Json::Num(cfg.iters as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("topo", Json::Str(cfg.topo.kind.clone())),
        ("algo", Json::Str(cfg.algo.method.name().into())),
        ("problem", Json::Str(cfg.problem.kind.clone())),
        (
            "quant",
            Json::Str(match cfg.quant.mode {
                crate::config::QuantMode::Fp32 => "fp32".into(),
                crate::config::QuantMode::Quantized { levels } => format!("q{levels}"),
            }),
        ),
        (
            "stages",
            Json::Arr(STAGES.iter().map(|s| Json::Str(s.name().into())).collect()),
        ),
    ])
}

/// [`crate::coordinator::Observer`] bridge: streams one compact line per
/// `every` steps from the [`StepRecord`] attached to each
/// [`crate::coordinator::StepReport`], and a stage/counter summary at
/// finish. Purely additive — it reads records, never the engine.
pub struct TelemetryObserver {
    every: usize,
    totals: StageSpans,
    data_bits: u64,
    stat_bits: u64,
    steps: u64,
}

impl TelemetryObserver {
    /// Print a line every `every` steps (0 = summary only).
    pub fn every(every: usize) -> Self {
        TelemetryObserver { every, totals: StageSpans::default(), data_bits: 0, stat_bits: 0, steps: 0 }
    }
}

impl Default for TelemetryObserver {
    fn default() -> Self {
        TelemetryObserver::every(100)
    }
}

impl crate::coordinator::Observer for TelemetryObserver {
    fn on_step(&mut self, rep: &crate::coordinator::StepReport) -> crate::coordinator::Control {
        if let Some(rec) = &rep.telemetry {
            self.steps += 1;
            self.totals.merge(&rec.spans);
            self.data_bits += rec.data_bits;
            self.stat_bits += rec.stat_bits;
            if self.every != 0 && (rep.t % self.every == 0 || rep.done) {
                println!(
                    "[telemetry] t={:>6}  data {:>8} b  stat {:>6} b  hot ({},{}) {:>9.0} B  spans {}",
                    rec.t,
                    rec.data_bits,
                    rec.stat_bits,
                    rec.hot_link.0,
                    rec.hot_link.1,
                    rec.hot_link_bytes,
                    crate::benchkit::fmt_secs(rec.spans.total()),
                );
            }
        }
        crate::coordinator::Control::Continue
    }

    fn on_finish(&mut self, _rec: &crate::metrics::Recorder) {
        if self.steps == 0 {
            return;
        }
        println!("[telemetry] {} steps, {} data bits, {} stat bits", self.steps, self.data_bits, self.stat_bits);
        for (stage, secs) in self.totals.iter() {
            if secs > 0.0 {
                println!("[telemetry]   {:<9} {}", stage.name(), crate::benchkit::fmt_secs(secs));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_spans_accumulate_and_merge() {
        let mut a = StageSpans::default();
        a.add(Stage::Quantize, 0.5);
        a.add(Stage::Quantize, 0.25);
        a.add(Stage::Decode, 1.0);
        assert_eq!(a.get(Stage::Quantize), 0.75);
        assert_eq!(a.total(), 1.75);
        let mut b = StageSpans::default();
        b.add(Stage::Decode, 1.0);
        b.merge(&a);
        assert_eq!(b.get(Stage::Decode), 2.0);
        assert_eq!(STAGES.len(), N_STAGES);
        // idx is a bijection onto 0..N_STAGES (the array contract).
        let mut seen = [false; N_STAGES];
        for s in STAGES {
            assert!(!seen[s.idx()], "duplicate idx for {:?}", s);
            seen[s.idx()] = true;
        }
    }

    #[test]
    fn ring_wraps_and_iterates_in_order() {
        let mut r = Ring::with_capacity(3);
        assert!(r.is_empty() && r.latest().is_none());
        for t in 1..=5u64 {
            r.push(StepRecord { t, ..Default::default() });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        let ts: Vec<u64> = r.iter().map(|x| x.t).collect();
        assert_eq!(ts, vec![3, 4, 5], "oldest → newest after wrap");
        assert_eq!(r.latest().unwrap().t, 5);
        // capacity 0: pushes are dropped, never panic
        let mut z = Ring::with_capacity(0);
        z.push(StepRecord::default());
        assert!(z.is_empty());
    }

    #[test]
    fn config_parse_covers_the_knob_grammar() {
        assert_eq!(TelemetryConfig::parse(""), None);
        assert_eq!(TelemetryConfig::parse("0"), None);
        assert_eq!(TelemetryConfig::parse("1"), Some(TelemetryConfig::memory()));
        assert_eq!(TelemetryConfig::parse("mem"), Some(TelemetryConfig::memory()));
        let j = TelemetryConfig::parse("/tmp/run.jsonl").unwrap();
        assert_eq!(j.jsonl.as_deref(), Some("/tmp/run.jsonl"));
        assert_eq!(j.ring, TelemetryConfig::default().ring);
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let mut t = Telemetry::off();
        assert!(!t.is_enabled());
        assert!(t.clock().is_none());
        assert!(t.spans_mut().is_none());
        t.on_data_round(100, 1.0, &[((0, 1), 10.0)]);
        t.on_stat_round(10, true, true);
        assert_eq!(t.end_step(1), None);
        assert_eq!(*t.counters(), Counters::default());
    }

    #[test]
    fn recorder_accumulates_rounds_into_step_records() {
        let mut t = Telemetry::new(&TelemetryConfig::memory(), &Json::Null).unwrap();
        assert!(t.is_enabled());
        t.on_data_round(800, 0.25, &[((0, 1), 50.0), ((1, 0), 100.0)]);
        t.on_data_round(400, 0.25, &[((0, 1), 25.0), ((1, 0), 50.0)]);
        t.on_stat_round(64, true, false);
        let rec = t.end_step(1).unwrap();
        assert_eq!(rec.t, 1);
        assert_eq!(rec.data_bits, 1200);
        assert_eq!(rec.stat_bits, 64);
        assert_eq!(rec.rounds, 2);
        assert_eq!(rec.stat_rounds, 1);
        assert!(rec.codec_refresh && !rec.level_update);
        assert_eq!(rec.hot_link, (1, 0));
        assert_eq!(rec.hot_link_bytes, 100.0);
        assert_eq!(rec.links, 2);
        assert_eq!(rec.spans.get(Stage::Exchange), 0.5);
        // step state resets; run totals persist
        let rec2 = t.end_step(2).unwrap();
        assert_eq!(rec2.data_bits, 0);
        assert_eq!(rec2.hot_link_bytes, 0.0);
        assert_eq!(t.counters().data_bits, 1200);
        assert_eq!(t.counters().steps, 2);
        assert_eq!(t.counters().codec_refreshes, 1);
        assert_eq!(t.totals().get(Stage::Exchange), 0.5);
        assert_eq!(t.ring().len(), 2);
    }

    #[test]
    fn step_and_summary_events_are_valid_json() {
        let rec = StepRecord {
            t: 7,
            data_bits: 123,
            hot_link: (2, 0),
            hot_link_bytes: 9.5,
            links: 6,
            ..Default::default()
        };
        let ev = step_event(&rec);
        let back = Json::parse(&ev.dump()).unwrap();
        assert_eq!(back.get("event").unwrap().as_str(), Some("step"));
        assert_eq!(back.get("t").unwrap().as_usize(), Some(7));
        assert_eq!(back.at(&["spans", "exchange"]).unwrap().as_f64(), Some(0.0));
        assert_eq!(back.get("hot_link").unwrap().as_array().unwrap().len(), 2);

        let mut t = Telemetry::new(&TelemetryConfig::memory(), &Json::Null).unwrap();
        t.on_data_round(8, 0.0, &[]);
        t.end_step(1);
        let names = vec!["embed".to_string(), "head".to_string()];
        let bits = vec![100u64, 300];
        let s = t.summary_event(Some((&names, &bits)), &[((0, 1), 5.0)], None);
        let back = Json::parse(&s.dump()).unwrap();
        assert_eq!(back.get("event").unwrap().as_str(), Some("summary"));
        assert_eq!(back.get("data_bits").unwrap().as_usize(), Some(8));
        assert_eq!(back.at(&["layer_bits", "head"]).unwrap().as_usize(), Some(300));
        assert_eq!(back.get("links").unwrap().as_usize(), Some(1));
        let lt = back.get("link_totals").unwrap().as_array().unwrap();
        assert_eq!(lt.len(), 1);
        assert_eq!(lt[0].as_array().unwrap().len(), 3, "[src, dst, bytes] triples");
        assert!(back.get("measured").is_none(), "no measured object without a wire");
    }

    #[test]
    fn summary_embeds_measured_wire_counters() {
        let t = Telemetry::new(&TelemetryConfig::memory(), &Json::Null).unwrap();
        let m = crate::net::MeasuredWire {
            rank: 1,
            data_rounds: 4,
            frames_sent: 10,
            frames_recv: 10,
            header_bytes: 480,
            data_sent: vec![((1, 0), 64), ((1, 2), 64)],
            data_recv: vec![((0, 1), 32), ((2, 1), 96)],
            control_sent: 24,
            control_recv: 48,
            oob_sent: 40,
            oob_recv: 80,
        };
        let s = t.summary_event(None, &[((1, 0), 64.0), ((1, 2), 64.0)], Some(&m));
        let back = Json::parse(&s.dump()).unwrap();
        assert_eq!(back.at(&["measured", "rank"]).unwrap().as_usize(), Some(1));
        assert_eq!(back.at(&["measured", "data_rounds"]).unwrap().as_usize(), Some(4));
        assert_eq!(back.at(&["measured", "data_bytes_sent"]).unwrap().as_usize(), Some(128));
        assert_eq!(back.at(&["measured", "data_bytes_recv"]).unwrap().as_usize(), Some(128));
        assert_eq!(back.at(&["measured", "header_bytes"]).unwrap().as_usize(), Some(480));
        assert_eq!(back.at(&["measured", "oob_bytes_recv"]).unwrap().as_usize(), Some(80));
        let links = back.at(&["measured", "links_sent"]).unwrap().as_array().unwrap();
        assert_eq!(links.len(), 2);
        assert_eq!(
            links[0].as_array().unwrap().iter().map(|j| j.as_f64().unwrap()).collect::<Vec<_>>(),
            vec![1.0, 0.0, 64.0]
        );
    }

    #[test]
    fn ef_fields_appear_only_on_reported_steps() {
        let mut t = Telemetry::new(&TelemetryConfig::memory(), &Json::Null).unwrap();
        // No on_ef call: the step event must carry no ef_* fields at all.
        t.on_data_round(8, 0.0, &[]);
        let plain = t.end_step(1).unwrap();
        assert!(!plain.ef);
        let ev = Json::parse(&step_event(&plain).dump()).unwrap();
        assert!(ev.get("ef_err_norm").is_none(), "EF-off steps stay byte-identical");
        assert!(ev.get("ef_delta").is_none());
        // Reported step: marks fold into the record and the event.
        t.on_ef(0.75, 0.125);
        let rec = t.end_step(2).unwrap();
        assert!(rec.ef);
        assert_eq!(rec.ef_err_norm, 0.75);
        assert_eq!(rec.ef_delta, 0.125);
        let ev = Json::parse(&step_event(&rec).dump()).unwrap();
        assert_eq!(ev.get("ef_err_norm").unwrap().as_f64(), Some(0.75));
        assert_eq!(ev.get("ef_delta").unwrap().as_f64(), Some(0.125));
        // Marks reset with the step.
        let rec3 = t.end_step(3).unwrap();
        assert!(!rec3.ef);
        // Disabled recorder: inert.
        let mut off = Telemetry::off();
        off.on_ef(1.0, 1.0);
        assert_eq!(off.end_step(1), None);
    }

    #[test]
    fn fault_events_count_and_surface_in_the_summary() {
        // Disabled recorder: inert, no counter movement.
        let mut off = Telemetry::off();
        off.on_fault("kill", 2, 5);
        assert_eq!(off.counters().faults, 0);

        let mut t = Telemetry::new(&TelemetryConfig::memory(), &Json::Null).unwrap();
        t.on_fault("rewire", 0, 10);
        t.on_fault("stale", 3, 12);
        assert_eq!(t.counters().faults, 2);
        let s = t.summary_event(None, &[], None);
        let back = Json::parse(&s.dump()).unwrap();
        assert_eq!(back.get("faults").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn clone_deep_copies_in_memory_state() {
        let mut a = Telemetry::new(&TelemetryConfig::memory(), &Json::Null).unwrap();
        a.on_data_round(100, 0.0, &[]);
        a.end_step(1);
        let mut b = a.clone();
        b.on_data_round(100, 0.0, &[]);
        b.end_step(2);
        assert_eq!(a.counters().steps, 1, "clone must not share counters");
        assert_eq!(b.counters().steps, 2);
        assert_eq!(a.ring().len(), 1);
        assert_eq!(b.ring().len(), 2);
    }
}
