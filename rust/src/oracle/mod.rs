//! Monotone VI problem suite and stochastic first-order oracles.
//!
//! The paper's object of study is the problem `find x* : ⟨A(x*), x − x*⟩ ≥ 0`
//! for a monotone operator `A`, accessed only through a stochastic oracle
//! `g(x; ω) = A(x) + U(x; ω)` under either the *absolute* (Assumption 2) or
//! *relative* (Assumption 3) noise model.
//!
//! * [`problems`] — concrete operators: bilinear saddle (skew, the GAN
//!   surrogate), strongly-monotone / co-coercive quadratics, the rotation
//!   operator (the classic EG-vs-GDA separator), matrix games.
//! * [`noise`] — oracles: bounded absolute noise, relative (multiplicative)
//!   noise, random coordinate descent (Appendix J.1) and random player
//!   updating (J.2), both of which satisfy Assumption 3 naturally.
//! * [`gap`] — the restricted gap function `Gap_C` used as the performance
//!   measure (Proposition 1), with closed forms for affine operators.

pub mod gap;
pub mod noise;
pub mod problems;

pub use gap::GapEvaluator;
pub use noise::{
    AbsoluteNoiseOracle, ExactOracle, Oracle, RandomPlayerOracle, RcdOracle, RelativeNoiseOracle,
};
pub use problems::{
    BilinearSaddle, BlockScaledQuadratic, CocoerciveQuadratic, MatrixGame, MonotoneQuadratic,
    Operator, RotationOperator,
};

use crate::config::ProblemConfig;
use crate::error::{Error, Result};
use crate::util::Rng;
use std::sync::Arc;

/// Build an operator from a [`ProblemConfig`] (the launcher entry point).
pub fn build_operator(cfg: &ProblemConfig, seed: u64) -> Result<Arc<dyn Operator>> {
    let mut rng = Rng::seed_from(seed ^ 0x0b5e55ed);
    match cfg.kind.as_str() {
        "bilinear" => Ok(Arc::new(BilinearSaddle::random(cfg.dim, 1.0, &mut rng)?)),
        "quadratic" => Ok(Arc::new(MonotoneQuadratic::random(cfg.dim, 0.1, 1.0, &mut rng)?)),
        "cocoercive" => Ok(Arc::new(CocoerciveQuadratic::random(cfg.dim, 0.1, 1.0, &mut rng)?)),
        "rotation" => Ok(Arc::new(RotationOperator::new(cfg.dim, 0.05, 1.0)?)),
        "game" => Ok(Arc::new(MatrixGame::random(cfg.dim, &mut rng)?)),
        // LM/GAN-shaped block-heterogeneous proxies (layer-wise benches;
        // runnable without AOT artifacts).
        "lm-proxy" => Ok(Arc::new(BlockScaledQuadratic::lm_proxy(cfg.dim, &mut rng)?)),
        "gan-proxy" => Ok(Arc::new(BlockScaledQuadratic::gan_proxy(cfg.dim, &mut rng)?)),
        other => Err(Error::Oracle(format!("unknown problem kind `{other}`"))),
    }
}

/// Build a per-worker oracle over an operator from the config's noise model.
pub fn build_oracle(
    op: Arc<dyn Operator>,
    cfg: &ProblemConfig,
    worker_seed: u64,
) -> Result<Box<dyn Oracle>> {
    let rng = Rng::seed_from(worker_seed);
    match cfg.noise.as_str() {
        "none" | "exact" => Ok(Box::new(ExactOracle::new(op))),
        "absolute" => Ok(Box::new(AbsoluteNoiseOracle::new(op, cfg.sigma, rng))),
        "relative" => Ok(Box::new(RelativeNoiseOracle::new(op, cfg.rel_c, rng))),
        "rcd" => Ok(Box::new(RcdOracle::new(op, rng))),
        "player" => Ok(Box::new(RandomPlayerOracle::new(op, 2, rng)?)),
        other => Err(Error::Oracle(format!("unknown noise model `{other}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_operator_all_kinds() {
        for kind in
            ["bilinear", "quadratic", "cocoercive", "rotation", "game", "lm-proxy", "gan-proxy"]
        {
            let cfg = ProblemConfig { kind: kind.into(), dim: 16, ..Default::default() };
            let op = build_operator(&cfg, 1).unwrap();
            assert!(op.dim() >= 16);
        }
        let bad = ProblemConfig { kind: "nope".into(), ..Default::default() };
        assert!(build_operator(&bad, 1).is_err());
    }

    #[test]
    fn build_oracle_all_noise_models() {
        let cfg = ProblemConfig { kind: "quadratic".into(), dim: 8, ..Default::default() };
        let op = build_operator(&cfg, 2).unwrap();
        for noise in ["none", "absolute", "relative", "rcd", "player"] {
            let mut c = cfg.clone();
            c.noise = noise.into();
            let mut oracle = build_oracle(op.clone(), &c, 3).unwrap();
            let x = vec![0.5f32; op.dim()];
            let mut g = vec![0.0f32; op.dim()];
            oracle.sample(&x, &mut g);
            assert!(g.iter().all(|v| v.is_finite()), "noise={noise}");
        }
    }
}
