//! §Perf — L3 hot-path microbenchmarks: quantize, entropy-encode, decode,
//! dequantize, and the whole compressor round-trip, at model-scale d.
//!
//! Targets (DESIGN.md §Perf): single-thread quantize+encode ≥ 400 MB/s so
//! the wire path is never the bottleneck against a 1 GbE (≈ 117 MiB/s)
//! link; the compressor round trip must cost well below the modeled
//! network saving it buys.

use qgenx::benchkit::{bench, fmt_secs, fmt_throughput, scaled, Table};
use qgenx::coding::SymbolCodec;
use qgenx::config::{LevelScheme, QuantConfig, QuantMode};
use qgenx::coordinator::Compressor;
use qgenx::net::NetModel;
use qgenx::quant::{
    decode_vector, dequantize, encode_vector, quantize, symbol_probs, Levels, SufficientStats,
    WireCodec,
};
use qgenx::util::Rng;

fn main() {
    println!("== §Perf: wire-path microbenchmarks ==\n");
    let d = scaled(4_000_000, 400_000);
    let bytes = 4 * d;
    let reps = scaled(10, 3);
    let mut rng = Rng::seed_from(0x9e7f);
    let v = rng.gaussian_vec(d, 1.0);
    let levels = Levels::uniform(14);

    let mut stats = SufficientStats::new(256, 2);
    stats.observe_bucketed(&v, 1024);
    let probs = symbol_probs(&stats, &levels);

    let mut table = Table::new(&["stage", "median", "throughput (vs f32 input)"]);

    // quantize
    let mut q_rng = Rng::seed_from(1);
    let t = bench("quantize", 1, reps, || {
        let qv = quantize(&v, &levels, 2, 1024, &mut q_rng).unwrap();
        std::hint::black_box(qv.symbols.len());
    });
    table.row(&["quantize (bucketed L2)".into(), fmt_secs(t.median()), fmt_throughput(bytes, t.median())]);

    let qv = quantize(&v, &levels, 2, 1024, &mut q_rng).unwrap();

    // encode per codec
    for kind in [SymbolCodec::Fixed, SymbolCodec::EliasGamma, SymbolCodec::Huffman] {
        let codec = match kind {
            SymbolCodec::Huffman => WireCodec::new(kind, &levels, Some(&probs)).unwrap(),
            _ => WireCodec::new(kind, &levels, None).unwrap(),
        };
        let t = bench(kind.name(), 1, reps, || {
            let (b, _) = encode_vector(&qv, &codec).unwrap();
            std::hint::black_box(b.len());
        });
        table.row(&[
            format!("encode ({})", kind.name()),
            fmt_secs(t.median()),
            fmt_throughput(bytes, t.median()),
        ]);
        let (wire, _) = encode_vector(&qv, &codec).unwrap();
        let t = bench("decode", 1, reps, || {
            let out = decode_vector(&wire, d, 1024, &codec).unwrap();
            std::hint::black_box(out.symbols.len());
        });
        table.row(&[
            format!("decode ({})", kind.name()),
            fmt_secs(t.median()),
            fmt_throughput(bytes, t.median()),
        ]);
    }

    // dequantize
    let t = bench("dequantize", 1, reps, || {
        let out = dequantize(&qv, &levels);
        std::hint::black_box(out.len());
    });
    table.row(&["dequantize".into(), fmt_secs(t.median()), fmt_throughput(bytes, t.median())]);

    // full compressor round trip (what the coordinator actually runs)
    let mut comp = Compressor::from_config(
        &QuantConfig {
            mode: QuantMode::Quantized { levels: 14 },
            scheme: LevelScheme::Uniform,
            codec: SymbolCodec::Huffman,
            bucket_size: 1024,
            ..Default::default()
        },
        Rng::seed_from(2),
    )
    .unwrap();
    // prime Huffman with real probabilities via one update
    let _ = comp.compress(&v).unwrap();
    let mut out = vec![0.0f32; d];
    let t_rt = bench("roundtrip", 1, reps, || {
        let (wire, _) = comp.compress(&v).unwrap();
        comp.decompress(&wire, &mut out).unwrap();
        std::hint::black_box(out[0]);
    });
    table.row(&[
        "compressor round-trip".into(),
        fmt_secs(t_rt.median()),
        fmt_throughput(bytes, t_rt.median()),
    ]);
    table.print();

    // Economics: is the codec cheaper than the network saving it buys?
    let net = NetModel::gbe();
    let (wire, _) = comp.compress(&v).unwrap();
    let t_fp32 = net.allgather_time(&[bytes; 3]);
    let t_q = net.allgather_time(&[wire.len(); 3]);
    let saving = t_fp32 - t_q;
    let cost = t_rt.median();
    println!(
        "\neconomics at d={d}, K=3, 1GbE: network saving {}/round vs codec cost {}/vector — {}",
        fmt_secs(saving),
        fmt_secs(cost),
        if cost < saving { "PROFITABLE" } else { "NOT profitable at this scale" },
    );
    println!(
        "wire size: {:.2} MB vs {:.2} MB fp32 ({:.1}x)",
        wire.len() as f64 / 1e6,
        bytes as f64 / 1e6,
        bytes as f64 / wire.len() as f64
    );
}
