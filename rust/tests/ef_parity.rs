//! Error-feedback parity suite — the neutrality and determinism pins of
//! the contractive compression subsystem (`[quant.ef]`, docs/CONFIG.md):
//!
//! * **Full feedback is exact**: with `k = d` the top-k operator keeps
//!   every coordinate, the error memory stays identically zero, and the
//!   trajectory is bit-identical to uncompressed fp32 on all three
//!   runner families (only the wire accounting differs).
//! * **Off means off**: a config that spells `[quant.ef] scheme = "off"`
//!   runs bit-identically — gap, cumulative bits, stat rounds — to a
//!   config that predates the table entirely, on all three families; a
//!   disabled table with leftover operator parameters is rejected.
//! * **Checkpoint / resume**: a session checkpointed mid-run with a
//!   *nonzero* error memory continues bit-for-bit, so the memory
//!   round-trips through the snapshot exactly.
//! * **Per-rank replication**: on exact topologies the threaded fabric
//!   (every rank owning its own compressor and decoding peers' frames
//!   off the wire) must reproduce the single-engine loopback trajectory
//!   for every scheme — the seeded random-k support and deterministic
//!   tie-breaks included.

use qgenx::config::{EfConfig, EfScheme, ExperimentConfig, QuantMode};
use qgenx::coordinator::{run_experiment, run_threaded, Session};

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 3;
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 12;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.3;
    cfg.quant.update_every = 60;
    cfg
}

/// One config per runner family: synchronous exact, gossip averaging,
/// and local steps with periodic sync.
fn family_cfg(family: &str) -> ExperimentConfig {
    let mut cfg = base_cfg();
    match family {
        "exact" => {}
        "gossip" => {
            cfg.workers = 6;
            cfg.topo.kind = "gossip".into();
            cfg.topo.degree = 2;
        }
        "local" => cfg.local.steps = 4,
        other => panic!("unknown family {other}"),
    }
    cfg
}

fn ef(scheme: EfScheme, k: usize, rank: usize) -> EfConfig {
    EfConfig { scheme, k, rank, ..Default::default() }
}

#[test]
fn full_feedback_top_k_matches_the_uncompressed_trajectory() {
    for family in ["exact", "gossip", "local"] {
        let mut fp32 = family_cfg(family);
        fp32.quant.mode = QuantMode::Fp32;
        let mut full = family_cfg(family);
        full.quant.ef = ef(EfScheme::TopK, fp32.problem.dim, 0); // k = d

        let base = run_experiment(&fp32).unwrap();
        let rec = run_experiment(&full).unwrap();
        for series in ["gap", "dist"] {
            assert_eq!(
                base.get(series).unwrap().ys(),
                rec.get(series).unwrap().ys(),
                "{family}: k = d keeps every coordinate — same {series} trajectory as fp32"
            );
        }
        assert_eq!(base.scalar("rounds"), rec.scalar("rounds"), "{family}");
        // The memory never charges: e_t ≡ 0, effective δ = 1, and the
        // worst-case bound δ = k/d = 1 as well.
        assert_eq!(rec.scalar("ef_err_norm"), Some(0.0), "{family}");
        assert_eq!(rec.scalar("ef_delta"), Some(1.0), "{family}");
        assert_eq!(rec.scalar("ef_delta_bound"), Some(1.0), "{family}");
        assert_eq!(rec.scalar("level_updates"), Some(0.0), "{family}: EF is non-adaptive");
        // The fp32 comparator carries no EF diagnostics at all.
        assert_eq!(base.scalar("ef_err_norm"), None, "{family}");
    }
}

#[test]
fn scheme_off_is_bit_identical_to_a_config_without_the_table() {
    // The parse path: an explicit `scheme = "off"` table is the default
    // disabled config, and leftover operator parameters under it are a
    // config error rather than silent dead weight.
    let off = ExperimentConfig::from_toml("[quant.ef]\nscheme = \"off\"\n").unwrap();
    assert_eq!(off.quant.ef, EfConfig::default());
    assert!(!off.quant.ef.enabled());
    assert!(ExperimentConfig::from_toml("[quant.ef]\nscheme = \"off\"\nk = 3\n").is_err());
    assert!(ExperimentConfig::from_toml("[quant.ef]\nscheme = \"topk\"\n").is_err());

    for family in ["exact", "gossip", "local"] {
        let plain = family_cfg(family);
        let mut tabled = family_cfg(family);
        tabled.quant.ef = off.quant.ef.clone();

        let a = run_experiment(&plain).unwrap();
        let b = run_experiment(&tabled).unwrap();
        for series in ["gap", "dist", "bits_cum"] {
            assert_eq!(
                a.get(series).unwrap().ys(),
                b.get(series).unwrap().ys(),
                "{family}: scheme = \"off\" must leave the unbiased path untouched ({series})"
            );
        }
        for scalar in ["total_bits", "level_updates", "rounds"] {
            assert_eq!(a.scalar(scalar), b.scalar(scalar), "{family}: {scalar}");
        }
        assert_eq!(b.scalar("ef_err_norm"), None, "{family}: no EF telemetry when off");
    }
}

#[test]
fn checkpoint_resume_with_live_error_memory_continues_bit_for_bit() {
    for family in ["exact", "gossip", "local"] {
        let mut cfg = family_cfg(family);
        cfg.quant.ef = ef(EfScheme::TopK, 3, 0); // k = d/4: heavy memory

        let whole = run_experiment(&cfg).unwrap();
        let err_norm = whole.scalar("ef_err_norm").unwrap();
        let delta = whole.scalar("ef_delta").unwrap();
        assert!(err_norm > 0.0, "{family}: k < d must leave a live error memory");
        assert!((0.0..=1.0).contains(&delta), "{family}: effective δ in [0, 1], got {delta}");
        assert_eq!(whole.scalar("level_updates"), Some(0.0), "{family}: zero stat rounds");

        let mut first = Session::builder(cfg.clone()).build().unwrap();
        first.run_to(cfg.iters / 2).unwrap();
        let cp = first.checkpoint().unwrap();
        drop(first);

        let mut resumed = Session::resume(cp).unwrap();
        resumed.run_to(cfg.iters).unwrap();
        let rec = resumed.into_recorder();
        for series in ["gap", "dist", "bits_cum"] {
            assert_eq!(
                whole.get(series).unwrap().ys(),
                rec.get(series).unwrap().ys(),
                "{family}: the error memory must round-trip the snapshot exactly ({series})"
            );
        }
        assert_eq!(whole.scalar("total_bits"), rec.scalar("total_bits"), "{family}");
        assert_eq!(rec.scalar("ef_err_norm"), Some(err_norm), "{family}");
        assert_eq!(rec.scalar("ef_delta"), Some(delta), "{family}");
    }
}

#[test]
fn per_rank_compressors_reproduce_the_loopback_trajectory() {
    let cases = [
        ("topk", ef(EfScheme::TopK, 3, 0)),
        ("randk", ef(EfScheme::RandK, 3, 0)),
        ("rankr", ef(EfScheme::RankR, 0, 2)),
    ];
    // Inline-vs-threaded bit parity is an exact-topology contract (the
    // inexact families replicate differently by design; see
    // tests/transport_parity.rs), so the sweep stays on exact graphs.
    for (name, ef_cfg) in cases {
        for topo in ["full-mesh", "ring"] {
            let mut cfg = family_cfg("exact");
            cfg.topo.kind = topo.into();
            cfg.quant.ef = ef_cfg.clone();
            let inline_rec = run_experiment(&cfg).unwrap();
            let threaded = run_threaded(&cfg).unwrap();
            for series in ["gap", "dist"] {
                assert_eq!(
                    inline_rec.get(series).unwrap().ys(),
                    threaded.recorder.get(series).unwrap().ys(),
                    "{name}/{topo}: replicated per-rank compressors must agree ({series})"
                );
            }
            // Rank 0's EF diagnostics are the same object in both
            // fabrics: same seed fork, same frames decoded.
            for scalar in ["ef_err_norm", "ef_delta", "rounds"] {
                assert_eq!(
                    inline_rec.scalar(scalar),
                    threaded.recorder.scalar(scalar),
                    "{name}/{topo}: {scalar}"
                );
            }
        }
    }
}
