//! Unbiased random quantization of stochastic dual vectors — the `Q` half
//! of the paper's `CODE ∘ Q` pipeline, plus the QAda adaptive-level
//! machinery (§3.3) and the Theorem 1 / Theorem 2 bound calculators.
//!
//! * [`levels`] — level sequences `ℓ = (0, ℓ_1, …, ℓ_s, 1)` (Definition 1):
//!   uniform (QSGD-style), exponential (NUQSGD-style), adaptive (QAda).
//! * [`quantizer`] — `Q_ℓ(v) = ‖v‖_q · s ⊙ [q_ℓ(u_1) … q_ℓ(u_d)]`, its
//!   deterministic core (explicit uniforms — bit-exact against the Pallas
//!   kernel), dequantization, and the bucketed variant torch_cgx uses.
//! * [`encode`] — the wire format: per-bucket `[norm f32][symbol codes +
//!   sign bits]` under a pluggable Ψ ([`crate::coding::SymbolCodec`]).
//! * [`adaptive`] — sufficient statistics (weighted histogram of normalized
//!   coordinates), the (QAda) variance objective, coordinate-descent level
//!   optimization, Proposition 2 symbol probabilities.
//! * [`bounds`] — Theorem 1 variance bound `ε_Q`, the QSGD/NUQSGD
//!   comparison bounds, Theorem 2 expected code length.

pub mod adaptive;
pub mod bounds;
pub mod encode;
pub mod levels;
pub mod quantizer;

pub use adaptive::{optimize_levels, symbol_probs, SufficientStats};
pub use bounds::{code_length_bound, epsilon_q, nuqsgd_variance_bound, qsgd_variance_bound};
pub use encode::{decode_vector, encode_vector, WireCodec};
pub use levels::Levels;
pub use quantizer::{
    dequantize, dequantize_into, quantize, quantize_with_uniforms, QuantizedVector,
};
