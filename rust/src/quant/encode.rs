//! The wire format: `CODE ∘ Q` (§3.2, Appendix K) and its inverse
//! `DEQ ∘ CODE`.
//!
//! Per bucket:  `[‖v‖_q : f32 (C_b = 32)]` then, for each coordinate, the
//! level-index symbol under Ψ followed by one sign bit *iff* the symbol is
//! nonzero (a zero reconstructs to 0 and needs no sign — Lemma 3's
//! `(1 − p_0) d` sign-bit count).
//!
//! Ψ options ([`WireCodec`]): fixed-width (torch_cgx UQ4/UQ8), Elias γ/δ on
//! `symbol + 1` (universal; QSGD-style), or canonical Huffman built from
//! the Proposition 2 probabilities (minimum expected length; the code
//! lengths travel with the level update on schedule `U`, not per message).
//!
//! The decoder needs `(d, bucket_size, levels, codec)` as side information
//! — all of which the coordinator distributes at setup / level updates, so
//! the steady-state wire carries only what Theorem 2 counts.

use super::levels::Levels;
use super::quantizer::QuantizedVector;
use crate::coding::{
    elias, BitReader, BitWriter, HuffmanCode, SymbolCodec,
};
use crate::error::{Error, Result};

/// A symbol codec bound to its side information (the Huffman table when Ψ
/// is Huffman). Construct once per level-update, reuse per message.
#[derive(Clone, Debug)]
pub struct WireCodec {
    pub kind: SymbolCodec,
    /// Fixed width in bits for `SymbolCodec::Fixed`.
    fixed_width: u32,
    /// Huffman table for `SymbolCodec::Huffman`.
    huffman: Option<HuffmanCode>,
}

impl WireCodec {
    /// Build a codec for an alphabet of `s + 2` symbols.
    pub fn new(kind: SymbolCodec, levels: &Levels, probs: Option<&[f64]>) -> Result<Self> {
        let n = levels.alphabet_size();
        let fixed_width = (usize::BITS - (n - 1).leading_zeros()).max(1);
        let huffman = match kind {
            SymbolCodec::Huffman => {
                let probs = probs.ok_or_else(|| {
                    Error::Codec("huffman codec needs symbol probabilities".into())
                })?;
                if probs.len() != n {
                    return Err(Error::Codec(format!(
                        "probs length {} != alphabet {n}",
                        probs.len()
                    )));
                }
                // Floor probabilities so every symbol stays encodable even if
                // the estimate assigned it zero mass.
                let floored: Vec<f64> = probs.iter().map(|&p| p.max(1e-9)).collect();
                Some(HuffmanCode::from_weights(&floored)?)
            }
            _ => None,
        };
        Ok(WireCodec { kind, fixed_width, huffman })
    }

    /// Expected bits for one symbol stream under `probs` (diagnostics).
    pub fn expected_symbol_bits(&self, probs: &[f64]) -> f64 {
        match self.kind {
            SymbolCodec::Fixed => self.fixed_width as f64,
            SymbolCodec::EliasGamma => probs
                .iter()
                .enumerate()
                .map(|(j, p)| p * elias::gamma_len(j as u64 + 1) as f64)
                .sum(),
            SymbolCodec::EliasDelta => probs
                .iter()
                .enumerate()
                .map(|(j, p)| p * elias::delta_len(j as u64 + 1) as f64)
                .sum(),
            SymbolCodec::Huffman => self.huffman.as_ref().unwrap().expected_len(probs),
        }
    }

    #[inline]
    fn encode_symbol(&self, w: &mut BitWriter, sym: u16) -> Result<()> {
        match self.kind {
            SymbolCodec::Fixed => {
                w.write_bits(sym as u64, self.fixed_width);
                Ok(())
            }
            SymbolCodec::EliasGamma => {
                elias::gamma_encode(w, sym as u64 + 1);
                Ok(())
            }
            SymbolCodec::EliasDelta => {
                elias::delta_encode(w, sym as u64 + 1);
                Ok(())
            }
            SymbolCodec::Huffman => self.huffman.as_ref().unwrap().encode(w, sym as usize),
        }
    }

    #[inline]
    fn decode_symbol(&self, r: &mut BitReader) -> Result<u16> {
        match self.kind {
            SymbolCodec::Fixed => Ok(r.read_bits(self.fixed_width)? as u16),
            SymbolCodec::EliasGamma => Ok((elias::gamma_decode(r)? - 1) as u16),
            SymbolCodec::EliasDelta => Ok((elias::delta_decode(r)? - 1) as u16),
            SymbolCodec::Huffman => Ok(self.huffman.as_ref().unwrap().decode(r)? as u16),
        }
    }
}

/// `CODE ∘ Q`: serialize a quantized vector. Returns the wire bytes; the
/// exact bit count (pre-padding) is `bytes.1`.
pub fn encode_vector(qv: &QuantizedVector, codec: &WireCodec) -> Result<(Vec<u8>, u64)> {
    // Capacity guess: norms + ~6 bits/coordinate.
    let mut w = BitWriter::with_capacity(4 * qv.norms.len() + qv.d);
    let b = qv.bucket_size;
    for (bi, &norm) in qv.norms.iter().enumerate() {
        w.write_f32(norm);
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(qv.d);
        if norm == 0.0 {
            continue; // empty bucket: decoder reconstructs zeros, no symbols
        }
        for i in lo..hi {
            let sym = qv.symbols[i];
            codec.encode_symbol(&mut w, sym)?;
            if sym != 0 {
                w.write_bit(qv.sign_is_neg(i));
            }
        }
    }
    let bits = w.bit_len();
    Ok((w.finish(), bits))
}

/// `DEQ ∘ CODE`: parse wire bytes back into a [`QuantizedVector`].
pub fn decode_vector(
    bytes: &[u8],
    d: usize,
    bucket_size: usize,
    codec: &WireCodec,
) -> Result<QuantizedVector> {
    let b = if bucket_size == 0 { d } else { bucket_size };
    let nb = d.div_ceil(b);
    let mut r = BitReader::new(bytes);
    let mut norms = Vec::with_capacity(nb);
    let mut symbols = vec![0u16; d];
    let mut sign_words = vec![0u64; d.div_ceil(64)];
    for bi in 0..nb {
        let norm = r.read_f32()?;
        if !norm.is_finite() || norm < 0.0 {
            return Err(Error::Codec(format!("bad bucket norm {norm}")));
        }
        norms.push(norm);
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(d);
        if norm == 0.0 {
            continue;
        }
        for i in lo..hi {
            let sym = codec.decode_symbol(&mut r)?;
            symbols[i] = sym;
            if sym != 0 && r.read_bit()? {
                sign_words[i / 64] |= 1u64 << (i % 64);
            }
        }
    }
    Ok(QuantizedVector { d, bucket_size: b, norms, symbols, sign_words })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::adaptive::{symbol_probs, SufficientStats};
    use crate::quant::quantizer::{dequantize, quantize};
    use crate::testkit::forall;
    use crate::util::Rng;

    fn all_codecs(levels: &Levels, probs: &[f64]) -> Vec<WireCodec> {
        vec![
            WireCodec::new(SymbolCodec::Fixed, levels, None).unwrap(),
            WireCodec::new(SymbolCodec::EliasGamma, levels, None).unwrap(),
            WireCodec::new(SymbolCodec::EliasDelta, levels, None).unwrap(),
            WireCodec::new(SymbolCodec::Huffman, levels, Some(probs)).unwrap(),
        ]
    }

    fn gaussian_probs(levels: &Levels, d: usize) -> Vec<f64> {
        let mut stats = SufficientStats::new(256, 2);
        let mut rng = Rng::seed_from(31);
        for _ in 0..8 {
            let g = rng.gaussian_vec(d, 1.0);
            stats.observe(&g);
        }
        symbol_probs(&stats, levels)
    }

    #[test]
    fn roundtrip_exact_all_codecs() {
        let levels = Levels::uniform(14);
        let probs = gaussian_probs(&levels, 512);
        let mut rng = Rng::seed_from(1);
        let v = rng.gaussian_vec(512, 1.0);
        let qv = quantize(&v, &levels, 2, 128, &mut rng).unwrap();
        for codec in all_codecs(&levels, &probs) {
            let (bytes, bits) = encode_vector(&qv, &codec).unwrap();
            assert!(bits as usize <= bytes.len() * 8);
            let back = decode_vector(&bytes, 512, 128, &codec).unwrap();
            assert_eq!(qv, back, "codec {:?}", codec.kind);
            // Dequantized values identical too.
            assert_eq!(dequantize(&qv, &levels), dequantize(&back, &levels));
        }
    }

    #[test]
    fn huffman_beats_fixed_on_skewed_gradients() {
        // Gaussian coordinates at large d are overwhelmingly near zero ->
        // low symbols dominate -> Huffman/Elias crush fixed-width.
        let levels = Levels::uniform(14);
        let d = 4096;
        let probs = gaussian_probs(&levels, d);
        let mut rng = Rng::seed_from(2);
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
        let fixed = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let huff = WireCodec::new(SymbolCodec::Huffman, &levels, Some(&probs)).unwrap();
        let (_, bits_fixed) = encode_vector(&qv, &fixed).unwrap();
        let (_, bits_huff) = encode_vector(&qv, &huff).unwrap();
        assert!(
            (bits_huff as f64) < 0.75 * bits_fixed as f64,
            "huffman {bits_huff} vs fixed {bits_fixed}"
        );
    }

    #[test]
    fn wire_is_far_smaller_than_fp32() {
        let levels = Levels::uniform(14); // UQ4
        let d = 1 << 14;
        let mut rng = Rng::seed_from(3);
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, &levels, 2, 1024, &mut rng).unwrap();
        let fixed = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let (bytes, _) = encode_vector(&qv, &fixed).unwrap();
        let fp32_bytes = 4 * d;
        assert!(
            bytes.len() * 2 < fp32_bytes,
            "wire {} should be well under fp32 {}",
            bytes.len(),
            fp32_bytes
        );
    }

    #[test]
    fn empty_bucket_encodes_compactly() {
        let levels = Levels::uniform(3);
        let v = vec![0.0f32; 256];
        let mut rng = Rng::seed_from(4);
        let qv = quantize(&v, &levels, 2, 64, &mut rng).unwrap();
        let codec = WireCodec::new(SymbolCodec::Fixed, &levels, None).unwrap();
        let (bytes, bits) = encode_vector(&qv, &codec).unwrap();
        // 4 buckets * 32-bit norms only.
        assert_eq!(bits, 4 * 32);
        let back = decode_vector(&bytes, 256, 64, &codec).unwrap();
        assert!(dequantize(&back, &levels).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn truncated_wire_is_error() {
        let levels = Levels::uniform(7);
        let mut rng = Rng::seed_from(5);
        let v = rng.gaussian_vec(64, 1.0);
        let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
        let codec = WireCodec::new(SymbolCodec::EliasGamma, &levels, None).unwrap();
        let (bytes, _) = encode_vector(&qv, &codec).unwrap();
        let cut = &bytes[..bytes.len() / 2];
        assert!(decode_vector(cut, 64, 0, &codec).is_err());
    }

    #[test]
    fn huffman_requires_probs() {
        let levels = Levels::uniform(3);
        assert!(WireCodec::new(SymbolCodec::Huffman, &levels, None).is_err());
        assert!(WireCodec::new(SymbolCodec::Huffman, &levels, Some(&[0.5, 0.5])).is_err());
    }

    #[test]
    fn expected_symbol_bits_tracks_measured() {
        let levels = Levels::uniform(14);
        let d = 8192;
        let probs = gaussian_probs(&levels, d);
        let mut rng = Rng::seed_from(6);
        let v = rng.gaussian_vec(d, 1.0);
        let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
        for codec in all_codecs(&levels, &probs) {
            let (_, bits) = encode_vector(&qv, &codec).unwrap();
            let nonzeros = d - qv.num_zeros();
            let predicted = 32.0 + codec.expected_symbol_bits(&probs) * d as f64 + nonzeros as f64;
            let measured = bits as f64;
            assert!(
                (measured - predicted).abs() / predicted < 0.15,
                "codec {:?}: measured {measured} predicted {predicted}",
                codec.kind
            );
        }
    }

    #[test]
    fn prop_roundtrip_random_everything() {
        forall("wire roundtrip", 60, |g| {
            let s = g.usize_in(1, 40);
            let levels = Levels::new(g.levels(s)).unwrap();
            let d = g.usize_in(1, 400);
            let bucket = *g.choose(&[0usize, 3, 50, 333]);
            let v = g.f32_vec(d, -3.0, 3.0);
            let uniforms: Vec<f32> = (0..d).map(|_| g.f32_in(0.0, 1.0)).collect();
            let qv = crate::quant::quantize_with_uniforms(&v, &levels, 2, bucket, &uniforms)
                .unwrap();
            let kinds = [SymbolCodec::Fixed, SymbolCodec::EliasGamma, SymbolCodec::EliasDelta];
            let kind = *g.choose(&kinds);
            let codec = WireCodec::new(kind, &levels, None).unwrap();
            let (bytes, _) = encode_vector(&qv, &codec).unwrap();
            let back = decode_vector(&bytes, d, bucket, &codec).unwrap();
            assert_eq!(qv, back);
        });
    }
}
