//! E11 — §3.1 unified variants (Examples 3.1–3.3): Quantized DA vs DE vs
//! OptDA on the same problem, same budget. Also reports communication
//! rounds — OptDA does one exchange per iteration (it reuses the previous
//! half-step query), DE does two.

use qgenx::benchkit::{scaled, Table};
use qgenx::config::{ExperimentConfig, Variant};
use qgenx::coordinator::run_experiment;

fn main() {
    println!("== E11 / §3.1: unified Q-GenX variants (DA / DE / OptDA) ==\n");
    let mut table = Table::new(&[
        "variant", "problem", "final gap", "final dist", "rounds", "total bits",
    ]);
    let mut csv = Vec::new();
    for problem in ["quadratic", "bilinear"] {
        for variant in
            [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging]
        {
            let mut cfg = ExperimentConfig::default();
            cfg.problem.kind = problem.into();
            cfg.problem.dim = 64;
            cfg.problem.noise = "absolute".into();
            cfg.problem.sigma = 0.5;
            cfg.workers = 3;
            cfg.iters = scaled(3000, 400);
            cfg.eval_every = cfg.iters;
            cfg.algo.variant = variant;
            cfg.algo.gamma0 = 0.3;
            cfg.seed = 33;
            let rec = run_experiment(&cfg).unwrap();
            let row = vec![
                variant.name().to_string(),
                problem.to_string(),
                format!("{:.5}", rec.get("gap").unwrap().last().unwrap()),
                format!("{:.5}", rec.get("dist").unwrap().last().unwrap()),
                format!("{:.0}", rec.scalar("rounds").unwrap()),
                format!("{:.2e}", rec.scalar("total_bits").unwrap()),
            ];
            table.row(&row);
            csv.push(row);
        }
    }
    table.print();
    println!("\nshape: DE and OptDA handle the skew (bilinear) problem; OptDA matches DE's");
    println!("quality with half the exchanges; DA is competitive only on the potential problem.");
    qgenx::benchkit::write_csv(
        "results/abl_variants.csv",
        &["variant", "problem", "final_gap", "final_dist", "rounds", "total_bits"],
        &csv,
    )
    .unwrap();
    println!("csv -> results/abl_variants.csv");
}
