//! `ExchangePolicy`: the per-iteration protocol of one runner family,
//! expressed against the [`super::engine::RoundEngine`] primitives.
//!
//! The seed implemented Algorithm 1 six times — exact, gossip and local
//! loops, hand-copied for the inline and threaded coordinators, plus a
//! QSGDA baseline with its own exchange loop. Each family is now **one**
//! implementation, driven by the [`crate::coordinator::Session`] state
//! machine over either fabric:
//!
//! * `ExactPolicy` — per-step dual exchange over an exact topology; one
//!   replica state (shared under loopback, replicated per rank under
//!   transport — identical decoded views keep them bit-identical).
//! * `GossipPolicy` — per-step dual exchange averaged over closed graph
//!   neighborhoods; one genuinely distinct replica per owned rank.
//! * `LocalPolicy` — `H` private extra-gradient iterations per replica
//!   between quantized model-delta syncs (`local.steps ≥ 2`), composing
//!   with both exact and gossip delta averaging.
//! * `SgdaPolicy` — the QSGDA comparator (Beznosikov et al. 2022) as an
//!   *algorithm policy* over the same engine: one exchange per iteration
//!   at `X_t`, `γ_t = γ₀/√t`, no extrapolation, no stat rounds — not a
//!   fourth hand-rolled runner. Always accounted as a full-mesh round
//!   (the Figure-4 comparison baseline ignores `[topo]`, as the seed did).
//!
//! Metric parity: each policy records exactly the series/scalars its
//! pre-Session runner recorded — the loopback fabric reproduces the inline
//! runner's recorder, transport rank 0 the threaded runner's — so the
//! wrappers in [`super::inline`] / [`super::threaded`] are bit-compatible
//! with the seed (regression-tested in `tests/session_parity.rs`).

use super::engine::{Query, RoundEngine};
use super::session::StepReport;
use crate::algo::{method_state, LocalQGenX, MethodState, Sgda};
use crate::config::{ExperimentConfig, Method};
use crate::error::Result;
use crate::metrics::{consensus_distance, Recorder, SyncAccounting};
use crate::oracle::GapEvaluator;
use crate::telemetry::Stage;
use std::time::Instant;

/// One runner family's per-iteration protocol (see module docs).
pub(crate) trait ExchangePolicy: Send {
    /// Advance one iteration (`t` is 1-based; `last` marks `t == iters`).
    fn step(
        &mut self,
        t: usize,
        last: bool,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()>;

    /// Record the eval-step metrics (called when `t % eval_every == 0` or
    /// on the last iteration). Under the transport fabric this may run a
    /// diagnostic barrier — every rank evaluates at the same steps.
    fn eval(
        &mut self,
        t: usize,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()>;

    /// Emit the end-of-run summary scalars.
    fn finish(&mut self, eng: &mut RoundEngine, rec: &mut Recorder) -> Result<()>;

    /// Current adaptive step-size γ_t.
    fn gamma(&self) -> f64;

    /// This endpoint's final replica state — the quantity the threaded
    /// replication invariant compares (sync bases for the local family).
    fn replica(&self) -> Vec<f32>;

    fn clone_box(&self) -> Box<dyn ExchangePolicy>;
}

/// The inline runners' summary scalar set (loopback fabric).
fn emit_loopback_summary(rec: &mut Recorder, eng: &RoundEngine) {
    rec.set_scalar("total_bits", eng.traffic.bits_sent as f64);
    rec.set_scalar("bits_per_round_per_worker", eng.traffic.bits_per_round_per_worker(eng.k));
    rec.set_scalar("sim_net_time", eng.traffic.sim_net_time);
    rec.set_scalar("compute_time", eng.traffic.compute_time);
    rec.set_scalar("rounds", eng.traffic.rounds as f64);
    rec.set_scalar("level_updates", eng.comps[0].updates() as f64);
    rec.set_scalar("epsilon_q", eng.comps[0].epsilon_q(eng.d));
    rec.set_scalar("wire_links", eng.links.links() as f64);
    rec.set_scalar("max_link_bytes", eng.links.max_link_bytes());
    if eng.rewires > 0 {
        rec.set_scalar("rewires", eng.rewires as f64);
    }
    eng.comps[0].emit_layer_scalars(rec);
    eng.comps[0].emit_ef_scalars(rec);
}

/// The threaded workers' rank-0 summary scalar set (transport fabric).
fn emit_transport_summary(rec: &mut Recorder, eng: &RoundEngine) {
    rec.set_scalar("total_bits", eng.traffic.bits_sent as f64);
    rec.set_scalar("rounds", eng.traffic.rounds as f64);
    rec.set_scalar("level_updates", eng.comps[0].updates() as f64);
    rec.set_scalar("sim_net_time", eng.traffic.sim_net_time);
    rec.set_scalar("compute_time", eng.traffic.compute_time);
    rec.set_scalar("wire_links", eng.links.links() as f64);
    rec.set_scalar("max_link_bytes", eng.links.max_link_bytes());
    if eng.rewires > 0 {
        rec.set_scalar("rewires", eng.rewires as f64);
    }
    eng.comps[0].emit_layer_scalars(rec);
    eng.comps[0].emit_ef_scalars(rec);
}

/// Per-method cadence scalars (`oracle_calls`, `exchanges_per_step`, plus
/// method-specific diagnostics). Emitted ONLY off the default method: the
/// frozen parity suite pins the default recorder's scalar name *set*, and
/// the refactor must be invisible there.
fn emit_method_summary(rec: &mut Recorder, method: Method, state: &dyn MethodState) {
    if method == Method::QGenX {
        return;
    }
    rec.set_scalar("oracle_calls", state.oracle_calls() as f64);
    rec.set_scalar("exchanges_per_step", state.exchanges_per_step());
    for (name, v) in state.method_scalars() {
        rec.set_scalar(name, v);
    }
}

fn gap_eval_for(eng: &RoundEngine) -> Option<GapEvaluator> {
    if eng.is_metrics_rank() {
        GapEvaluator::around_solution(eng.op.as_ref(), 2.0)
    } else {
        None
    }
}

/// Push the shared per-eval diagnostics (γ_t, cumulative bits/time, layer
/// series) on the metrics rank.
fn push_step_diagnostics(rec: &mut Recorder, eng: &RoundEngine, tf: f64, gamma: f64) {
    rec.push("gamma", tf, gamma);
    rec.push("bits_cum", tf, eng.traffic.bits_sent as f64);
    rec.push("sim_time_cum", tf, eng.traffic.total_time());
    eng.comps[0].record_layer_series(rec, tf);
    eng.comps[0].record_ef_series(rec, tf);
}

// ---------------------------------------------------------------- exact --

/// Exact topologies: every rank consumes all `K` decoded duals, so one
/// method replica per endpoint stays bit-identical everywhere. The
/// replica is whatever [`crate::config::Method`] selects behind the
/// cadence seam; the policy just executes its round-plan — a `None` base
/// query skips the base exchange entirely (the single-call cadence).
#[derive(Clone)]
pub(crate) struct ExactPolicy {
    state: Box<dyn MethodState>,
    method: Method,
    gap_eval: Option<GapEvaluator>,
}

impl ExactPolicy {
    pub(crate) fn new(cfg: &ExperimentConfig, eng: &RoundEngine) -> Self {
        let x0 = vec![0.0f32; eng.d];
        // recv[0] is all K under exact topologies — the replica averages
        // every worker's dual, in both fabrics.
        let state = method_state(&cfg.algo, &x0, eng.recv[0].len());
        ExactPolicy { state, method: cfg.algo.method, gap_eval: gap_eval_for(eng) }
    }
}

impl ExchangePolicy for ExactPolicy {
    fn step(
        &mut self,
        t: usize,
        _last: bool,
        eng: &mut RoundEngine,
        _rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        rep.level_update = eng.maybe_per_step_stat(t)?;
        // The decode buffer is consumed by reference, as the seed runner
        // did — no per-iteration K×d clone on the hottest loop.
        let x_half = if let Some(xq) = self.state.base_query() {
            eng.dual_exchange(Query::Shared(&xq))?;
            let c = eng.tele.clock();
            let xh = self.state.extrapolate(&eng.decoded)?;
            eng.tele.lap(c, Stage::Apply);
            xh
        } else {
            let c = eng.tele.clock();
            let xh = self.state.extrapolate(&[])?;
            eng.tele.lap(c, Stage::Apply);
            xh
        };
        eng.dual_exchange(Query::Shared(&x_half))?;
        let c = eng.tele.clock();
        self.state.update(&eng.decoded)?;
        eng.tele.lap(c, Stage::Apply);
        Ok(())
    }

    fn eval(
        &mut self,
        t: usize,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        let tf = t as f64;
        let avg = self.state.ergodic_average();
        if let Some(ev) = &self.gap_eval {
            let gap = ev.gap(eng.op.as_ref(), &avg);
            let dist = ev.dist_to_center(&avg);
            rec.push("gap", tf, gap);
            rec.push("dist", tf, dist);
            rep.gap = Some(gap);
            rep.dist = Some(dist);
        }
        if eng.is_loopback() {
            let res = eng.op.residual(&avg);
            rec.push("residual", tf, res);
            rep.residual = Some(res);
        }
        if eng.is_metrics_rank() {
            push_step_diagnostics(rec, eng, tf, self.state.gamma());
        }
        Ok(())
    }

    fn finish(&mut self, eng: &mut RoundEngine, rec: &mut Recorder) -> Result<()> {
        if eng.is_loopback() {
            emit_loopback_summary(rec, eng);
        } else if eng.is_metrics_rank() {
            emit_transport_summary(rec, eng);
        }
        if eng.is_metrics_rank() {
            emit_method_summary(rec, self.method, self.state.as_ref());
        }
        Ok(())
    }

    fn gamma(&self) -> f64 {
        self.state.gamma()
    }

    fn replica(&self) -> Vec<f32> {
        self.state.x_world()
    }

    fn clone_box(&self) -> Box<dyn ExchangePolicy> {
        Box::new(self.clone())
    }
}

// --------------------------------------------------------------- gossip --

/// Inexact (gossip) topologies: one genuinely distinct replica per owned
/// rank, each averaging duals over its closed neighborhood only. Level
/// updates stay global (the wire format needs identical codecs), so the
/// control plane pools full-mesh while the data plane gossips.
#[derive(Clone)]
pub(crate) struct GossipPolicy {
    states: Vec<Box<dyn MethodState>>,
    method: Method,
    gap_eval: Option<GapEvaluator>,
}

impl GossipPolicy {
    pub(crate) fn new(cfg: &ExperimentConfig, eng: &RoundEngine) -> Self {
        let x0 = vec![0.0f32; eng.d];
        let states =
            eng.recv.iter().map(|n| method_state(&cfg.algo, &x0, n.len())).collect();
        GossipPolicy { states, method: cfg.algo.method, gap_eval: gap_eval_for(eng) }
    }
}

impl ExchangePolicy for GossipPolicy {
    fn step(
        &mut self,
        t: usize,
        _last: bool,
        eng: &mut RoundEngine,
        _rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        rep.level_update = eng.maybe_per_step_stat(t)?;
        // Base exchange: each replica queries at its *own* iterate. A
        // `None` base query (single-call cadence) skips the round for
        // every replica — the method is uniform across them.
        let base_views: Vec<Vec<Vec<f32>>> = if self.states[0].base_query().is_some() {
            let queries: Vec<Vec<f32>> =
                self.states.iter().map(|s| s.base_query().expect("uniform cadence")).collect();
            eng.dual_exchange(Query::PerOwned(&queries))?;
            (0..self.states.len()).map(|i| eng.view_of(i)).collect()
        } else {
            vec![Vec::new(); self.states.len()]
        };
        let c = eng.tele.clock();
        let x_halves: Vec<Vec<f32>> = self
            .states
            .iter_mut()
            .zip(base_views.iter())
            .map(|(s, v)| s.extrapolate(v))
            .collect::<Result<_>>()?;
        eng.tele.lap(c, Stage::Apply);
        eng.dual_exchange(Query::PerOwned(&x_halves))?;
        let c = eng.tele.clock();
        for (i, s) in self.states.iter_mut().enumerate() {
            s.update(&eng.view_of(i))?;
        }
        eng.tele.lap(c, Stage::Apply);
        Ok(())
    }

    fn eval(
        &mut self,
        t: usize,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        let tf = t as f64;
        let pairs: Vec<(Vec<f32>, Vec<f32>)> =
            self.states.iter().map(|s| (s.x_world(), s.ergodic_average())).collect();
        if let Some((iterates, mean_avg)) = eng.cross_view(&pairs)? {
            if let Some(ev) = &self.gap_eval {
                let gap = ev.gap(eng.op.as_ref(), &mean_avg);
                let dist = ev.dist_to_center(&mean_avg);
                rec.push("gap", tf, gap);
                rec.push("dist", tf, dist);
                rep.gap = Some(gap);
                rep.dist = Some(dist);
            }
            if eng.is_loopback() {
                let res = eng.op.residual(&mean_avg);
                rec.push("residual", tf, res);
                rep.residual = Some(res);
            }
            let cons = consensus_distance(&iterates);
            rec.push("consensus_dist", tf, cons);
            rep.consensus = Some(cons);
        }
        if eng.is_metrics_rank() {
            push_step_diagnostics(rec, eng, tf, self.states[0].gamma());
        }
        Ok(())
    }

    fn finish(&mut self, eng: &mut RoundEngine, rec: &mut Recorder) -> Result<()> {
        if eng.is_loopback() {
            // bits_per_round_per_worker stays the mesh-normalized yardstick
            // of Theorems 3/4, plus the consensus scalar only this family
            // produces (transport: the run_threaded wrapper sets it from
            // the collected replicas, as the seed did).
            let final_iterates: Vec<Vec<f32>> = self.states.iter().map(|s| s.x_world()).collect();
            emit_loopback_summary(rec, eng);
            rec.set_scalar("consensus_dist", consensus_distance(&final_iterates));
        } else if eng.is_metrics_rank() {
            emit_transport_summary(rec, eng);
        }
        if eng.is_metrics_rank() {
            emit_method_summary(rec, self.method, self.states[0].as_ref());
        }
        Ok(())
    }

    fn gamma(&self) -> f64 {
        self.states[0].gamma()
    }

    fn replica(&self) -> Vec<f32> {
        self.states[0].x_world()
    }

    fn clone_box(&self) -> Box<dyn ExchangePolicy> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------- local --

/// Local-steps family (`local.steps = H ≥ 2`): `H` private extra-gradient
/// iterations per replica, then one quantized model-delta exchange and a
/// resync by (neighborhood-)averaging. See `algo::local` for the replica
/// invariances and why agreement is asserted on sync bases.
///
/// With `local.straggler_rate > 0` the sync becomes **bounded-staleness
/// semi-async**: a seeded per-(step, worker) draw models which senders
/// miss the sync deadline; their *previous* delta is carried forward
/// instead (up to `local.staleness` consecutive substitutions, after
/// which the sync falls back to the blocking barrier and uses the fresh
/// delta). The physical exchange is unchanged — the deadline is modeled,
/// so every rank makes the identical substitution decision and runs stay
/// bit-for-bit reproducible. `straggler_rate = 0` (default) skips the
/// whole path: no allocations, no RNG draws, bit-identical to the
/// fully-synchronous family.
#[derive(Clone)]
pub(crate) struct LocalPolicy {
    reps: Vec<LocalQGenX>,
    method: Method,
    sync_acc: SyncAccounting,
    gap_eval: Option<GapEvaluator>,
    h: usize,
    /// Max consecutive stale substitutions per sender before blocking.
    staleness: usize,
    /// Modeled probability a sender misses each sync deadline.
    straggler_rate: f64,
    /// Seed for the per-(step, worker) deadline draws.
    fault_seed: u64,
    /// Last fresh delta seen from each worker (only workers this endpoint
    /// actually receives from are ever populated).
    carried: Vec<Option<Vec<f32>>>,
    /// Consecutive substitutions per worker since its last fresh delta.
    missed: Vec<u32>,
}

impl LocalPolicy {
    pub(crate) fn new(cfg: &ExperimentConfig, eng: &RoundEngine) -> Self {
        let x0 = vec![0.0f32; eng.d];
        let reps = eng.owned.iter().map(|_| LocalQGenX::from_algo(&cfg.algo, &x0)).collect();
        LocalPolicy {
            reps,
            method: cfg.algo.method,
            sync_acc: SyncAccounting::new(),
            gap_eval: gap_eval_for(eng),
            h: cfg.local.steps,
            staleness: cfg.local.staleness,
            straggler_rate: cfg.local.straggler_rate,
            fault_seed: cfg.seed ^ 0x5354_414c_455f_5351,
            carried: vec![None; eng.k],
            missed: vec![0; eng.k],
        }
    }

    /// Decide this sync's stale substitutions (straggler model; see the
    /// type docs). Returns the per-worker substitution mask, or `None`
    /// when the semi-async path is disabled. Updates `carried`/`missed`
    /// and emits `stale` fault telemetry for each substitution.
    fn stale_mask(&mut self, t: usize, eng: &mut RoundEngine) -> Option<Vec<bool>> {
        if self.straggler_rate <= 0.0 {
            return None;
        }
        // Workers this endpoint receives from (union over owned replicas —
        // all K under loopback/exact, the closed neighborhood per rank
        // under gossip+transport). Carried deltas exist only for these.
        let mut received = vec![false; eng.k];
        for n in &eng.recv {
            for &w in n {
                received[w] = true;
            }
        }
        let mut mask = vec![false; eng.k];
        let mut stale_now = 0u64;
        for (w, slot) in mask.iter_mut().enumerate() {
            if !received[w] {
                continue;
            }
            // One seeded draw per (sync step, sender): identical on every
            // rank, so all endpoints substitute the same senders.
            let mut s = self.fault_seed ^ ((t as u64) << 20) ^ w as u64;
            let u = (crate::util::rng::splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64;
            let straggles = u < self.straggler_rate;
            if straggles && (self.missed[w] as usize) < self.staleness && self.carried[w].is_some()
            {
                *slot = true;
                self.missed[w] += 1;
                stale_now += 1;
                eng.tele.on_fault("stale", w, t as u64);
            } else {
                // Fresh delta arrived in time (or the staleness cap forced
                // the blocking barrier): adopt it and reset the debt.
                self.carried[w] = Some(eng.decoded[w].clone());
                self.missed[w] = 0;
            }
        }
        if stale_now > 0 {
            self.sync_acc.add_stale(stale_now);
        }
        Some(mask)
    }
}

impl ExchangePolicy for LocalPolicy {
    fn step(
        &mut self,
        t: usize,
        last: bool,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        // (1) One private extra-gradient iteration per owned replica.
        let t0 = Instant::now();
        for (i, r) in self.reps.iter_mut().enumerate() {
            eng.local_round(i, r)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        eng.traffic.add_compute(dt);
        eng.tele.span_secs(Stage::Apply, dt);

        // (2) Delta synchronization every H iterations (plus a final sync
        //     so the run always ends on a consensus point).
        if t % self.h == 0 || last {
            rep.synced = true;
            let deltas: Vec<Vec<f32>> = self.reps.iter().map(|r| r.delta()).collect();
            let round_bits = eng.vector_exchange(&deltas)?;

            if eng.is_metrics_rank() {
                // Pre-averaging drift. Loopback measures the raw iterates;
                // transport rank 0 measures the *decoded* deltas it already
                // holds (no extra barrier; common sync base cancels) — the
                // same split the seed's two local runners had.
                let drift = if eng.is_loopback() {
                    let iterates: Vec<Vec<f32>> = self.reps.iter().map(|r| r.x_world()).collect();
                    consensus_distance(&iterates)
                } else {
                    consensus_distance(&eng.view_of(0))
                };
                self.sync_acc.record(rec, t, drift, round_bits);
            }

            // Bounded-staleness deadline model: which senders' deltas are
            // replaced by their carried (stale) predecessor this sync.
            let stale = self.stale_mask(t, eng);

            // Resync each replica onto its neighborhood-averaged delta
            // (all K under exact topologies), substituting carried deltas
            // for modeled stragglers.
            let c = eng.tele.clock();
            for (i, r) in self.reps.iter_mut().enumerate() {
                let n = &eng.recv[i];
                let mut mean = vec![0.0f32; eng.d];
                for &w in n {
                    let src: &[f32] = match (&stale, &self.carried[w]) {
                        (Some(mask), Some(old)) if mask[w] => old,
                        _ => &eng.decoded[w],
                    };
                    for (m, &x) in mean.iter_mut().zip(src.iter()) {
                        *m += x / n.len() as f32;
                    }
                }
                r.resync(&mean)?;
            }
            eng.tele.lap(c, Stage::Apply);

            // Control plane: pooled stat exchange at the first sync on or
            // after each due point.
            rep.level_update = eng.maybe_local_stat(t)?;
        }
        Ok(())
    }

    fn eval(
        &mut self,
        t: usize,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        let tf = t as f64;
        let pairs: Vec<(Vec<f32>, Vec<f32>)> =
            self.reps.iter().map(|r| (r.x_world(), r.ergodic_average())).collect();
        if let Some((iterates, mean_avg)) = eng.cross_view(&pairs)? {
            if let Some(ev) = &self.gap_eval {
                let gap = ev.gap(eng.op.as_ref(), &mean_avg);
                let dist = ev.dist_to_center(&mean_avg);
                rec.push("gap", tf, gap);
                rec.push("dist", tf, dist);
                rep.gap = Some(gap);
                rep.dist = Some(dist);
            }
            if eng.is_loopback() {
                let res = eng.op.residual(&mean_avg);
                rec.push("residual", tf, res);
                rep.residual = Some(res);
            }
            let cons = consensus_distance(&iterates);
            rec.push("consensus_dist", tf, cons);
            rep.consensus = Some(cons);
        }
        if eng.is_metrics_rank() {
            push_step_diagnostics(rec, eng, tf, self.reps[0].gamma());
        }
        Ok(())
    }

    fn finish(&mut self, eng: &mut RoundEngine, rec: &mut Recorder) -> Result<()> {
        if eng.is_loopback() {
            // Final consensus over the *sync bases*: the run ends on a
            // sync, and the consensus point is computed by identical
            // arithmetic on every replica (see `algo::local`).
            let bases: Vec<Vec<f32>> = self.reps.iter().map(|r| r.sync_base().to_vec()).collect();
            emit_loopback_summary(rec, eng);
            self.sync_acc.emit_scalars(rec);
            rec.set_scalar("local_steps", self.h as f64);
            rec.set_scalar("consensus_dist", consensus_distance(&bases));
        } else if eng.is_metrics_rank() {
            emit_transport_summary(rec, eng);
            rec.set_scalar("local_steps", self.h as f64);
            self.sync_acc.emit_scalars(rec);
        }
        // The local family exchanges model deltas every H steps, not
        // per-iteration duals, so `exchanges_per_step` does not apply
        // (sync cadence is already reported by `syncs`) — only the
        // method's oracle-call count is meaningful here.
        if eng.is_metrics_rank() && self.method != Method::QGenX {
            rec.set_scalar("oracle_calls", self.reps[0].oracle_calls() as f64);
        }
        Ok(())
    }

    fn gamma(&self) -> f64 {
        self.reps[0].gamma()
    }

    fn replica(&self) -> Vec<f32> {
        self.reps[0].sync_base().to_vec()
    }

    fn clone_box(&self) -> Box<dyn ExchangePolicy> {
        Box::new(self.clone())
    }
}

// ----------------------------------------------------------------- sgda --

/// QSGDA baseline (Beznosikov et al. 2022): quantized SGDA with
/// `γ_t = γ₀/√t` — same oracles/compressors/network, only the update rule
/// differs (no extrapolation, no adaptive step, no stat rounds). The
/// Figure-4 comparator, always accounted as a full-mesh round.
#[derive(Clone)]
pub(crate) struct SgdaPolicy {
    sgda: Sgda,
    gap_eval: Option<GapEvaluator>,
}

impl SgdaPolicy {
    pub(crate) fn new(cfg: &ExperimentConfig, eng: &RoundEngine) -> Self {
        let x0 = vec![0.0f32; eng.d];
        SgdaPolicy { sgda: Sgda::new(&x0, cfg.algo.gamma0, true), gap_eval: gap_eval_for(eng) }
    }
}

impl ExchangePolicy for SgdaPolicy {
    fn step(
        &mut self,
        _t: usize,
        _last: bool,
        eng: &mut RoundEngine,
        _rec: &mut Recorder,
        _rep: &mut StepReport,
    ) -> Result<()> {
        let xq = self.sgda.query();
        eng.dual_exchange(Query::Shared(&xq))?;
        let c = eng.tele.clock();
        self.sgda.update(&eng.decoded);
        eng.tele.lap(c, Stage::Apply);
        Ok(())
    }

    fn eval(
        &mut self,
        t: usize,
        eng: &mut RoundEngine,
        rec: &mut Recorder,
        rep: &mut StepReport,
    ) -> Result<()> {
        if !eng.is_metrics_rank() {
            return Ok(());
        }
        let tf = t as f64;
        let avg = self.sgda.ergodic_average();
        if let Some(ev) = &self.gap_eval {
            let gap = ev.gap(eng.op.as_ref(), &avg);
            let dist = ev.dist_to_center(&avg);
            rec.push("gap", tf, gap);
            rec.push("dist", tf, dist);
            rec.push("dist_last", tf, ev.dist_to_center(self.sgda.x()));
            rep.gap = Some(gap);
            rep.dist = Some(dist);
        }
        if eng.is_loopback() {
            let res = eng.op.residual(&avg);
            rec.push("residual", tf, res);
            rep.residual = Some(res);
        }
        rec.push("bits_cum", tf, eng.traffic.bits_sent as f64);
        Ok(())
    }

    fn finish(&mut self, eng: &mut RoundEngine, rec: &mut Recorder) -> Result<()> {
        // Deliberately the seed baseline's single scalar: keeping the
        // `--qsgda` CLI/bench output identical is part of the fold-in
        // contract.
        if eng.is_metrics_rank() {
            rec.set_scalar("total_bits", eng.traffic.bits_sent as f64);
        }
        Ok(())
    }

    fn gamma(&self) -> f64 {
        self.sgda.gamma()
    }

    fn replica(&self) -> Vec<f32> {
        self.sgda.x().to_vec()
    }

    fn clone_box(&self) -> Box<dyn ExchangePolicy> {
        Box::new(self.clone())
    }
}
