//! E2 — Figure 1 (middle/right) + Figure 3: the backward-time breakdown
//! table — average GenBP / DiscBP / PenBP / Total per mode, where the
//! "backward" total includes gradient exchange (that is where DDP does its
//! communication in the paper's measurement).
//!
//! Paper's 3×V100 numbers for reference (seconds):
//!   UQ4  2.99 / 7.40 / 1.59 / 12.96
//!   UQ8  2.99 / 7.65 / 1.69 / 13.29
//!   FP32 3.00 / 8.36 / 1.69 / 14.05
//!
//! Shape to reproduce: GenBP/PenBP ≈ constant across modes (compute-bound),
//! DiscBP+comm shrinks with compression, Total(UQ4) < Total(UQ8) < Total(FP32)
//! with a ~8% total saving at the paper's scale.

use qgenx::benchkit::{scaled, Table};
use qgenx::net::NetModel;
use qgenx::runtime::{default_artifacts_dir, Runtime};
use qgenx::train::{GanMode, GanTrainConfig, GanTrainer};

fn main() {
    println!("== E2 / Figure 1 (mid/right) + Figure 3: backward-time breakdown ==\n");
    let Some(dir) = default_artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    let steps = scaled(40, 8);

    // Measure each mode; the backward phases (GenBP/DiscBP/PenBP) are
    // mode-independent by construction (the compressor never touches the
    // model graph), so we pool them across modes and attribute only the
    // comm term per mode — this removes the ±15% run-to-run HLO-exec noise
    // on this 1-core box that would otherwise swamp the comm delta.
    let mut raw = Vec::new();
    for mode in [GanMode::Uq4, GanMode::Uq8, GanMode::Fp32] {
        let cfg = GanTrainConfig {
            mode,
            steps,
            workers: 3,
            eval_every: steps + 1, // skip metric evals: pure timing
            ..Default::default()
        };
        let mut tr = GanTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
        // warmup: pay XLA compilation + cache fill outside the measurement
        for _ in 0..2 {
            tr.step().unwrap();
        }
        tr.reset_counters();
        for _ in 0..steps {
            tr.step().unwrap();
        }
        let n = tr.phases.steps as f64;
        let (g, d, p, _) = tr.phases.averages();
        raw.push((mode, g, d, p, tr.phases.comm / n));
    }
    let nm = raw.len() as f64;
    let g_shared: f64 = raw.iter().map(|r| r.1).sum::<f64>() / nm;
    let d_shared: f64 = raw.iter().map(|r| r.2).sum::<f64>() / nm;
    let p_shared: f64 = raw.iter().map(|r| r.3).sum::<f64>() / nm;

    let mut table =
        Table::new(&["Mode", "GenBP (ms)", "DiscBP (ms)", "PenBP (ms)", "Comm (ms)", "Total (ms)"]);
    let mut csv = Vec::new();
    let mut totals = Vec::new();
    for (mode, _, _, _, comm) in &raw {
        let tot = g_shared + d_shared + p_shared + comm;
        let row = vec![
            mode.name().to_string(),
            format!("{:.2}", g_shared * 1e3),
            format!("{:.2}", d_shared * 1e3),
            format!("{:.2}", p_shared * 1e3),
            format!("{:.2}", comm * 1e3),
            format!("{:.2}", tot * 1e3),
        ];
        table.row(&row);
        csv.push(row);
        totals.push((*mode, tot));
    }
    table.print();

    let t_uq4 = totals[0].1;
    let t_uq8 = totals[1].1;
    let t_fp32 = totals[2].1;
    println!(
        "\ntotal-time savings vs FP32: UQ4 {:.1}%, UQ8 {:.1}%  (paper: ~8% on 3xV100/Ethernet)",
        (1.0 - t_uq4 / t_fp32) * 100.0,
        (1.0 - t_uq8 / t_fp32) * 100.0
    );
    assert!(t_uq4 < t_fp32, "UQ4 total must beat FP32: {t_uq4} vs {t_fp32}");
    // UQ8 is marginal in the paper too (5.4% saving on 3xV100); on this
    // 1-core box the CPU decode of 8-bit symbols can eat the network
    // saving, so we report it rather than assert a win.
    if t_uq8 > t_fp32 {
        println!(
            "note: UQ8 total exceeds FP32 here — the Rust symbol decode at ~200 MB/s \
             outweighs the modeled 1GbE saving at this model size (paper's CUDA codec \
             is effectively free). UQ4 still wins outright."
        );
    }

    qgenx::benchkit::write_csv(
        "results/fig1_backprop_table.csv",
        &["mode", "gen_bp_ms", "disc_bp_ms", "pen_bp_ms", "comm_ms", "total_ms"],
        &csv,
    )
    .unwrap();
    println!("csv -> results/fig1_backprop_table.csv");
}
