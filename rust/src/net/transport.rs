//! In-process synchronous allgather for the threaded coordinator.
//!
//! `K` worker threads each deposit one payload per round and receive
//! everyone's payloads — the exact communication pattern of Algorithm 1
//! ("each processor receives stochastic dual vectors from all other
//! processors"). Payloads are `Vec<u8>` — real encoded wire bytes, so the
//! transport also measures exact per-round sizes. Topology-restricted
//! delivery (ring/star/tree/gossip) is layered on top by
//! [`crate::topo::Collective`], which uses this full exchange as the
//! physical substrate and applies the logical delivery pattern.
//!
//! Implementation: a two-phase (deposit → read) sense-reversing barrier on
//! one mutex + condvar. A worker that panics mid-round would leave its
//! peers blocked forever with a plain `std::sync::Barrier`; instead every
//! worker holds a [`PoisonGuard`] whose `Drop` during a panic marks the
//! group poisoned and wakes all waiters, which then return
//! [`Error::Coordinator`] — the failure propagates instead of deadlocking.
//! (Clean `Err` returns don't unwind, so the coordinator additionally calls
//! [`AllGather::poison`] when a worker exits with an error.)

use crate::error::{Error, Result};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// One synchronous allgather group of `k` participants.
pub struct AllGather {
    k: usize,
    state: Mutex<State>,
    cv: Condvar,
}

struct State {
    payloads: Vec<Option<Arc<Vec<u8>>>>,
    /// Deposits received this round.
    deposited: usize,
    /// Participants that finished reading this round.
    read: usize,
    /// Round counter; readers wait on it to flip before re-entering.
    generation: u64,
    /// Set when any participant panicked; sticky.
    poisoned: bool,
}

impl AllGather {
    pub fn new(k: usize) -> Arc<Self> {
        assert!(k >= 1);
        Arc::new(AllGather {
            k,
            state: Mutex::new(State {
                payloads: vec![None; k],
                deposited: 0,
                read: 0,
                generation: 0,
                poisoned: false,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn peers(&self) -> usize {
        self.k
    }

    /// RAII handle that poisons the group if dropped during a panic.
    /// Every worker thread should hold one for the duration of its run.
    pub fn guard(self: &Arc<Self>) -> PoisonGuard {
        PoisonGuard(self.clone())
    }

    /// Mark the group poisoned and wake all waiters.
    pub fn poison(&self) {
        let mut s = self.lock();
        s.poisoned = true;
        self.cv.notify_all();
    }

    pub fn is_poisoned(&self) -> bool {
        self.lock().poisoned
    }

    /// Deposits outstanding in the current round (diagnostics/tests).
    pub fn pending_deposits(&self) -> usize {
        self.lock().deposited
    }

    /// Lock the state, surviving mutex poisoning (a panicking peer may have
    /// held the lock; our own `poisoned` flag is the source of truth).
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn wait<'a>(&self, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
        self.cv.wait(g).unwrap_or_else(|e| e.into_inner())
    }

    fn poison_err() -> Error {
        Error::Coordinator("allgather poisoned: a peer worker panicked mid-round".into())
    }

    /// Exchange: worker `rank` contributes `payload`, gets back all `k`
    /// payloads (rank-indexed, including its own). Blocks until everyone
    /// arrives. Errors on double-deposit within a round and when the group
    /// is poisoned by a peer's panic.
    pub fn exchange(&self, rank: usize, payload: Vec<u8>) -> Result<Vec<Arc<Vec<u8>>>> {
        assert!(rank < self.k);
        // Phase 1: deposit, then wait until all k deposits are in.
        let mut s = self.lock();
        if s.poisoned {
            return Err(Self::poison_err());
        }
        if s.payloads[rank].is_some() {
            return Err(Error::Coordinator(format!(
                "worker {rank} deposited twice in one round"
            )));
        }
        s.payloads[rank] = Some(Arc::new(payload));
        s.deposited += 1;
        if s.deposited == self.k {
            self.cv.notify_all();
        }
        while s.deposited < self.k && !s.poisoned {
            s = self.wait(s);
        }
        if s.poisoned {
            return Err(Self::poison_err());
        }
        let out: Vec<Arc<Vec<u8>>> =
            s.payloads.iter().map(|p| p.clone().expect("slot must be filled")).collect();
        // Phase 2: the last reader resets the slots and flips the
        // generation; everyone else waits for the flip so a fast worker's
        // next-round deposit cannot race a slow worker's read.
        s.read += 1;
        if s.read == self.k {
            s.deposited = 0;
            s.read = 0;
            for p in s.payloads.iter_mut() {
                *p = None;
            }
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            let gen = s.generation;
            while s.generation == gen && !s.poisoned {
                s = self.wait(s);
            }
            if s.poisoned {
                return Err(Self::poison_err());
            }
        }
        Ok(out)
    }
}

/// Dropping this during a panic poisons the [`AllGather`] group so peers
/// blocked in [`AllGather::exchange`] error out instead of deadlocking.
pub struct PoisonGuard(Arc<AllGather>);

impl Drop for PoisonGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn allgather_delivers_everyones_payload() {
        let k = 4;
        let ag = AllGather::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ag = ag.clone();
                thread::spawn(move || {
                    for round in 0..10u8 {
                        let payload = vec![rank as u8, round];
                        let got = ag.exchange(rank, payload).unwrap();
                        assert_eq!(got.len(), k);
                        for (r, p) in got.iter().enumerate() {
                            assert_eq!(p.as_slice(), &[r as u8, round]);
                        }
                    }
                    rank
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn single_participant_trivially_exchanges() {
        let ag = AllGather::new(1);
        let got = ag.exchange(0, vec![7, 7]).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].as_slice(), &[7, 7]);
        // and again — generations reset correctly for the next round
        let got = ag.exchange(0, vec![8]).unwrap();
        assert_eq!(got[0].as_slice(), &[8]);
    }

    #[test]
    fn payload_sizes_vary_per_round() {
        let k = 2;
        let ag = AllGather::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let ag = ag.clone();
                thread::spawn(move || {
                    for round in 1..6usize {
                        let payload = vec![rank as u8; round * (rank + 1)];
                        let got = ag.exchange(rank, payload).unwrap();
                        assert_eq!(got[0].len(), round);
                        assert_eq!(got[1].len(), round * 2);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn peer_panic_poisons_instead_of_deadlocking() {
        let k = 3;
        let ag = AllGather::new(k);
        let mut handles = Vec::new();
        // Workers 0 and 2 run normally; worker 1 panics mid-round after a
        // successful first exchange.
        for rank in [0usize, 2] {
            let ag = ag.clone();
            handles.push(thread::spawn(move || -> Result<()> {
                let _guard = ag.guard();
                ag.exchange(rank, vec![rank as u8])?;
                // Round 2: worker 1 never deposits; this must error out, not hang.
                ag.exchange(rank, vec![rank as u8])?;
                Ok(())
            }));
        }
        let crasher = {
            let ag = ag.clone();
            thread::spawn(move || {
                let _guard = ag.guard();
                ag.exchange(1, vec![1]).unwrap();
                panic!("simulated oracle failure on worker 1");
            })
        };
        assert!(crasher.join().is_err(), "crasher must panic");
        for h in handles {
            let res = h.join().expect("survivors must not panic");
            let err = res.expect_err("survivors must observe poisoning");
            assert!(err.to_string().contains("poisoned"), "got: {err}");
        }
        assert!(ag.is_poisoned());
        // Any later round fails fast.
        assert!(ag.exchange(0, vec![0]).is_err());
    }

    #[test]
    fn double_deposit_is_an_error_not_a_panic() {
        let ag = AllGather::new(2);
        let ag2 = ag.clone();
        let t = thread::spawn(move || ag2.exchange(0, vec![0]));
        // Wait until the spawned thread's rank-0 deposit has actually
        // landed (a sleep would race on a loaded machine), then deposit on
        // the same rank — must error immediately.
        while ag.pending_deposits() == 0 {
            thread::yield_now();
        }
        let err = ag.exchange(0, vec![9]).expect_err("double deposit");
        assert!(err.to_string().contains("twice"), "got: {err}");
        // Unblock the waiter so the test ends cleanly.
        let got = ag.exchange(1, vec![1]).unwrap();
        assert_eq!(got.len(), 2);
        t.join().unwrap().unwrap();
    }
}
