//! Telemetry contract suite (docs/OBSERVABILITY.md):
//!
//! * **Neutrality** — telemetry on vs off is bit-identical on every
//!   trajectory point, every deterministic scalar, every wire byte, and
//!   the final replicas, for the inline and the threaded coordinator.
//!   Exemptions mirror `session_parity.rs`: `sim_time_cum` (series) and
//!   `compute_time` (scalar) hold measured wall-clock compute.
//! * **Overhead** — a steady-state loopback data round with the default
//!   in-memory recorder performs **zero heap allocations**, asserted
//!   under the counting allocator this binary installs.
//! * **Accounting** — the recorder's wire-bit counters reconcile exactly
//!   with the traffic totals the metrics surface reports, and the JSONL
//!   stream is `manifest`, then one `step` per iteration, then `summary`.

use qgenx::benchkit::{allocs, CountingAlloc};
use qgenx::config::ExperimentConfig;
use qgenx::coordinator::{run_threaded, Algorithm, Session};
use qgenx::metrics::Recorder;
use qgenx::net::AllGather;
use qgenx::runtime::json::Json;
use qgenx::telemetry::{TelemetryConfig, TELEMETRY_SCHEMA};

// Makes `benchkit::allocs()` count for this whole test binary (the
// zero-allocation assertions below are vacuous without it).
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workers = 3;
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 16;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.3;
    cfg.quant.update_every = 60;
    cfg
}

/// Point-for-point, name-for-name equality, minus the measured-time
/// exemptions (the same contract `session_parity.rs` pins).
fn assert_recorders_match(tag: &str, off: &Recorder, on: &Recorder) {
    let ka: Vec<&String> = off.series.keys().collect();
    let kb: Vec<&String> = on.series.keys().collect();
    assert_eq!(ka, kb, "{tag}: series name sets must match");
    for (name, s) in &off.series {
        if name == "sim_time_cum" {
            continue;
        }
        let n = on.get(name).unwrap();
        assert_eq!(s.xs(), n.xs(), "{tag}/{name}: eval steps must match");
        assert_eq!(s.ys(), n.ys(), "{tag}/{name}: values must match bit-for-bit");
    }
    let sa: Vec<&String> = off.scalars.keys().collect();
    let sb: Vec<&String> = on.scalars.keys().collect();
    assert_eq!(sa, sb, "{tag}: scalar name sets must match");
    for (name, v) in &off.scalars {
        if name == "compute_time" {
            continue;
        }
        assert_eq!(*v, on.scalar(name).unwrap(), "{tag}/{name}: scalar must match");
    }
}

/// Run one inline session to completion; `telemetry` = None leaves the
/// recorder off (modulo a QGENX_TELEMETRY env override, which is neutral
/// by exactly the contract under test).
fn run_inline(
    cfg: &ExperimentConfig,
    algorithm: Algorithm,
    telemetry: Option<TelemetryConfig>,
) -> (Recorder, Vec<f32>) {
    let mut b = Session::builder(cfg.clone()).algorithm(algorithm);
    if let Some(t) = telemetry {
        b = b.telemetry(t);
    }
    let mut s = b.build().unwrap();
    s.run_to(cfg.iters).unwrap();
    let replica = s.replica();
    (s.into_recorder(), replica)
}

#[test]
fn telemetry_is_neutral_inline_across_families() {
    let exact = base_cfg();
    let mut gossip = base_cfg();
    gossip.workers = 5;
    gossip.topo.kind = "gossip".into();
    gossip.topo.degree = 2;
    let mut local = base_cfg();
    local.local.steps = 4;
    for (tag, cfg, algo) in [
        ("exact", &exact, Algorithm::QGenX),
        ("gossip", &gossip, Algorithm::QGenX),
        ("local", &local, Algorithm::QGenX),
        ("sgda", &exact, Algorithm::Sgda),
    ] {
        let (rec_off, x_off) = run_inline(cfg, algo, None);
        let (rec_on, x_on) = run_inline(cfg, algo, Some(TelemetryConfig::memory()));
        assert_recorders_match(tag, &rec_off, &rec_on);
        assert_eq!(x_off, x_on, "{tag}: replicas must match bit-for-bit");
    }
}

/// `run_threaded` with per-rank telemetry explicitly enabled — the same
/// harness shape as `coordinator::threaded`, minus the invariant checks
/// it already owns.
fn run_threaded_with_telemetry(cfg: &ExperimentConfig) -> (Recorder, Vec<Vec<f32>>) {
    let k = cfg.workers;
    let transport = AllGather::new(k);
    let handles: Vec<_> = (0..k)
        .map(|rank| {
            let cfg = cfg.clone();
            let transport = transport.clone();
            std::thread::spawn(move || {
                let mut s = Session::builder(cfg.clone())
                    .transport(transport, rank)
                    .telemetry(TelemetryConfig::memory())
                    .build()
                    .unwrap();
                s.run_to(cfg.iters).unwrap();
                let replica = s.replica();
                (s.into_recorder(), replica)
            })
        })
        .collect();
    let mut recorders = Vec::new();
    let mut replicas = Vec::new();
    for h in handles {
        let (rec, x) = h.join().unwrap();
        recorders.push(rec);
        replicas.push(x);
    }
    (recorders.swap_remove(0), replicas)
}

#[test]
fn telemetry_is_neutral_threaded() {
    let cfg = base_cfg();
    let off = run_threaded(&cfg).unwrap();
    let (rec_on, replicas_on) = run_threaded_with_telemetry(&cfg);
    assert_eq!(off.replicas, replicas_on, "threaded replicas must match bit-for-bit");
    assert_recorders_match("threaded", &off.recorder, &rec_on);
}

#[test]
fn steady_state_loopback_step_allocates_zero() {
    // Steady state: arenas sized, ring preallocated, codecs built. Stat
    // rounds and eval steps legitimately allocate (they are not data
    // rounds), so take the *minimum* allocation count over a window of
    // steps — the acceptance criterion is that plain data steps hit 0.
    let mut cfg = base_cfg();
    cfg.iters = 200;
    cfg.eval_every = 200;
    cfg.quant.update_every = 60;
    let mut s = Session::builder(cfg).telemetry(TelemetryConfig::memory()).build().unwrap();
    for _ in 0..80 {
        s.step().unwrap(); // warmup: first messages size every buffer
    }
    let mut min_allocs = u64::MAX;
    for _ in 0..40 {
        let before = allocs();
        s.step().unwrap();
        min_allocs = min_allocs.min(allocs() - before);
    }
    assert_eq!(
        min_allocs, 0,
        "a steady-state loopback data round with in-memory telemetry must not allocate"
    );
}

#[test]
fn step_reports_carry_records_and_counters_reconcile() {
    let cfg = base_cfg();
    let iters = cfg.iters;
    let mut s = Session::builder(cfg).telemetry(TelemetryConfig::memory()).build().unwrap();
    let mut rounds = 0u64;
    for _ in 0..iters {
        let rep = s.step().unwrap();
        let rec = rep.telemetry.expect("every step must carry a StepRecord");
        assert_eq!(rec.t as usize, rep.t);
        rounds += rec.rounds as u64;
    }
    let tele = s.telemetry();
    let c = *tele.counters();
    assert_eq!(c.steps, iters as u64);
    assert_eq!(c.data_rounds, rounds);
    assert_eq!(c.data_rounds, 2 * iters as u64, "exact family: 2 data rounds per step");
    assert!(c.stat_rounds >= 1, "update_every=60 over 200 iters must fire stat rounds");
    assert!(c.codec_refreshes >= 1);
    assert_eq!(tele.ring().latest().unwrap().t as usize, iters);
    assert!(tele.totals().total() > 0.0, "spans must accumulate measured time");
    // Wire-bit reconciliation: data + stat plane counters must equal the
    // run's total wire bits, exactly.
    let rec = s.into_recorder();
    let total_bits = rec.scalar("total_bits").unwrap();
    assert_eq!((c.data_bits + c.stat_bits) as f64, total_bits);
}

#[test]
fn jsonl_stream_is_manifest_steps_summary() {
    let path = std::env::temp_dir().join(format!("qgenx_tele_{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    let mut cfg = base_cfg();
    cfg.iters = 60;
    cfg.eval_every = 30;
    let rec = Session::builder(cfg.clone())
        .telemetry(TelemetryConfig::jsonl(&path_s))
        .build()
        .unwrap()
        .run()
        .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let events: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let kind = |e: &Json| e.get("event").and_then(|v| v.as_str()).unwrap().to_string();
    assert_eq!(kind(&events[0]), "manifest");
    assert_eq!(
        events[0].get("schema").and_then(|v| v.as_usize()),
        Some(TELEMETRY_SCHEMA as usize)
    );
    assert_eq!(kind(events.last().unwrap()), "summary");
    let steps: Vec<&Json> = events.iter().filter(|e| kind(e) == "step").collect();
    assert_eq!(steps.len(), cfg.iters, "one step event per iteration");
    assert_eq!(events.len(), cfg.iters + 2, "manifest + steps + summary, nothing else");
    // Per-step spans cover the full taxonomy; bits reconcile with the run.
    for s in &steps {
        let spans = s.get("spans").unwrap();
        for stage in ["sample", "quantize", "encode", "exchange", "decode", "apply", "stat"] {
            assert!(spans.get(stage).is_some(), "span {stage} missing");
        }
    }
    let summary = events.last().unwrap();
    let sum_bits = summary.get("data_bits").and_then(|v| v.as_f64()).unwrap()
        + summary.get("stat_bits").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(sum_bits, rec.scalar("total_bits").unwrap());
    let step_bits: f64 = steps
        .iter()
        .map(|s| {
            s.get("data_bits").and_then(|v| v.as_f64()).unwrap()
                + s.get("stat_bits").and_then(|v| v.as_f64()).unwrap()
        })
        .sum();
    assert_eq!(step_bits, sum_bits, "summary totals must equal the sum of step events");
    std::fs::remove_file(&path).ok();
}
