//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! The compile path (`make artifacts`) runs Python exactly once; from then
//! on this module is the only contact point with the model — the request
//! path is pure Rust + XLA:
//!
//! ```text
//! PjRtClient::cpu()
//!   └─ HloModuleProto::from_text_file("artifacts/<entry>.hlo.txt")
//!        └─ XlaComputation::from_proto → client.compile → execute
//! ```
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//! Executables are compiled once and cached; `Runtime` is `Send` but not
//! `Sync` — give each worker thread its own instance or route through the
//! leader.

pub mod json;
pub mod manifest;

pub use manifest::{EntryMeta, Manifest, TensorMeta};

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Input tensor for an execution: f32 or i32, with a shape.
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> Arg<'a> {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Arg::F32(data, shape) => {
                check_len(data.len(), shape)?;
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Arg::I32(data, shape) => {
                check_len(data.len(), shape)?;
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

fn check_len(len: usize, shape: &[usize]) -> Result<()> {
    let want: usize = shape.iter().product();
    if len != want {
        return Err(Error::Runtime(format!("arg has {len} elements, shape {shape:?} wants {want}")));
    }
    Ok(())
}

/// One loaded artifact set: PJRT client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifacts directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        // Opt-in banner (the old log::info! was a no-op without a backend;
        // keep stderr clean by default for benches and piped output).
        if std::env::var_os("QGENX_VERBOSE").is_some() {
            eprintln!(
                "runtime: platform={} devices={} artifacts={}",
                client.platform_name(),
                client.device_count(),
                dir.display()
            );
        }
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) the named entry.
    pub fn prepare(&mut self, entry: &str) -> Result<()> {
        if self.cache.contains_key(entry) {
            return Ok(());
        }
        let meta = self.manifest.entry(entry)?;
        let path = self.dir.join(&meta.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(entry.to_string(), exe);
        Ok(())
    }

    /// Execute `entry` with `args`; returns the flattened f32 contents of
    /// every output leaf (scalars become length-1 vectors). Integer outputs
    /// are rejected — all our entry points return f32.
    pub fn run(&mut self, entry: &str, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        self.prepare(entry)?;
        let meta = self.manifest.entry(entry)?;
        if args.len() != meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{entry}: got {} args, manifest says {}",
                args.len(),
                meta.inputs.len()
            )));
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let exe = self.cache.get(entry).expect("prepared above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime(format!("{entry}: empty result")))?;
        // aot.py lowers with return_tuple=True: single tuple literal.
        let tuple = first.to_literal_sync()?;
        let leaves = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(leaves.len());
        for leaf in leaves {
            out.push(leaf.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Convenience: run an entry that returns `(scalar, vector)` — the
    /// shape of every `*_step` training entry.
    pub fn run_loss_grad(&mut self, entry: &str, args: &[Arg]) -> Result<(f32, Vec<f32>)> {
        let mut outs = self.run(entry, args)?;
        if outs.len() != 2 {
            return Err(Error::Runtime(format!(
                "{entry}: expected (loss, grads), got {} outputs",
                outs.len()
            )));
        }
        let grads = outs.pop().unwrap();
        let loss = outs.pop().unwrap();
        Ok((loss.first().copied().unwrap_or(f32::NAN), grads))
    }

    /// Load a raw little-endian f32 blob (e.g. `lm_params_init.f32`).
    pub fn load_f32_blob(&self, name: &str) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(name))?;
        if bytes.len() % 4 != 0 {
            return Err(Error::Runtime(format!("{name}: length {} not multiple of 4", bytes.len())));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Locate the artifacts directory: `$QGENX_ARTIFACTS`, else `artifacts/`
/// relative to the workspace root (walking up from cwd).
pub fn default_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("QGENX_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are skipped (not
    /// failed) when the artifacts are absent so `cargo test` stays green in
    /// a fresh checkout. The Makefile's `test` target builds artifacts
    /// first, so CI always exercises them.
    fn runtime() -> Option<Runtime> {
        let dir = default_artifacts_dir()?;
        Some(Runtime::open(dir).expect("artifacts exist but failed to open"))
    }

    #[test]
    fn open_and_manifest() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(rt.manifest().lm.params > 100_000);
        assert!(rt.manifest().entry("quantize").is_ok());
        assert!(rt.manifest().entry("nope").is_err());
    }

    #[test]
    fn quantize_artifact_matches_rust_quantizer() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let d = rt.manifest().quantize_d;
        let nl = rt.manifest().quantize_levels;
        let mut rng = crate::util::Rng::seed_from(7);
        let v = rng.gaussian_vec(d, 1.0);
        let uniforms = rng.uniform_vec(d);
        // uniform levels 0..1 with nl total points = nl - 2 interior
        let levels = crate::quant::Levels::uniform(nl - 2);
        let lv = levels.full_f32();
        let norm = [crate::util::norm2(&v) as f32];

        let outs = rt
            .run(
                "quantize",
                &[
                    Arg::F32(&v, &[d]),
                    Arg::F32(&lv, &[nl]),
                    Arg::F32(&uniforms, &[d]),
                    Arg::F32(&norm, &[1]),
                ],
            )
            .unwrap();
        let hlo_out = &outs[0];

        // Rust-native quantization with the same uniforms.
        let qv = crate::quant::quantize_with_uniforms(&v, &levels, 2, 0, &uniforms).unwrap();
        let rust_out = crate::quant::dequantize(&qv, &levels);

        // Cross-layer agreement: identical up to f32-vs-f64 boundary
        // rounding. Count mismatches; they must be rare and adjacent-level.
        let mut mismatches = 0;
        for i in 0..d {
            let a = hlo_out[i];
            let b = rust_out[i];
            if (a - b).abs() > 1e-6 * norm[0] {
                mismatches += 1;
                // any disagreement must be one quantization bin
                let bin = (a - b).abs() / norm[0];
                assert!(bin < 0.2, "coordinate {i}: {a} vs {b} differ by more than a bin");
            }
        }
        assert!(
            (mismatches as f64) < 0.001 * d as f64 + 2.0,
            "{mismatches}/{d} mismatches between HLO and rust quantizers"
        );
    }

    #[test]
    fn lm_step_runs_and_loss_near_log_vocab() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = rt.manifest().clone();
        let params = rt.load_f32_blob(&m.lm_init_file).unwrap();
        assert_eq!(params.len(), m.lm.params);
        let mut rng = crate::util::Rng::seed_from(3);
        let tokens: Vec<i32> =
            (0..m.lm.batch * m.lm.seq).map(|_| rng.below(m.lm.vocab as u64) as i32).collect();
        let (loss, grads) = rt
            .run_loss_grad(
                "lm_step",
                &[
                    Arg::F32(&params, &[m.lm.params]),
                    Arg::I32(&tokens, &[m.lm.batch, m.lm.seq]),
                ],
            )
            .unwrap();
        assert!(loss.is_finite());
        let logv = (m.lm.vocab as f32).ln();
        assert!((loss - logv).abs() < 1.0, "loss {loss} vs ln V {logv}");
        assert_eq!(grads.len(), m.lm.params);
        assert!(crate::util::norm2(&grads) > 0.0);
    }

    #[test]
    fn gan_steps_run() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = rt.manifest().clone();
        let tg = rt.load_f32_blob(&m.gan_g_init_file).unwrap();
        let td = rt.load_f32_blob(&m.gan_d_init_file).unwrap();
        let b = m.gan.batch;
        let mut rng = crate::util::Rng::seed_from(4);
        let real = rng.gaussian_vec(b * 2, 1.0);
        let z = rng.gaussian_vec(b * m.gan.nz, 1.0);
        let eps = rng.uniform_vec(b);
        let (ld, gd) = rt
            .run_loss_grad(
                "gan_disc_step",
                &[
                    Arg::F32(&td, &[m.gan.params_d]),
                    Arg::F32(&tg, &[m.gan.params_g]),
                    Arg::F32(&real, &[b, 2]),
                    Arg::F32(&z, &[b, m.gan.nz]),
                    Arg::F32(&eps, &[b, 1]),
                ],
            )
            .unwrap();
        assert!(ld.is_finite());
        assert_eq!(gd.len(), m.gan.params_d);
        let (lg, gg) = rt
            .run_loss_grad(
                "gan_gen_step",
                &[
                    Arg::F32(&td, &[m.gan.params_d]),
                    Arg::F32(&tg, &[m.gan.params_g]),
                    Arg::F32(&z, &[b, m.gan.nz]),
                ],
            )
            .unwrap();
        assert!(lg.is_finite());
        assert_eq!(gg.len(), m.gan.params_g);
        // sample
        let outs = rt
            .run(
                "gan_sample",
                &[Arg::F32(&tg, &[m.gan.params_g]), Arg::F32(&z, &[b, m.gan.nz])],
            )
            .unwrap();
        assert_eq!(outs[0].len(), b * 2);
    }

    #[test]
    fn arg_shape_validation() {
        let a = Arg::F32(&[1.0, 2.0], &[3]);
        assert!(a.to_literal().is_err());
        let b = Arg::F32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert!(b.to_literal().is_ok());
    }
}
