//! The Q-GenX state machine (Algorithm 1's per-iteration math, § update
//! rule (Q-GenX)).
//!
//! The struct is *communication-agnostic*: callers (the coordinator, the
//! single-process bench runner) obtain query points from it and feed back
//! the `K` decoded dual vectors. This keeps the algorithm testable in
//! isolation and reusable across the threaded and inline execution modes.
//!
//! Iteration protocol (enforced by [`QGenXPhase`]):
//!
//! 1. [`QGenX::base_query`] → where to evaluate `V_{k,t}` (or `None` for
//!    the DA/OptDA variants, which need no fresh base query);
//! 2. [`QGenX::extrapolate`] with the `K` base vectors → `X_{t+1/2}`;
//! 3. evaluate oracles at `X_{t+1/2}`, feed them to [`QGenX::update`] —
//!    which advances `Y`, the adaptive step-size and `X_{t+1} = γ_{t+1} Y_{t+1}`,
//!    and accumulates the ergodic average `X̄_{T+1/2}` that Theorems 3/4
//!    bound.
//!
//! Iterates live in coordinates shifted by `x₀` (the template inequality's
//! `X_1 = 0` normalization): `X_t^{world} = x₀ + X_t`.

use super::stepsize::AdaptiveStepSize;
use crate::config::Variant;
use crate::error::{Error, Result};
use crate::util::{axpy, mean_into};

/// Protocol phase (guards against out-of-order driving).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QGenXPhase {
    /// Expecting `extrapolate` (start of iteration t).
    AwaitBase,
    /// Expecting `update` with the half-step vectors.
    AwaitHalf,
}

/// Q-GenX iterate state for one run.
#[derive(Clone)]
pub struct QGenX {
    variant: Variant,
    d: usize,
    k: usize,
    /// Origin shift x₀ (iterates are stored relative to it).
    x0: Vec<f32>,
    /// X_t (shifted).
    x: Vec<f32>,
    /// Y_t (dual accumulator, shifted; Y_1 = 0).
    y: Vec<f32>,
    /// X_{t+1/2} (shifted).
    x_half: Vec<f32>,
    /// Running sum of X_{t+1/2} for the ergodic average.
    x_half_sum: Vec<f64>,
    /// V̂_{k,t+1/2} from the previous iteration (OptDA reuse).
    prev_half: Option<Vec<Vec<f32>>>,
    /// Base vectors of the current iteration (kept to measure
    /// ‖V̂_{k,t} − V̂_{k,t+1/2}‖² for the step-size).
    cur_base: Vec<Vec<f32>>,
    step: AdaptiveStepSize,
    t: usize,
    phase: QGenXPhase,
    // scratch
    mean_buf: Vec<f32>,
}

impl QGenX {
    /// New run from world-coordinate start `x0` with `k` workers.
    pub fn new(variant: Variant, x0: &[f32], k: usize, gamma0: f64, adaptive: bool) -> Self {
        let d = x0.len();
        QGenX {
            variant,
            d,
            k,
            x0: x0.to_vec(),
            x: vec![0.0; d],
            y: vec![0.0; d],
            x_half: vec![0.0; d],
            x_half_sum: vec![0.0; d],
            prev_half: None,
            cur_base: Vec::new(),
            step: AdaptiveStepSize::new(gamma0, k, adaptive),
            t: 0,
            phase: QGenXPhase::AwaitBase,
            mean_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn iteration(&self) -> usize {
        self.t
    }

    pub fn gamma(&self) -> f64 {
        self.step.gamma()
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Current iterate `X_t` in world coordinates.
    pub fn x_world(&self) -> Vec<f32> {
        let mut out = self.x0.clone();
        for i in 0..self.d {
            out[i] += self.x[i];
        }
        out
    }

    /// Half-step iterate `X_{t+1/2}` in world coordinates (valid after
    /// [`Self::extrapolate`]).
    pub fn x_half_world(&self) -> Vec<f32> {
        let mut out = self.x0.clone();
        for i in 0..self.d {
            out[i] += self.x_half[i];
        }
        out
    }

    /// Ergodic average `X̄ = (1/T) Σ X_{t+1/2}` in world coordinates — the
    /// point Theorems 3/4 certify.
    pub fn ergodic_average(&self) -> Vec<f32> {
        let t = self.t.max(1) as f64;
        let mut out = self.x0.clone();
        for i in 0..self.d {
            out[i] += (self.x_half_sum[i] / t) as f32;
        }
        out
    }

    /// Translate the iterate to `target` (world coordinates) by moving the
    /// origin shift `x₀` — the resynchronization primitive of the
    /// local-steps mode ([`crate::algo::LocalQGenX`]). The dual accumulator
    /// `Y`, the adaptive step-size and the iteration counter are untouched
    /// (they live in shifted coordinates and are translation-invariant);
    /// the ergodic-average *history* is translated along with the iterate,
    /// which is exactly what makes the mean ergodic average across replicas
    /// invariant under consensus averaging (the per-replica corrections
    /// `mean_delta − delta_r` sum to zero over `r`).
    ///
    /// Because the world iterate is re-derived as `x₀ + X` on every read,
    /// the landing point is exact only up to one f32 rounding ulp — callers
    /// needing bit-identical agreement across replicas must compare the
    /// *target* they passed (see [`crate::algo::LocalQGenX::sync_base`]),
    /// not the post-shift iterate.
    ///
    /// Only legal between iterations (phase `AwaitBase`).
    pub fn shift_world(&mut self, target: &[f32]) -> Result<()> {
        if self.phase != QGenXPhase::AwaitBase {
            return Err(Error::Coordinator("shift_world called mid-iteration".into()));
        }
        if target.len() != self.d {
            return Err(Error::Coordinator("shift_world target dim mismatch".into()));
        }
        let cur = self.x_world();
        for i in 0..self.d {
            self.x0[i] += target[i] - cur[i];
        }
        Ok(())
    }

    /// Where workers must evaluate the *base* oracle query `V_{k,t}`, if a
    /// fresh one is needed this iteration:
    /// * DE → `Some(X_t)` — the classic extra-gradient first leg;
    /// * DA → `None` (`V̂_{k,t} ≡ 0`);
    /// * OptDA → `None` (reuses `V̂_{k,t−1/2}` — one oracle call per
    ///   iteration, half the queries and half the communication).
    pub fn base_query(&self) -> Option<Vec<f32>> {
        match self.variant {
            Variant::DualExtrapolation => Some(self.x_world()),
            Variant::DualAveraging | Variant::OptimisticDualAveraging => None,
        }
    }

    /// Step 1: form `X_{t+1/2} = X_t − (γ_t/K) Σ_k V̂_{k,t}`.
    ///
    /// `base_vectors` must be the `K` decoded dual vectors when
    /// [`Self::base_query`] returned `Some`; pass `&[]` otherwise (the
    /// variant supplies its own base internally).
    pub fn extrapolate(&mut self, base_vectors: &[Vec<f32>]) -> Result<Vec<f32>> {
        if self.phase != QGenXPhase::AwaitBase {
            return Err(Error::Coordinator("extrapolate called out of phase".into()));
        }
        self.cur_base = match self.variant {
            Variant::DualExtrapolation => {
                if base_vectors.len() != self.k {
                    return Err(Error::Coordinator(format!(
                        "DE variant needs {} base vectors, got {}",
                        self.k,
                        base_vectors.len()
                    )));
                }
                base_vectors.to_vec()
            }
            Variant::DualAveraging => vec![vec![0.0; self.d]; self.k],
            Variant::OptimisticDualAveraging => match self.prev_half.take() {
                Some(prev) => prev,
                None => vec![vec![0.0; self.d]; self.k], // V̂_{k,1/2} at t = 1
            },
        };
        for v in &self.cur_base {
            if v.len() != self.d {
                return Err(Error::Coordinator("base vector dim mismatch".into()));
            }
        }
        let gamma = self.step.gamma();
        let refs: Vec<&[f32]> = self.cur_base.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        self.x_half.copy_from_slice(&self.x);
        axpy(-(gamma as f32), &self.mean_buf, &mut self.x_half);
        self.phase = QGenXPhase::AwaitHalf;
        Ok(self.x_half_world())
    }

    /// Step 2: consume the `K` half-step vectors `V̂_{k,t+1/2}` evaluated at
    /// `X_{t+1/2}`; advances `Y`, the step-size, and `X_{t+1} = γ_{t+1} Y_{t+1}`.
    pub fn update(&mut self, half_vectors: &[Vec<f32>]) -> Result<()> {
        if self.phase != QGenXPhase::AwaitHalf {
            return Err(Error::Coordinator("update called out of phase".into()));
        }
        if half_vectors.len() != self.k {
            return Err(Error::Coordinator(format!(
                "need {} half vectors, got {}",
                self.k,
                half_vectors.len()
            )));
        }
        for v in half_vectors {
            if v.len() != self.d {
                return Err(Error::Coordinator("half vector dim mismatch".into()));
            }
        }
        // Ergodic average accumulates X_{t+1/2}.
        for i in 0..self.d {
            self.x_half_sum[i] += self.x_half[i] as f64;
        }
        // Y_{t+1} = Y_t − (1/K) Σ V̂_{k,t+1/2}
        let refs: Vec<&[f32]> = half_vectors.iter().map(|v| v.as_slice()).collect();
        mean_into(&refs, &mut self.mean_buf);
        axpy(-1.0, &self.mean_buf, &mut self.y);
        // Step-size learns Σ_k ‖V̂_{k,t} − V̂_{k,t+1/2}‖².
        self.step.observe_pairs(&self.cur_base, half_vectors);
        // X_{t+1} = γ_{t+1} Y_{t+1}
        let g_next = self.step.gamma() as f32;
        for i in 0..self.d {
            self.x[i] = g_next * self.y[i];
        }
        if self.variant == Variant::OptimisticDualAveraging {
            self.prev_half = Some(half_vectors.to_vec());
        }
        self.t += 1;
        self.phase = QGenXPhase::AwaitBase;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{ExactOracle, MonotoneQuadratic, Operator, Oracle};
    use crate::util::{dist_sq, Rng};
    use std::sync::Arc;

    /// Drive Q-GenX on an exact oracle for `iters` and return final dist².
    fn run_exact(variant: Variant, iters: usize, gamma0: f64) -> (f64, f64) {
        let mut rng = Rng::seed_from(42);
        let op = Arc::new(MonotoneQuadratic::random(12, 0.3, 1.0, &mut rng).unwrap());
        let xs = op.solution().unwrap();
        let x0 = vec![0.0f32; 12];
        let k = 2;
        let mut oracles: Vec<ExactOracle> =
            (0..k).map(|_| ExactOracle::new(op.clone())).collect();
        let mut state = QGenX::new(variant, &x0, k, gamma0, true);
        let d0 = dist_sq(&x0, &xs);
        for _ in 0..iters {
            let base = if let Some(xq) = state.base_query() {
                oracles
                    .iter_mut()
                    .map(|o| {
                        let mut g = vec![0.0f32; 12];
                        o.sample(&xq, &mut g);
                        g
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let xh = state.extrapolate(&base).unwrap();
            let half: Vec<Vec<f32>> = oracles
                .iter_mut()
                .map(|o| {
                    let mut g = vec![0.0f32; 12];
                    o.sample(&xh, &mut g);
                    g
                })
                .collect();
            state.update(&half).unwrap();
        }
        let avg = state.ergodic_average();
        (dist_sq(&avg, &xs) / d0.max(1e-12), dist_sq(&state.x_world(), &xs) / d0.max(1e-12))
    }

    #[test]
    fn de_variant_converges_on_quadratic() {
        let (avg_ratio, last_ratio) = run_exact(Variant::DualExtrapolation, 3000, 0.25);
        assert!(avg_ratio < 1e-2, "ergodic ratio {avg_ratio}");
        assert!(last_ratio < 1.0, "last-iterate ratio {last_ratio}");
    }

    #[test]
    fn da_variant_converges_on_quadratic() {
        let (avg_ratio, _) = run_exact(Variant::DualAveraging, 3000, 0.25);
        assert!(avg_ratio < 5e-2, "ergodic ratio {avg_ratio}");
    }

    #[test]
    fn optda_variant_converges_on_quadratic() {
        let (avg_ratio, _) = run_exact(Variant::OptimisticDualAveraging, 3000, 0.25);
        assert!(avg_ratio < 1e-2, "ergodic ratio {avg_ratio}");
    }

    #[test]
    fn de_converges_on_pure_rotation_where_gda_diverges() {
        use crate::oracle::RotationOperator;
        let op = Arc::new(RotationOperator::new(8, 0.0, 1.0).unwrap());
        let xs = op.solution().unwrap();
        let d = 8;
        let x0 = vec![0.0f32; d];
        let mut oracle = ExactOracle::new(op.clone());
        // Q-GenX (DE)
        let mut state = QGenX::new(Variant::DualExtrapolation, &x0, 1, 0.3, true);
        for _ in 0..4000 {
            let xq = state.base_query().unwrap();
            let mut g = vec![0.0f32; d];
            oracle.sample(&xq, &mut g);
            let xh = state.extrapolate(&[g]).unwrap();
            let mut gh = vec![0.0f32; d];
            oracle.sample(&xh, &mut gh);
            state.update(&[gh]).unwrap();
        }
        let avg = state.ergodic_average();
        let r_eg = dist_sq(&avg, &xs) / dist_sq(&x0, &xs);
        assert!(r_eg < 0.05, "EG-on-rotation ratio {r_eg}");

        // Plain GDA with the same initial step diverges (or fails to
        // contract) on the pure rotation.
        let mut x = x0.clone();
        let gamma = 0.3f32;
        for _ in 0..4000 {
            let mut g = vec![0.0f32; d];
            oracle.sample(&x, &mut g);
            for i in 0..d {
                x[i] -= gamma * g[i];
            }
            if !x.iter().all(|v| v.is_finite()) {
                break;
            }
        }
        let r_gda = if x.iter().all(|v| v.is_finite()) {
            dist_sq(&x, &xs) / dist_sq(&x0, &xs)
        } else {
            f64::INFINITY
        };
        assert!(r_gda > 1.0, "GDA unexpectedly converged: {r_gda}");
    }

    #[test]
    fn phase_protocol_enforced() {
        let mut state = QGenX::new(Variant::DualAveraging, &[0.0; 4], 1, 1.0, true);
        // update before extrapolate -> error
        assert!(state.update(&[vec![0.0; 4]]).is_err());
        state.extrapolate(&[]).unwrap();
        // double extrapolate -> error
        assert!(state.extrapolate(&[]).is_err());
        state.update(&[vec![0.0; 4]]).unwrap();
    }

    #[test]
    fn dimension_mismatches_rejected() {
        let mut state = QGenX::new(Variant::DualExtrapolation, &[0.0; 4], 2, 1.0, true);
        // wrong worker count
        assert!(state.extrapolate(&[vec![0.0; 4]]).is_err());
        // wrong dim
        assert!(state
            .extrapolate(&[vec![0.0; 3], vec![0.0; 3]])
            .is_err());
    }

    #[test]
    fn da_needs_no_base_query_and_de_does() {
        let de = QGenX::new(Variant::DualExtrapolation, &[0.0; 2], 1, 1.0, true);
        assert!(de.base_query().is_some());
        let da = QGenX::new(Variant::DualAveraging, &[0.0; 2], 1, 1.0, true);
        assert!(da.base_query().is_none());
        let opt = QGenX::new(Variant::OptimisticDualAveraging, &[0.0; 2], 1, 1.0, true);
        assert!(opt.base_query().is_none());
    }

    #[test]
    fn x0_shift_is_respected() {
        // With zero oracle vectors the iterate must stay at x0 exactly.
        let x0 = vec![3.0f32, -2.0];
        let mut state = QGenX::new(Variant::DualAveraging, &x0, 1, 1.0, true);
        for _ in 0..5 {
            state.extrapolate(&[]).unwrap();
            state.update(&[vec![0.0; 2]]).unwrap();
        }
        assert_eq!(state.x_world(), x0);
        assert_eq!(state.ergodic_average(), x0);
    }

    #[test]
    fn shift_world_moves_iterate_and_preserves_dynamics() {
        let mut state = QGenX::new(Variant::DualExtrapolation, &[0.0; 3], 1, 0.5, true);
        let _ = state.base_query();
        state.extrapolate(&[vec![1.0, -1.0, 0.5]]).unwrap();
        state.update(&[vec![0.5, 0.5, 0.5]]).unwrap();
        let gamma_before = state.gamma();
        let t_before = state.iteration();
        let target = vec![2.0f32, -3.0, 0.25];
        state.shift_world(&target).unwrap();
        // The shift re-derives x_world from x0 + x, so the landing point is
        // exact only up to one f32 rounding ulp.
        for (w, t) in state.x_world().iter().zip(target.iter()) {
            assert!((w - t).abs() <= 1e-6 * (1.0 + t.abs()), "{w} vs {t}");
        }
        assert_eq!(state.gamma(), gamma_before);
        assert_eq!(state.iteration(), t_before);
        // mid-iteration shift is rejected
        let _ = state.base_query();
        state.extrapolate(&[vec![0.0; 3]]).unwrap();
        assert!(state.shift_world(&target).is_err());
        state.update(&[vec![0.0; 3]]).unwrap();
        // dim mismatch rejected
        assert!(state.shift_world(&[0.0; 2]).is_err());
    }

    #[test]
    fn gamma_shrinks_under_noisy_vectors() {
        let mut state = QGenX::new(Variant::DualExtrapolation, &[0.0; 4], 1, 1.0, true);
        let g0 = state.gamma();
        let mut rng = Rng::seed_from(9);
        for _ in 0..50 {
            let _ = state.base_query();
            let b = rng.gaussian_vec(4, 1.0);
            state.extrapolate(&[b]).unwrap();
            let h = rng.gaussian_vec(4, 1.0);
            state.update(&[h]).unwrap();
        }
        assert!(state.gamma() < g0 * 0.5, "gamma {} vs {}", state.gamma(), g0);
    }
}
