//! Elias universal integer codes (Elias, 1975).
//!
//! Used as Ψ when the symbol distribution is unknown but smaller level
//! indices are more frequent — the regime the paper inherits from QSGD.
//! All codes operate on positive integers `n >= 1`; the wire layer maps a
//! level index `j` to `j + 1`.
//!
//! * γ(n): `floor(log2 n)` zeros, then the `floor(log2 n)+1`-bit binary of n
//!   — `2⌊log n⌋ + 1` bits.
//! * δ(n): γ(⌊log n⌋+1) then the mantissa — `⌊log n⌋ + 2⌊log(⌊log n⌋+1)⌋ + 1`
//!   bits, asymptotically shorter than γ.
//! * ω(n): Elias' recursive code ("recursive coding" in Appendix K).

use super::bitio::{reverse_low_bits, BitReader, BitWriter};
use crate::error::{Error, Result};

#[inline]
fn ilog2(n: u64) -> u32 {
    63 - n.leading_zeros()
}

/// Encode γ(n), n >= 1.
pub fn gamma_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1, "Elias gamma needs n >= 1");
    let nb = ilog2(n);
    if nb <= 28 {
        // Whole codeword in one call (2·nb+1 ≤ 57): nb zeros, then the
        // nb+1 significant bits of n MSB-first — i.e. their bit-reversal
        // shifted past the zero run. Bit-identical to the per-bit loop
        // below (pinned by `tests/encode_parity.rs`).
        let rev = reverse_low_bits(n, nb + 1);
        w.write_bits(rev << nb, 2 * nb + 1);
        return;
    }
    // Rare big-n path (symbols here are small): the original per-bit loop.
    w.write_bits(0, nb.min(57));
    if nb > 57 {
        w.write_bits(0, nb - 57);
    }
    w.write_bit(true);
    for i in (0..nb).rev() {
        w.write_bit((n >> i) & 1 == 1);
    }
}

/// Decode γ.
pub fn gamma_decode(r: &mut BitReader) -> Result<u64> {
    // Fast path: when the whole codeword sits in one peek, resolve the
    // zero run with `trailing_zeros` and the mantissa with one more peek.
    let (peek, avail) = r.peek_bits(57);
    if peek != 0 {
        let nb = peek.trailing_zeros();
        if 2 * nb + 1 <= avail {
            r.skip_bits(nb + 1); // the zero run and the leading 1
            if nb == 0 {
                return Ok(1);
            }
            let (body, body_avail) = r.peek_bits(nb);
            debug_assert_eq!(body_avail, nb);
            r.skip_bits(nb);
            return Ok((1u64 << nb) | reverse_low_bits(body, nb));
        }
    }
    let mut nb = 0u32;
    loop {
        if r.read_bit()? {
            break;
        }
        nb += 1;
        if nb > 63 {
            return Err(Error::Codec("gamma: run of zeros too long".into()));
        }
    }
    let mut n = 1u64;
    for _ in 0..nb {
        n = (n << 1) | r.read_bit()? as u64;
    }
    Ok(n)
}

/// γ code length in bits.
pub fn gamma_len(n: u64) -> u64 {
    assert!(n >= 1);
    2 * ilog2(n) as u64 + 1
}

/// Encode δ(n), n >= 1.
pub fn delta_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    let nb = ilog2(n);
    gamma_encode(w, nb as u64 + 1);
    if nb == 0 {
        return;
    }
    // The nb mantissa bits (below the leading 1) MSB-first, emitted as
    // their bit-reversal in at most two calls (write_bits caps at 57).
    let mantissa = n & ((1u64 << nb) - 1);
    let rev = reverse_low_bits(mantissa, nb);
    if nb <= 57 {
        w.write_bits(rev, nb);
    } else {
        w.write_bits(rev & ((1u64 << 57) - 1), 57);
        w.write_bits(rev >> 57, nb - 57);
    }
}

/// Decode δ.
pub fn delta_decode(r: &mut BitReader) -> Result<u64> {
    let nb = gamma_decode(r)? - 1;
    if nb > 63 {
        return Err(Error::Codec("delta: length field too large".into()));
    }
    if nb == 0 {
        return Ok(1);
    }
    if nb <= 57 {
        // Fast path: the whole mantissa in one peek.
        let (body, avail) = r.peek_bits(nb as u32);
        if avail == nb as u32 {
            r.skip_bits(nb as u32);
            return Ok((1u64 << nb) | reverse_low_bits(body, nb as u32));
        }
    }
    let mut n = 1u64;
    for _ in 0..nb {
        n = (n << 1) | r.read_bit()? as u64;
    }
    Ok(n)
}

/// δ code length in bits.
pub fn delta_len(n: u64) -> u64 {
    assert!(n >= 1);
    let nb = ilog2(n) as u64;
    gamma_len(nb + 1) + nb
}

/// Encode ω(n) (Elias omega / recursive).
pub fn omega_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    // Build groups back-to-front.
    let mut groups: Vec<u64> = Vec::new();
    let mut k = n;
    while k > 1 {
        groups.push(k);
        k = ilog2(k) as u64;
    }
    for g in groups.iter().rev() {
        let nb = ilog2(*g) + 1;
        for i in (0..nb).rev() {
            w.write_bit((*g >> i) & 1 == 1);
        }
    }
    w.write_bit(false); // terminating 0
}

/// Decode ω.
pub fn omega_decode(r: &mut BitReader) -> Result<u64> {
    let mut n = 1u64;
    loop {
        if !r.read_bit()? {
            return Ok(n);
        }
        // group of n more bits, first bit was the leading 1
        if n > 62 {
            return Err(Error::Codec("omega: group too large".into()));
        }
        let mut v = 1u64;
        for _ in 0..n {
            v = (v << 1) | r.read_bit()? as u64;
        }
        n = v;
    }
}

/// ω code length in bits.
pub fn omega_len(n: u64) -> u64 {
    assert!(n >= 1);
    let mut bits = 1u64; // terminator
    let mut k = n;
    while k > 1 {
        bits += ilog2(k) as u64 + 1;
        k = ilog2(k) as u64;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn gamma_known_values() {
        // gamma(1) = "1", gamma(2) = "010", gamma(3)="011" ... lengths 1,3,3,5..
        assert_eq!(gamma_len(1), 1);
        assert_eq!(gamma_len(2), 3);
        assert_eq!(gamma_len(3), 3);
        assert_eq!(gamma_len(4), 5);
        assert_eq!(gamma_len(255), 15);
    }

    #[test]
    fn roundtrip_small_all_codes() {
        for n in 1..=1000u64 {
            let mut w = BitWriter::new();
            gamma_encode(&mut w, n);
            delta_encode(&mut w, n);
            omega_encode(&mut w, n);
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            assert_eq!(gamma_decode(&mut r).unwrap(), n, "gamma {n}");
            assert_eq!(delta_decode(&mut r).unwrap(), n, "delta {n}");
            assert_eq!(omega_decode(&mut r).unwrap(), n, "omega {n}");
        }
    }

    #[test]
    fn lengths_match_encodings() {
        let codecs: [(fn(&mut BitWriter, u64), fn(u64) -> u64); 3] = [
            (gamma_encode, gamma_len),
            (delta_encode, delta_len),
            (omega_encode, omega_len),
        ];
        for n in [1u64, 2, 3, 7, 8, 100, 1023, 1024, 1 << 20] {
            for (enc, len) in codecs {
                let mut w = BitWriter::new();
                enc(&mut w, n);
                assert_eq!(w.bit_len(), len(n), "n={n}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_random_sequences() {
        // Mixed-codec stream: record codec choices, then decode with them.
        forall("mixed elias roundtrip", 100, |g| {
            let k = g.usize_in(1, 200);
            let items: Vec<(u64, usize)> =
                (0..k).map(|_| (g.u64_below(1 << 32) + 1, g.usize_in(0, 2))).collect();
            let mut w = BitWriter::new();
            for &(n, c) in &items {
                match c {
                    0 => gamma_encode(&mut w, n),
                    1 => delta_encode(&mut w, n),
                    _ => omega_encode(&mut w, n),
                }
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(n, c) in &items {
                let got = match c {
                    0 => gamma_decode(&mut r).unwrap(),
                    1 => delta_decode(&mut r).unwrap(),
                    _ => omega_decode(&mut r).unwrap(),
                };
                assert_eq!(got, n);
            }
        });
        forall("gamma stream roundtrip", 100, |g| {
            let k = g.usize_in(1, 300);
            let ns: Vec<u64> = (0..k).map(|_| g.u64_below(1 << 40) + 1).collect();
            let mut w = BitWriter::new();
            for &n in &ns {
                gamma_encode(&mut w, n);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &n in &ns {
                assert_eq!(gamma_decode(&mut r).unwrap(), n);
            }
        });
    }

    #[test]
    fn roundtrip_across_fast_slow_boundaries() {
        // gamma's one-call fast path covers nb ≤ 28; exercise both sides
        // of that boundary plus the 57-bit mantissa split in delta.
        for n in [
            (1u64 << 28) - 1,
            1 << 28,
            (1 << 29) - 1,
            1 << 29,
            (1 << 57) + 12345,
            u64::MAX / 2,
        ] {
            let mut w = BitWriter::new();
            gamma_encode(&mut w, n);
            delta_encode(&mut w, n);
            let bytes = w.finish();
            assert_eq!(w_len_check(&bytes, n), n);
        }
    }

    fn w_len_check(bytes: &[u8], n: u64) -> u64 {
        let mut r = BitReader::new(bytes);
        assert_eq!(gamma_decode(&mut r).unwrap(), n);
        delta_decode(&mut r).unwrap()
    }

    #[test]
    fn delta_beats_gamma_for_large_n() {
        assert!(delta_len(1 << 30) < gamma_len(1 << 30));
    }

    #[test]
    fn decode_garbage_is_error_not_panic() {
        // all-zero bytes: gamma sees an endless zero run then truncation
        let bytes = vec![0u8; 4];
        let mut r = BitReader::new(&bytes);
        assert!(gamma_decode(&mut r).is_err());
    }
}
