//! HLO-backed training drivers — the paper's GAN experiment and the E2E
//! LM validation, both running Algorithm 1's communication pattern over
//! real model gradients produced by the AOT artifacts.
//!
//! * [`data`] — synthetic data: ring-of-Gaussians (2D GAN benchmark),
//!   structured token streams for the LM, and the energy-distance metric
//!   (the FID analog for 2D distributions).
//! * [`gan`] — WGAN-GP training with quantized gradient exchange across K
//!   simulated workers, per-phase backward timing (GenBP/DiscBP/PenBP) and
//!   exact wire-bit accounting: regenerates Figure 1/2/3.
//! * [`lm`] — distributed data-parallel tiny-GPT training with quantized
//!   allgather (the E2E driver behind `examples/lm_e2e.rs`).

pub mod data;
pub mod gan;
pub mod lm;

pub use gan::{GanMode, GanTrainConfig, GanTrainer};
pub use lm::{LmOptimizer, LmTrainConfig, LmTrainer};
