//! The paper's §5 experiment end-to-end: WGAN-GP training with quantized
//! gradient exchange across 3 workers, comparing FP32 / UQ8 / UQ4 —
//! quality trajectory (energy distance, the FID analog), backward-time
//! breakdown (GenBP/DiscBP/PenBP) and total wire traffic.
//!
//! Exercises the full three-layer stack: Pallas-kernel-bearing AOT
//! artifacts loaded via PJRT, driven by the Rust coordinator.
//!
//! ```bash
//! make artifacts && cargo run --release --example gan_2d [steps]
//! ```

use qgenx::net::NetModel;
use qgenx::runtime::{default_artifacts_dir, Runtime};
use qgenx::train::{GanMode, GanTrainConfig, GanTrainer};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let dir = default_artifacts_dir()
        .ok_or("run `make artifacts` first")?;
    let mut rt = Runtime::open(dir)?;
    let net = NetModel::gbe();

    println!("WGAN-GP on ring-of-Gaussians, K=3 workers, {steps} steps/mode, 1 GbE model\n");
    let mut rows = Vec::new();
    for mode in [GanMode::Uq4, GanMode::Uq8, GanMode::Fp32] {
        let cfg = GanTrainConfig {
            mode,
            steps,
            workers: 3,
            eval_every: (steps / 6).max(1),
            ..Default::default()
        };
        let mut tr = GanTrainer::new(&mut rt, cfg, net)?;
        let rec = tr.train()?;
        println!("[{}] energy-distance trajectory:", mode.name());
        for (x, y) in &rec.get("metric").unwrap().points {
            println!("   step {x:>5.0}: {y:.4}");
        }
        let (g, d, p, tot) = tr.phases.averages();
        rows.push((
            mode.name(),
            g * 1e3,
            d * 1e3,
            p * 1e3,
            tot * 1e3,
            tr.traffic.bits_sent as f64 / 8.0 / 1.0e6,
            rec.get("metric").unwrap().last().unwrap(),
        ));
        rec.to_csv(&format!("results/gan2d_{}.csv", mode.name().to_lowercase()))?;
        println!();
    }

    println!("| Mode | GenBP ms | DiscBP ms | PenBP ms | Total ms | Wire MB | final ED |");
    println!("|------|----------|-----------|----------|----------|---------|----------|");
    for (m, g, d, p, t, mb, ed) in &rows {
        println!("| {m} | {g:.2} | {d:.2} | {p:.2} | {t:.2} | {mb:.1} | {ed:.4} |");
    }
    println!("\n(cf. paper Fig. 1: UQ4 < UQ8 < FP32 total time; quality trajectories overlap)");
    Ok(())
}
