//! E11 — local steps × quantizer: total wire bits at matched gap.
//!
//! PR 1 varied *where* bytes flow (topologies); this bench varies *how
//! often*. Each worker runs `H` private extra-gradient iterations between
//! communication rounds and the replicas exchange quantized model deltas
//! (`coordinator::inline::run_local`), so the wire carries one vector per
//! worker per `H` iterations instead of one-to-two per iteration. Method:
//!
//! 1. Sweep `H ∈ {1, 2, 4, 8}` × quantizer (uq4 / uq8 / fp32) on a
//!    monotone quadratic VI at fixed iteration budget; every run records
//!    `gap` and `bits_cum` on the same eval grid.
//! 2. Matched-gap accounting: the target gap is set so every run in a
//!    sweep reaches it (1.05 × the worst final gap); a run's cost is
//!    `bits_cum` at its first eval point at or below the target. This is
//!    the honest comparison — fewer bits per iteration is only a win if
//!    the gap still gets there.
//! 3. Report per-sync drift and bits/sync so the communication/accuracy
//!    trade is visible, not just the total.
//!
//! Acceptance (full-scale mode): with uq4 on the quadratic, every
//! `H ∈ {2, 4, 8}` reaches the matched gap with strictly fewer total wire
//! bits than `H = 1`.

use qgenx::benchkit::{fast_mode, scaled, write_csv, Table};
use qgenx::config::{ExperimentConfig, QuantMode};
use qgenx::coordinator::run_experiment;
use qgenx::metrics::Recorder;

const LOCAL_STEPS: [usize; 4] = [1, 2, 4, 8];

fn run_one(mode: &str, h: usize, iters: usize) -> Recorder {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("local_steps_{mode}_h{h}");
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 128;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.5;
    cfg.workers = 8;
    cfg.iters = iters;
    cfg.eval_every = (iters / 40).max(1);
    cfg.seed = 17;
    cfg.quant.mode = QuantMode::parse(mode).unwrap();
    cfg.local.steps = h;
    run_experiment(&cfg).expect("local-steps run")
}

/// `bits_cum` at the first eval point whose gap is at or below `target`
/// (the eval grids are identical across runs, so this is a fair match).
fn bits_to_gap(rec: &Recorder, target: f64) -> Option<f64> {
    let gaps = rec.get("gap").unwrap();
    let bits = rec.get("bits_cum").unwrap();
    gaps.points
        .iter()
        .zip(bits.points.iter())
        .find(|((_, g), _)| *g <= target)
        .map(|(_, (_, b))| *b)
}

fn main() {
    println!("== E11: local steps x quantizer — total bits at matched gap ==\n");
    let iters = scaled(2000, 300);
    let mut csv = Vec::new();
    let mut uq4_all_beat_h1 = true;

    for mode in ["uq4", "uq8", "fp32"] {
        let recs: Vec<(usize, Recorder)> =
            LOCAL_STEPS.iter().map(|&h| (h, run_one(mode, h, iters))).collect();
        // Matched gap: every run in the sweep must reach it.
        let target = 1.05
            * recs
                .iter()
                .map(|(_, r)| r.get("gap").unwrap().last().unwrap())
                .fold(0.0f64, f64::max);
        let base_bits = bits_to_gap(&recs[0].1, target).expect("H=1 reaches its own final gap");

        let mut table = Table::new(&[
            "H", "final gap", "bits@gap", "x vs H=1", "total bits", "syncs", "drift/sync",
        ]);
        for (h, rec) in &recs {
            let bits = bits_to_gap(rec, target).expect("every run reaches the matched gap");
            let ratio = base_bits / bits;
            let row = vec![
                h.to_string(),
                format!("{:.4}", rec.get("gap").unwrap().last().unwrap()),
                format!("{:.3e}", bits),
                format!("{ratio:.2}"),
                format!("{:.3e}", rec.scalar("total_bits").unwrap()),
                format!("{:.0}", rec.scalar("syncs").unwrap_or(0.0)),
                format!("{:.4}", rec.scalar("mean_sync_drift").unwrap_or(0.0)),
            ];
            table.row(&row);
            let mut crow = vec![mode.to_string()];
            crow.extend(row);
            csv.push(crow);
            if mode == "uq4" && *h > 1 {
                uq4_all_beat_h1 &= bits < base_bits;
            }
        }
        println!("-- mode = {mode} (matched gap {target:.4}, T = {iters}) --");
        table.print();
        println!();
    }
    write_csv(
        "results/local_steps.csv",
        &["mode", "H", "final_gap", "bits_at_gap", "speedup_vs_h1", "total_bits", "syncs", "drift_per_sync"],
        &csv,
    )
    .unwrap();

    if fast_mode() {
        println!("acceptance check skipped in QGENX_BENCH_FAST mode (budget too small)");
    } else {
        println!(
            "acceptance: uq4 quadratic — every H in {{2,4,8}} reaches the matched gap \
             with strictly fewer wire bits than H = 1: {}",
            if uq4_all_beat_h1 { "YES" } else { "NO" }
        );
    }
    println!(
        "\npaper shape: local steps compose with CODE∘Q as an independent\n\
         communication-reduction axis (Beznosikov et al.'s three pillars):\n\
         the wire moves one delta per worker per H iterations instead of\n\
         one-to-two duals per iteration, and the matched-gap bit cost drops\n\
         as long as the intra-segment drift stays small relative to the\n\
         consensus trajectory."
    );
}
