//! Per-family regression suite: the Session-based runners must be
//! **bit-identical** — trajectories and wire accounting — to the
//! pre-Session coordinators.
//!
//! The `legacy` module below is a frozen, verbatim copy of the seed's six
//! run loops (inline exact/gossip/local + QSGDA, threaded per-step +
//! local worker loops) as they stood before the `Session` refactor,
//! re-expressed against the crate's public API. The tests run each runner
//! family through both the legacy loop and the new wrapper and compare:
//!
//! * every recorded series point-for-point (`sim_time_cum` exempt — it
//!   contains measured wall-clock compute), including the series *name
//!   sets*, so the wrappers can neither drop nor invent metrics;
//! * every summary scalar (`compute_time` exempt, same reason);
//! * the threaded replicas (the replication-invariant payload).
//!
//! If a Session change breaks any of these, the break is intentional API
//! surface work and this frozen copy is the place to prove it.
#![allow(clippy::too_many_arguments)]

use qgenx::metrics::Recorder;

/// The pre-Session coordinators, frozen. Do not "clean up" — fidelity to
/// the seed is the point.
mod legacy {
    use qgenx::algo::{LocalQGenX, QGenX, Sgda};
    use qgenx::config::ExperimentConfig;
    use qgenx::coordinator::{Compressor, UpdateSchedule};
    use qgenx::error::{Error, Result};
    use qgenx::metrics::{consensus_distance, Recorder, SyncAccounting};
    use qgenx::net::{AllGather, NetModel, TrafficStats};
    use qgenx::oracle::{build_operator, build_oracle, GapEvaluator, Oracle};
    use qgenx::topo::{build_collective, Collective, LinkTraffic, Topology};
    use qgenx::util::Rng;
    use std::sync::Arc;
    use std::time::Instant;

    /// Stat-exchange schedule shared by the exact and gossip runners: active
    /// only when something adapts (level placement or Huffman tables) and the
    /// pipeline is actually quantized.
    fn adaptive_schedule(cfg: &ExperimentConfig, comps: &[Compressor]) -> UpdateSchedule {
        if cfg.quant.adapts() && comps[0].is_quantized() {
            UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
        } else {
            UpdateSchedule::never()
        }
    }

    /// Summary scalars shared by the exact and gossip runners — one emission
    /// point so cross-topology CSV columns cannot drift apart.
    fn emit_summary_scalars(
        rec: &mut Recorder,
        traffic: &TrafficStats,
        links: &LinkTraffic,
        comps: &[Compressor],
        k: usize,
        d: usize,
    ) {
        rec.set_scalar("total_bits", traffic.bits_sent as f64);
        rec.set_scalar("bits_per_round_per_worker", traffic.bits_per_round_per_worker(k));
        rec.set_scalar("sim_net_time", traffic.sim_net_time);
        rec.set_scalar("compute_time", traffic.compute_time);
        rec.set_scalar("rounds", traffic.rounds as f64);
        rec.set_scalar("level_updates", comps[0].updates() as f64);
        rec.set_scalar("epsilon_q", comps[0].epsilon_q(d));
        rec.set_scalar("wire_links", links.links() as f64);
        rec.set_scalar("max_link_bytes", links.max_link_bytes());
        // Layer-wise pipelines additionally report per-layer scalars
        // (layer_bits/<name>, layer_variance/<name>, layer_levels/<name>);
        // no-op otherwise.
        comps[0].emit_layer_scalars(rec);
    }

    /// Run one Q-GenX experiment per the config; returns the metric recorder
    /// with series `gap`, `dist`, `residual`, `gamma`, `bits_cum`,
    /// `sim_time_cum` and summary scalars. The exchange rounds run over the
    /// configured [`Topology`]; the config selects one of three runner
    /// families:
    ///
    /// * **exact** (this function's body) — per-step dual exchange over an
    ///   exact topology, the seed's Algorithm 1;
    /// * **gossip** (the private `run_gossip`) — inexact topologies: per-step
    ///   dual exchange averaged over graph neighborhoods, plus `consensus_dist`;
    /// * **local** (the private `run_local`) — `local.steps ≥ 2`: private extra-gradient
    ///   iterations between syncs, quantized model-delta averaging at syncs.
    ///
    /// `local.steps = 1` deliberately does *not* engage the delta-sync
    /// machinery: with one local step the algorithm communicates every
    /// iteration anyway, and the per-step dual exchange is the trajectory the
    /// paper's theorems describe — so it runs the exact (or gossip) path,
    /// bit-for-bit identical to the seed.
    pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Recorder> {
        cfg.validate()?;
        let topo = Topology::from_config(&cfg.topo, cfg.workers)?;
        let collective = build_collective(topo, cfg.workers)?;
        if cfg.local.steps > 1 {
            return run_local(cfg, collective);
        }
        if !topo.is_exact() {
            return run_gossip(cfg, collective);
        }
        let op = build_operator(&cfg.problem, cfg.seed)?;
        let d = op.dim();
        let k = cfg.workers;
        let root = Rng::seed_from(cfg.seed);

        // K private oracles + K compression endpoints.
        let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
            .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
            .collect::<Result<_>>()?;
        let mut comps: Vec<Compressor> = (0..k)
            .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
            .collect::<Result<_>>()?;

        let schedule = adaptive_schedule(cfg, &comps);

        let x0 = vec![0.0f32; d];
        let mut state =
            QGenX::new(cfg.algo.variant, &x0, k, cfg.algo.gamma0, cfg.algo.adaptive_step);

        let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
        let net = NetModel::from_config(&cfg.net);
        let mut traffic = TrafficStats::default();
        let mut links = LinkTraffic::new();
        let mut rec = Recorder::new();

        // Scratch buffers reused across iterations.
        let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
        let mut g_buf = vec![0.0f32; d];

        for t in 1..=cfg.iters {
            // (1) Level-update step: exchange sufficient statistics, pool,
            //     re-optimize — identical on all workers.
            if schedule.is_update(t) {
                let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
                let bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
                traffic.record_allgather(&bits, &net);
                let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                for comp in comps.iter_mut() {
                    comp.update_levels(&rank_order)?;
                }
            }

            // (2) Base exchange (variant-dependent).
            let base_vecs: Vec<Vec<f32>> = if let Some(xq) = state.base_query() {
                let t0 = Instant::now();
                let mut bits = Vec::with_capacity(k);
                let mut wires = Vec::with_capacity(k);
                for w in 0..k {
                    oracles[w].sample(&xq, &mut g_buf);
                    let (bytes, b) = comps[w].compress(&g_buf)?;
                    bits.push(b);
                    wires.push(bytes);
                }
                // Everyone decodes everyone (we decode once — identical everywhere).
                for w in 0..k {
                    comps[w].decompress(&wires[w], &mut decoded[w])?;
                }
                traffic.add_compute(t0.elapsed().as_secs_f64());
                collective.record_round(&bits, &net, &mut traffic);
                links.record(collective.as_ref(), &bits);
                decoded.clone()
            } else {
                Vec::new()
            };

            // (3) Extrapolate.
            let x_half = state.extrapolate(&base_vecs)?;

            // (4) Half-step exchange.
            let t0 = Instant::now();
            let mut bits = Vec::with_capacity(k);
            let mut wires = Vec::with_capacity(k);
            for w in 0..k {
                oracles[w].sample(&x_half, &mut g_buf);
                let (bytes, b) = comps[w].compress(&g_buf)?;
                bits.push(b);
                wires.push(bytes);
            }
            for w in 0..k {
                comps[w].decompress(&wires[w], &mut decoded[w])?;
            }
            traffic.add_compute(t0.elapsed().as_secs_f64());
            collective.record_round(&bits, &net, &mut traffic);
            links.record(collective.as_ref(), &bits);
            state.update(&decoded)?;

            // (5) Evaluation.
            if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
                let avg = state.ergodic_average();
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&avg));
                }
                rec.push("residual", t as f64, op.residual(&avg));
                rec.push("gamma", t as f64, state.gamma());
                rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
                rec.push("sim_time_cum", t as f64, traffic.total_time());
                comps[0].record_layer_series(&mut rec, t as f64);
            }
        }

        emit_summary_scalars(&mut rec, &traffic, &links, &comps, k, d);
        Ok(rec)
    }

    /// Inexact (gossip) runner: `K` genuinely distinct replicas, each
    /// averaging dual vectors over its closed graph neighborhood only. The
    /// exchange still moves real encoded wire bytes (decode is
    /// sender-deterministic, so decoding once per sender is exact); traffic
    /// follows the gossip α-β cost. Level updates stay *global* — the decode
    /// side of the wire format requires identical codecs on every replica, so
    /// the control plane (small, infrequent stat payloads) is pooled full-mesh
    /// while the data plane gossips; see `coordinator::mod` docs.
    fn run_gossip(cfg: &ExperimentConfig, collective: Arc<dyn Collective>) -> Result<Recorder> {
        let op = build_operator(&cfg.problem, cfg.seed)?;
        let d = op.dim();
        let k = cfg.workers;
        let root = Rng::seed_from(cfg.seed);
        let neigh: Vec<Vec<usize>> = (0..k).map(|r| collective.recipients(r)).collect();

        let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
            .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
            .collect::<Result<_>>()?;
        let mut comps: Vec<Compressor> = (0..k)
            .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
            .collect::<Result<_>>()?;

        let schedule = adaptive_schedule(cfg, &comps);

        let x0 = vec![0.0f32; d];
        let mut states: Vec<QGenX> = neigh
            .iter()
            .map(|n| {
                QGenX::new(cfg.algo.variant, &x0, n.len(), cfg.algo.gamma0, cfg.algo.adaptive_step)
            })
            .collect();

        let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
        let net = NetModel::from_config(&cfg.net);
        let mut traffic = TrafficStats::default();
        let mut links = LinkTraffic::new();
        let mut rec = Recorder::new();
        let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
        let mut g_buf = vec![0.0f32; d];

        // Compress every worker's sample, decode once per sender, and hand each
        // replica its neighborhood view (rank order within the neighborhood).
        let exchange_views = |queries: &[Vec<f32>],
                                  oracles: &mut [Box<dyn Oracle>],
                                  comps: &mut [Compressor],
                                  decoded: &mut [Vec<f32>],
                                  traffic: &mut TrafficStats,
                                  links: &mut LinkTraffic,
                                  g_buf: &mut [f32]|
         -> Result<Vec<Vec<Vec<f32>>>> {
            let t0 = Instant::now();
            let mut bits = Vec::with_capacity(k);
            let mut wires = Vec::with_capacity(k);
            for w in 0..k {
                oracles[w].sample(&queries[w], g_buf);
                let (bytes, b) = comps[w].compress(g_buf)?;
                bits.push(b);
                wires.push(bytes);
            }
            for w in 0..k {
                comps[w].decompress(&wires[w], &mut decoded[w])?;
            }
            traffic.add_compute(t0.elapsed().as_secs_f64());
            collective.record_round(&bits, &net, traffic);
            links.record(collective.as_ref(), &bits);
            Ok(neigh
                .iter()
                .map(|n| n.iter().map(|&w| decoded[w].clone()).collect())
                .collect())
        };

        for t in 1..=cfg.iters {
            // (1) Global (full-mesh) stat pooling keeps all codecs identical.
            if schedule.is_update(t) {
                let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
                let bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
                traffic.record_allgather(&bits, &net);
                let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                for comp in comps.iter_mut() {
                    comp.update_levels(&rank_order)?;
                }
            }

            // (2) Base exchange: each replica queries at its *own* iterate.
            let base_views: Vec<Vec<Vec<f32>>> = if states[0].base_query().is_some() {
                let queries: Vec<Vec<f32>> =
                    states.iter().map(|s| s.base_query().expect("DE variant")).collect();
                exchange_views(
                    &queries,
                    &mut oracles,
                    &mut comps,
                    &mut decoded,
                    &mut traffic,
                    &mut links,
                    &mut g_buf,
                )?
            } else {
                vec![Vec::new(); k]
            };

            // (3) Per-replica extrapolation to its own half-step point.
            let x_halves: Vec<Vec<f32>> = states
                .iter_mut()
                .zip(base_views.iter())
                .map(|(s, v)| s.extrapolate(v))
                .collect::<Result<_>>()?;

            // (4) Half-step exchange at the per-replica half points.
            let half_views = exchange_views(
                &x_halves,
                &mut oracles,
                &mut comps,
                &mut decoded,
                &mut traffic,
                &mut links,
                &mut g_buf,
            )?;
            for (s, v) in states.iter_mut().zip(half_views.iter()) {
                s.update(v)?;
            }

            // (5) Evaluation at the mean ergodic average + consensus tracking.
            if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
                let averages: Vec<Vec<f32>> = states.iter().map(|s| s.ergodic_average()).collect();
                let mut mean_avg = vec![0.0f32; d];
                for a in &averages {
                    for (m, &x) in mean_avg.iter_mut().zip(a.iter()) {
                        *m += x / k as f32;
                    }
                }
                let iterates: Vec<Vec<f32>> = states.iter().map(|s| s.x_world()).collect();
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
                }
                rec.push("residual", t as f64, op.residual(&mean_avg));
                rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
                rec.push("gamma", t as f64, states[0].gamma());
                rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
                rec.push("sim_time_cum", t as f64, traffic.total_time());
                comps[0].record_layer_series(&mut rec, t as f64);
            }
        }

        // Same scalar set as the exact path (bits_per_round_per_worker is the
        // mesh-normalized figure Theorems 3/4 reference; under gossip it is a
        // comparison yardstick, not a per-edge quantity), plus the consensus
        // scalar only this runner can produce.
        let final_iterates: Vec<Vec<f32>> = states.iter().map(|s| s.x_world()).collect();
        emit_summary_scalars(&mut rec, &traffic, &links, &comps, k, d);
        rec.set_scalar("consensus_dist", consensus_distance(&final_iterates));
        Ok(rec)
    }

    /// Local-steps runner (`local.steps = H ≥ 2`): each worker runs `H`
    /// extra-gradient iterations against its *private* oracle between
    /// communication rounds, then the replicas exchange quantized **model
    /// deltas** (`X_t − X_sync`, one vector per worker per sync — not one or
    /// two duals per iteration) over the configured collective and
    /// re-synchronize by averaging the decoded deltas.
    ///
    /// * Exact topologies: every replica averages all `K` decoded deltas, so
    ///   replicas are bit-identical immediately after every sync; the
    ///   `sync_drift` series tracks how far they diverged *within* each local
    ///   segment.
    /// * Gossip: each replica averages deltas over its closed neighborhood
    ///   only — replicas drift persistently, tracked by `consensus_dist` just
    ///   like [`run_gossip`].
    ///
    /// The control plane (stat pooling for QAda / Huffman refreshes) stays
    /// global and fires at the first sync on or after each due point — the
    /// early warmup `update_every.min(10)` the per-step runners also use, then
    /// every `update_every` — because between syncs there is no wire to carry
    /// stats. Note the statistics now describe *delta* coordinates (that is
    /// what the codec compresses in this mode), so the refreshed levels/tables
    /// fit the actual wire distribution.
    fn run_local(cfg: &ExperimentConfig, collective: Arc<dyn Collective>) -> Result<Recorder> {
        let op = build_operator(&cfg.problem, cfg.seed)?;
        let d = op.dim();
        let k = cfg.workers;
        let h = cfg.local.steps;
        let root = Rng::seed_from(cfg.seed);
        let neigh: Vec<Vec<usize>> = (0..k).map(|r| collective.recipients(r)).collect();

        let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
            .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
            .collect::<Result<_>>()?;
        let mut comps: Vec<Compressor> = (0..k)
            .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
            .collect::<Result<_>>()?;

        let adaptive = cfg.quant.adapts() && comps[0].is_quantized();
        let update_every = cfg.quant.update_every;
        // First refresh at the first sync on or after the same early warmup
        // point the per-step runners use (update_every.min(10)) — without it,
        // runs shorter than update_every would never refresh at all.
        let mut next_stat_due = update_every.min(10);

        let x0 = vec![0.0f32; d];
        let mut replicas: Vec<LocalQGenX> = (0..k)
            .map(|_| {
                LocalQGenX::new(cfg.algo.variant, &x0, cfg.algo.gamma0, cfg.algo.adaptive_step)
            })
            .collect();

        let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
        let net = NetModel::from_config(&cfg.net);
        let mut traffic = TrafficStats::default();
        let mut links = LinkTraffic::new();
        let mut rec = Recorder::new();
        let mut sync_acc = SyncAccounting::new();
        let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
        let mut g_buf = vec![0.0f32; d];

        for t in 1..=cfg.iters {
            // (1) One private extra-gradient iteration per replica — no wire.
            let t0 = Instant::now();
            for (rep, oracle) in replicas.iter_mut().zip(oracles.iter_mut()) {
                rep.local_round(oracle.as_mut(), &mut g_buf)?;
            }
            traffic.add_compute(t0.elapsed().as_secs_f64());

            // (2) Synchronization every H local iterations (plus a final sync
            //     so the run always ends on a consensus point).
            if t % h == 0 || t == cfg.iters {
                // (2a) Quantize + exchange the model deltas.
                let t0 = Instant::now();
                let mut bits = Vec::with_capacity(k);
                let mut wires = Vec::with_capacity(k);
                for w in 0..k {
                    let delta = replicas[w].delta();
                    let (bytes, b) = comps[w].compress(&delta)?;
                    bits.push(b);
                    wires.push(bytes);
                }
                for w in 0..k {
                    comps[w].decompress(&wires[w], &mut decoded[w])?;
                }
                traffic.add_compute(t0.elapsed().as_secs_f64());
                let bits_before = traffic.bits_sent;
                collective.record_round(&bits, &net, &mut traffic);
                links.record(collective.as_ref(), &bits);

                // (2b) Pre-averaging drift + per-sync bit accounting.
                let iterates: Vec<Vec<f32>> = replicas.iter().map(|r| r.x_world()).collect();
                sync_acc.record(
                    &mut rec,
                    t,
                    consensus_distance(&iterates),
                    traffic.bits_sent - bits_before,
                );

                // (2c) Resync each replica onto its neighborhood-averaged delta
                //      (all K under exact topologies).
                for (rep, n) in replicas.iter_mut().zip(neigh.iter()) {
                    let mut mean = vec![0.0f32; d];
                    for &w in n {
                        for (m, &x) in mean.iter_mut().zip(decoded[w].iter()) {
                            *m += x / n.len() as f32;
                        }
                    }
                    rep.resync(&mean)?;
                }

                // (2d) Control plane: pooled stat exchange at the first sync on
                //      or after each due point (always full-mesh — the wire
                //      format needs identical codecs everywhere).
                if adaptive && update_every != 0 && t >= next_stat_due {
                    let payloads: Vec<Vec<u8>> = comps.iter().map(|c| c.stats_payload()).collect();
                    let stat_bits: Vec<u64> = payloads.iter().map(|p| 8 * p.len() as u64).collect();
                    traffic.record_allgather(&stat_bits, &net);
                    let rank_order: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                    for comp in comps.iter_mut() {
                        comp.update_levels(&rank_order)?;
                    }
                    next_stat_due = t + update_every;
                }
            }

            // (3) Evaluation at the mean ergodic average across replicas.
            if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
                let mut mean_avg = vec![0.0f32; d];
                for rep in &replicas {
                    for (m, &x) in mean_avg.iter_mut().zip(rep.ergodic_average().iter()) {
                        *m += x / k as f32;
                    }
                }
                let iterates: Vec<Vec<f32>> = replicas.iter().map(|r| r.x_world()).collect();
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
                }
                rec.push("residual", t as f64, op.residual(&mean_avg));
                rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
                rec.push("gamma", t as f64, replicas[0].gamma());
                rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
                rec.push("sim_time_cum", t as f64, traffic.total_time());
                comps[0].record_layer_series(&mut rec, t as f64);
            }
        }

        // Final consensus over the *sync bases*: the run ends on a sync, and
        // the consensus point is computed by identical arithmetic on every
        // replica — exactly 0 under exact topologies (the raw iterates can sit
        // an origin-shift rounding ulp off it; see `algo::local` docs).
        let final_bases: Vec<Vec<f32>> = replicas.iter().map(|r| r.sync_base().to_vec()).collect();
        emit_summary_scalars(&mut rec, &traffic, &links, &comps, k, d);
        sync_acc.emit_scalars(&mut rec);
        rec.set_scalar("local_steps", h as f64);
        rec.set_scalar("consensus_dist", consensus_distance(&final_bases));
        Ok(rec)
    }

    /// QSGDA baseline (Beznosikov et al. 2022): quantized SGDA with γ_t = γ₀/√t,
    /// same oracles/compressors/network — only the update rule differs
    /// (no extrapolation, no adaptive step). The Figure-4 comparator.
    pub fn run_qsgda_baseline(cfg: &ExperimentConfig) -> Result<Recorder> {
        cfg.validate()?;
        let op = build_operator(&cfg.problem, cfg.seed)?;
        let d = op.dim();
        let k = cfg.workers;
        let root = Rng::seed_from(cfg.seed);
        let mut oracles: Vec<Box<dyn Oracle>> = (0..k)
            .map(|w| build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (w as u64 + 1) * 0x9e37))
            .collect::<Result<_>>()?;
        let mut comps: Vec<Compressor> = (0..k)
            .map(|w| Compressor::from_config(&cfg.quant, root.fork(w as u64 + 101)))
            .collect::<Result<_>>()?;
        let x0 = vec![0.0f32; d];
        let mut sgda = Sgda::new(&x0, cfg.algo.gamma0, true);
        let gap_eval = GapEvaluator::around_solution(op.as_ref(), 2.0);
        let net = NetModel::from_config(&cfg.net);
        let mut traffic = TrafficStats::default();
        let mut rec = Recorder::new();
        let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];
        let mut g_buf = vec![0.0f32; d];

        for t in 1..=cfg.iters {
            let xq = sgda.query();
            let mut bits = Vec::with_capacity(k);
            let mut wires = Vec::with_capacity(k);
            for w in 0..k {
                oracles[w].sample(&xq, &mut g_buf);
                let (bytes, b) = comps[w].compress(&g_buf)?;
                bits.push(b);
                wires.push(bytes);
            }
            for w in 0..k {
                comps[w].decompress(&wires[w], &mut decoded[w])?;
            }
            traffic.record_allgather(&bits, &net);
            sgda.update(&decoded);
            if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
                let avg = sgda.ergodic_average();
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&avg));
                    rec.push("dist_last", t as f64, ev.dist_to_center(sgda.x()));
                }
                rec.push("residual", t as f64, op.residual(&avg));
                rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
            }
        }
        rec.set_scalar("total_bits", traffic.bits_sent as f64);
        Ok(rec)
    }


    /// Outcome of one threaded run: rank-0 recorder plus the final iterate of
    /// every replica (for the replication invariant check and tests).
    pub struct ThreadedRun {
        pub recorder: Recorder,
        pub replicas: Vec<Vec<f32>>,
    }

    /// Run Algorithm 1 on `K` OS threads over the configured topology.
    /// Functionally equivalent to [`super::inline::run_experiment`] modulo RNG
    /// stream interleaving.
    pub fn run_threaded(cfg: &ExperimentConfig) -> Result<ThreadedRun> {
        cfg.validate()?;
        let topo = Topology::from_config(&cfg.topo, cfg.workers)?;
        let collective = build_collective(topo, cfg.workers)?;
        let op = build_operator(&cfg.problem, cfg.seed)?;
        let d = op.dim();
        let k = cfg.workers;
        let transport = AllGather::new(k);
        let net = NetModel::from_config(&cfg.net);
        let schedule = if cfg.quant.adapts() {
            UpdateSchedule::new(cfg.quant.update_every.min(10), cfg.quant.update_every)
        } else {
            UpdateSchedule::never()
        };

        let handles: Vec<std::thread::JoinHandle<Result<(Recorder, Vec<f32>)>>> = (0..k)
            .map(|rank| {
                let op = op.clone();
                let cfg = cfg.clone();
                let transport = transport.clone();
                let collective = collective.clone();
                std::thread::Builder::new()
                    .name(format!("qgenx-worker-{rank}"))
                    .spawn(move || {
                        let out = if cfg.local.steps > 1 {
                            worker_local_loop(rank, &cfg, op, transport.clone(), collective, net, d)
                        } else {
                            worker_loop(
                                rank,
                                &cfg,
                                op,
                                transport.clone(),
                                collective,
                                net,
                                schedule,
                                d,
                            )
                        };
                        // An Err return (codec/oracle failure) must release the
                        // peers just like a panic does — otherwise they block at
                        // the barrier forever waiting for this worker's deposit.
                        if let Err(e) = &out {
                            transport.poison(&format!("worker {rank} failed: {e}"));
                        }
                        out
                    })
                    .expect("spawn worker")
            })
            .collect();

        let mut recorders = Vec::with_capacity(k);
        let mut replicas = Vec::with_capacity(k);
        for h in handles {
            let (rec, x) = h
                .join()
                .map_err(|_| Error::Coordinator("worker thread panicked".into()))??;
            recorders.push(rec);
            replicas.push(x);
        }
        let mut recorder = recorders.swap_remove(0);
        if topo.is_exact() {
            // Replication invariant: all replicas ended at the same iterate.
            for r in 1..k {
                if replicas[r] != replicas[0] {
                    return Err(Error::Coordinator(format!(
                        "replica divergence: worker {r} differs from worker 0"
                    )));
                }
            }
        } else {
            recorder.set_scalar("consensus_dist", consensus_distance(&replicas));
        }
        Ok(ThreadedRun { recorder, replicas })
    }

    /// Out-of-band diagnostic allgather at eval steps: every rank contributes
    /// `[X_t ‖ X̄]` as raw f32 (deliberately NOT billed to traffic — it exists
    /// so rank 0 can evaluate cross-replica metrics, not as protocol traffic);
    /// every rank must call it at the same step so the barrier matches.
    /// Returns `Some((per-rank iterates, mean ergodic average))` on rank 0,
    /// `None` elsewhere.
    fn diag_exchange(
        rank: usize,
        k: usize,
        d: usize,
        transport: &AllGather,
        x_world: &[f32],
        ergodic: &[f32],
    ) -> Result<Option<(Vec<Vec<f32>>, Vec<f32>)>> {
        let mut diag = Vec::with_capacity(8 * d);
        for &x in x_world.iter().chain(ergodic.iter()) {
            diag.extend_from_slice(&x.to_le_bytes());
        }
        let got = transport.exchange(rank, diag)?;
        if rank != 0 {
            return Ok(None);
        }
        let mut iterates = Vec::with_capacity(k);
        let mut mean_avg = vec![0.0f32; d];
        for p in &got {
            let f: Vec<f32> = p
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            if f.len() != 2 * d {
                return Err(Error::Coordinator("bad diagnostic payload".into()));
            }
            iterates.push(f[..d].to_vec());
            for (m, &x) in mean_avg.iter_mut().zip(f[d..].iter()) {
                *m += x / k as f32;
            }
        }
        Ok(Some((iterates, mean_avg)))
    }

    #[allow(clippy::too_many_arguments)]
    fn worker_loop(
        rank: usize,
        cfg: &ExperimentConfig,
        op: Arc<dyn qgenx::oracle::Operator>,
        transport: Arc<AllGather>,
        collective: Arc<dyn Collective>,
        net: NetModel,
        schedule: UpdateSchedule,
        d: usize,
    ) -> Result<(Recorder, Vec<f32>)> {
        // A panic anywhere below must not strand peers at the barrier.
        let _poison = transport.guard();
        let k = cfg.workers;
        let exact = collective.topology().is_exact();
        // Ranks whose payloads this worker consumes (all K for exact
        // topologies; the closed neighborhood under gossip).
        let recv_ranks = collective.recipients(rank);
        let k_local = recv_ranks.len();
        let root = Rng::seed_from(cfg.seed);
        let mut oracle =
            build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (rank as u64 + 1) * 0x9e37)?;
        let mut comp = Compressor::from_config(&cfg.quant, root.fork(rank as u64 + 101))?;
        let mut state = QGenX::new(
            cfg.algo.variant,
            &vec![0.0f32; d],
            k_local,
            cfg.algo.gamma0,
            cfg.algo.adaptive_step,
        );
        let gap_eval =
            if rank == 0 { GapEvaluator::around_solution(op.as_ref(), 2.0) } else { None };
        let mut traffic = TrafficStats::default();
        let mut links = LinkTraffic::new();
        let mut rec = Recorder::new();
        let mut g_buf = vec![0.0f32; d];
        let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];

        // One exchange round: contribute my wire bytes through the collective
        // and decode the payloads it logically delivers into `decoded`
        // (sender-indexed). Callers read `decoded` directly when exact —
        // zero-copy, as the seed did — and take the `recv_ranks` view under
        // gossip.
        let exchange = |payload: Vec<u8>,
                        comp: &Compressor,
                        decoded: &mut Vec<Vec<f32>>,
                        traffic: &mut TrafficStats,
                        links: &mut LinkTraffic|
         -> Result<()> {
            let (recv, bits) = collective.exchange(transport.as_ref(), rank, payload)?;
            collective.record_round(&bits, &net, traffic);
            if rank == 0 {
                links.record(collective.as_ref(), &bits);
            }
            for (sender, bytes) in &recv {
                comp.decompress(bytes, &mut decoded[*sender])?;
            }
            Ok(())
        };
        let neighborhood_view = |decoded: &[Vec<f32>]| -> Vec<Vec<f32>> {
            recv_ranks.iter().map(|&r| decoded[r].clone()).collect()
        };

        for t in 1..=cfg.iters {
            // (1) stat exchange + synchronized level update — always global
            //     (full-mesh), so codecs stay identical on every worker.
            if schedule.is_update(t) && comp.is_quantized() {
                let payload = comp.stats_payload();
                let got = transport.exchange(rank, payload)?;
                let bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
                traffic.record_allgather(&bits, &net);
                let rank_order: Vec<&[u8]> = got.iter().map(|p| p.as_slice()).collect();
                comp.update_levels(&rank_order)?;
            }

            // (2) base exchange
            let base_vecs: Vec<Vec<f32>> = if let Some(xq) = state.base_query() {
                let t0 = Instant::now();
                oracle.sample(&xq, &mut g_buf);
                let (bytes, _) = comp.compress(&g_buf)?;
                traffic.add_compute(t0.elapsed().as_secs_f64());
                exchange(bytes, &comp, &mut decoded, &mut traffic, &mut links)?;
                if exact { decoded.clone() } else { neighborhood_view(&decoded) }
            } else {
                Vec::new()
            };

            // (3) extrapolate (identical on every replica when exact; the
            //     replica's own neighborhood mean under gossip)
            let x_half = state.extrapolate(&base_vecs)?;

            // (4) half-step exchange
            let t0 = Instant::now();
            oracle.sample(&x_half, &mut g_buf);
            let (bytes, _) = comp.compress(&g_buf)?;
            traffic.add_compute(t0.elapsed().as_secs_f64());
            exchange(bytes, &comp, &mut decoded, &mut traffic, &mut links)?;
            if exact {
                state.update(&decoded)?;
            } else {
                state.update(&neighborhood_view(&decoded))?;
            }

            // (5) evaluation
            let eval_now = t % cfg.eval_every.max(1) == 0 || t == cfg.iters;
            if eval_now && !exact {
                if let Some((iterates, mean_avg)) = diag_exchange(
                    rank,
                    k,
                    d,
                    &transport,
                    &state.x_world(),
                    &state.ergodic_average(),
                )? {
                    rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
                    if let Some(ev) = &gap_eval {
                        rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                        rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
                    }
                }
            } else if eval_now && rank == 0 {
                let avg = state.ergodic_average();
                if let Some(ev) = &gap_eval {
                    rec.push("gap", t as f64, ev.gap(op.as_ref(), &avg));
                    rec.push("dist", t as f64, ev.dist_to_center(&avg));
                }
            }
            if eval_now && rank == 0 {
                rec.push("gamma", t as f64, state.gamma());
                rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
                rec.push("sim_time_cum", t as f64, traffic.total_time());
                comp.record_layer_series(&mut rec, t as f64);
            }
        }
        if rank == 0 {
            rec.set_scalar("total_bits", traffic.bits_sent as f64);
            rec.set_scalar("rounds", traffic.rounds as f64);
            rec.set_scalar("level_updates", comp.updates() as f64);
            rec.set_scalar("sim_net_time", traffic.sim_net_time);
            rec.set_scalar("compute_time", traffic.compute_time);
            rec.set_scalar("wire_links", links.links() as f64);
            rec.set_scalar("max_link_bytes", links.max_link_bytes());
            comp.emit_layer_scalars(&mut rec);
        }
        Ok((rec, state.x_world()))
    }

    /// Local-steps worker loop (`local.steps = H ≥ 2`): `H` private
    /// extra-gradient iterations per communication round, then a quantized
    /// **model-delta** exchange over the collective and a resync onto the
    /// (neighborhood-)averaged delta. The threaded twin of
    /// [`super::inline::run_experiment`]'s local runner; see that runner's
    /// docs for the algorithm and the `coordinator::mod` docs for the
    /// exact / gossip / local runner split.
    ///
    /// Diagnostics: the `sync_drift` series is computed on rank 0 from the
    /// *decoded* deltas it already holds (no extra barrier) — under exact
    /// topologies that is the global pre-averaging drift up to quantization
    /// noise; under gossip it is rank 0's neighborhood view.
    #[allow(clippy::too_many_arguments)]
    fn worker_local_loop(
        rank: usize,
        cfg: &ExperimentConfig,
        op: Arc<dyn qgenx::oracle::Operator>,
        transport: Arc<AllGather>,
        collective: Arc<dyn Collective>,
        net: NetModel,
        d: usize,
    ) -> Result<(Recorder, Vec<f32>)> {
        // A panic anywhere below must not strand peers at the barrier.
        let _poison = transport.guard();
        let k = cfg.workers;
        let h = cfg.local.steps;
        let recv_ranks = collective.recipients(rank);
        let root = Rng::seed_from(cfg.seed);
        let mut oracle =
            build_oracle(op.clone(), &cfg.problem, cfg.seed ^ (rank as u64 + 1) * 0x9e37)?;
        let mut comp = Compressor::from_config(&cfg.quant, root.fork(rank as u64 + 101))?;
        let mut rep = LocalQGenX::new(
            cfg.algo.variant,
            &vec![0.0f32; d],
            cfg.algo.gamma0,
            cfg.algo.adaptive_step,
        );
        let gap_eval =
            if rank == 0 { GapEvaluator::around_solution(op.as_ref(), 2.0) } else { None };
        let adaptive = cfg.quant.adapts() && comp.is_quantized();
        let update_every = cfg.quant.update_every;
        // Same early-warmup due point as the inline local runner (and, in
        // spirit, the per-step runners' UpdateSchedule) — deterministic in t,
        // so every rank fires the stat barrier at the same syncs.
        let mut next_stat_due = update_every.min(10);
        let mut traffic = TrafficStats::default();
        let mut links = LinkTraffic::new();
        let mut rec = Recorder::new();
        let mut sync_acc = SyncAccounting::new();
        let mut g_buf = vec![0.0f32; d];
        let mut decoded: Vec<Vec<f32>> = vec![vec![0.0f32; d]; k];

        for t in 1..=cfg.iters {
            // (1) One private extra-gradient iteration — no wire.
            let t0 = Instant::now();
            rep.local_round(oracle.as_mut(), &mut g_buf)?;
            traffic.add_compute(t0.elapsed().as_secs_f64());

            // (2) Delta synchronization every H iterations (plus final).
            if t % h == 0 || t == cfg.iters {
                let t0 = Instant::now();
                let delta = rep.delta();
                let (bytes, _) = comp.compress(&delta)?;
                traffic.add_compute(t0.elapsed().as_secs_f64());
                let (recv, bits) = collective.exchange(transport.as_ref(), rank, bytes)?;
                let bits_before = traffic.bits_sent;
                collective.record_round(&bits, &net, &mut traffic);
                for (sender, payload) in &recv {
                    comp.decompress(payload, &mut decoded[*sender])?;
                }
                if rank == 0 {
                    links.record(collective.as_ref(), &bits);
                    // Drift of the decoded deltas == drift of the pre-averaging
                    // iterates (the common sync base cancels in the deviations).
                    let view: Vec<Vec<f32>> =
                        recv_ranks.iter().map(|&r| decoded[r].clone()).collect();
                    sync_acc.record(
                        &mut rec,
                        t,
                        consensus_distance(&view),
                        traffic.bits_sent - bits_before,
                    );
                }
                let mut mean = vec![0.0f32; d];
                for &w in &recv_ranks {
                    for (m, &x) in mean.iter_mut().zip(decoded[w].iter()) {
                        *m += x / recv_ranks.len() as f32;
                    }
                }
                rep.resync(&mean)?;

                // Control plane: global stat pooling at the first sync on or
                // after each due point (identical schedule on all ranks).
                if adaptive && update_every != 0 && t >= next_stat_due {
                    let payload = comp.stats_payload();
                    let got = transport.exchange(rank, payload)?;
                    let stat_bits: Vec<u64> = got.iter().map(|p| 8 * p.len() as u64).collect();
                    traffic.record_allgather(&stat_bits, &net);
                    let rank_order: Vec<&[u8]> = got.iter().map(|p| p.as_slice()).collect();
                    comp.update_levels(&rank_order)?;
                    next_stat_due = t + update_every;
                }
            }

            // (3) Evaluation via the shared out-of-band diagnostic exchange
            //     (every rank calls it so the barrier matches; local mode
            //     evaluates at the mean ergodic average across replicas, like
            //     the inline local runner).
            if t % cfg.eval_every.max(1) == 0 || t == cfg.iters {
                if let Some((iterates, mean_avg)) = diag_exchange(
                    rank,
                    k,
                    d,
                    &transport,
                    &rep.x_world(),
                    &rep.ergodic_average(),
                )? {
                    rec.push("consensus_dist", t as f64, consensus_distance(&iterates));
                    if let Some(ev) = &gap_eval {
                        rec.push("gap", t as f64, ev.gap(op.as_ref(), &mean_avg));
                        rec.push("dist", t as f64, ev.dist_to_center(&mean_avg));
                    }
                    rec.push("gamma", t as f64, rep.gamma());
                    rec.push("bits_cum", t as f64, traffic.bits_sent as f64);
                    rec.push("sim_time_cum", t as f64, traffic.total_time());
                    comp.record_layer_series(&mut rec, t as f64);
                }
            }
        }
        if rank == 0 {
            rec.set_scalar("total_bits", traffic.bits_sent as f64);
            rec.set_scalar("rounds", traffic.rounds as f64);
            rec.set_scalar("level_updates", comp.updates() as f64);
            rec.set_scalar("sim_net_time", traffic.sim_net_time);
            rec.set_scalar("compute_time", traffic.compute_time);
            rec.set_scalar("wire_links", links.links() as f64);
            rec.set_scalar("max_link_bytes", links.max_link_bytes());
            rec.set_scalar("local_steps", h as f64);
            sync_acc.emit_scalars(&mut rec);
            comp.emit_layer_scalars(&mut rec);
        }
        // Report the final *sync base* as this replica's end state: the run
        // ends on a sync, the consensus point is computed by identical
        // arithmetic on every rank (bit-identical under exact topologies — the
        // replication invariant `run_threaded` asserts), whereas the raw
        // iterate can sit an origin-shift rounding ulp off it.
        Ok((rec, rep.sync_base().to_vec()))
    }

}

// ---------------------------------------------------------------------------
// Comparison contract: everything deterministic must match exactly.
// ---------------------------------------------------------------------------

/// Series and scalars must match point-for-point and name-for-name.
/// Exemptions: `sim_time_cum` (series) and `compute_time` (scalar) contain
/// measured wall-clock compute, which no refactor can reproduce.
fn assert_recorders_match(tag: &str, legacy: &Recorder, new: &Recorder) {
    let ka: Vec<&String> = legacy.series.keys().collect();
    let kb: Vec<&String> = new.series.keys().collect();
    assert_eq!(ka, kb, "{tag}: series name sets must match");
    for (name, s) in &legacy.series {
        if name == "sim_time_cum" {
            continue;
        }
        let n = new.get(name).unwrap();
        assert_eq!(s.xs(), n.xs(), "{tag}/{name}: eval steps must match");
        assert_eq!(s.ys(), n.ys(), "{tag}/{name}: values must match bit-for-bit");
    }
    let sa: Vec<&String> = legacy.scalars.keys().collect();
    let sb: Vec<&String> = new.scalars.keys().collect();
    assert_eq!(sa, sb, "{tag}: scalar name sets must match");
    for (name, v) in &legacy.scalars {
        if name == "compute_time" {
            continue;
        }
        assert_eq!(*v, new.scalar(name).unwrap(), "{tag}/{name}: scalar must match");
    }
}

fn base_cfg() -> qgenx::config::ExperimentConfig {
    let mut cfg = qgenx::config::ExperimentConfig::default();
    cfg.workers = 3;
    cfg.iters = 300;
    cfg.eval_every = 75;
    cfg.problem.kind = "quadratic".into();
    cfg.problem.dim = 16;
    cfg.problem.noise = "absolute".into();
    cfg.problem.sigma = 0.3;
    cfg.quant.update_every = 100;
    cfg
}

// ------------------------------------------------------------ inline -------

#[test]
fn inline_exact_matches_legacy_for_all_variants() {
    use qgenx::config::Variant;
    for v in [Variant::DualAveraging, Variant::DualExtrapolation, Variant::OptimisticDualAveraging]
    {
        let mut cfg = base_cfg();
        cfg.algo.variant = v;
        cfg.iters = 250;
        let old = legacy::run_experiment(&cfg).unwrap();
        let new = qgenx::coordinator::run_experiment(&cfg).unwrap();
        assert_recorders_match(&format!("exact/{v:?}"), &old, &new);
    }
}

#[test]
fn inline_exact_aggregating_topologies_match_legacy() {
    for kind in ["star", "ring", "hierarchical"] {
        let mut cfg = base_cfg();
        cfg.workers = 6;
        cfg.iters = 150;
        cfg.eval_every = 50;
        cfg.topo.kind = kind.into();
        let old = legacy::run_experiment(&cfg).unwrap();
        let new = qgenx::coordinator::run_experiment(&cfg).unwrap();
        assert_recorders_match(&format!("exact/{kind}"), &old, &new);
    }
}

#[test]
fn inline_exact_layerwise_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.quant.bucket_size = 8;
    cfg.quant.layers.names = vec!["lo".into(), "hi".into()];
    cfg.quant.layers.bounds = vec![8];
    cfg.quant.layers.budget = 4.0;
    let old = legacy::run_experiment(&cfg).unwrap();
    let new = qgenx::coordinator::run_experiment(&cfg).unwrap();
    assert_recorders_match("exact/layerwise", &old, &new);
}

#[test]
fn inline_gossip_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.workers = 8;
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.topo.kind = "gossip".into();
    cfg.topo.degree = 3;
    let old = legacy::run_experiment(&cfg).unwrap();
    let new = qgenx::coordinator::run_experiment(&cfg).unwrap();
    assert_recorders_match("gossip", &old, &new);
}

#[test]
fn inline_local_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.local.steps = 4;
    let old = legacy::run_experiment(&cfg).unwrap();
    let new = qgenx::coordinator::run_experiment(&cfg).unwrap();
    assert_recorders_match("local", &old, &new);
}

#[test]
fn inline_local_composed_with_gossip_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.workers = 8;
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.local.steps = 5;
    cfg.topo.kind = "gossip".into();
    cfg.topo.degree = 3;
    let old = legacy::run_experiment(&cfg).unwrap();
    let new = qgenx::coordinator::run_experiment(&cfg).unwrap();
    assert_recorders_match("local+gossip", &old, &new);
}

#[test]
fn qsgda_matches_legacy() {
    let cfg = base_cfg();
    let old = legacy::run_qsgda_baseline(&cfg).unwrap();
    let new = qgenx::coordinator::run_qsgda_baseline(&cfg).unwrap();
    assert_recorders_match("qsgda", &old, &new);
    // The baseline's CLI contract: exactly one summary scalar, as seeded.
    assert_eq!(new.scalars.len(), 1, "qsgda must emit only total_bits");
}

// ---------------------------------------------------------- threaded -------

#[test]
fn threaded_exact_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.iters = 150;
    cfg.eval_every = 50;
    cfg.quant.update_every = 60;
    let old = legacy::run_threaded(&cfg).unwrap();
    let new = qgenx::coordinator::run_threaded(&cfg).unwrap();
    assert_eq!(old.replicas, new.replicas, "exact replicas must match bit-for-bit");
    assert_recorders_match("threaded/exact", &old.recorder, &new.recorder);
}

#[test]
fn threaded_gossip_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.workers = 5;
    cfg.iters = 120;
    cfg.eval_every = 40;
    cfg.topo.kind = "gossip".into();
    cfg.topo.degree = 2;
    let old = legacy::run_threaded(&cfg).unwrap();
    let new = qgenx::coordinator::run_threaded(&cfg).unwrap();
    assert_eq!(old.replicas, new.replicas, "gossip replicas are deterministic per rank");
    assert_recorders_match("threaded/gossip", &old.recorder, &new.recorder);
}

#[test]
fn threaded_local_matches_legacy() {
    let mut cfg = base_cfg();
    cfg.iters = 200;
    cfg.eval_every = 50;
    cfg.local.steps = 4;
    let old = legacy::run_threaded(&cfg).unwrap();
    let new = qgenx::coordinator::run_threaded(&cfg).unwrap();
    assert_eq!(old.replicas, new.replicas, "local sync bases must match bit-for-bit");
    assert_recorders_match("threaded/local", &old.recorder, &new.recorder);
}

#[test]
fn threaded_fp32_bit_accounting_matches_legacy_exactly() {
    // fp32 payloads are deterministic in size, so even the transport
    // fabric's whole-byte accounting must agree to the bit.
    let mut cfg = base_cfg();
    cfg.iters = 60;
    cfg.eval_every = 30;
    cfg.quant.mode = qgenx::config::QuantMode::Fp32;
    let old = legacy::run_threaded(&cfg).unwrap();
    let new = qgenx::coordinator::run_threaded(&cfg).unwrap();
    assert_eq!(old.replicas, new.replicas);
    assert_recorders_match("threaded/fp32", &old.recorder, &new.recorder);
}
