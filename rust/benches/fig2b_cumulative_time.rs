//! E3 — Figure 2b: accumulated backpropagation + gradient-exchange time
//! vs training step, per mode. The paper's plot shows three straight lines
//! with FP32 steepest; the gap between them is the communication saving.

use qgenx::benchkit::{scaled, Table};
use qgenx::net::NetModel;
use qgenx::runtime::{default_artifacts_dir, Runtime};
use qgenx::train::{GanMode, GanTrainConfig, GanTrainer};

fn main() {
    println!("== E3 / Figure 2b: cumulative backprop + exchange time ==\n");
    let Some(dir) = default_artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut rt = Runtime::open(dir).unwrap();
    let steps = scaled(60, 12);
    let probe = (steps / 6).max(1);

    let mut series: Vec<(GanMode, Vec<(usize, f64)>)> = Vec::new();
    for mode in [GanMode::Fp32, GanMode::Uq8, GanMode::Uq4] {
        let cfg = GanTrainConfig {
            mode,
            steps,
            workers: 3,
            eval_every: steps + 1,
            ..Default::default()
        };
        let mut tr = GanTrainer::new(&mut rt, cfg, NetModel::gbe()).unwrap();
        for _ in 0..2 {
            tr.step().unwrap(); // compile warmup, untimed
        }
        tr.reset_counters();
        let mut pts = Vec::new();
        for t in 1..=steps {
            tr.step().unwrap();
            if t % probe == 0 {
                pts.push((t, tr.phases.total()));
            }
        }
        series.push((mode, pts));
    }

    let mut table = Table::new(&["step", "FP32 cum (s)", "UQ8 cum (s)", "UQ4 cum (s)"]);
    let mut csv = Vec::new();
    for i in 0..series[0].1.len() {
        let row = vec![
            series[0].1[i].0.to_string(),
            format!("{:.3}", series[0].1[i].1),
            format!("{:.3}", series[1].1[i].1),
            format!("{:.3}", series[2].1[i].1),
        ];
        table.row(&row);
        csv.push(row);
    }
    table.print();

    let fp32 = series[0].1.last().unwrap().1;
    let uq8 = series[1].1.last().unwrap().1;
    let uq4 = series[2].1.last().unwrap().1;
    println!(
        "\nfinal cumulative time: FP32 {fp32:.3}s, UQ8 {uq8:.3}s ({:.1}% saved), UQ4 {uq4:.3}s ({:.1}% saved)",
        (1.0 - uq8 / fp32) * 100.0,
        (1.0 - uq4 / fp32) * 100.0
    );
    println!("paper shape (Fig. 2b): three near-linear curves, FP32 on top.");
    qgenx::benchkit::write_csv(
        "results/fig2b_cumtime.csv",
        &["step", "fp32", "uq8", "uq4"],
        &csv,
    )
    .unwrap();
    println!("csv -> results/fig2b_cumtime.csv");
}
