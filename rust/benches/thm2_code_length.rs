//! E8 — Theorem 2 (code-length bound): measured wire bits per coordinate
//! vs the `C_b + (1−p₀)d + (H(L)+1)d` bound, for every Ψ codec, across
//! level schemes; the QSGD-style Elias-on-uniform-levels configuration is
//! the baseline the paper's bound is compared against.
//!
//! Expected shape: bound ≥ measured for Huffman (the bound is stated for
//! the optimal per-symbol prefix code); Huffman-on-QAda-levels ≤
//! Elias-on-uniform ≤ fixed-width.

use qgenx::benchkit::{scaled, Table};
use qgenx::coding::SymbolCodec;
use qgenx::quant::{
    code_length_bound, encode_vector, optimize_levels, quantize, symbol_probs, Levels,
    SufficientStats, WireCodec,
};
use qgenx::util::Rng;

fn main() {
    println!("== E8 / Theorem 2: expected code length — measured vs bound ==\n");
    let trials = scaled(20, 4);
    let mut rng = Rng::seed_from(0xE8);
    let d = 16384usize;

    let mut table = Table::new(&[
        "s", "scheme", "codec", "bits/coord (measured)", "bound/coord (Thm 2)", "fp32 ratio",
    ]);
    let mut csv = Vec::new();

    for &s in &[7usize, 15, 31] {
        // Estimate stats once per s.
        let mut stats = SufficientStats::new(512, 2);
        for _ in 0..8 {
            let g = rng.gaussian_vec(d, 1.0);
            stats.observe(&g);
        }
        for scheme in ["uniform", "adaptive"] {
            let levels = match scheme {
                "uniform" => Levels::uniform(s),
                _ => optimize_levels(&stats, s, None, 8).unwrap(),
            };
            let probs = symbol_probs(&stats, &levels);
            for codec_kind in
                [SymbolCodec::Fixed, SymbolCodec::EliasGamma, SymbolCodec::Huffman]
            {
                let codec = match codec_kind {
                    SymbolCodec::Huffman => {
                        WireCodec::new(codec_kind, &levels, Some(&probs)).unwrap()
                    }
                    _ => WireCodec::new(codec_kind, &levels, None).unwrap(),
                };
                let mut bits_acc = 0u64;
                for _ in 0..trials {
                    let v = rng.gaussian_vec(d, 1.0);
                    let qv = quantize(&v, &levels, 2, 0, &mut rng).unwrap();
                    let (_, bits) = encode_vector(&qv, &codec).unwrap();
                    bits_acc += bits;
                }
                let measured = bits_acc as f64 / trials as f64 / d as f64;
                let bound = code_length_bound(&probs, d, 32, 1) / d as f64;
                if codec_kind == SymbolCodec::Huffman {
                    assert!(
                        measured <= bound * 1.05,
                        "Thm 2 violated: measured {measured} > bound {bound} (s={s} {scheme})"
                    );
                }
                let row = vec![
                    s.to_string(),
                    scheme.to_string(),
                    codec.kind.name().to_string(),
                    format!("{measured:.3}"),
                    format!("{bound:.3}"),
                    format!("{:.1}x", 32.0 / measured),
                ];
                table.row(&row);
                csv.push(row);
            }
        }
    }
    table.print();
    qgenx::benchkit::write_csv(
        "results/thm2_codelen.csv",
        &["s", "scheme", "codec", "measured_bits", "bound_bits", "fp32_ratio"],
        &csv,
    )
    .unwrap();
    println!("\ncsv -> results/thm2_codelen.csv");
    println!(
        "paper shape: Huffman(QAda) beats Elias(uniform) beats fixed-width; bound holds for the \
         optimal prefix code."
    );
}
