//! The method-cadence seam.
//!
//! Every first-class algorithm is a [`MethodState`]: a phase machine that
//! *owns* its per-iteration oracle-call/exchange cadence and exposes it to
//! the coordinator as a round-plan —
//!
//! ```text
//! base_query()  -> Option<query>   // None ⇒ no base exchange this step
//! extrapolate(decoded base duals)  -> half-step query
//! update(decoded half duals)
//! ```
//!
//! The policies in `coordinator::policy` execute that plan verbatim; they
//! no longer assume the Q-GenX two-call/two-exchange shape. A method that
//! returns `None` from [`MethodState::base_query`] costs ONE oracle call
//! and ONE quantized exchange per iteration, and every policy (exact,
//! gossip, local-steps) picks that up for free.
//!
//! The adaptive step-size rule ([`crate::algo::AdaptiveStepSize`]) is
//! shared across methods — it only needs the base/half dual pairs, which
//! every cadence produces.
//!
//! Methods: [`crate::algo::QGenX`] (the paper template, all three
//! variants), [`crate::algo::PastExtraGradient`] (`algo::past`, single
//! call), [`crate::algo::AndersonEg`] (`algo::anderson`, safeguarded
//! EG-AA(1)).

use crate::algo::anderson::AndersonEg;
use crate::algo::past::PastExtraGradient;
use crate::algo::qgenx::QGenX;
use crate::config::{AlgoConfig, Method};
use crate::error::Result;

/// One first-class algorithm behind the method-cadence seam.
///
/// Implementations are deterministic phase machines over *decoded* dual
/// vectors — quantization, wire formats, topologies and fabrics all live
/// on the policy side of the seam.
pub trait MethodState: Send {
    /// Where workers must evaluate the *base* oracle query this iteration,
    /// or `None` if the method supplies its own base internally (no base
    /// exchange happens at all — the single-call cadence).
    fn base_query(&self) -> Option<Vec<f32>>;

    /// Consume the decoded base duals (`&[]` iff [`Self::base_query`]
    /// returned `None`) and produce the half-step query point.
    fn extrapolate(&mut self, base_vectors: &[Vec<f32>]) -> Result<Vec<f32>>;

    /// Consume the decoded half-step duals; completes the iteration.
    fn update(&mut self, half_vectors: &[Vec<f32>]) -> Result<()>;

    /// Current step-size γ_t.
    fn gamma(&self) -> f64;

    /// Completed iterations.
    fn iteration(&self) -> usize;

    /// Current iterate in world coordinates.
    fn x_world(&self) -> Vec<f32>;

    /// The averaged point the method's rate certifies (ergodic average of
    /// the half-step/extrapolated iterates).
    fn ergodic_average(&self) -> Vec<f32>;

    /// Translate the iterate to `target` (world coordinates) — the
    /// local-steps resynchronization primitive. Only legal between
    /// iterations.
    fn shift_world(&mut self, target: &[f32]) -> Result<()>;

    /// Cumulative oracle calls *per worker* after [`Self::iteration`]
    /// completed iterations.
    fn oracle_calls(&self) -> u64;

    /// Quantized data exchanges per iteration — a structural constant of
    /// the cadence (2.0 for two-exchange methods, 1.0 for single-call).
    fn exchanges_per_step(&self) -> f64;

    /// Extra method-specific diagnostics to surface as run scalars
    /// (name, value). Empty by default.
    fn method_scalars(&self) -> Vec<(&'static str, f64)> {
        Vec::new()
    }

    fn clone_box(&self) -> Box<dyn MethodState>;
}

impl Clone for Box<dyn MethodState> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

impl MethodState for QGenX {
    fn base_query(&self) -> Option<Vec<f32>> {
        QGenX::base_query(self)
    }

    fn extrapolate(&mut self, base_vectors: &[Vec<f32>]) -> Result<Vec<f32>> {
        QGenX::extrapolate(self, base_vectors)
    }

    fn update(&mut self, half_vectors: &[Vec<f32>]) -> Result<()> {
        QGenX::update(self, half_vectors)
    }

    fn gamma(&self) -> f64 {
        QGenX::gamma(self)
    }

    fn iteration(&self) -> usize {
        QGenX::iteration(self)
    }

    fn x_world(&self) -> Vec<f32> {
        QGenX::x_world(self)
    }

    fn ergodic_average(&self) -> Vec<f32> {
        QGenX::ergodic_average(self)
    }

    fn shift_world(&mut self, target: &[f32]) -> Result<()> {
        QGenX::shift_world(self, target)
    }

    fn oracle_calls(&self) -> u64 {
        // DE queries base + half; DA skips the base (V̂_t ≡ 0); OptDA
        // reuses the previous half — one call each.
        let per_step = match self.variant() {
            crate::config::Variant::DualExtrapolation => 2,
            crate::config::Variant::DualAveraging
            | crate::config::Variant::OptimisticDualAveraging => 1,
        };
        per_step * self.iteration() as u64
    }

    fn exchanges_per_step(&self) -> f64 {
        match self.variant() {
            crate::config::Variant::DualExtrapolation => 2.0,
            crate::config::Variant::DualAveraging
            | crate::config::Variant::OptimisticDualAveraging => 1.0,
        }
    }

    fn clone_box(&self) -> Box<dyn MethodState> {
        Box::new(self.clone())
    }
}

/// Construct the configured method's state for `k` workers at `x0`.
///
/// This is the one dispatch point on [`Method`]; everything downstream
/// (policies, the LM trainer, benches) is method-agnostic.
pub fn method_state(algo: &AlgoConfig, x0: &[f32], k: usize) -> Box<dyn MethodState> {
    match algo.method {
        Method::QGenX => {
            Box::new(QGenX::new(algo.variant, x0, k, algo.gamma0, algo.adaptive_step))
        }
        Method::Peg => Box::new(PastExtraGradient::new(x0, k, algo.gamma0, algo.adaptive_step)),
        Method::EgAa => Box::new(AndersonEg::new(x0, k, algo.gamma0, algo.adaptive_step)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;

    fn algo(method: Method) -> AlgoConfig {
        AlgoConfig { method, gamma0: 0.5, ..AlgoConfig::default() }
    }

    #[test]
    fn factory_dispatches_on_method() {
        let x0 = vec![0.5; 6];
        let q = method_state(&algo(Method::QGenX), &x0, 3);
        assert!(q.base_query().is_some(), "default DE queries a base");
        assert_eq!(q.exchanges_per_step(), 2.0);
        let p = method_state(&algo(Method::Peg), &x0, 3);
        assert!(p.base_query().is_none(), "PEG never queries a base");
        assert_eq!(p.exchanges_per_step(), 1.0);
        let a = method_state(&algo(Method::EgAa), &x0, 3);
        assert!(a.base_query().is_some());
        assert_eq!(a.exchanges_per_step(), 2.0);
        for s in [&q, &p, &a] {
            assert_eq!(s.x_world(), x0);
            assert_eq!(s.iteration(), 0);
            assert_eq!(s.oracle_calls(), 0);
        }
    }

    #[test]
    fn qgenx_cadence_constants_track_the_variant() {
        let x0 = vec![0.0; 4];
        for (variant, calls, exch) in [
            (Variant::DualExtrapolation, 4u64, 2.0),
            (Variant::DualAveraging, 2, 1.0),
            (Variant::OptimisticDualAveraging, 2, 1.0),
        ] {
            let mut s: Box<dyn MethodState> =
                Box::new(QGenX::new(variant, &x0, 2, 0.5, true));
            for _ in 0..2 {
                let base = match s.base_query() {
                    Some(_) => vec![vec![0.1; 4]; 2],
                    None => Vec::new(),
                };
                s.extrapolate(&base).unwrap();
                s.update(&[vec![0.2; 4], vec![0.3; 4]]).unwrap();
            }
            assert_eq!(s.oracle_calls(), calls, "{variant:?}");
            assert_eq!(s.exchanges_per_step(), exch, "{variant:?}");
        }
    }

    #[test]
    fn boxed_state_clones_independently() {
        let mut a = method_state(&algo(Method::Peg), &[1.0, 2.0], 1);
        let b = a.clone();
        a.extrapolate(&[]).unwrap();
        a.update(&[vec![0.5, 0.5]]).unwrap();
        assert_eq!(a.iteration(), 1);
        assert_eq!(b.iteration(), 0, "clone is a deep copy");
    }
}
