//! Time-series recording + CSV emission for experiments.
//!
//! Every driver/bench records into a [`Recorder`]; `to_csv` writes the
//! machine-readable companion of the printed tables so EXPERIMENTS.md can
//! reference exact numbers.

use crate::error::Result;
use std::collections::BTreeMap;

/// Consensus distance across `K` replica iterates — the disagreement
/// metric for inexact (gossip) topologies, recorded as the
/// `consensus_dist` series/scalar:
///
/// `C({x_r}) = sqrt( (1/K) Σ_r ‖x_r − x̄‖² )`,  `x̄ = (1/K) Σ_r x_r`
///
/// i.e. the RMS deviation of the replicas from their mean. Exact
/// topologies keep replicas bit-identical, so `C ≡ 0`; under gossip, `C`
/// tracks how far neighborhood averaging has let the replicas drift —
/// the quantity decentralized-VI analyses (e.g. Beznosikov et al. 2021)
/// bound via the spectral gap of the communication graph.
pub fn consensus_distance(replicas: &[Vec<f32>]) -> f64 {
    let k = replicas.len();
    if k == 0 {
        return 0.0;
    }
    let d = replicas[0].len();
    debug_assert!(replicas.iter().all(|r| r.len() == d));
    let mut mean = vec![0.0f64; d];
    for r in replicas {
        for (m, &x) in mean.iter_mut().zip(r.iter()) {
            *m += x as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= k as f64;
    }
    let mut sum_sq = 0.0f64;
    for r in replicas {
        for (m, &x) in mean.iter().zip(r.iter()) {
            let dev = x as f64 - m;
            sum_sq += dev * dev;
        }
    }
    (sum_sq / k as f64).sqrt()
}

/// Per-synchronization accounting for the local-steps runners: how far the
/// replicas drifted during each local segment (consensus distance of the
/// iterates *before* the delta averaging) and how many wire bits each sync
/// round cost. Recorded as the `sync_drift` / `sync_bits` series plus the
/// `syncs` / `bits_per_sync` / `mean_sync_drift` summary scalars.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncAccounting {
    syncs: u64,
    bits: u64,
    drift_sum: f64,
    stale: u64,
}

impl SyncAccounting {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sync round at local iteration `t`: `drift` is the
    /// pre-averaging consensus distance, `bits` the wire bits this round
    /// put on the network (data plane only — stat rounds are accounted
    /// separately, as in the other runners).
    pub fn record(&mut self, rec: &mut Recorder, t: usize, drift: f64, bits: u64) {
        self.syncs += 1;
        self.bits += bits;
        self.drift_sum += drift;
        rec.push("sync_drift", t as f64, drift);
        rec.push("sync_bits", t as f64, bits as f64);
    }

    pub fn syncs(&self) -> u64 {
        self.syncs
    }

    /// Count `n` stale substitutions: sync slots where a straggler missed
    /// the bounded-staleness deadline and a carried-forward delta stood in
    /// for its fresh one (the semi-async local-steps path).
    pub fn add_stale(&mut self, n: u64) {
        self.stale += n;
    }

    pub fn stale(&self) -> u64 {
        self.stale
    }

    /// Emit the summary scalars (call once at the end of a run). The
    /// `stale_syncs` scalar only appears when substitutions happened, so
    /// fully-synchronous runs keep their scalar set (and parity baselines)
    /// unchanged.
    pub fn emit_scalars(&self, rec: &mut Recorder) {
        rec.set_scalar("syncs", self.syncs as f64);
        if self.syncs > 0 {
            rec.set_scalar("bits_per_sync", self.bits as f64 / self.syncs as f64);
            rec.set_scalar("mean_sync_drift", self.drift_sum / self.syncs as f64);
        }
        if self.stale > 0 {
            rec.set_scalar("stale_syncs", self.stale as f64);
        }
    }
}

/// One named scalar series indexed by step.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub points: Vec<(f64, f64)>, // (step/x, value)
}

impl Series {
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    pub fn xs(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.0).collect()
    }

    pub fn ys(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.1).collect()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// A bundle of named series plus scalar summary values.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Series>,
    pub scalars: BTreeMap<String, f64>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, x: f64, y: f64) {
        self.series.entry(name.to_string()).or_default().push(x, y);
    }

    pub fn set_scalar(&mut self, name: &str, v: f64) {
        self.scalars.insert(name.to_string(), v);
    }

    pub fn scalar(&self, name: &str) -> Option<f64> {
        self.scalars.get(name).copied()
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Write all series into one long-format CSV: `series,x,y`.
    pub fn to_csv(&self, path: &str) -> Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::from("series,x,y\n");
        for (name, s) in &self.series {
            for (x, y) in &s.points {
                out.push_str(&format!("{name},{x},{y}\n"));
            }
        }
        for (name, v) in &self.scalars {
            out.push_str(&format!("scalar:{name},0,{v}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Merge another recorder (prefixing its series names).
    pub fn merge_prefixed(&mut self, prefix: &str, other: &Recorder) {
        for (name, s) in &other.series {
            let e = self.series.entry(format!("{prefix}/{name}")).or_default();
            e.points.extend_from_slice(&s.points);
        }
        for (name, v) in &other.scalars {
            self.scalars.insert(format!("{prefix}/{name}"), *v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut r = Recorder::new();
        r.push("gap", 1.0, 0.5);
        r.push("gap", 2.0, 0.25);
        r.set_scalar("total_bits", 1234.0);
        assert_eq!(r.get("gap").unwrap().len(), 2);
        assert_eq!(r.get("gap").unwrap().last(), Some(0.25));
        assert_eq!(r.scalar("total_bits"), Some(1234.0));
        assert_eq!(r.get("gap").unwrap().xs(), vec![1.0, 2.0]);
    }

    #[test]
    fn csv_roundtrip_format() {
        let mut r = Recorder::new();
        r.push("a", 0.0, 1.0);
        r.set_scalar("s", 2.0);
        let path = "/tmp/qgenx_test_metrics.csv";
        r.to_csv(path).unwrap();
        let contents = std::fs::read_to_string(path).unwrap();
        assert!(contents.contains("series,x,y"));
        assert!(contents.contains("a,0,1"));
        assert!(contents.contains("scalar:s,0,2"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn consensus_distance_basics() {
        // identical replicas → zero
        let same = vec![vec![1.0f32, 2.0]; 4];
        assert_eq!(consensus_distance(&same), 0.0);
        // two replicas at ±1 around 0 in one coordinate: RMS deviation = 1
        let two = vec![vec![1.0f32], vec![-1.0f32]];
        assert!((consensus_distance(&two) - 1.0).abs() < 1e-12);
        // scale-equivariant
        let twox = vec![vec![2.0f32], vec![-2.0f32]];
        assert!((consensus_distance(&twox) - 2.0).abs() < 1e-12);
        assert_eq!(consensus_distance(&[]), 0.0);
    }

    #[test]
    fn sync_accounting_series_and_scalars() {
        let mut rec = Recorder::new();
        let mut acc = SyncAccounting::new();
        acc.record(&mut rec, 4, 0.5, 1000);
        acc.record(&mut rec, 8, 1.5, 3000);
        assert_eq!(acc.syncs(), 2);
        acc.emit_scalars(&mut rec);
        assert_eq!(rec.scalar("syncs"), Some(2.0));
        assert_eq!(rec.scalar("bits_per_sync"), Some(2000.0));
        assert_eq!(rec.scalar("mean_sync_drift"), Some(1.0));
        assert_eq!(rec.get("sync_drift").unwrap().xs(), vec![4.0, 8.0]);
        assert_eq!(rec.get("sync_bits").unwrap().ys(), vec![1000.0, 3000.0]);
        // empty accounting emits only the count
        let mut rec2 = Recorder::new();
        SyncAccounting::new().emit_scalars(&mut rec2);
        assert_eq!(rec2.scalar("syncs"), Some(0.0));
        assert_eq!(rec2.scalar("bits_per_sync"), None);
    }

    #[test]
    fn stale_syncs_scalar_only_appears_after_substitutions() {
        let mut rec = Recorder::new();
        let mut acc = SyncAccounting::new();
        acc.record(&mut rec, 4, 0.5, 1000);
        acc.emit_scalars(&mut rec);
        assert_eq!(rec.scalar("stale_syncs"), None, "fully-sync run adds no scalar");
        acc.add_stale(2);
        acc.add_stale(1);
        assert_eq!(acc.stale(), 3);
        let mut rec2 = Recorder::new();
        acc.emit_scalars(&mut rec2);
        assert_eq!(rec2.scalar("stale_syncs"), Some(3.0));
    }

    #[test]
    fn merge_prefixes() {
        let mut a = Recorder::new();
        let mut b = Recorder::new();
        b.push("loss", 1.0, 2.0);
        b.set_scalar("x", 1.0);
        a.merge_prefixed("worker0", &b);
        assert!(a.get("worker0/loss").is_some());
        assert_eq!(a.scalar("worker0/x"), Some(1.0));
    }
}
